//! Fixture tests for `cargo xtask lint`: each rule has a passing and a
//! failing fixture under `tests/fixtures/` (deliberately outside the
//! crate's compile targets, so they may violate the invariants), plus
//! one integration test that runs the full lint over the real repo and
//! requires zero findings — the same gate CI runs.

use std::path::Path;
use xtask::{
    check_env_knobs, check_optflags, check_relaxed, check_unsafe_safety, lint_repo, scan,
    SourceFile,
};

#[test]
fn scanner_separates_channels() {
    let src = "let s = \"// SAFETY: in a string\"; // real comment\n";
    let sc = scan(src);
    assert!(sc.code[0].contains("let s ="));
    assert!(sc.strings[0].contains("// SAFETY: in a string"));
    assert!(!sc.code[0].contains("SAFETY"));
    assert!(sc.comments[0].contains("real comment"));
    // channels are column-aligned
    assert_eq!(sc.code[0].chars().count(), sc.strings[0].chars().count());
    assert_eq!(sc.code[0].chars().count(), sc.comments[0].chars().count());
}

#[test]
fn scanner_handles_raw_strings_lifetimes_and_chars() {
    let src = "fn f<'a>(x: &'a u32) -> char {\n    let _r = r#\"unsafe \"quoted\" inside\"#;\n    let _c = '\"';\n    '{'\n}\n";
    let sc = scan(src);
    // the raw string's `unsafe` must land in the strings channel
    assert!(!sc.code.iter().any(|l| l.contains("unsafe")));
    assert!(sc.strings[1].contains("unsafe \"quoted\" inside"));
    // lifetimes stay code; the quote and brace char literals do not
    // open a string (the `{` on line 4 would otherwise swallow line 5)
    assert!(sc.code[0].contains("'a u32"));
    assert!(sc.strings[2].contains('"'));
    assert_eq!(sc.code[4].trim(), "}");
}

#[test]
fn scanner_handles_nested_block_comments() {
    let src = "/* outer /* inner */ still comment */ let x = 1;\n";
    let sc = scan(src);
    assert!(sc.comments[0].contains("still comment"));
    assert!(sc.code[0].contains("let x = 1;"));
    assert!(!sc.code[0].contains("outer"));
}

#[test]
fn unsafe_rule_passes_on_documented_sites() {
    let f = SourceFile::new("src/ok.rs", include_str!("fixtures/safety_ok.rs"));
    let findings = check_unsafe_safety(&f);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn unsafe_rule_flags_missing_safety_comment() {
    let f = SourceFile::new("src/bad.rs", include_str!("fixtures/safety_missing.rs"));
    let findings = check_unsafe_safety(&f);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "unsafe-safety");
    assert_eq!(findings[0].line, 7);
}

#[test]
fn env_knob_rule_flags_undocumented_reads() {
    let src = [SourceFile::new("src/knobs.rs", include_str!("fixtures/knobs_src.rs"))];
    let findings = check_env_knobs(&src, include_str!("fixtures/knobs_arch.md"));
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "env-knob");
    assert!(findings[0].message.contains("SANDSLASH_FIXTURE_MISSING"));
    assert_eq!(findings[0].line, 6);
}

#[test]
fn optflags_rule_requires_doc_row_and_test_toggle() {
    let opts = SourceFile::new("src/engine/opts.rs", include_str!("fixtures/optflags_src.rs"));
    let tests = [SourceFile::new("tests/diff.rs", include_str!("fixtures/optflags_tests.rs"))];
    let findings = check_optflags(&opts, include_str!("fixtures/optflags_arch.md"), &tests);
    assert_eq!(findings.len(), 2, "{findings:?}");
    assert!(findings
        .iter()
        .any(|f| f.rule == "optflags-doc" && f.message.contains("beta")));
    assert!(findings
        .iter()
        .any(|f| f.rule == "optflags-test" && f.message.contains("gamma")));
}

#[test]
fn relaxed_rule_flags_only_the_cross_module_write() {
    let files = [
        SourceFile::new("src/gauge.rs", include_str!("fixtures/relaxed_decl.rs")),
        SourceFile::new("src/writer.rs", include_str!("fixtures/relaxed_writer.rs")),
    ];
    let findings = check_relaxed(&files, "");
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "relaxed-ordering");
    assert_eq!(findings[0].file, "src/writer.rs");
    assert!(findings[0].message.contains("`level`"));
}

#[test]
fn relaxed_allowlist_clears_audited_sites_and_flags_stale_entries() {
    let files = [
        SourceFile::new("src/gauge.rs", include_str!("fixtures/relaxed_decl.rs")),
        SourceFile::new("src/writer.rs", include_str!("fixtures/relaxed_writer.rs")),
    ];
    let cleared = check_relaxed(&files, "# audited\nsrc/writer.rs:level\n");
    assert!(cleared.is_empty(), "{cleared:?}");
    let stale = check_relaxed(&files, "src/writer.rs:level\nsrc/nowhere.rs:ghost\n");
    assert_eq!(stale.len(), 1, "{stale:?}");
    assert_eq!(stale[0].rule, "relaxed-allowlist");
    assert!(stale[0].message.contains("src/nowhere.rs:ghost"));
}

#[test]
fn missing_root_is_an_error_not_a_pass() {
    assert!(lint_repo(Path::new("/nonexistent/fixture/root")).is_err());
}

/// The gate CI runs: the repository itself must be lint-clean.
#[test]
fn the_repo_itself_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
    let findings = lint_repo(&root).expect("lint must run on the repo");
    assert!(
        findings.is_empty(),
        "repo lint findings:\n{}",
        findings.iter().map(ToString::to_string).collect::<Vec<_>>().join("\n")
    );
}
