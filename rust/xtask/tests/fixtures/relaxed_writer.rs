//! Fixture: a foreign module writing someone else's atomic.

use crate::Gauge;
use std::sync::atomic::Ordering;

pub fn read(g: &Gauge) -> u64 {
    g.level.load(Ordering::Relaxed) // loads are never flagged
}

pub fn publish(g: &Gauge) {
    g.level.store(7, Ordering::Release); // Release is fine cross-module
}

pub fn try_claim(g: &Gauge) -> bool {
    // Relaxed *failure* ordering is fine: success ordering publishes.
    g.level
        .compare_exchange(0, 1, Ordering::AcqRel, Ordering::Relaxed)
        .is_ok()
}

pub fn poke(g: &Gauge) {
    g.level.store(9, Ordering::Relaxed); // the one true violation
}
