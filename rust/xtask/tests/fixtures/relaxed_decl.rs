//! Fixture: the module that owns the atomic.

use std::sync::atomic::{AtomicU64, Ordering};

pub struct Gauge {
    pub level: AtomicU64,
}

impl Gauge {
    pub fn bump(&self) {
        // same-file Relaxed write: the declaring module owns the protocol
        self.level.fetch_add(1, Ordering::Relaxed);
    }
}
