//! Fixture: two env-knob reads, one documented, one not.

pub fn knobs() -> (Option<String>, Option<String>) {
    let a = std::env::var("SANDSLASH_FIXTURE_DOCUMENTED").ok();
    // mentions of SANDSLASH_FIXTURE_COMMENTED in comments must not count
    let b = std::env::var("SANDSLASH_FIXTURE_MISSING").ok();
    (a, b)
}
