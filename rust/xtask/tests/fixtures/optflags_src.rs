//! Fixture `OptFlags` with three fields exercising both sub-rules.

/// Fixture flags.
pub struct OptFlags {
    /// documented and tested: clean
    pub alpha: bool,
    /// tested but undocumented: `optflags-doc`
    pub beta: bool,
    /// documented but untested: `optflags-test`
    pub gamma: bool,
}
