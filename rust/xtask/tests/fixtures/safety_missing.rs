//! Negative fixture: one undocumented `unsafe` block.

pub fn dispatch(p: *const u32) -> u32 {
    let _msg = "unsafe in a string literal must not count";
    // unsafe in a plain comment must not count either
    let _lambda = || 0;
    unsafe { *p }
}
