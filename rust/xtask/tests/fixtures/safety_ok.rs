//! Positive fixture: every `unsafe` site is documented.

/// Reads through the pointer.
///
/// # Safety
/// `p` must be valid for reads.
#[inline]
pub unsafe fn read_raw(p: *const u32) -> u32 {
    // SAFETY: the caller upholds the contract documented above.
    unsafe { *p }
}

pub fn dispatch(p: *const u32) -> u32 {
    // SAFETY: fixture pointer is always valid where this is called.
    // (two-line comment runs must be walked in full)
    unsafe { read_raw(p) }
}

// SAFETY: fixture type has no interior state.
unsafe impl Send for Token {}

pub struct Token;

fn same_line(p: *const u32) -> u32 {
    unsafe { *p } // SAFETY: same-line comments count too
}
