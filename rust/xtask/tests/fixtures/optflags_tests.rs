//! Fixture differential test file.

fn toggles() {
    let mut f = base();
    f.alpha = !f.alpha;
    f.beta = !f.beta;
    let _ = probe.gamma(); // method call: must NOT count as a field toggle
}
