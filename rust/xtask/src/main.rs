//! `cargo xtask lint` — run the repo-invariant lint and exit non-zero
//! on findings. See the library crate docs for the rules.

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "usage: cargo xtask lint [--root <repo-root>]";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => {
            let root = match root_arg(&args[1..]) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("{e}");
                    return ExitCode::from(2);
                }
            };
            match xtask::lint_repo(&root) {
                Ok(findings) if findings.is_empty() => {
                    println!("xtask lint: clean");
                    ExitCode::SUCCESS
                }
                Ok(findings) => {
                    for f in &findings {
                        eprintln!("{f}");
                    }
                    eprintln!("xtask lint: {} finding(s)", findings.len());
                    ExitCode::FAILURE
                }
                Err(e) => {
                    eprintln!("xtask lint: {e}");
                    ExitCode::from(2)
                }
            }
        }
        _ => {
            eprintln!("{USAGE}");
            ExitCode::from(2)
        }
    }
}

fn root_arg(rest: &[String]) -> Result<PathBuf, String> {
    match rest {
        // xtask lives at <repo>/rust/xtask, so the default root is two up.
        [] => Ok(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("..")),
        [flag, path] if flag == "--root" => Ok(PathBuf::from(path)),
        _ => Err(USAGE.to_string()),
    }
}
