//! Repo-invariant lint for the Sandslash workspace: `cargo xtask lint`.
//!
//! Pure text analysis over the checked-in sources — no `syn`, no
//! `rustc` internals, no dependencies at all, so it runs on the same
//! zero-dependency toolchain as the crate itself (PR 8). Four
//! invariants:
//!
//! 1. **`unsafe` is documented** (`unsafe-safety`): every line of code
//!    containing the `unsafe` keyword needs a `// SAFETY:` comment on
//!    the same line or in the contiguous comment/attribute block
//!    directly above it (a `/// # Safety` doc section counts).
//! 2. **Env knobs are documented** (`env-knob`): every `SANDSLASH_*`
//!    string literal under `rust/src` must appear in the
//!    "## Environment knobs" table of ARCHITECTURE.md.
//! 3. **`OptFlags` fields are live kill switches** (`optflags-doc` /
//!    `optflags-test`): every `pub` field of `OptFlags` must be listed
//!    in ARCHITECTURE.md's "## Where `OptFlags` branch" table and be
//!    toggled by name (`.field`) somewhere under `rust/tests`.
//! 4. **No cross-module Relaxed writes** (`relaxed-ordering`): an
//!    atomic store/RMW with `Ordering::Relaxed` whose target atomic is
//!    declared in a *different* file is flagged unless the write site
//!    is recorded in `rust/RELAXED_ALLOWLIST.txt`. A Relaxed *failure*
//!    ordering on `compare_exchange` is fine (the success ordering is
//!    what publishes), and same-file writes are the declaring module's
//!    own business. Stale allowlist entries are flagged too
//!    (`relaxed-allowlist`), so the audit record cannot rot.
//!
//! The scanner underneath splits each source line into three
//! column-aligned channels — code, string-literal contents, comment
//! text — so `unsafe` in a doc comment or `SANDSLASH_FOO` in a plain
//! comment never miscounts. It understands line comments, nested block
//! comments, escapes, raw strings, and the char-literal-vs-lifetime
//! ambiguity of `'`.

#![forbid(unsafe_code)]
#![warn(missing_docs)]

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// One lint violation, pointing at a repo-relative file and 1-based line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Short rule identifier, e.g. `unsafe-safety`.
    pub rule: &'static str,
    /// Repo-relative path (forward slashes) of the offending file.
    pub file: String,
    /// 1-based line number the finding anchors to.
    pub line: usize,
    /// Human-readable explanation with the suggested fix.
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}:{}: {}", self.rule, self.file, self.line, self.message)
    }
}

/// A source file split into per-line code / string / comment channels.
pub struct SourceFile {
    /// Repo-relative path (forward slashes).
    pub path: String,
    /// The channel-separated text.
    pub sc: Scanned,
}

impl SourceFile {
    /// Scan `source` and tag it with `path` for findings.
    pub fn new(path: impl Into<String>, source: &str) -> Self {
        Self { path: path.into(), sc: scan(source) }
    }
}

/// Per-line channel separation of one Rust source file. The three
/// vectors are parallel (one entry per line) and column-aligned: a
/// character appears in exactly one channel, space-padded in the other
/// two, so byte offsets are comparable across channels.
pub struct Scanned {
    /// Everything outside strings and comments (keywords, idents, punctuation).
    pub code: Vec<String>,
    /// String- and char-literal contents (delimiters stay in `code`).
    pub strings: Vec<String>,
    /// Line- and block-comment text, including the comment markers.
    pub comments: Vec<String>,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum St {
    Code,
    Line,
    Block(usize),
    Str,
    RawStr(usize),
}

#[derive(Clone, Copy)]
enum Chan {
    Code,
    Str,
    Com,
}

#[derive(Default)]
struct LineBufs {
    code: String,
    strings: String,
    comments: String,
}

impl LineBufs {
    fn put(&mut self, chan: Chan, c: char) {
        let (code, strings, comments) = match chan {
            Chan::Code => (c, ' ', ' '),
            Chan::Str => (' ', c, ' '),
            Chan::Com => (' ', ' ', c),
        };
        self.code.push(code);
        self.strings.push(strings);
        self.comments.push(comments);
    }
}

fn is_ident_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

fn is_ident_char_at(cs: &[char], j: usize) -> bool {
    cs.get(j).is_some_and(|&c| c == '_' || c.is_alphanumeric())
}

/// Split Rust source into code / string / comment channels.
pub fn scan(src: &str) -> Scanned {
    let cs: Vec<char> = src.chars().collect();
    let mut sc = Scanned { code: Vec::new(), strings: Vec::new(), comments: Vec::new() };
    let mut cur = LineBufs::default();
    let mut st = St::Code;
    let mut i = 0usize;
    while i < cs.len() {
        let c = cs[i];
        if c == '\n' {
            sc.code.push(std::mem::take(&mut cur.code));
            sc.strings.push(std::mem::take(&mut cur.strings));
            sc.comments.push(std::mem::take(&mut cur.comments));
            if st == St::Line {
                st = St::Code;
            }
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                if c == '/' && cs.get(i + 1) == Some(&'/') {
                    cur.put(Chan::Com, '/');
                    cur.put(Chan::Com, '/');
                    st = St::Line;
                    i += 2;
                } else if c == '/' && cs.get(i + 1) == Some(&'*') {
                    cur.put(Chan::Com, '/');
                    cur.put(Chan::Com, '*');
                    st = St::Block(1);
                    i += 2;
                } else if c == '"' {
                    cur.put(Chan::Code, '"');
                    st = St::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !is_ident_char_at(&cs, i.wrapping_sub(1)) {
                    // Possible raw (or raw byte) string: `r"`, `r#"`, `br##"`...
                    let mut j = i;
                    if cs[j] == 'b' {
                        j += 1;
                    }
                    let mut started = false;
                    if cs.get(j) == Some(&'r') {
                        let mut k = j + 1;
                        let mut hashes = 0usize;
                        while cs.get(k) == Some(&'#') {
                            hashes += 1;
                            k += 1;
                        }
                        if cs.get(k) == Some(&'"') {
                            for &d in &cs[i..=k] {
                                cur.put(Chan::Code, d);
                            }
                            st = St::RawStr(hashes);
                            i = k + 1;
                            started = true;
                        }
                    }
                    if !started {
                        cur.put(Chan::Code, c);
                        i += 1;
                    }
                } else if c == '\'' && (cs.get(i + 1) == Some(&'\\') || cs.get(i + 2) == Some(&'\''))
                {
                    // Char literal (an escape, or `'x'`); otherwise `'` is a lifetime.
                    i = consume_char_literal(&cs, i, &mut cur);
                } else {
                    cur.put(Chan::Code, c);
                    i += 1;
                }
            }
            St::Line => {
                cur.put(Chan::Com, c);
                i += 1;
            }
            St::Block(d) => {
                if c == '*' && cs.get(i + 1) == Some(&'/') {
                    cur.put(Chan::Com, '*');
                    cur.put(Chan::Com, '/');
                    st = if d == 1 { St::Code } else { St::Block(d - 1) };
                    i += 2;
                } else if c == '/' && cs.get(i + 1) == Some(&'*') {
                    cur.put(Chan::Com, '/');
                    cur.put(Chan::Com, '*');
                    st = St::Block(d + 1);
                    i += 2;
                } else {
                    cur.put(Chan::Com, c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    cur.put(Chan::Str, '\\');
                    match cs.get(i + 1) {
                        Some(&'\n') | None => i += 1,
                        Some(&e) => {
                            cur.put(Chan::Str, e);
                            i += 2;
                        }
                    }
                } else if c == '"' {
                    cur.put(Chan::Code, '"');
                    st = St::Code;
                    i += 1;
                } else {
                    cur.put(Chan::Str, c);
                    i += 1;
                }
            }
            St::RawStr(h) => {
                let closes = c == '"' && (1..=h).all(|k| cs.get(i + k) == Some(&'#'));
                if closes {
                    cur.put(Chan::Code, '"');
                    for _ in 0..h {
                        cur.put(Chan::Code, '#');
                    }
                    i += 1 + h;
                    st = St::Code;
                } else {
                    cur.put(Chan::Str, c);
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() {
        sc.code.push(cur.code);
        sc.strings.push(cur.strings);
        sc.comments.push(cur.comments);
    }
    sc
}

fn consume_char_literal(cs: &[char], start: usize, cur: &mut LineBufs) -> usize {
    cur.put(Chan::Code, '\'');
    let mut i = start + 1;
    let mut budget = 12usize; // longest is '\u{10FFFF}'
    while i < cs.len() && budget > 0 {
        match cs[i] {
            '\'' => {
                cur.put(Chan::Code, '\'');
                return i + 1;
            }
            '\n' => return i, // malformed; let the caller flush the line
            '\\' => {
                cur.put(Chan::Str, '\\');
                if let Some(&e) = cs.get(i + 1) {
                    if e == '\n' {
                        return i + 1;
                    }
                    cur.put(Chan::Str, e);
                }
                i += 2;
                budget = budget.saturating_sub(2);
            }
            d => {
                cur.put(Chan::Str, d);
                i += 1;
                budget -= 1;
            }
        }
    }
    i
}

/// Whole-word (identifier-boundary) search.
pub fn has_word(hay: &str, word: &str) -> bool {
    let b = hay.as_bytes();
    let mut from = 0;
    while let Some(p) = hay[from..].find(word) {
        let s = from + p;
        let e = s + word.len();
        from = e;
        let pre_ok = s == 0 || !is_ident_byte(b[s - 1]);
        let post_ok = !b.get(e).copied().is_some_and(is_ident_byte);
        if pre_ok && post_ok {
            return true;
        }
    }
    false
}

/// Extract the text of a `## <header>` markdown section (up to the
/// next `## ` header, or end of document). Empty if the header is
/// absent.
fn section<'a>(md: &'a str, header: &str) -> &'a str {
    let Some(p) = md.find(header) else { return "" };
    let rest = &md[p + header.len()..];
    match rest.find("\n## ") {
        Some(q) => &rest[..q],
        None => rest,
    }
}

// ---------------------------------------------------------------- rule 1

/// Rule 1: every `unsafe` in the code channel needs a `// SAFETY:`
/// comment on the same line or in the contiguous comment/attribute
/// block directly above (a `# Safety` doc section counts).
pub fn check_unsafe_safety(f: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, code) in f.sc.code.iter().enumerate() {
        if !has_word(code, "unsafe") {
            continue;
        }
        if f.sc.comments[i].contains("SAFETY:") {
            continue;
        }
        // Walk the contiguous run of comment-only and attribute lines
        // directly above; a blank or plain code line ends the run.
        let mut run = String::new();
        let mut j = i;
        while j > 0 {
            j -= 1;
            let code_t = f.sc.code[j].trim();
            let com_t = f.sc.comments[j].trim();
            let is_attr = code_t.starts_with('#');
            let comment_only = code_t.is_empty() && !com_t.is_empty();
            if is_attr || comment_only {
                run.push_str(com_t);
                run.push('\n');
            } else {
                break;
            }
        }
        if run.contains("SAFETY:") || run.contains("# Safety") {
            continue;
        }
        out.push(Finding {
            rule: "unsafe-safety",
            file: f.path.clone(),
            line: i + 1,
            message: "`unsafe` without a `// SAFETY:` comment (same line or the comment \
                      block directly above) or a `# Safety` doc section"
                .to_string(),
        });
    }
    out
}

// ---------------------------------------------------------------- rule 2

/// Rule 2: every `SANDSLASH_*` name appearing in a string literal must
/// be documented in ARCHITECTURE.md's "## Environment knobs" section.
pub fn check_env_knobs(src_files: &[SourceFile], architecture_md: &str) -> Vec<Finding> {
    let knobs = section(architecture_md, "## Environment knobs");
    let mut out = Vec::new();
    let mut seen: BTreeSet<(String, String)> = BTreeSet::new();
    for f in src_files {
        for (ln, s) in f.sc.strings.iter().enumerate() {
            for name in env_names(s) {
                if knobs.contains(&name) {
                    continue;
                }
                if seen.insert((f.path.clone(), name.clone())) {
                    out.push(Finding {
                        rule: "env-knob",
                        file: f.path.clone(),
                        line: ln + 1,
                        message: format!(
                            "`{name}` is read here but missing from the \
                             \"## Environment knobs\" table in ARCHITECTURE.md"
                        ),
                    });
                }
            }
        }
    }
    out
}

fn env_names(s: &str) -> Vec<String> {
    const PREFIX: &str = "SANDSLASH_";
    let b = s.as_bytes();
    let mut v = Vec::new();
    let mut from = 0;
    while let Some(p) = s[from..].find(PREFIX) {
        let start = from + p;
        let pre_ok = start == 0 || !is_ident_byte(b[start - 1]);
        let mut e = start + PREFIX.len();
        while b.get(e).is_some_and(|&c| c == b'_' || c.is_ascii_uppercase() || c.is_ascii_digit()) {
            e += 1;
        }
        if pre_ok && e > start + PREFIX.len() {
            v.push(s[start..e].to_string());
        }
        from = e;
    }
    v
}

// ---------------------------------------------------------------- rule 3

/// Rule 3: every `pub` field of `OptFlags` must be (a) named in
/// ARCHITECTURE.md's "## Where `OptFlags` branch" table and (b)
/// toggled by name (`.field`, not a method call) in some test file, so
/// a grep-able differential test proves the kill switch is live.
pub fn check_optflags(
    opts: &SourceFile,
    architecture_md: &str,
    test_files: &[SourceFile],
) -> Vec<Finding> {
    let mut out = Vec::new();
    let fields = optflags_fields(opts);
    if fields.is_empty() {
        out.push(Finding {
            rule: "optflags",
            file: opts.path.clone(),
            line: 1,
            message: "could not parse any `pub` field out of `struct OptFlags` — \
                      if the struct moved, update xtask's lint"
                .to_string(),
        });
        return out;
    }
    let table = section(architecture_md, "## Where `OptFlags` branch");
    for (name, line) in &fields {
        if !table.contains(&format!("`{name}`")) {
            out.push(Finding {
                rule: "optflags-doc",
                file: opts.path.clone(),
                line: *line,
                message: format!(
                    "`OptFlags::{name}` is not documented in ARCHITECTURE.md's \
                     \"## Where `OptFlags` branch\" table"
                ),
            });
        }
        let referenced = test_files
            .iter()
            .any(|tf| tf.sc.code.iter().any(|l| has_field_ref(l, name)));
        if !referenced {
            out.push(Finding {
                rule: "optflags-test",
                file: opts.path.clone(),
                line: *line,
                message: format!(
                    "`OptFlags::{name}` is never toggled as `.{name}` in rust/tests — \
                     add a differential test flipping it"
                ),
            });
        }
    }
    out
}

fn optflags_fields(sf: &SourceFile) -> Vec<(String, usize)> {
    let mut v = Vec::new();
    let mut inside = false;
    for (i, l) in sf.sc.code.iter().enumerate() {
        let t = l.trim();
        if !inside {
            if t.contains("pub struct OptFlags") {
                inside = true;
            }
            continue;
        }
        if t == "}" {
            break;
        }
        if let Some(rest) = t.strip_prefix("pub ") {
            if let Some(colon) = rest.find(':') {
                let name = rest[..colon].trim();
                if !name.is_empty() && name.bytes().all(is_ident_byte) {
                    v.push((name.to_string(), i + 1));
                }
            }
        }
    }
    v
}

fn has_field_ref(line: &str, name: &str) -> bool {
    let pat = format!(".{name}");
    let b = line.as_bytes();
    let mut from = 0;
    while let Some(p) = line[from..].find(&pat) {
        let s = from + p;
        let e = s + pat.len();
        from = e;
        // `.field(` is a method call, `.fieldx` a longer name — skip both.
        let bad = b.get(e).copied().is_some_and(|c| c == b'(' || is_ident_byte(c));
        if !bad {
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------- rule 4

const ATOMIC_TYPES: &[&str] = &[
    "AtomicBool",
    "AtomicU8",
    "AtomicU16",
    "AtomicU32",
    "AtomicU64",
    "AtomicUsize",
    "AtomicI8",
    "AtomicI16",
    "AtomicI32",
    "AtomicI64",
    "AtomicIsize",
];

const WRITE_METHODS: &[&str] = &[
    "store",
    "swap",
    "compare_exchange",
    "fetch_update",
    "fetch_add",
    "fetch_sub",
    "fetch_and",
    "fetch_nand",
    "fetch_or",
    "fetch_xor",
    "fetch_max",
    "fetch_min",
];

/// Map every `name: AtomicXxx` declaration (static, field, or struct
/// literal) to the set of files that declare it. References
/// (`&AtomicU64`), generics (`Vec<AtomicU64>`) and paths
/// (`atomic::AtomicU64`) are not declarations and are skipped.
pub fn atomic_declarations(files: &[SourceFile]) -> BTreeMap<String, BTreeSet<String>> {
    let mut map: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for f in files {
        for l in &f.sc.code {
            for ty in ATOMIC_TYPES {
                let b = l.as_bytes();
                let mut from = 0;
                while let Some(p) = l[from..].find(ty) {
                    let s = from + p;
                    from = s + ty.len();
                    let pre_ok = s == 0 || !is_ident_byte(b[s - 1]);
                    let post_ok = !b.get(s + ty.len()).copied().is_some_and(is_ident_byte);
                    if !pre_ok || !post_ok {
                        continue;
                    }
                    if let Some(name) = decl_name_before(l, s) {
                        map.entry(name).or_default().insert(f.path.clone());
                    }
                }
            }
        }
    }
    map
}

fn decl_name_before(l: &str, ty_start: usize) -> Option<String> {
    let b = l.as_bytes();
    let mut i = ty_start;
    while i > 0 && b[i - 1].is_ascii_whitespace() {
        i -= 1;
    }
    if i == 0 || b[i - 1] != b':' {
        return None;
    }
    if i >= 2 && b[i - 2] == b':' {
        return None; // `path::AtomicU64`, not a declaration
    }
    i -= 1;
    while i > 0 && b[i - 1].is_ascii_whitespace() {
        i -= 1;
    }
    let e = i;
    while i > 0 && is_ident_byte(b[i - 1]) {
        i -= 1;
    }
    if i == e || b[i].is_ascii_digit() {
        return None;
    }
    Some(l[i..e].to_string())
}

fn trailing_ident(s: &str) -> Option<String> {
    let t = s.trim_end();
    let b = t.as_bytes();
    let mut i = t.len();
    while i > 0 && is_ident_byte(b[i - 1]) {
        i -= 1;
    }
    if i == t.len() {
        return None;
    }
    let name = &t[i..];
    if name.bytes().all(|c| c.is_ascii_digit()) {
        return None; // tuple index like `.0`
    }
    Some(name.to_string())
}

fn balanced_end(s: &str, open: usize) -> Option<usize> {
    let mut depth = 0i32;
    for (idx, c) in s[open..].char_indices() {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(open + idx);
                }
            }
            _ => {}
        }
    }
    None
}

fn split_top(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut start = 0usize;
    for (idx, c) in s.char_indices() {
        match c {
            '(' | '[' | '{' => depth += 1,
            ')' | ']' | '}' => depth -= 1,
            ',' if depth == 0 => {
                out.push(s[start..idx].trim());
                start = idx + 1;
            }
            _ => {}
        }
    }
    let tail = s[start..].trim();
    if !tail.is_empty() || !out.is_empty() {
        out.push(tail);
    }
    out
}

/// `name` used as an atomic (`name.`) somewhere in `ctx`, at an
/// identifier boundary. `name(` method calls do not count.
fn names_dotted(ctx: &str, name: &str) -> bool {
    let b = ctx.as_bytes();
    let mut from = 0;
    while let Some(p) = ctx[from..].find(name) {
        let s = from + p;
        let e = s + name.len();
        from = e;
        let pre_ok = s == 0 || !is_ident_byte(b[s - 1]);
        if pre_ok && b.get(e) == Some(&b'.') {
            return true;
        }
    }
    false
}

fn fallback_culprit(
    decls: &BTreeMap<String, BTreeSet<String>>,
    f: &SourceFile,
    lines: &[String],
    i: usize,
    joined: &str,
) -> Option<String> {
    let mut ctx = String::new();
    for l in &lines[i.saturating_sub(2)..i] {
        ctx.push_str(l);
        ctx.push(' ');
    }
    ctx.push_str(joined);
    let mut foreign = None;
    for (name, homes) in decls {
        if names_dotted(&ctx, name) {
            if homes.contains(&f.path) {
                return None; // a same-file atomic is in play — benign
            }
            if foreign.is_none() {
                foreign = Some(name.clone());
            }
        }
    }
    foreign
}

/// Rule 4: flag `Ordering::Relaxed` on atomic writes whose target is
/// declared in a different file, unless allowlisted. The allowlist
/// format is one `path:name` entry per line (`#` comments allowed);
/// entries that match no flagged site are themselves findings.
pub fn check_relaxed(files: &[SourceFile], allowlist_text: &str) -> Vec<Finding> {
    let decls = atomic_declarations(files);
    let allow: BTreeSet<String> = allowlist_text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(String::from)
        .collect();
    let pats: Vec<String> = WRITE_METHODS.iter().map(|m| format!(".{m}")).collect();
    let mut used: BTreeSet<String> = BTreeSet::new();
    let mut out = Vec::new();
    for f in files {
        let lines = &f.sc.code;
        for (i, line) in lines.iter().enumerate() {
            if !line.contains('.') {
                continue;
            }
            // A call statement may wrap; analyse a small joined window
            // but only accept matches that start on this line.
            let end = (i + 6).min(lines.len());
            let joined = lines[i..end].join(" ");
            let first_len = line.len();
            for (m, pat) in WRITE_METHODS.iter().zip(&pats) {
                let mut from = 0;
                while let Some(p) = joined[from..].find(pat.as_str()) {
                    let dot = from + p;
                    from = dot + pat.len();
                    if dot >= first_len {
                        break;
                    }
                    let mut call = dot + pat.len();
                    if *m == "compare_exchange" && joined[call..].starts_with("_weak") {
                        call += "_weak".len();
                    }
                    if joined.as_bytes().get(call) != Some(&b'(') {
                        continue;
                    }
                    let Some(close) = balanced_end(&joined, call) else { continue };
                    let argv = split_top(&joined[call + 1..close]);
                    // The ordering that *publishes*: the success ordering
                    // for compare_exchange*, the set ordering for
                    // fetch_update, the last argument otherwise.
                    let ord = match *m {
                        "compare_exchange" => argv.get(2).copied(),
                        "fetch_update" => argv.first().copied(),
                        _ => argv.last().copied(),
                    };
                    let Some(ord) = ord else { continue };
                    if !has_word(ord, "Relaxed") {
                        continue;
                    }
                    let culprit = match trailing_ident(&joined[..dot]) {
                        Some(recv) => match decls.get(&recv) {
                            Some(homes) if homes.contains(&f.path) => None,
                            Some(_) => Some(recv),
                            None => fallback_culprit(&decls, f, lines, i, &joined),
                        },
                        None => fallback_culprit(&decls, f, lines, i, &joined),
                    };
                    let Some(name) = culprit else { continue };
                    let key = format!("{}:{name}", f.path);
                    if allow.contains(&key) {
                        used.insert(key);
                        continue;
                    }
                    let home = decls[&name].iter().next().cloned().unwrap_or_default();
                    out.push(Finding {
                        rule: "relaxed-ordering",
                        file: f.path.clone(),
                        line: i + 1,
                        message: format!(
                            "`.{m}` with `Ordering::Relaxed` on atomic `{name}` declared in \
                             {home} — a cross-module Relaxed write; use Release/AcqRel, or \
                             audit it and add `{key}` to rust/RELAXED_ALLOWLIST.txt"
                        ),
                    });
                }
            }
        }
    }
    for entry in allow.difference(&used) {
        out.push(Finding {
            rule: "relaxed-allowlist",
            file: "rust/RELAXED_ALLOWLIST.txt".to_string(),
            line: 1,
            message: format!("stale allowlist entry `{entry}` matches no flagged write site"),
        });
    }
    out
}

// ------------------------------------------------------------- repo walk

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let p = entry?.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn load_tree(root: &Path, rel: &str) -> Result<Vec<SourceFile>, String> {
    let dir = root.join(rel);
    if !dir.exists() {
        return Ok(Vec::new());
    }
    let mut paths = Vec::new();
    collect_rs(&dir, &mut paths).map_err(|e| format!("walk {}: {e}", dir.display()))?;
    paths.sort();
    let mut v = Vec::new();
    for p in paths {
        let text = fs::read_to_string(&p).map_err(|e| format!("read {}: {e}", p.display()))?;
        let rp = p.strip_prefix(root).unwrap_or(&p).to_string_lossy().replace('\\', "/");
        v.push(SourceFile::new(rp, &text));
    }
    Ok(v)
}

/// Run every lint rule over the repository rooted at `root`. Returns
/// findings sorted by (file, line, rule); empty means the repo is
/// clean. Errors only on unreadable inputs (missing ARCHITECTURE.md,
/// unreadable source tree), never on findings.
pub fn lint_repo(root: &Path) -> Result<Vec<Finding>, String> {
    let arch_path = root.join("ARCHITECTURE.md");
    let architecture = fs::read_to_string(&arch_path)
        .map_err(|e| format!("read {}: {e} (is --root the repo root?)", arch_path.display()))?;
    let allow = fs::read_to_string(root.join("rust").join("RELAXED_ALLOWLIST.txt"))
        .unwrap_or_default();

    let src = load_tree(root, "rust/src")?;
    if src.is_empty() {
        return Err(format!("no Rust sources under {}/rust/src", root.display()));
    }
    let tests = load_tree(root, "rust/tests")?;
    let benches = load_tree(root, "rust/benches")?;
    let xtask_src = load_tree(root, "rust/xtask/src")?;

    let mut findings = Vec::new();
    for f in src.iter().chain(&tests).chain(&benches).chain(&xtask_src) {
        findings.extend(check_unsafe_safety(f));
    }
    findings.extend(check_env_knobs(&src, &architecture));
    match src.iter().find(|f| f.path.ends_with("engine/opts.rs")) {
        Some(opts) => findings.extend(check_optflags(opts, &architecture, &tests)),
        None => findings.push(Finding {
            rule: "optflags",
            file: "rust/src/engine/opts.rs".to_string(),
            line: 1,
            message: "file not found — did `OptFlags` move? update xtask's lint".to_string(),
        }),
    }
    findings.extend(check_relaxed(&src, &allow));
    findings.sort_by(|a, b| {
        a.file.cmp(&b.file).then(a.line.cmp(&b.line)).then(a.rule.cmp(b.rule))
    });
    Ok(findings)
}
