//! Cross-engine integration tests: every path through the system must
//! agree on every count, across graph families, thread counts and
//! optimization presets.

use sandslash::apps::baselines::emulation::{self, System};
use sandslash::apps::baselines::{gap_tc, kclist, peregrine_fsm, pgd};
use sandslash::apps::{clique, fsm_app, motif, sl, solve, tc, MiningOutput};
use sandslash::engine::{MinerConfig, OptFlags, ProblemSpec};
use sandslash::graph::gen;
use sandslash::pattern::library;

fn cfg() -> MinerConfig {
    MinerConfig::custom(4, 16, OptFlags::hi())
}

const SYSTEMS: [System; 5] = [
    System::SandslashHi,
    System::SandslashLo,
    System::AutomineLike,
    System::PangolinLike,
    System::PeregrineLike,
];

#[test]
fn tc_all_paths_agree_across_families() {
    for g in [
        gen::rmat(9, 8, 1, &[]),
        gen::erdos_renyi(500, 0.03, 2, &[]),
        gen::barabasi_albert(600, 5, 3, &[]),
    ] {
        let want = tc::tc_hi(&g, &cfg());
        assert_eq!(gap_tc::gap_tc(&g, &cfg()), want);
        for s in SYSTEMS {
            assert_eq!(emulation::tc(&g, s, &cfg()).unwrap().value, want, "{}", s.name());
        }
    }
}

#[test]
fn cliques_all_paths_agree() {
    let g = gen::rmat(9, 9, 4, &[]);
    for k in [3, 4, 5, 6] {
        let want = clique::clique_hi(&g, k, &cfg()).0;
        assert_eq!(clique::clique_lo(&g, k, &cfg()).0, want, "lo k={k}");
        assert_eq!(kclist::kclist(&g, k, &cfg()).0, want, "kclist k={k}");
        for s in SYSTEMS {
            assert_eq!(emulation::clique(&g, k, s, &cfg()).unwrap().value, want, "{} k={k}", s.name());
        }
    }
}

#[test]
fn motifs_all_paths_agree() {
    let g = gen::rmat(8, 6, 5, &[]);
    for k in [3, 4] {
        let want = emulation::motifs(&g, k, System::SandslashHi, &cfg()).unwrap().value;
        for s in SYSTEMS {
            assert_eq!(emulation::motifs(&g, k, s, &cfg()).unwrap().value, want, "{} k={k}", s.name());
        }
        let pgd_counts = match k {
            3 => pgd::pgd_motif3(&g, &cfg()).unwrap(),
            _ => pgd::pgd_motif4(&g, &cfg()).unwrap(),
        };
        assert_eq!(pgd_counts, want, "pgd k={k}");
    }
}

#[test]
fn sl_systems_agree_on_both_patterns() {
    let g = gen::rmat(8, 7, 6, &[]);
    for p in [library::diamond(), library::cycle(4)] {
        let want = sl::sl_count(&g, &p, &cfg()).unwrap().value;
        for s in [System::SandslashHi, System::PangolinLike, System::PeregrineLike] {
            assert_eq!(emulation::sl(&g, &p, s, &cfg()).unwrap().value, want, "{}", s.name());
        }
    }
}

#[test]
fn fsm_three_engines_agree() {
    let g = gen::erdos_renyi(60, 0.08, 7, &[1, 2, 3]);
    let a = fsm_app::fsm(&g, 3, 1, &cfg()).unwrap().value;
    let b = fsm_app::fsm_bfs(&g, 3, 1, &cfg()).unwrap().value;
    let c = peregrine_fsm::peregrine_fsm(&g, 3, 1, &cfg()).unwrap().frequent;
    let key = |r: &[sandslash::engine::fsm::FrequentPattern]| {
        r.iter()
            .map(|f| (f.code.clone(), f.support))
            .collect::<Vec<_>>()
    };
    assert_eq!(key(&a), key(&b));
    assert_eq!(key(&a), key(&c));
}

#[test]
fn thread_scaling_preserves_all_results() {
    let g = gen::rmat(9, 8, 8, &[]);
    for threads in [1, 2, 8] {
        let c = MinerConfig::custom(threads, 8, OptFlags::hi());
        assert_eq!(tc::tc_hi(&g, &c), tc::tc_hi(&g, &cfg()));
        assert_eq!(clique::clique_lo(&g, 5, &c).0, clique::clique_lo(&g, 5, &cfg()).0);
        assert_eq!(motif::motif4_lo(&g, &c).unwrap(), motif::motif4_lo(&g, &cfg()).unwrap());
    }
}

#[test]
fn solve_facade_covers_all_five_apps() {
    let g = gen::rmat(8, 8, 9, &[]);
    let lg = gen::erdos_renyi(80, 0.08, 10, &[1, 2]);
    match solve(&g, &ProblemSpec::tc(), &cfg()).unwrap().value {
        MiningOutput::Count(c) => assert_eq!(c, tc::tc_hi(&g, &cfg())),
        o => panic!("{o:?}"),
    }
    match solve(&g, &ProblemSpec::clique_listing(4), &cfg()).unwrap().value {
        MiningOutput::Count(c) => assert_eq!(c, clique::clique_hi(&g, 4, &cfg()).0),
        o => panic!("{o:?}"),
    }
    match solve(&g, &ProblemSpec::motif_counting(4), &cfg()).unwrap().value {
        MiningOutput::PerPattern(rows) => {
            let got: Vec<u64> = rows.iter().map(|(_, c)| *c).collect();
            assert_eq!(got, motif::motif4_hi(&g, &cfg()).unwrap().value);
        }
        o => panic!("{o:?}"),
    }
    match solve(&g, &ProblemSpec::subgraph_listing(library::diamond()), &cfg()).unwrap().value {
        MiningOutput::Count(c) => {
            assert_eq!(c, sl::sl_count(&g, &library::diamond(), &cfg()).unwrap().value)
        }
        o => panic!("{o:?}"),
    }
    match solve(&lg, &ProblemSpec::fsm(2, 2), &cfg()).unwrap().value {
        MiningOutput::Frequent(rows) => {
            assert_eq!(rows.len(), fsm_app::fsm(&lg, 2, 2, &cfg()).unwrap().value.len());
        }
        o => panic!("{o:?}"),
    }
}

#[test]
fn every_opt_flag_is_a_live_kill_switch() {
    // Companion to `cargo xtask lint` rule 3: every `OptFlags` field
    // must have a grep-able `.field` differential toggle proving the
    // flag can be flipped without changing a count. Starting from the
    // Sandslash-Lo preset (all optimizations on, `stats` off), flip
    // each field singly — every optimization is count-preserving and
    // `stats` only adds instrumentation, so the diamond count must not
    // move.
    let g = gen::rmat(8, 7, 6, &[]);
    let p = library::diamond();
    let base = OptFlags::lo();
    let count = |opts: OptFlags| {
        sl::sl_count(&g, &p, &MinerConfig::custom(4, 16, opts)).unwrap().value
    };
    let want = count(base);
    assert!(want > 0, "degenerate input: no diamonds in the test graph");
    let mut flips: Vec<(&str, OptFlags)> = Vec::new();
    {
        let mut f = base;
        f.sb = !f.sb;
        flips.push(("sb", f));
    }
    {
        let mut f = base;
        f.dag = !f.dag;
        flips.push(("dag", f));
    }
    {
        let mut f = base;
        f.mo = !f.mo;
        flips.push(("mo", f));
    }
    {
        let mut f = base;
        f.df = !f.df;
        flips.push(("df", f));
    }
    {
        let mut f = base;
        f.mnc = !f.mnc;
        flips.push(("mnc", f));
    }
    {
        let mut f = base;
        f.mec = !f.mec;
        flips.push(("mec", f));
    }
    {
        let mut f = base;
        f.sets = !f.sets;
        flips.push(("sets", f));
    }
    {
        let mut f = base;
        f.lc = !f.lc;
        flips.push(("lc", f));
    }
    {
        let mut f = base;
        f.lg = !f.lg;
        flips.push(("lg", f));
    }
    {
        let mut f = base;
        f.extcore = !f.extcore;
        flips.push(("extcore", f));
    }
    {
        let mut f = base;
        f.plan = !f.plan;
        flips.push(("plan", f));
    }
    {
        let mut f = base;
        f.stats = !f.stats;
        flips.push(("stats", f));
    }
    for (name, flipped) in flips {
        assert_ne!(flipped, base, "the `{name}` flip must actually change the flags");
        assert_eq!(
            count(flipped),
            want,
            "flipping `{name}` changed the diamond count — a kill switch must be count-preserving"
        );
    }
}

#[test]
fn dataset_registry_consistency() {
    use sandslash::coordinator::datasets;
    // tiny datasets must load and produce consistent counts across systems
    let g = datasets::load("lj-tiny").unwrap();
    let want = tc::tc_hi(&g, &cfg());
    assert_eq!(emulation::tc(&g, System::PeregrineLike, &cfg()).unwrap().value, want);
    assert_eq!(emulation::tc(&g, System::PangolinLike, &cfg()).unwrap().value, want);
}
