//! Differential tests for the set-centric extension engine:
//!
//! 1. the adaptive kernels in `graph::setops` against naive reference
//!    implementations over randomized sorted lists (including the skew
//!    regimes that select the galloping path), and
//! 2. the set-centric DFS frontier against the scalar probe path (with
//!    and without MNC) across the pattern library on random RMAT graphs
//!    — the end-to-end guarantee that the kernel rewrite changes wall
//!    time only, never counts.

use sandslash::engine::hooks::NoHooks;
use sandslash::engine::{dfs, MinerConfig, OptFlags};
use sandslash::graph::{gen, setops};
use sandslash::pattern::{library, plan, Pattern};
use sandslash::util::bitset::BitSet;
use sandslash::util::rng::Rng;

// ---------- kernel-level differentials ----------

fn rand_sorted(rng: &mut Rng, universe: u64, max_len: u64) -> Vec<u32> {
    let len = rng.below(max_len + 1) as usize;
    let mut v: Vec<u32> = (0..len).map(|_| rng.below(universe) as u32).collect();
    v.sort_unstable();
    v.dedup();
    v
}

fn naive_intersect(a: &[u32], b: &[u32]) -> Vec<u32> {
    a.iter().copied().filter(|x| b.contains(x)).collect()
}

fn naive_difference(a: &[u32], b: &[u32]) -> Vec<u32> {
    a.iter().copied().filter(|x| !b.contains(x)).collect()
}

#[test]
fn kernels_match_naive_references_randomized() {
    let mut rng = Rng::seeded(0xDEC0DE);
    for case in 0..200 {
        // alternate balanced and heavily skewed length regimes so both
        // the merge and gallop kernels are exercised
        let (la, lb) = match case % 4 {
            0 => (64, 64),
            1 => (4, 4096),
            2 => (4096, 4),
            _ => (256, 32),
        };
        let a = rand_sorted(&mut rng, 8192, la);
        let b = rand_sorted(&mut rng, 8192, lb);
        let want = naive_intersect(&a, &b);
        assert_eq!(setops::intersect_count(&a, &b), want.len(), "case {case}");
        let mut got = Vec::new();
        setops::intersect_into(&a, &b, &mut got);
        assert_eq!(got, want, "case {case}");

        let bound = rng.below(8192) as u32;
        let want_below: Vec<u32> =
            want.iter().copied().filter(|&x| x < bound).collect();
        assert_eq!(
            setops::intersect_count_below(&a, &b, bound),
            want_below.len(),
            "case {case} bound {bound}"
        );
        got.clear();
        setops::intersect_into_below(&a, &b, bound, &mut got);
        assert_eq!(got, want_below, "case {case} bound {bound}");

        got.clear();
        setops::difference_into(&a, &b, &mut got);
        assert_eq!(got, naive_difference(&a, &b), "case {case}");

        let mut bits = BitSet::new(8192);
        for &x in &b {
            bits.insert(x as usize);
        }
        assert_eq!(
            setops::intersect_bitset_count(&a, &bits),
            want.len(),
            "case {case}"
        );
        let mut keep = a.clone();
        setops::retain_in_bitset(&mut keep, &bits);
        assert_eq!(keep, want, "case {case}");
        let mut rem = a.clone();
        setops::retain_not_in_bitset(&mut rem, &bits);
        assert_eq!(rem, naive_difference(&a, &b), "case {case}");
    }
}

// ---------- engine-level differentials ----------

fn patterns() -> Vec<(&'static str, Pattern)> {
    vec![
        ("triangle", library::triangle()),
        ("wedge", library::wedge()),
        ("diamond", library::diamond()),
        ("4-cycle", library::cycle(4)),
        ("4-clique", library::clique(4)),
        ("5-clique", library::clique(5)),
    ]
}

fn count_with(
    g: &sandslash::graph::CsrGraph,
    p: &Pattern,
    vertex_induced: bool,
    sets: bool,
    mnc: bool,
    threads: usize,
) -> u64 {
    let pl = plan(p, vertex_induced, true);
    let mut opts = OptFlags::hi();
    opts.sets = sets;
    opts.mnc = mnc;
    let cfg = MinerConfig { threads, chunk: 16, opts };
    dfs::count(g, &pl, &cfg, &NoHooks).0
}

#[test]
fn set_centric_matches_scalar_across_patterns_and_rmat_graphs() {
    for seed in [11u64, 22, 33] {
        let g = gen::rmat(9, 6, seed, &[]);
        for (name, p) in patterns() {
            for vertex_induced in [true, false] {
                let set = count_with(&g, &p, vertex_induced, true, true, 2);
                let scalar_mnc = count_with(&g, &p, vertex_induced, false, true, 2);
                let scalar_probe = count_with(&g, &p, vertex_induced, false, false, 2);
                assert_eq!(
                    set, scalar_mnc,
                    "set vs scalar+mnc: seed={seed} {name} induced={vertex_induced}"
                );
                assert_eq!(
                    set, scalar_probe,
                    "set vs scalar probe: seed={seed} {name} induced={vertex_induced}"
                );
            }
        }
    }
}

#[test]
fn set_centric_thread_invariant_on_skewed_graph() {
    // heavy-tailed RMAT: exercises the high-degree-root bitmap mode in
    // some worker tasks but not others
    let g = gen::rmat(10, 8, 7, &[]);
    for (name, p) in patterns() {
        let t1 = count_with(&g, &p, true, true, true, 1);
        let t4 = count_with(&g, &p, true, true, true, 4);
        assert_eq!(t1, t4, "{name}");
    }
}

#[test]
fn set_centric_matches_on_labeled_graph() {
    // labeled pattern vertices add the residual per-candidate label
    // filter to the set path
    let g = gen::rmat(8, 6, 5, &[1, 2, 3]);
    let mut tri = library::triangle();
    tri.set_label(0, 1);
    tri.set_label(1, 2);
    let mut cl4 = library::clique(4);
    cl4.set_label(2, 3);
    for (name, p) in [("labeled triangle", tri), ("labeled 4-clique", cl4)] {
        let set = count_with(&g, &p, true, true, true, 2);
        let scalar = count_with(&g, &p, true, false, true, 2);
        assert_eq!(set, scalar, "{name}");
    }
}
