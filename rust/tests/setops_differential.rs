//! Differential tests for the set-centric extension engine:
//!
//! 1. the adaptive kernels in `graph::setops` against naive reference
//!    implementations over randomized sorted lists (including the skew
//!    regimes that select the galloping path), and
//! 2. the set-centric DFS frontier against the scalar probe path (with
//!    and without MNC) across the pattern library on random RMAT graphs
//!    — the end-to-end guarantee that the kernel rewrite changes wall
//!    time only, never counts; and
//! 3. the PR-3 SIMD surface: the adaptive dispatch (which may select
//!    the SSE/AVX2 kernels) against the portable scalar references, on
//!    the shapes vectorized code breaks first — bound edge cases,
//!    lengths straddling the vector width, unaligned slice starts — a
//!    seeded fuzz loop over every new kernel family, and engine counts
//!    invariant under the process-global SIMD kill switch.

use sandslash::engine::hooks::NoHooks;
use sandslash::engine::{dfs, MinerConfig, OptFlags};
use sandslash::graph::{gen, setops};
use sandslash::pattern::{library, plan, Pattern};
use sandslash::util::bitset::BitSet;
use sandslash::util::rng::Rng;

// ---------- kernel-level differentials ----------

fn rand_sorted(rng: &mut Rng, universe: u64, max_len: u64) -> Vec<u32> {
    let len = rng.below(max_len + 1) as usize;
    let mut v: Vec<u32> = (0..len).map(|_| rng.below(universe) as u32).collect();
    v.sort_unstable();
    v.dedup();
    v
}

fn naive_intersect(a: &[u32], b: &[u32]) -> Vec<u32> {
    a.iter().copied().filter(|x| b.contains(x)).collect()
}

fn naive_difference(a: &[u32], b: &[u32]) -> Vec<u32> {
    a.iter().copied().filter(|x| !b.contains(x)).collect()
}

#[test]
fn kernels_match_naive_references_randomized() {
    let mut rng = Rng::seeded(0xDEC0DE);
    for case in 0..200 {
        // alternate balanced and heavily skewed length regimes so both
        // the merge and gallop kernels are exercised
        let (la, lb) = match case % 4 {
            0 => (64, 64),
            1 => (4, 4096),
            2 => (4096, 4),
            _ => (256, 32),
        };
        let a = rand_sorted(&mut rng, 8192, la);
        let b = rand_sorted(&mut rng, 8192, lb);
        let want = naive_intersect(&a, &b);
        assert_eq!(setops::intersect_count(&a, &b), want.len(), "case {case}");
        let mut got = Vec::new();
        setops::intersect_into(&a, &b, &mut got);
        assert_eq!(got, want, "case {case}");

        let bound = rng.below(8192) as u32;
        let want_below: Vec<u32> =
            want.iter().copied().filter(|&x| x < bound).collect();
        assert_eq!(
            setops::intersect_count_below(&a, &b, bound),
            want_below.len(),
            "case {case} bound {bound}"
        );
        got.clear();
        setops::intersect_into_below(&a, &b, bound, &mut got);
        assert_eq!(got, want_below, "case {case} bound {bound}");

        got.clear();
        setops::difference_into(&a, &b, &mut got);
        assert_eq!(got, naive_difference(&a, &b), "case {case}");

        let mut bits = BitSet::new(8192);
        for &x in &b {
            bits.insert(x as usize);
        }
        assert_eq!(
            setops::intersect_bitset_count(&a, &bits),
            want.len(),
            "case {case}"
        );
        let mut keep = a.clone();
        setops::retain_in_bitset(&mut keep, &bits);
        assert_eq!(keep, want, "case {case}");
        let mut rem = a.clone();
        setops::retain_not_in_bitset(&mut rem, &bits);
        assert_eq!(rem, naive_difference(&a, &b), "case {case}");
    }
}

// ---------- PR-3: SIMD kernel edge cases and scalar differentials ----------

#[test]
fn bounded_kernels_at_zero_and_past_max() {
    // long enough that the SIMD block merge is eligible when available
    let a: Vec<u32> = (0..120).map(|x| x * 3).collect();
    let b: Vec<u32> = (0..120).map(|x| x * 2).collect();
    // bound == 0: nothing survives, and the kernels must not be entered
    // with nonsense slices
    assert_eq!(setops::intersect_count_below(&a, &b, 0), 0);
    let mut out = Vec::new();
    setops::intersect_into_below(&a, &b, 0, &mut out);
    assert!(out.is_empty());
    // bound past the max element: identical to the unbounded kernel
    let all = naive_intersect(&a, &b);
    assert_eq!(setops::intersect_count_below(&a, &b, u32::MAX), all.len());
    out.clear();
    setops::intersect_into_below(&a, &b, u32::MAX, &mut out);
    assert_eq!(out, all);
    // bound exactly one past the max element
    let past = a.last().unwrap().max(b.last().unwrap()) + 1;
    assert_eq!(setops::intersect_count_below(&a, &b, past), all.len());
}

#[test]
fn lengths_straddling_vector_width_and_unaligned_starts() {
    // every length 0..=35 on one side crosses the SSE (4) and AVX2 (8)
    // block widths and the SIMD_MIN_LEN dispatch threshold; offset
    // sub-slices exercise unaligned loads
    for la in 0..=35usize {
        for lb in [0usize, 1, 3, 4, 7, 8, 9, 15, 16, 17, 31, 32, 33, 35] {
            let a: Vec<u32> = (0..la as u32).map(|x| x * 3).collect();
            let b: Vec<u32> = (0..lb as u32).map(|x| x * 2).collect();
            let want = naive_intersect(&a, &b);
            assert_eq!(setops::intersect_count(&a, &b), want.len(), "la={la} lb={lb}");
            let mut got = Vec::new();
            setops::intersect_into(&a, &b, &mut got);
            assert_eq!(got, want, "la={la} lb={lb}");
            for off_a in 0..a.len().min(4) {
                for off_b in 0..b.len().min(4) {
                    let (sa, sb) = (&a[off_a..], &b[off_b..]);
                    let want = naive_intersect(sa, sb);
                    assert_eq!(
                        setops::intersect_count(sa, sb),
                        want.len(),
                        "la={la} lb={lb} off_a={off_a} off_b={off_b}"
                    );
                    got.clear();
                    setops::intersect_into(sa, sb, &mut got);
                    assert_eq!(got, want, "la={la} lb={lb} off_a={off_a} off_b={off_b}");
                }
            }
        }
    }
}

#[test]
fn simd_vs_scalar_kernel_fuzz() {
    // fixed seed per the util/rng.rs convention; every family of the
    // PR-3 kernel surface against its scalar reference
    let mut rng = Rng::seeded(0x51D3);
    for case in 0..300u64 {
        let (la, lb) = match case % 5 {
            0 => (8u64, 8u64),
            1 => (35, 35),
            2 => (300, 300),
            3 => (64, 1024),
            _ => (1 + rng.below(200), 1 + rng.below(200)),
        };
        let a = rand_sorted(&mut rng, 4096, la);
        let b = rand_sorted(&mut rng, 4096, lb);
        // adaptive dispatch (may pick SSE/AVX2) vs the scalar merge
        assert_eq!(
            setops::intersect_count(&a, &b),
            setops::merge_count(&a, &b),
            "case {case}"
        );
        let mut got = Vec::new();
        let mut want = Vec::new();
        setops::intersect_into(&a, &b, &mut got);
        setops::merge_into(&a, &b, &mut want);
        assert_eq!(got, want, "case {case}");
        // bounded variants at a random bound
        let bound = rng.below(4096) as u32;
        let want_below: Vec<u32> = want.iter().copied().filter(|&x| x < bound).collect();
        assert_eq!(
            setops::intersect_count_below(&a, &b, bound),
            want_below.len(),
            "case {case} bound {bound}"
        );
        got.clear();
        setops::intersect_into_below(&a, &b, bound, &mut got);
        assert_eq!(got, want_below, "case {case} bound {bound}");
        // word-parallel AND(+popcount) vs the list kernels
        let mut x = BitSet::new(4096);
        let mut y = BitSet::new(4096);
        for &v in &a {
            x.insert(v as usize);
        }
        for &v in &b {
            y.insert(v as usize);
        }
        assert_eq!(
            setops::intersect_words_count(x.words(), y.words()),
            want.len(),
            "case {case}"
        );
        got.clear();
        setops::and_words_into(x.words(), y.words(), &mut got);
        assert_eq!(got, want, "case {case}");
        // mask-range scan vs the scalar loop
        let masks: Vec<u32> =
            (0..rng.below(80)).map(|_| rng.next_u64() as u32 & 0xFF).collect();
        let want_bits = rng.next_u64() as u32 & 0x7;
        let veto_bits = rng.next_u64() as u32 & 0x30;
        got.clear();
        setops::mask_filter_into(&masks, 1000, want_bits, veto_bits, &mut got);
        let want_masks: Vec<u32> = masks
            .iter()
            .enumerate()
            .filter(|(_, &m)| m & want_bits == want_bits && m & veto_bits == 0)
            .map(|(k, _)| 1000 + k as u32)
            .collect();
        assert_eq!(got, want_masks, "case {case}");
        // gathered code filter vs the scalar loop
        let codes: Vec<u32> = (0..512).map(|_| rng.next_u64() as u32 & 0xFF).collect();
        let keys: Vec<u32> = (0..rng.below(64)).map(|_| rng.below(512) as u32).collect();
        got.clear();
        setops::gather_mask_filter_into(&codes, &keys, want_bits, veto_bits, &mut got);
        let want_keys: Vec<u32> = keys
            .iter()
            .copied()
            .filter(|&u| {
                let c = codes[u as usize];
                c & want_bits == want_bits && c & veto_bits == 0
            })
            .collect();
        assert_eq!(got, want_keys, "case {case}");
    }
}

#[test]
fn engine_counts_invariant_under_simd_toggle() {
    // `set_simd_enabled` is process-global; concurrent tests in this
    // binary stay correct at either level (every kernel is exact), so
    // this test asserts only count equality, never dispatch selection
    for seed in [11u64, 22, 33] {
        let g = gen::rmat(9, 6, seed, &[]);
        for (name, p) in patterns() {
            for vertex_induced in [true, false] {
                setops::set_simd_enabled(false);
                let scalar_kernels = count_with(&g, &p, vertex_induced, true, true, 2);
                setops::set_simd_enabled(true);
                let simd_kernels = count_with(&g, &p, vertex_induced, true, true, 2);
                assert_eq!(
                    scalar_kernels, simd_kernels,
                    "seed={seed} {name} induced={vertex_induced}"
                );
            }
        }
        // and through the LG stage, whose dense mode rides the mask
        // kernels
        for p in [library::diamond(), library::clique(5)] {
            let pl = plan(&p, true, true);
            let lo = MinerConfig::custom(2, 16, OptFlags::lo());
            setops::set_simd_enabled(false);
            let a = dfs::count(&g, &pl, &lo, &NoHooks).unwrap().value;
            setops::set_simd_enabled(true);
            let b = dfs::count(&g, &pl, &lo, &NoHooks).unwrap().value;
            assert_eq!(a, b, "LG stage, seed={seed}");
        }
    }
}

// ---------- engine-level differentials ----------

fn patterns() -> Vec<(&'static str, Pattern)> {
    vec![
        ("triangle", library::triangle()),
        ("wedge", library::wedge()),
        ("diamond", library::diamond()),
        ("4-cycle", library::cycle(4)),
        ("4-clique", library::clique(4)),
        ("5-clique", library::clique(5)),
    ]
}

fn count_with(
    g: &sandslash::graph::CsrGraph,
    p: &Pattern,
    vertex_induced: bool,
    sets: bool,
    mnc: bool,
    threads: usize,
) -> u64 {
    let pl = plan(p, vertex_induced, true);
    let mut opts = OptFlags::hi();
    opts.sets = sets;
    opts.mnc = mnc;
    let cfg = MinerConfig::custom(threads, 16, opts);
    dfs::count(g, &pl, &cfg, &NoHooks).unwrap().value
}

#[test]
fn set_centric_matches_scalar_across_patterns_and_rmat_graphs() {
    for seed in [11u64, 22, 33] {
        let g = gen::rmat(9, 6, seed, &[]);
        for (name, p) in patterns() {
            for vertex_induced in [true, false] {
                let set = count_with(&g, &p, vertex_induced, true, true, 2);
                let scalar_mnc = count_with(&g, &p, vertex_induced, false, true, 2);
                let scalar_probe = count_with(&g, &p, vertex_induced, false, false, 2);
                assert_eq!(
                    set, scalar_mnc,
                    "set vs scalar+mnc: seed={seed} {name} induced={vertex_induced}"
                );
                assert_eq!(
                    set, scalar_probe,
                    "set vs scalar probe: seed={seed} {name} induced={vertex_induced}"
                );
            }
        }
    }
}

#[test]
fn set_centric_thread_invariant_on_skewed_graph() {
    // heavy-tailed RMAT: exercises the high-degree-root bitmap mode in
    // some worker tasks but not others
    let g = gen::rmat(10, 8, 7, &[]);
    for (name, p) in patterns() {
        let t1 = count_with(&g, &p, true, true, true, 1);
        let t4 = count_with(&g, &p, true, true, true, 4);
        assert_eq!(t1, t4, "{name}");
    }
}

#[test]
fn set_centric_matches_on_labeled_graph() {
    // labeled pattern vertices add the residual per-candidate label
    // filter to the set path
    let g = gen::rmat(8, 6, 5, &[1, 2, 3]);
    let mut tri = library::triangle();
    tri.set_label(0, 1);
    tri.set_label(1, 2);
    let mut cl4 = library::clique(4);
    cl4.set_label(2, 3);
    for (name, p) in [("labeled triangle", tri), ("labeled 4-clique", cl4)] {
        let set = count_with(&g, &p, true, true, true, 2);
        let scalar = count_with(&g, &p, true, false, true, 2);
        assert_eq!(set, scalar, "{name}");
    }
}
