//! Resident-service concurrency suite (PR 7).
//!
//! One in-process `Service` (no real socket — `tests` in
//! `service/net.rs` cover the TCP path) is shared by many client
//! threads submitting a randomized mix of patterns and budgets. The
//! invariants under test are the tentpole's whole value proposition:
//!
//! * every **completed** answer is bit-identical to a fresh one-shot
//!   engine run of the same query — multi-tenancy never changes counts;
//! * cache **hits replay the exact bytes** of the miss that filled them
//!   (same `Arc`, same rendered fragment);
//! * a **poisoned** query (injected worker panic) fails alone: every
//!   concurrent tenant still completes exactly, and the service stays up;
//! * a **deadline-tripped** query returns a marked partial while its
//!   neighbors complete exactly, and the partial is never cached;
//! * the scoped thread-locals (`budget::with_cancel`,
//!   `sched::with_overrides`) that make the engine reentrant do **not
//!   leak** across queries sharing a thread.
//!
//! Engine-running tests skip under `SANDSLASH_NO_GOV=1` (the service
//! refuses to start ungoverned — asserted by the last test, which runs
//! in every configuration), so the CI no-governance leg stays green.

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use sandslash::coordinator::datasets;
use sandslash::engine::budget::{self, Budget};
use sandslash::engine::hooks::NoHooks;
use sandslash::engine::{dfs, CancelToken, MinerConfig, OptFlags};
use sandslash::graph::CsrGraph;
use sandslash::obs::registry;
use sandslash::pattern::{plan, Pattern};
use sandslash::service::json;
use sandslash::service::{
    count_result, resolve_pattern, Body, Op, PatternSpec, Priority, Request, Response, Service,
    ServiceConfig, CODE_OVERLOADED,
};
use sandslash::util::fault::{self, FaultAction, FaultPlan, Stage};
use sandslash::util::metrics::{dispatch, sched as sched_counters};
use sandslash::util::pool;
use sandslash::util::rng::Rng;

/// Fault installation and the governance thread-locals are process
/// globals; serialize every test in this binary, recovering the lock
/// if a previous test's assertion poisoned it.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

const GRAPH: &str = "er-small";

/// The pattern population the randomized tenants draw from. All are
/// cheap on `er-small` so the suite stays fast even single-threaded.
const PATTERNS: &[&str] =
    &["triangle", "wedge", "diamond", "tailed-triangle", "4path", "4star", "4cycle", "4clique"];

fn test_service() -> Arc<Service> {
    let svc = Service::new(ServiceConfig {
        max_inflight: 8,
        max_queued: 64,
        cache_bytes: 1 << 20,
        default_threads: 2,
        default_budget: Budget::default(),
    })
    .expect("governed test environment");
    svc.preload(GRAPH).expect("test dataset resident");
    Arc::new(svc)
}

fn named(name: &str) -> Pattern {
    resolve_pattern(&PatternSpec::Named(name.to_string())).expect("known library pattern")
}

/// A fresh one-shot run of the same query the service executes:
/// identical plan, identical config shape. This is the ground truth the
/// resident answers must match byte-for-byte.
fn one_shot(g: &CsrGraph, name: &str, induced: bool) -> String {
    let p = named(name);
    let pl = plan(&p, induced, true);
    let cfg = MinerConfig::custom(2, pool::default_chunk(), OptFlags::hi());
    let out = dfs::count(g, &pl, &cfg, &NoHooks).expect("unbudgeted run cannot fail");
    assert!(out.complete, "unbudgeted one-shot must complete");
    count_result(out.value, None)
}

fn query(id: &str, name: &str) -> Request {
    let mut req = Request::query(id, GRAPH, PatternSpec::Named(name.to_string()));
    req.threads = Some(2);
    req
}

/// Unpack a successful body; panics (with the error) on a named failure.
fn ok_body(resp: &Response) -> (Arc<String>, bool, i32, Option<u64>) {
    match &resp.body {
        Body::Ok { result, cached, code, epoch, .. } => (result.clone(), *cached, *code, *epoch),
        Body::Err(e) => panic!("query {} failed: {} ({})", resp.id, e.name, e.detail),
    }
}

#[test]
fn randomized_tenants_get_bit_identical_answers() {
    if !budget::governance_enabled() {
        return;
    }
    let _guard = serial();
    let svc = test_service();
    let g = datasets::load(GRAPH).unwrap();

    // ground truth for every (pattern, induced) cell, computed up front
    // so worker threads only compare.
    let mut expected = std::collections::HashMap::new();
    for &name in PATTERNS {
        for induced in [false, true] {
            expected.insert((name, induced), one_shot(&g, name, induced));
        }
    }
    let expected = Arc::new(expected);

    let clients: Vec<_> = (0..8)
        .map(|t| {
            let svc = Arc::clone(&svc);
            let expected = Arc::clone(&expected);
            std::thread::spawn(move || {
                let mut rng = Rng::seeded(0xbeef + t as u64);
                for q in 0..6 {
                    let name = PATTERNS[rng.below(PATTERNS.len() as u64) as usize];
                    let induced = rng.chance(0.3);
                    let mut req = query(&format!("t{t}-q{q}"), name);
                    req.vertex_induced = induced;
                    if rng.chance(0.25) {
                        // a budget far below the root-block count: this
                        // tenant must come back a marked partial.
                        req.max_tasks = Some(1 + rng.below(3));
                    }
                    if rng.chance(0.2) {
                        req.priority = Priority::High;
                    }
                    let (result, _cached, code, epoch) = ok_body(&svc.handle(&req));
                    assert_eq!(epoch, Some(0));
                    if code == 0 {
                        assert_eq!(
                            *result,
                            expected[&(name, induced)],
                            "tenant t{t} query {q} ({name}, induced={induced}) diverged \
                             from its one-shot ground truth"
                        );
                    } else {
                        assert_eq!(code, 6, "only the task budget can trip these tenants");
                        assert!(result.contains("\"complete\":false"));
                        assert!(result.contains("\"tripped\":\"task-budget\""));
                    }
                }
            })
        })
        .collect();
    for c in clients {
        c.join().expect("client thread must not panic");
    }

    // after the storm every cell answers exactly — tripped partials from
    // budgeted tenants must not have leaked into the cache.
    for (&(name, induced), want) in expected.iter() {
        let mut req = query(&format!("post-{name}-{induced}"), name);
        req.vertex_induced = induced;
        let (result, _cached, code, _) = ok_body(&svc.handle(&req));
        assert_eq!(code, 0);
        assert_eq!(*result, *want, "post-storm {name} induced={induced}");
    }
}

#[test]
fn cache_hits_replay_the_exact_miss_bytes() {
    if !budget::governance_enabled() {
        return;
    }
    let _guard = serial();
    let svc = test_service();

    let (miss, cached, code, _) = ok_body(&svc.handle(&query("m1", "triangle")));
    assert!(!cached, "first query must be a miss");
    assert_eq!(code, 0);

    let (hit, cached, code, _) = ok_body(&svc.handle(&query("m2", "triangle")));
    assert!(cached, "second identical query must hit");
    assert_eq!(code, 0);
    assert!(Arc::ptr_eq(&miss, &hit), "a hit shares the miss's allocation");
    assert_eq!(*hit, *miss);

    // no_cache bypasses the probe but recomputes the same bytes.
    let mut req = query("m3", "triangle");
    req.no_cache = true;
    let (fresh, cached, code, _) = ok_body(&svc.handle(&req));
    assert!(!cached, "no_cache queries never report a hit");
    assert_eq!(code, 0);
    assert_eq!(*fresh, *miss);
    assert!(!Arc::ptr_eq(&fresh, &miss), "no_cache recomputes rather than replays");

    let stats = svc.cache_stats();
    assert_eq!((stats.hits, stats.misses, stats.fills), (1, 1, 1));
}

#[test]
fn deadline_tripped_query_is_partial_while_neighbors_complete_exactly() {
    if !budget::governance_enabled() {
        return;
    }
    let _guard = serial();
    let svc = test_service();
    let g = datasets::load(GRAPH).unwrap();

    let neighbors = ["triangle", "wedge", "diamond", "4path"];
    let victim = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || {
            let mut req = query("victim", "4clique");
            // an already-expired deadline trips at the first poll; no_cache
            // keeps the victim off the single-flight path so it cannot
            // coalesce onto (or poison) a neighbor's complete answer.
            req.deadline_ms = Some(0);
            req.no_cache = true;
            svc.handle(&req)
        })
    };
    let others: Vec<_> = neighbors
        .iter()
        .map(|&name| {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || svc.handle(&query(&format!("n-{name}"), name)))
        })
        .collect();

    let (partial, cached, code, _) = ok_body(&victim.join().unwrap());
    assert_eq!(code, 5, "deadline partials carry the PR-6 deadline code");
    assert!(!cached);
    assert!(partial.contains("\"complete\":false"));
    assert!(partial.contains("\"tripped\":\"deadline\""));
    assert_eq!(*partial, count_result(0, Some(sandslash::engine::CancelReason::Deadline)));

    for (resp, &name) in others.into_iter().map(|h| h.join().unwrap()).zip(neighbors.iter()) {
        let (result, _cached, code, _) = ok_body(&resp);
        assert_eq!(code, 0, "neighbor {name} must be untouched by the victim's deadline");
        assert_eq!(*result, one_shot(&g, name, false), "neighbor {name}");
    }

    // the partial was never cached: the next 4clique query recomputes
    // (miss) and completes exactly.
    let (full, cached, code, _) = ok_body(&svc.handle(&query("post", "4clique")));
    assert!(!cached, "a tripped partial must not fill the cache");
    assert_eq!(code, 0);
    assert_eq!(*full, one_shot(&g, "4clique", false));
}

#[test]
fn poisoned_query_does_not_affect_concurrent_tenants() {
    if !budget::governance_enabled() {
        return;
    }
    let _guard = serial();
    let svc = test_service();
    let g = datasets::load(GRAPH).unwrap();

    // crossing 0 is the first root-block claim anywhere in the process:
    // exactly one of the concurrent tenants draws the poison.
    fault::install(FaultPlan {
        action: FaultAction::Panic,
        at_task: 0,
        stage: Some(Stage::RootClaim),
    });
    let names = ["triangle", "wedge", "diamond", "4path", "4star", "4cycle"];
    let tenants: Vec<_> = names
        .iter()
        .map(|&name| {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || (name, svc.handle(&query(&format!("p-{name}"), name))))
        })
        .collect();
    let results: Vec<_> = tenants.into_iter().map(|h| h.join().unwrap()).collect();
    fault::clear();

    let mut poisoned = 0;
    for (name, resp) in &results {
        match &resp.body {
            Body::Err(e) => {
                poisoned += 1;
                assert_eq!(e.name, "worker-panic", "tenant {name}");
                assert_eq!(e.code, 4, "worker panics surface the PR-6 panic code");
                assert!(e.detail.contains("injected fault"));
            }
            Body::Ok { result, code, .. } => {
                assert_eq!(*code, 0, "tenant {name}");
                assert_eq!(**result, one_shot(&g, name, false), "tenant {name}");
            }
        }
    }
    assert_eq!(poisoned, 1, "exactly one tenant draws the single armed fault");

    // the service survived: the poisoned pattern now answers exactly
    // (the panicked fill was rejected, not cached), and ping works.
    for (name, _) in &results {
        let (result, _, code, _) = ok_body(&svc.handle(&query(&format!("r-{name}"), name)));
        assert_eq!(code, 0);
        assert_eq!(*result, one_shot(&g, name, false), "rerun {name}");
    }
    let (pong, _, code, _) = ok_body(&svc.handle(&Request::bare("ping", Op::Ping)));
    assert_eq!(code, 0);
    assert!(pong.contains("\"pong\":true"));
}

#[test]
fn admission_rejects_with_the_overloaded_code_when_saturated() {
    if !budget::governance_enabled() {
        return;
    }
    let _guard = serial();
    let svc = Arc::new(
        Service::new(ServiceConfig {
            max_inflight: 1,
            max_queued: 1,
            cache_bytes: 1 << 20,
            default_threads: 2,
            default_budget: Budget::default(),
        })
        .expect("governed test environment"),
    );
    svc.preload(GRAPH).expect("test dataset resident");

    // hold the only inflight slot for a while via an injected delay at
    // the first root-block claim.
    fault::install(FaultPlan {
        action: FaultAction::Delay(Duration::from_millis(400)),
        at_task: 0,
        stage: Some(Stage::RootClaim),
    });
    let slow = {
        let svc = Arc::clone(&svc);
        let mut req = query("slow", "triangle");
        req.no_cache = true;
        std::thread::spawn(move || svc.handle(&req))
    };
    std::thread::sleep(Duration::from_millis(100));
    let waiter = {
        let svc = Arc::clone(&svc);
        let mut req = query("queued", "wedge");
        req.no_cache = true;
        std::thread::spawn(move || svc.handle(&req))
    };
    std::thread::sleep(Duration::from_millis(100));

    // inflight full, queue full: the third tenant is refused, not hung.
    let resp = svc.handle(&query("refused", "diamond"));
    match &resp.body {
        Body::Err(e) => {
            assert_eq!(e.name, "overloaded");
            assert_eq!(e.code, CODE_OVERLOADED);
        }
        Body::Ok { .. } => panic!("a saturated service must refuse the third tenant"),
    }

    let (_, _, code, _) = ok_body(&slow.join().unwrap());
    assert_eq!(code, 0, "the delayed tenant still completes");
    let (_, _, code, _) = ok_body(&waiter.join().unwrap());
    assert_eq!(code, 0, "the queued tenant runs once the slot frees");
    fault::clear();
}

#[test]
fn invalidate_bumps_the_epoch_and_forces_recompute() {
    if !budget::governance_enabled() {
        return;
    }
    let _guard = serial();
    let svc = test_service();

    let (first, cached, _, epoch) = ok_body(&svc.handle(&query("e1", "triangle")));
    assert!(!cached);
    assert_eq!(epoch, Some(0));
    let (_, cached, _, _) = ok_body(&svc.handle(&query("e2", "triangle")));
    assert!(cached);

    let mut inv = Request::bare("inv", Op::Invalidate);
    inv.graph = Some(GRAPH.to_string());
    let (body, _, code, _) = ok_body(&svc.handle(&inv));
    assert_eq!(code, 0);
    assert!(body.contains("\"epoch\":1"), "invalidate reports the new epoch: {body}");
    assert!(body.contains("\"purged\":1"), "one resident entry purged: {body}");

    // same query, new epoch: a miss that recomputes the same bytes.
    let (again, cached, code, epoch) = ok_body(&svc.handle(&query("e3", "triangle")));
    assert!(!cached, "an epoch bump must orphan the old entry");
    assert_eq!(code, 0);
    assert_eq!(epoch, Some(1));
    assert_eq!(*again, *first, "the graph did not change, only the epoch");
    let (_, cached, _, _) = ok_body(&svc.handle(&query("e4", "triangle")));
    assert!(cached, "the recompute refilled the cache under the new key");
}

#[test]
fn scoped_thread_locals_do_not_leak() {
    if !budget::governance_enabled() {
        return;
    }
    let _guard = serial();
    let svc = test_service();
    let g = datasets::load(GRAPH).unwrap();
    let pl = plan(&named("triangle"), false, true);
    let cfg = MinerConfig::custom(2, pool::default_chunk(), OptFlags::hi());

    // an ambient pre-cancelled token trips a direct engine run...
    let cancelled = Arc::new(CancelToken::new());
    cancelled.cancel();
    let inside = budget::with_cancel(Arc::clone(&cancelled), || {
        let out = dfs::count(&g, &pl, &cfg, &NoHooks).unwrap();
        assert!(!out.complete, "a pre-cancelled ambient token must trip the run");

        // ...but a service query inside the same scope installs its own
        // per-query token, shadowing the ambient one: it completes.
        let (result, _, code, _) = ok_body(&svc.handle(&query("shadow", "wedge")));
        assert_eq!(code, 0, "the service's per-query token shadows the ambient cancel");
        (*result).clone()
    });
    assert_eq!(inside, one_shot(&g, "wedge", false));

    // after the scope the same thread is clean: nothing leaked.
    let out = dfs::count(&g, &pl, &cfg, &NoHooks).unwrap();
    assert!(out.complete, "the cancelled token must not outlive its scope");
    let (result, _, code, _) = ok_body(&svc.handle(&query("after", "triangle")));
    assert_eq!(code, 0);
    assert_eq!(*result, one_shot(&g, "triangle", false));
}

/// PR 9: a traced tenant's profile reconciles with the unified
/// registry's counter deltas (the same events, two vantage points),
/// and tracing one tenant never perturbs its neighbors' answers.
#[test]
fn traced_profile_reconciles_with_registry_and_leaves_neighbors_alone() {
    if !budget::governance_enabled() {
        return;
    }
    let _guard = serial();
    let svc = test_service();
    let g = datasets::load(GRAPH).unwrap();

    // Phase 1 (quiescent): one traced query, with dispatch counting on,
    // so the per-query histogram must equal the process-global deltas —
    // the two observers watch the same note_* call sites.
    let was = dispatch::enabled();
    dispatch::set_enabled(true);
    let d0 = dispatch::snapshot();
    let s0 = sched_counters::snapshot();
    let r0 = registry::snapshot();
    let mut req = query("traced", "triangle");
    req.trace = true;
    req.no_cache = true;
    let resp = svc.handle(&req);
    let d1 = dispatch::snapshot();
    let s1 = sched_counters::snapshot();
    let r1 = registry::snapshot();
    dispatch::set_enabled(was);

    let (result, cached, code, _) = ok_body(&resp);
    assert_eq!(code, 0);
    assert!(!cached, "no_cache keeps the traced run on the engine path");
    assert_eq!(*result, one_shot(&g, "triangle", false), "tracing must not change the answer");

    let line = resp.render();
    let v = json::parse(&line).expect("traced response parses");
    let profile = v.get("profile").expect("profile attached");
    let section = |sec: &str, key: &str| {
        profile
            .get(sec)
            .and_then(|s| s.get(key))
            .and_then(|n| n.as_u64())
            .unwrap_or_else(|| panic!("profile missing {sec}.{key}: {line}"))
    };
    for (key, delta) in [
        ("merge", d1.merge - d0.merge),
        ("gallop", d1.gallop - d0.gallop),
        ("simd_merge", d1.simd_merge - d0.simd_merge),
        ("word_parallel", d1.word_parallel - d0.word_parallel),
        ("mask_filter", d1.mask_filter - d0.mask_filter),
        ("gather_filter", d1.gather_filter - d0.gather_filter),
        ("difference", d1.difference - d0.difference),
    ] {
        assert_eq!(section("dispatch", key), delta, "dispatch.{key} diverged from the registry");
    }
    for (key, delta) in [
        ("claims", s1.claims - s0.claims),
        ("steals", s1.steals - s0.steals),
        ("shard_claims", s1.shard_claims - s0.shard_claims),
        ("splits", s1.splits - s0.splits),
    ] {
        assert_eq!(section("sched", key), delta, "sched.{key} diverged from the registry");
    }
    // the response itself landed in the unified service counters
    assert_eq!(r1.service.responses_total(), r0.service.responses_total() + 1);
    assert_eq!(r1.service.responses[0], r0.service.responses[0] + 1);

    // Phase 2 (concurrent): a traced tenant among untraced neighbors —
    // every neighbor still answers bit-identically to its one-shot.
    let traced = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || {
            let mut req = query("traced-2", "4clique");
            req.trace = true;
            req.no_cache = true;
            svc.handle(&req)
        })
    };
    let names = ["wedge", "4path", "4star", "4cycle"];
    let neighbors: Vec<_> = names
        .iter()
        .map(|&name| {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || (name, svc.handle(&query(&format!("nb-{name}"), name))))
        })
        .collect();
    for h in neighbors {
        let (name, resp) = h.join().unwrap();
        let (result, _, code, _) = ok_body(&resp);
        assert_eq!(code, 0, "neighbor {name}");
        assert_eq!(*result, one_shot(&g, name, false), "tracing a tenant perturbed {name}");
        assert!(
            !resp.render().contains("\"profile\":"),
            "an untraced neighbor must not carry a profile"
        );
    }
    let resp = traced.join().unwrap();
    let (result, _, code, _) = ok_body(&resp);
    assert_eq!(code, 0);
    assert_eq!(*result, one_shot(&g, "4clique", false));
    assert!(resp.render().contains("\"profile\":{"), "the traced tenant keeps its profile");
}

#[test]
fn ungoverned_environments_refuse_to_start_a_service() {
    let _guard = serial();
    let cfg = ServiceConfig {
        max_inflight: 2,
        max_queued: 4,
        cache_bytes: 1 << 20,
        default_threads: 2,
        default_budget: Budget::default(),
    };
    if budget::governance_enabled() {
        // scoped disable (unit-test hook) must refuse...
        budget::with_governance_disabled(|| {
            assert!(Service::new(cfg.clone()).is_err(), "ungoverned Service::new must refuse");
        });
        // ...and a governed environment must accept.
        assert!(Service::new(cfg).is_ok());
    } else {
        // the SANDSLASH_NO_GOV=1 CI leg lands here: refusal is the whole
        // contract — a resident process without deadlines or cancellation
        // cannot protect its tenants.
        assert!(Service::new(cfg).is_err(), "SANDSLASH_NO_GOV must refuse a resident service");
    }
}
