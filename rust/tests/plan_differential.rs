//! PR-10 differential suite: the decomposition counting planner vs the
//! enumerated oracle.
//!
//! The planner ([`sandslash::pattern::decompose`]) answers count-only
//! queries from algebraic decompositions — closed-form degree scans
//! plus small governed anchor enumerations stitched together with
//! derived inclusion–exclusion coefficients — instead of enumerating
//! one embedding per match. Its whole correctness contract is
//! *bit-identical counts*: for every supported pattern the planned
//! answer must equal the enumerated answer exactly, on every graph, at
//! every thread count, in both induced modes. This file pins that
//! contract:
//!
//! - every pattern in `library::all_motifs(3..=5)` plus the explicit
//!   diamond / tailed-triangle anchors, across 3 RMAT seeds × threads
//!   {1, 8} × the `plan` kill switch on/off (the switch itself must be
//!   count-invariant);
//! - the non-induced leg (raw wedge / star / diamond recipes, which
//!   use different formula leaves than the induced ones);
//! - the whole-census path vs the ESU oracle, with the ISSUE-10
//!   acceptance assertion that the planner *enumerates strictly fewer
//!   embeddings* (engine stats) while agreeing bit-for-bit;
//! - the governance leg: a deadline trip mid-plan degrades to a
//!   `complete == false` partial (never a panic, never a wrong
//!   "complete" answer), and the resident service refuses to cache it.
//!
//! The kill switch is exercised through the `OptFlags::plan` *field*
//! here (process-wide `SANDSLASH_NO_PLAN` is OnceLock-cached, so the
//! env form gets its own CI leg instead — see `rust-plan` in ci.yml).

use std::sync::Arc;
use std::time::Duration;

use sandslash::engine::budget;
use sandslash::engine::esu::{count_motifs, MotifTable};
use sandslash::engine::hooks::NoHooks;
use sandslash::engine::{Budget, MinerConfig, OptFlags};
use sandslash::graph::gen;
use sandslash::graph::CsrGraph;
use sandslash::pattern::{decompose, library, Pattern};
use sandslash::service::{Body, PatternSpec, Request, Service, ServiceConfig};

const SEEDS: [u64; 3] = [3, 11, 29];
const THREADS: [usize; 2] = [1, 8];

fn cfg_with(threads: usize, plan: bool) -> MinerConfig {
    let mut c = MinerConfig::custom(threads, 16, OptFlags::hi());
    c.opts.plan = plan;
    c
}

/// The full battery the tentpole promises: every 3/4/5-vertex motif
/// plus the two explicit decomposition anchors.
fn battery() -> Vec<Pattern> {
    let mut pats: Vec<Pattern> = Vec::new();
    for k in 3..=5 {
        pats.extend(library::all_motifs(k));
    }
    pats.push(library::diamond());
    pats.push(library::tailed_triangle());
    pats
}

/// Induced leg: planner on vs planner off (the enumerated oracle) must
/// be bit-identical for every battery pattern, seed, and thread count.
#[test]
fn planned_counts_match_enumerated_counts_vertex_induced() {
    for seed in SEEDS {
        let g = gen::rmat(8, 5, seed, &[]);
        for p in battery() {
            for threads in THREADS {
                let oracle = decompose::count_with_plan(&g, &p, true, &cfg_with(threads, false))
                    .unwrap()
                    .value;
                let planned = decompose::count_with_plan(&g, &p, true, &cfg_with(threads, true))
                    .unwrap()
                    .value;
                assert_eq!(
                    planned, oracle,
                    "induced {p} on rmat(8,5,{seed}) at {threads} threads: \
                     planner disagrees with enumeration"
                );
            }
        }
    }
}

/// Non-induced leg: the raw recipes (star via vertex-comb, diamond via
/// edge triangle-pairs) use different leaves than the induced ones, so
/// they get their own sweep. Patterns whose raw form has no recipe
/// (paths, cycles, cliques) ride along as plan-direct coverage.
#[test]
fn planned_counts_match_enumerated_counts_edge_induced() {
    let mut pats = vec![
        library::wedge(),
        library::star(3),
        library::star(4),
        library::star(5),
        library::diamond(),
        library::tailed_triangle(),
        library::path(4),
        library::cycle(4),
        library::clique(4),
    ];
    pats.extend(library::all_motifs(3));
    for seed in SEEDS {
        let g = gen::rmat(8, 5, seed, &[]);
        for p in &pats {
            for threads in THREADS {
                let oracle = decompose::count_with_plan(&g, p, false, &cfg_with(threads, false))
                    .unwrap()
                    .value;
                let planned = decompose::count_with_plan(&g, p, false, &cfg_with(threads, true))
                    .unwrap()
                    .value;
                assert_eq!(
                    planned, oracle,
                    "non-induced {p} on rmat(8,5,{seed}) at {threads} threads: \
                     planner disagrees with enumeration"
                );
            }
        }
    }
}

/// Whole-census path vs the ESU oracle: identical vectors, and — the
/// ISSUE-10 acceptance criterion — the planner reaches them while
/// enumerating strictly fewer embeddings than ESU's per-subgraph walk.
#[test]
fn census_matches_esu_and_enumerates_strictly_fewer_embeddings() {
    for seed in SEEDS {
        let g = gen::rmat(9, 5, seed, &[]);
        for k in [3usize, 4] {
            let mut cfg = cfg_with(4, true);
            cfg.opts = cfg.opts.with_stats();
            let planned = decompose::motif_census(&g, k, &cfg).unwrap();
            let esu = count_motifs(&g, k, &cfg, &NoHooks, &MotifTable::new(k)).unwrap();
            assert_eq!(
                planned.value, esu.value,
                "{k}-motif census on rmat(9,5,{seed}): planner disagrees with ESU"
            );
            if decompose::plan_enabled_default() {
                assert!(
                    planned.stats.enumerated < esu.stats.enumerated,
                    "{k}-motif census on rmat(9,5,{seed}): planner enumerated \
                     {} embeddings, ESU {} — the decomposition must shrink the \
                     enumeration space, not just match counts",
                    planned.stats.enumerated,
                    esu.stats.enumerated
                );
            }
        }
    }
}

/// Governance leg, engine half: an already-expired deadline trips the
/// anchor enumeration mid-plan; the planner must surface an honest
/// `complete == false` partial (tripped reason attached), never a
/// fabricated total.
#[test]
fn deadline_trip_mid_plan_degrades_to_partial() {
    if !budget::governance_enabled() {
        return;
    }
    let g = gen::rmat(8, 5, 3, &[]);
    let cfg = cfg_with(2, true).with_deadline(Duration::from_nanos(1));
    for p in [library::diamond(), library::tailed_triangle()] {
        let out = decompose::count_with_plan(&g, &p, true, &cfg).unwrap();
        assert!(
            !out.complete,
            "an expired deadline must degrade the planned {p} count to a partial"
        );
        assert!(out.tripped.is_some(), "partial outcomes carry their trip reason");
    }
    let census = decompose::motif_census(&g, 4, &cfg).unwrap();
    assert!(!census.complete, "an expired deadline must degrade the census to a partial");
}

fn frag_count(frag: &str) -> u64 {
    sandslash::service::json::parse(frag)
        .ok()
        .and_then(|v| v.get("count").and_then(|c| c.as_u64()))
        .expect("count field in the result fragment")
}

/// Governance leg, service half: the resident service routes count-only
/// queries through the planner; a deadline-tripped partial must answer
/// with the PR-6 code and must **never** enter the result cache, and
/// the planned answer that does get cached must be bit-identical to the
/// enumerated oracle (cache compatibility across the kill switch).
#[test]
fn service_routes_counts_through_the_planner_and_never_caches_partials() {
    if !budget::governance_enabled() {
        return;
    }
    let svc = Service::new(ServiceConfig {
        max_inflight: 2,
        max_queued: 4,
        cache_bytes: 1 << 20,
        default_threads: 2,
        default_budget: Budget::default(),
    })
    .expect("governed test environment");
    let svc = Arc::new(svc);
    svc.preload("er-small").expect("test dataset resident");

    // 1. deadline-tripped planned query: partial code, never cached.
    //    Vertex-induced so the plan carries governed anchor pieces (the
    //    raw diamond recipe is formula-only and has nothing to trip).
    let mut tripped = Request::query("p1", "er-small", PatternSpec::Named("diamond".into()));
    tripped.vertex_induced = true;
    tripped.deadline_ms = Some(0);
    let resp = svc.handle(&tripped);
    match &resp.body {
        Body::Ok { code, cached, result, .. } => {
            assert_ne!(*code, 0, "a 0ms deadline must trip the planned query");
            assert!(!*cached);
            assert!(result.contains("\"complete\":false"));
        }
        Body::Err(e) => panic!("tripped query must still answer: {e:?}"),
    }
    let stats = svc.cache_stats();
    assert_eq!(stats.fills, 0, "tripped partials must never fill the cache");
    assert!(stats.rejected >= 1, "the partial must be rejected by the cache, not dropped");

    // 2. the same query unbudgeted: a true miss (nothing was cached),
    //    answered by the planner, bit-identical to the enumerated
    //    oracle on the same deterministic dataset
    let mut req = Request::query("p2", "er-small", PatternSpec::Named("diamond".into()));
    req.vertex_induced = true;
    let (count, was_cached) = match &svc.handle(&req).body {
        Body::Ok { code, cached, result, .. } => {
            assert_eq!(*code, 0);
            (frag_count(result), *cached)
        }
        Body::Err(e) => panic!("query failed: {e:?}"),
    };
    assert!(!was_cached, "the tripped partial must not have been cached");
    let er_small = gen::erdos_renyi(2000, 0.005, 7, &[]);
    let oracle = enumerated_diamond_count(&er_small);
    assert_eq!(
        count, oracle,
        "the service's planned answer must be bit-identical to the enumerated oracle"
    );

    // 3. replay: the complete planned answer is cache-compatible
    let mut req = Request::query("p3", "er-small", PatternSpec::Named("diamond".into()));
    req.vertex_induced = true;
    match &svc.handle(&req).body {
        Body::Ok { code, cached, result, .. } => {
            assert_eq!(*code, 0);
            assert!(*cached, "the complete planned answer must have been cached");
            assert_eq!(frag_count(result), oracle);
        }
        Body::Err(e) => panic!("replay failed: {e:?}"),
    }
}

/// The enumerated (planner-off) oracle for the service leg's
/// vertex-induced diamond query.
fn enumerated_diamond_count(g: &CsrGraph) -> u64 {
    decompose::count_with_plan(g, &library::diamond(), true, &cfg_with(2, false))
        .unwrap()
        .value
}
