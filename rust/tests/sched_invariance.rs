//! Scheduler invariance suite (PR 4).
//!
//! The work-stealing, locality-sharded executor (`sandslash::exec`)
//! must be *observationally invisible*: every app produces identical
//! results across thread counts, the steal/cursor scheduler swap, and
//! shard counts — the global-cursor oracle referees the stealing pool
//! exactly as the scalar kernels referee the SIMD dispatch. The skewed
//! regression then pins the other half of the contract: on a two-hub
//! graph the scheduler must not merely agree, it must actually steal
//! and split (asserted through `util::metrics::sched` counters),
//! otherwise the whole subsystem silently degrades to the old cursor.
//!
//! Scheduling knobs are applied two ways at once — per-run
//! `MinerConfig` fields for the DFS-driven paths and scoped
//! thread-local `sched::with_overrides` for the apps that go through
//! the fixed `util::pool` adapter signatures — so both control planes
//! are exercised. Overrides are thread-local, but the scheduler
//! counters are process-global, so the tests serialize on one lock to
//! keep each snapshot window attributable to its own run.

use sandslash::apps::{clique, fsm_app, motif, sl, tc};
use sandslash::engine::hooks::NoHooks;
use sandslash::engine::{dfs, MinerConfig, OptFlags};
use sandslash::exec::sched::{self, Overrides};
use sandslash::graph::{gen, CsrGraph};
use sandslash::pattern::{library, plan};
use sandslash::util::metrics;

/// Serializes the tests in this binary (see module docs). A panicking
/// test poisons the lock; later tests recover the guard and proceed.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// Order-independent FSM result: (rendered pattern, support), sorted.
fn fsm_fingerprint(g: &CsrGraph, cfg: &MinerConfig) -> Vec<(String, u64)> {
    let r = fsm_app::fsm(g, 2, 2, cfg).unwrap().value;
    let mut rows: Vec<(String, u64)> =
        r.iter().map(|f| (format!("{}", f.pattern), f.support)).collect();
    rows.sort();
    rows
}

#[test]
fn all_apps_invariant_across_threads_steal_shards() {
    let _guard = serial();
    let g = gen::rmat(10, 8, 7, &[]);
    let gl = gen::erdos_renyi(60, 0.15, 21, &[1, 2]);
    // reference: sequential run on the cursor oracle
    let base = MinerConfig::single_thread(OptFlags::hi()).with_steal(false);
    let tc_ref = tc::tc_hi(&g, &base);
    let cl4_ref = clique::clique_hi(&g, 4, &base).0;
    let cl5_ref = clique::clique_hi(&g, 5, &base).0;
    let m3_ref = motif::motif3_hi(&g, &base).unwrap().value;
    let sl_ref = sl::sl_count(&g, &library::diamond(), &base).unwrap().value;
    let fsm_ref = fsm_fingerprint(&gl, &base);
    assert!(tc_ref > 0 && cl4_ref > 0, "degenerate reference input");
    for threads in [1usize, 2, 8] {
        for steal in [false, true] {
            for shards in [1usize, 2] {
                let cfg = MinerConfig::custom(threads, 8, OptFlags::hi())
                    .with_steal(steal)
                    .with_shards(shards);
                let label = format!("threads={threads} steal={steal} shards={shards}");
                sched::with_overrides(
                    Overrides { steal: Some(steal), shards: Some(shards) },
                    || {
                        assert_eq!(tc::tc_hi(&g, &cfg), tc_ref, "tc {label}");
                        assert_eq!(clique::clique_hi(&g, 4, &cfg).0, cl4_ref, "clique-4 {label}");
                        assert_eq!(clique::clique_hi(&g, 5, &cfg).0, cl5_ref, "clique-5 {label}");
                        assert_eq!(
                            motif::motif3_hi(&g, &cfg).unwrap().value,
                            m3_ref,
                            "motif-3 {label}"
                        );
                        assert_eq!(
                            sl::sl_count(&g, &library::diamond(), &cfg).unwrap().value,
                            sl_ref,
                            "sl {label}"
                        );
                        assert_eq!(fsm_fingerprint(&gl, &cfg), fsm_ref, "fsm {label}");
                    },
                );
            }
        }
    }
}

#[test]
fn generic_dfs_invariant_on_skewed_input_across_full_matrix() {
    let _guard = serial();
    // the generic engine (the split-protocol publisher) gets its own
    // sweep on the adversarial input, including the Lo (LG) preset
    let g = gen::two_hub(1 << 10);
    for opts in [OptFlags::hi(), OptFlags::lo()] {
        for pat in [library::triangle(), library::clique(4), library::cycle(4)] {
            let pl = plan(&pat, true, true);
            let base = MinerConfig::single_thread(opts).with_steal(false);
            let (want, _) = dfs::count(&g, &pl, &base, &NoHooks).unwrap().into_parts();
            for threads in [2usize, 8] {
                for steal in [false, true] {
                    for shards in [1usize, 2] {
                        let cfg = MinerConfig::custom(threads, 1, opts)
                            .with_steal(steal)
                            .with_shards(shards);
                        let (got, _) = dfs::count(&g, &pl, &cfg, &NoHooks).unwrap().into_parts();
                        assert_eq!(
                            got, want,
                            "pattern {pat} threads={threads} steal={steal} shards={shards}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn skewed_two_hub_graph_actually_steals_and_splits() {
    let _guard = serial();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores < 2 || !sched::steal_enabled_default() {
        // single-core hosts cannot guarantee a thief runs while the hub
        // grinds, and the SANDSLASH_NO_STEAL oracle job pins the cursor
        eprintln!(
            "skipping steal/split counter assertions (cores={cores}, steal_default={})",
            sched::steal_enabled_default()
        );
        return;
    }
    // Two hub roots carry almost all mining work (gen::two_hub docs):
    // with grain 1 the other workers drain the cheap roots, go hungry,
    // steal the grinder's deque ranges, and then force level-1 splits
    // of the hub candidate sets. All of that must be observable.
    let g = gen::two_hub(1 << 13);
    let pl = plan(&library::triangle(), true, true);
    let oracle_cfg =
        MinerConfig::custom(8, 1, OptFlags::hi()).with_steal(false).with_shards(1);
    let (want, _) = dfs::count(&g, &pl, &oracle_cfg, &NoHooks).unwrap().into_parts();
    assert!(want > 0, "degenerate skewed input");

    // The hub grind dominates the cheap tail by >10x, so starvation —
    // and with it a split — fires on any real parallel execution; a
    // bounded retry absorbs pathological OS scheduling on loaded
    // runners without weakening the regression (a broken protocol
    // fails every attempt deterministically).
    let steal_cfg = MinerConfig::custom(8, 1, OptFlags::hi()).with_shards(1);
    let (mut claims_fired, mut steals_fired, mut splits_fired) = (false, false, false);
    for _attempt in 0..3 {
        let before = metrics::sched::snapshot();
        let (got, _) = dfs::count(&g, &pl, &steal_cfg, &NoHooks).unwrap().into_parts();
        let after = metrics::sched::snapshot();
        assert_eq!(got, want, "stealing run disagrees with the cursor oracle");
        claims_fired |= after.claims > before.claims;
        steals_fired |= after.steals > before.steals;
        splits_fired |= after.splits > before.splits;
        if claims_fired && steals_fired && splits_fired {
            break;
        }
    }
    assert!(claims_fired, "no cursor block was ever claimed");
    assert!(steals_fired, "no deque steal fired on the two-hub graph");
    assert!(
        splits_fired,
        "no level-1 split fired on the two-hub graph — hub roots were mined sequentially"
    );

    // sharded run: hub work lives in shard 0, so shard 1's workers must
    // migrate (foreign-shard claims or steals) to finish the run
    let sharded_cfg = MinerConfig::custom(8, 1, OptFlags::hi()).with_shards(2);
    let b2 = metrics::sched::snapshot();
    let (got2, _) = dfs::count(&g, &pl, &sharded_cfg, &NoHooks).unwrap().into_parts();
    let a2 = metrics::sched::snapshot();
    assert_eq!(got2, want, "sharded stealing run disagrees with the cursor oracle");
    assert!(
        a2.migrations() > b2.migrations(),
        "two shards finished without any cross-worker migration"
    );
}
