//! PR-9 observability oracle: tracing observes, never steers.
//!
//! The contract of [`sandslash::obs`] is that every hook is passive —
//! a query traced via [`sandslash::obs::trace::with_trace`] must
//! produce results bit-identical to the same query untraced, on every
//! engine (DFS, ESU, BFS, FSM). This file is the differential oracle
//! for that contract, plus the post-mortem half of the layer: an
//! injected worker panic must leave a flight-recorder trail
//! ([`sandslash::obs::flight`]) that names the faulted stage.
//!
//! The tests serialize on one mutex: fault injection and the flight
//! rings are process-global, and the bit-identity runs compare counts
//! across calls that must not interleave with a planned fault.

use std::sync::Arc;

use sandslash::engine::bfs::bfs_count_motifs;
use sandslash::engine::budget;
use sandslash::engine::esu::{count_motifs, MotifTable};
use sandslash::engine::fsm::mine_fsm;
use sandslash::engine::hooks::NoHooks;
use sandslash::engine::{dfs, MineError, MinerConfig, OptFlags};
use sandslash::graph::gen;
use sandslash::obs::flight;
use sandslash::obs::trace::{self, QueryTrace};
use sandslash::pattern::{library, plan};
use sandslash::util::fault::{self, FaultAction, FaultPlan, Stage};

/// Serializes the tests in this binary (module docs). A panicking test
/// poisons the lock; later tests recover the guard and proceed.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn tri_plan() -> sandslash::pattern::MatchingPlan {
    plan(&library::triangle(), true, true)
}

/// The tentpole acceptance check: all four engines, traced vs
/// untraced, counts bit-identical — and the traces must actually have
/// recorded work, so a hook-threading regression cannot pass as a
/// no-op trace.
#[test]
fn traced_counts_bit_identical_on_every_engine() {
    let _guard = serial();
    let g = gen::rmat(9, 8, 5, &[]);
    let lg = gen::erdos_renyi(60, 0.12, 9, &[1, 2, 3]);
    let cfg = MinerConfig::custom(2, 8, OptFlags::hi());
    let pl = tri_plan();
    let t3 = MotifTable::new(3);
    let fp = |r: &[sandslash::engine::fsm::FrequentPattern]| {
        r.iter().map(|f| (f.code.clone(), f.support)).collect::<Vec<_>>()
    };

    let want_dfs = dfs::count(&g, &pl, &cfg, &NoHooks).unwrap().value;
    let want_esu = count_motifs(&g, 3, &cfg, &NoHooks, &t3).unwrap().value;
    let want_bfs = bfs_count_motifs(&g, 3, &cfg, &t3).unwrap().value.counts;
    let want_fsm = mine_fsm(&lg, 3, 1, &cfg).unwrap().value;
    assert!(want_dfs > 0, "degenerate input");

    let tr_dfs = Arc::new(QueryTrace::new());
    let got_dfs = trace::with_trace(tr_dfs.clone(), || {
        dfs::count(&g, &pl, &cfg, &NoHooks).unwrap().value
    });
    assert_eq!(got_dfs, want_dfs, "tracing changed the DFS count");
    assert!(
        tr_dfs.level_calls_total() > 0,
        "a traced DFS run must record per-level extension calls"
    );
    assert!(
        tr_dfs.dispatch_total() > 0,
        "a traced set-centric run must record kernel dispatches"
    );

    let tr_esu = Arc::new(QueryTrace::new());
    let got_esu = trace::with_trace(tr_esu.clone(), || {
        count_motifs(&g, 3, &cfg, &NoHooks, &t3).unwrap().value
    });
    assert_eq!(got_esu, want_esu, "tracing changed the ESU motif counts");

    let tr_bfs = Arc::new(QueryTrace::new());
    let got_bfs = trace::with_trace(tr_bfs.clone(), || {
        bfs_count_motifs(&g, 3, &cfg, &t3).unwrap().value.counts
    });
    assert_eq!(got_bfs, want_bfs, "tracing changed the BFS motif counts");

    let tr_fsm = Arc::new(QueryTrace::new());
    let got_fsm = trace::with_trace(tr_fsm.clone(), || {
        mine_fsm(&lg, 3, 1, &cfg).unwrap().value
    });
    assert_eq!(fp(&got_fsm), fp(&want_fsm), "tracing changed the FSM result");

    // governed runs charge the budget ledger through the trace too
    if budget::governance_enabled() {
        assert!(
            tr_dfs.budget_charges() > 0,
            "a governed traced run must record budget charges"
        );
    }
}

/// The scoped-install contract ([`trace::with_trace`] mirrors
/// `budget::with_cancel`): the trace is visible inside the closure,
/// restored on exit, and its rendered profile is well-formed one-line
/// JSON carrying every section of the schema in EXPERIMENTS.md §PR-9.
#[test]
fn trace_scope_restores_and_render_is_well_formed() {
    let _guard = serial();
    assert!(trace::current().is_none(), "no trace may leak into this test");
    let g = gen::rmat(8, 6, 7, &[]);
    let pl = tri_plan();
    let cfg = MinerConfig::custom(2, 8, OptFlags::hi());
    let tr = Arc::new(QueryTrace::new());
    trace::with_trace(tr.clone(), || {
        let inside = trace::current().expect("trace must be installed in scope");
        assert!(Arc::ptr_eq(&inside, &tr), "current() must return the installed trace");
        dfs::count(&g, &pl, &cfg, &NoHooks).unwrap();
    });
    assert!(trace::current().is_none(), "with_trace must restore the empty state");

    let profile = tr.render();
    assert!(!profile.contains('\n'), "profile must be one line: {profile}");
    assert!(profile.starts_with('{') && profile.ends_with('}'), "{profile}");
    for section in [
        "\"levels\":[",
        "\"dispatch\":{\"merge\":",
        "\"sched\":{\"claims\":",
        "\"modes\":{\"lg_roots\":",
        "\"budget\":{\"charges\":",
        "\"cache\":",
        "\"admission\":",
    ] {
        assert!(profile.contains(section), "profile missing {section}: {profile}");
    }
    // an untripped run renders a null trip, and a level entry recorded
    // real wall time for the levels the DFS actually visited
    assert!(profile.contains("\"trip\":null"), "{profile}");
    assert!(profile.contains("\"level\":"), "{profile}");
}

/// A fresh trace renders the empty profile — every counter zero, no
/// levels, verdicts null — so a cache-hit response's profile is
/// honest about having run no engine work.
#[test]
fn empty_trace_renders_empty_profile() {
    let tr = QueryTrace::new();
    let profile = tr.render();
    assert!(profile.contains("\"levels\":[]"), "{profile}");
    assert!(profile.contains("\"cache\":null"), "{profile}");
    assert!(profile.contains("\"admission\":null"), "{profile}");
    assert_eq!(tr.dispatch_total(), 0);
    assert_eq!(tr.level_calls_total(), 0);
}

/// The post-mortem acceptance check: an injected worker panic is
/// contained as [`MineError::WorkerPanicked`] and the flight recorder
/// holds a trail that names the faulted stage — both the last stage
/// crossing and the panic event stamped with it.
#[test]
fn injected_worker_panic_leaves_a_flight_trail_naming_the_stage() {
    let _guard = serial();
    if !budget::governance_enabled() {
        eprintln!("skipping flight-trail check: panic isolation needs governance on");
        return;
    }
    let g = gen::rmat(9, 8, 5, &[]);
    let pl = tri_plan();
    fault::install(FaultPlan {
        action: FaultAction::Panic,
        at_task: 0,
        stage: Some(Stage::RootClaim),
    });
    let res = dfs::count(&g, &pl, &MinerConfig::custom(2, 8, OptFlags::hi()), &NoHooks);
    fault::clear();
    match res {
        Err(MineError::WorkerPanicked { engine, payload }) => {
            assert_eq!(engine, "dfs");
            assert!(payload.contains("injected fault"), "payload {payload:?}");
        }
        other => panic!("expected WorkerPanicked, got {other:?}"),
    }
    // the same text the panic already dumped to stderr, re-rendered
    // for inspection (dumping never drains the rings)
    let text = flight::render("test-inspection");
    assert!(
        text.contains("\"event\":\"stage\",\"stage\":\"root-claim\""),
        "flight trail must show the root-claim crossing:\n{text}"
    );
    assert!(
        text.contains("\"event\":\"panic\",\"stage\":\"root-claim\""),
        "flight trail must stamp the panic with the faulted stage:\n{text}"
    );
    assert!(
        text.contains("\"event\":\"query-start\""),
        "flight trail must show the governed run opening:\n{text}"
    );
}
