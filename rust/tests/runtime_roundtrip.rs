//! Runtime integration: the AOT artifacts (Layer 1/2) must reproduce the
//! combinatorial engine's numbers through PJRT (Layer 3). Skips politely
//! when artifacts haven't been built (`make artifacts`).

use sandslash::apps::motif::motif4_hi;
use sandslash::apps::tc::tc_hi;
use sandslash::engine::{MinerConfig, OptFlags};
use sandslash::graph::gen;
use sandslash::runtime::accel::Accelerator;
use sandslash::runtime::tiles::TiledAdjacency;

fn cfg() -> MinerConfig {
    MinerConfig::custom(2, 16, OptFlags::hi())
}

fn accel() -> Option<Accelerator> {
    if !std::path::Path::new("artifacts/tc_tile.hlo.txt").exists() {
        eprintln!("artifacts missing; run `make artifacts` (skipping)");
        return None;
    }
    match Accelerator::load("artifacts") {
        Ok(a) => Some(a),
        Err(e) => {
            // e.g. built without the `xla` feature: the stub always errors
            eprintln!("accelerator unavailable ({e:#}); skipping");
            None
        }
    }
}

#[test]
fn xla_triangle_count_matches_engine() {
    let Some(a) = accel() else { return };
    for g in [
        gen::erdos_renyi(300, 0.05, 1, &[]),
        gen::rmat(9, 5, 2, &[]),
        gen::ring(500),
    ] {
        let want = tc_hi(&g, &cfg());
        let got = a.triangle_count(&g).expect("xla tc");
        assert_eq!(got, want);
    }
}

#[test]
fn xla_motif4_matches_engine() {
    let Some(a) = accel() else { return };
    let g = gen::erdos_renyi(400, 0.02, 3, &[]);
    let want = motif4_hi(&g, &cfg()).unwrap().value;
    let got = a.motif4(&g, &cfg()).expect("xla motif4");
    assert_eq!(got, want);
}

#[test]
fn cpu_tile_reference_matches_engine() {
    // the pure-Rust tile reference validates the tiling independent of XLA
    let g = gen::rmat(8, 6, 4, &[]);
    let tiled = TiledAdjacency::build(&g, true);
    assert_eq!(tiled.masked_trace_cpu() as u64, tc_hi(&g, &cfg()));
}

#[test]
fn empty_tile_skipping_is_lossless() {
    let Some(a) = accel() else { return };
    // ring graph: extremely sparse tiling, most tiles empty
    let g = gen::ring(1000);
    assert_eq!(a.triangle_count(&g).expect("xla"), 0);
    let g2 = gen::complete(130); // spans >1 tile, dense
    let want = tc_hi(&g2, &cfg());
    assert_eq!(a.triangle_count(&g2).expect("xla"), want);
}
