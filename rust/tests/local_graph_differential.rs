//! Differential tests for the generalized local-graph stage (PR 2):
//! the LG-enabled DFS engine (`OptFlags::lo`, which layers `lg` on the
//! set-centric frontier) must produce exactly the counts of the
//! PR-1 set-centric path (`OptFlags::hi`) and of the scalar probe
//! oracle, across the pattern library — including the non-clique
//! patterns (wedge, diamond, house, cycles) whose plans exercise
//! non-cone levels, anti-adjacency bitmasks, and pre-LG seed lists —
//! on randomized RMAT graphs, vertex- and edge-induced, single- and
//! multi-threaded.

use sandslash::engine::hooks::NoHooks;
use sandslash::engine::{dfs, MinerConfig, OptFlags};
use sandslash::graph::gen;
use sandslash::pattern::{library, plan, Pattern};

/// House: a 4-cycle with a triangle roof — the classic non-clique,
/// non-library pattern from the paper's SL/motif discussions.
fn house() -> Pattern {
    Pattern::from_edges(&[(0, 1), (1, 2), (2, 3), (3, 0), (0, 4), (1, 4)])
}

fn patterns() -> Vec<(&'static str, Pattern)> {
    vec![
        ("wedge", library::wedge()),
        ("triangle", library::triangle()),
        ("diamond", library::diamond()),
        ("tailed-triangle", library::tailed_triangle()),
        ("4-cycle", library::cycle(4)),
        ("5-cycle", library::cycle(5)),
        ("house", house()),
        ("4-clique", library::clique(4)),
        ("5-clique", library::clique(5)),
        ("3-star", library::star(3)),
    ]
}

fn count_with(
    g: &sandslash::graph::CsrGraph,
    p: &Pattern,
    vertex_induced: bool,
    opts: OptFlags,
    threads: usize,
) -> u64 {
    let pl = plan(p, vertex_induced, true);
    let cfg = MinerConfig::custom(threads, 16, opts);
    dfs::count(g, &pl, &cfg, &NoHooks).unwrap().value
}

#[test]
fn lg_matches_set_centric_and_scalar_across_patterns_and_rmat_seeds() {
    for seed in [11u64, 22, 33] {
        let g = gen::rmat(9, 6, seed, &[]);
        for (name, p) in patterns() {
            for vertex_induced in [true, false] {
                let lg = count_with(&g, &p, vertex_induced, OptFlags::lo(), 2);
                let set = count_with(&g, &p, vertex_induced, OptFlags::hi(), 2);
                let mut scalar_opts = OptFlags::hi();
                scalar_opts.sets = false;
                let scalar = count_with(&g, &p, vertex_induced, scalar_opts, 2);
                assert_eq!(
                    lg, set,
                    "lg vs set-centric: seed={seed} {name} induced={vertex_induced}"
                );
                assert_eq!(
                    lg, scalar,
                    "lg vs scalar: seed={seed} {name} induced={vertex_induced}"
                );
            }
        }
    }
}

#[test]
fn lg_thread_invariant_on_skewed_graph() {
    // heavy-tailed RMAT: some roots exceed the LG universe crossover so
    // worker tasks mix the global set-centric and local-graph paths
    let g = gen::rmat(10, 8, 7, &[]);
    for (name, p) in patterns() {
        let t1 = count_with(&g, &p, true, OptFlags::lo(), 1);
        let t4 = count_with(&g, &p, true, OptFlags::lo(), 4);
        assert_eq!(t1, t4, "{name}");
    }
}

#[test]
fn lg_matches_on_labeled_graph() {
    // labeled pattern vertices exercise the residual label filter on
    // the local-graph candidate loop
    let g = gen::rmat(8, 6, 5, &[1, 2, 3]);
    let mut dia = library::diamond();
    dia.set_label(0, 1);
    dia.set_label(3, 2);
    let mut cyc = library::cycle(4);
    cyc.set_label(1, 3);
    for (name, p) in [("labeled diamond", dia), ("labeled 4-cycle", cyc)] {
        let lg = count_with(&g, &p, true, OptFlags::lo(), 2);
        let set = count_with(&g, &p, true, OptFlags::hi(), 2);
        assert_eq!(lg, set, "{name}");
    }
}

#[test]
fn lg_matches_on_hub_graph_straddling_the_crossover() {
    // star-core graph: hub roots blow past the universe cap (stay on
    // the global path), spoke roots switch to LG — counts must agree
    // regardless of which side of the crossover each subtree lands on
    let hub_deg = 3000usize; // > LG_UNIVERSE_CAP
    let mut b = sandslash::graph::builder::GraphBuilder::new(hub_deg + 2);
    for v in 2..(hub_deg + 2) as u32 {
        b.add_edge(0, v);
        b.add_edge(1, v);
    }
    b.add_edge(0, 1);
    let g = b.build();
    for (name, p) in
        [("diamond", library::diamond()), ("4-cycle", library::cycle(4)), ("wedge", library::wedge())]
    {
        let lg = count_with(&g, &p, true, OptFlags::lo(), 2);
        let set = count_with(&g, &p, true, OptFlags::hi(), 2);
        assert_eq!(lg, set, "{name}");
    }
}
