//! Extension-core invariance suite (PR 5).
//!
//! The shared extension core (`sandslash::engine::extend`) must be
//! *observationally invisible*: the ESU, BFS and FSM engines produce
//! bit-identical results with the core on and off (their seed scalar
//! loops are the retained oracles), across thread counts, the
//! steal/cursor scheduler swap, and shard counts — the same
//! oracle-referee contract as the SIMD kernels (`SANDSLASH_NO_SIMD`)
//! and the scheduler (`SANDSLASH_NO_STEAL`). The two-hub regression
//! then pins the other half of the claim: the migration is real on
//! both axes, i.e. (1) the adaptive/bitset kernel families are
//! *selected* inside ESU and FSM extension (per-engine dispatch
//! lanes, `metrics::dispatch::snapshot_for`), and (2) a non-DFS
//! engine actually *publishes* level-1 splits (per-engine split
//! lanes, `metrics::sched::splits_for`) — otherwise the rebase would
//! be a wrapper rename.
//!
//! Input sizing: the invariance matrix multiplies out to hundreds of
//! runs, so the RMAT legs use scale-6 graphs (edge factor 4 for
//! k ≤ 4, 2 for k = 5 — ESU's search space on a 64-vertex graph grows
//! with deg^(k-1)); the adversarial two-hub legs use k = 3 and σ = 0
//! (hub-centered FSM patterns have MNI support 1 — their center
//! domain is one hub — so any positive σ would prune exactly the
//! heavy subtrees the skew regression exists to exercise).
//!
//! Scheduler counters and dispatch counting are process-global, so the
//! tests serialize on one lock (the `sched_invariance.rs` pattern).
//! Under `SANDSLASH_NO_EXTCORE=1` (the CI oracle leg) the core never
//! runs: the invariance checks degenerate to oracle-vs-oracle and the
//! counter assertions are skipped, exactly like the `NO_STEAL` leg
//! skips the steal assertions.

use std::sync::Mutex;

use sandslash::engine::bfs::bfs_count_motifs;
use sandslash::engine::esu::{count_motifs, MotifTable};
use sandslash::engine::extend;
use sandslash::engine::fsm::mine_fsm;
use sandslash::engine::hooks::NoHooks;
use sandslash::engine::{MinerConfig, OptFlags};
use sandslash::exec::sched::{self, Overrides};
use sandslash::graph::builder::GraphBuilder;
use sandslash::graph::{gen, CsrGraph};
use sandslash::pattern::CanonCode;
use sandslash::util::metrics::{dispatch, sched as sched_counters, tag};

/// Serializes the tests in this binary (see module docs). A panicking
/// test poisons the lock; later tests recover the guard and proceed.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// The PR-5 invariance matrix: threads {1, 8} × steal {off, on} ×
/// shards {1, 2}, applied through both control planes (per-run config
/// fields + scoped overrides for any adapter-bound path).
fn for_matrix(mut check: impl FnMut(&MinerConfig, &str)) {
    for threads in [1usize, 8] {
        for steal in [false, true] {
            for shards in [1usize, 2] {
                let cfg = MinerConfig::custom(threads, 1, OptFlags::hi())
                    .with_steal(steal)
                    .with_shards(shards);
                let label = format!("threads={threads} steal={steal} shards={shards}");
                sched::with_overrides(
                    Overrides { steal: Some(steal), shards: Some(shards) },
                    || check(&cfg, &label),
                );
            }
        }
    }
}

/// Clone of `g` with labels assigned round-robin from `labels` (FSM
/// needs labeled inputs; `gen::two_hub` is unlabeled).
fn labeled_clone(g: &CsrGraph, labels: &[u32]) -> CsrGraph {
    let n = g.num_vertices();
    let edges: Vec<(u32, u32)> = g.edges().collect();
    GraphBuilder::from_edges(n, &edges)
        .with_labels((0..n).map(|v| labels[v % labels.len()]).collect())
        .build()
}

/// Order-independent FSM fingerprint: (code, support, embeddings).
fn fsm_fingerprint(
    g: &CsrGraph,
    max_edges: usize,
    sigma: u64,
    cfg: &MinerConfig,
) -> Vec<(CanonCode, u64, u64)> {
    mine_fsm(g, max_edges, sigma, cfg)
        .unwrap()
        .value
        .iter()
        .map(|f| (f.code.clone(), f.support, f.embeddings))
        .collect()
}

/// The RMAT input for one motif size: edge factor 4 for k ≤ 4, 2 for
/// k = 5 (module docs).
fn kmc_graph(k: usize, seed: u64) -> CsrGraph {
    gen::rmat(6, if k == 5 { 2 } else { 4 }, seed, &[])
}

#[test]
fn kmc_core_matches_oracle_across_seeds_k_and_matrix() {
    let _guard = serial();
    for seed in [5u64, 23, 71] {
        for k in [3usize, 4, 5] {
            let g = kmc_graph(k, seed);
            let table = MotifTable::new(k);
            let oracle_cfg =
                MinerConfig::single_thread(OptFlags::hi().with_extcore(false)).with_steal(false);
            let (want, _) = count_motifs(&g, k, &oracle_cfg, &NoHooks, &table)
                .unwrap()
                .into_parts();
            assert!(want.iter().sum::<u64>() > 0, "degenerate input seed={seed} k={k}");
            for_matrix(|cfg, label| {
                let (got, _) =
                    count_motifs(&g, k, cfg, &NoHooks, &table).unwrap().into_parts();
                assert_eq!(&got, &want, "seed={seed} k={k} core {label}");
                let mut oracle = *cfg;
                oracle.opts.extcore = false;
                let (got_o, _) =
                    count_motifs(&g, k, &oracle, &NoHooks, &table).unwrap().into_parts();
                assert_eq!(&got_o, &want, "seed={seed} k={k} oracle {label}");
            });
        }
    }
    // the adversarial graph (k = 3: a hub root's ESU subtree is every
    // vertex pair above it, so k ≥ 4 cubes the leaf count)
    let g = gen::two_hub(256);
    let table = MotifTable::new(3);
    let oracle_cfg =
        MinerConfig::single_thread(OptFlags::hi().with_extcore(false)).with_steal(false);
    let (want, _) = count_motifs(&g, 3, &oracle_cfg, &NoHooks, &table).unwrap().into_parts();
    for_matrix(|cfg, label| {
        let (got, _) = count_motifs(&g, 3, cfg, &NoHooks, &table).unwrap().into_parts();
        assert_eq!(&got, &want, "two_hub core {label}");
        let mut oracle = *cfg;
        oracle.opts.extcore = false;
        let (got_o, _) = count_motifs(&g, 3, &oracle, &NoHooks, &table).unwrap().into_parts();
        assert_eq!(&got_o, &want, "two_hub oracle {label}");
    });
}

#[test]
fn bfs_core_matches_oracle_across_seeds_and_matrix() {
    let _guard = serial();
    for seed in [5u64, 23, 71] {
        for k in [3usize, 4, 5] {
            let g = kmc_graph(k, seed);
            let table = MotifTable::new(k);
            // ESU (core-vs-oracle checked above) referees BFS
            let esu_cfg =
                MinerConfig::single_thread(OptFlags::hi().with_extcore(false)).with_steal(false);
            let (want, _) =
                count_motifs(&g, k, &esu_cfg, &NoHooks, &table).unwrap().into_parts();
            for_matrix(|cfg, label| {
                let core = bfs_count_motifs(&g, k, cfg, &table).unwrap().value;
                assert_eq!(&core.counts, &want, "seed={seed} k={k} core {label}");
                let mut oracle = *cfg;
                oracle.opts.extcore = false;
                let o = bfs_count_motifs(&g, k, &oracle, &table).unwrap().value;
                assert_eq!(&o.counts, &want, "seed={seed} k={k} oracle {label}");
                // levels are identical element-for-element, so the
                // materialization footprint agrees too
                assert_eq!(
                    core.peak_embeddings, o.peak_embeddings,
                    "seed={seed} k={k} peak {label}"
                );
            });
        }
    }
    // the adversarial graph (k = 3: hub roots square the level size
    // past that)
    let g = gen::two_hub(256);
    let table = MotifTable::new(3);
    let esu_cfg =
        MinerConfig::single_thread(OptFlags::hi().with_extcore(false)).with_steal(false);
    let (want, _) = count_motifs(&g, 3, &esu_cfg, &NoHooks, &table).unwrap().into_parts();
    for_matrix(|cfg, label| {
        assert_eq!(
            bfs_count_motifs(&g, 3, cfg, &table).unwrap().value.counts,
            want,
            "two_hub {label}"
        );
    });
}

#[test]
fn fsm_core_matches_oracle_across_grid_and_matrix() {
    let _guard = serial();
    // support × max-edges grid, three seeds, core vs oracle
    for seed in [7u64, 29, 83] {
        let g = gen::erdos_renyi(55, 0.12, seed, &[1, 2, 3]);
        for sigma in [0u64, 1, 3] {
            for max_edges in [2usize, 3] {
                let oracle_cfg = MinerConfig::custom(2, 1, OptFlags::hi().with_extcore(false));
                let want = fsm_fingerprint(&g, max_edges, sigma, &oracle_cfg);
                let got = fsm_fingerprint(
                    &g,
                    max_edges,
                    sigma,
                    &MinerConfig::custom(2, 1, OptFlags::hi()),
                );
                assert_eq!(got, want, "seed={seed} sigma={sigma} max_edges={max_edges}");
            }
        }
    }
    // thread/steal/shard matrix on one ER grid point plus the labeled
    // adversarial graph (max_edges = 2 keeps the 8-config sweep cheap;
    // σ = 0 keeps the hub bins alive — module docs)
    let g = gen::erdos_renyi(55, 0.12, 7, &[1, 2, 3]);
    let hub = labeled_clone(&gen::two_hub(64), &[1, 2, 3]);
    let base = MinerConfig::single_thread(OptFlags::hi().with_extcore(false)).with_steal(false);
    let want_g = fsm_fingerprint(&g, 3, 1, &base);
    let want_hub = fsm_fingerprint(&hub, 2, 0, &base);
    assert!(!want_g.is_empty() && !want_hub.is_empty(), "degenerate FSM inputs");
    for_matrix(|cfg, label| {
        assert_eq!(fsm_fingerprint(&g, 3, 1, cfg), want_g, "er {label}");
        assert_eq!(fsm_fingerprint(&hub, 2, 0, cfg), want_hub, "two_hub {label}");
        let mut oracle = *cfg;
        oracle.opts.extcore = false;
        assert_eq!(fsm_fingerprint(&g, 3, 1, &oracle), want_g, "er oracle {label}");
        assert_eq!(fsm_fingerprint(&hub, 2, 0, &oracle), want_hub, "two_hub oracle {label}");
    });
    // one deep (max_edges = 3) pass over the adversarial graph,
    // core vs oracle
    let deep_core = fsm_fingerprint(&hub, 3, 0, &MinerConfig::custom(8, 1, OptFlags::hi()));
    let deep_oracle = fsm_fingerprint(
        &hub,
        3,
        0,
        &MinerConfig::custom(8, 1, OptFlags::hi().with_extcore(false)),
    );
    assert_eq!(deep_core, deep_oracle, "two_hub max_edges=3");
}

#[test]
fn two_hub_migration_is_real_on_kernel_and_scheduler_axes() {
    let _guard = serial();
    if !extend::extcore_enabled_default() {
        eprintln!("skipping extcore counter assertions (SANDSLASH_NO_EXTCORE pins the oracles)");
        return;
    }

    // ---- kernel axis: the adaptive/bitset families fire inside the
    // tagged ESU and FSM lanes (any thread count — selection is
    // workload-driven, not timing-driven) ----
    dispatch::set_enabled(true);

    let esu_graph = gen::two_hub(1 << 9);
    let esu_table = MotifTable::new(3);
    let esu_cfg = MinerConfig::custom(2, 1, OptFlags::hi());
    let before = dispatch::snapshot_for(tag::Engine::Esu);
    let (esu_counts, _) =
        count_motifs(&esu_graph, 3, &esu_cfg, &NoHooks, &esu_table).unwrap().into_parts();
    let after = dispatch::snapshot_for(tag::Engine::Esu);
    assert!(
        after.word_parallel > before.word_parallel,
        "ESU's dense anti-intersection (word-parallel AND-NOT) never fired on two_hub"
    );

    // hub degree 139 ≥ 32× the sorted-embedding length, so the member
    // intersections inside FSM extension take the gallop family
    let fsm_graph = labeled_clone(&gen::two_hub(140), &[1, 2, 3]);
    let fsm_cfg = MinerConfig::custom(2, 1, OptFlags::hi());
    let f_before = dispatch::snapshot_for(tag::Engine::Fsm);
    let fsm_result = mine_fsm(&fsm_graph, 2, 0, &fsm_cfg).unwrap().value;
    let f_after = dispatch::snapshot_for(tag::Engine::Fsm);
    assert!(!fsm_result.is_empty());
    assert!(
        f_after.beyond_scalar() > f_before.beyond_scalar(),
        "no adaptive kernel family (gallop/SIMD/bitset) fired inside FSM extension on two_hub"
    );
    // PR 8 closes the counter gap: the sorted anti-intersection
    // (`difference_into`, FSM's fresh-candidate split against the
    // embedding members) now has its own dispatch family, and it must
    // actually fire in the tagged FSM lane on this workload
    assert!(
        f_after.difference > f_before.difference,
        "FSM's difference_into (fresh-candidate anti-intersection) never fired on two_hub"
    );

    // ---- scheduler axis: a non-DFS engine publishes at least one
    // split on the skewed input (needs real parallelism) ----
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores < 2 || !sched::steal_enabled_default() {
        eprintln!("skipping split assertions (cores={cores}, steal off)");
        return;
    }

    // ESU: the two hub roots carry ~all the k=3 work; with grain 1 and
    // 8 workers the cheap roots drain fast, workers starve, and the
    // hub's level-1 extension suffix must be published. Bounded retry
    // absorbs pathological OS scheduling (sched_invariance.rs pattern).
    let steal_cfg = MinerConfig::custom(8, 1, OptFlags::hi()).with_shards(1);
    let mut esu_split = false;
    for _attempt in 0..5 {
        let splits_before = sched_counters::splits_for(tag::Engine::Esu);
        let (got, _) =
            count_motifs(&esu_graph, 3, &steal_cfg, &NoHooks, &esu_table).unwrap().into_parts();
        assert_eq!(got, esu_counts, "ESU stealing run changed the counts");
        if sched_counters::splits_for(tag::Engine::Esu) > splits_before {
            esu_split = true;
            break;
        }
    }
    assert!(esu_split, "no ESU level-1 split fired on two_hub — hub roots ran sequentially");

    // FSM: few root-pattern bins, heavy child subtrees (3-edge
    // expansions over the hub wedge bins; σ = 0 keeps them alive) —
    // starving workers must receive published child-suffix windows.
    let fsm_hub = labeled_clone(&gen::two_hub(48), &[1, 2, 3]);
    let fsm_steal_cfg = MinerConfig::custom(8, 1, OptFlags::hi()).with_shards(1);
    let want = fsm_fingerprint(&fsm_hub, 3, 0, &MinerConfig::single_thread(OptFlags::hi()));
    let mut fsm_split = false;
    for _attempt in 0..5 {
        let splits_before = sched_counters::splits_for(tag::Engine::Fsm);
        let got = fsm_fingerprint(&fsm_hub, 3, 0, &fsm_steal_cfg);
        assert_eq!(got, want, "FSM stealing run changed the result");
        if sched_counters::splits_for(tag::Engine::Fsm) > splits_before {
            fsm_split = true;
            break;
        }
    }
    assert!(fsm_split, "no FSM root-bin split fired on two_hub — fat bins ran sequentially");
}
