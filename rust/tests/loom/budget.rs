//! CancelToken under the model: exactly one trip reason wins on a
//! racing cancel, and every observer agrees on the winner.

use sandslash::engine::budget::{CancelReason, CancelToken};
use sandslash::util::model;
use std::sync::Arc;

#[test]
fn racing_trips_elect_exactly_one_reason() {
    model::check(|| {
        let token = Arc::new(CancelToken::new());
        let t1 = {
            let token = token.clone();
            model::thread::spawn(move || token.trip(CancelReason::Deadline))
        };
        let t2 = {
            let token = token.clone();
            model::thread::spawn(move || token.trip(CancelReason::Caller))
        };
        let won1 = t1.join().unwrap();
        let won2 = t2.join().unwrap();
        assert!(
            won1 ^ won2,
            "exactly one racing trip must win (got {won1}/{won2})"
        );
        let reason = token.cancelled().expect("a tripped token reports a reason");
        let expected = if won1 { CancelReason::Deadline } else { CancelReason::Caller };
        assert_eq!(reason, expected, "the reported reason must be the winner's");
        assert!(token.is_cancelled());
        // later trips are ignored — the original cause survives
        assert!(!token.trip(CancelReason::TaskBudget));
        assert_eq!(token.cancelled(), Some(expected));
    });
}

#[test]
fn cancel_is_visible_to_a_concurrent_poller() {
    model::check(|| {
        let token = Arc::new(CancelToken::new());
        let poller = {
            let token = token.clone();
            model::thread::spawn(move || {
                // a cooperative worker: poll until the trip lands
                while !token.is_cancelled() {
                    model::thread::yield_now();
                }
                token.cancelled()
            })
        };
        token.cancel();
        assert_eq!(poller.join().unwrap(), Some(CancelReason::Caller));
    });
}
