//! Work-stealing scheduler under the model: across every explored
//! interleaving of deque pushes, length-mirror updates, cursor claims,
//! steals, and the active-count termination protocol, every root task
//! runs exactly once — no task is lost at termination (a worker may
//! only break after observing `active == 0` *and* a thorough sweep
//! finding nothing) and none is duplicated.
//!
//! The root-space size is deliberately tiny: the protocol machinery
//! (claim → lazy halving → steal → idle sweep → terminate) is fully
//! exercised at n=3, and every added root multiplies the schedule
//! space the preemption-bounded DFS has to cover.

use sandslash::exec::sched::{reduce, SchedPolicy, Task};
use sandslash::util::model::Model;

#[test]
fn no_task_is_lost_or_duplicated_at_termination() {
    // Two modeled workers over three roots at grain 1: worker 0 claims
    // the whole block, halves it into its deque, and worker 1 must
    // steal or idle-sweep — the exact protocol whose failure mode is a
    // task left in a deque when both workers break.
    let n = 3usize;
    let want: u64 = (1..=n as u64).sum();
    Model { preemption_bound: 2, max_schedules: 2048 }.check(|| {
        let pol = SchedPolicy { threads: 2, chunk: 1, steal: true, shards: 1 };
        let total = reduce(
            n,
            &pol,
            || 0u64,
            |acc, _, task| {
                if let Task::Roots { start, end } = task {
                    for r in start..end {
                        *acc += r as u64 + 1;
                    }
                }
            },
            |a, b| a + b,
        );
        assert_eq!(total, want, "a root was lost or ran twice");
    });
}

#[test]
fn cursor_oracle_terminates_exactly_once_per_root() {
    // The seed scheduler under the same model: the global cursor's
    // fetch_add claims must partition the root space in every
    // interleaving of the two workers.
    let n = 4usize;
    let want: u64 = (0..n as u64).sum();
    Model { preemption_bound: 2, max_schedules: 2048 }.check(|| {
        let pol = SchedPolicy { threads: 2, chunk: 1, steal: false, shards: 1 };
        let total = reduce(
            n,
            &pol,
            || 0u64,
            |acc, _, task| {
                if let Task::Roots { start, end } = task {
                    for r in start..end {
                        *acc += r as u64;
                    }
                }
            },
            |a, b| a + b,
        );
        assert_eq!(total, want, "the cursor lost or repeated a claim");
    });
}
