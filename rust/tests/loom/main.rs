//! PR-8 loom suite: the four migrated protocols re-run under every
//! interleaving the in-tree model checker explores.
//!
//! Build and run with
//! `RUSTFLAGS="--cfg loom" cargo test -p rust_pallas --test loom`
//! — and ONLY `--test loom`: under `--cfg loom` the library's sync
//! facade routes onto the token-serialized model primitives, which are
//! sound only inside `model::check`; the ordinary suites would put
//! real concurrency on them. Knobs: `SANDSLASH_MODEL_ITERS` (schedule
//! cap) and `SANDSLASH_MODEL_PREEMPTIONS` (preemption bound) override
//! the per-test bounds' defaults. Without `--cfg loom` this target
//! compiles to an empty test binary.
#![cfg(loom)]

mod admission;
mod budget;
mod cache;
mod sched;
