//! Single-flight result cache under the model: a failed (rejected)
//! leader can never clobber a newer leader's fill, waiters either
//! coalesce a real fill or take over leadership themselves, and the
//! compute-once guarantee holds per cacheable resolution.

use sandslash::pattern::CanonCode;
use sandslash::service::cache::{CacheKey, HookKind, ResultCache};
use sandslash::util::model;
use std::sync::Arc;

fn key() -> CacheKey {
    CacheKey {
        graph: "g".to_string(),
        epoch: 0,
        pattern: CanonCode { n: 3, labels: vec![0, 0, 0], bits: 0b11 },
        vertex_induced: false,
        hook: HookKind::Count,
    }
}

fn val(s: &str) -> Arc<String> {
    Arc::new(s.to_string())
}

#[test]
fn rejected_leader_never_clobbers_the_newer_fill() {
    model::check(|| {
        let cache = Arc::new(ResultCache::new(1 << 16));
        let k = key();
        // One thread's compute always fails (budget-tripped partial,
        // not cacheable); the other's succeeds. Across every
        // interleaving of leadership, waiting, rejection re-opening
        // the key, and the second leadership, the successful fill must
        // survive in the table.
        let rejecter = {
            let (cache, k) = (cache.clone(), k.clone());
            model::thread::spawn(move || cache.get_or_compute(&k, || (val("partial"), false)))
        };
        let filler = {
            let (cache, k) = (cache.clone(), k.clone());
            model::thread::spawn(move || cache.get_or_compute(&k, || (val("done"), true)))
        };
        let (rv, _) = rejecter.join().unwrap();
        let (fv, _) = filler.join().unwrap();
        // each caller got a plausible value: its own compute's output,
        // or the other's via coalescing / a ready hit
        assert!(rv.as_str() == "partial" || rv.as_str() == "done", "got {rv}");
        assert!(fv.as_str() == "done" || fv.as_str() == "partial", "got {fv}");
        let stats = cache.stats();
        // the cacheable compute resolves at most once; the rejecting
        // compute runs only if it led before a fill existed
        assert!(stats.fills <= 1, "one cacheable compute: fills={}", stats.fills);
        assert!(stats.rejected <= 1, "one failing compute: rejected={}", stats.rejected);
        if stats.fills == 1 {
            // THE invariant: whatever order the rejection and the fill
            // resolved in, the fill is still probeable — the rejected
            // leader's cleanup removed only its own pending slot
            let (v, cached) = cache.get_or_compute(&k, || {
                unreachable!("the fill must still be resident")
            });
            assert!(cached);
            assert_eq!(v.as_str(), "done");
        } else {
            // the filler either led directly or was woken by the
            // rejection and led next — in every interleaving its
            // cacheable compute runs and fills exactly once
            panic!("the cacheable compute must have filled (stats: {stats:?})");
        }
    });
}

#[test]
fn concurrent_misses_agree_on_one_set_of_bytes() {
    model::check(|| {
        let cache = Arc::new(ResultCache::new(1 << 16));
        let k = key();
        let a = {
            let (cache, k) = (cache.clone(), k.clone());
            model::thread::spawn(move || cache.get_or_compute(&k, || (val("done"), true)))
        };
        let b = {
            let (cache, k) = (cache.clone(), k.clone());
            model::thread::spawn(move || cache.get_or_compute(&k, || (val("done"), true)))
        };
        let (va, _) = a.join().unwrap();
        let (vb, _) = b.join().unwrap();
        let stats = cache.stats();
        assert_eq!(
            stats.fills + stats.rejected,
            stats.misses,
            "every leadership resolves exactly once (stats: {stats:?})"
        );
        // both callers and the table hold the same bytes: a hit is
        // byte-identical to its miss-path original
        let (vc, cached) = cache.get_or_compute(&k, || unreachable!("must hit"));
        assert!(cached);
        assert_eq!(va.as_str(), "done");
        assert_eq!(vb.as_str(), "done");
        assert_eq!(vc.as_str(), "done");
    });
}
