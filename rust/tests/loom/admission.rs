//! Admission gate under the model: `inflight` never exceeds
//! `max_inflight` in any interleaving, permits are never lost (every
//! queued waiter is eventually admitted), and the high-priority class
//! claims freed slots first.

use sandslash::service::admission::{Admission, Priority};
use sandslash::util::model;
use sandslash::util::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

#[test]
fn inflight_never_exceeds_the_bound() {
    model::check(|| {
        let gate = Arc::new(Admission::new(1, 4));
        // under loom this is the model atomic, so the increment, the
        // peak check, and the decrement interleave with the gate's own
        // lock/condvar traffic at every explorable point
        let concurrent = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let (gate, concurrent) = (gate.clone(), concurrent.clone());
                model::thread::spawn(move || {
                    let permit = gate.admit(Priority::Normal).expect("queue depth 4 never rejects 2 clients");
                    let inside = concurrent.fetch_add(1, Ordering::SeqCst) + 1;
                    assert!(inside <= 1, "two permits live under max_inflight=1");
                    model::thread::yield_now();
                    concurrent.fetch_sub(1, Ordering::SeqCst);
                    drop(permit);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // no permit leaked: the gate is fully drained
        assert_eq!(gate.snapshot(), (0, 0));
    });
}

#[test]
fn freed_slot_prefers_the_high_priority_waiter() {
    model::check(|| {
        let gate = Arc::new(Admission::new(1, 4));
        let order = Arc::new(AtomicUsize::new(0));
        let holder = gate.admit(Priority::Normal).expect("empty gate admits");
        let normal = {
            let (gate, order) = (gate.clone(), order.clone());
            model::thread::spawn(move || {
                let _p = gate.admit(Priority::Normal).unwrap();
                order.fetch_add(1, Ordering::SeqCst)
            })
        };
        let high = {
            let (gate, order) = (gate.clone(), order.clone());
            model::thread::spawn(move || {
                let _p = gate.admit(Priority::High).unwrap();
                order.fetch_add(1, Ordering::SeqCst)
            })
        };
        // make sure the high waiter is actually queued before the slot
        // frees — otherwise "preference" is vacuous for this schedule
        while gate.snapshot().1 < 2 {
            model::thread::yield_now();
        }
        drop(holder);
        let normal_rank = normal.join().unwrap();
        let high_rank = high.join().unwrap();
        assert!(
            high_rank < normal_rank,
            "queued high-priority waiter must be admitted before the queued normal \
             (high ran {high_rank}, normal ran {normal_rank})"
        );
        assert_eq!(gate.snapshot(), (0, 0));
    });
}
