//! Query-governance suite (PR 6): deadlines, task budgets, caller
//! cancellation, and worker panic isolation across every engine.
//!
//! What must hold (ISSUE 6 acceptance):
//!
//! * A tripped budget is **not** an error: the engine returns a partial
//!   [`Outcome`] whose value is a lower bound on the true count, with
//!   `complete == false` and the tripping [`CancelReason`].
//! * Task budgets are honored within one block grain; a budget wide
//!   enough for the whole root space completes bit-identically.
//! * An injected panic at any engine stage ([`Stage`]) surfaces as
//!   [`MineError::WorkerPanicked`] with the process alive and the pool
//!   unpoisoned — the same engine completes cleanly immediately after —
//!   across the full threads × steal × shards matrix.
//! * With budgets unset, governed counts are bit-identical to runs with
//!   governance disabled outright (the differential-oracle discipline
//!   every PR in this repo follows).
//! * The CLI maps every governance ending to a distinct exit code and a
//!   one-line diagnosis naming the knob to raise, while still printing
//!   the partial answer.
//!
//! The fault harness ([`sandslash::util::fault`]) and the governance
//! counters are process-global, so the tests serialize on one lock —
//! the `sched_invariance.rs` pattern.

use std::sync::Arc;
use std::time::Duration;

use sandslash::engine::bfs::bfs_count_motifs;
use sandslash::engine::budget::{self, Budget};
use sandslash::engine::esu::{count_motifs, MotifTable};
use sandslash::engine::fsm::mine_fsm;
use sandslash::engine::hooks::NoHooks;
use sandslash::engine::{dfs, CancelReason, CancelToken, MineError, MinerConfig, OptFlags};
use sandslash::exec::sched::{self, Overrides};
use sandslash::graph::gen;
use sandslash::pattern::{library, plan};
use sandslash::util::fault::{self, FaultAction, FaultPlan, Stage};
use sandslash::util::metrics;

/// Serializes the tests in this binary (module docs). A panicking test
/// poisons the lock; later tests recover the guard and proceed.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn tri_plan() -> sandslash::pattern::MatchingPlan {
    plan(&library::triangle(), true, true)
}

#[test]
fn golden_diagnosis_strings_and_exit_codes() {
    // Satellite 3: the messages are part of the CLI contract — golden,
    // not just substring-matched.
    assert_eq!(
        CancelReason::Deadline.diagnosis(),
        "deadline exceeded: counts below are partial; raise --deadline-ms \
         (or SANDSLASH_DEADLINE_MS) or narrow the query to finish"
    );
    assert_eq!(
        CancelReason::TaskBudget.diagnosis(),
        "task budget exhausted: counts below are partial; raise --max-tasks \
         (or SANDSLASH_MAX_TASKS) or narrow the query to finish"
    );
    assert_eq!(
        CancelReason::Caller.diagnosis(),
        "cancelled by caller: counts below are partial up to the cancellation point"
    );
    assert_eq!(
        CancelReason::WorkerPanic.diagnosis(),
        "a worker panicked mid-run: results were discarded, not returned partial"
    );
    assert_eq!(
        format!("{}", MineError::WorkerPanicked { engine: "dfs", payload: "boom".into() }),
        "a dfs worker panicked mid-run: boom; the run was drained cleanly (no results) \
         — rerun, or fix the panicking hook"
    );
    // code map: 0 complete, 1 load, 2 usage, then the governance codes
    assert_eq!(
        [
            MineError::WorkerPanicked { engine: "dfs", payload: String::new() }.exit_code(),
            CancelReason::Deadline.exit_code(),
            CancelReason::TaskBudget.exit_code(),
            CancelReason::Caller.exit_code(),
        ],
        [4, 5, 6, 7]
    );
}

#[test]
fn deadline_trips_mid_run_and_returns_a_partial_lower_bound() {
    let _guard = serial();
    let g = gen::rmat(10, 8, 11, &[]);
    let pl = tri_plan();
    let want = dfs::count(&g, &pl, &MinerConfig::custom(2, 8, OptFlags::hi()), &NoHooks)
        .unwrap()
        .value;
    assert!(want > 0, "degenerate input");
    // a delay fault makes the first claimed block reliably outlast a
    // short deadline; one thread, grain 1, so the remaining blocks are
    // refused one by one after the trip
    fault::install(FaultPlan {
        action: FaultAction::Delay(Duration::from_millis(80)),
        at_task: 0,
        stage: Some(Stage::RootClaim),
    });
    let cfg = MinerConfig::custom(1, 1, OptFlags::hi())
        .with_deadline(Duration::from_millis(20));
    let before = metrics::gov::snapshot();
    let out = dfs::count(&g, &pl, &cfg, &NoHooks).unwrap();
    let after = metrics::gov::snapshot();
    fault::clear();
    assert!(!out.complete, "an outlasted deadline must not report complete");
    assert_eq!(out.tripped, Some(CancelReason::Deadline));
    assert!(out.value <= want, "partial {} exceeds true count {want}", out.value);
    assert_eq!(after.deadline_trips, before.deadline_trips + 1);
}

#[test]
fn expired_deadline_yields_partial_outcomes_on_every_engine() {
    let _guard = serial();
    let g = gen::rmat(8, 6, 7, &[]);
    let lg = gen::erdos_renyi(50, 0.12, 9, &[1, 2]);
    let cfg = MinerConfig::custom(2, 8, OptFlags::hi()).with_deadline(Duration::ZERO);
    let table = MotifTable::new(3);
    let d = dfs::count(&g, &tri_plan(), &cfg, &NoHooks).unwrap();
    assert!(!d.complete && d.tripped == Some(CancelReason::Deadline));
    assert_eq!(d.value, 0, "no block may run under an already-expired deadline");
    let e = count_motifs(&g, 3, &cfg, &NoHooks, &table).unwrap();
    assert!(!e.complete && e.tripped == Some(CancelReason::Deadline));
    assert!(e.value.iter().all(|&c| c == 0));
    let f = mine_fsm(&lg, 2, 1, &cfg).unwrap();
    assert!(!f.complete && f.tripped == Some(CancelReason::Deadline));
    let b = bfs_count_motifs(&g, 3, &cfg, &table).unwrap();
    assert!(!b.complete && b.tripped == Some(CancelReason::Deadline));
}

#[test]
fn task_budget_honored_within_one_block_grain() {
    let _guard = serial();
    let g = gen::rmat(10, 8, 11, &[]);
    let pl = tri_plan();
    let want = dfs::count(&g, &pl, &MinerConfig::custom(2, 8, OptFlags::hi()), &NoHooks)
        .unwrap()
        .value;
    // one thread, grain 1: each admitted task is exactly one root, so a
    // budget of 4 mines at most 4 roots before refusing
    let cfg = MinerConfig::custom(1, 1, OptFlags::hi()).with_max_tasks(4);
    let before = metrics::gov::snapshot();
    let out = dfs::count(&g, &pl, &cfg, &NoHooks).unwrap();
    let after = metrics::gov::snapshot();
    assert!(!out.complete);
    assert_eq!(out.tripped, Some(CancelReason::TaskBudget));
    assert!(out.value <= want);
    assert!(
        out.stats.enumerated <= 4 * g.num_vertices() as u64,
        "4 grain-1 tasks cannot enumerate more than 4 roots' candidates"
    );
    assert_eq!(after.task_budget_trips, before.task_budget_trips + 1);
    // a budget covering every block completes bit-identically
    let n = g.num_vertices() as u64;
    let wide = MinerConfig::custom(1, 1, OptFlags::hi()).with_max_tasks(n + 8);
    let ok = dfs::count(&g, &pl, &wide, &NoHooks).unwrap();
    assert!(ok.complete && ok.tripped.is_none());
    assert_eq!(ok.value, want);
}

#[test]
fn caller_cancellation_stops_the_run_at_its_first_poll() {
    let _guard = serial();
    let g = gen::rmat(9, 8, 3, &[]);
    let pl = tri_plan();
    let token = Arc::new(CancelToken::new());
    token.cancel(); // pre-tripped: no block may be admitted
    let out = budget::with_cancel(token, || {
        dfs::count(&g, &pl, &MinerConfig::custom(2, 8, OptFlags::hi()), &NoHooks)
    })
    .unwrap();
    assert!(!out.complete);
    assert_eq!(out.tripped, Some(CancelReason::Caller));
    assert_eq!(out.value, 0);
    // outside the scope, the same run completes — the token was scoped
    let clean = dfs::count(&g, &pl, &MinerConfig::custom(2, 8, OptFlags::hi()), &NoHooks)
        .unwrap();
    assert!(clean.complete);
    assert!(clean.value > 0);
}

#[test]
fn budgets_unset_counts_bit_identical_to_governance_disabled() {
    let _guard = serial();
    let g = gen::rmat(9, 8, 5, &[]);
    let lg = gen::erdos_renyi(60, 0.12, 9, &[1, 2, 3]);
    let cfg = MinerConfig::custom(4, 8, OptFlags::hi());
    assert_eq!(cfg.budget, Budget::default(), "test premise: no limits set");
    let pl = tri_plan();
    let t3 = MotifTable::new(3);
    let fp = |r: &[sandslash::engine::fsm::FrequentPattern]| {
        r.iter().map(|f| (f.code.clone(), f.support)).collect::<Vec<_>>()
    };
    let (raw_dfs, raw_esu, raw_bfs, raw_fsm) = budget::with_governance_disabled(|| {
        (
            dfs::count(&g, &pl, &cfg, &NoHooks).unwrap().value,
            count_motifs(&g, 3, &cfg, &NoHooks, &t3).unwrap().value,
            bfs_count_motifs(&g, 3, &cfg, &t3).unwrap().value.counts,
            mine_fsm(&lg, 3, 1, &cfg).unwrap().value,
        )
    });
    let gov_dfs = dfs::count(&g, &pl, &cfg, &NoHooks).unwrap();
    assert!(gov_dfs.complete && gov_dfs.tripped.is_none());
    assert_eq!(gov_dfs.value, raw_dfs);
    assert_eq!(count_motifs(&g, 3, &cfg, &NoHooks, &t3).unwrap().value, raw_esu);
    assert_eq!(bfs_count_motifs(&g, 3, &cfg, &t3).unwrap().value.counts, raw_bfs);
    assert_eq!(fp(&mine_fsm(&lg, 3, 1, &cfg).unwrap().value), fp(&raw_fsm));
}

#[test]
fn injected_root_claim_panic_is_isolated_across_the_matrix() {
    let _guard = serial();
    let g = gen::rmat(8, 6, 7, &[]);
    let pl = tri_plan();
    let want = dfs::count(&g, &pl, &MinerConfig::single_thread(OptFlags::hi()), &NoHooks)
        .unwrap()
        .value;
    for threads in [1usize, 8] {
        for steal in [false, true] {
            for shards in [1usize, 2] {
                let label = format!("threads={threads} steal={steal} shards={shards}");
                let cfg = MinerConfig::custom(threads, 1, OptFlags::hi())
                    .with_steal(steal)
                    .with_shards(shards);
                sched::with_overrides(
                    Overrides { steal: Some(steal), shards: Some(shards) },
                    || {
                        fault::install(FaultPlan {
                            action: FaultAction::Panic,
                            at_task: 0,
                            stage: Some(Stage::RootClaim),
                        });
                        let res = dfs::count(&g, &pl, &cfg, &NoHooks);
                        fault::clear();
                        match res {
                            Err(MineError::WorkerPanicked { engine, payload }) => {
                                assert_eq!(engine, "dfs", "{label}");
                                assert!(
                                    payload.contains("injected fault"),
                                    "{label}: payload {payload:?}"
                                );
                            }
                            other => {
                                panic!("{label}: expected WorkerPanicked, got {other:?}")
                            }
                        }
                        // process alive, pool unpoisoned: the very next
                        // run on the same configuration completes exactly
                        let again = dfs::count(&g, &pl, &cfg, &NoHooks).unwrap();
                        assert!(again.complete, "{label}");
                        assert_eq!(again.value, want, "{label}");
                    },
                );
            }
        }
    }
}

#[test]
fn every_engine_surfaces_injected_panics_with_the_process_alive() {
    let _guard = serial();
    let g = gen::rmat(8, 6, 7, &[]);
    let lg = gen::erdos_renyi(60, 0.12, 9, &[1, 2, 3]);
    let cfg = MinerConfig::custom(4, 4, OptFlags::hi());
    let t3 = MotifTable::new(3);

    // ESU: panic in a claimed root task
    fault::install(FaultPlan {
        action: FaultAction::Panic,
        at_task: 0,
        stage: Some(Stage::RootClaim),
    });
    let esu = count_motifs(&g, 3, &cfg, &NoHooks, &t3);
    fault::clear();
    assert!(
        matches!(&esu, Err(MineError::WorkerPanicked { engine: "esu", .. })),
        "esu: {esu:?}"
    );

    // FSM: panic inside child-pattern regeneration
    fault::install(FaultPlan {
        action: FaultAction::Panic,
        at_task: 0,
        stage: Some(Stage::FsmRegen),
    });
    let fsm = mine_fsm(&lg, 3, 1, &cfg);
    fault::clear();
    assert!(
        matches!(&fsm, Err(MineError::WorkerPanicked { engine: "fsm", .. })),
        "fsm: {fsm:?}"
    );

    // BFS: panic inside a level-expansion block
    fault::install(FaultPlan {
        action: FaultAction::Panic,
        at_task: 0,
        stage: Some(Stage::BfsLevel),
    });
    let bfs = bfs_count_motifs(&g, 3, &cfg, &t3);
    fault::clear();
    assert!(
        matches!(&bfs, Err(MineError::WorkerPanicked { engine: "bfs", .. })),
        "bfs: {bfs:?}"
    );

    // harness disarmed: every engine completes cleanly in this process
    assert!(count_motifs(&g, 3, &cfg, &NoHooks, &t3).unwrap().complete);
    assert!(mine_fsm(&lg, 3, 1, &cfg).unwrap().complete);
    assert!(bfs_count_motifs(&g, 3, &cfg, &t3).unwrap().complete);
}

#[test]
fn split_task_panic_is_isolated_when_splits_fire() {
    let _guard = serial();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores < 2 || !sched::steal_enabled_default() {
        eprintln!("skipping split-task fault injection (cores={cores}, steal off)");
        return;
    }
    // two hub roots carry ~all the work; grain 1 and 8 workers starve
    // the cheap tail into the split protocol (the PR-4 regression
    // input), so a SplitTask crossing fires on some bounded attempt
    let g = gen::two_hub(1 << 13);
    let pl = tri_plan();
    let cfg = MinerConfig::custom(8, 1, OptFlags::hi()).with_shards(1);
    let want = dfs::count(&g, &pl, &MinerConfig::single_thread(OptFlags::hi()), &NoHooks)
        .unwrap()
        .value;
    let mut fired = false;
    for _attempt in 0..5 {
        fault::install(FaultPlan {
            action: FaultAction::Panic,
            at_task: 0,
            stage: Some(Stage::SplitTask),
        });
        let res = dfs::count(&g, &pl, &cfg, &NoHooks);
        fault::clear();
        match res {
            Err(MineError::WorkerPanicked { engine, payload }) => {
                assert_eq!(engine, "dfs");
                assert!(payload.contains("injected fault"), "payload {payload:?}");
                fired = true;
                break;
            }
            // no split happened on this attempt (timing): the run must
            // then be complete and exact, never silently partial
            Ok(out) => {
                assert!(out.complete);
                assert_eq!(out.value, want);
            }
            Err(other) => panic!("unexpected error: {other}"),
        }
    }
    assert!(fired, "no split task fired across 5 attempts on the two-hub graph");
}

#[test]
fn cli_maps_governance_endings_to_distinct_exit_codes() {
    // Satellite 3, end to end: spawn the real binary. `--system
    // peregrine` routes tc through the governed generic engine (the
    // default `hi` system is the hand-tuned ungoverned kernel).
    let bin = env!("CARGO_BIN_EXE_sandslash");
    let run = |args: &[&str], envs: &[(&str, &str)]| {
        let mut cmd = std::process::Command::new(bin);
        cmd.args(args);
        for (k, v) in envs {
            cmd.env(k, v);
        }
        let out = cmd.output().expect("spawn sandslash");
        (
            out.status.code(),
            String::from_utf8_lossy(&out.stdout).into_owned(),
            String::from_utf8_lossy(&out.stderr).into_owned(),
        )
    };
    let tc: &[&str] =
        &["tc", "--graph", "er-small", "--system", "peregrine", "--threads", "1"];

    // worker panic -> exit 4, diagnosis on stderr, no partial answer
    let (code, _, err) = run(tc, &[("SANDSLASH_FAULT", "panic@0")]);
    assert_eq!(code, Some(4), "stderr: {err}");
    assert!(err.contains("worker panicked mid-run"), "{err}");
    assert!(err.contains("injected fault"), "{err}");

    // task budget -> exit 6, knob named, partial answer still printed
    let (code, outp, err) = run(&[tc, &["--max-tasks", "1"]].concat(), &[]);
    assert_eq!(code, Some(6), "stderr: {err}");
    assert!(err.contains("raise --max-tasks"), "{err}");
    assert!(outp.contains("triangles = "), "partial answer must still print: {outp}");

    // deadline (first block delayed past it) -> exit 5, knob named
    let (code, outp, err) = run(
        &[tc, &["--deadline-ms", "10"]].concat(),
        &[("SANDSLASH_FAULT", "delay@0:200")],
    );
    assert_eq!(code, Some(5), "stderr: {err}");
    assert!(err.contains("raise --deadline-ms"), "{err}");
    assert!(outp.contains("triangles = "), "{outp}");

    // SANDSLASH_NO_GOV disables budgets outright -> complete, exit 0
    let (code, outp, err) =
        run(&[tc, &["--max-tasks", "1"]].concat(), &[("SANDSLASH_NO_GOV", "1")]);
    assert_eq!(code, Some(0), "stderr: {err}");
    assert!(outp.contains("triangles = "), "{outp}");

    // unusable budget flags are rejected loudly and the run completes
    let (code, _, err) = run(&[tc, &["--max-tasks", "banana"]].concat(), &[]);
    assert_eq!(code, Some(0), "stderr: {err}");
    assert!(err.contains("ignoring --max-tasks"), "{err}");

    // usage and load failures keep their reserved codes
    let (code, _, _) = run(&["frobnicate"], &[]);
    assert_eq!(code, Some(2));
    let (code, _, _) = run(&["tc", "--graph", "no-such-graph"], &[]);
    assert_eq!(code, Some(1));
}
