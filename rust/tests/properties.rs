//! Property-based tests (hand-rolled driver over the crate's seeded RNG,
//! standing in for proptest — see DESIGN.md §4): invariants that must
//! hold for *any* graph, exercised across randomized instances.

use sandslash::apps::{clique, motif, sl, tc};
use sandslash::engine::{fsm, MinerConfig, OptFlags};
use sandslash::graph::builder::relabel;
use sandslash::graph::{gen, CsrGraph};
use sandslash::pattern::library;
use sandslash::util::rng::Rng;

fn cfg() -> MinerConfig {
    MinerConfig::custom(2, 16, OptFlags::hi())
}

/// Random graph drawn from a seeded family mix.
fn random_graph(rng: &mut Rng) -> CsrGraph {
    match rng.below(3) {
        0 => gen::erdos_renyi(
            40 + rng.below(60) as usize,
            0.05 + rng.f64() * 0.2,
            rng.next_u64(),
            &[],
        ),
        1 => gen::rmat(7 + rng.below(2) as u32, 4 + rng.below(6) as usize, rng.next_u64(), &[]),
        _ => gen::barabasi_albert(50 + rng.below(100) as usize, 3, rng.next_u64(), &[]),
    }
}

fn random_permutation(rng: &mut Rng, n: usize) -> Vec<u32> {
    let mut p: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut p);
    p
}

#[test]
fn prop_counts_invariant_under_relabeling() {
    let mut rng = Rng::seeded(0xC0FFEE);
    for round in 0..12 {
        let g = random_graph(&mut rng);
        let perm = random_permutation(&mut rng, g.num_vertices());
        let h = relabel(&g, &perm);
        assert_eq!(tc::tc_hi(&g, &cfg()), tc::tc_hi(&h, &cfg()), "round {round}");
        assert_eq!(
            clique::clique_lo(&g, 4, &cfg()).0,
            clique::clique_lo(&h, 4, &cfg()).0,
            "round {round}"
        );
        assert_eq!(
            motif::motif4_lo(&g, &cfg()).unwrap(),
            motif::motif4_lo(&h, &cfg()).unwrap(),
            "round {round}"
        );
        assert_eq!(
            sl::sl_count(&g, &library::diamond(), &cfg()).unwrap().value,
            sl::sl_count(&h, &library::diamond(), &cfg()).unwrap().value,
            "round {round}"
        );
    }
}

#[test]
fn prop_hi_equals_lo_equals_brute() {
    let mut rng = Rng::seeded(0xBEEF);
    for round in 0..8 {
        let g = gen::erdos_renyi(
            30 + rng.below(20) as usize,
            0.1 + rng.f64() * 0.2,
            rng.next_u64(),
            &[],
        );
        let brute3 = clique::clique_brute(&g, 3);
        assert_eq!(tc::tc_hi(&g, &cfg()), brute3, "round {round}");
        for k in [4, 5] {
            let brute = clique::clique_brute(&g, k);
            assert_eq!(clique::clique_hi(&g, k, &cfg()).0, brute, "hi round {round} k={k}");
            assert_eq!(clique::clique_lo(&g, k, &cfg()).0, brute, "lo round {round} k={k}");
        }
    }
}

#[test]
fn prop_motif_identities() {
    // Global combinatorial identities tie the motif census to degree
    // statistics — a strong oracle that needs no enumeration.
    let mut rng = Rng::seeded(0xF00D);
    for round in 0..10 {
        let g = random_graph(&mut rng);
        let m3 = motif::motif3_lo(&g, &cfg());
        // wedges + 3*triangles == sum_v C(deg v, 2)
        let paths2: u64 = (0..g.num_vertices() as u32)
            .map(|v| {
                let d = g.degree(v) as u64;
                d.saturating_sub(1) * d / 2
            })
            .sum();
        assert_eq!(m3[0] + 3 * m3[1], paths2, "round {round}");

        let m4 = motif::motif4_lo(&g, &cfg()).unwrap();
        let hi4 = motif::motif4_hi(&g, &cfg()).unwrap().value;
        assert_eq!(m4, hi4, "round {round}");
    }
}

#[test]
fn prop_fsm_antimonotone_and_label_permutation() {
    let mut rng = Rng::seeded(0xAB5);
    for round in 0..6 {
        let g = gen::erdos_renyi(
            40 + rng.below(30) as usize,
            0.08 + rng.f64() * 0.08,
            rng.next_u64(),
            &[1, 2, 3],
        );
        // anti-monotonicity of result sets in sigma
        let r1 = fsm::mine_fsm(&g, 3, 1, &cfg()).unwrap().value;
        let r2 = fsm::mine_fsm(&g, 3, 3, &cfg()).unwrap().value;
        let codes1: Vec<_> = r1.iter().map(|f| f.code.clone()).collect();
        for f in &r2 {
            assert!(codes1.contains(&f.code), "round {round}: sigma-up grew the set");
            assert!(f.support > 3);
        }
        // every frequent pattern's parent-support >= its own support
        for f in &r1 {
            if f.pattern.num_edges() >= 2 {
                let parent = fsm::canonical_parent_code(&f.pattern);
                let ps = r1
                    .iter()
                    .find(|x| x.code == parent)
                    .map(|x| x.support)
                    .expect("parent of a frequent pattern must be frequent");
                assert!(ps >= f.support, "round {round}: MNI not anti-monotone");
            }
        }
    }
}

#[test]
fn prop_edge_count_conservation_in_generators() {
    let mut rng = Rng::seeded(0x9E3);
    for _ in 0..10 {
        let g = random_graph(&mut rng);
        // CSR symmetry: directed degree sum equals 2x undirected edges
        let degsum: usize = (0..g.num_vertices() as u32).map(|v| g.degree(v)).sum();
        assert_eq!(degsum, 2 * g.num_undirected_edges());
        // neighbor lists sorted, no self loops, no duplicates
        for v in 0..g.num_vertices() as u32 {
            let ns = g.neighbors(v);
            assert!(ns.windows(2).all(|w| w[0] < w[1]), "sorted+unique");
            assert!(!ns.contains(&v), "no self loop");
        }
    }
}
