//! Wire-protocol golden suite (PR 7).
//!
//! The resident service speaks line-delimited JSON; this file pins the
//! grammar down: requests round-trip through `render`/`parse_request`,
//! every malformed line is rejected with a **stable named error** (not
//! ignored, not guessed at), and the structured `code` field carries
//! exactly the PR-6 CLI exit-code table — `deadline`=5, `task-budget`=6,
//! `caller`=7 — so a client can switch on codes without caring whether
//! it ran `sandslash dfs` or asked the resident process.
//!
//! Engine-backed code-parity tests skip under `SANDSLASH_NO_GOV=1`
//! (the service refuses to start there; `service_concurrency.rs`
//! asserts the refusal).

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use sandslash::engine::bfs::BfsCapExceeded;
use sandslash::engine::budget::{self, Budget};
use sandslash::engine::{CancelReason, MineError};
use sandslash::service::json;
use sandslash::service::protocol::{mine_error_code, mine_error_name, trip_name};
use sandslash::service::{
    count_result, parse_request, resolve_pattern, response_code, Body, Op, PatternSpec, Priority,
    Request, Response, Service, ServiceConfig, CODE_OVERLOADED,
};
use sandslash::util::fault::{self, FaultAction, FaultPlan, Stage};

static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn requests_round_trip_through_render_and_parse() {
    let mut battery = vec![
        Request::query("q1", "er-small", PatternSpec::Named("triangle".into())),
        Request::query("q2", "lj-mini", PatternSpec::Edges(vec![(0, 1), (1, 2), (2, 0)])),
        Request::bare("p1", Op::Ping),
        Request::bare("s1", Op::Stats),
        Request::bare("g1", Op::Graphs),
        Request::bare("x1", Op::Shutdown),
    ];
    // every optional knob at a non-default value
    let mut loaded = Request::query("q3", "ba-small", PatternSpec::Named("4clique".into()));
    loaded.vertex_induced = true;
    loaded.deadline_ms = Some(250);
    loaded.max_tasks = Some(1000);
    loaded.threads = Some(4);
    loaded.priority = Priority::High;
    loaded.no_cache = true;
    loaded.trace = true;
    battery.push(loaded);
    let mut cancel = Request::bare("c1", Op::Cancel);
    cancel.target = Some("q3".into());
    battery.push(cancel);
    let mut inv = Request::bare("i1", Op::Invalidate);
    inv.graph = Some("er-small".into());
    battery.push(inv);
    // ids carrying JSON-significant characters must survive escaping
    battery.push(Request::query("q\"4\\", "er-small", PatternSpec::Named("wedge".into())));

    for req in battery {
        let line = req.render();
        let back = parse_request(&line)
            .unwrap_or_else(|e| panic!("round-trip of {line} rejected: {} ({})", e.name, e.detail));
        assert_eq!(back, req, "round-trip of {line}");
        // a second bounce is bit-stable
        assert_eq!(back.render(), line);
    }
}

#[test]
fn malformed_lines_are_rejected_with_stable_names() {
    let long_id = "x".repeat(129);
    let cases: Vec<(String, &str)> = vec![
        ("not json{".into(), "malformed-json"),
        ("".into(), "malformed-json"),
        ("[1,2]".into(), "not-an-object"),
        ("\"just a string\"".into(), "not-an-object"),
        ("{}".into(), "missing-field"),
        ("{\"op\":\"query\"}".into(), "missing-field"),
        ("{\"id\":\"\"}".into(), "bad-field"),
        (format!("{{\"id\":\"{long_id}\"}}"), "bad-field"),
        ("{\"id\":7}".into(), "missing-field"), // a non-string id is no id at all
        ("{\"id\":\"x\",\"op\":\"frobnicate\"}".into(), "unknown-op"),
        ("{\"id\":\"x\",\"op\":7}".into(), "bad-field"),
        ("{\"id\":\"x\",\"wat\":1}".into(), "unknown-field"),
        ("{\"id\":\"x\",\"graph\":\"\"}".into(), "bad-field"),
        ("{\"id\":\"x\",\"pattern\":3}".into(), "bad-field"),
        ("{\"id\":\"x\",\"induced\":\"yes\"}".into(), "bad-field"),
        ("{\"id\":\"x\",\"deadline_ms\":-1}".into(), "bad-field"),
        ("{\"id\":\"x\",\"deadline_ms\":\"soon\"}".into(), "bad-field"),
        ("{\"id\":\"x\",\"max_tasks\":0}".into(), "bad-field"),
        ("{\"id\":\"x\",\"threads\":0}".into(), "bad-field"),
        ("{\"id\":\"x\",\"threads\":257}".into(), "bad-field"),
        ("{\"id\":\"x\",\"priority\":\"urgent\"}".into(), "bad-field"),
        ("{\"id\":\"x\",\"no_cache\":1}".into(), "bad-field"),
        ("{\"id\":\"x\",\"trace\":\"yes\"}".into(), "bad-field"),
        ("{\"id\":\"x\",\"trace\":1}".into(), "bad-field"),
        ("{\"id\":\"x\",\"target\":\"\"}".into(), "bad-field"),
        ("{\"id\":\"x\",\"edges\":\"zigzag\"}".into(), "bad-edges"),
        ("{\"id\":\"x\",\"edges\":[[0]]}".into(), "bad-edges"),
        ("{\"id\":\"x\",\"edges\":[[0,1,2]]}".into(), "bad-edges"),
        ("{\"id\":\"x\",\"edges\":[[0,\"a\"]]}".into(), "bad-edges"),
    ];
    for (line, want) in cases {
        let e = parse_request(&line)
            .err()
            .unwrap_or_else(|| panic!("line {line:?} must be rejected"));
        assert_eq!(e.name, want, "line {line:?} rejected under the wrong name: {}", e.detail);
        assert_eq!(e.code, 2, "protocol rejections reuse the PR-6 usage code");
    }
}

#[test]
fn pattern_resolution_accepts_the_library_and_rejects_junk() {
    // the named catalogue, pinned by (vertices, edges)
    let catalogue = [
        ("triangle", 3, 3),
        ("wedge", 3, 2),
        ("diamond", 4, 5),
        ("tailed-triangle", 4, 4),
        ("4path", 4, 3),
        ("4star", 4, 3),
        ("4cycle", 4, 4),
        ("5cycle", 5, 5),
        ("4clique", 4, 6),
        ("5clique", 5, 10),
    ];
    for (name, nv, ne) in catalogue {
        let p = resolve_pattern(&PatternSpec::Named(name.into()))
            .unwrap_or_else(|e| panic!("{name} must resolve: {}", e.detail));
        assert_eq!((p.num_vertices(), p.num_edges()), (nv, ne), "{name}");
    }
    assert_eq!(
        resolve_pattern(&PatternSpec::Named("heptagram".into())).unwrap_err().name,
        "unknown-pattern"
    );

    // explicit edge lists: the cache key's canonical-code domain is
    // guarded at the door
    let bad_edges = [
        vec![],                                               // empty
        vec![(0, 0)],                                         // self-loop
        vec![(0, 1), (1, 0)],                                 // duplicate (undirected)
        vec![(0, 1), (2, 3)],                                 // disconnected
        vec![(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6), (6, 7), (7, 8)], // 9 vertices
    ];
    for edges in bad_edges {
        let e = resolve_pattern(&PatternSpec::Edges(edges.clone())).unwrap_err();
        assert_eq!(e.name, "bad-edges", "edges {edges:?}: {}", e.detail);
    }
    let tri = resolve_pattern(&PatternSpec::Edges(vec![(0, 1), (1, 2), (2, 0)])).unwrap();
    assert_eq!((tri.num_vertices(), tri.num_edges()), (3, 3));
}

#[test]
fn responses_render_golden_lines() {
    // success, with every structural field populated
    let ok = Response::ok("q1", Arc::new(count_result(7, None)), true, 0, Some(3));
    let line = ok.render();
    assert_eq!(
        line,
        "{\"id\":\"q1\",\"ok\":true,\"code\":0,\"cached\":true,\"epoch\":3,\
         \"result\":{\"count\":7,\"complete\":true,\"tripped\":null}}"
    );
    assert_eq!(response_code(&line), Some(0));

    // a tripped partial is still ok:true (an answer, just a lower
    // bound) — the nonzero code is what flags it
    let partial =
        Response::ok("q2", Arc::new(count_result(41, Some(CancelReason::Deadline))), false, 5, Some(0));
    let line = partial.render();
    assert_eq!(
        line,
        "{\"id\":\"q2\",\"ok\":true,\"code\":5,\"cached\":false,\"epoch\":0,\
         \"result\":{\"count\":41,\"complete\":false,\"tripped\":\"deadline\"}}"
    );
    assert_eq!(response_code(&line), Some(5));

    // a traced response carries the profile strictly after `result`,
    // so the untraced wire shapes above stay byte-identical to PR 7
    let traced = Response::ok_with_profile(
        "q9",
        Arc::new(count_result(7, None)),
        false,
        0,
        Some(1),
        "{\"levels\":[]}".to_string(),
    );
    let line = traced.render();
    assert_eq!(
        line,
        "{\"id\":\"q9\",\"ok\":true,\"code\":0,\"cached\":false,\"epoch\":1,\
         \"result\":{\"count\":7,\"complete\":true,\"tripped\":null},\
         \"profile\":{\"levels\":[]}}"
    );
    assert_eq!(response_code(&line), Some(0));

    // named errors
    let err = Response::error("z", sandslash::service::ProtoError::usage("unknown-op", "boom"));
    let line = err.render();
    assert_eq!(line, "{\"id\":\"z\",\"ok\":false,\"code\":2,\"error\":\"unknown-op\",\"detail\":\"boom\"}");
    assert_eq!(response_code(&line), Some(2));

    // non-responses yield no code at all
    assert_eq!(response_code("gibberish"), None);
    assert_eq!(response_code("{\"id\":\"x\"}"), None);
}

/// The wire vocabulary and the PR-6 exit-code table are the same table.
#[test]
fn code_and_name_tables_match_pr6() {
    assert_eq!(
        [
            CancelReason::WorkerPanic.exit_code(),
            CancelReason::Deadline.exit_code(),
            CancelReason::TaskBudget.exit_code(),
            CancelReason::Caller.exit_code(),
        ],
        [4, 5, 6, 7]
    );
    assert_eq!(trip_name(CancelReason::Deadline), "deadline");
    assert_eq!(trip_name(CancelReason::TaskBudget), "task-budget");
    assert_eq!(trip_name(CancelReason::Caller), "caller");
    assert_eq!(trip_name(CancelReason::WorkerPanic), "worker-panic");

    let panic = MineError::WorkerPanicked { engine: "dfs", payload: "boom".into() };
    assert_eq!(mine_error_code(&panic), 4);
    assert_eq!(mine_error_name(&panic), "worker-panic");
    let cap: MineError =
        BfsCapExceeded { level: 3, embeddings: 9, bytes: 10, cap: 5 }.into();
    assert_eq!(mine_error_code(&cap), 3);
    assert_eq!(mine_error_name(&cap), "bfs-cap");

    // the one service-only code extends the table without colliding
    assert_eq!(CODE_OVERLOADED, 8);

    // tripped fragments are renderable for every reason
    for reason in [CancelReason::Deadline, CancelReason::TaskBudget, CancelReason::Caller] {
        let frag = count_result(11, Some(reason));
        assert!(frag.contains("\"complete\":false"));
        assert!(frag.contains(&format!("\"tripped\":\"{}\"", trip_name(reason))));
    }
}

fn test_service() -> Arc<Service> {
    let svc = Service::new(ServiceConfig {
        max_inflight: 4,
        max_queued: 8,
        cache_bytes: 1 << 20,
        default_threads: 2,
        default_budget: Budget::default(),
    })
    .expect("governed test environment");
    svc.preload("er-small").expect("test dataset resident");
    Arc::new(svc)
}

fn ok_parts(resp: &Response) -> (Arc<String>, i32) {
    match &resp.body {
        Body::Ok { result, code, .. } => (result.clone(), *code),
        Body::Err(e) => panic!("query {} failed: {} ({})", resp.id, e.name, e.detail),
    }
}

/// Live end-to-end parity: a resident query tripped by each governance
/// knob answers with exactly the PR-6 code for that knob.
#[test]
fn governed_trips_surface_their_pr6_codes_on_the_wire() {
    if !budget::governance_enabled() {
        return;
    }
    let _guard = serial();
    let svc = test_service();

    // deadline = 5: an already-expired deadline trips at the first poll
    let mut req = Request::query("d", "er-small", PatternSpec::Named("triangle".into()));
    req.deadline_ms = Some(0);
    req.no_cache = true;
    let (frag, code) = ok_parts(&svc.handle(&req));
    assert_eq!(code, CancelReason::Deadline.exit_code());
    assert_eq!(*frag, count_result(0, Some(CancelReason::Deadline)));

    // task-budget = 6: one task against a multi-block root space
    // (er-small spans several claim blocks at the default grain) must
    // trip
    let mut req = Request::query("t", "er-small", PatternSpec::Named("triangle".into()));
    req.max_tasks = Some(1);
    req.no_cache = true;
    let (frag, code) = ok_parts(&svc.handle(&req));
    assert_eq!(code, CancelReason::TaskBudget.exit_code());
    assert!(frag.contains("\"tripped\":\"task-budget\""));

    // caller = 7: slow the victim with an injected delay at its first
    // root claim (threads=1 so no second worker can drain the roots
    // while it sleeps), then land a cancel op mid-run
    fault::install(FaultPlan {
        action: FaultAction::Delay(Duration::from_millis(400)),
        at_task: 0,
        stage: Some(Stage::RootClaim),
    });
    let victim = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || {
            let mut req = Request::query("victim", "er-small", PatternSpec::Named("wedge".into()));
            req.threads = Some(1);
            req.no_cache = true;
            svc.handle(&req)
        })
    };
    let mut cancel = Request::bare("c", Op::Cancel);
    cancel.target = Some("victim".into());
    let mut landed = false;
    for _ in 0..200 {
        let (frag, code) = ok_parts(&svc.handle(&cancel));
        assert_eq!(code, 0, "cancel is an op, not a query; it has no trip code of its own");
        if frag.contains("\"cancelled\":true") {
            landed = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let resp = victim.join().unwrap();
    fault::clear();
    assert!(landed, "the cancel op must find the delayed victim in flight");
    let (frag, code) = ok_parts(&resp);
    assert_eq!(code, CancelReason::Caller.exit_code());
    assert!(frag.contains("\"complete\":false"));
    assert!(frag.contains("\"tripped\":\"caller\""));

    // cancelling a finished id is idempotent, not an error
    let (frag, code) = ok_parts(&svc.handle(&cancel));
    assert_eq!(code, 0);
    assert!(frag.contains("\"cancelled\":false"));
}

/// `handle_line` is the wire loop's whole contract: parse errors come
/// back as renderable lines with id `"?"`, good lines dispatch.
#[test]
fn handle_line_round_trips_the_wire_shapes() {
    if !budget::governance_enabled() {
        return;
    }
    let _guard = serial();
    let svc = test_service();

    let pong = svc.handle_line("{\"id\":\"p\",\"op\":\"ping\"}");
    assert_eq!(pong, "{\"id\":\"p\",\"ok\":true,\"code\":0,\"cached\":false,\"result\":{\"pong\":true}}");
    assert_eq!(response_code(&pong), Some(0));

    let rejected = svc.handle_line("][");
    assert!(rejected.starts_with("{\"id\":\"?\",\"ok\":false,\"code\":2,\"error\":\"malformed-json\""));
    assert_eq!(response_code(&rejected), Some(2));

    let unknown = svc.handle_line("{\"id\":\"u\",\"graph\":\"atlantis\",\"pattern\":\"triangle\"}");
    assert!(unknown.contains("\"error\":\"unknown-graph\""));
    assert_eq!(response_code(&unknown), Some(1));

    let answered = svc.handle_line("{\"id\":\"q\",\"graph\":\"er-small\",\"pattern\":\"triangle\"}");
    assert!(answered.contains("\"ok\":true"));
    assert!(answered.contains("\"complete\":true"));
    assert_eq!(response_code(&answered), Some(0));

    // the stats op reflects the traffic this test just generated
    let stats = svc.handle_line("{\"id\":\"s\",\"op\":\"stats\"}");
    assert!(stats.contains("\"queries\":1"), "one engine query ran: {stats}");
    assert!(stats.contains("\"entries\":1"), "its fill is resident: {stats}");
}

/// PR 9: the `stats` op carries every counter family of the unified
/// registry — dispatch, sched, gov, and the service counters — plus
/// the embedded Prometheus text exposition.
#[test]
fn stats_op_exposes_every_counter_family_and_the_exposition() {
    if !budget::governance_enabled() {
        return;
    }
    let _guard = serial();
    let svc = test_service();
    let answered = svc.handle_line("{\"id\":\"q\",\"graph\":\"er-small\",\"pattern\":\"triangle\"}");
    assert!(answered.contains("\"ok\":true"), "{answered}");

    let stats = svc.handle_line("{\"id\":\"s\",\"op\":\"stats\"}");
    for section in [
        "\"dispatch\":{\"merge\":",
        "\"sched\":{\"claims\":",
        "\"gov\":{\"deadline_trips\":",
        "\"service\":{\"responses\":[",
        "\"admission_sheds\":",
        "\"idle_timeout_closes\":",
        "\"epoch_bumps\":",
        "\"exposition\":\"",
    ] {
        assert!(stats.contains(section), "stats missing {section}: {stats}");
    }

    // the exposition rides the wire escaped; parsed back out it is the
    // Prometheus text format with every metric family present
    let v = json::parse(&stats).expect("stats response parses");
    let expo = v
        .get("result")
        .and_then(|r| r.get("exposition"))
        .and_then(|e| e.as_str())
        .expect("exposition string in the stats result")
        .to_string();
    for metric in [
        "sandslash_dispatch_calls_total",
        "sandslash_sched_events_total",
        "sandslash_gov_trips_total",
        "sandslash_gov_panics_caught_total",
        "sandslash_gov_faults_injected_total",
        "sandslash_service_responses_total",
        "sandslash_admission_sheds_total",
        "sandslash_service_idle_timeout_closes_total",
        "sandslash_registry_epoch_bumps_total",
        "sandslash_service_queries_total",
        "sandslash_admission_inflight",
        "sandslash_cache_events_total",
        "sandslash_cache_bytes",
        "sandslash_cache_entries",
    ] {
        assert!(expo.contains(metric), "exposition missing {metric}:\n{expo}");
    }
    for line in expo.lines() {
        assert!(
            line.starts_with('#') || line.starts_with("sandslash_") || line.is_empty(),
            "non-exposition line {line:?}"
        );
    }
}

/// PR 9: `"trace":true` attaches a per-query profile object to the
/// response; untraced responses never carry the key.
#[test]
fn traced_queries_attach_a_profile_and_untraced_ones_do_not() {
    if !budget::governance_enabled() {
        return;
    }
    let _guard = serial();
    let svc = test_service();

    let plain = svc.handle_line(
        "{\"id\":\"u\",\"graph\":\"er-small\",\"pattern\":\"triangle\",\"no_cache\":true}",
    );
    assert!(plain.contains("\"ok\":true"), "{plain}");
    assert!(!plain.contains("\"profile\":"), "untraced response grew a profile: {plain}");

    let traced = svc.handle_line(
        "{\"id\":\"t\",\"graph\":\"er-small\",\"pattern\":\"triangle\",\
         \"no_cache\":true,\"trace\":true}",
    );
    assert!(traced.contains("\"ok\":true"), "{traced}");
    assert!(traced.contains("\"profile\":{"), "{traced}");
    let v = json::parse(&traced).expect("traced response parses");
    let profile = v.get("profile").expect("profile object");
    // no_cache forces the bypass verdict, and admission was timed
    assert_eq!(profile.get("cache").and_then(|c| c.as_str()), Some("bypass"));
    assert_eq!(
        profile
            .get("admission")
            .and_then(|a| a.get("verdict"))
            .and_then(|s| s.as_str()),
        Some("admitted")
    );
    // the engine really ran under the trace: kernel dispatches landed
    let dispatch = profile.get("dispatch").expect("dispatch section");
    assert!(dispatch.get("merge").and_then(|n| n.as_u64()).is_some(), "{traced}");

    // a cache hit is traced too, with the hit verdict and no engine work
    let hit = svc.handle_line(
        "{\"id\":\"h1\",\"graph\":\"er-small\",\"pattern\":\"triangle\"}",
    );
    assert!(hit.contains("\"ok\":true"), "{hit}");
    let hit2 = svc.handle_line(
        "{\"id\":\"h2\",\"graph\":\"er-small\",\"pattern\":\"triangle\",\"trace\":true}",
    );
    let v = json::parse(&hit2).expect("traced hit parses");
    assert_eq!(v.get("cached").and_then(|c| c.as_bool()), Some(true), "{hit2}");
    let profile = v.get("profile").expect("profile object on the hit");
    assert_eq!(profile.get("cache").and_then(|c| c.as_str()), Some("hit"));
}
