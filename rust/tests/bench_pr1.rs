//! Tier-1 smoke benchmark for the PR-1 set-centric extension work, the
//! PR-3 SIMD kernel dispatch, and the PR-4 scheduler swap: every
//! `cargo test` run (a) differentially checks the scalar and
//! set-centric paths on RMAT(2^14) inputs at full scale, (b) re-runs
//! the set-centric configuration with the vectorized kernels
//! force-disabled and re-enabled — asserting via the dispatch counters
//! that the SIMD merge is actually *selected* on the TC and k-CL
//! workloads when the host supports it — (c) re-runs the same
//! workloads on the global-cursor oracle and the work-stealing
//! scheduler, asserting equal counts everywhere and (on a skewed
//! two-hub input) that steals/splits actually fire, (d) runs the ESU
//! k-MC and FSM workloads on their seed scalar extension oracles and
//! on the shared extension core (`pr5-*` sections, counts asserted
//! equal), (e) re-runs the TC workload untraced and under a per-query
//! trace (`pr9-obs`, counts asserted bit-identical), (f) runs the
//! 4-motif census and a 5-clique count on the enumerated oracle and
//! through the PR-10 decomposition planner (`pr10-plan`, counts
//! asserted bit-identical and — planner live — the census enumeration
//! space asserted strictly smaller), and (g) rewrites `BENCH_pr1.json`
//! at the repo root with single-shot wall times, then asserts the
//! artifact no longer carries any `"pending"` placeholder and holds
//! every section this run wrote. The `table5_tc` / `table6_kcl`
//! benches overwrite the same sections with properly sampled release
//! numbers — this test just keeps the artifact alive and honest on
//! every tier-1 run.

use sandslash::apps::motif;
use sandslash::engine::esu::{count_motifs, MotifTable};
use sandslash::engine::fsm::mine_fsm;
use sandslash::engine::hooks::NoHooks;
use sandslash::engine::{dfs, MinerConfig, OptFlags};
use sandslash::graph::{gen, setops};
use sandslash::graph::CsrGraph;
use sandslash::pattern::{decompose, library, plan, Pattern};
use sandslash::util::bench::{
    pr1_report_path, pr10_compare, pr3_compare, pr4_compare, pr5_compare, pr6_compare,
    pr7_compare, pr9_compare, Pr1Section,
};
use sandslash::util::timer::timed;

fn measure_and_write(
    g: &CsrGraph,
    p: &Pattern,
    graph_desc: &str,
    pname: &str,
    section: &str,
) -> f64 {
    let pl = plan(p, true, true);
    let set_cfg = MinerConfig::new(OptFlags::hi());
    let mut scalar_cfg = set_cfg;
    scalar_cfg.opts.sets = false;
    // first runs double as warmup and as the differential check
    // (budgets unset here, so governed runs always complete — unwrap)
    let (set_count, _) = dfs::count(g, &pl, &set_cfg, &NoHooks).unwrap().into_parts();
    let (scalar_count, _) = dfs::count(g, &pl, &scalar_cfg, &NoHooks).unwrap().into_parts();
    assert_eq!(
        set_count, scalar_count,
        "scalar vs set-centric disagree on {graph_desc} / {pname}"
    );
    let (_, scalar_secs) = timed(|| dfs::count(g, &pl, &scalar_cfg, &NoHooks).unwrap().value);
    let (_, set_secs) = timed(|| dfs::count(g, &pl, &set_cfg, &NoHooks).unwrap().value);
    let s = Pr1Section {
        graph: graph_desc,
        pattern: pname,
        count: set_count,
        scalar_secs,
        set_secs,
        dag_secs: None,
        samples: 1,
    };
    if let Err(e) = s.write(section, set_cfg.threads) {
        eprintln!("skipping BENCH_pr1.json write: {e}");
    }
    s.speedup()
}

/// PR-3 rows (§PR-3) through the shared protocol (`bench::pr3_compare`):
/// the same set-centric run with the portable scalar kernels and with
/// runtime SIMD dispatch, from the same process; count equality and
/// SIMD-merge *selection* (dispatch-counter delta) asserted inside.
fn measure_pr3(
    g: &CsrGraph,
    p: &Pattern,
    graph_desc: &str,
    pname: &str,
    section: &str,
) -> f64 {
    let pl = plan(p, true, true);
    let cfg = MinerConfig::new(OptFlags::hi());
    let s = pr3_compare(
        graph_desc,
        pname,
        1,
        || {
            // warmup + count
            let (count, _) = dfs::count(g, &pl, &cfg, &NoHooks).unwrap().into_parts();
            let (_, secs) = timed(|| dfs::count(g, &pl, &cfg, &NoHooks).unwrap().value);
            (count, secs)
        },
        || dfs::count(g, &pl, &cfg, &NoHooks).unwrap().value,
    );
    if let Err(e) = s.write(section, cfg.threads) {
        eprintln!("skipping BENCH_pr1.json write: {e}");
    }
    s.speedup()
}

/// PR-4 rows (§PR-4) through the shared protocol (`bench::pr4_compare`):
/// the same set-centric run scheduled by the global-cursor oracle and
/// by the work-stealing pool; count equality asserted on both the timed
/// input and a skewed two-hub input, where the scheduler counters must
/// also show steals/splits actually firing (when this host can run
/// parallel at all).
fn measure_pr4(
    g: &CsrGraph,
    p: &Pattern,
    skew: &CsrGraph,
    graph_desc: &str,
    pname: &str,
    section: &str,
) -> f64 {
    let pl = plan(p, true, true);
    let cfg = MinerConfig::new(OptFlags::hi());
    // small grain so the skewed run has enough tasks to starve workers
    // into the split protocol
    let skew_cfg = MinerConfig::custom(cfg.threads.max(4), 1, OptFlags::hi());
    let s = pr4_compare(
        graph_desc,
        pname,
        1,
        cfg.threads,
        skew_cfg.threads,
        || {
            // warmup + count
            let (count, _) = dfs::count(g, &pl, &cfg, &NoHooks).unwrap().into_parts();
            let (_, secs) = timed(|| dfs::count(g, &pl, &cfg, &NoHooks).unwrap().value);
            (count, secs)
        },
        || dfs::count(skew, &pl, &skew_cfg, &NoHooks).unwrap().value,
    );
    if let Err(e) = s.write(section, cfg.threads) {
        eprintln!("skipping BENCH_pr1.json write: {e}");
    }
    s.speedup()
}

/// PR-5 rows (§PR-5) through the shared protocol (`bench::pr5_compare`):
/// the same ESU k-MC / FSM workload with the extension core off (seed
/// scalar oracles) and on, counts asserted equal inside the protocol.
fn measure_pr5() -> (f64, f64) {
    // k-MC on the pattern-oblivious ESU engine
    let g_mc = gen::rmat(9, 6, 42, &[]);
    let table = MotifTable::new(4);
    let kmc = pr5_compare("rmat scale=9 ef=6 seed=42", "4-motif-esu", 1, |use_core| {
        let cfg = MinerConfig::new(OptFlags::hi().with_extcore(use_core));
        // warmup + check
        let (counts, _) = count_motifs(&g_mc, 4, &cfg, &NoHooks, &table).unwrap().into_parts();
        let (_, secs) =
            timed(|| count_motifs(&g_mc, 4, &cfg, &NoHooks, &table).unwrap().value);
        (counts.iter().sum(), secs)
    });
    if let Err(e) = kmc.write("pr5-kmc", MinerConfig::new(OptFlags::hi()).threads) {
        eprintln!("skipping BENCH_pr1.json write: {e}");
    }
    // FSM on the sub-pattern-tree engine (labeled input)
    let g_fsm = gen::erdos_renyi(150, 0.06, 42, &[1, 2, 3]);
    let fsm = pr5_compare("er n=150 p=0.06 seed=42 labels=3", "fsm k<=3 sigma=2", 1, |use_core| {
        let cfg = MinerConfig::new(OptFlags::hi().with_extcore(use_core));
        let r = mine_fsm(&g_fsm, 3, 2, &cfg).unwrap().value; // warmup + check
        let fp = r.iter().fold(r.len() as u64, |h, f| {
            h.wrapping_mul(1_000_003).wrapping_add(f.support)
        });
        let (_, secs) = timed(|| mine_fsm(&g_fsm, 3, 2, &cfg).unwrap().value.len());
        (fp, secs)
    });
    if let Err(e) = fsm.write("pr5-fsm", MinerConfig::new(OptFlags::hi()).threads) {
        eprintln!("skipping BENCH_pr1.json write: {e}");
    }
    (kmc.speedup(), fsm.speedup())
}

/// PR-6 row (§PR-6) through the shared protocol (`bench::pr6_compare`):
/// the same governed TC workload with the governance layer scoped off
/// and back on, budgets unset — counts asserted bit-identical and the
/// trip counters asserted silent inside the protocol. The recorded
/// ratio is the whole cost of the admission poll sites (expected ≈ 1).
fn measure_pr6(g: &CsrGraph, graph_desc: &str) -> f64 {
    let pl = plan(&library::triangle(), true, true);
    let cfg = MinerConfig::new(OptFlags::hi());
    let s = pr6_compare(graph_desc, "triangle", 1, || {
        // warmup + count (budgets unset, so governed runs always complete)
        let (count, _) = dfs::count(g, &pl, &cfg, &NoHooks).unwrap().into_parts();
        let (_, secs) = timed(|| dfs::count(g, &pl, &cfg, &NoHooks).unwrap().value);
        (count, secs)
    });
    if let Err(e) = s.write("pr6-governance", cfg.threads) {
        eprintln!("skipping BENCH_pr1.json write: {e}");
    }
    s.overhead()
}

/// PR-7 row (§PR-7) through the shared protocol (`bench::pr7_compare`):
/// one triangle query against an in-process resident service, cold
/// (admission + governed run + cache fill; the graph is preloaded so
/// load time is not conflated into the query) and again cached (byte
/// replay), counts asserted equal across the cache inside the
/// protocol. Returns `None` under `SANDSLASH_NO_GOV` — the service
/// refuses to start ungoverned, so there is nothing to measure.
fn measure_pr7() -> Option<f64> {
    use sandslash::service::{json, Body, PatternSpec, Request, Service, ServiceConfig};
    if !sandslash::engine::budget::governance_enabled() {
        return None;
    }
    let threads = MinerConfig::new(OptFlags::hi()).threads;
    let service = Service::new(ServiceConfig {
        max_inflight: 2,
        max_queued: 4,
        cache_bytes: 1 << 20,
        default_threads: threads,
        default_budget: sandslash::engine::Budget::default(),
    })
    .unwrap();
    service.preload("er-small").unwrap();
    let mut runs = 0u32;
    let s = pr7_compare("er n=2000 p=0.005 seed=7 (er-small)", "triangle", 1, || {
        runs += 1;
        let req = Request::query(
            &format!("bench-{runs}"),
            "er-small",
            PatternSpec::Named("triangle".to_string()),
        );
        let (resp, secs) = timed(|| service.handle(&req));
        match &resp.body {
            Body::Ok { result, cached, code, .. } => {
                assert_eq!(*code, 0, "bench query must complete");
                let count = json::parse(result)
                    .ok()
                    .and_then(|v| v.get("count").and_then(|c| c.as_u64()))
                    .expect("count field in the result fragment");
                (count, secs, *cached)
            }
            Body::Err(e) => panic!("bench query failed: {e:?}"),
        }
    });
    if let Err(e) = s.write("pr7-service", threads) {
        eprintln!("skipping BENCH_pr1.json write: {e}");
    }
    Some(s.speedup())
}

/// PR-9 row (§PR-9) through the shared protocol (`bench::pr9_compare`):
/// the same TC workload untraced (the default pay-nothing path) and
/// under an installed per-query trace — counts asserted bit-identical
/// and the trace asserted non-empty inside the protocol. The recorded
/// ratio is the whole cost of a live trace (expected ≈ 1).
fn measure_pr9(g: &CsrGraph, graph_desc: &str) -> f64 {
    let pl = plan(&library::triangle(), true, true);
    let cfg = MinerConfig::new(OptFlags::hi());
    let s = pr9_compare(graph_desc, "triangle", 1, || {
        // warmup + count (tracing observes only, so runs always agree)
        let (count, _) = dfs::count(g, &pl, &cfg, &NoHooks).unwrap().into_parts();
        let (_, secs) = timed(|| dfs::count(g, &pl, &cfg, &NoHooks).unwrap().value);
        (count, secs)
    });
    if let Err(e) = s.write("pr9-obs", cfg.threads) {
        eprintln!("skipping BENCH_pr1.json write: {e}");
    }
    s.overhead()
}

/// PR-10 rows (§PR-10) through the shared protocol
/// (`bench::pr10_compare`): the 4-motif census and a 5-clique count on
/// the enumerated oracle (`plan = false`) and through the
/// decomposition planner, counts asserted bit-identical inside the
/// protocol. The census additionally asserts (planner live) that the
/// planner's engine-stats `enumerated` counter is strictly smaller
/// than the ESU oracle's — the ISSUE-10 acceptance criterion; the
/// 5-clique is its own optimal anchor, so its planner route is the
/// direct one and its ratio is recorded as ≈ 1.
fn measure_pr10(g: &CsrGraph, graph_desc: &str) -> (f64, f64) {
    let threads = MinerConfig::new(OptFlags::hi()).threads;
    let fingerprint = |counts: &[u64]| {
        counts.iter().fold(counts.len() as u64, |h, c| {
            h.wrapping_mul(1_000_003).wrapping_add(*c)
        })
    };
    let census = pr10_compare(
        graph_desc,
        "4-motif-census",
        1,
        decompose::plan_enabled_default(),
        |use_planner| {
            let cfg = MinerConfig::new(OptFlags::hi().with_plan(use_planner).with_stats());
            // warmup + stats capture (budgets unset — always complete)
            let out = motif::motif4(g, &cfg).unwrap();
            let (_, secs) = timed(|| motif::motif4(g, &cfg).unwrap().value);
            (fingerprint(&out.value), secs, out.stats.enumerated)
        },
    );
    if let Err(e) = census.write("pr10-plan", threads) {
        eprintln!("skipping BENCH_pr1.json write: {e}");
    }
    let p5 = library::clique(5);
    let clique5 = pr10_compare(graph_desc, "5-clique", 1, false, |use_planner| {
        let cfg = MinerConfig::new(OptFlags::hi().with_plan(use_planner).with_stats());
        let out = decompose::count_with_plan(g, &p5, true, &cfg).unwrap();
        let (_, secs) = timed(|| decompose::count_with_plan(g, &p5, true, &cfg).unwrap().value);
        (out.value, secs, out.stats.enumerated)
    });
    if let Err(e) = clique5.write("pr10-clique5", threads) {
        eprintln!("skipping BENCH_pr1.json write: {e}");
    }
    (census.speedup(), clique5.speedup())
}

#[test]
fn bench_pr1_smoke_regenerates_report() {
    let g_tc = gen::rmat(14, 8, 42, &[]);
    let tc_speedup = measure_and_write(
        &g_tc,
        &library::triangle(),
        "rmat scale=14 ef=8 seed=42",
        "triangle",
        "tc",
    );
    let g_cl = gen::rmat(14, 4, 42, &[]);
    let cl_speedup = measure_and_write(
        &g_cl,
        &library::clique(4),
        "rmat scale=14 ef=4 seed=42",
        "4-clique",
        "kcl4",
    );
    // PR-3: scalar vs SIMD kernel dispatch on the same two workloads
    let tc_simd = measure_pr3(
        &g_tc,
        &library::triangle(),
        "rmat scale=14 ef=8 seed=42",
        "triangle",
        "pr3-tc",
    );
    let cl_simd = measure_pr3(
        &g_cl,
        &library::clique(4),
        "rmat scale=14 ef=4 seed=42",
        "4-clique",
        "pr3-kcl4",
    );
    // PR-4: cursor vs work-stealing scheduler on the same two
    // workloads; the skewed two-hub input inside the shared protocol
    // asserts steals/splits actually fire
    let skew = gen::two_hub(1 << 13);
    let tc_sched = measure_pr4(
        &g_tc,
        &library::triangle(),
        &skew,
        "rmat scale=14 ef=8 seed=42",
        "triangle",
        "pr4-sched-tc",
    );
    let cl_sched = measure_pr4(
        &g_cl,
        &library::clique(4),
        &skew,
        "rmat scale=14 ef=4 seed=42",
        "4-clique",
        "pr4-sched-kcl4",
    );
    // PR-5: scalar extension oracles vs the shared extension core on
    // the ESU and FSM engines
    let (kmc_core, fsm_core) = measure_pr5();
    // PR-6: governance on vs scoped off, budgets unset (poll-site cost)
    let gov_overhead = measure_pr6(&g_tc, "rmat scale=14 ef=8 seed=42");
    // PR-7: the resident service's cold vs cached query latency
    let service_speedup = measure_pr7();
    let service_note = match service_speedup {
        Some(x) => format!("cold over cached — tc {x:.2}x"),
        None => "service skipped (ungoverned)".to_string(),
    };
    // PR-9: untraced vs traced run of the same workload (hook cost)
    let trace_overhead = measure_pr9(&g_tc, "rmat scale=14 ef=8 seed=42");
    // PR-10: enumerated counting oracle vs the decomposition planner
    let (plan_speedup, clique5_speedup) = measure_pr10(&g_cl, "rmat scale=14 ef=4 seed=42");
    // Satellite (g): the artifact this run just rewrote must no longer
    // carry the seed's `"pending"` placeholder anywhere, and every
    // section written above must actually be present. Skipped only if
    // the artifact is unreadable (the per-section writes already
    // degraded to eprintln in that case).
    if let Ok(report) = std::fs::read_to_string(pr1_report_path()) {
        assert!(
            !report.contains("pending"),
            "BENCH_pr1.json still carries a pending placeholder after the smoke run"
        );
        let mut expected = vec![
            "\"tc\"",
            "\"kcl4\"",
            "\"pr3-tc\"",
            "\"pr3-kcl4\"",
            "\"pr4-sched-tc\"",
            "\"pr4-sched-kcl4\"",
            "\"pr5-kmc\"",
            "\"pr5-fsm\"",
            "\"pr6-governance\"",
            "\"pr9-obs\"",
            "\"pr10-plan\"",
            "\"pr10-clique5\"",
        ];
        if service_speedup.is_some() {
            expected.push("\"pr7-service\"");
        }
        for section in expected {
            assert!(
                report.contains(section),
                "BENCH_pr1.json is missing the {section} section this run wrote"
            );
        }
    }
    eprintln!(
        "BENCH_pr1 smoke: set-centric speedup over scalar — tc {tc_speedup:.2}x, \
         4-clique {cl_speedup:.2}x; {} kernels over scalar kernels — tc {tc_simd:.2}x, \
         4-clique {cl_simd:.2}x; stealing over cursor — tc {tc_sched:.2}x, \
         4-clique {cl_sched:.2}x; extension core over scalar oracles — \
         4-MC {kmc_core:.2}x, FSM {fsm_core:.2}x; governance-on over off — \
         tc {gov_overhead:.2}x; resident service {service_note}; traced over \
         untraced — tc {trace_overhead:.2}x; planner over enumeration — \
         4-motif census {plan_speedup:.2}x, 5-clique {clique5_speedup:.2}x ({})",
        setops::simd_level_name(),
        pr1_report_path().display()
    );
}
