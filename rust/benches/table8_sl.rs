//! Regenerates paper Table 8: SL (diamond, 4-cycle) for Pangolin-like,
//! Peregrine-like (both without MNC) and Sandslash-Hi.
use sandslash::coordinator::campaign;

fn main() {
    let rows = campaign::table8(&["lj-tiny", "or-tiny", "fr-tiny"]);
    println!("{}", campaign::to_markdown(&rows));
    println!("\nExpected shape (paper): MNC gives Sandslash the edge; the");
    println!("no-MNC emulations pay a has_edge probe per (candidate, position).");
}
