//! Regenerates paper Fig. 11: k-CL on the Friendster-like graph for
//! k = 4..8 across all systems (log-scale time in the paper).
use sandslash::coordinator::campaign;

fn main() {
    let rows = campaign::fig11("fr-tiny", 4..=8);
    println!("{}", campaign::to_markdown(&rows));
    println!("\nExpected shape (paper): emulations blow up with k; Sandslash-Lo");
    println!("tracks (and beats) kClist throughout.");
}
