//! Regenerates paper Table 5: TC execution time across systems
//! (Pangolin-, AutoMine-, Peregrine-like emulations, GAP, Sandslash-Hi)
//! on the five unlabeled mini datasets.
use sandslash::coordinator::campaign;

fn main() {
    let graphs = sandslash::coordinator::datasets::unlabeled_names();
    let rows = campaign::table5(graphs);
    println!("{}", campaign::to_markdown(&rows));
    println!("\nExpected shape (paper): DAG-based systems (Pangolin-like, GAP,");
    println!("Sandslash-Hi) cluster together; Peregrine-like (no DAG) and");
    println!("AutoMine-like (no SB, 6x space) trail.");
}
