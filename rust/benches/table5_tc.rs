//! Regenerates paper Table 5: TC execution time across systems
//! (Pangolin-, AutoMine-, Peregrine-like emulations, GAP, Sandslash-Hi)
//! on the five unlabeled mini datasets — then runs the PR-1 measurement:
//! scalar (probe/MNC) vs set-centric extension for triangle counting on
//! RMAT(2^14), recording the `tc` section of `BENCH_pr1.json` at the
//! repo root.
use sandslash::coordinator::campaign;
use sandslash::engine::hooks::NoHooks;
use sandslash::engine::{dfs, MinerConfig, OptFlags};
use sandslash::graph::gen;
use sandslash::pattern::{library, plan};
use sandslash::util::bench::{pr1_report_path, pr3_compare, pr4_compare, print_table, Bench, Pr1Section};

fn main() {
    let graphs = sandslash::coordinator::datasets::unlabeled_names();
    let rows = campaign::table5(graphs);
    println!("{}", campaign::to_markdown(&rows));
    println!("\nExpected shape (paper): DAG-based systems (Pangolin-like, GAP,");
    println!("Sandslash-Hi) cluster together; Peregrine-like (no DAG) and");
    println!("AutoMine-like (no SB, 6x space) trail.");

    // ---- PR-1: scalar vs set-centric extension, TC on RMAT(2^14) ----
    let g = gen::rmat(14, 8, 42, &[]);
    let pl = plan(&library::triangle(), true, true);
    let set_cfg = MinerConfig::new(OptFlags::hi());
    let mut scalar_cfg = set_cfg;
    scalar_cfg.opts.sets = false;
    let (set_count, _) = dfs::count(&g, &pl, &set_cfg, &NoHooks).unwrap().into_parts();
    let (scalar_count, _) = dfs::count(&g, &pl, &scalar_cfg, &NoHooks).unwrap().into_parts();
    assert_eq!(set_count, scalar_count, "scalar/set-centric differential failed");

    let bench = Bench::quick();
    let r_scalar = bench.run("tc-scalar", || dfs::count(&g, &pl, &scalar_cfg, &NoHooks).unwrap().value);
    let r_set = bench.run("tc-set", || dfs::count(&g, &pl, &set_cfg, &NoHooks).unwrap().value);
    let r_dag = bench.run("tc-dag", || sandslash::apps::tc::tc_hi(&g, &set_cfg));
    let fmt = |r: &sandslash::util::bench::BenchResult| {
        vec![
            format!("{:.4}", r.min()),
            format!("{:.4}", r.median()),
            format!("{:.4}", r.mean()),
        ]
    };
    print_table(
        "PR-1 TC: scalar vs set-centric (rmat scale=14 ef=8 seed=42)",
        &["min s", "median s", "mean s"],
        &[
            ("scalar (probe+MNC)".to_string(), fmt(&r_scalar)),
            ("set-centric".to_string(), fmt(&r_set)),
            ("dag+intersect (tc_hi)".to_string(), fmt(&r_dag)),
        ],
    );
    let section = Pr1Section {
        graph: "rmat scale=14 ef=8 seed=42",
        pattern: "triangle",
        count: set_count,
        scalar_secs: r_scalar.min(),
        set_secs: r_set.min(),
        dag_secs: Some(r_dag.min()),
        samples: r_set.samples.len(),
    };
    println!(
        "\ntriangles = {set_count}; set-centric speedup over scalar = {:.2}x",
        section.speedup()
    );
    if let Err(e) = section.write("tc", set_cfg.threads) {
        eprintln!("could not write BENCH_pr1.json: {e}");
    } else {
        println!("wrote `tc` section of {}", pr1_report_path().display());
    }

    // ---- PR-3: scalar vs SIMD kernel dispatch, same input, same run
    // (shared protocol: count equality + SIMD-merge selection asserted
    // inside bench::pr3_compare) ----
    let mut nsamples = 0usize;
    let mut pr3 = pr3_compare(
        "rmat scale=14 ef=8 seed=42",
        "triangle",
        1,
        || {
            let (count, _) = dfs::count(&g, &pl, &set_cfg, &NoHooks).unwrap().into_parts();
            let r = bench.run("tc-set-kernels", || dfs::count(&g, &pl, &set_cfg, &NoHooks).unwrap().value);
            nsamples = r.samples.len();
            (count, r.min())
        },
        || dfs::count(&g, &pl, &set_cfg, &NoHooks).unwrap().value,
    );
    pr3.samples = nsamples;
    print_table(
        "PR-3 TC kernels: scalar vs SIMD dispatch (rmat scale=14 ef=8 seed=42)",
        &["min s"],
        &[
            ("scalar kernels (forced)".to_string(), vec![format!("{:.4}", pr3.scalar_secs)]),
            (
                format!("simd kernels ({})", pr3.simd),
                vec![format!("{:.4}", pr3.simd_secs)],
            ),
        ],
    );
    println!("\nkernel speedup ({} over scalar) = {:.2}x", pr3.simd, pr3.speedup());
    if let Err(e) = pr3.write("pr3-tc", set_cfg.threads) {
        eprintln!("could not write BENCH_pr1.json: {e}");
    } else {
        println!("wrote `pr3-tc` section of {}", pr1_report_path().display());
    }

    // ---- PR-4: global-cursor oracle vs work-stealing scheduler, same
    // input, same run (shared protocol: count equality on the timed and
    // the skewed two-hub inputs, plus steal/split counter movement,
    // asserted inside bench::pr4_compare) ----
    let skew = gen::two_hub(1 << 13);
    let skew_cfg = MinerConfig::custom(set_cfg.threads.max(4), 1, OptFlags::hi());
    let mut nsamples4 = 0usize;
    let mut pr4 = pr4_compare(
        "rmat scale=14 ef=8 seed=42",
        "triangle",
        1,
        set_cfg.threads,
        skew_cfg.threads,
        || {
            let (count, _) = dfs::count(&g, &pl, &set_cfg, &NoHooks).unwrap().into_parts();
            let r = bench.run("tc-sched", || dfs::count(&g, &pl, &set_cfg, &NoHooks).unwrap().value);
            nsamples4 = r.samples.len();
            (count, r.min())
        },
        || dfs::count(&skew, &pl, &skew_cfg, &NoHooks).unwrap().value,
    );
    pr4.samples = nsamples4;
    print_table(
        "PR-4 TC scheduler: cursor vs stealing (rmat scale=14 ef=8 seed=42)",
        &["min s"],
        &[
            ("global cursor (oracle)".to_string(), vec![format!("{:.4}", pr4.cursor_secs)]),
            (
                format!("stealing ({} shard(s))", pr4.shards),
                vec![format!("{:.4}", pr4.steal_secs)],
            ),
        ],
    );
    println!(
        "\nscheduler speedup (stealing over cursor) = {:.2}x; skewed input moved \
         {} steal(s) + {} split(s)",
        pr4.speedup(),
        pr4.skew_steals,
        pr4.skew_splits
    );
    if let Err(e) = pr4.write("pr4-sched-tc", set_cfg.threads) {
        eprintln!("could not write BENCH_pr1.json: {e}");
    } else {
        println!("wrote `pr4-sched-tc` section of {}", pr1_report_path().display());
    }
}
