//! Regenerates paper Table 7: k-MC (k = 3, 4) across systems + PGD +
//! Sandslash-Lo (formula-based local counting).
use sandslash::coordinator::campaign;

fn main() {
    let rows = campaign::table7(&["lj-tiny", "or-tiny"], &[3, 4]);
    println!("{}", campaign::to_markdown(&rows));
    println!("\nExpected shape (paper): LC makes Sandslash-Lo orders of magnitude");
    println!("faster than Sandslash-Hi on 4-MC; PGD close behind (no SB);");
    println!("BFS (Pangolin-like) worst on 4-MC.");
}
