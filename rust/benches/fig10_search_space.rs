//! Regenerates paper Fig. 10: number of enumerated embeddings,
//! Sandslash-Hi vs Sandslash-Lo, for 5-CL and 4-MC.
use sandslash::coordinator::campaign;

fn main() {
    let rows = campaign::fig10(&["or-tiny", "fr-tiny"]);
    println!("{}", campaign::to_markdown(&rows));
    println!("\nExpected shape (paper): Lo's LG/LC prune the enumeration space by");
    println!("orders of magnitude (the 'result' column holds the counter).");
}
