//! Regenerates paper Table 9: k-FSM across support thresholds for the
//! BFS engine (Pangolin-like), pattern-at-a-time (Peregrine-like),
//! single-queue DFS (DistGraph-like) and Sandslash DFS.
use sandslash::coordinator::campaign;

fn main() {
    let rows = campaign::table9(&["pa-tiny", "yo-tiny", "pdb-tiny"], 3, &[2, 4, 10]);
    println!("{}", campaign::to_markdown(&rows));
    println!("\nExpected shape (paper): Sandslash DFS wins when many patterns are");
    println!("frequent (low sigma); pattern-at-a-time pays per-pattern rescans.");
}
