//! Regenerates paper Fig. 9: speedup from local-graph search (LG) —
//! k-CL (k = 4..8, hand-tuned kClist path) plus the PR-2 generalized
//! LG stage on non-clique patterns (diamond, tailed-triangle, 4-cycle)
//! through the generic DFS engine — on the Orkut- and Friendster-like
//! minis. Every row pair asserts hi/lo count equality, so the bench
//! doubles as a differential check. The PR-3 block then re-runs the
//! LG-heavy configurations with the vectorized kernels force-disabled
//! vs re-enabled, so the figure also records what the SIMD dispatch is
//! worth on this stage (its dense mode rides the mask kernels).
use sandslash::coordinator::{campaign, datasets};
use sandslash::engine::hooks::NoHooks;
use sandslash::engine::{dfs, MinerConfig, OptFlags};
use sandslash::pattern::{library, plan};
use sandslash::util::bench::{pr3_compare, print_table, Bench};

fn main() {
    let rows = campaign::fig9(&["or-tiny", "fr-tiny"], 8);
    println!("{}", campaign::to_markdown(&rows));
    println!("\nExpected shape (paper): k-CL speedup 1.2-3.5x, growing with k on");
    println!("the denser graph, peaking then flattening on the sparser one.");
    println!("Non-clique patterns gain less (fewer cone levels to shrink at) but");
    println!("must never lose past the crossover; heuristic in EXPERIMENTS.md.");

    // ---- PR-3: scalar vs SIMD kernels through the LG stage, via the
    // shared protocol (count equality + SIMD-merge selection asserted
    // inside bench::pr3_compare) ----
    let g = datasets::load("or-tiny").expect("dataset");
    let bench = Bench::quick();
    let mut table = Vec::new();
    for (pname, p) in [
        ("diamond", library::diamond()),
        ("5-clique", library::clique(5)),
    ] {
        let pl = plan(&p, true, true);
        let cfg = MinerConfig::new(OptFlags::lo());
        let pr3 = pr3_compare(
            "or-tiny",
            pname,
            1,
            || {
                let (count, _) = dfs::count(&g, &pl, &cfg, &NoHooks).unwrap().into_parts();
                let r = bench.run("lg-kernels", || dfs::count(&g, &pl, &cfg, &NoHooks).unwrap().value);
                (count, r.min())
            },
            || dfs::count(&g, &pl, &cfg, &NoHooks).unwrap().value,
        );
        table.push((
            format!("{pname} scalar kernels"),
            vec![format!("{:.4}", pr3.scalar_secs)],
        ));
        table.push((
            format!("{pname} simd kernels ({})", pr3.simd),
            vec![format!("{:.4}", pr3.simd_secs)],
        ));
    }
    print_table(
        "PR-3 LG stage (OptFlags::lo, or-tiny): scalar vs SIMD kernel dispatch",
        &["min s"],
        &table,
    );
}
