//! Regenerates paper Fig. 9: k-CL speedup from local-graph search (LG),
//! k = 4..8, on the Orkut- and Friendster-like minis.
use sandslash::coordinator::campaign;

fn main() {
    let rows = campaign::fig9(&["or-tiny", "fr-tiny"], 8);
    println!("{}", campaign::to_markdown(&rows));
    println!("\nExpected shape (paper): speedup 1.2-3.5x, growing with k on the");
    println!("denser graph, peaking then flattening on the sparser one.");
}
