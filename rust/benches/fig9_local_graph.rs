//! Regenerates paper Fig. 9: speedup from local-graph search (LG) —
//! k-CL (k = 4..8, hand-tuned kClist path) plus the PR-2 generalized
//! LG stage on non-clique patterns (diamond, tailed-triangle, 4-cycle)
//! through the generic DFS engine — on the Orkut- and Friendster-like
//! minis. Every row pair asserts hi/lo count equality, so the bench
//! doubles as a differential check.
use sandslash::coordinator::campaign;

fn main() {
    let rows = campaign::fig9(&["or-tiny", "fr-tiny"], 8);
    println!("{}", campaign::to_markdown(&rows));
    println!("\nExpected shape (paper): k-CL speedup 1.2-3.5x, growing with k on");
    println!("the denser graph, peaking then flattening on the sparser one.");
    println!("Non-clique patterns gain less (fewer cone levels to shrink at) but");
    println!("must never lose past the crossover; heuristic in EXPERIMENTS.md.");
}
