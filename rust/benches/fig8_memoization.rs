//! Regenerates paper Fig. 8: speedup of MNC/MEC memoization for k-MC.
use sandslash::coordinator::campaign;

fn main() {
    let rows = campaign::fig8(&["lj-tiny", "or-tiny"], 4);
    println!("{}", campaign::to_markdown(&rows));
    println!("\nExpected shape (paper): MNC avoids per-position has_edge probes;");
    println!("speedup grows with graph density (paper: 7.4x MEC, 87x MNC avg).");
}
