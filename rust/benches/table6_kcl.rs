//! Regenerates paper Table 6: k-CL (k = 4, 5) across systems + kClist +
//! Sandslash-Lo. Emulation-heavy -> tiny datasets keep the no-DAG
//! baselines inside bench budget (paper shows them timing out at scale).
use sandslash::coordinator::campaign;

fn main() {
    let rows = campaign::table6(&["lj-tiny", "or-tiny", "fr-tiny"], &[4, 5]);
    println!("{}", campaign::to_markdown(&rows));
    println!("\nExpected shape (paper): Sandslash-Lo ~ kClist < Sandslash-Hi <<");
    println!("Peregrine-like ~ Pangolin-like ~ AutoMine-like.");
}
