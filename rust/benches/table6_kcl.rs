//! Regenerates paper Table 6: k-CL (k = 4, 5) across systems + kClist +
//! Sandslash-Lo. Emulation-heavy -> tiny datasets keep the no-DAG
//! baselines inside bench budget (paper shows them timing out at scale).
//! Then runs the PR-1 measurement: scalar (probe/MNC) vs set-centric
//! extension for 4-clique counting on RMAT(2^14), recording the `kcl4`
//! section of `BENCH_pr1.json` at the repo root.
use sandslash::coordinator::campaign;
use sandslash::engine::hooks::NoHooks;
use sandslash::engine::{dfs, MinerConfig, OptFlags};
use sandslash::graph::gen;
use sandslash::pattern::{library, plan};
use sandslash::util::bench::{pr1_report_path, pr3_compare, pr4_compare, print_table, Bench, Pr1Section};

fn main() {
    let rows = campaign::table6(&["lj-tiny", "or-tiny", "fr-tiny"], &[4, 5]);
    println!("{}", campaign::to_markdown(&rows));
    println!("\nExpected shape (paper): Sandslash-Lo ~ kClist < Sandslash-Hi <<");
    println!("Peregrine-like ~ Pangolin-like ~ AutoMine-like.");

    // ---- PR-1: scalar vs set-centric extension, 4-CL on RMAT(2^14) ----
    let g = gen::rmat(14, 4, 42, &[]);
    let pl = plan(&library::clique(4), true, true);
    let set_cfg = MinerConfig::new(OptFlags::hi());
    let mut scalar_cfg = set_cfg;
    scalar_cfg.opts.sets = false;
    let (set_count, _) = dfs::count(&g, &pl, &set_cfg, &NoHooks).unwrap().into_parts();
    let (scalar_count, _) = dfs::count(&g, &pl, &scalar_cfg, &NoHooks).unwrap().into_parts();
    assert_eq!(set_count, scalar_count, "scalar/set-centric differential failed");

    let bench = Bench::quick();
    let r_scalar = bench.run("kcl4-scalar", || dfs::count(&g, &pl, &scalar_cfg, &NoHooks).unwrap().value);
    let r_set = bench.run("kcl4-set", || dfs::count(&g, &pl, &set_cfg, &NoHooks).unwrap().value);
    let r_dag = bench.run("kcl4-dag", || {
        sandslash::apps::clique::clique_hi(&g, 4, &set_cfg).0
    });
    let fmt = |r: &sandslash::util::bench::BenchResult| {
        vec![
            format!("{:.4}", r.min()),
            format!("{:.4}", r.median()),
            format!("{:.4}", r.mean()),
        ]
    };
    print_table(
        "PR-1 4-CL: scalar vs set-centric (rmat scale=14 ef=4 seed=42)",
        &["min s", "median s", "mean s"],
        &[
            ("scalar (probe+MNC)".to_string(), fmt(&r_scalar)),
            ("set-centric".to_string(), fmt(&r_set)),
            ("dag running-intersect (clique_hi)".to_string(), fmt(&r_dag)),
        ],
    );
    let section = Pr1Section {
        graph: "rmat scale=14 ef=4 seed=42",
        pattern: "4-clique",
        count: set_count,
        scalar_secs: r_scalar.min(),
        set_secs: r_set.min(),
        dag_secs: Some(r_dag.min()),
        samples: r_set.samples.len(),
    };
    println!(
        "\n4-cliques = {set_count}; set-centric speedup over scalar = {:.2}x",
        section.speedup()
    );
    if let Err(e) = section.write("kcl4", set_cfg.threads) {
        eprintln!("could not write BENCH_pr1.json: {e}");
    } else {
        println!("wrote `kcl4` section of {}", pr1_report_path().display());
    }

    // ---- PR-3: scalar vs SIMD kernel dispatch, same input, same run
    // (shared protocol: count equality + SIMD-merge selection asserted
    // inside bench::pr3_compare) ----
    let mut nsamples = 0usize;
    let mut pr3 = pr3_compare(
        "rmat scale=14 ef=4 seed=42",
        "4-clique",
        1,
        || {
            let (count, _) = dfs::count(&g, &pl, &set_cfg, &NoHooks).unwrap().into_parts();
            let r = bench.run("kcl4-set-kernels", || dfs::count(&g, &pl, &set_cfg, &NoHooks).unwrap().value);
            nsamples = r.samples.len();
            (count, r.min())
        },
        || dfs::count(&g, &pl, &set_cfg, &NoHooks).unwrap().value,
    );
    pr3.samples = nsamples;
    print_table(
        "PR-3 4-CL kernels: scalar vs SIMD dispatch (rmat scale=14 ef=4 seed=42)",
        &["min s"],
        &[
            ("scalar kernels (forced)".to_string(), vec![format!("{:.4}", pr3.scalar_secs)]),
            (
                format!("simd kernels ({})", pr3.simd),
                vec![format!("{:.4}", pr3.simd_secs)],
            ),
        ],
    );
    println!("\nkernel speedup ({} over scalar) = {:.2}x", pr3.simd, pr3.speedup());
    if let Err(e) = pr3.write("pr3-kcl4", set_cfg.threads) {
        eprintln!("could not write BENCH_pr1.json: {e}");
    } else {
        println!("wrote `pr3-kcl4` section of {}", pr1_report_path().display());
    }

    // ---- PR-4: global-cursor oracle vs work-stealing scheduler, same
    // input, same run (shared protocol: count equality on the timed and
    // the skewed two-hub inputs, plus steal/split counter movement,
    // asserted inside bench::pr4_compare) ----
    let skew = gen::two_hub(1 << 13);
    let skew_cfg = MinerConfig::custom(set_cfg.threads.max(4), 1, OptFlags::hi());
    let mut nsamples4 = 0usize;
    let mut pr4 = pr4_compare(
        "rmat scale=14 ef=4 seed=42",
        "4-clique",
        1,
        set_cfg.threads,
        skew_cfg.threads,
        || {
            let (count, _) = dfs::count(&g, &pl, &set_cfg, &NoHooks).unwrap().into_parts();
            let r = bench.run("kcl4-sched", || dfs::count(&g, &pl, &set_cfg, &NoHooks).unwrap().value);
            nsamples4 = r.samples.len();
            (count, r.min())
        },
        || dfs::count(&skew, &pl, &skew_cfg, &NoHooks).unwrap().value,
    );
    pr4.samples = nsamples4;
    print_table(
        "PR-4 4-CL scheduler: cursor vs stealing (rmat scale=14 ef=4 seed=42)",
        &["min s"],
        &[
            ("global cursor (oracle)".to_string(), vec![format!("{:.4}", pr4.cursor_secs)]),
            (
                format!("stealing ({} shard(s))", pr4.shards),
                vec![format!("{:.4}", pr4.steal_secs)],
            ),
        ],
    );
    println!(
        "\nscheduler speedup (stealing over cursor) = {:.2}x; skewed input moved \
         {} steal(s) + {} split(s)",
        pr4.speedup(),
        pr4.skew_steals,
        pr4.skew_splits
    );
    if let Err(e) = pr4.write("pr4-sched-kcl4", set_cfg.threads) {
        eprintln!("could not write BENCH_pr1.json: {e}");
    } else {
        println!("wrote `pr4-sched-kcl4` section of {}", pr1_report_path().display());
    }
}
