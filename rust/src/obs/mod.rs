//! Observability layer (PR 9): per-query tracing, a unified metrics
//! registry, and a post-mortem flight recorder.
//!
//! The paper's two-level story — automatic high-level optimizations
//! plus user-directed low-level ones — is only debuggable when kernel
//! dispatch, stealing, LG crossovers, and budget trips are
//! attributable to a *specific query and level*. The process-global
//! counter families in [`crate::util::metrics`] cannot do that on the
//! multi-tenant PR-7 service, and nothing preserved a trail when a
//! worker panicked. This module adds the three missing pieces:
//!
//! - [`trace`] — a scoped, thread-local-propagated [`trace::QueryTrace`]
//!   attached through the same reentrancy surface as
//!   [`crate::engine::budget::with_cancel`]: per-level timings,
//!   per-family dispatch histograms, steal/split/claim counts, LG and
//!   ExtCore mode selections, budget charges, cache and admission
//!   verdicts. Default-off and pay-for-what-you-use: every hook is one
//!   thread-local flag check when no trace is installed, and recording
//!   is purely observational — counts are bit-identical on/off
//!   (differential-tested in `rust/tests/obs_differential.rs`).
//! - [`registry`] — one snapshotting registry over every counter
//!   family (dispatch/sched/gov plus the PR-9 service counters:
//!   responses by code, admission sheds, idle-timeout closes, registry
//!   epoch bumps) with a Prometheus-style text exposition, served by
//!   the service `stats` op and `sandslash query --stats`.
//! - [`flight`] — fixed-size lock-free per-worker event rings (query
//!   start/end, trips, steals, splits, fault-stage crossings, panics)
//!   dumped to stderr as line-JSON on worker panic or budget trip,
//!   capacity via `SANDSLASH_FLIGHT_EVENTS`.

pub mod flight;
pub mod registry;
pub mod trace;
