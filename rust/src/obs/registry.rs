//! Unified metrics registry (PR 9): one snapshot over every counter
//! family, plus a Prometheus-style text exposition.
//!
//! PR 8 left three disconnected process-global families in
//! [`crate::util::metrics`] (dispatch/sched/gov) and the PR-7 service
//! had no export path at all. This module adds the missing service
//! counters (responses by wire code, admission sheds, idle-timeout
//! connection closes, graph-registry epoch bumps — all bumped here so
//! the cross-module Relaxed-write lint stays clean), a single
//! [`snapshot`] combining every family, and [`exposition`] rendering
//! the snapshot (plus the caller's point-in-time service gauges) as
//! Prometheus text format. The service `stats` op serves both the
//! structured JSON and the exposition; `sandslash query --stats`
//! prints the latter.
//!
//! Counters are monotone and process-global: attribute to a code
//! region via before/after [`snapshot`] deltas, exactly like the
//! underlying families.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::metrics::{dispatch, gov, sched};

/// Distinct wire response codes (0 ok .. 8 overloaded; the PR-6/PR-7
/// shared code table).
pub const RESPONSE_CODES: usize = 9;

#[allow(clippy::declare_interior_mutable_const)] // array-init seed only
const ZERO: AtomicU64 = AtomicU64::new(0);

static RESPONSES: [AtomicU64; RESPONSE_CODES] = [ZERO; RESPONSE_CODES];
static ADMISSION_SHEDS: AtomicU64 = AtomicU64::new(0);
static IDLE_TIMEOUT_CLOSES: AtomicU64 = AtomicU64::new(0);
static EPOCH_BUMPS: AtomicU64 = AtomicU64::new(0);

/// Count one wire response by its `code` field (out-of-table codes
/// are dropped rather than mis-binned).
pub(crate) fn note_response(code: i32) {
    if (0..RESPONSE_CODES as i32).contains(&code) {
        RESPONSES[code as usize].fetch_add(1, Ordering::Relaxed);
    }
}

/// Count one admission shed (a query refused with `overloaded`).
pub(crate) fn note_admission_shed() {
    ADMISSION_SHEDS.fetch_add(1, Ordering::Relaxed);
}

/// Count one connection closed by the idle read timeout
/// (`SANDSLASH_IDLE_TIMEOUT_MS`, close reason `idle-timeout`).
pub(crate) fn note_idle_timeout_close() {
    IDLE_TIMEOUT_CLOSES.fetch_add(1, Ordering::Relaxed);
}

/// Count one graph-registry epoch bump (an `invalidate` op that found
/// its graph resident).
pub(crate) fn note_epoch_bump() {
    EPOCH_BUMPS.fetch_add(1, Ordering::Relaxed);
}

/// Point-in-time copy of the PR-9 service counters.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ServiceCounts {
    /// Responses sent, indexed by wire `code` (0 ok .. 8 overloaded).
    pub responses: [u64; RESPONSE_CODES],
    /// Queries refused by admission control (`overloaded`).
    pub admission_sheds: u64,
    /// Connections closed by the idle read timeout.
    pub idle_timeout_closes: u64,
    /// Graph-registry epoch bumps via the `invalidate` op.
    pub epoch_bumps: u64,
}

impl ServiceCounts {
    /// Total responses across every code.
    pub fn responses_total(&self) -> u64 {
        self.responses.iter().sum()
    }
}

/// One unified snapshot across every counter family (relaxed loads:
/// exact under quiescence, monotone lower bounds under concurrency).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct RegistrySnapshot {
    /// Kernel-dispatch selections ([`dispatch::snapshot`]).
    pub dispatch: dispatch::DispatchCounts,
    /// Scheduler events ([`sched::snapshot`]).
    pub sched: sched::SchedCounts,
    /// Governance events ([`gov::snapshot`]).
    pub gov: gov::GovCounts,
    /// PR-9 service counters.
    pub service: ServiceCounts,
}

/// Read every counter family at once.
pub fn snapshot() -> RegistrySnapshot {
    let mut responses = [0u64; RESPONSE_CODES];
    for (slot, c) in responses.iter_mut().zip(RESPONSES.iter()) {
        *slot = c.load(Ordering::Relaxed);
    }
    RegistrySnapshot {
        dispatch: dispatch::snapshot(),
        sched: sched::snapshot(),
        gov: gov::snapshot(),
        service: ServiceCounts {
            responses,
            admission_sheds: ADMISSION_SHEDS.load(Ordering::Relaxed),
            idle_timeout_closes: IDLE_TIMEOUT_CLOSES.load(Ordering::Relaxed),
            epoch_bumps: EPOCH_BUMPS.load(Ordering::Relaxed),
        },
    }
}

/// Point-in-time service gauges owned by a `Service` instance (not
/// process-global counters), supplied by the caller so the exposition
/// can cover cache occupancy and admission depth without this module
/// depending on the service types.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ServiceGauges {
    /// Queries accepted since service start.
    pub queries: u64,
    /// Queries currently holding an admission permit.
    pub inflight: u64,
    /// Queries currently waiting in the admission queue.
    pub queued: u64,
    /// Result-cache hits.
    pub cache_hits: u64,
    /// Result-cache misses.
    pub cache_misses: u64,
    /// Requests coalesced onto an in-flight leader.
    pub cache_coalesced: u64,
    /// Completed fills inserted into the cache.
    pub cache_fills: u64,
    /// Fills rejected (oversized or partial results).
    pub cache_rejected: u64,
    /// Entries evicted by the LRU byte cap.
    pub cache_evictions: u64,
    /// Entries invalidated by epoch bumps.
    pub cache_invalidated: u64,
    /// Bytes resident in the result cache.
    pub cache_bytes: u64,
    /// Entries resident in the result cache.
    pub cache_entries: u64,
}

fn counter(out: &mut String, name: &str, value: u64) {
    out.push_str("# TYPE ");
    out.push_str(name);
    out.push_str(" counter\n");
    out.push_str(name);
    out.push(' ');
    out.push_str(&value.to_string());
    out.push('\n');
}

fn gauge(out: &mut String, name: &str, value: u64) {
    out.push_str("# TYPE ");
    out.push_str(name);
    out.push_str(" gauge\n");
    out.push_str(name);
    out.push(' ');
    out.push_str(&value.to_string());
    out.push('\n');
}

fn labeled(out: &mut String, name: &str, label: &str, rows: &[(&str, u64)]) {
    out.push_str("# TYPE ");
    out.push_str(name);
    out.push_str(" counter\n");
    for (value_label, value) in rows {
        out.push_str(&format!("{name}{{{label}=\"{value_label}\"}} {value}\n"));
    }
}

/// Render `snap` (and, when given, per-service `gauges`) as
/// Prometheus text exposition format: `# TYPE` headers followed by
/// `name{label="value"} N` sample lines, newline-terminated.
pub fn exposition(snap: &RegistrySnapshot, gauges: Option<&ServiceGauges>) -> String {
    let mut out = String::with_capacity(2048);
    let d = &snap.dispatch;
    labeled(
        &mut out,
        "sandslash_dispatch_calls_total",
        "family",
        &[
            ("merge", d.merge),
            ("gallop", d.gallop),
            ("simd_merge", d.simd_merge),
            ("word_parallel", d.word_parallel),
            ("mask_filter", d.mask_filter),
            ("gather_filter", d.gather_filter),
            ("difference", d.difference),
        ],
    );
    let s = &snap.sched;
    labeled(
        &mut out,
        "sandslash_sched_events_total",
        "event",
        &[
            ("claims", s.claims),
            ("steals", s.steals),
            ("shard_claims", s.shard_claims),
            ("splits", s.splits),
        ],
    );
    let g = &snap.gov;
    labeled(
        &mut out,
        "sandslash_gov_trips_total",
        "reason",
        &[
            ("deadline", g.deadline_trips),
            ("task-budget", g.task_budget_trips),
            ("caller", g.caller_trips),
            ("worker-panic", g.panic_trips),
        ],
    );
    counter(&mut out, "sandslash_gov_panics_caught_total", g.panics_caught);
    counter(&mut out, "sandslash_gov_faults_injected_total", g.faults_injected);
    let sv = &snap.service;
    {
        out.push_str("# TYPE sandslash_service_responses_total counter\n");
        for (code, value) in sv.responses.iter().enumerate() {
            out.push_str(&format!(
                "sandslash_service_responses_total{{code=\"{code}\"}} {value}\n"
            ));
        }
    }
    counter(&mut out, "sandslash_admission_sheds_total", sv.admission_sheds);
    counter(&mut out, "sandslash_service_idle_timeout_closes_total", sv.idle_timeout_closes);
    counter(&mut out, "sandslash_registry_epoch_bumps_total", sv.epoch_bumps);
    if let Some(gg) = gauges {
        counter(&mut out, "sandslash_service_queries_total", gg.queries);
        gauge(&mut out, "sandslash_admission_inflight", gg.inflight);
        gauge(&mut out, "sandslash_admission_queued", gg.queued);
        labeled(
            &mut out,
            "sandslash_cache_events_total",
            "event",
            &[
                ("hits", gg.cache_hits),
                ("misses", gg.cache_misses),
                ("coalesced", gg.cache_coalesced),
                ("fills", gg.cache_fills),
                ("rejected", gg.cache_rejected),
                ("evictions", gg.cache_evictions),
                ("invalidated", gg.cache_invalidated),
            ],
        );
        gauge(&mut out, "sandslash_cache_bytes", gg.cache_bytes);
        gauge(&mut out, "sandslash_cache_entries", gg.cache_entries);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_counters_record_and_snapshot() {
        let before = snapshot();
        note_response(0);
        note_response(8);
        note_response(99); // out of table: dropped, not mis-binned
        note_admission_shed();
        note_idle_timeout_close();
        note_epoch_bump();
        let after = snapshot();
        assert!(after.service.responses[0] > before.service.responses[0]);
        assert!(after.service.responses[8] > before.service.responses[8]);
        assert!(after.service.admission_sheds > before.service.admission_sheds);
        assert!(after.service.idle_timeout_closes > before.service.idle_timeout_closes);
        assert!(after.service.epoch_bumps > before.service.epoch_bumps);
        assert!(after.service.responses_total() >= before.service.responses_total() + 2);
    }

    #[test]
    fn exposition_is_well_formed_and_covers_every_family() {
        let snap = snapshot();
        let gauges = ServiceGauges { queries: 3, cache_entries: 1, ..ServiceGauges::default() };
        let text = exposition(&snap, Some(&gauges));
        for family in [
            "sandslash_dispatch_calls_total",
            "sandslash_sched_events_total",
            "sandslash_gov_trips_total",
            "sandslash_service_responses_total",
            "sandslash_admission_sheds_total",
            "sandslash_cache_events_total",
        ] {
            assert!(text.contains(&format!("# TYPE {family} counter")), "{family}\n{text}");
        }
        assert!(text.contains("sandslash_dispatch_calls_total{family=\"merge\"} "));
        assert!(text.contains("sandslash_service_responses_total{code=\"8\"} "));
        assert!(text.contains("sandslash_service_queries_total 3\n"));
        assert!(text.ends_with('\n'));
        // every non-comment line is `name[{label}] value`
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').expect("sample line");
            assert!(!name.is_empty());
            value.parse::<u64>().expect("numeric sample value");
        }
        // without gauges the service-instance families are absent
        let bare = exposition(&snap, None);
        assert!(!bare.contains("sandslash_cache_bytes"));
    }
}
