//! Flight recorder (PR 9): fixed-size lock-free per-worker event
//! rings for post-mortem diagnosis.
//!
//! When a worker panics or a budget trips, the interesting question
//! is *what was the process doing just before* — which stage was
//! crossing, who stole what, which query was in flight. Logs are too
//! expensive for always-on recording, so this keeps a bounded ring of
//! recent events per worker thread: recording is a few relaxed atomic
//! stores into a pre-allocated slot (no locks, no allocation), and
//! the rings are only ever read when something already went wrong.
//!
//! Events recorded: query start/end (governed runs), budget trips,
//! steals, splits, fault-stage crossings ([`crate::util::fault`]),
//! and caught worker panics (stamped with the last stage the thread
//! crossed — what "names the faulted stage" in the dump). On a worker
//! panic or a trip the full recorder is dumped to stderr as line-JSON
//! prefixed `sandslash-flight:`; [`render`] exposes the same text for
//! tests.
//!
//! Ring capacity comes from `SANDSLASH_FLIGHT_EVENTS` (events per
//! ring, default 64, same loud-reject parse contract as every knob)
//! and is pinned at first use. Slots are recycled oldest-first; a
//! reader racing a writer can observe a torn event, which is
//! acceptable for a post-mortem aid and keeps the write path
//! wait-free.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::util::fault::Stage;
use crate::util::pool;

/// Worker-thread ring slots; threads beyond this share rings
/// (assignment wraps), which only blurs attribution, never drops
/// events.
const MAX_RINGS: usize = 64;

/// Default events retained per ring.
const DEFAULT_EVENTS: usize = 64;

const KIND_EMPTY: u8 = 0;
const KIND_QUERY_START: u8 = 1;
const KIND_QUERY_END: u8 = 2;
const KIND_TRIP: u8 = 3;
const KIND_STEAL: u8 = 4;
const KIND_SPLIT: u8 = 5;
const KIND_STAGE: u8 = 6;
const KIND_PANIC: u8 = 7;

struct Slot {
    seq: AtomicU64,
    kind: AtomicU8,
    arg: AtomicU64,
}

struct Ring {
    head: AtomicU64,
    slots: Box<[Slot]>,
}

struct Flight {
    rings: Vec<Ring>,
    capacity: usize,
}

static FLIGHT: OnceLock<Flight> = OnceLock::new();
static NEXT_RING: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static MY_RING: Cell<usize> = const { Cell::new(usize::MAX) };
    static LAST_STAGE: Cell<u64> = const { Cell::new(0) };
}

fn flight() -> &'static Flight {
    FLIGHT.get_or_init(|| {
        let capacity = pool::positive_usize_env(
            "SANDSLASH_FLIGHT_EVENTS",
            "the default flight-ring capacity",
        )
        .unwrap_or(DEFAULT_EVENTS)
        .min(1 << 16);
        let rings = (0..MAX_RINGS)
            .map(|_| Ring {
                head: AtomicU64::new(0),
                slots: (0..capacity)
                    .map(|_| Slot {
                        seq: AtomicU64::new(0),
                        kind: AtomicU8::new(KIND_EMPTY),
                        arg: AtomicU64::new(0),
                    })
                    .collect(),
            })
            .collect();
        Flight { rings, capacity }
    })
}

#[inline]
fn my_ring(f: &Flight) -> &Ring {
    let idx = MY_RING.with(|c| {
        if c.get() == usize::MAX {
            c.set(NEXT_RING.fetch_add(1, Ordering::Relaxed) % MAX_RINGS);
        }
        c.get()
    });
    &f.rings[idx]
}

#[inline]
fn record(kind: u8, arg: u64) {
    let f = flight();
    let ring = my_ring(f);
    let seq = ring.head.fetch_add(1, Ordering::Relaxed);
    let slot = &ring.slots[(seq as usize) % f.capacity];
    // Mark the slot in-progress, fill it, then publish the kind last:
    // a racing reader sees either the old event, "empty", or the new
    // event — never a half-written kind with a stale payload tag.
    slot.kind.store(KIND_EMPTY, Ordering::Release);
    slot.arg.store(arg, Ordering::Relaxed);
    slot.seq.store(seq, Ordering::Relaxed);
    slot.kind.store(kind, Ordering::Release);
}

fn stage_code(stage: Stage) -> u64 {
    match stage {
        Stage::RootClaim => 1,
        Stage::SplitTask => 2,
        Stage::FsmRegen => 3,
        Stage::BfsLevel => 4,
    }
}

fn stage_name(code: u64) -> &'static str {
    match code {
        1 => "root-claim",
        2 => "split-task",
        3 => "fsm-regen",
        4 => "bfs-level",
        _ => "none",
    }
}

/// Record the start of a governed run on this thread.
#[inline]
pub(crate) fn note_query_start() {
    record(KIND_QUERY_START, 0);
}

/// Record the end of a governed run on this thread.
#[inline]
pub(crate) fn note_query_end() {
    record(KIND_QUERY_END, 0);
}

/// Record a cancel-token trip (arg: the PR-6 exit code of the
/// reason).
#[inline]
pub(crate) fn note_trip(code: u64) {
    record(KIND_TRIP, code);
}

/// Record a successful steal (arg: the victim worker index).
#[inline]
pub(crate) fn note_steal(victim: usize) {
    record(KIND_STEAL, victim as u64);
}

/// Record a published split task.
#[inline]
pub(crate) fn note_split() {
    record(KIND_SPLIT, 0);
}

/// Record a fault-point crossing and remember it as this thread's
/// most recent stage — the stage a subsequent [`note_panic`] is
/// stamped with.
#[inline]
pub(crate) fn note_stage(stage: Stage) {
    let code = stage_code(stage);
    LAST_STAGE.with(|c| c.set(code));
    record(KIND_STAGE, code);
}

/// Record a caught worker panic, stamped with the last fault stage
/// this thread crossed (0 = none seen).
#[inline]
pub(crate) fn note_panic() {
    let stage = LAST_STAGE.with(|c| c.get());
    record(KIND_PANIC, stage);
}

fn event_json(ring: usize, seq: u64, kind: u8, arg: u64) -> Option<String> {
    let body = match kind {
        KIND_QUERY_START => "\"event\":\"query-start\"".to_string(),
        KIND_QUERY_END => "\"event\":\"query-end\"".to_string(),
        KIND_TRIP => format!("\"event\":\"trip\",\"code\":{arg}"),
        KIND_STEAL => format!("\"event\":\"steal\",\"victim\":{arg}"),
        KIND_SPLIT => "\"event\":\"split\"".to_string(),
        KIND_STAGE => format!("\"event\":\"stage\",\"stage\":\"{}\"", stage_name(arg)),
        KIND_PANIC => format!("\"event\":\"panic\",\"stage\":\"{}\"", stage_name(arg)),
        _ => return None,
    };
    Some(format!("{{\"ring\":{ring},\"seq\":{seq},{body}}}"))
}

/// Render the entire recorder as the line-JSON dump text: one
/// `sandslash-flight:` line per retained event (per ring, oldest
/// first), bracketed by begin/end marker lines carrying `reason`.
/// Used by [`dump_to_stderr`] and directly by tests.
pub fn render(reason: &str) -> String {
    let f = flight();
    let mut out = String::with_capacity(1024);
    out.push_str(&format!("sandslash-flight: begin dump (reason={reason})\n"));
    let mut total = 0usize;
    for (ring_idx, ring) in f.rings.iter().enumerate() {
        if ring.head.load(Ordering::Acquire) == 0 {
            continue;
        }
        let mut events: Vec<(u64, u8, u64)> = ring
            .slots
            .iter()
            .filter_map(|slot| {
                let kind = slot.kind.load(Ordering::Acquire);
                if kind == KIND_EMPTY {
                    return None;
                }
                Some((slot.seq.load(Ordering::Relaxed), kind, slot.arg.load(Ordering::Relaxed)))
            })
            .collect();
        events.sort_by_key(|&(seq, _, _)| seq);
        for (seq, kind, arg) in events {
            if let Some(line) = event_json(ring_idx, seq, kind, arg) {
                out.push_str("sandslash-flight: ");
                out.push_str(&line);
                out.push('\n');
                total += 1;
            }
        }
    }
    out.push_str(&format!("sandslash-flight: end dump ({total} events)\n"));
    out
}

/// Dump the recorder to stderr (worker panic or budget trip). One
/// `eprint!` call so concurrent dumps interleave per-dump, not
/// per-line.
pub fn dump_to_stderr(reason: &str) {
    eprint!("{}", render(reason));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_record_and_render() {
        note_query_start();
        note_stage(Stage::RootClaim);
        note_steal(3);
        note_split();
        note_trip(5);
        note_panic();
        note_query_end();
        let text = render("unit-test");
        assert!(text.starts_with("sandslash-flight: begin dump (reason=unit-test)\n"), "{text}");
        assert!(text.contains("\"event\":\"query-start\""), "{text}");
        assert!(text.contains("\"event\":\"stage\",\"stage\":\"root-claim\""), "{text}");
        assert!(text.contains("\"event\":\"steal\",\"victim\":3"), "{text}");
        assert!(text.contains("\"event\":\"trip\",\"code\":5"), "{text}");
        assert!(text.contains("\"event\":\"panic\",\"stage\":\"root-claim\""), "{text}");
        assert!(text.trim_end().ends_with("events)"), "{text}");
        // every event line parses as one JSON object after the prefix
        for line in text.lines() {
            let rest = line.strip_prefix("sandslash-flight: ").expect("prefix");
            if rest.starts_with('{') {
                assert!(rest.ends_with('}'), "{rest}");
            }
        }
    }

    #[test]
    fn ring_wraps_and_keeps_newest() {
        let capacity = flight().capacity;
        for _ in 0..capacity + 8 {
            note_split();
        }
        let text = render("wrap");
        // the dump stays bounded by the ring, no matter how many events fired
        let lines = text.lines().filter(|l| l.contains("\"event\"")).count();
        assert!(lines <= MAX_RINGS * capacity);
        assert!(text.contains("\"event\":\"split\""));
    }
}
