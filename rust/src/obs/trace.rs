//! Per-query tracing (PR 9): a scoped, thread-local-propagated
//! profile accumulator.
//!
//! A [`QueryTrace`] is attached with [`with_trace`] — the same
//! Drop-restore reentrancy shape as
//! [`crate::engine::budget::with_cancel`] and the scheduler override
//! scope — and the executor re-installs the caller's trace inside
//! every spawned worker (thread-locals do not cross
//! `thread::scope`), so one query's events land in one query's
//! profile even when several tenants share the process.
//!
//! Pay-for-what-you-use: every hook ([`on_dispatch`], [`on_steal`],
//! [`LevelSpan`], ...) first reads a thread-local `Cell<bool>` and
//! returns when no trace is installed, so the untraced hot path pays
//! one flag check and nothing else. Recording is purely
//! observational — no hook influences kernel selection, scheduling,
//! or budgets — which is what makes the on/off bit-identity
//! differential suite (`rust/tests/obs_differential.rs`) hold by
//! construction.
//!
//! All counter fields are atomics bumped only by methods in this
//! file (the repo-invariant lint audits cross-module Relaxed writes);
//! relaxed loads in [`QueryTrace::render`] are exact once the traced
//! run has joined its workers.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::engine::budget::CancelReason;

/// Deepest extension level with its own timing/call slot; deeper
/// levels (none of the engines exceed this today — patterns are
/// ≤ 16 vertices) fold into the last slot.
pub const MAX_LEVELS: usize = 16;

/// Number of kernel-dispatch families, matching
/// [`crate::util::metrics::dispatch::DispatchCounts`] field order.
pub const FAMILIES: usize = 7;

/// Family names in [`crate::util::metrics::dispatch::DispatchCounts`]
/// field order — index `i` of the trace histogram is family
/// `FAMILY_NAMES[i]`.
pub const FAMILY_NAMES: [&str; FAMILIES] = [
    "merge",
    "gallop",
    "simd_merge",
    "word_parallel",
    "mask_filter",
    "gather_filter",
    "difference",
];

/// How the result cache answered a traced query (recorded by the
/// service layer after `get_or_compute` resolves).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheVerdict {
    /// Computed fresh and (if complete) filled into the cache.
    Miss,
    /// Served from the cache (including single-flight coalescing onto
    /// an in-flight leader).
    Hit,
    /// Cache skipped entirely (`no_cache` request, or one-shot CLI).
    Bypass,
}

#[allow(clippy::declare_interior_mutable_const)] // fresh-profile init seed only
const ZERO: AtomicU64 = AtomicU64::new(0);

/// Per-query profile accumulator. Shared by `Arc` between the
/// attaching scope and every worker mining on its behalf; rendered as
/// a one-line JSON profile with [`render`](Self::render).
#[derive(Debug)]
pub struct QueryTrace {
    level_calls: [AtomicU64; MAX_LEVELS],
    level_nanos: [AtomicU64; MAX_LEVELS],
    dispatch: [AtomicU64; FAMILIES],
    claims: AtomicU64,
    steals: AtomicU64,
    shard_claims: AtomicU64,
    splits: AtomicU64,
    lg_roots: AtomicU64,
    excl_dense: AtomicU64,
    excl_sparse: AtomicU64,
    budget_charges: AtomicU64,
    trip_code: AtomicU64,
    cache_verdict: AtomicU64,
    admission_recorded: AtomicU64,
    admission_wait_nanos: AtomicU64,
    plan_kind: AtomicU64,
    plan_leaves: AtomicU64,
    plan_anchor_pieces: AtomicU64,
    plan_formula_pieces: AtomicU64,
    plan_piece_nanos: AtomicU64,
}

impl Default for QueryTrace {
    fn default() -> Self {
        Self::new()
    }
}

impl QueryTrace {
    /// A fresh all-zero profile.
    pub fn new() -> Self {
        QueryTrace {
            level_calls: [ZERO; MAX_LEVELS],
            level_nanos: [ZERO; MAX_LEVELS],
            dispatch: [ZERO; FAMILIES],
            claims: ZERO,
            steals: ZERO,
            shard_claims: ZERO,
            splits: ZERO,
            lg_roots: ZERO,
            excl_dense: ZERO,
            excl_sparse: ZERO,
            budget_charges: ZERO,
            trip_code: ZERO,
            cache_verdict: ZERO,
            admission_recorded: ZERO,
            admission_wait_nanos: ZERO,
            plan_kind: ZERO,
            plan_leaves: ZERO,
            plan_anchor_pieces: ZERO,
            plan_formula_pieces: ZERO,
            plan_piece_nanos: ZERO,
        }
    }

    #[inline]
    fn bump_dispatch(&self, family: usize) {
        if family < FAMILIES {
            self.dispatch[family].fetch_add(1, Ordering::Relaxed);
        }
    }

    #[inline]
    fn note_level(&self, level: usize, nanos: u64) {
        let slot = level.min(MAX_LEVELS - 1);
        self.level_calls[slot].fetch_add(1, Ordering::Relaxed);
        self.level_nanos[slot].fetch_add(nanos, Ordering::Relaxed);
    }

    #[inline]
    fn note_trip(&self, reason: CancelReason) {
        // First trip wins, mirroring the cancel-token latch: the
        // governor only reports the reason that actually won the race.
        let code = trip_code(reason);
        let _ = self.trip_code.compare_exchange(0, code, Ordering::Relaxed, Ordering::Relaxed);
    }

    /// Record how long this query waited in the admission queue
    /// (service layer; 0 nanos still marks the verdict as `admitted`).
    pub fn set_admission_wait(&self, nanos: u64) {
        self.admission_wait_nanos.store(nanos, Ordering::Relaxed);
        self.admission_recorded.store(1, Ordering::Relaxed);
    }

    /// Record the result-cache verdict (service layer).
    pub fn set_cache_verdict(&self, v: CacheVerdict) {
        let code = match v {
            CacheVerdict::Miss => 1,
            CacheVerdict::Hit => 2,
            CacheVerdict::Bypass => 3,
        };
        self.cache_verdict.store(code, Ordering::Relaxed);
    }

    /// Root blocks claimed from a worker's own shard while this trace
    /// was installed.
    pub fn claims(&self) -> u64 {
        self.claims.load(Ordering::Relaxed)
    }

    /// Tasks stolen from another worker's deque.
    pub fn steals(&self) -> u64 {
        self.steals.load(Ordering::Relaxed)
    }

    /// Root blocks claimed from a foreign shard's cursor.
    pub fn shard_claims(&self) -> u64 {
        self.shard_claims.load(Ordering::Relaxed)
    }

    /// Level-1 suffixes published as split tasks.
    pub fn splits(&self) -> u64 {
        self.splits.load(Ordering::Relaxed)
    }

    /// Roots routed through the shrinking-local-graph (LG) path.
    pub fn lg_roots(&self) -> u64 {
        self.lg_roots.load(Ordering::Relaxed)
    }

    /// ExtCore exclusion-chain mode selections: `(dense, sparse)`.
    pub fn excl_modes(&self) -> (u64, u64) {
        (self.excl_dense.load(Ordering::Relaxed), self.excl_sparse.load(Ordering::Relaxed))
    }

    /// Budget charges (governed task admissions) on this query's behalf.
    pub fn budget_charges(&self) -> u64 {
        self.budget_charges.load(Ordering::Relaxed)
    }

    /// Total kernel dispatches across every family.
    pub fn dispatch_total(&self) -> u64 {
        self.dispatch.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Total recorded extension calls across every level.
    pub fn level_calls_total(&self) -> u64 {
        self.level_calls.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// What the PR-10 counting planner selected for this query:
    /// `None` if no planner decision was traced, otherwise `"direct"`
    /// (enumerated oracle) or `"decomposed"`.
    pub fn plan_selected(&self) -> Option<&'static str> {
        match self.plan_kind.load(Ordering::Relaxed) {
            0 => None,
            1 => Some("direct"),
            _ => Some("decomposed"),
        }
    }

    /// Planner leaf count recorded by the selection hook.
    pub fn plan_leaves(&self) -> u64 {
        self.plan_leaves.load(Ordering::Relaxed)
    }

    /// Executed planner pieces: `(anchor enumerations, formula scans)`.
    pub fn plan_pieces(&self) -> (u64, u64) {
        (
            self.plan_anchor_pieces.load(Ordering::Relaxed),
            self.plan_formula_pieces.load(Ordering::Relaxed),
        )
    }

    /// Total nanoseconds spent inside planner pieces (anchors + scans).
    pub fn plan_piece_nanos(&self) -> u64 {
        self.plan_piece_nanos.load(Ordering::Relaxed)
    }

    /// Render the accumulated profile as one line of JSON (the
    /// `"profile"` field of a traced service response, and the file
    /// written by the one-shot CLI's `--profile`). Level rows with no
    /// calls are omitted; the dispatch histogram always lists all
    /// seven families.
    pub fn render(&self) -> String {
        let mut out = String::with_capacity(512);
        out.push_str("{\"levels\":[");
        let mut first = true;
        for level in 0..MAX_LEVELS {
            let calls = self.level_calls[level].load(Ordering::Relaxed);
            if calls == 0 {
                continue;
            }
            if !first {
                out.push(',');
            }
            first = false;
            let nanos = self.level_nanos[level].load(Ordering::Relaxed);
            out.push_str(&format!(
                "{{\"level\":{level},\"calls\":{calls},\"nanos\":{nanos}}}"
            ));
        }
        out.push_str("],\"dispatch\":{");
        for (i, name) in FAMILY_NAMES.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let n = self.dispatch[i].load(Ordering::Relaxed);
            out.push_str(&format!("\"{name}\":{n}"));
        }
        out.push_str(&format!(
            "}},\"sched\":{{\"claims\":{},\"steals\":{},\"shard_claims\":{},\"splits\":{}}}",
            self.claims(),
            self.steals(),
            self.shard_claims(),
            self.splits()
        ));
        let (dense, sparse) = self.excl_modes();
        out.push_str(&format!(
            ",\"modes\":{{\"lg_roots\":{},\"extcore_dense\":{dense},\"extcore_sparse\":{sparse}}}",
            self.lg_roots()
        ));
        match self.plan_selected() {
            None => out.push_str(",\"plan\":null"),
            Some(kind) => {
                let (anchors, formulas) = self.plan_pieces();
                out.push_str(&format!(
                    ",\"plan\":{{\"kind\":\"{kind}\",\"leaves\":{},\"anchor_pieces\":{anchors},\
                     \"formula_pieces\":{formulas},\"piece_nanos\":{}}}",
                    self.plan_leaves(),
                    self.plan_piece_nanos()
                ));
            }
        }
        out.push_str(&format!(",\"budget\":{{\"charges\":{}", self.budget_charges()));
        match self.trip_code.load(Ordering::Relaxed) {
            0 => out.push_str(",\"trip\":null}"),
            code => out.push_str(&format!(",\"trip\":\"{}\"}}", trip_name(code))),
        }
        match self.cache_verdict.load(Ordering::Relaxed) {
            0 => out.push_str(",\"cache\":null"),
            1 => out.push_str(",\"cache\":\"miss\""),
            2 => out.push_str(",\"cache\":\"hit\""),
            _ => out.push_str(",\"cache\":\"bypass\""),
        }
        if self.admission_recorded.load(Ordering::Relaxed) != 0 {
            out.push_str(&format!(
                ",\"admission\":{{\"verdict\":\"admitted\",\"wait_nanos\":{}}}",
                self.admission_wait_nanos.load(Ordering::Relaxed)
            ));
        } else {
            out.push_str(",\"admission\":null");
        }
        out.push('}');
        out
    }
}

/// The PR-6 exit code for a trip reason (shared code table: the CLI
/// process exit, the wire `code` field, and the profile all agree).
fn trip_code(reason: CancelReason) -> u64 {
    match reason {
        CancelReason::WorkerPanic => 4,
        CancelReason::Deadline => 5,
        CancelReason::TaskBudget => 6,
        CancelReason::Caller => 7,
    }
}

fn trip_name(code: u64) -> &'static str {
    match code {
        4 => "worker-panic",
        5 => "deadline",
        6 => "task-budget",
        7 => "caller",
        _ => "unknown",
    }
}

thread_local! {
    static CURRENT: RefCell<Option<Arc<QueryTrace>>> = const { RefCell::new(None) };
    static ACTIVE: Cell<bool> = const { Cell::new(false) };
}

/// Run `f` with every traced event on this thread (and, via the
/// executor's propagation, on every worker it spawns) recorded into
/// `trace`. Scoped and nesting-safe: the previous trace is restored
/// on return, panic included — the same Drop-restore shape as
/// [`crate::engine::budget::with_cancel`].
pub fn with_trace<R>(trace: Arc<QueryTrace>, f: impl FnOnce() -> R) -> R {
    let prev = CURRENT.with(|t| t.replace(Some(trace)));
    ACTIVE.with(|a| a.set(true));
    struct Restore(Option<Arc<QueryTrace>>);
    impl Drop for Restore {
        fn drop(&mut self) {
            let prev = self.0.take();
            ACTIVE.with(|a| a.set(prev.is_some()));
            CURRENT.with(|t| *t.borrow_mut() = prev);
        }
    }
    let _restore = Restore(prev);
    f()
}

/// The trace installed on this thread, if any — captured by the
/// executor before `thread::scope` so spawned workers can re-install
/// it (thread-locals do not cross scope boundaries).
pub fn current() -> Option<Arc<QueryTrace>> {
    if !active() {
        return None;
    }
    CURRENT.with(|t| t.borrow().clone())
}

/// [`with_trace`] when `trace` is `Some`, plain `f()` otherwise — the
/// shape the executor uses to re-install a captured caller trace
/// inside spawned workers without branching at every hook site.
#[inline]
pub(crate) fn with_optional<R>(trace: Option<Arc<QueryTrace>>, f: impl FnOnce() -> R) -> R {
    match trace {
        Some(t) => with_trace(t, f),
        None => f(),
    }
}

/// Fast per-thread "is a trace installed" check — the single flag
/// read every hook pays when tracing is off.
#[inline]
fn active() -> bool {
    ACTIVE.with(|a| a.get())
}

#[inline]
fn with_current(f: impl FnOnce(&QueryTrace)) {
    if active() {
        CURRENT.with(|t| {
            if let Some(tr) = t.borrow().as_ref() {
                f(tr);
            }
        });
    }
}

/// Hook: one kernel dispatch of `family` (index into
/// [`FAMILY_NAMES`]); called by the dispatch counters alongside the
/// process-global bump.
#[inline]
pub(crate) fn on_dispatch(family: usize) {
    with_current(|t| t.bump_dispatch(family));
}

/// Hook: a root block claimed from the worker's own shard.
#[inline]
pub(crate) fn on_claim() {
    with_current(|t| {
        t.claims.fetch_add(1, Ordering::Relaxed);
    });
}

/// Hook: a task stolen from another worker's deque.
#[inline]
pub(crate) fn on_steal() {
    with_current(|t| {
        t.steals.fetch_add(1, Ordering::Relaxed);
    });
}

/// Hook: a root block claimed from a foreign shard's cursor.
#[inline]
pub(crate) fn on_shard_claim() {
    with_current(|t| {
        t.shard_claims.fetch_add(1, Ordering::Relaxed);
    });
}

/// Hook: a level-1 suffix published as a split task.
#[inline]
pub(crate) fn on_split() {
    with_current(|t| {
        t.splits.fetch_add(1, Ordering::Relaxed);
    });
}

/// Hook: a root routed through the shrinking-local-graph path.
#[inline]
pub(crate) fn on_lg_root() {
    with_current(|t| {
        t.lg_roots.fetch_add(1, Ordering::Relaxed);
    });
}

/// Hook: the ExtCore exclusion chain selected its dense (bitset) mode.
#[inline]
pub(crate) fn on_excl_dense() {
    with_current(|t| {
        t.excl_dense.fetch_add(1, Ordering::Relaxed);
    });
}

/// Hook: the ExtCore exclusion chain selected its sparse (sorted-list)
/// mode.
#[inline]
pub(crate) fn on_excl_sparse() {
    with_current(|t| {
        t.excl_sparse.fetch_add(1, Ordering::Relaxed);
    });
}

/// Hook: the governor charged one task against this query's budget.
#[inline]
pub(crate) fn on_budget_charge() {
    with_current(|t| {
        t.budget_charges.fetch_add(1, Ordering::Relaxed);
    });
}

/// Hook: this query's cancel token latched `reason` (first trip wins).
#[inline]
pub(crate) fn on_trip(reason: CancelReason) {
    with_current(|t| t.note_trip(reason));
}

/// Hook: the PR-10 counting planner selected a route for this query
/// (`decomposed == false` means the enumerated oracle runs) with
/// `leaves` execution pieces. Plain stores: one selection per traced
/// query; a census records its single aggregate selection.
#[inline]
pub(crate) fn on_plan_select(decomposed: bool, leaves: u64) {
    with_current(|t| {
        t.plan_kind.store(if decomposed { 2 } else { 1 }, Ordering::Relaxed);
        t.plan_leaves.store(leaves, Ordering::Relaxed);
    });
}

/// Hook: one planner piece finished — an anchor enumeration
/// (`anchor == true`) or a formula scan — after `nanos` of work.
#[inline]
pub(crate) fn on_plan_piece(anchor: bool, nanos: u64) {
    with_current(|t| {
        if anchor {
            t.plan_anchor_pieces.fetch_add(1, Ordering::Relaxed);
        } else {
            t.plan_formula_pieces.fetch_add(1, Ordering::Relaxed);
        }
        t.plan_piece_nanos.fetch_add(nanos, Ordering::Relaxed);
    });
}

/// Inclusive per-level timing guard: created at the top of an
/// extension call, records `(calls += 1, nanos += elapsed)` for its
/// level on drop. When no trace is installed it holds no timestamp
/// and drop is a no-op, so the untraced path pays one flag check.
pub(crate) struct LevelSpan {
    level: usize,
    start: Option<Instant>,
}

impl LevelSpan {
    #[inline]
    pub(crate) fn enter(level: usize) -> Self {
        let start = if active() { Some(Instant::now()) } else { None };
        LevelSpan { level, start }
    }
}

impl Drop for LevelSpan {
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            let nanos = t0.elapsed().as_nanos() as u64;
            with_current(|t| t.note_level(self.level, nanos));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hooks_are_inert_without_a_trace() {
        on_dispatch(0);
        on_claim();
        on_steal();
        on_budget_charge();
        on_plan_select(true, 3);
        on_plan_piece(true, 10);
        drop(LevelSpan::enter(2));
        assert!(current().is_none());
    }

    #[test]
    fn with_trace_records_and_restores() {
        let tr = Arc::new(QueryTrace::new());
        with_trace(tr.clone(), || {
            assert!(current().is_some());
            on_dispatch(0);
            on_dispatch(6);
            on_claim();
            on_steal();
            on_shard_claim();
            on_split();
            on_lg_root();
            on_excl_dense();
            on_excl_sparse();
            on_budget_charge();
            on_plan_select(true, 4);
            on_plan_piece(true, 100);
            on_plan_piece(false, 50);
            drop(LevelSpan::enter(1));
            // nested scopes restore the outer trace
            let inner = Arc::new(QueryTrace::new());
            with_trace(inner.clone(), || on_claim());
            assert_eq!(inner.claims(), 1);
            on_claim();
        });
        assert!(current().is_none());
        assert_eq!(tr.dispatch_total(), 2);
        assert_eq!(tr.claims(), 2);
        assert_eq!(tr.steals(), 1);
        assert_eq!(tr.shard_claims(), 1);
        assert_eq!(tr.splits(), 1);
        assert_eq!(tr.lg_roots(), 1);
        assert_eq!(tr.excl_modes(), (1, 1));
        assert_eq!(tr.budget_charges(), 1);
        assert_eq!(tr.level_calls_total(), 1);
        assert_eq!(tr.plan_selected(), Some("decomposed"));
        assert_eq!(tr.plan_leaves(), 4);
        assert_eq!(tr.plan_pieces(), (1, 1));
        assert_eq!(tr.plan_piece_nanos(), 150);
    }

    #[test]
    fn profile_renders_one_json_line() {
        let tr = Arc::new(QueryTrace::new());
        with_trace(tr.clone(), || {
            on_dispatch(0);
            on_claim();
            drop(LevelSpan::enter(0));
            on_trip(CancelReason::Deadline);
            on_trip(CancelReason::Caller); // second trip loses the latch
        });
        tr.set_cache_verdict(CacheVerdict::Miss);
        tr.set_admission_wait(125);
        let p = tr.render();
        assert!(!p.contains('\n'));
        assert!(p.contains("\"level\":0"), "{p}");
        assert!(p.contains("\"merge\":1"), "{p}");
        assert!(p.contains("\"claims\":1"), "{p}");
        assert!(p.contains("\"trip\":\"deadline\""), "{p}");
        assert!(p.contains("\"cache\":\"miss\""), "{p}");
        assert!(p.contains("\"wait_nanos\":125"), "{p}");
        // no planner decision traced: explicit null, not absence
        assert!(p.contains("\"plan\":null"), "{p}");
    }

    #[test]
    fn profile_renders_plan_selection() {
        let tr = Arc::new(QueryTrace::new());
        with_trace(tr.clone(), || {
            on_plan_select(true, 2);
            on_plan_piece(true, 40);
            on_plan_piece(false, 2);
        });
        let p = tr.render();
        assert!(
            p.contains(
                "\"plan\":{\"kind\":\"decomposed\",\"leaves\":2,\"anchor_pieces\":1,\
                 \"formula_pieces\":1,\"piece_nanos\":42}"
            ),
            "{p}"
        );
        // the PR-9 smoke-grep anchors survive the insertion
        assert!(p.contains("\"levels\":["), "{p}");
        assert!(p.contains("\"dispatch\":{\"merge\":"), "{p}");
        assert!(p.contains("\"sched\":{\"claims\":"), "{p}");
    }
}
