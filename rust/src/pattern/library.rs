//! Pattern constructors and motif enumeration — the paper's "helper
//! functions to enumerate a clique or all patterns of a given size k"
//! (§3.1 footnote 2).

use super::canonical::{canonical_code, CanonCode};
use super::pgraph::Pattern;

/// Complete graph on `k` vertices.
pub fn clique(k: usize) -> Pattern {
    let mut p = Pattern::new(k);
    for u in 0..k {
        for v in (u + 1)..k {
            p.add_edge(u, v);
        }
    }
    p
}

/// The 3-clique.
pub fn triangle() -> Pattern {
    clique(3)
}

/// Simple path on `k` vertices.
pub fn path(k: usize) -> Pattern {
    let mut p = Pattern::new(k);
    for v in 1..k {
        p.add_edge(v - 1, v);
    }
    p
}

/// Path on 3 vertices (open triangle).
pub fn wedge() -> Pattern {
    path(3)
}

/// Simple cycle on `k` vertices.
pub fn cycle(k: usize) -> Pattern {
    let mut p = path(k);
    p.add_edge(k - 1, 0);
    p
}

/// Star with `leaves` leaves (center = vertex 0).
pub fn star(leaves: usize) -> Pattern {
    let mut p = Pattern::new(leaves + 1);
    for v in 1..=leaves {
        p.add_edge(0, v);
    }
    p
}

/// Diamond = K4 minus one edge.
pub fn diamond() -> Pattern {
    Pattern::from_edges(&[(0, 1), (0, 2), (1, 2), (1, 3), (2, 3)])
}

/// Tailed triangle = triangle with a pendant edge.
pub fn tailed_triangle() -> Pattern {
    Pattern::from_edges(&[(0, 1), (0, 2), (1, 2), (2, 3)])
}

/// All connected k-vertex motifs (vertex-induced patterns), one per
/// isomorphism class, enumerated by brute force over edge subsets and
/// deduplicated by canonical code. k=3 -> 2 motifs, k=4 -> 6, k=5 -> 21
/// (Fig. 1 of the paper shows the 3- and 4-vertex sets).
pub fn all_motifs(k: usize) -> Vec<Pattern> {
    assert!((2..=6).contains(&k));
    let pairs: Vec<(usize, usize)> = (0..k)
        .flat_map(|u| ((u + 1)..k).map(move |v| (u, v)))
        .collect();
    let mut seen: Vec<CanonCode> = Vec::new();
    let mut out = Vec::new();
    for mask in 0u32..(1 << pairs.len()) {
        let mut p = Pattern::new(k);
        for (i, &(u, v)) in pairs.iter().enumerate() {
            if mask >> i & 1 == 1 {
                p.add_edge(u, v);
            }
        }
        if !p.is_connected() {
            continue;
        }
        let code = canonical_code(&p);
        if !seen.contains(&code) {
            seen.push(code);
            out.push(p);
        }
    }
    // stable order: by edge count then code — gives deterministic motif ids
    let mut indexed: Vec<(usize, CanonCode, Pattern)> = out
        .into_iter()
        .map(|p| (p.num_edges(), canonical_code(&p), p))
        .collect();
    indexed.sort_by(|a, b| (a.0, &a.1).cmp(&(b.0, &b.1)));
    indexed.into_iter().map(|(_, _, p)| p).collect()
}

/// Human names for the 3-motifs in `all_motifs(3)` order.
pub const MOTIF3_NAMES: [&str; 2] = ["wedge", "triangle"];
/// Human names for the 4-motifs in `all_motifs(4)` order.
pub const MOTIF4_NAMES: [&str; 6] =
    ["3-star", "4-path", "tailed-triangle", "4-cycle", "diamond", "4-clique"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::canonical::isomorphic;

    #[test]
    fn motif_counts_match_theory() {
        assert_eq!(all_motifs(3).len(), 2);
        assert_eq!(all_motifs(4).len(), 6);
        assert_eq!(all_motifs(5).len(), 21);
    }

    #[test]
    fn motif3_order_is_wedge_triangle() {
        let m = all_motifs(3);
        assert!(isomorphic(&m[0], &wedge()));
        assert!(isomorphic(&m[1], &triangle()));
    }

    #[test]
    fn motif4_order_matches_names() {
        let m = all_motifs(4);
        assert!(isomorphic(&m[0], &star(3)));
        assert!(isomorphic(&m[1], &path(4)));
        assert!(isomorphic(&m[2], &tailed_triangle()));
        assert!(isomorphic(&m[3], &cycle(4)));
        assert!(isomorphic(&m[4], &diamond()));
        assert!(isomorphic(&m[5], &clique(4)));
    }

    #[test]
    fn constructors_have_expected_shape() {
        assert!(clique(5).is_clique());
        assert_eq!(cycle(5).num_edges(), 5);
        assert_eq!(star(4).num_vertices(), 5);
        assert_eq!(diamond().num_edges(), 5);
        assert_eq!(tailed_triangle().num_edges(), 4);
        assert_eq!(path(4).min_degree(), 1);
    }
}
