//! Canonical codes for small patterns.
//!
//! A canonical code is a total-order invariant of the isomorphism class:
//! two patterns are isomorphic iff their codes are equal. We use the
//! lexicographically-minimal (label-sequence, adjacency-bitstring) over
//! all vertex permutations, with degree/label partition pruning — cheap
//! for the ≤ 8-vertex patterns GPM mines, and exact. This implements the
//! paper's pattern classification fallback (Appendix B.5) and pattern
//! identity for FSM sub-pattern binning.

use super::pgraph::Pattern;

/// Canonical code: (n, labels in canonical order, upper-triangle
/// adjacency bits row-major).
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CanonCode {
    /// Number of vertices.
    pub n: u8,
    /// Vertex labels in canonical vertex order.
    pub labels: Vec<u32>,
    /// Upper-triangle adjacency bits, row-major.
    pub bits: u64,
}

/// Compute the canonical code by brute-force minimization over
/// permutations, pruned by sorting vertices into (label, degree) classes
/// first (only permutations within classes can be minimal).
pub fn canonical_code(p: &Pattern) -> CanonCode {
    canonical_form(p).0
}

/// Canonical code plus the minimizing permutation (perm[old] = canonical
/// position). Needed by FSM to align embedding mappings of isomorphic
/// children into a shared position space before binning.
pub fn canonical_form(p: &Pattern) -> (CanonCode, Vec<usize>) {
    let n = p.num_vertices();
    assert!(n <= 8, "canonical_code supports patterns up to 8 vertices");
    // group vertices by (label, degree) signature — the canonical order
    // must list signature groups in sorted order, so we only permute
    // within groups.
    let mut verts: Vec<usize> = (0..n).collect();
    verts.sort_by_key(|&v| (p.label(v), std::cmp::Reverse(p.degree(v)), v));

    let mut best: Option<(CanonCode, Vec<usize>)> = None;
    let mut perm: Vec<usize> = vec![0; n]; // perm[old] = new position
    permute_groups(p, &verts, 0, &mut perm, &mut best);
    best.unwrap()
}

fn signature(p: &Pattern, v: usize) -> (u32, std::cmp::Reverse<usize>) {
    (p.label(v), std::cmp::Reverse(p.degree(v)))
}

fn permute_groups(
    p: &Pattern,
    sorted: &[usize],
    pos: usize,
    perm: &mut Vec<usize>,
    best: &mut Option<(CanonCode, Vec<usize>)>,
) {
    let n = p.num_vertices();
    if pos == n {
        let code = encode(p, perm);
        if best.as_ref().map(|(b, _)| code < *b).unwrap_or(true) {
            *best = Some((code, perm.clone()));
        }
        return;
    }
    // find the signature group containing position `pos`
    let sig = signature(p, sorted[pos]);
    let group_end = (pos..n)
        .take_while(|&i| signature(p, sorted[i]) == sig)
        .last()
        .unwrap()
        + 1;
    // try every unused member of the group at position `pos`
    let mut members: Vec<usize> = sorted[pos..group_end].to_vec();
    heap_permutations(&mut members, &mut |order| {
        for (off, &v) in order.iter().enumerate() {
            perm[v] = pos + off;
        }
        permute_groups_rest(p, sorted, group_end, perm, best);
    });
}

fn permute_groups_rest(
    p: &Pattern,
    sorted: &[usize],
    pos: usize,
    perm: &mut Vec<usize>,
    best: &mut Option<(CanonCode, Vec<usize>)>,
) {
    permute_groups(p, sorted, pos, perm, best)
}

fn heap_permutations(xs: &mut Vec<usize>, f: &mut impl FnMut(&[usize])) {
    let n = xs.len();
    if n == 0 {
        f(xs);
        return;
    }
    let mut c = vec![0usize; n];
    f(xs);
    let mut i = 0;
    while i < n {
        if c[i] < i {
            if i % 2 == 0 {
                xs.swap(0, i);
            } else {
                xs.swap(c[i], i);
            }
            f(xs);
            c[i] += 1;
            i = 0;
        } else {
            c[i] = 0;
            i += 1;
        }
    }
}

fn encode(p: &Pattern, perm: &[usize]) -> CanonCode {
    let n = p.num_vertices();
    let mut inv = vec![0usize; n]; // inv[new] = old
    for old in 0..n {
        inv[perm[old]] = old;
    }
    let mut bits: u64 = 0;
    let mut bit = 0;
    for i in 0..n {
        for j in (i + 1)..n {
            if p.has_edge(inv[i], inv[j]) {
                bits |= 1 << bit;
            }
            bit += 1;
        }
    }
    CanonCode {
        n: n as u8,
        labels: (0..n).map(|i| p.label(inv[i])).collect(),
        bits,
    }
}

/// Graph isomorphism for small patterns, via canonical codes.
pub fn isomorphic(a: &Pattern, b: &Pattern) -> bool {
    a.num_vertices() == b.num_vertices()
        && a.num_edges() == b.num_edges()
        && canonical_code(a) == canonical_code(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relabeled_patterns_share_code() {
        let p = Pattern::from_edges(&[(0, 1), (1, 2), (2, 3)]); // path
        let q = Pattern::from_edges(&[(0, 2), (2, 1), (1, 3)]); // same path, renamed
        assert_eq!(canonical_code(&p), canonical_code(&q));
        assert!(isomorphic(&p, &q));
    }

    #[test]
    fn distinguishes_path_from_star() {
        let path = Pattern::from_edges(&[(0, 1), (1, 2), (2, 3)]);
        let star = Pattern::from_edges(&[(0, 1), (0, 2), (0, 3)]);
        assert!(!isomorphic(&path, &star));
    }

    #[test]
    fn distinguishes_by_labels() {
        let mut a = Pattern::from_edges(&[(0, 1)]);
        a.set_label(0, 1);
        a.set_label(1, 2);
        let mut b = Pattern::from_edges(&[(0, 1)]);
        b.set_label(0, 2);
        b.set_label(1, 1);
        // same structure, label multiset equal -> isomorphic as labeled graphs
        assert_eq!(canonical_code(&a), canonical_code(&b));
        let mut c = Pattern::from_edges(&[(0, 1)]);
        c.set_label(0, 1);
        c.set_label(1, 1);
        assert_ne!(canonical_code(&a), canonical_code(&c));
    }

    #[test]
    fn labeled_wedge_symmetry() {
        // wedge u-c-v: labels (1,9,2) and (2,9,1) are the same labeled
        // pattern; (1,9,1) differs.
        let mk = |lu, lc, lv| {
            let mut p = Pattern::from_edges(&[(0, 1), (1, 2)]);
            p.set_label(0, lu);
            p.set_label(1, lc);
            p.set_label(2, lv);
            p
        };
        assert_eq!(canonical_code(&mk(1, 9, 2)), canonical_code(&mk(2, 9, 1)));
        assert_ne!(canonical_code(&mk(1, 9, 2)), canonical_code(&mk(1, 9, 1)));
    }

    #[test]
    fn clique_code_is_all_ones() {
        let k4 = Pattern::from_edges(&[(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]);
        let code = canonical_code(&k4);
        assert_eq!(code.bits, 0b111111);
    }

    /// The library patterns the property tests below sweep: every 3- and
    /// 4-vertex motif class, plus 5-vertex shapes at both density
    /// extremes. This is the pattern population the PR-7 result cache
    /// keys on, so the two properties below are exactly its soundness
    /// (isomorphic ⇒ one key) and precision (non-isomorphic ⇒ distinct
    /// keys) obligations.
    fn cache_key_population() -> Vec<Pattern> {
        let mut pop = super::super::library::all_motifs(3);
        pop.extend(super::super::library::all_motifs(4));
        pop.push(super::super::library::clique(5));
        pop.push(super::super::library::cycle(5));
        pop.push(super::super::library::path(5));
        pop.push(super::super::library::star(4));
        pop
    }

    fn random_perm(rng: &mut crate::util::rng::Rng, n: usize) -> Vec<usize> {
        let mut perm: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut perm);
        perm
    }

    #[test]
    fn property_random_relabelings_share_one_code() {
        let mut rng = crate::util::rng::Rng::seeded(0x7c4);
        for (i, p) in cache_key_population().iter().enumerate() {
            let code = canonical_code(p);
            for round in 0..24 {
                let perm = random_perm(&mut rng, p.num_vertices());
                let q = p.permuted(&perm);
                assert_eq!(
                    canonical_code(&q),
                    code,
                    "pattern {i} round {round}: relabeling {perm:?} changed the code"
                );
                assert!(isomorphic(p, &q));
            }
        }
    }

    #[test]
    fn property_non_isomorphic_patterns_never_collide() {
        let pop = cache_key_population();
        let codes: Vec<CanonCode> = pop.iter().map(canonical_code).collect();
        for i in 0..pop.len() {
            for j in (i + 1)..pop.len() {
                assert_ne!(
                    codes[i], codes[j],
                    "patterns {i} and {j} collided: {} vs {}",
                    pop[i], pop[j]
                );
            }
        }
    }

    #[test]
    fn property_labeled_relabelings_share_one_code_and_labels_split_classes() {
        // label each population pattern two ways: uniformly (still one
        // class per shape) and with a distinguished vertex (which must
        // split the class from the uniform one)
        let mut rng = crate::util::rng::Rng::seeded(0x51a5);
        for p in cache_key_population() {
            let n = p.num_vertices();
            let mut uniform = p.clone();
            for v in 0..n {
                uniform.set_label(v, 7);
            }
            let mut marked = uniform.clone();
            marked.set_label(0, 9);
            let (u_code, m_code) = (canonical_code(&uniform), canonical_code(&marked));
            assert_ne!(u_code, m_code, "a distinguished label must split the class");
            for _ in 0..12 {
                let perm = random_perm(&mut rng, n);
                assert_eq!(canonical_code(&uniform.permuted(&perm)), u_code);
                // permuting relocates the mark with its vertex — still
                // the same labeled isomorphism class
                assert_eq!(canonical_code(&marked.permuted(&perm)), m_code);
            }
        }
    }
}
