//! Pattern analysis: representation, isomorphism/automorphism, symmetry
//! breaking, matching orders and canonical codes.

pub mod canonical;
pub mod decompose;
pub mod library;
pub mod matching_order;
pub mod pgraph;
pub mod symmetry;

pub use canonical::{canonical_code, isomorphic, CanonCode};
pub use decompose::{count_with_plan, motif_census, CountPlan};
pub use matching_order::{plan, MatchingPlan};
pub use pgraph::Pattern;
