//! Pattern-guided search plans (paper Appendix B.3 "Matching Order").
//!
//! For an explicit pattern, Sandslash analyzes the pattern once and emits
//! a `MatchingPlan`: the order in which pattern vertices are matched plus
//! per-level constraint masks (adjacency, induced non-adjacency, symmetry
//! partial orders, labels, degree bounds). The DFS engine interprets the
//! plan directly — this is the "Sandslash generates toExtend/toAdd
//! automatically for explicit-pattern problems" of Appendix B.4.
//!
//! Order selection is the paper's greedy: prefer placing vertices that
//! (1) participate in more symmetry-breaking partial orders with already
//! placed vertices, then (2) have more edges to placed vertices (denser
//! sub-pattern first).

use super::pgraph::Pattern;
use super::symmetry::symmetry_constraints;

#[derive(Clone, Debug)]
pub struct LevelPlan {
    /// Original pattern vertex matched at this position.
    pub pattern_vertex: usize,
    /// Positions j < i whose match must be adjacent to the candidate.
    pub adj_mask: u32,
    /// Positions j < i whose match must NOT be adjacent (vertex-induced).
    pub nonadj_mask: u32,
    /// Candidate id must be greater than matches at these positions.
    pub gt_mask: u32,
    /// Candidate id must be less than matches at these positions.
    pub lt_mask: u32,
    /// Position whose neighborhood the engine scans for candidates
    /// (must be set in `adj_mask`); position 0 has no pivot.
    pub pivot: usize,
    /// Required vertex label (0 when unlabeled).
    pub label: u32,
    /// Pattern degree of this vertex (degree-filtering bound).
    pub degree: usize,
}

#[derive(Clone, Debug)]
pub struct MatchingPlan {
    pub levels: Vec<LevelPlan>,
    pub vertex_induced: bool,
    /// True if symmetry-breaking constraints are included in the masks.
    pub sb: bool,
}

impl MatchingPlan {
    pub fn size(&self) -> usize {
        self.levels.len()
    }
}

/// Build a matching plan for `p`. `vertex_induced` adds non-adjacency
/// constraints; `sb` embeds symmetry-breaking partial orders.
pub fn plan(p: &Pattern, vertex_induced: bool, sb: bool) -> MatchingPlan {
    let n = p.num_vertices();
    assert!(n >= 1);
    let constraints = if sb { symmetry_constraints(p) } else { Vec::new() };

    // --- greedy order over original pattern vertices ---
    let mut order: Vec<usize> = Vec::with_capacity(n);
    let mut placed: u16 = 0;
    // first vertex: most constraints, then max degree, then min id
    let score0 = |v: usize| {
        let c = constraints.iter().filter(|&&(a, b)| a == v || b == v).count();
        (c, p.degree(v))
    };
    let first = (0..n).max_by_key(|&v| (score0(v), std::cmp::Reverse(v))).unwrap();
    order.push(first);
    placed |= 1 << first;
    while order.len() < n {
        let next = (0..n)
            .filter(|&v| placed >> v & 1 == 0)
            .filter(|&v| p.adj_mask(v) & placed != 0) // stay connected
            .max_by_key(|&v| {
                let cons = constraints
                    .iter()
                    .filter(|&&(a, b)| {
                        (a == v && placed >> b & 1 == 1) || (b == v && placed >> a & 1 == 1)
                    })
                    .count();
                let edges = (p.adj_mask(v) & placed).count_ones();
                (cons, edges, std::cmp::Reverse(v))
            })
            .expect("pattern must be connected");
        order.push(next);
        placed |= 1 << next;
    }

    // --- per-level constraint masks in position space ---
    let mut pos_of = vec![0usize; n];
    for (i, &v) in order.iter().enumerate() {
        pos_of[v] = i;
    }
    let levels = order
        .iter()
        .enumerate()
        .map(|(i, &v)| {
            let mut adj_mask = 0u32;
            let mut nonadj_mask = 0u32;
            for j in 0..i {
                let u = order[j];
                if p.has_edge(u, v) {
                    adj_mask |= 1 << j;
                } else if vertex_induced {
                    nonadj_mask |= 1 << j;
                }
            }
            let mut gt_mask = 0u32;
            let mut lt_mask = 0u32;
            for &(a, b) in &constraints {
                // constraint: match(a) < match(b)
                if b == v && pos_of[a] < i {
                    gt_mask |= 1 << pos_of[a];
                }
                if a == v && pos_of[b] < i {
                    lt_mask |= 1 << pos_of[b];
                }
            }
            // pivot: latest adjacent position (smallest expected frontier)
            let pivot = if adj_mask == 0 {
                0
            } else {
                31 - adj_mask.leading_zeros() as usize
            };
            LevelPlan {
                pattern_vertex: v,
                adj_mask,
                nonadj_mask,
                gt_mask,
                lt_mask,
                pivot,
                label: p.label(v),
                degree: p.degree(v),
            }
        })
        .collect();

    MatchingPlan { levels, vertex_induced, sb }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::library;

    #[test]
    fn triangle_plan_is_total_order() {
        let pl = plan(&library::triangle(), true, true);
        assert_eq!(pl.size(), 3);
        // every level after the first connects to all previous
        assert_eq!(pl.levels[1].adj_mask, 0b1);
        assert_eq!(pl.levels[2].adj_mask, 0b11);
        // symmetry fully broken: each new vertex > some previous
        assert!(pl.levels[1].gt_mask != 0);
        assert!(pl.levels[2].gt_mask != 0);
    }

    #[test]
    fn diamond_plan_matches_triangle_first() {
        // paper Fig. 12: the chosen order matches a triangle before the
        // 4th vertex (denser sub-pattern first).
        let pl = plan(&library::diamond(), true, true);
        let first3: Vec<usize> = pl.levels[..3].iter().map(|l| l.pattern_vertex).collect();
        // positions 1 and 2 of the diamond are the degree-3 chord vertices
        assert!(first3.contains(&1) && first3.contains(&2));
        // level 2 closes a triangle (adjacent to both previous)
        assert_eq!(pl.levels[2].adj_mask & 0b11, 0b11);
    }

    #[test]
    fn wedge_plan_nonadjacency() {
        let pl = plan(&library::wedge(), true, true);
        // the two endpoints are mutually non-adjacent in an induced wedge
        let last = &pl.levels[2];
        assert_ne!(last.nonadj_mask, 0);
        // endpoints are symmetric: a gt/lt constraint must exist somewhere
        assert!(pl.levels.iter().any(|l| l.gt_mask != 0 || l.lt_mask != 0));
    }

    #[test]
    fn edge_induced_plan_has_no_nonadjacency() {
        let pl = plan(&library::cycle(4), false, true);
        assert!(pl.levels.iter().all(|l| l.nonadj_mask == 0));
    }

    #[test]
    fn pivot_always_adjacent_and_prior() {
        for p in [library::clique(4), library::diamond(), library::cycle(4), library::star(3)] {
            let pl = plan(&p, true, true);
            for (i, l) in pl.levels.iter().enumerate().skip(1) {
                assert!(l.adj_mask >> l.pivot & 1 == 1, "{p} level {i}");
                assert!(l.pivot < i);
            }
        }
    }

    #[test]
    fn order_is_permutation() {
        for k in 3..=6 {
            let pl = plan(&library::clique(k), true, true);
            let mut vs: Vec<usize> = pl.levels.iter().map(|l| l.pattern_vertex).collect();
            vs.sort_unstable();
            assert_eq!(vs, (0..k).collect::<Vec<_>>());
        }
    }
}
