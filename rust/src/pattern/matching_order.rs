//! Pattern-guided search plans (paper Appendix B.3 "Matching Order").
//!
//! For an explicit pattern, Sandslash analyzes the pattern once and emits
//! a `MatchingPlan`: the order in which pattern vertices are matched plus
//! per-level constraint masks (adjacency, induced non-adjacency, symmetry
//! partial orders, labels, degree bounds). The DFS engine interprets the
//! plan directly — this is the "Sandslash generates toExtend/toAdd
//! automatically for explicit-pattern problems" of Appendix B.4.
//!
//! Order selection is the paper's greedy: prefer placing vertices that
//! (1) participate in more symmetry-breaking partial orders with already
//! placed vertices, then (2) have more edges to placed vertices (denser
//! sub-pattern first).

use super::pgraph::Pattern;
use super::symmetry::symmetry_constraints;

/// Per-level constraints of a [`MatchingPlan`], interpreted by the DFS
/// engine ([`crate::engine::dfs`]). All masks are bit-vectors over
/// *positions* (earlier levels of the plan), not pattern vertex ids.
#[derive(Clone, Debug)]
pub struct LevelPlan {
    /// Original pattern vertex matched at this position.
    pub pattern_vertex: usize,
    /// Positions j < i whose match must be adjacent to the candidate.
    pub adj_mask: u32,
    /// Positions j < i whose match must NOT be adjacent (vertex-induced).
    pub nonadj_mask: u32,
    /// Candidate id must be greater than matches at these positions.
    pub gt_mask: u32,
    /// Candidate id must be less than matches at these positions.
    pub lt_mask: u32,
    /// Position whose neighborhood the engine scans for candidates
    /// (must be set in `adj_mask`); position 0 has no pivot.
    pub pivot: usize,
    /// Required vertex label (0 when unlabeled).
    pub label: u32,
    /// Pattern degree of this vertex (degree-filtering bound).
    pub degree: usize,
    /// LG metadata: true when this position constrains *every* deeper
    /// level (`adj_mask_i` contains this position for all `i > pos`).
    /// Choosing a vertex at such a level lets the local-graph engine
    /// shrink the candidate universe kClist-style, because no future
    /// candidate can be non-adjacent to it.
    pub lg_cone: bool,
    /// LG metadata: positions `j < pos` whose *neighborhoods* seed the
    /// local-graph universe when the engine switches to LG at this
    /// level — the union of `adj_mask & (2^pos - 1)` over this and all
    /// deeper levels. Every future candidate is adjacent to at least
    /// one of these matched vertices iff `pos >= MatchingPlan::lg_level`.
    pub lg_pre_mask: u32,
    /// LG metadata: like [`LevelPlan::lg_pre_mask`] but including
    /// non-adjacency sources — the positions whose adjacency bit must be
    /// precomputed for universe members at LG init so anti-edge
    /// constraints resolve against local ids.
    pub lg_touch_mask: u32,
}

/// A compiled matching order: one [`LevelPlan`] per pattern vertex, in
/// the order the engine matches them.
#[derive(Clone, Debug)]
pub struct MatchingPlan {
    /// Per-position constraint sets, index = matching position.
    pub levels: Vec<LevelPlan>,
    /// True when non-adjacency constraints are included (vertex-induced
    /// semantics).
    pub vertex_induced: bool,
    /// True if symmetry-breaking constraints are included in the masks.
    pub sb: bool,
    /// Smallest position `L >= 1` such that every level `i >= L` has an
    /// adjacency constraint against some position `< L`. From this
    /// level on, the union of the matched vertices' neighborhoods
    /// covers every future candidate, so the engine may switch to
    /// shrinking local graphs ([`crate::engine::local_graph`]). Always
    /// `<= size() - 1` for a connected pattern with at least two
    /// vertices (the single-vertex plan keeps the initial sentinel 1,
    /// which the engine's remaining-levels guard never reaches).
    pub lg_level: usize,
}

impl MatchingPlan {
    /// Number of pattern vertices (= number of levels).
    pub fn size(&self) -> usize {
        self.levels.len()
    }
}

/// Build a matching plan for `p`. `vertex_induced` adds non-adjacency
/// constraints; `sb` embeds symmetry-breaking partial orders.
pub fn plan(p: &Pattern, vertex_induced: bool, sb: bool) -> MatchingPlan {
    let n = p.num_vertices();
    assert!(n >= 1);
    let constraints = if sb { symmetry_constraints(p) } else { Vec::new() };

    // --- greedy order over original pattern vertices ---
    let mut order: Vec<usize> = Vec::with_capacity(n);
    let mut placed: u16 = 0;
    // first vertex: most constraints, then max degree, then min id
    let score0 = |v: usize| {
        let c = constraints.iter().filter(|&&(a, b)| a == v || b == v).count();
        (c, p.degree(v))
    };
    let first = (0..n).max_by_key(|&v| (score0(v), std::cmp::Reverse(v))).unwrap();
    order.push(first);
    placed |= 1 << first;
    while order.len() < n {
        let next = (0..n)
            .filter(|&v| placed >> v & 1 == 0)
            .filter(|&v| p.adj_mask(v) & placed != 0) // stay connected
            .max_by_key(|&v| {
                let cons = constraints
                    .iter()
                    .filter(|&&(a, b)| {
                        (a == v && placed >> b & 1 == 1) || (b == v && placed >> a & 1 == 1)
                    })
                    .count();
                let edges = (p.adj_mask(v) & placed).count_ones();
                (cons, edges, std::cmp::Reverse(v))
            })
            .expect("pattern must be connected");
        order.push(next);
        placed |= 1 << next;
    }

    // --- per-level constraint masks in position space ---
    let mut pos_of = vec![0usize; n];
    for (i, &v) in order.iter().enumerate() {
        pos_of[v] = i;
    }
    let levels = order
        .iter()
        .enumerate()
        .map(|(i, &v)| {
            let mut adj_mask = 0u32;
            let mut nonadj_mask = 0u32;
            for j in 0..i {
                let u = order[j];
                if p.has_edge(u, v) {
                    adj_mask |= 1 << j;
                } else if vertex_induced {
                    nonadj_mask |= 1 << j;
                }
            }
            let mut gt_mask = 0u32;
            let mut lt_mask = 0u32;
            for &(a, b) in &constraints {
                // constraint: match(a) < match(b)
                if b == v && pos_of[a] < i {
                    gt_mask |= 1 << pos_of[a];
                }
                if a == v && pos_of[b] < i {
                    lt_mask |= 1 << pos_of[b];
                }
            }
            // pivot: latest adjacent position (smallest expected frontier)
            let pivot = if adj_mask == 0 {
                0
            } else {
                31 - adj_mask.leading_zeros() as usize
            };
            LevelPlan {
                pattern_vertex: v,
                adj_mask,
                nonadj_mask,
                gt_mask,
                lt_mask,
                pivot,
                label: p.label(v),
                degree: p.degree(v),
                lg_cone: false,     // filled below
                lg_pre_mask: 0,     // filled below
                lg_touch_mask: 0,   // filled below
            }
        })
        .collect();

    let mut plan = MatchingPlan { levels, vertex_induced, sb, lg_level: n.max(2) - 1 };
    fill_lg_metadata(&mut plan);
    plan
}

/// Derive the local-graph metadata from the finished masks: suffix
/// unions of (non-)adjacency sources per level, the cone flags, and the
/// earliest level at which the matched prefix's neighborhoods cover all
/// future candidates (see [`MatchingPlan::lg_level`]).
fn fill_lg_metadata(plan: &mut MatchingPlan) {
    let n = plan.levels.len();
    // suffix unions, restricted per level to already-matched positions
    let mut adj_union = 0u32;
    let mut touch_union = 0u32;
    for i in (0..n).rev() {
        adj_union |= plan.levels[i].adj_mask;
        touch_union |= plan.levels[i].adj_mask | plan.levels[i].nonadj_mask;
        let low = (1u32 << i) - 1;
        plan.levels[i].lg_pre_mask = adj_union & low;
        plan.levels[i].lg_touch_mask = touch_union & low;
    }
    // cone: position p constrains every deeper level
    for p in 0..n {
        plan.levels[p].lg_cone =
            ((p + 1)..n).all(|i| plan.levels[i].adj_mask >> p & 1 == 1);
    }
    // earliest coverage level: every level >= L touches a position < L.
    // Coverage is monotone in L, so the first satisfying L is minimal;
    // L = n-1 always qualifies for a connected pattern (adj_mask of the
    // last level is non-empty and within the first n-1 positions).
    for l in 1..n {
        let low = (1u32 << l) - 1;
        if (l..n).all(|i| plan.levels[i].adj_mask & low != 0) {
            plan.lg_level = l;
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::library;

    #[test]
    fn triangle_plan_is_total_order() {
        let pl = plan(&library::triangle(), true, true);
        assert_eq!(pl.size(), 3);
        // every level after the first connects to all previous
        assert_eq!(pl.levels[1].adj_mask, 0b1);
        assert_eq!(pl.levels[2].adj_mask, 0b11);
        // symmetry fully broken: each new vertex > some previous
        assert!(pl.levels[1].gt_mask != 0);
        assert!(pl.levels[2].gt_mask != 0);
    }

    #[test]
    fn diamond_plan_matches_triangle_first() {
        // paper Fig. 12: the chosen order matches a triangle before the
        // 4th vertex (denser sub-pattern first).
        let pl = plan(&library::diamond(), true, true);
        let first3: Vec<usize> = pl.levels[..3].iter().map(|l| l.pattern_vertex).collect();
        // positions 1 and 2 of the diamond are the degree-3 chord vertices
        assert!(first3.contains(&1) && first3.contains(&2));
        // level 2 closes a triangle (adjacent to both previous)
        assert_eq!(pl.levels[2].adj_mask & 0b11, 0b11);
    }

    #[test]
    fn wedge_plan_nonadjacency() {
        let pl = plan(&library::wedge(), true, true);
        // the two endpoints are mutually non-adjacent in an induced wedge
        let last = &pl.levels[2];
        assert_ne!(last.nonadj_mask, 0);
        // endpoints are symmetric: a gt/lt constraint must exist somewhere
        assert!(pl.levels.iter().any(|l| l.gt_mask != 0 || l.lt_mask != 0));
    }

    #[test]
    fn edge_induced_plan_has_no_nonadjacency() {
        let pl = plan(&library::cycle(4), false, true);
        assert!(pl.levels.iter().all(|l| l.nonadj_mask == 0));
    }

    #[test]
    fn pivot_always_adjacent_and_prior() {
        for p in [library::clique(4), library::diamond(), library::cycle(4), library::star(3)] {
            let pl = plan(&p, true, true);
            for (i, l) in pl.levels.iter().enumerate().skip(1) {
                assert!(l.adj_mask >> l.pivot & 1 == 1, "{p} level {i}");
                assert!(l.pivot < i);
            }
        }
    }

    #[test]
    fn lg_metadata_invariants() {
        for p in [
            library::clique(5),
            library::diamond(),
            library::cycle(4),
            library::cycle(5),
            library::wedge(),
            library::star(3),
            library::tailed_triangle(),
        ] {
            for vi in [true, false] {
                let pl = plan(&p, vi, true);
                let k = pl.size();
                // lg_level is a valid coverage point
                assert!(pl.lg_level >= 1 && pl.lg_level <= k.max(2) - 1, "{p}");
                let low = (1u32 << pl.lg_level) - 1;
                for i in pl.lg_level..k {
                    assert_ne!(pl.levels[i].adj_mask & low, 0, "{p} level {i}");
                }
                // cone flags match their definition
                for pos in 0..k {
                    let want = ((pos + 1)..k)
                        .all(|i| pl.levels[i].adj_mask >> pos & 1 == 1);
                    assert_eq!(pl.levels[pos].lg_cone, want, "{p} pos {pos}");
                }
                // pre/touch masks are the suffix source unions
                for l in 0..k {
                    let lowl = (1u32 << l) - 1;
                    let adj: u32 =
                        (l..k).fold(0, |m, i| m | pl.levels[i].adj_mask) & lowl;
                    let touch: u32 = (l..k).fold(0, |m, i| {
                        m | pl.levels[i].adj_mask | pl.levels[i].nonadj_mask
                    }) & lowl;
                    assert_eq!(pl.levels[l].lg_pre_mask, adj, "{p} level {l}");
                    assert_eq!(pl.levels[l].lg_touch_mask, touch, "{p} level {l}");
                }
            }
        }
    }

    #[test]
    fn lg_level_for_known_patterns() {
        // cliques: every level is adjacent to position 0 and every
        // position is a cone
        let pl = plan(&library::clique(5), true, true);
        assert_eq!(pl.lg_level, 1);
        assert!(pl.levels.iter().all(|l| l.lg_cone));
        // diamond: triangle matched first, position 0 in every mask
        let pl = plan(&library::diamond(), true, true);
        assert_eq!(pl.lg_level, 1);
        // 4-cycle: the last level is adjacent only to positions 1 and 2,
        // so coverage begins at level 2
        let pl = plan(&library::cycle(4), true, true);
        assert_eq!(pl.lg_level, 2);
        // the two path-interior positions cannot both constrain all
        // future levels in a 4-cycle
        assert!(!(pl.levels[0].lg_cone && pl.levels[1].lg_cone));
    }

    #[test]
    fn order_is_permutation() {
        for k in 3..=6 {
            let pl = plan(&library::clique(k), true, true);
            let mut vs: Vec<usize> = pl.levels.iter().map(|l| l.pattern_vertex).collect();
            vs.sort_unstable();
            assert_eq!(vs, (0..k).collect::<Vec<_>>());
        }
    }
}
