//! Automorphism enumeration and symmetry breaking (paper Appendix B.1).
//!
//! Overcounting of automorphic embeddings is prevented by imposing
//! partial orders between the data vertices matched at symmetric pattern
//! positions (Grochow–Kellis style): repeatedly pick a vertex with a
//! non-trivial orbit under the remaining automorphism group, constrain it
//! to be the minimum of its orbit, and restrict to its stabilizer. The
//! result is a set of `(a, b)` constraints meaning `id(match(a)) <
//! id(match(b))`, under which every embedding is enumerated exactly once.

use super::pgraph::Pattern;

/// All automorphisms of the pattern (as permutations perm[old] = new),
/// enumerated by backtracking with label/degree pruning.
pub fn automorphisms(p: &Pattern) -> Vec<Vec<usize>> {
    let n = p.num_vertices();
    let mut out = Vec::new();
    let mut perm = vec![usize::MAX; n];
    let mut used: u16 = 0;
    backtrack(p, 0, &mut perm, &mut used, &mut out);
    out
}

fn backtrack(
    p: &Pattern,
    v: usize,
    perm: &mut Vec<usize>,
    used: &mut u16,
    out: &mut Vec<Vec<usize>>,
) {
    let n = p.num_vertices();
    if v == n {
        out.push(perm.clone());
        return;
    }
    for img in 0..n {
        if *used >> img & 1 == 1 {
            continue;
        }
        if p.label(img) != p.label(v) || p.degree(img) != p.degree(v) {
            continue;
        }
        // adjacency to already-mapped vertices must be preserved
        let ok = (0..v).all(|u| p.has_edge(u, v) == p.has_edge(perm[u], img));
        if !ok {
            continue;
        }
        perm[v] = img;
        *used |= 1 << img;
        backtrack(p, v + 1, perm, used, out);
        *used &= !(1 << img);
        perm[v] = usize::MAX;
    }
}

/// Number of automorphisms (the multiplicity each unordered embedding
/// would be counted with if symmetry breaking were off — used by the
/// AutoMine-like emulation to divide at the end).
pub fn automorphism_count(p: &Pattern) -> u64 {
    automorphisms(p).len() as u64
}

/// Symmetry-breaking partial order: pairs (a, b) meaning the data vertex
/// matched at pattern vertex `a` must have smaller id than at `b`.
pub fn symmetry_constraints(p: &Pattern) -> Vec<(usize, usize)> {
    let n = p.num_vertices();
    let mut group = automorphisms(p);
    let mut constraints = Vec::new();
    for v in 0..n {
        if group.len() <= 1 {
            break;
        }
        // orbit of v under the remaining group
        let mut orbit: Vec<usize> = group.iter().map(|g| g[v]).collect();
        orbit.sort_unstable();
        orbit.dedup();
        for &u in &orbit {
            if u != v {
                constraints.push((v, u));
            }
        }
        // restrict to the stabilizer of v
        group.retain(|g| g[v] == v);
    }
    constraints
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pattern::library;

    #[test]
    fn triangle_has_six_automorphisms() {
        assert_eq!(automorphism_count(&library::clique(3)), 6);
    }

    #[test]
    fn k4_has_24() {
        assert_eq!(automorphism_count(&library::clique(4)), 24);
    }

    #[test]
    fn path3_has_two() {
        // path 0-1-2: identity and the flip
        assert_eq!(automorphism_count(&library::path(3)), 2);
    }

    #[test]
    fn star_automorphisms() {
        // star with 3 leaves: 3! = 6
        assert_eq!(automorphism_count(&library::star(3)), 6);
    }

    #[test]
    fn cycle4_has_eight() {
        assert_eq!(automorphism_count(&library::cycle(4)), 8); // dihedral D4
    }

    #[test]
    fn labels_restrict_automorphisms() {
        let mut p = library::clique(3);
        p.set_label(0, 7);
        assert_eq!(automorphism_count(&p), 2); // only 1<->2 swap remains
    }

    #[test]
    fn clique_constraints_form_total_order() {
        let cs = symmetry_constraints(&library::clique(4));
        // breaking all of S4 yields a chain 0<1<2<3 (6 pairwise constraints
        // when expressed transitively; our greedy emits orbits per level)
        assert!(cs.contains(&(0, 1)) && cs.contains(&(0, 2)) && cs.contains(&(0, 3)));
        assert!(cs.contains(&(1, 2)) && cs.contains(&(1, 3)));
        assert!(cs.contains(&(2, 3)));
    }

    #[test]
    fn wedge_constraints_break_endpoint_swap() {
        // wedge 0-1, 1-2: symmetric endpoints 0 and 2
        let cs = symmetry_constraints(&library::path(3));
        assert_eq!(cs, vec![(0, 2)]);
    }

    #[test]
    fn constraint_count_equals_enumeration_reduction() {
        // property: for any pattern, constraints leave exactly one
        // representative per automorphism class of vertex orderings.
        for p in [library::clique(3), library::cycle(4), library::diamond(), library::star(3)] {
            let cs = symmetry_constraints(&p);
            let n = p.num_vertices();
            let mut count = 0u64;
            // count permutations of 0..n (as "data ids") satisfying constraints
            let mut perm: Vec<usize> = (0..n).collect();
            loop {
                if cs.iter().all(|&(a, b)| perm[a] < perm[b]) {
                    count += 1;
                }
                if !next_permutation(&mut perm) {
                    break;
                }
            }
            let auts = automorphism_count(&p);
            let fact: u64 = (1..=n as u64).product();
            assert_eq!(count, fact / auts, "pattern {p}");
        }
    }

    fn next_permutation(p: &mut [usize]) -> bool {
        let n = p.len();
        if n < 2 {
            return false;
        }
        let mut i = n - 1;
        while i > 0 && p[i - 1] >= p[i] {
            i -= 1;
        }
        if i == 0 {
            return false;
        }
        let mut j = n - 1;
        while p[j] <= p[i - 1] {
            j -= 1;
        }
        p.swap(i - 1, j);
        p[i..].reverse();
        true
    }
}
