//! Decomposition counting planner (PR 10): algebraic motif counting
//! without per-embedding enumeration.
//!
//! For a *count-only* query (the `NoHooks` / `HookKind::Count`
//! boundary — nothing observes individual embeddings), enumerating
//! every embedding is the wrong asymptotic: DwarvesGraph
//! (arXiv 2008.09682) and PGD show that a pattern can be decomposed
//! into small **anchor pieces** (cliques, cycles) that are cheap to
//! enumerate plus **formula leaves** (per-vertex / per-edge degree
//! reductions) whose combination recovers the exact count. This module
//! is the planner: [`decompose`] searches the known decomposition
//! space with a cost model and emits a [`CountPlan`]; [`execute`] runs
//! the plan's leaves — closed-form [`parallel_reduce`] scans and small
//! *governed* [`dfs::count`](crate::engine::dfs::count) runs over the
//! existing set kernels — and combines them with inclusion–exclusion
//! coefficients **derived, not hard-coded**: the coefficient of motif
//! `M` in a formula leaf `F` is the number of `F`-configurations
//! inside `M`, counted on the ≤16-vertex [`Pattern`] itself
//! ([`formula_on_pattern`]), with anchor enumeration symmetry handled
//! by [`automorphism_count`]. The PGD constants of
//! [`crate::apps::motif::motif4_lo`] fall out as a special case (the
//! unit tests assert exactly that), and `motif4_lo` / the PGD baseline
//! remain as independent hand-derived oracles.
//!
//! Kill-switch discipline (PR 1..9): the planner is a default-on
//! [`OptFlags::plan`](crate::engine::OptFlags::plan) stage gated by
//! the process-wide `SANDSLASH_NO_PLAN=1` switch
//! ([`plan_enabled_default`]), and the enumerated path — the exact
//! seed `plan(p) + dfs::count` run — is both the fallback for
//! unsupported patterns and the differential oracle
//! (`rust/tests/plan_differential.rs`): plan-vs-enumerate answers are
//! bit-identical, which is what keeps the service's canonical-code
//! result cache plan-agnostic.
//!
//! Governance: anchor leaves ride the governed DFS engine, so a
//! deadline / task-budget trip mid-plan surfaces as a *partial*
//! [`Outcome`] (`complete == false`, value clamped best-effort — the
//! algebra is unsound on a partial anchor, so the value is a debris
//! count, exactly like any tripped enumeration partial) and the
//! service's code-0 gate keeps it out of the result cache. Remaining
//! leaves are skipped once a trip latches.

use std::sync::OnceLock;
use std::time::Instant;

use crate::engine::budget::{CancelReason, MineError, Outcome};
use crate::engine::dfs;
use crate::engine::hooks::NoHooks;
use crate::engine::MinerConfig;
use crate::graph::CsrGraph;
use crate::obs::trace;
use crate::util::metrics::SearchStats;
use crate::util::pool::parallel_reduce;

use super::canonical::canonical_code;
use super::library;
use super::matching_order;
use super::pgraph::Pattern;
use super::symmetry::automorphism_count;

/// Whether the decomposition planner is enabled for this process:
/// `true` unless `SANDSLASH_NO_PLAN` is set non-empty and non-zero.
/// Cached after the first read (like
/// [`crate::engine::extend::extcore_enabled_default`]), so the kill
/// switch is a process-start decision, not a per-query race.
pub fn plan_enabled_default() -> bool {
    static CACHE: OnceLock<bool> = OnceLock::new();
    *CACHE.get_or_init(|| {
        !std::env::var("SANDSLASH_NO_PLAN")
            .is_ok_and(|v| !v.trim().is_empty() && v.trim() != "0")
    })
}

// ---------------------------------------------------------------- leaves

/// A closed-form formula leaf: one `parallel_reduce` scan whose value,
/// evaluated on the data graph, is a known linear combination of
/// induced motif counts (coefficients via [`formula_on_pattern`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Formula {
    /// `Σ_v C(deg v, k)` — one pass over vertices. Counts every
    /// k-star subgraph once (raw/non-induced k-star count).
    VertexComb(usize),
    /// `Σ_e C(tri_e, 2)` where `tri_e = |N(u) ∩ N(v)|` — one pass over
    /// edges. Counts every diamond subgraph once (by its hinge edge).
    EdgeTriPairs,
    /// `Σ_e tri_e·(s_u + s_v)` with `s_u = deg u − tri_e − 1` — counts
    /// tailed-triangle configurations (edge + one common + one
    /// exclusive neighbor).
    EdgeTriSides,
    /// `Σ_e s_u·s_v` — counts 4-path configurations centered on an
    /// edge (one exclusive neighbor on each side).
    EdgeSideProduct,
}

/// `C(d, k)` with a u128 intermediate (hub degrees in scale-free
/// inputs make the falling factorial overflow u64 well before the
/// count itself does).
fn binom(d: u64, k: usize) -> u64 {
    if (d as usize) < k {
        return 0;
    }
    let mut num: u128 = 1;
    for i in 0..k as u128 {
        num *= d as u128 - i;
    }
    let fact: u128 = (1..=k as u128).product();
    (num / fact) as u64
}

/// Shared formula leaf `Σ_v C(deg v, k)`: the *one* implementation of
/// the per-vertex degree reduction, used by the planner, by
/// `motif3_lo`/`motif4_lo` and by the PGD baseline (PR 10 rebased the
/// hand-rolled copies onto this).
pub fn vertex_comb_sum(g: &CsrGraph, cfg: &MinerConfig, k: usize) -> u64 {
    parallel_reduce(
        g.num_vertices(),
        cfg.threads,
        cfg.chunk,
        || 0u64,
        |acc, v| {
            *acc += binom(g.degree(v as u32) as u64, k);
        },
        |a, b| a + b,
    )
}

/// Shared formula leaves over one edge pass: returns
/// `(Σ C(tri_e,2), Σ tri_e(s_u+s_v), Σ s_u·s_v)` — the body of the
/// paper's Listing 3, computed once for all three edge formulas.
pub fn edge_local_counts(g: &CsrGraph, cfg: &MinerConfig) -> (u64, u64, u64) {
    let edges: Vec<(u32, u32)> = g.edges().collect();
    parallel_reduce(
        edges.len(),
        cfg.threads,
        cfg.chunk,
        || (0u64, 0u64, 0u64),
        |acc, i| {
            let (u, v) = edges[i];
            let tri = g.intersect_count(u, v) as u64;
            let su = g.degree(u) as u64 - tri - 1;
            let sv = g.degree(v) as u64 - tri - 1;
            acc.0 += tri.saturating_sub(1) * tri / 2;
            acc.1 += tri * (su + sv);
            acc.2 += su * sv;
        },
        |a, b| (a.0 + b.0, a.1 + b.1, a.2 + b.2),
    )
}

/// Evaluate a formula leaf *on a pattern*: the number of
/// `f`-configurations inside `m`. Because a formula's graph-side value
/// is `Σ_{M} formula_on_pattern(f, M) · induced_count(M)` over the
/// same-size motifs, these are precisely the inclusion–exclusion
/// coefficients of the decomposition — derived from the pattern's own
/// adjacency structure instead of transcribed from PGD.
pub fn formula_on_pattern(f: Formula, m: &Pattern) -> u64 {
    let n = m.num_vertices();
    match f {
        Formula::VertexComb(k) => {
            (0..n).map(|v| binom(m.degree(v) as u64, k)).sum()
        }
        Formula::EdgeTriPairs | Formula::EdgeTriSides | Formula::EdgeSideProduct => {
            let mut total = 0u64;
            for (u, v) in m.edges() {
                let tri = (m.adj_mask(u) & m.adj_mask(v)).count_ones() as u64;
                let su = m.degree(u) as u64 - tri - 1;
                let sv = m.degree(v) as u64 - tri - 1;
                total += match f {
                    Formula::EdgeTriPairs => tri.saturating_sub(1) * tri / 2,
                    Formula::EdgeTriSides => tri * (su + sv),
                    Formula::EdgeSideProduct => su * sv,
                    Formula::VertexComb(_) => unreachable!(),
                };
            }
            total
        }
    }
}

/// The coefficient vector of `f` against a motif family: entry `i` is
/// the number of `f`-configurations inside `motifs[i]`.
pub fn overlap_coeffs(f: Formula, motifs: &[Pattern]) -> Vec<u64> {
    motifs.iter().map(|m| formula_on_pattern(f, m)).collect()
}

// ---------------------------------------------------------------- plans

/// Indices of the anchor motifs in `all_motifs(4)` order.
const M4_CYCLE: usize = 3;
const M4_CLIQUE: usize = 5;

/// The formula that solves each non-anchor index of `all_motifs(4)`.
fn motif4_formula(idx: usize) -> Formula {
    match idx {
        0 => Formula::VertexComb(3),    // 3-star
        1 => Formula::EdgeSideProduct,  // 4-path
        2 => Formula::EdgeTriSides,     // tailed-triangle
        4 => Formula::EdgeTriPairs,     // diamond
        _ => unreachable!("motif4 index {idx} is an anchor, not a formula target"),
    }
}

/// How a [`CountPlan`] computes its count.
#[derive(Clone, Debug, PartialEq, Eq)]
enum Target {
    /// The enumerated oracle: `plan(p) + dfs::count`, bit-identical to
    /// the pre-PR-10 path (also the kill-switch route).
    Direct,
    /// Induced wedge: `Σ_v C(d,2) − 3·T` with a triangle anchor
    /// (coefficient 3 derived over `all_motifs(3)`).
    WedgeInduced,
    /// Raw (non-induced) k-star: `Σ_v C(d, leaves)` — no anchor at all.
    StarRaw(usize),
    /// Raw (non-induced) diamond: `Σ_e C(tri_e, 2)` — no anchor.
    DiamondRaw,
    /// Induced 4-motif at this `all_motifs(4)` index, solved by the
    /// memoized anchor+formula system of [`Ctx::induced_motif4`].
    Induced4(usize),
}

/// A counting plan for one pattern: either the enumerated oracle
/// (`Direct`) or a decomposition into formula and anchor leaves. Built
/// by [`decompose`], run by [`execute`].
#[derive(Clone, Debug)]
pub struct CountPlan {
    pattern: Pattern,
    vertex_induced: bool,
    target: Target,
    /// Estimated cost of the chosen route (cost-model units; the
    /// losing candidates' estimates are not retained).
    est_cost: f64,
    /// Number of leaves (scans + anchors) the plan will execute.
    leaves: usize,
}

impl CountPlan {
    /// Whether the planner found (and the cost model chose) a genuine
    /// decomposition; `false` means the enumerated oracle runs.
    pub fn decomposed(&self) -> bool {
        self.target != Target::Direct
    }

    /// Number of leaves (formula scans + anchor enumerations) the plan
    /// executes; 1 for the direct route.
    pub fn leaves(&self) -> usize {
        self.leaves
    }

    /// The cost-model estimate of the chosen route (arbitrary units;
    /// comparable only across candidates for the same query).
    pub fn est_cost(&self) -> f64 {
        self.est_cost
    }

    /// Short human label of the chosen decomposition (trace/debug).
    pub fn describe(&self) -> &'static str {
        match self.target {
            Target::Direct => "direct",
            Target::WedgeInduced => "wedge:vertex-comb-minus-triangles",
            Target::StarRaw(_) => "star:vertex-comb",
            Target::DiamondRaw => "diamond:edge-tri-pairs",
            Target::Induced4(0) => "induced4:3-star",
            Target::Induced4(1) => "induced4:4-path",
            Target::Induced4(2) => "induced4:tailed-triangle",
            Target::Induced4(_) => "induced4",
        }
    }
}

/// Rough per-route cost model (documented in EXPERIMENTS.md §PR-10).
/// Enumerating pattern `q` explores ≈ `m · d̄^(k−2)` partial
/// embeddings, divided by `|Aut(q)|` for the symmetry-broken DFS; a
/// vertex formula costs one `n` scan, an edge formula one `m · d̄`
/// pass (an intersection per edge).
struct CostModel {
    n: f64,
    m: f64,
    davg: f64,
}

impl CostModel {
    fn of(g: &CsrGraph) -> Self {
        let n = g.num_vertices().max(1) as f64;
        let m = g.num_undirected_edges().max(1) as f64;
        Self { n, m, davg: (2.0 * m / n).max(1.0) }
    }

    fn enumerate(&self, q: &Pattern) -> f64 {
        let k = q.num_vertices().max(2) as i32;
        self.m * self.davg.powi(k - 2) / automorphism_count(q) as f64
    }

    fn vertex_pass(&self) -> f64 {
        self.n
    }

    fn edge_pass(&self) -> f64 {
        self.m * self.davg
    }

    fn target(&self, t: &Target, p: &Pattern) -> f64 {
        match t {
            Target::Direct => self.enumerate(p),
            Target::WedgeInduced => {
                self.vertex_pass() + self.enumerate(&library::triangle())
            }
            Target::StarRaw(_) => self.vertex_pass(),
            Target::DiamondRaw => self.edge_pass(),
            // the solve's transitive pieces, deduplicated: the edge
            // pass is shared by every edge formula, the 4-clique
            // anchor by diamond/tailed-triangle/3-star, the 4-cycle
            // anchor by the 4-path
            Target::Induced4(0) => {
                self.vertex_pass() + self.edge_pass() + self.enumerate(&library::clique(4))
            }
            Target::Induced4(1) => self.edge_pass() + self.enumerate(&library::cycle(4)),
            Target::Induced4(_) => self.edge_pass() + self.enumerate(&library::clique(4)),
        }
    }
}

fn leaves_of(t: &Target) -> usize {
    match t {
        Target::Direct => 1,
        Target::WedgeInduced => 2,           // vertex pass + triangle anchor
        Target::StarRaw(_) | Target::DiamondRaw => 1,
        Target::Induced4(0) => 3,            // vertex pass + edge pass + K4
        Target::Induced4(_) => 2,            // edge pass + anchor
    }
}

/// Search the decomposition space for `p` and pick the cheapest route
/// under the [`CostModel`] built from `g`'s summary statistics. The
/// candidate set is the known algebraic identities applicable to this
/// pattern (matched by canonical code) plus the enumerated oracle;
/// unsupported patterns — labeled patterns, 5-vertex motifs, raw-mode
/// patterns without a raw identity — always plan `Direct`, so the
/// planner is total and bit-identical by construction.
pub fn decompose(p: &Pattern, vertex_induced: bool, g: &CsrGraph) -> CountPlan {
    let recipe = recipe_for(p, vertex_induced);
    let model = CostModel::of(g);
    let direct_cost = model.target(&Target::Direct, p);
    let (target, est_cost) = match recipe {
        Some(t) => {
            let c = model.target(&t, p);
            if c < direct_cost {
                (t, c)
            } else {
                (Target::Direct, direct_cost)
            }
        }
        None => (Target::Direct, direct_cost),
    };
    let leaves = leaves_of(&target);
    CountPlan { pattern: p.clone(), vertex_induced, target, est_cost, leaves }
}

/// The algebraic identity applicable to `p` in the requested counting
/// mode, if any.
fn recipe_for(p: &Pattern, vertex_induced: bool) -> Option<Target> {
    if p.is_labeled() || p.num_vertices() < 3 {
        return None;
    }
    let code = canonical_code(p);
    let k = p.num_vertices();
    if k == 3 && code == canonical_code(&library::wedge()) {
        // the raw wedge count is the same vertex scan with no anchor:
        // Σ C(d,2) counts every wedge subgraph exactly once
        return Some(if vertex_induced { Target::WedgeInduced } else { Target::StarRaw(2) });
    }
    if k == 4 {
        let motifs = library::all_motifs(4);
        let idx = motifs.iter().position(|m| canonical_code(m) == code)?;
        return match (idx, vertex_induced) {
            // anchors are their own cheapest enumeration
            (M4_CYCLE | M4_CLIQUE, _) => None,
            (_, true) => Some(Target::Induced4(idx)),
            // raw mode: only the anchor-free identities apply
            (0, false) => Some(Target::StarRaw(3)),
            (4, false) => Some(Target::DiamondRaw),
            _ => None,
        };
    }
    // larger stars keep their raw closed form at any size
    if !vertex_induced && is_star(p) {
        return Some(Target::StarRaw(k - 1));
    }
    None
}

fn is_star(p: &Pattern) -> bool {
    let k = p.num_vertices();
    k >= 3
        && p.num_edges() == k - 1
        && (0..k).any(|c| p.degree(c) == k - 1)
}

// ------------------------------------------------------------- execution

/// Shared execution state: memoized pieces, merged engine stats, and
/// the first governance trip (which latches and short-circuits every
/// later leaf).
struct Ctx<'a> {
    g: &'a CsrGraph,
    cfg: &'a MinerConfig,
    stats: SearchStats,
    tripped: Option<CancelReason>,
    edge_locals: Option<(u64, u64, u64)>,
    motif4: [Option<u64>; 6],
}

impl<'a> Ctx<'a> {
    fn new(g: &'a CsrGraph, cfg: &'a MinerConfig) -> Self {
        Ctx {
            g,
            cfg,
            stats: SearchStats::default(),
            tripped: None,
            edge_locals: None,
            motif4: [None; 6],
        }
    }

    /// Enumerate one anchor pattern through the governed DFS engine
    /// (vertex-induced, symmetry-broken — exact-once counts).
    fn anchor(&mut self, p: &Pattern) -> Result<u64, MineError> {
        if self.tripped.is_some() {
            return Ok(0);
        }
        let t0 = Instant::now();
        let pl = matching_order::plan(p, true, true);
        let out = dfs::count(self.g, &pl, self.cfg, &NoHooks)?;
        trace::on_plan_piece(true, t0.elapsed().as_nanos() as u64);
        self.stats.merge(&out.stats);
        if let Some(reason) = out.tripped {
            self.tripped = Some(reason);
        }
        Ok(out.value)
    }

    /// Evaluate one formula leaf on the data graph (memoizing the
    /// shared edge pass). Skipped — returns 0 — once a trip latched.
    fn formula(&mut self, f: Formula) -> u64 {
        if self.tripped.is_some() {
            return 0;
        }
        match f {
            Formula::VertexComb(k) => {
                let t0 = Instant::now();
                let v = vertex_comb_sum(self.g, self.cfg, k);
                trace::on_plan_piece(false, t0.elapsed().as_nanos() as u64);
                v
            }
            _ => {
                let (a, b, c) = self.edge_locals();
                match f {
                    Formula::EdgeTriPairs => a,
                    Formula::EdgeTriSides => b,
                    Formula::EdgeSideProduct => c,
                    Formula::VertexComb(_) => unreachable!(),
                }
            }
        }
    }

    fn edge_locals(&mut self) -> (u64, u64, u64) {
        if let Some(t) = self.edge_locals {
            return t;
        }
        let t0 = Instant::now();
        let t = edge_local_counts(self.g, self.cfg);
        trace::on_plan_piece(false, t0.elapsed().as_nanos() as u64);
        // the edge pass is one intersection per undirected edge
        self.stats.intersections += self.g.num_undirected_edges() as u64;
        self.edge_locals = Some(t);
        t
    }

    /// The induced count of `motifs[idx]` (all_motifs(4) order),
    /// memoized: anchors (4-cycle, 4-clique) enumerate, every other
    /// index solves its formula leaf against the already-known motifs
    /// with derived coefficients. Dependencies recurse (they form a
    /// DAG: diamond → K4, tailed-triangle → diamond, 4-path → C4,
    /// 3-star → {TT, diamond, K4}).
    fn induced_motif4(
        &mut self,
        motifs: &[Pattern],
        idx: usize,
        depth: usize,
    ) -> Result<u64, MineError> {
        assert!(depth < 8, "decomposition dependency recursion runaway");
        if let Some(v) = self.motif4[idx] {
            return Ok(v);
        }
        let v = match idx {
            M4_CLIQUE => self.anchor(&library::clique(4))?,
            M4_CYCLE => self.anchor(&library::cycle(4))?,
            _ => {
                let f = motif4_formula(idx);
                let coeffs = overlap_coeffs(f, motifs);
                debug_assert!(coeffs[idx] > 0, "formula must see its own target");
                // dependencies first (anchors trip fast under a blown
                // deadline; the formula scan then short-circuits)
                let mut acc: i128 = 0;
                for (j, &cj) in coeffs.iter().enumerate() {
                    if j != idx && cj > 0 {
                        let known = self.induced_motif4(motifs, j, depth + 1)?;
                        acc -= cj as i128 * known as i128;
                    }
                }
                acc += self.formula(f) as i128;
                finish_div(acc, coeffs[idx], self.tripped.is_some())
            }
        };
        self.motif4[idx] = Some(v);
        Ok(v)
    }

    fn outcome<T>(self, value: T) -> Outcome<T> {
        match self.tripped {
            None => Outcome::complete(value, self.stats),
            Some(reason) => Outcome::partial(value, self.stats, reason),
        }
    }
}

/// Close an inclusion–exclusion solve: on a complete run the
/// remainder must divide exactly and be non-negative (the identities
/// are theorems — a violation is an engine bug, so it asserts); on a
/// tripped partial the debris is clamped into range.
fn finish_div(acc: i128, divisor: u64, partial: bool) -> u64 {
    let d = divisor as i128;
    if partial {
        return acc.div_euclid(d).max(0) as u64;
    }
    assert!(
        acc >= 0 && acc % d == 0,
        "inclusion–exclusion solve left remainder {acc} (divisor {d}): \
         anchor/formula disagreement"
    );
    (acc / d) as u64
}

/// Run a [`CountPlan`]. Direct plans are bit-identical to the seed
/// `plan(p) + dfs::count` path; decomposed plans combine their leaves
/// and forward the governed [`Outcome`] contract (a tripped anchor
/// yields `complete == false`).
pub fn execute(
    g: &CsrGraph,
    plan: &CountPlan,
    cfg: &MinerConfig,
) -> Result<Outcome<u64>, MineError> {
    trace::on_plan_select(plan.decomposed(), plan.leaves as u64);
    let mut ctx = Ctx::new(g, cfg);
    let value = match &plan.target {
        Target::Direct => {
            let pl = matching_order::plan(&plan.pattern, plan.vertex_induced, true);
            return dfs::count(g, &pl, cfg, &NoHooks);
        }
        Target::WedgeInduced => {
            let motifs = library::all_motifs(3);
            let coeffs = overlap_coeffs(Formula::VertexComb(2), &motifs);
            let t = ctx.anchor(&library::triangle())?;
            let acc = ctx.formula(Formula::VertexComb(2)) as i128 - coeffs[1] as i128 * t as i128;
            finish_div(acc, coeffs[0], ctx.tripped.is_some())
        }
        Target::StarRaw(leaves) => ctx.formula(Formula::VertexComb(*leaves)),
        Target::DiamondRaw => ctx.formula(Formula::EdgeTriPairs),
        Target::Induced4(idx) => {
            let motifs = library::all_motifs(4);
            ctx.induced_motif4(&motifs, *idx, 0)?
        }
    };
    Ok(ctx.outcome(value))
}

/// Count `p` in `g`, planner-fronted: the PR-10 entry point for every
/// count-only query. With the stage inactive
/// ([`OptFlags::plan_active`](crate::engine::OptFlags::plan_active)
/// false — per-run opt-out or `SANDSLASH_NO_PLAN=1`) this **is** the
/// seed enumerated path, byte for byte; otherwise [`decompose`] picks
/// a route and [`execute`] runs it, with the same `Result<Outcome>`
/// governance contract either way.
pub fn count_with_plan(
    g: &CsrGraph,
    p: &Pattern,
    vertex_induced: bool,
    cfg: &MinerConfig,
) -> Result<Outcome<u64>, MineError> {
    if !cfg.opts.plan_active() {
        let pl = matching_order::plan(p, vertex_induced, true);
        return dfs::count(g, &pl, cfg, &NoHooks);
    }
    let cp = decompose(p, vertex_induced, g);
    execute(g, &cp, cfg)
}

/// Full algebraic k-motif census (k ∈ 3..=4), `all_motifs(k)` order:
/// anchors enumerate once (triangle for k=3; 4-clique and 4-cycle for
/// k=4), everything else is solved from shared formula leaves — the
/// whole census costs two small anchor enumerations plus one vertex
/// and one edge scan, against the ESU oracle's enumeration of *every*
/// connected k-subgraph. Callers gate on
/// [`OptFlags::plan_active`](crate::engine::OptFlags::plan_active)
/// (see [`crate::apps::motif::motif3`] /
/// [`crate::apps::motif::motif4`]); this function always plans.
pub fn motif_census(
    g: &CsrGraph,
    k: usize,
    cfg: &MinerConfig,
) -> Result<Outcome<Vec<u64>>, MineError> {
    assert!((3..=4).contains(&k), "algebraic census supports k in 3..=4");
    let mut ctx = Ctx::new(g, cfg);
    if k == 3 {
        trace::on_plan_select(true, 2);
        let motifs = library::all_motifs(3);
        let coeffs = overlap_coeffs(Formula::VertexComb(2), &motifs);
        let t = ctx.anchor(&library::triangle())?;
        let acc = ctx.formula(Formula::VertexComb(2)) as i128 - coeffs[1] as i128 * t as i128;
        let w = finish_div(acc, coeffs[0], ctx.tripped.is_some());
        return Ok(ctx.outcome(vec![w, t]));
    }
    trace::on_plan_select(true, 4); // K4 + C4 anchors, edge pass, vertex pass
    let motifs = library::all_motifs(4);
    let mut counts = vec![0u64; motifs.len()];
    // anchors first (trip fast), then the dependency-ordered solves
    for idx in [M4_CLIQUE, M4_CYCLE, 4, 2, 1, 0] {
        counts[idx] = ctx.induced_motif4(&motifs, idx, 0)?;
    }
    Ok(ctx.outcome(counts))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::hooks::NoHooks;
    use crate::engine::OptFlags;
    use crate::graph::gen;

    fn cfg() -> MinerConfig {
        MinerConfig::custom(2, 16, OptFlags::hi())
    }

    /// The derived inclusion–exclusion coefficients must reproduce the
    /// hand-transcribed PGD constants of `motif4_lo` exactly.
    #[test]
    fn derived_coefficients_match_pgd_constants() {
        let m3 = library::all_motifs(3);
        assert_eq!(overlap_coeffs(Formula::VertexComb(2), &m3), vec![1, 3]);
        let m4 = library::all_motifs(4);
        // order: [3-star, 4-path, tailed-triangle, 4-cycle, diamond, 4-clique]
        assert_eq!(overlap_coeffs(Formula::EdgeTriPairs, &m4), vec![0, 0, 0, 0, 1, 6]);
        assert_eq!(overlap_coeffs(Formula::EdgeTriSides, &m4), vec![0, 0, 2, 0, 4, 0]);
        assert_eq!(overlap_coeffs(Formula::EdgeSideProduct, &m4), vec![0, 1, 0, 4, 0, 0]);
        assert_eq!(overlap_coeffs(Formula::VertexComb(3), &m4), vec![1, 0, 1, 0, 2, 4]);
    }

    #[test]
    fn binom_small_values() {
        assert_eq!(binom(5, 2), 10);
        assert_eq!(binom(4, 3), 4);
        assert_eq!(binom(2, 3), 0);
        assert_eq!(binom(16384, 3), 16384 * 16383 * 16382 / 6);
    }

    #[test]
    fn census_matches_esu_oracle() {
        use crate::engine::esu::{count_motifs, MotifTable};
        for seed in [3, 9] {
            let g = gen::rmat(8, 5, seed, &[]);
            for k in [3usize, 4] {
                let table = MotifTable::new(k);
                let (want, _) =
                    count_motifs(&g, k, &cfg(), &NoHooks, &table).unwrap().into_parts();
                let got = motif_census(&g, k, &cfg()).unwrap();
                assert!(got.complete);
                assert_eq!(got.value, want, "k={k} seed={seed}");
            }
        }
    }

    #[test]
    fn census_enumerates_strictly_less_than_esu() {
        use crate::engine::esu::{count_motifs, MotifTable};
        let g = gen::rmat(8, 6, 11, &[]);
        let c = MinerConfig::custom(2, 16, OptFlags::hi().with_stats());
        let table = MotifTable::new(4);
        let esu = count_motifs(&g, 4, &c, &NoHooks, &table).unwrap();
        let planned = motif_census(&g, 4, &c).unwrap();
        assert_eq!(planned.value, esu.value);
        assert!(
            planned.stats.enumerated < esu.stats.enumerated,
            "planner enumerated {} vs ESU {}",
            planned.stats.enumerated,
            esu.stats.enumerated
        );
    }

    #[test]
    fn single_pattern_plans_agree_with_enumeration() {
        let g = gen::rmat(8, 5, 7, &[]);
        let patterns: Vec<Pattern> = library::all_motifs(4)
            .into_iter()
            .chain(library::all_motifs(3))
            .chain([library::star(4)])
            .collect();
        for p in &patterns {
            for vi in [true, false] {
                let pl = matching_order::plan(p, vi, true);
                let (want, _) = dfs::count(&g, &pl, &cfg(), &NoHooks).unwrap().into_parts();
                let got = count_with_plan(&g, p, vi, &cfg()).unwrap();
                assert!(got.complete);
                assert_eq!(got.value, want, "pattern {p} vi={vi}");
            }
        }
    }

    #[test]
    fn kill_switch_flag_pins_the_enumerated_route() {
        // with `plan` off, count_with_plan must be the oracle itself
        let g = gen::rmat(7, 5, 5, &[]);
        let p = library::diamond();
        let mut c = cfg();
        c.opts.plan = false;
        assert!(!c.opts.plan_active());
        let pl = matching_order::plan(&p, true, true);
        let want = dfs::count(&g, &pl, &c, &NoHooks).unwrap().value;
        assert_eq!(count_with_plan(&g, &p, true, &c).unwrap().value, want);
    }

    #[test]
    fn unsupported_patterns_plan_direct() {
        let g = gen::rmat(7, 5, 5, &[]);
        // 5-vertex motif: no identity in the table
        let p5 = library::cycle(5);
        assert!(!decompose(&p5, true, &g).decomposed());
        // labeled pattern: identities assume unlabeled degrees
        let mut lp = library::wedge();
        lp.set_label(0, 1);
        assert!(!decompose(&lp, true, &g).decomposed());
        // anchors are their own cheapest enumeration
        assert!(!decompose(&library::clique(4), true, &g).decomposed());
        assert!(!decompose(&library::cycle(4), true, &g).decomposed());
        // the supported ones do decompose on a dense-enough input
        assert!(decompose(&library::diamond(), true, &g).decomposed());
        assert!(decompose(&library::wedge(), true, &g).decomposed());
        assert_eq!(decompose(&library::diamond(), true, &g).leaves(), 2);
    }

    #[test]
    fn cost_model_prefers_direct_on_sparse_inputs() {
        // ring: d̄ = 2, so the K4-anchor route cannot beat enumerating
        // the diamond directly — the search must keep the oracle
        let ring = gen::ring(64);
        let cp = decompose(&library::diamond(), true, &ring);
        assert!(!cp.decomposed(), "chose {} at est {}", cp.describe(), cp.est_cost());
        // and the count is still exact through the Direct route
        let want = dfs::count(
            &ring,
            &matching_order::plan(&library::diamond(), true, true),
            &cfg(),
            &NoHooks,
        )
        .unwrap()
        .value;
        assert_eq!(count_with_plan(&ring, &library::diamond(), true, &cfg()).unwrap().value, want);
    }

    #[test]
    fn deadline_trip_yields_partial_outcome() {
        use std::time::Duration;
        // a deadline that has already expired trips the first anchor;
        // the census must surface complete == false, never panic
        let g = gen::rmat(8, 6, 13, &[]);
        let c = cfg().with_deadline(Duration::from_nanos(1));
        let out = motif_census(&g, 4, &c).unwrap();
        assert!(!out.complete, "expired deadline must yield a partial census");
        assert!(out.tripped.is_some());
    }

    #[test]
    fn plan_describes_and_counts_leaves() {
        let g = gen::rmat(7, 6, 3, &[]);
        let w = decompose(&library::wedge(), true, &g);
        assert_eq!(w.describe(), "wedge:vertex-comb-minus-triangles");
        assert_eq!(w.leaves(), 2);
        let d = decompose(&library::diamond(), false, &g);
        assert_eq!(d.describe(), "diamond:edge-tri-pairs");
        assert_eq!(d.leaves(), 1);
        let s = decompose(&library::star(4), false, &g);
        assert_eq!(s.describe(), "star:vertex-comb");
    }
}
