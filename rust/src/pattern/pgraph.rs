//! Small pattern graphs (≤ 16 vertices) with dense bitmask adjacency.
//!
//! A *pattern* (paper §2) is an explicitly-given small graph; embeddings
//! of it are searched in the big CSR input graph. Patterns are specified
//! as edge-lists exactly as in the paper's high-level API (e.g. TC's
//! pattern is `{(0,1),(0,2),(1,2)}`).

/// Patterns are capped at 16 vertices (adjacency masks fit in `u16`).
pub const MAX_PATTERN_VERTICES: usize = 16;

#[derive(Clone, Debug, PartialEq, Eq, Hash)]
/// A small pattern graph with bitmask adjacency rows.
pub struct Pattern {
    n: usize,
    /// adj[i] = bitmask of neighbors of i.
    adj: [u16; MAX_PATTERN_VERTICES],
    /// Vertex labels (0 = unlabeled).
    labels: [u32; MAX_PATTERN_VERTICES],
}

impl Pattern {
    /// Edgeless pattern on `n` vertices.
    pub fn new(n: usize) -> Self {
        assert!(n <= MAX_PATTERN_VERTICES);
        Self { n, adj: [0; MAX_PATTERN_VERTICES], labels: [0; MAX_PATTERN_VERTICES] }
    }

    /// Build from an edge list; n = max endpoint + 1.
    pub fn from_edges(edges: &[(usize, usize)]) -> Self {
        let n = edges
            .iter()
            .map(|&(u, v)| u.max(v) + 1)
            .max()
            .unwrap_or(0);
        let mut p = Self::new(n);
        for &(u, v) in edges {
            p.add_edge(u, v);
        }
        p
    }

    /// Add an undirected edge.
    pub fn add_edge(&mut self, u: usize, v: usize) {
        assert!(u != v && u < self.n && v < self.n);
        self.adj[u] |= 1 << v;
        self.adj[v] |= 1 << u;
    }

    /// Set the label of `v` (labels are matched exactly).
    pub fn set_label(&mut self, v: usize, label: u32) {
        self.labels[v] = label;
    }

    /// Label of `v` (0 = unlabeled).
    pub fn label(&self, v: usize) -> u32 {
        self.labels[v]
    }

    /// Number of vertices.
    pub fn num_vertices(&self) -> usize {
        self.n
    }

    /// Number of edges.
    pub fn num_edges(&self) -> usize {
        (0..self.n).map(|i| self.adj[i].count_ones() as usize).sum::<usize>() / 2
    }

    #[inline]
    /// Adjacency test.
    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.adj[u] >> v & 1 == 1
    }

    #[inline]
    /// Adjacency row of `v` as a bitmask.
    pub fn adj_mask(&self, v: usize) -> u16 {
        self.adj[v]
    }

    /// Degree of `v`.
    pub fn degree(&self, v: usize) -> usize {
        self.adj[v].count_ones() as usize
    }

    /// Smallest vertex degree.
    pub fn min_degree(&self) -> usize {
        (0..self.n).map(|v| self.degree(v)).min().unwrap_or(0)
    }

    /// All edges (u < v).
    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for u in 0..self.n {
            for v in (u + 1)..self.n {
                if self.has_edge(u, v) {
                    out.push((u, v));
                }
            }
        }
        out
    }

    /// True when every vertex pair is adjacent.
    pub fn is_clique(&self) -> bool {
        self.num_edges() == self.n * (self.n - 1) / 2
    }

    /// Connectivity check over the adjacency masks.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen: u16 = 1;
        let mut frontier: u16 = 1;
        while frontier != 0 {
            let mut next: u16 = 0;
            let mut f = frontier;
            while f != 0 {
                let v = f.trailing_zeros() as usize;
                f &= f - 1;
                next |= self.adj[v] & !seen;
            }
            seen |= next;
            frontier = next;
        }
        seen.count_ones() as usize == self.n
    }

    /// Whether any vertex carries a non-zero label.
    pub fn is_labeled(&self) -> bool {
        (0..self.n).any(|v| self.labels[v] != 0)
    }

    /// Induced sub-pattern on the vertex set given by `mask`, vertices
    /// renumbered in ascending order.
    pub fn induced(&self, mask: u16) -> Pattern {
        let verts: Vec<usize> =
            (0..self.n).filter(|&v| mask >> v & 1 == 1).collect();
        let mut p = Pattern::new(verts.len());
        for (i, &u) in verts.iter().enumerate() {
            p.labels[i] = self.labels[u];
            for (j, &v) in verts.iter().enumerate().skip(i + 1) {
                if self.has_edge(u, v) {
                    p.add_edge(i, j);
                }
            }
        }
        p
    }

    /// Apply a vertex permutation: new vertex `perm[i]` takes old `i`.
    pub fn permuted(&self, perm: &[usize]) -> Pattern {
        let mut p = Pattern::new(self.n);
        for u in 0..self.n {
            p.labels[perm[u]] = self.labels[u];
            for v in (u + 1)..self.n {
                if self.has_edge(u, v) {
                    p.add_edge(perm[u], perm[v]);
                }
            }
        }
        p
    }
}

impl std::fmt::Display for Pattern {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "P{}[", self.n)?;
        for (i, (u, v)) in self.edges().iter().enumerate() {
            if i > 0 {
                write!(f, " ")?;
            }
            write!(f, "({u},{v})")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triangle_basics() {
        let p = Pattern::from_edges(&[(0, 1), (0, 2), (1, 2)]);
        assert_eq!(p.num_vertices(), 3);
        assert_eq!(p.num_edges(), 3);
        assert!(p.is_clique() && p.is_connected());
        assert_eq!(p.min_degree(), 2);
    }

    #[test]
    fn wedge_is_not_clique() {
        let p = Pattern::from_edges(&[(0, 1), (1, 2)]);
        assert!(!p.is_clique());
        assert!(p.is_connected());
        assert_eq!(p.degree(1), 2);
        assert_eq!(p.min_degree(), 1);
    }

    #[test]
    fn disconnected_detected() {
        let mut p = Pattern::new(4);
        p.add_edge(0, 1);
        p.add_edge(2, 3);
        assert!(!p.is_connected());
    }

    #[test]
    fn induced_subpattern() {
        let diamond = Pattern::from_edges(&[(0, 1), (0, 2), (1, 2), (1, 3), (2, 3)]);
        let tri = diamond.induced(0b0111);
        assert_eq!(tri.num_edges(), 3);
        assert!(tri.is_clique());
        let edge = diamond.induced(0b1001); // vertices 0,3: non-adjacent
        assert_eq!(edge.num_edges(), 0);
    }

    #[test]
    fn permuted_preserves_edge_count() {
        let p = Pattern::from_edges(&[(0, 1), (1, 2), (2, 3)]);
        let q = p.permuted(&[3, 2, 1, 0]);
        assert_eq!(q.num_edges(), 3);
        assert!(q.has_edge(3, 2) && q.has_edge(2, 1) && q.has_edge(1, 0));
    }
}
