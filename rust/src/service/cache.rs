//! Canonical-pattern result cache with single-flight coalescing.
//!
//! Peregrine's observation (PAPERS.md, arXiv 2004.02369) is that
//! pattern-aware canonicalization makes semantically equal queries
//! *syntactically* equal — which is exactly what makes a cross-tenant
//! result cache sound. The key is
//! ([`graph`, `epoch`](CacheKey::graph), [`CanonCode`], induced mode,
//! [`HookKind`]): two tenants asking for "diamond on livej" — one by
//! name, one as an explicit relabeled edge list — hash to the same
//! entry, while a graph mutation (epoch bump via the `invalidate` op)
//! orphans every stale entry by construction.
//!
//! Three load-bearing properties, each unit-tested below:
//!
//! * **Single-flight**: concurrent misses for one key run the compute
//!   once — the first caller becomes the leader, the rest block and
//!   replay the leader's bytes ([`CacheStats::coalesced`]).
//! * **Partial results are never cached**: the leader reports whether
//!   its value is cacheable (budget-tripped [`Outcome`]s are not); a
//!   non-cacheable fill wakes the waiters to run for themselves rather
//!   than poisoning the cache with a lower bound.
//! * **LRU byte cap**: entries are charged key + value bytes against
//!   [`ResultCache::cap_bytes`] (`SANDSLASH_CACHE_BYTES`); inserting
//!   past the cap evicts least-recently-used entries first.
//!
//! Values are `Arc<String>` — the pre-rendered result fragment of
//! [`crate::service::protocol::count_result`] — so a cache hit is
//! byte-identical to its miss-path original by construction (the
//! concurrency suite asserts this end to end).
//!
//! [`Outcome`]: crate::engine::Outcome

use std::collections::HashMap;
use std::sync::Arc;

use crate::pattern::CanonCode;
// PR-8: the table mutex + resolution condvar go through the sync
// facade so the loom suite can model-check the owner-tokened
// single-flight protocol (tests/loom/cache.rs proves a slow failed
// leader never clobbers a newer fill).
use crate::util::sync::{Condvar, Mutex};

/// Which low-level hook surface produced the cached value. Today the
/// service serves counting queries only ([`HookKind::Count`]); the
/// field exists so listing or per-pattern hooks can share the cache
/// without colliding with counts.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum HookKind {
    /// Plain embedding count ([`crate::engine::dfs::count`] + `NoHooks`).
    Count,
}

/// The cache key (see the module docs).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// Graph name in the registry.
    pub graph: String,
    /// Graph epoch the result was computed against.
    pub epoch: u64,
    /// Canonical form of the query pattern.
    pub pattern: CanonCode,
    /// Vertex-induced vs edge-induced matching.
    pub vertex_induced: bool,
    /// Hook surface.
    pub hook: HookKind,
}

impl CacheKey {
    /// Approximate heap footprint charged against the byte cap.
    fn bytes(&self) -> usize {
        self.graph.len() + self.pattern.labels.len() * 4 + 48
    }
}

/// Monotonic cache counters (the `stats` op and the test assertions).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Probes answered from a ready entry.
    pub hits: u64,
    /// Probes that found nothing and became the computing leader.
    pub misses: u64,
    /// Probes that blocked on an in-flight leader and replayed its
    /// bytes (single-flight coalescing).
    pub coalesced: u64,
    /// Complete results inserted.
    pub fills: u64,
    /// Results refused (budget-tripped partials, errors).
    pub rejected: u64,
    /// Entries evicted by the LRU byte cap.
    pub evictions: u64,
    /// Entries dropped by graph invalidation.
    pub invalidated: u64,
}

enum Slot {
    Ready { value: Arc<String>, bytes: usize, last_used: u64 },
    /// A leader is computing; `generation` bumps on every resolution
    /// so waiters can tell "resolved" from spurious wakeups. The owner
    /// token keeps a slow leader's resolution from clobbering a newer
    /// leader's pending slot (possible after a rejected fill re-opens
    /// the key while the old leader is still unwinding).
    Pending { owner: u64 },
}

#[derive(Default)]
struct Inner {
    map: HashMap<CacheKey, Slot>,
    bytes: usize,
    tick: u64,
    generation: u64,
    next_owner: u64,
    stats: CacheStats,
}

/// The cache (see the module docs). One `Mutex` + `Condvar` guards the
/// whole table — probes are two hash lookups, computes run unlocked.
pub struct ResultCache {
    cap_bytes: usize,
    inner: Mutex<Inner>,
    resolved: Condvar,
}

impl ResultCache {
    /// A cache bounded at `cap_bytes` of charged key + value bytes.
    pub fn new(cap_bytes: usize) -> Self {
        Self { cap_bytes, inner: Mutex::new(Inner::default()), resolved: Condvar::new() }
    }

    /// The configured byte cap.
    pub fn cap_bytes(&self) -> usize {
        self.cap_bytes
    }

    /// Probe for `key`, computing on miss with single-flight
    /// coalescing. `compute` returns the value and whether it is
    /// cacheable (complete); it is called at most once per
    /// `get_or_compute` call, and — across all concurrent callers of
    /// one key — once per cacheable resolution. Returns the value and
    /// whether it came from the cache (a ready entry or a coalesced
    /// leader fill).
    pub fn get_or_compute(
        &self,
        key: &CacheKey,
        compute: impl FnOnce() -> (Arc<String>, bool),
    ) -> (Arc<String>, bool) {
        enum Probe {
            Hit,
            Wait,
            Lead,
        }
        let mut inner = self.inner.lock().unwrap();
        let owner;
        loop {
            let probe = match inner.map.get(key) {
                Some(Slot::Ready { .. }) => Probe::Hit,
                Some(Slot::Pending { .. }) => Probe::Wait,
                None => Probe::Lead,
            };
            match probe {
                Probe::Hit => {
                    inner.tick += 1;
                    let tick = inner.tick;
                    let value = match inner.map.get_mut(key) {
                        Some(Slot::Ready { value, last_used, .. }) => {
                            *last_used = tick;
                            value.clone()
                        }
                        _ => unreachable!(),
                    };
                    inner.stats.hits += 1;
                    return (value, true);
                }
                Probe::Wait => {
                    let gen_seen = inner.generation;
                    while inner.generation == gen_seen {
                        inner = self.resolved.wait(inner).unwrap();
                    }
                    // a resolution happened somewhere: if this key's
                    // leader filled a ready entry, the next loop turn
                    // replays it (counted as a coalesced hit); if the
                    // fill was rejected, the slot is gone and this
                    // caller races to become the next leader.
                    if matches!(inner.map.get(key), Some(Slot::Ready { .. })) {
                        inner.stats.coalesced += 1;
                    }
                }
                Probe::Lead => {
                    owner = inner.next_owner;
                    inner.next_owner += 1;
                    inner.map.insert(key.clone(), Slot::Pending { owner });
                    inner.stats.misses += 1;
                    break;
                }
            }
        }
        drop(inner);
        // leader: compute unlocked, resolve under the lock. The guard
        // un-wedges waiters even if `compute` panics (engine panics are
        // caught by the governor, but the cache must not rely on it).
        let guard = PendingGuard { cache: self, key, owner };
        let (value, cacheable) = compute();
        guard.resolve(value.clone(), cacheable);
        (value, false)
    }

    fn resolve_slot(&self, key: &CacheKey, owner: u64, fill: Option<(Arc<String>, usize)>) {
        let mut inner = self.inner.lock().unwrap();
        if matches!(inner.map.get(key), Some(Slot::Pending { owner: o }) if *o == owner) {
            inner.map.remove(key);
        }
        match fill {
            // complete results for one key are deterministic, so if a
            // racing leader already filled the entry, keeping theirs is
            // equivalent — only the bytes accounting must stay exact
            Some((value, bytes)) if bytes <= self.cap_bytes => {
                if inner.map.contains_key(key) {
                    inner.stats.rejected += 1;
                } else {
                    inner.tick += 1;
                    let tick = inner.tick;
                    inner.bytes += bytes;
                    inner
                        .map
                        .insert(key.clone(), Slot::Ready { value, bytes, last_used: tick });
                    inner.stats.fills += 1;
                    while inner.bytes > self.cap_bytes {
                        let victim = inner
                            .map
                            .iter()
                            .filter_map(|(k, s)| match s {
                                Slot::Ready { last_used, .. } => Some((*last_used, k.clone())),
                                Slot::Pending { .. } => None,
                            })
                            .min_by_key(|(t, _)| *t)
                            .map(|(_, k)| k);
                        match victim {
                            Some(k) => {
                                if let Some(Slot::Ready { bytes, .. }) = inner.map.remove(&k) {
                                    inner.bytes -= bytes;
                                    inner.stats.evictions += 1;
                                }
                            }
                            None => break,
                        }
                    }
                }
            }
            Some(_) | None => inner.stats.rejected += 1,
        }
        inner.generation += 1;
        drop(inner);
        self.resolved.notify_all();
    }

    /// Drop every entry of `graph` (any epoch). The registry bumps the
    /// epoch too, so even a racing fill against the old epoch can never
    /// be probed again — this purge just frees its bytes early.
    pub fn purge_graph(&self, graph: &str) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let stale: Vec<CacheKey> = inner
            .map
            .iter()
            .filter(|(k, s)| k.graph == graph && matches!(s, Slot::Ready { .. }))
            .map(|(k, _)| k.clone())
            .collect();
        for k in &stale {
            if let Some(Slot::Ready { bytes, .. }) = inner.map.remove(k) {
                inner.bytes -= bytes;
                inner.stats.invalidated += 1;
            }
        }
        stale.len()
    }

    /// Current counters.
    pub fn stats(&self) -> CacheStats {
        self.inner.lock().unwrap().stats
    }

    /// Charged bytes resident right now.
    pub fn bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// Ready entries resident right now.
    pub fn len(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.map.values().filter(|s| matches!(s, Slot::Ready { .. })).count()
    }

    /// Whether no ready entry is resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Removes a wedged `Pending` slot if the leader's compute panics.
struct PendingGuard<'a> {
    cache: &'a ResultCache,
    key: &'a CacheKey,
    owner: u64,
}

impl PendingGuard<'_> {
    fn resolve(self, value: Arc<String>, cacheable: bool) {
        let fill = cacheable.then(|| {
            let bytes = self.key.bytes() + value.len();
            (value, bytes)
        });
        self.cache.resolve_slot(self.key, self.owner, fill);
        std::mem::forget(self);
    }
}

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        self.cache.resolve_slot(self.key, self.owner, None);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Barrier;

    fn key(graph: &str, epoch: u64, bits: u64) -> CacheKey {
        CacheKey {
            graph: graph.to_string(),
            epoch,
            pattern: CanonCode { n: 3, labels: vec![0, 0, 0], bits },
            vertex_induced: false,
            hook: HookKind::Count,
        }
    }

    fn val(s: &str) -> Arc<String> {
        Arc::new(s.to_string())
    }

    #[test]
    fn hit_replays_the_exact_miss_bytes() {
        let cache = ResultCache::new(1 << 16);
        let k = key("g", 0, 0b11);
        let (first, hit) = cache.get_or_compute(&k, || (val("{\"count\":7}"), true));
        assert!(!hit);
        let (second, hit) = cache.get_or_compute(&k, || unreachable!("must not recompute"));
        assert!(hit);
        assert!(Arc::ptr_eq(&first, &second), "hits must replay the original bytes");
        assert_eq!(cache.stats().hits, 1);
        assert_eq!(cache.stats().misses, 1);
    }

    #[test]
    fn single_flight_coalesces_concurrent_misses() {
        // a blocking hook: the leader's compute parks on a barrier until
        // every other client is provably waiting on the pending slot
        let cache = Arc::new(ResultCache::new(1 << 16));
        let k = key("g", 0, 0b11);
        let computes = Arc::new(AtomicUsize::new(0));
        let release = Arc::new(Barrier::new(2)); // leader's compute + the coordinator
        let n_waiters = 7;
        let mut handles = Vec::new();
        // leader
        {
            let (cache, k, computes, release) =
                (cache.clone(), k.clone(), computes.clone(), release.clone());
            handles.push(std::thread::spawn(move || {
                cache.get_or_compute(&k, || {
                    computes.fetch_add(1, Ordering::SeqCst);
                    release.wait(); // block until waiters have piled up
                    (val("{\"count\":7}"), true)
                })
            }));
        }
        // wait until the pending slot exists, then pile on waiters
        while computes.load(Ordering::SeqCst) == 0 {
            std::thread::yield_now();
        }
        for _ in 0..n_waiters {
            let (cache, k, computes) = (cache.clone(), k.clone(), computes.clone());
            handles.push(std::thread::spawn(move || {
                cache.get_or_compute(&k, || {
                    computes.fetch_add(1, Ordering::SeqCst);
                    (val("never"), true)
                })
            }));
        }
        // give the waiters time to reach the condvar, then release the
        // leader (a late waiter still coalesces — it finds the ready
        // entry — so the count assertions hold either way)
        std::thread::sleep(std::time::Duration::from_millis(20));
        release.wait();
        let results: Vec<(Arc<String>, bool)> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(computes.load(Ordering::SeqCst), 1, "compute must run exactly once");
        let leader = results.iter().find(|(_, cached)| !cached).unwrap().0.clone();
        for (v, _) in &results {
            assert!(Arc::ptr_eq(v, &leader), "coalesced waiters replay the leader's bytes");
        }
        assert_eq!(results.iter().filter(|(_, cached)| *cached).count(), n_waiters);
        let stats = cache.stats();
        assert_eq!((stats.misses, stats.fills), (1, 1));
        assert_eq!(stats.hits, n_waiters as u64);
    }

    #[test]
    fn partial_results_are_never_cached_and_waiters_rerun() {
        let cache = Arc::new(ResultCache::new(1 << 16));
        let k = key("g", 0, 0b11);
        // leader resolves non-cacheable (budget-tripped partial)
        let (v, cached) = cache.get_or_compute(&k, || (val("partial"), false));
        assert_eq!((v.as_str(), cached), ("partial", false));
        assert_eq!(cache.stats().rejected, 1);
        assert_eq!(cache.len(), 0, "partials must not be cached");
        // the next probe is a fresh miss, not a hit on the partial
        let (v, cached) = cache.get_or_compute(&k, || (val("complete"), true));
        assert_eq!((v.as_str(), cached), ("complete", false));
        assert_eq!(cache.stats().misses, 2);
    }

    #[test]
    fn waiters_on_a_rejected_fill_run_for_themselves() {
        let cache = Arc::new(ResultCache::new(1 << 16));
        let k = key("g", 0, 0b11);
        let in_compute = Arc::new(Barrier::new(2));
        let leader = {
            let (cache, k, in_compute) = (cache.clone(), k.clone(), in_compute.clone());
            std::thread::spawn(move || {
                cache.get_or_compute(&k, || {
                    in_compute.wait();
                    // simulate a deadline trip: not cacheable
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    (val("partial"), false)
                })
            })
        };
        in_compute.wait(); // leader is computing; this probe coalesces
        let (v, cached) = cache.get_or_compute(&k, || (val("mine"), true));
        // the waiter was woken by a rejected fill and ran its own
        // compute (its budget may differ from the leader's)
        assert_eq!((v.as_str(), cached), ("mine", false));
        assert_eq!(leader.join().unwrap().0.as_str(), "partial");
        assert_eq!(cache.stats().rejected, 1);
        assert_eq!(cache.stats().fills, 1);
    }

    #[test]
    fn lru_byte_cap_evicts_least_recently_used_first() {
        // room for two ~100-byte entries, not three
        let k1 = key("g", 0, 1);
        let per_entry = k1.bytes() + 40;
        let cache = ResultCache::new(2 * per_entry);
        let big = "x".repeat(40);
        let (k2, k3) = (key("g", 0, 2), key("g", 0, 3));
        cache.get_or_compute(&k1, || (val(&big), true));
        cache.get_or_compute(&k2, || (val(&big), true));
        assert_eq!((cache.len(), cache.stats().evictions), (2, 0));
        // touch k1 so k2 is the LRU victim
        cache.get_or_compute(&k1, || unreachable!());
        cache.get_or_compute(&k3, || (val(&big), true));
        assert_eq!(cache.stats().evictions, 1);
        assert_eq!(cache.len(), 2);
        // k2 was evicted; k1 and k3 still hit
        cache.get_or_compute(&k1, || unreachable!());
        cache.get_or_compute(&k3, || unreachable!());
        let recomputed = std::cell::Cell::new(false);
        cache.get_or_compute(&k2, || {
            recomputed.set(true);
            (val(&big), true)
        });
        assert!(recomputed.get(), "the LRU victim must have been k2");
        // an entry bigger than the whole cap is refused outright
        let huge = "y".repeat(3 * per_entry);
        let (_, cached) = cache.get_or_compute(&key("g", 0, 4), || (val(&huge), true));
        assert!(!cached);
        let evictions_before = cache.stats().evictions;
        let (_, cached) = cache.get_or_compute(&key("g", 0, 4), || (val(&huge), true));
        assert!(!cached, "an over-cap value must never displace the working set");
        assert_eq!(cache.stats().evictions, evictions_before);
    }

    #[test]
    fn epoch_bump_orphans_old_entries_and_purge_frees_bytes() {
        let cache = ResultCache::new(1 << 16);
        let old = key("livej", 0, 0b11);
        cache.get_or_compute(&old, || (val("{\"count\":9}"), true));
        assert_eq!(cache.len(), 1);
        // an epoch bump changes the key: same query, fresh compute
        let new = CacheKey { epoch: 1, ..old.clone() };
        let ran = std::cell::Cell::new(false);
        cache.get_or_compute(&new, || {
            ran.set(true);
            (val("{\"count\":10}"), true)
        });
        assert!(ran.get(), "epoch bump must miss");
        // purge drops both epochs' entries for the graph, not others
        let other = key("orkut", 0, 0b11);
        cache.get_or_compute(&other, || (val("{\"count\":1}"), true));
        let bytes_before = cache.bytes();
        assert_eq!(cache.purge_graph("livej"), 2);
        assert_eq!(cache.stats().invalidated, 2);
        assert!(cache.bytes() < bytes_before);
        assert_eq!(cache.len(), 1);
        cache.get_or_compute(&other, || unreachable!("other graphs must survive the purge"));
    }
}
