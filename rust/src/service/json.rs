//! Minimal JSON parser for the line-delimited service protocol.
//!
//! The offline registry has no serde, so the service parses requests
//! (and the CLI client parses responses) through this hand-rolled
//! recursive-descent reader — the read-side twin of the write-only
//! [`crate::util::bench::Json`] builder. Numbers keep their raw source
//! text ([`JsonValue::Num`]) so `u64` counts round-trip losslessly
//! instead of being squeezed through an `f64`.
//!
//! The grammar is standard JSON (RFC 8259) with two defensive limits,
//! both rejected loudly rather than clamped: nesting deeper than
//! [`MAX_DEPTH`] and inputs longer than [`MAX_LINE_BYTES`] — a resident
//! process must bound what one malformed client line can cost.

/// Maximum container nesting accepted by [`parse`]; protocol objects
/// are at most three levels deep, so 32 is generous.
pub const MAX_DEPTH: usize = 32;

/// Maximum request-line length accepted by [`parse`] (1 MiB): enough
/// for any explicit edge list over ≤ 8-vertex patterns by orders of
/// magnitude, small enough that a hostile line cannot balloon memory.
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// A parsed JSON value. Object keys keep source order (the protocol
/// never needs map semantics, and `Vec` keeps golden tests stable).
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number, as its raw source text (lossless for `u64`).
    Num(String),
    /// A string, unescaped.
    Str(String),
    /// An array.
    Arr(Vec<JsonValue>),
    /// An object: `(key, value)` pairs in source order.
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Object field lookup (first occurrence); `None` for non-objects.
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean payload, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// This number as a `u64`, if it is a non-negative integer that
    /// fits (raw-text parse — no `f64` round-trip).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            JsonValue::Num(raw) => raw.parse::<u64>().ok(),
            _ => None,
        }
    }

    /// The array elements, if this is an array.
    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(xs) => Some(xs),
            _ => None,
        }
    }
}

/// A parse failure: byte offset and a short reason, both surfaced in
/// the protocol's `malformed-json` error detail.
#[derive(Clone, Debug, PartialEq)]
pub struct JsonError {
    /// Byte offset of the failure in the input line.
    pub pos: usize,
    /// Short human-readable reason.
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} at byte {}", self.msg, self.pos)
    }
}

/// Parse one JSON document; trailing non-whitespace is an error (one
/// request per line, nothing smuggled after it).
pub fn parse(text: &str) -> Result<JsonValue, JsonError> {
    if text.len() > MAX_LINE_BYTES {
        return Err(JsonError {
            pos: MAX_LINE_BYTES,
            msg: format!("input exceeds {MAX_LINE_BYTES} bytes"),
        });
    }
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

/// Escape a string for embedding in rendered JSON output (the write
/// side lives in [`crate::util::bench::Json`]; the protocol renders
/// through this shared helper so request and response escaping agree).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        JsonError { pos: self.i, msg: msg.into() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<JsonValue, JsonError> {
        if depth > MAX_DEPTH {
            return Err(self.err(format!("nesting deeper than {MAX_DEPTH}")));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: JsonValue) -> Result<JsonValue, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("expected '{word}'")))
        }
    }

    fn object(&mut self, depth: usize) -> Result<JsonValue, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(JsonValue::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(JsonValue::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<JsonValue, JsonError> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(JsonValue::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(JsonValue::Arr(xs));
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                return Err(self.err("unterminated string"));
            };
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        return Err(self.err("unterminated escape"));
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xd800..0xdc00).contains(&hi) {
                                // surrogate pair: require the low half
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    if !(0xdc00..0xe000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00)
                                } else {
                                    return Err(self.err("unpaired high surrogate"));
                                }
                            } else if (0xdc00..0xe000).contains(&hi) {
                                return Err(self.err("unpaired low surrogate"));
                            } else {
                                hi
                            };
                            match char::from_u32(cp) {
                                Some(ch) => out.push(ch),
                                None => return Err(self.err("invalid unicode escape")),
                            }
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("raw control character in string")),
                _ => {
                    // re-scan the full UTF-8 sequence from the source
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && (self.b[end] & 0xc0) == 0x80 {
                        end += 1;
                    }
                    match std::str::from_utf8(&self.b[start..end]) {
                        Ok(s) => {
                            out.push_str(s);
                            self.i = end;
                        }
                        Err(_) => return Err(self.err("invalid utf-8 in string")),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<JsonValue, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let digits_start = self.i;
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.i == digits_start {
            return Err(self.err("expected digits"));
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            let frac = self.i;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
            if self.i == frac {
                return Err(self.err("expected fraction digits"));
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            let exp = self.i;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
            if self.i == exp {
                return Err(self.err("expected exponent digits"));
            }
        }
        Ok(JsonValue::Num(
            std::str::from_utf8(&self.b[start..self.i]).unwrap().to_string(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_protocol_shaped_objects() {
        let v = parse(
            r#"{"id":"q1","op":"query","graph":"er-small","edges":[[0,1],[1,2]],
               "induced":true,"deadline_ms":50,"none":null}"#,
        )
        .unwrap();
        assert_eq!(v.get("id").unwrap().as_str(), Some("q1"));
        assert_eq!(v.get("induced").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("deadline_ms").unwrap().as_u64(), Some(50));
        assert_eq!(v.get("none"), Some(&JsonValue::Null));
        let edges = v.get("edges").unwrap().as_array().unwrap();
        assert_eq!(edges[1].as_array().unwrap()[0].as_u64(), Some(1));
    }

    #[test]
    fn u64_counts_round_trip_losslessly() {
        let big = u64::MAX;
        let v = parse(&format!("{{\"count\":{big}}}")).unwrap();
        assert_eq!(v.get("count").unwrap().as_u64(), Some(big));
        // floats and negatives are not u64s
        assert_eq!(parse("1.5").unwrap().as_u64(), None);
        assert_eq!(parse("-3").unwrap().as_u64(), None);
    }

    #[test]
    fn escapes_round_trip() {
        let original = "a \"b\" \\ / \n\t\u{0008}\u{000c}\r ☃ \u{1F600}";
        let line = format!("{{\"s\":\"{}\"}}", escape(original));
        let v = parse(&line).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some(original));
        // explicit surrogate pair
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
    }

    #[test]
    fn rejects_malformed_inputs_with_position() {
        for bad in [
            "",
            "{",
            "{\"a\"}",
            "{\"a\":1,}",
            "[1 2]",
            "tru",
            "\"unterminated",
            "\"bad \\q escape\"",
            "01x",
            "1 trailing",
            "{\"a\":1} {\"b\":2}",
            r#""\ud800""#,
        ] {
            assert!(parse(bad).is_err(), "accepted {bad:?}");
        }
        let e = parse("[1,,2]").unwrap_err();
        assert!(e.pos > 0 && e.to_string().contains("byte"));
    }

    #[test]
    fn rejects_hostile_depth_and_length() {
        let deep = format!("{}1{}", "[".repeat(MAX_DEPTH + 2), "]".repeat(MAX_DEPTH + 2));
        assert!(parse(&deep).is_err());
        let long = format!("\"{}\"", "x".repeat(MAX_LINE_BYTES));
        assert!(parse(&long).is_err());
    }
}
