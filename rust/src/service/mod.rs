//! The resident mining service (PR 7): `sandslash serve`.
//!
//! The one-shot CLI pays graph loading, plan construction, and pool
//! spin-up per invocation — the Pangolin-shaped cost model this module
//! leaves behind. The service loads each graph **once** into an
//! `Arc`-shared immutable CSR ([`registry`]), accepts concurrent
//! pattern queries over a line-delimited JSON protocol ([`protocol`],
//! [`net`]), and multiplexes them onto the PR-4 stealing scheduler with
//! per-query PR-6 [`Budget`]s, priorities, and bounded admission
//! ([`admission`]). In front of execution sits a canonical-pattern
//! result cache ([`cache`]): Peregrine-style canonicalization makes
//! semantically equal queries syntactically equal, so two tenants
//! asking for "diamond on livej" share one computation — with
//! single-flight coalescing, and budget-tripped partials never cached.
//!
//! Layer map:
//!
//! * [`json`] — minimal RFC 8259 parser/escaper (std-only, no serde)
//! * [`protocol`] — request/response grammar, named errors, the
//!   structured-code table (PR-6 CLI exit codes as response fields)
//! * [`admission`] — bounded in-flight + queue-or-reject gate
//! * [`registry`] — load-once `Arc` graph sharing with epochs
//! * [`cache`] — canonical-key result cache, single-flight, LRU bytes
//! * [`core`] — [`Service`]: admission → cache probe → governed run →
//!   cache fill
//! * [`net`] — thin TCP line transport (`serve`/`query` subcommands)
//!
//! Reentrancy contract: everything ambient the engines consult is
//! *scoped* — [`sched::with_overrides`] and [`budget::with_cancel`]
//! are restore-on-exit thread-locals installed around one run, so
//! queries sharing the process never leak scheduler pinning or cancel
//! tokens into each other (asserted by the concurrency suite).
//!
//! [`Budget`]: crate::engine::Budget
//! [`sched::with_overrides`]: crate::exec::sched::with_overrides
//! [`budget::with_cancel`]: crate::engine::budget::with_cancel

pub mod admission;
pub mod cache;
pub mod core;
pub mod json;
pub mod net;
pub mod protocol;
pub mod registry;

pub use admission::{AdmitError, Admission, Permit, Priority};
pub use cache::{CacheKey, CacheStats, HookKind, ResultCache};
pub use self::core::{Service, ServiceConfig, ServiceError};
pub use net::{request_over_socket, Server};
pub use protocol::{
    count_result, parse_request, resolve_pattern, response_code, Body, Op, PatternSpec,
    ProtoError, Request, Response, CODE_OVERLOADED,
};
pub use registry::{GraphRegistry, RegistryError};
