//! Graph registry: load once, share via `Arc`, version with epochs.
//!
//! The resident process loads each graph exactly once into an immutable
//! [`CsrGraph`] behind an `Arc`; every concurrent query of that graph
//! clones the `Arc` (refcount bump, no copy) and runs against the same
//! CSR arrays. Loading is single-flight: when two tenants race to be
//! the first user of `livej`, one loads while the other blocks on the
//! registry condvar — never two materializations of one dataset.
//!
//! Every graph carries an **epoch**, the cache-coherence token of the
//! service: [`crate::service::cache::CacheKey`] embeds it, so bumping
//! the epoch (the `invalidate` protocol op) orphans every cached result
//! of the old version by construction — no cache scan races. Today's
//! datasets are deterministic generators
//! ([`crate::coordinator::datasets`]), so a bump keeps the same `Arc`;
//! an incremental-update path (ROADMAP) would swap in a new snapshot
//! under the same lock and inherit the coherence story unchanged.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::datasets;
use crate::graph::CsrGraph;

/// Why a graph lookup failed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RegistryError {
    /// The name is not in the dataset registry.
    UnknownGraph(String),
}

enum Entry {
    /// Another thread is materializing the graph.
    Loading,
    Ready { graph: Arc<CsrGraph>, epoch: u64 },
}

/// The registry (see the module docs).
#[derive(Default)]
pub struct GraphRegistry {
    inner: Mutex<HashMap<String, Entry>>,
    loaded: Condvar,
}

impl GraphRegistry {
    /// An empty registry; graphs materialize on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// The shared graph and its current epoch, loading on first use
    /// (single-flight — concurrent first users block, not double-load).
    pub fn get(&self, name: &str) -> Result<(Arc<CsrGraph>, u64), RegistryError> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            match inner.get(name) {
                Some(Entry::Ready { graph, epoch }) => return Ok((graph.clone(), *epoch)),
                Some(Entry::Loading) => inner = self.loaded.wait(inner).unwrap(),
                None => break,
            }
        }
        inner.insert(name.to_string(), Entry::Loading);
        drop(inner);
        // materialize unlocked — generator datasets take real time
        let loaded = datasets::load(name).map(Arc::new);
        let mut inner = self.inner.lock().unwrap();
        let out = match loaded {
            Some(graph) => {
                inner.insert(
                    name.to_string(),
                    Entry::Ready { graph: graph.clone(), epoch: 0 },
                );
                Ok((graph, 0))
            }
            None => {
                inner.remove(name);
                Err(RegistryError::UnknownGraph(name.to_string()))
            }
        };
        drop(inner);
        self.loaded.notify_all();
        out
    }

    /// Bump the epoch of a loaded graph (the `invalidate` op), orphaning
    /// every cached result keyed to the old epoch. Returns the new epoch,
    /// or `None` if the graph was never loaded (nothing to invalidate).
    pub fn bump_epoch(&self, name: &str) -> Option<u64> {
        let mut inner = self.inner.lock().unwrap();
        match inner.get_mut(name) {
            Some(Entry::Ready { epoch, .. }) => {
                *epoch += 1;
                Some(*epoch)
            }
            _ => None,
        }
    }

    /// `(name, epoch, vertices, undirected edges)` of every resident
    /// graph, name-sorted (the `graphs` op).
    pub fn resident(&self) -> Vec<(String, u64, usize, usize)> {
        let inner = self.inner.lock().unwrap();
        let mut out: Vec<_> = inner
            .iter()
            .filter_map(|(name, e)| match e {
                Entry::Ready { graph, epoch } => Some((
                    name.clone(),
                    *epoch,
                    graph.num_vertices(),
                    graph.num_undirected_edges(),
                )),
                Entry::Loading => None,
            })
            .collect();
        out.sort();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn loads_once_and_shares_the_arc() {
        let reg = GraphRegistry::new();
        let (a, e0) = reg.get("er-small").unwrap();
        let (b, e1) = reg.get("er-small").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second get must share, not reload");
        assert_eq!((e0, e1), (0, 0));
        assert_eq!(
            reg.get("no-such-graph"),
            Err(RegistryError::UnknownGraph("no-such-graph".into()))
        );
    }

    #[test]
    fn concurrent_first_users_single_flight() {
        let reg = Arc::new(GraphRegistry::new());
        let loaded = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let (reg, loaded) = (reg.clone(), loaded.clone());
                std::thread::spawn(move || {
                    let (g, _) = reg.get("er-small").unwrap();
                    loaded.fetch_add(1, Ordering::SeqCst);
                    g.num_vertices()
                })
            })
            .collect();
        let sizes: Vec<usize> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(sizes.windows(2).all(|w| w[0] == w[1]));
        assert_eq!(loaded.load(Ordering::SeqCst), 8);
        assert_eq!(reg.resident().len(), 1);
    }

    #[test]
    fn epoch_bumps_are_per_graph_and_need_a_resident_graph() {
        let reg = GraphRegistry::new();
        assert_eq!(reg.bump_epoch("er-small"), None, "nothing resident yet");
        reg.get("er-small").unwrap();
        reg.get("ba-small").unwrap();
        assert_eq!(reg.bump_epoch("er-small"), Some(1));
        assert_eq!(reg.bump_epoch("er-small"), Some(2));
        let (_, e) = reg.get("er-small").unwrap();
        assert_eq!(e, 2, "get must observe the bumped epoch");
        let (_, other) = reg.get("ba-small").unwrap();
        assert_eq!(other, 0, "bumps must not leak across graphs");
    }
}
