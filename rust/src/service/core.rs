//! The resident service: admission → cache probe → governed run →
//! cache fill.
//!
//! [`Service`] owns the four shared structures of the resident process
//! — the [`GraphRegistry`], the [`ResultCache`], the [`Admission`]
//! gate, and the in-flight token table (`cancel` op) — and exposes one
//! transport-free entry point, [`Service::handle`], that both the TCP
//! listener ([`super::net`]) and the in-process test harness call. A
//! query's life:
//!
//! 1. **Admission**: claim a slot from the bounded gate, or fail with
//!    `overloaded` ([`CODE_OVERLOADED`]) when the wait queue is full.
//! 2. **Cache probe**: canonicalize the pattern
//!    ([`crate::pattern::canonical_code`]) and probe the result cache
//!    under (graph, epoch, canonical form, induced mode, hook kind).
//!    A hit replays the miss-path bytes; a concurrent miss coalesces
//!    onto the in-flight leader.
//! 3. **Governed run**: on a true miss, build a per-query
//!    [`MinerConfig`] (request budget over the service default), install
//!    the query's [`CancelToken`] via the scoped
//!    [`budget::with_cancel`], and run the DFS engine on the shared
//!    stealing scheduler. Each run builds its own worker pool, so
//!    concurrent queries are structurally independent — the PR-6 worker
//!    panic isolation makes a poisoned query a code-4 *response*, never
//!    a process death.
//! 4. **Cache fill**: complete results (code 0) are inserted; tripped
//!    partials and errors are rejected (waiters rerun for themselves,
//!    because *their* budget may well afford the full answer).
//!
//! The service **refuses to start ungoverned**
//! ([`ServiceError::Ungoverned`]): with `SANDSLASH_NO_GOV=1` there are
//! no deadline polls, no task budgets, and no panic containment — every
//! multi-tenant guarantee above would be silently void.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::admission::{AdmitError, Admission};
use super::cache::{CacheKey, CacheStats, HookKind, ResultCache};
use super::protocol::{
    count_result, mine_error_code, mine_error_name, parse_request, resolve_pattern, Op,
    ProtoError, Request, Response, CODE_OVERLOADED,
};
use super::registry::{GraphRegistry, RegistryError};
use crate::engine::budget::{self, CancelToken};
use crate::engine::{MinerConfig, OptFlags};
use crate::graph::CsrGraph;
use crate::obs::registry as obs_registry;
use crate::obs::trace::{self as qtrace, CacheVerdict, QueryTrace};
use crate::pattern::{canonical_code, decompose, Pattern};
use crate::util::pool;

/// Service-level tunables; [`ServiceConfig::from_env`] reads the
/// `SANDSLASH_*` knobs, tests construct explicit values.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Queries running at once (`SANDSLASH_MAX_INFLIGHT`, default 4).
    pub max_inflight: usize,
    /// Queries allowed to wait (default `2 × max_inflight`).
    pub max_queued: usize,
    /// Result-cache byte cap (`SANDSLASH_CACHE_BYTES`, default 64 MiB).
    pub cache_bytes: usize,
    /// Worker threads per query when the request doesn't override.
    pub default_threads: usize,
    /// Budget applied when the request doesn't override
    /// (seeded from the PR-6 env knobs like every one-shot run).
    pub default_budget: crate::engine::Budget,
}

impl ServiceConfig {
    /// Read the service knobs from the environment (loud-reject parses,
    /// like every `SANDSLASH_*` numeric knob).
    pub fn from_env() -> Self {
        let max_inflight =
            pool::positive_usize_env("SANDSLASH_MAX_INFLIGHT", "the default of 4").unwrap_or(4);
        Self {
            max_inflight,
            max_queued: 2 * max_inflight,
            cache_bytes: pool::positive_usize_env("SANDSLASH_CACHE_BYTES", "the default 64 MiB")
                .unwrap_or(64 << 20),
            default_threads: pool::default_threads(),
            default_budget: crate::engine::Budget::from_env(),
        }
    }
}

/// Why the service refused to start.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// Governance is disabled (`SANDSLASH_NO_GOV=1` or a scoped
    /// [`budget::with_governance_disabled`]): no deadlines, no budgets,
    /// no panic isolation — unacceptable for a multi-tenant resident
    /// process, so the refusal is loud, not a degraded start.
    Ungoverned,
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::Ungoverned => write!(
                f,
                "refusing to serve ungoverned: SANDSLASH_NO_GOV disables the deadline, \
                 task-budget, and worker-panic containment every tenant depends on; \
                 unset it to start the service"
            ),
        }
    }
}

/// The resident service (see the module docs).
pub struct Service {
    cfg: ServiceConfig,
    registry: GraphRegistry,
    cache: ResultCache,
    admission: Admission,
    /// Cancel tokens of in-flight queries, keyed by request id (the
    /// `cancel` op's target namespace). Entries live exactly as long as
    /// the query; a finished id is free for reuse.
    inflight: Mutex<HashMap<String, Arc<CancelToken>>>,
    shutdown: AtomicBool,
    queries: AtomicU64,
}

impl Service {
    /// A fresh service, or [`ServiceError::Ungoverned`] when governance
    /// is off (the service never starts without its safety substrate).
    pub fn new(cfg: ServiceConfig) -> Result<Self, ServiceError> {
        if !budget::governance_enabled() {
            return Err(ServiceError::Ungoverned);
        }
        let admission = Admission::new(cfg.max_inflight, cfg.max_queued);
        let cache = ResultCache::new(cfg.cache_bytes);
        Ok(Self {
            cfg,
            registry: GraphRegistry::new(),
            cache,
            admission,
            inflight: Mutex::new(HashMap::new()),
            shutdown: AtomicBool::new(false),
            queries: AtomicU64::new(0),
        })
    }

    /// Handle one wire line: parse, dispatch, render. Parse failures
    /// respond with id `"?"` (the line never yielded one).
    pub fn handle_line(&self, line: &str) -> String {
        match parse_request(line) {
            Ok(req) => self.handle(&req).render(),
            Err(e) => Response::error("?", e).render(),
        }
    }

    /// Handle one parsed request (the transport-free entry point the
    /// in-process suites drive directly). Every response is counted by
    /// structured code in the unified metrics registry (PR 9).
    pub fn handle(&self, req: &Request) -> Response {
        let resp = match req.op {
            Op::Query => self.run_query(req),
            Op::Cancel => self.cancel(req),
            Op::Invalidate => self.invalidate(req),
            Op::Graphs => self.graphs(req),
            Op::Stats => self.stats_op(req),
            Op::Ping => ok_fragment(req, "{\"pong\":true}"),
            Op::Shutdown => {
                self.shutdown.store(true, Ordering::SeqCst);
                ok_fragment(req, "{\"shutdown\":true}")
            }
        };
        obs_registry::note_response(resp.code());
        resp
    }

    /// Whether a `shutdown` op has been handled (polled by the
    /// listener's accept loop).
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Current cache counters (test and `stats` surface).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Materialize a graph before the first query asks for it (the
    /// `serve --preload` flag). Returns `(vertices, undirected edges)`.
    pub fn preload(&self, name: &str) -> Result<(usize, usize), RegistryError> {
        let (g, _) = self.registry.get(name)?;
        Ok((g.num_vertices(), g.num_undirected_edges()))
    }

    /// The service configuration in effect.
    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    fn run_query(&self, req: &Request) -> Response {
        let Some(graph_name) = req.graph.as_deref() else {
            return Response::error(
                &req.id,
                ProtoError::usage("missing-field", "query requires \"graph\""),
            );
        };
        let Some(spec) = req.pattern.as_ref() else {
            return Response::error(
                &req.id,
                ProtoError::usage("missing-field", "query requires \"pattern\" or \"edges\""),
            );
        };
        let pattern = match resolve_pattern(spec) {
            Ok(p) => p,
            Err(e) => return Response::error(&req.id, e),
        };
        // traced queries get a private profile accumulator; recording
        // is purely observational, so counts are identical either way
        let trace = req.trace.then(|| Arc::new(QueryTrace::new()));
        let admit_t0 = trace.as_ref().map(|_| std::time::Instant::now());
        // admission before loading: an overloaded service must shed
        // work before materializing graphs for it
        let permit = match self.admission.admit(req.priority) {
            Ok(p) => p,
            Err(AdmitError::Overloaded { inflight, queued }) => {
                obs_registry::note_admission_shed();
                return Response::error(
                    &req.id,
                    ProtoError {
                        name: "overloaded",
                        detail: format!(
                            "{inflight} in flight, {queued} queued; retry later or raise \
                             SANDSLASH_MAX_INFLIGHT"
                        ),
                        code: CODE_OVERLOADED,
                    },
                )
            }
        };
        if let (Some(tr), Some(t0)) = (&trace, admit_t0) {
            tr.set_admission_wait(t0.elapsed().as_nanos() as u64);
        }
        let (g, epoch) = match self.registry.get(graph_name) {
            Ok(pair) => pair,
            Err(RegistryError::UnknownGraph(name)) => {
                return Response::error(
                    &req.id,
                    ProtoError {
                        name: "unknown-graph",
                        detail: format!("no dataset named {name:?} in the registry"),
                        code: 1,
                    },
                )
            }
        };
        self.queries.fetch_add(1, Ordering::Relaxed);
        // register the cancel token under the request id for the
        // lifetime of the run (the `cancel` op's lookup)
        let token = Arc::new(CancelToken::new());
        {
            let mut inflight = self.inflight.lock().unwrap();
            if inflight.contains_key(&req.id) {
                return Response::error(
                    &req.id,
                    ProtoError::usage(
                        "duplicate-id",
                        "a query with this id is already in flight",
                    ),
                );
            }
            inflight.insert(req.id.clone(), token.clone());
        }
        let _unregister = Unregister { service: self, id: &req.id };
        let key = CacheKey {
            graph: graph_name.to_string(),
            epoch,
            pattern: canonical_code(&pattern),
            vertex_induced: req.vertex_induced,
            hook: HookKind::Count,
        };
        // the compute closure smuggles its code/error past the cache's
        // (value, cacheable) signature; a cache hit leaves them at the
        // defaults, which is exact — only code-0 results are ever cached
        let code = std::cell::Cell::new(0i32);
        let err: std::cell::RefCell<Option<ProtoError>> = std::cell::RefCell::new(None);
        let compute = || {
            // install the query's trace for the engine run, so every
            // dispatch/sched/budget event lands in this query's profile
            let run = qtrace::with_optional(trace.clone(), || {
                self.execute(&g, &pattern, req, &token)
            });
            match run {
                Ok((fragment, c)) => {
                    code.set(c);
                    (Arc::new(fragment), c == 0)
                }
                Err(e) => {
                    code.set(e.code);
                    *err.borrow_mut() = Some(e);
                    (Arc::new(String::new()), false)
                }
            }
        };
        let (value, cached) = if req.no_cache {
            (compute().0, false)
        } else {
            self.cache.get_or_compute(&key, compute)
        };
        drop(permit);
        if let Some(tr) = &trace {
            tr.set_cache_verdict(if req.no_cache {
                CacheVerdict::Bypass
            } else if cached {
                CacheVerdict::Hit
            } else {
                CacheVerdict::Miss
            });
        }
        match (err.into_inner(), trace) {
            (Some(e), _) => Response::error(&req.id, e),
            (None, Some(tr)) => Response::ok_with_profile(
                &req.id,
                value,
                cached,
                code.get(),
                Some(epoch),
                tr.render(),
            ),
            (None, None) => Response::ok(&req.id, value, cached, code.get(), Some(epoch)),
        }
    }

    /// The governed engine run of one true cache miss.
    fn execute(
        &self,
        g: &CsrGraph,
        p: &Pattern,
        req: &Request,
        token: &Arc<CancelToken>,
    ) -> Result<(String, i32), ProtoError> {
        let mut cfg = MinerConfig::custom(
            req.threads.unwrap_or(self.cfg.default_threads),
            pool::default_chunk(),
            OptFlags::hi(),
        );
        cfg.budget = self.cfg.default_budget;
        if let Some(ms) = req.deadline_ms {
            cfg.budget.deadline = Some(Duration::from_millis(ms));
        }
        if let Some(n) = req.max_tasks {
            cfg.budget.max_tasks = Some(n);
        }
        // count-only queries go through the PR-10 decomposition
        // planner (enumerated oracle when inactive — answers are
        // bit-identical either way, which is what keeps the
        // canonical-code cache plan-agnostic). The scoped token
        // install is what makes `cancel` reach this run — and it is
        // scoped: it restores on exit, never leaking into whatever
        // query this pool thread serves next
        let run = budget::with_cancel(token.clone(), || {
            decompose::count_with_plan(g, p, req.vertex_induced, &cfg)
        });
        match run {
            Ok(out) => {
                let code = out.tripped.map(|r| r.exit_code()).unwrap_or(0);
                Ok((count_result(out.value, out.tripped), code))
            }
            Err(e) => Err(ProtoError {
                name: mine_error_name(&e),
                detail: e.to_string(),
                code: mine_error_code(&e),
            }),
        }
    }

    fn cancel(&self, req: &Request) -> Response {
        let Some(target) = req.target.as_deref() else {
            return Response::error(
                &req.id,
                ProtoError::usage("missing-field", "cancel requires \"target\""),
            );
        };
        // idempotent: a finished (or never-seen) target is not an error,
        // the caller just learns nothing was in flight to cancel
        let hit = match self.inflight.lock().unwrap().get(target) {
            Some(token) => {
                token.cancel();
                true
            }
            None => false,
        };
        ok_rendered(req, format!("{{\"cancelled\":{hit}}}"))
    }

    fn invalidate(&self, req: &Request) -> Response {
        let Some(graph) = req.graph.as_deref() else {
            return Response::error(
                &req.id,
                ProtoError::usage("missing-field", "invalidate requires \"graph\""),
            );
        };
        let epoch = self.registry.bump_epoch(graph);
        if epoch.is_some() {
            obs_registry::note_epoch_bump();
        }
        let purged = self.cache.purge_graph(graph);
        let epoch_json =
            epoch.map(|e| e.to_string()).unwrap_or_else(|| "null".to_string());
        ok_rendered(req, format!("{{\"epoch\":{epoch_json},\"purged\":{purged}}}"))
    }

    fn graphs(&self, req: &Request) -> Response {
        let rows: Vec<String> = self
            .registry
            .resident()
            .into_iter()
            .map(|(name, epoch, vertices, edges)| {
                format!(
                    "{{\"name\":\"{}\",\"epoch\":{epoch},\"vertices\":{vertices},\"edges\":{edges}}}",
                    super::json::escape(&name),
                )
            })
            .collect();
        ok_rendered(req, format!("{{\"graphs\":[{}]}}", rows.join(",")))
    }

    fn stats_op(&self, req: &Request) -> Response {
        let s = self.cache.stats();
        let (inflight, queued) = self.admission.snapshot();
        let snap = obs_registry::snapshot();
        let gauges = self.gauges();
        let mut out = format!(
            "{{\"queries\":{},\"inflight\":{inflight},\"queued\":{queued},\
             \"cache\":{{\"hits\":{},\"misses\":{},\"coalesced\":{},\"fills\":{},\
             \"rejected\":{},\"evictions\":{},\"invalidated\":{},\"bytes\":{},\
             \"entries\":{}}}",
            self.queries.load(Ordering::Relaxed),
            s.hits,
            s.misses,
            s.coalesced,
            s.fills,
            s.rejected,
            s.evictions,
            s.invalidated,
            self.cache.bytes(),
            self.cache.len(),
        );
        // unified registry families (PR 9): process-global dispatch,
        // scheduler, governance, and service counters
        let d = &snap.dispatch;
        out.push_str(&format!(
            ",\"dispatch\":{{\"merge\":{},\"gallop\":{},\"simd_merge\":{},\
             \"word_parallel\":{},\"mask_filter\":{},\"gather_filter\":{},\
             \"difference\":{}}}",
            d.merge, d.gallop, d.simd_merge, d.word_parallel, d.mask_filter, d.gather_filter,
            d.difference,
        ));
        out.push_str(&format!(
            ",\"sched\":{{\"claims\":{},\"steals\":{},\"shard_claims\":{},\"splits\":{}}}",
            snap.sched.claims, snap.sched.steals, snap.sched.shard_claims, snap.sched.splits,
        ));
        let gv = &snap.gov;
        out.push_str(&format!(
            ",\"gov\":{{\"deadline_trips\":{},\"task_budget_trips\":{},\"caller_trips\":{},\
             \"panic_trips\":{},\"panics_caught\":{},\"faults_injected\":{}}}",
            gv.deadline_trips,
            gv.task_budget_trips,
            gv.caller_trips,
            gv.panic_trips,
            gv.panics_caught,
            gv.faults_injected,
        ));
        let responses: Vec<String> =
            snap.service.responses.iter().map(|n| n.to_string()).collect();
        out.push_str(&format!(
            ",\"service\":{{\"responses\":[{}],\"admission_sheds\":{},\
             \"idle_timeout_closes\":{},\"epoch_bumps\":{}}}",
            responses.join(","),
            snap.service.admission_sheds,
            snap.service.idle_timeout_closes,
            snap.service.epoch_bumps,
        ));
        // Prometheus-style exposition of the same snapshot, embedded as
        // one escaped string so one op serves both surfaces
        out.push_str(&format!(
            ",\"exposition\":\"{}\"",
            super::json::escape(&obs_registry::exposition(&snap, Some(&gauges)))
        ));
        out.push('}');
        ok_rendered(req, out)
    }

    /// Live service gauges for the metrics exposition (the non-monotonic
    /// complement of the registry's counters).
    fn gauges(&self) -> obs_registry::ServiceGauges {
        let s = self.cache.stats();
        let (inflight, queued) = self.admission.snapshot();
        obs_registry::ServiceGauges {
            queries: self.queries.load(Ordering::Relaxed),
            inflight: inflight as u64,
            queued: queued as u64,
            cache_hits: s.hits,
            cache_misses: s.misses,
            cache_coalesced: s.coalesced,
            cache_fills: s.fills,
            cache_rejected: s.rejected,
            cache_evictions: s.evictions,
            cache_invalidated: s.invalidated,
            cache_bytes: self.cache.bytes() as u64,
            cache_entries: self.cache.len() as u64,
        }
    }
}

fn ok_fragment(req: &Request, fragment: &str) -> Response {
    Response::ok(&req.id, Arc::new(fragment.to_string()), false, 0, None)
}

fn ok_rendered(req: &Request, fragment: String) -> Response {
    Response::ok(&req.id, Arc::new(fragment), false, 0, None)
}

/// Removes the in-flight token entry when the query ends, however it
/// ends.
struct Unregister<'a> {
    service: &'a Service,
    id: &'a str,
}

impl Drop for Unregister<'_> {
    fn drop(&mut self) {
        self.service.inflight.lock().unwrap().remove(self.id);
    }
}
