//! TCP transport for the resident service: line in, line out.
//!
//! Std-only (`std::net`), loopback-oriented. One thread per connection
//! reads newline-delimited requests and writes one response line per
//! request — all the concurrency, admission, and governance lives in
//! [`Service`], so this file is deliberately thin plumbing. The
//! `shutdown` op flips the service flag; the connection that carried it
//! then pokes the listener with a loopback connect so the blocking
//! `accept` observes the flag (std has no portable non-blocking accept
//! without polling).
//!
//! A Unix-socket transport would be this same file with
//! `UnixListener`; TCP on `127.0.0.1` was chosen because it also works
//! in the CI smoke test without a filesystem rendezvous.
//!
//! **Idle read timeout** (PR 9): a resident process must not let an
//! abandoned client pin a connection thread forever. With
//! `SANDSLASH_IDLE_TIMEOUT_MS` set to a positive integer (unset = off,
//! the seed behaviour), each connection's blocking read carries that
//! timeout; a connection that stays silent past it is closed with the
//! named reason `idle-timeout`, counted in the unified metrics
//! registry ([`crate::obs::registry`]).

use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use super::core::Service;

/// A bound, not-yet-serving listener (bind first so the caller can
/// learn the ephemeral port before the accept loop starts).
pub struct Server {
    service: Arc<Service>,
    listener: TcpListener,
    addr: SocketAddr,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port).
    pub fn bind(service: Arc<Service>, addr: &str) -> io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(Self { service, listener, addr })
    }

    /// The bound address (the ephemeral port, for `--port-file` and the
    /// in-test client).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Accept connections until a `shutdown` op lands; joins every
    /// connection thread before returning.
    pub fn serve(self) -> io::Result<()> {
        let mut handles = Vec::new();
        for stream in self.listener.incoming() {
            if self.service.shutdown_requested() {
                break;
            }
            let stream = match stream {
                Ok(s) => s,
                // transient accept errors (aborted handshake) are not
                // service-fatal
                Err(_) => continue,
            };
            let service = self.service.clone();
            let addr = self.addr;
            handles.push(std::thread::spawn(move || serve_connection(service, stream, addr)));
            if self.service.shutdown_requested() {
                break;
            }
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

/// The idle read timeout from `SANDSLASH_IDLE_TIMEOUT_MS` (loud-reject
/// parse via the shared helper; unset = no timeout), resolved once per
/// process.
fn idle_timeout_ms() -> Option<u64> {
    static CACHE: OnceLock<Option<u64>> = OnceLock::new();
    *CACHE.get_or_init(|| {
        crate::util::pool::positive_usize_env(
            "SANDSLASH_IDLE_TIMEOUT_MS",
            "no idle timeout (idle connections stay open)",
        )
        .map(|ms| ms as u64)
    })
}

fn serve_connection(service: Arc<Service>, stream: TcpStream, addr: SocketAddr) {
    let idle = idle_timeout_ms();
    if let Some(ms) = idle {
        // a failed setsockopt leaves the seed blocking behaviour, which
        // is safe — the timeout is a hygiene bound, not a correctness one
        let _ = stream.set_read_timeout(Some(Duration::from_millis(ms)));
    }
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break, // clean EOF
            Ok(_) => {}
            // both kinds, because platforms disagree on which one a
            // read timeout surfaces as
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                crate::obs::registry::note_idle_timeout_close();
                eprintln!(
                    "sandslash: closing connection (reason=idle-timeout, no request within {}ms)",
                    idle.unwrap_or(0)
                );
                break;
            }
            Err(_) => break,
        }
        if line.trim().is_empty() {
            continue;
        }
        let response = service.handle_line(&line);
        if writer.write_all(response.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
            || writer.flush().is_err()
        {
            break;
        }
        if service.shutdown_requested() {
            // unblock the accept loop so Server::serve can wind down
            let _ = TcpStream::connect(addr);
            break;
        }
    }
}

/// One-shot client: send one request line, read one response line (the
/// `sandslash query` subcommand and the socket smoke test).
pub fn request_over_socket(addr: &str, line: &str) -> io::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let mut response = String::new();
    BufReader::new(stream).read_line(&mut response)?;
    Ok(response.trim_end().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::core::ServiceConfig;
    use crate::service::protocol::response_code;

    fn test_config() -> ServiceConfig {
        ServiceConfig {
            max_inflight: 2,
            max_queued: 8,
            cache_bytes: 1 << 20,
            default_threads: 2,
            default_budget: crate::engine::Budget::default(),
        }
    }

    #[test]
    fn socket_round_trip_and_shutdown() {
        if !crate::engine::budget::governance_enabled() {
            return; // the service refuses to start ungoverned
        }
        let service = Arc::new(Service::new(test_config()).unwrap());
        let server = Server::bind(service, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().to_string();
        let serving = std::thread::spawn(move || server.serve().unwrap());
        let pong =
            request_over_socket(&addr, "{\"id\":\"p\",\"op\":\"ping\"}").unwrap();
        assert!(pong.contains("\"pong\":true"), "{pong}");
        assert_eq!(response_code(&pong), Some(0));
        let q = request_over_socket(
            &addr,
            "{\"id\":\"q\",\"graph\":\"er-small\",\"pattern\":\"triangle\"}",
        )
        .unwrap();
        assert!(q.contains("\"count\":"), "{q}");
        assert!(q.contains("\"complete\":true"), "{q}");
        let bye =
            request_over_socket(&addr, "{\"id\":\"x\",\"op\":\"shutdown\"}").unwrap();
        assert!(bye.contains("\"shutdown\":true"), "{bye}");
        serving.join().unwrap();
    }
}
