//! Admission control: bounded in-flight queries, queue-or-reject.
//!
//! A resident process serving many tenants must bound *both* axes of
//! load: how many queries run at once (each run spawns its own worker
//! pool — unbounded concurrency would oversubscribe every query) and
//! how many may wait (an unbounded queue converts overload into
//! unbounded latency; rejecting at a depth bound keeps the tail
//! honest). [`Admission::admit`] blocks while a slot is pending and
//! returns [`AdmitError::Overloaded`] the moment the wait queue is
//! full — callers surface it as the `overloaded` protocol error
//! ([`crate::service::protocol::CODE_OVERLOADED`]) and clients retry.
//!
//! Two priority classes: when a slot frees, [`Priority::High`] waiters
//! go first; normal waiters only claim a slot while no high waiter is
//! queued. Within a class, wakeup order is the condvar's (fairness is
//! not guaranteed, starvation across classes is: high traffic can
//! starve normal traffic by design — the knob is the caller's).
//!
//! Knobs: `SANDSLASH_MAX_INFLIGHT` seeds
//! [`crate::service::ServiceConfig::from_env`] (loud-reject parse like
//! every `SANDSLASH_*` numeric knob); the queue bound is
//! `2 × max_inflight`, matching the classic "one running, one
//! waiting" provisioning rule.

// PR-8: the state mutex + slot condvar go through the sync facade so
// the loom suite can model-check the bounded-in-flight protocol
// (tests/loom/admission.rs proves inflight never exceeds
// max_inflight and permits are never lost).
use crate::util::sync::{Condvar, Mutex};

/// Admission priority class of one query.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Priority {
    /// Default class.
    #[default]
    Normal,
    /// Preferred class: claims freed slots before any normal waiter.
    High,
}

/// Why admission refused a query.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// In-flight and queued bounds were both at capacity.
    Overloaded {
        /// Queries running when the request was refused.
        inflight: usize,
        /// Queries waiting when the request was refused.
        queued: usize,
    },
}

#[derive(Default)]
struct State {
    inflight: usize,
    queued_normal: usize,
    queued_high: usize,
}

/// The admission gate (see the module docs).
pub struct Admission {
    max_inflight: usize,
    max_queued: usize,
    state: Mutex<State>,
    cv: Condvar,
}

/// An admitted query's slot; dropping it frees the slot and wakes
/// waiters.
pub struct Permit<'a> {
    gate: &'a Admission,
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        let mut s = self.gate.state.lock().unwrap();
        s.inflight -= 1;
        drop(s);
        self.gate.cv.notify_all();
    }
}

impl Admission {
    /// A gate admitting `max_inflight` concurrent queries and queueing
    /// up to `max_queued` more (both clamped to ≥ 1).
    pub fn new(max_inflight: usize, max_queued: usize) -> Self {
        Self {
            max_inflight: max_inflight.max(1),
            max_queued: max_queued.max(1),
            state: Mutex::new(State::default()),
            cv: Condvar::new(),
        }
    }

    /// Admit one query: returns a [`Permit`] (possibly after waiting in
    /// the bounded queue) or [`AdmitError::Overloaded`] immediately
    /// when the queue is full — never an unbounded wait behind an
    /// unbounded line.
    pub fn admit(&self, priority: Priority) -> Result<Permit<'_>, AdmitError> {
        let mut s = self.state.lock().unwrap();
        if !self.can_claim(&s, priority) {
            if s.queued_normal + s.queued_high >= self.max_queued {
                return Err(AdmitError::Overloaded {
                    inflight: s.inflight,
                    queued: s.queued_normal + s.queued_high,
                });
            }
            match priority {
                Priority::Normal => s.queued_normal += 1,
                Priority::High => s.queued_high += 1,
            }
            while !self.can_claim(&s, priority) {
                s = self.cv.wait(s).unwrap();
            }
            match priority {
                Priority::Normal => s.queued_normal -= 1,
                Priority::High => s.queued_high -= 1,
            }
        }
        s.inflight += 1;
        Ok(Permit { gate: self })
    }

    fn can_claim(&self, s: &State, priority: Priority) -> bool {
        s.inflight < self.max_inflight
            && (priority == Priority::High || s.queued_high == 0)
    }

    /// `(inflight, queued)` right now (the `stats` op).
    pub fn snapshot(&self) -> (usize, usize) {
        let s = self.state.lock().unwrap();
        (s.inflight, s.queued_normal + s.queued_high)
    }

    /// The in-flight bound.
    pub fn max_inflight(&self) -> usize {
        self.max_inflight
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn bounds_inflight_and_rejects_past_queue_depth() {
        let gate = Arc::new(Admission::new(1, 1));
        let p1 = gate.admit(Priority::Normal).unwrap();
        // one waiter fits in the queue...
        let g2 = gate.clone();
        let waiter = std::thread::spawn(move || {
            let _p = g2.admit(Priority::Normal).unwrap();
        });
        // ...wait until it is actually queued, then the next is refused
        while gate.snapshot().1 == 0 {
            std::thread::yield_now();
        }
        assert!(matches!(
            gate.admit(Priority::Normal),
            Err(AdmitError::Overloaded { inflight: 1, queued: 1 })
        ));
        drop(p1);
        waiter.join().unwrap();
        assert_eq!(gate.snapshot(), (0, 0));
    }

    #[test]
    fn high_priority_claims_freed_slots_first() {
        let gate = Arc::new(Admission::new(1, 8));
        let permit = gate.admit(Priority::Normal).unwrap();
        let order = Arc::new(AtomicUsize::new(0));
        let mut first_of = Vec::new();
        let mut handles = Vec::new();
        // queue normals first, then a high
        for prio in [Priority::Normal, Priority::Normal, Priority::High] {
            let (g, ord) = (gate.clone(), order.clone());
            let slot = Arc::new(AtomicUsize::new(usize::MAX));
            if prio == Priority::High {
                first_of.push(slot.clone());
            }
            // make sure each waiter is queued before spawning the next
            let before = g.snapshot().1;
            handles.push(std::thread::spawn(move || {
                let _p = g.admit(prio).unwrap();
                slot.store(ord.fetch_add(1, Ordering::SeqCst), Ordering::SeqCst);
                std::thread::sleep(Duration::from_millis(1));
            }));
            while gate.snapshot().1 == before {
                std::thread::yield_now();
            }
        }
        drop(permit);
        for h in handles {
            h.join().unwrap();
        }
        // the high-priority waiter ran before both queued normals
        assert_eq!(first_of[0].load(Ordering::SeqCst), 0);
    }
}
