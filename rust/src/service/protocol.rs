//! The line-delimited JSON protocol of the resident service.
//!
//! One request per line, one response line per request, over any byte
//! stream (the TCP listener in [`super::net`], or no transport at all —
//! the in-process test harness calls [`crate::service::Service::handle`]
//! with parsed [`Request`]s directly).
//!
//! # Request grammar
//!
//! ```json
//! {"id":"q1","op":"query","graph":"er-small","pattern":"diamond",
//!  "induced":false,"deadline_ms":50,"max_tasks":100,"threads":2,
//!  "priority":"high","no_cache":false}
//! ```
//!
//! * `id` (required): caller-chosen correlation token, echoed back.
//! * `op` (default `"query"`): `query`, `cancel` (with `target` naming
//!   the in-flight query id), `invalidate` (with `graph`; bumps the
//!   graph epoch), `graphs`, `stats`, `ping`, `shutdown`.
//! * `pattern` names a library pattern (see [`resolve_pattern`]), or
//!   `edges` gives an explicit list `[[0,1],[1,2],...]` (≤ 8 vertices,
//!   simple, connected). Both forms canonicalize to the same cache key.
//! * `induced` selects vertex-induced matching (default `false` =
//!   edge-induced, the SL semantics).
//! * `deadline_ms` / `max_tasks` set the per-query [`Budget`]
//!   (`deadline_ms: 0` is accepted and trips at the first poll site —
//!   useful for testing the partial-result path deterministically).
//! * `trace` (default `false`) attaches a per-query
//!   [`QueryTrace`](crate::obs::trace::QueryTrace) profile to the
//!   response as a `"profile"` object (PR 9) — recording is purely
//!   observational, so the counts are bit-identical either way.
//! * Unknown fields are **rejected** (`unknown-field`), not ignored: a
//!   typo'd budget knob silently ignored would be an unbounded query.
//!
//! # Response grammar
//!
//! ```json
//! {"id":"q1","ok":true,"code":0,"cached":false,"epoch":0,
//!  "result":{"count":1136,"complete":true,"tripped":null}}
//! {"id":"q1","ok":false,"code":2,"error":"bad-field","detail":"..."}
//! ```
//!
//! `code` carries the PR-6 CLI exit-code table as a *structured field*
//! (the process never exits): 0 complete, 1 load/internal, 2 malformed
//! request, 3 BFS cap, 4 worker panic, 5 deadline, 6 task budget,
//! 7 caller cancel — the numbers are delegated to
//! [`CancelReason::exit_code`] / [`MineError::exit_code`] so the two
//! tables cannot drift — plus the service-only 8 (admission rejected
//! the query: queue full).
//!
//! [`Budget`]: crate::engine::Budget
//! [`MineError::exit_code`]: crate::engine::MineError::exit_code

use std::sync::Arc;

use super::admission::Priority;
use super::json::{self, JsonValue};
use crate::engine::{CancelReason, MineError};
use crate::pattern::{library, Pattern};

/// Admission rejected the query (bounded queue full) — the only
/// response code not in the PR-6 CLI exit table, which stops at 7.
pub const CODE_OVERLOADED: i32 = 8;

/// Largest pattern the service accepts: the canonical-code domain
/// ([`crate::pattern::canonical_code`] covers ≤ 8 vertices), which the
/// result-cache key is built on.
pub const MAX_SERVICE_PATTERN_VERTICES: usize = 8;

/// A named protocol error: the stable `name` is the machine-readable
/// contract (asserted by the golden tests), `detail` is for humans,
/// `code` is the structured response code.
#[derive(Clone, Debug, PartialEq)]
pub struct ProtoError {
    /// Stable machine-readable error name (e.g. `"bad-field"`).
    pub name: &'static str,
    /// Human-readable detail; never load-bearing.
    pub detail: String,
    /// Structured response code (the PR-6 exit-code table, plus
    /// [`CODE_OVERLOADED`]).
    pub code: i32,
}

impl ProtoError {
    /// A malformed-request error (code 2, the CLI usage code).
    pub fn usage(name: &'static str, detail: impl Into<String>) -> Self {
        Self { name, detail: detail.into(), code: 2 }
    }
}

/// Request operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Run a pattern query (the default).
    Query,
    /// Cancel the in-flight query named by `target`.
    Cancel,
    /// Bump the named graph's epoch, invalidating its cache entries.
    Invalidate,
    /// List resident graphs and their epochs.
    Graphs,
    /// Service counters: cache stats, admission state, queries served.
    Stats,
    /// Liveness probe.
    Ping,
    /// Ask the listener to stop accepting connections.
    Shutdown,
}

impl Op {
    fn name(self) -> &'static str {
        match self {
            Op::Query => "query",
            Op::Cancel => "cancel",
            Op::Invalidate => "invalidate",
            Op::Graphs => "graphs",
            Op::Stats => "stats",
            Op::Ping => "ping",
            Op::Shutdown => "shutdown",
        }
    }
}

/// How the query names its pattern.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PatternSpec {
    /// A library pattern by name (see [`resolve_pattern`]).
    Named(String),
    /// An explicit edge list (validated in [`resolve_pattern`]).
    Edges(Vec<(usize, usize)>),
}

/// One parsed request line. Constructed by [`parse_request`] (the wire
/// path) or directly (the in-process test harness); [`Request::render`]
/// and [`parse_request`] round-trip.
#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    /// Caller-chosen correlation id, echoed in the response.
    pub id: String,
    /// Operation (default `query`).
    pub op: Op,
    /// Graph name (`query`, `invalidate`).
    pub graph: Option<String>,
    /// Pattern (`query`).
    pub pattern: Option<PatternSpec>,
    /// Vertex-induced matching (default edge-induced).
    pub vertex_induced: bool,
    /// Per-query deadline override (`0` trips at the first poll).
    pub deadline_ms: Option<u64>,
    /// Per-query task-budget override.
    pub max_tasks: Option<u64>,
    /// Per-query worker-thread override.
    pub threads: Option<usize>,
    /// Admission priority (default [`Priority::Normal`]).
    pub priority: Priority,
    /// Bypass the result cache for this query.
    pub no_cache: bool,
    /// Attach a per-query trace profile to the response (PR 9).
    pub trace: bool,
    /// Target query id (`cancel`).
    pub target: Option<String>,
}

impl Request {
    /// A plain query for `pattern` on `graph`, defaults elsewhere.
    pub fn query(id: &str, graph: &str, pattern: PatternSpec) -> Self {
        Self {
            id: id.to_string(),
            op: Op::Query,
            graph: Some(graph.to_string()),
            pattern: Some(pattern),
            vertex_induced: false,
            deadline_ms: None,
            max_tasks: None,
            threads: None,
            priority: Priority::Normal,
            no_cache: false,
            trace: false,
            target: None,
        }
    }

    /// A bare non-query operation.
    pub fn bare(id: &str, op: Op) -> Self {
        Self { op, graph: None, pattern: None, ..Self::query(id, "", PatternSpec::Named(String::new())) }
    }

    /// Render as one protocol line (no trailing newline). Fields at
    /// their defaults are omitted, so `parse_request(render(r)) == r`.
    pub fn render(&self) -> String {
        let mut out = format!("{{\"id\":\"{}\"", json::escape(&self.id));
        out.push_str(&format!(",\"op\":\"{}\"", self.op.name()));
        if let Some(g) = &self.graph {
            out.push_str(&format!(",\"graph\":\"{}\"", json::escape(g)));
        }
        match &self.pattern {
            Some(PatternSpec::Named(name)) => {
                out.push_str(&format!(",\"pattern\":\"{}\"", json::escape(name)));
            }
            Some(PatternSpec::Edges(edges)) => {
                let body: Vec<String> =
                    edges.iter().map(|&(u, v)| format!("[{u},{v}]")).collect();
                out.push_str(&format!(",\"edges\":[{}]", body.join(",")));
            }
            None => {}
        }
        if self.vertex_induced {
            out.push_str(",\"induced\":true");
        }
        if let Some(ms) = self.deadline_ms {
            out.push_str(&format!(",\"deadline_ms\":{ms}"));
        }
        if let Some(n) = self.max_tasks {
            out.push_str(&format!(",\"max_tasks\":{n}"));
        }
        if let Some(t) = self.threads {
            out.push_str(&format!(",\"threads\":{t}"));
        }
        if self.priority == Priority::High {
            out.push_str(",\"priority\":\"high\"");
        }
        if self.no_cache {
            out.push_str(",\"no_cache\":true");
        }
        if self.trace {
            out.push_str(",\"trace\":true");
        }
        if let Some(t) = &self.target {
            out.push_str(&format!(",\"target\":\"{}\"", json::escape(t)));
        }
        out.push('}');
        out
    }
}

/// Parse one request line. Every rejection carries a stable error name
/// (`malformed-json`, `not-an-object`, `missing-field`, `bad-field`,
/// `unknown-field`, `unknown-op`) and code 2.
pub fn parse_request(line: &str) -> Result<Request, ProtoError> {
    let v = json::parse(line)
        .map_err(|e| ProtoError::usage("malformed-json", e.to_string()))?;
    let JsonValue::Obj(pairs) = &v else {
        return Err(ProtoError::usage("not-an-object", "request must be a JSON object"));
    };
    let id = match v.get("id").and_then(|x| x.as_str()) {
        Some(s) if !s.is_empty() && s.len() <= 128 => s.to_string(),
        Some(_) => {
            return Err(ProtoError::usage("bad-field", "id must be 1..=128 characters"))
        }
        None => return Err(ProtoError::usage("missing-field", "id (string) is required")),
    };
    let op = match v.get("op").map(|x| x.as_str()) {
        None => Op::Query,
        Some(Some("query")) => Op::Query,
        Some(Some("cancel")) => Op::Cancel,
        Some(Some("invalidate")) => Op::Invalidate,
        Some(Some("graphs")) => Op::Graphs,
        Some(Some("stats")) => Op::Stats,
        Some(Some("ping")) => Op::Ping,
        Some(Some("shutdown")) => Op::Shutdown,
        Some(Some(other)) => {
            return Err(ProtoError::usage("unknown-op", format!("op {other:?}")))
        }
        Some(None) => return Err(ProtoError::usage("bad-field", "op must be a string")),
    };
    let mut req = Request {
        id,
        op,
        graph: None,
        pattern: None,
        vertex_induced: false,
        deadline_ms: None,
        max_tasks: None,
        threads: None,
        priority: Priority::Normal,
        no_cache: false,
        trace: false,
        target: None,
    };
    for (key, val) in pairs {
        match key.as_str() {
            "id" | "op" => {}
            "graph" => match val.as_str() {
                Some(s) if !s.is_empty() => req.graph = Some(s.to_string()),
                _ => {
                    return Err(ProtoError::usage("bad-field", "graph must be a non-empty string"))
                }
            },
            "pattern" => match val.as_str() {
                Some(s) => req.pattern = Some(PatternSpec::Named(s.to_string())),
                None => {
                    return Err(ProtoError::usage("bad-field", "pattern must be a string"))
                }
            },
            "edges" => req.pattern = Some(PatternSpec::Edges(parse_edges(val)?)),
            "induced" => match val.as_bool() {
                Some(b) => req.vertex_induced = b,
                None => {
                    return Err(ProtoError::usage("bad-field", "induced must be a boolean"))
                }
            },
            "deadline_ms" => match val.as_u64() {
                Some(ms) => req.deadline_ms = Some(ms),
                None => {
                    return Err(ProtoError::usage(
                        "bad-field",
                        "deadline_ms must be a non-negative integer",
                    ))
                }
            },
            "max_tasks" => match val.as_u64() {
                Some(n) if n > 0 => req.max_tasks = Some(n),
                _ => {
                    return Err(ProtoError::usage(
                        "bad-field",
                        "max_tasks must be a positive integer",
                    ))
                }
            },
            "threads" => match val.as_u64() {
                Some(t) if (1..=256).contains(&t) => req.threads = Some(t as usize),
                _ => {
                    return Err(ProtoError::usage("bad-field", "threads must be in 1..=256"))
                }
            },
            "priority" => match val.as_str() {
                Some("normal") => req.priority = Priority::Normal,
                Some("high") => req.priority = Priority::High,
                _ => {
                    return Err(ProtoError::usage(
                        "bad-field",
                        "priority must be \"normal\" or \"high\"",
                    ))
                }
            },
            "no_cache" => match val.as_bool() {
                Some(b) => req.no_cache = b,
                None => {
                    return Err(ProtoError::usage("bad-field", "no_cache must be a boolean"))
                }
            },
            "trace" => match val.as_bool() {
                Some(b) => req.trace = b,
                None => {
                    return Err(ProtoError::usage("bad-field", "trace must be a boolean"))
                }
            },
            "target" => match val.as_str() {
                Some(s) if !s.is_empty() => req.target = Some(s.to_string()),
                _ => {
                    return Err(ProtoError::usage("bad-field", "target must be a non-empty string"))
                }
            },
            other => {
                return Err(ProtoError::usage(
                    "unknown-field",
                    format!("unknown field {other:?} (rejected, not ignored)"),
                ))
            }
        }
    }
    Ok(req)
}

fn parse_edges(val: &JsonValue) -> Result<Vec<(usize, usize)>, ProtoError> {
    let bad = || ProtoError::usage("bad-edges", "edges must be [[u,v],...] of integers");
    let rows = val.as_array().ok_or_else(bad)?;
    let mut edges = Vec::with_capacity(rows.len());
    for row in rows {
        let pair = row.as_array().ok_or_else(bad)?;
        if pair.len() != 2 {
            return Err(bad());
        }
        let u = pair[0].as_u64().ok_or_else(bad)?;
        let v = pair[1].as_u64().ok_or_else(bad)?;
        edges.push((u as usize, v as usize));
    }
    Ok(edges)
}

/// Resolve a [`PatternSpec`] to a validated [`Pattern`].
///
/// Named patterns: `triangle`, `wedge`, `diamond`, `tailed-triangle`,
/// `4path`, `4star`, `4cycle`, `5cycle`, `4clique`, `5clique`.
/// Explicit edge lists must be simple (no self-loops or duplicates),
/// connected, and span ≤ [`MAX_SERVICE_PATTERN_VERTICES`] vertices —
/// the canonical-code domain the cache key lives in.
pub fn resolve_pattern(spec: &PatternSpec) -> Result<Pattern, ProtoError> {
    match spec {
        PatternSpec::Named(name) => match name.as_str() {
            "triangle" => Ok(library::triangle()),
            "wedge" => Ok(library::wedge()),
            "diamond" => Ok(library::diamond()),
            "tailed-triangle" => Ok(library::tailed_triangle()),
            "4path" => Ok(library::path(4)),
            "4star" => Ok(library::star(3)),
            "4cycle" => Ok(library::cycle(4)),
            "5cycle" => Ok(library::cycle(5)),
            "4clique" => Ok(library::clique(4)),
            "5clique" => Ok(library::clique(5)),
            other => Err(ProtoError::usage(
                "unknown-pattern",
                format!(
                    "pattern {other:?}; known: triangle wedge diamond tailed-triangle \
                     4path 4star 4cycle 5cycle 4clique 5clique (or explicit \"edges\")"
                ),
            )),
        },
        PatternSpec::Edges(edges) => {
            let bad = |detail: String| ProtoError::usage("bad-edges", detail);
            if edges.is_empty() {
                return Err(bad("edge list is empty".into()));
            }
            let n = edges.iter().map(|&(u, v)| u.max(v) + 1).max().unwrap();
            if n > MAX_SERVICE_PATTERN_VERTICES {
                return Err(bad(format!(
                    "pattern spans {n} vertices; the service caps at \
                     {MAX_SERVICE_PATTERN_VERTICES} (canonical-code domain)"
                )));
            }
            let mut seen = std::collections::HashSet::new();
            for &(u, v) in edges {
                if u == v {
                    return Err(bad(format!("self-loop ({u},{v})")));
                }
                if !seen.insert((u.min(v), u.max(v))) {
                    return Err(bad(format!("duplicate edge ({u},{v})")));
                }
            }
            let p = Pattern::from_edges(edges);
            if !p.is_connected() {
                return Err(bad("pattern must be connected".into()));
            }
            Ok(p)
        }
    }
}

/// The stable wire name of a budget trip (`result.tripped`), matching
/// the knob vocabulary of [`CancelReason::diagnosis`].
pub fn trip_name(reason: CancelReason) -> &'static str {
    match reason {
        CancelReason::Deadline => "deadline",
        CancelReason::TaskBudget => "task-budget",
        CancelReason::Caller => "caller",
        CancelReason::WorkerPanic => "worker-panic",
    }
}

/// Render the cacheable result fragment of a count query. This exact
/// string is what the result cache stores and what cache hits replay —
/// the byte-equality contract of the concurrency suite.
pub fn count_result(count: u64, tripped: Option<CancelReason>) -> String {
    match tripped {
        None => format!("{{\"count\":{count},\"complete\":true,\"tripped\":null}}"),
        Some(r) => format!(
            "{{\"count\":{count},\"complete\":false,\"tripped\":\"{}\"}}",
            trip_name(r)
        ),
    }
}

/// The structured response code of an engine error — delegated to
/// [`MineError::exit_code`] so the wire table and the PR-6 CLI exit
/// table are the same table.
pub fn mine_error_code(e: &MineError) -> i32 {
    e.exit_code()
}

/// The stable wire name of an engine error.
pub fn mine_error_name(e: &MineError) -> &'static str {
    match e {
        MineError::BfsCapExceeded(_) => "bfs-cap",
        MineError::WorkerPanicked { .. } => "worker-panic",
    }
}

/// One response line.
#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    /// Echo of the request id (`"?"` when the request had none).
    pub id: String,
    /// Success or named failure.
    pub body: Body,
}

/// Response payload.
#[derive(Clone, Debug, PartialEq)]
pub enum Body {
    /// A successful operation. `result` is the pre-rendered fragment
    /// (shared with the cache — an `Arc` so hits are the same bytes).
    Ok {
        /// Pre-rendered result object fragment.
        result: Arc<String>,
        /// Whether the fragment came from the result cache.
        cached: bool,
        /// Structured code (0 complete; 5/6/7 = tripped partial).
        code: i32,
        /// Graph epoch the result was computed against (queries only).
        epoch: Option<u64>,
        /// Pre-rendered per-query trace profile (traced queries only,
        /// PR 9) — rendered after `result` so untraced responses are
        /// byte-identical to the pre-trace wire format.
        profile: Option<String>,
    },
    /// A named failure.
    Err(ProtoError),
}

impl Response {
    /// A successful response.
    pub fn ok(id: &str, result: Arc<String>, cached: bool, code: i32, epoch: Option<u64>) -> Self {
        Self { id: id.to_string(), body: Body::Ok { result, cached, code, epoch, profile: None } }
    }

    /// A successful response carrying a rendered trace profile (PR 9).
    pub fn ok_with_profile(
        id: &str,
        result: Arc<String>,
        cached: bool,
        code: i32,
        epoch: Option<u64>,
        profile: String,
    ) -> Self {
        Self {
            id: id.to_string(),
            body: Body::Ok { result, cached, code, epoch, profile: Some(profile) },
        }
    }

    /// A named-error response.
    pub fn error(id: &str, e: ProtoError) -> Self {
        Self { id: id.to_string(), body: Body::Err(e) }
    }

    /// The structured response code.
    pub fn code(&self) -> i32 {
        match &self.body {
            Body::Ok { code, .. } => *code,
            Body::Err(e) => e.code,
        }
    }

    /// Render as one protocol line (no trailing newline).
    pub fn render(&self) -> String {
        match &self.body {
            Body::Ok { result, cached, code, epoch, profile } => {
                let epoch_part = match epoch {
                    Some(e) => format!(",\"epoch\":{e}"),
                    None => String::new(),
                };
                let profile_part = match profile {
                    Some(p) => format!(",\"profile\":{p}"),
                    None => String::new(),
                };
                format!(
                    "{{\"id\":\"{}\",\"ok\":true,\"code\":{code},\"cached\":{cached}{epoch_part},\"result\":{result}{profile_part}}}",
                    json::escape(&self.id),
                )
            }
            Body::Err(e) => format!(
                "{{\"id\":\"{}\",\"ok\":false,\"code\":{},\"error\":\"{}\",\"detail\":\"{}\"}}",
                json::escape(&self.id),
                e.code,
                e.name,
                json::escape(&e.detail),
            ),
        }
    }
}

/// Pull the structured `code` field out of a rendered response line
/// (the CLI client exits with it, mirroring the one-shot commands).
pub fn response_code(line: &str) -> Option<i32> {
    let v = json::parse(line).ok()?;
    v.get("code")?.as_u64().map(|c| c as i32)
}
