//! Sandslash CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!   gen <kind> --out <file> [--scale N --ef N --seed N --labels N]
//!   stats   --graph <name|file>
//!   tc      --graph <name|file> [--system S]
//!   clique  --graph <name|file> --k K [--lo] [--system S]
//!   motif   --graph <name|file> --k K [--lo] [--system S]
//!   sl      --graph <name|file> --pattern diamond|4cycle [--system S]
//!   fsm     --graph <name|file> --k K --sigma S [--bfs|--peregrine]
//!   accel   --graph <name|file> [--artifacts DIR] [--motif4]
//!   campaign <table5|table6|table7|table8|table9|fig8|fig9|fig10|fig11|scaling|all>
//!   serve   [--addr A] [--port-file F] [--max-inflight N] [--cache-bytes N]
//!           [--threads N] [--preload g1,g2]
//!   query   --addr A|--port-file F [--id I] [--op OP] [--graph G]
//!           [--pattern P] [--induced] [--deadline-ms N] [--max-tasks N]
//!           [--threads N] [--high] [--no-cache] [--trace] [--stats]
//!           [--target ID] [--line JSON]
//!
//! `--graph` accepts a registered dataset name (see coordinator::datasets)
//! or a path to an edge-list / .csr snapshot file.
//!
//! Global scheduler flags (any subcommand): `--no-steal` pins the run
//! to the global-cursor scheduling oracle, `--shards N` overrides the
//! detected locality shard count (PR 4; see `sandslash::exec`), and
//! `--no-extcore` pins the ESU/BFS/FSM engines to their seed scalar
//! extension oracles (PR 5; see `sandslash::engine::extend`), and
//! `--no-plan` pins count-only queries to the enumerated counting
//! oracle instead of the decomposition planner (PR 10; see
//! `sandslash::pattern::decompose` — the process-wide equivalents are
//! `SANDSLASH_NO_STEAL=1` / `SANDSLASH_NO_EXTCORE=1` /
//! `SANDSLASH_NO_PLAN=1`).
//!
//! Governance flags (PR 6, any mining subcommand): `--deadline-ms N`
//! bounds the run's wall clock, `--max-tasks N` bounds its scheduler
//! task count (env equivalents `SANDSLASH_DEADLINE_MS` /
//! `SANDSLASH_MAX_TASKS`). A tripped budget still prints the partial
//! counts, then exits nonzero. Exit codes: 0 complete, 1 load/internal
//! error, 2 usage, 3 BFS level cap, 4 worker panic, 5 deadline,
//! 6 task budget, 7 caller cancel.
//!
//! Resident service (PR 7): `serve` starts the long-lived multi-tenant
//! query process (see `sandslash::service`); `query` is the one-shot
//! line client, exiting with the response's structured `code` — the
//! same table as above, plus 8 = admission rejected (overloaded).
//!
//! Observability (PR 9): `--profile <path>` on any subcommand wraps the
//! whole run in a [`QueryTrace`](sandslash::obs::trace::QueryTrace) and
//! writes the JSON profile to `<path>`; `query --trace` asks the
//! service to attach the same profile to its response, and
//! `query --stats` fetches the `stats` op and prints the unified
//! registry's Prometheus-style exposition.

use sandslash::apps::baselines::emulation::{self, System};
use sandslash::apps::{clique, fsm_app, motif, sl, tc};
use sandslash::coordinator::{campaign, datasets};
use sandslash::engine::{MineError, MinerConfig, OptFlags, Outcome};
use sandslash::exec::sched::{self, Overrides};
use sandslash::graph::{gen, io, stats, CsrGraph};
use sandslash::pattern::library;
use sandslash::util::cli::Args;
use sandslash::util::metrics::SearchStats;
use sandslash::util::timer::{fmt_secs, timed};

fn main() {
    let args = Args::from_env();
    let code = run(&args);
    std::process::exit(code);
}

fn run(args: &Args) -> i32 {
    // Scheduler flags apply through scoped overrides around the whole
    // dispatch: the hand-tuned apps (tc_hi, clique DAG loops, motif
    // formulas) reach the scheduler through the `util::pool` adapters,
    // which never see `MinerConfig::steal`/`shards` — only the
    // overrides (and the env kill switch) reach every path.
    let dispatch = || {
        sched::with_overrides(sched_overrides(args), || match args.subcommand.as_deref() {
            Some("gen") => cmd_gen(args),
            Some("stats") => cmd_stats(args),
            Some("tc") => cmd_tc(args),
            Some("clique") => cmd_clique(args),
            Some("motif") => cmd_motif(args),
            Some("sl") => cmd_sl(args),
            Some("fsm") => cmd_fsm(args),
            Some("accel") => cmd_accel(args),
            Some("campaign") => cmd_campaign(args),
            Some("serve") => cmd_serve(args),
            Some("query") => cmd_query(args),
            _ => {
                eprintln!("{}", USAGE);
                2
            }
        })
    };
    // --profile <path> (PR 9): trace the whole one-shot run and write
    // the JSON profile; recording is observational, counts unchanged
    let Some(path) = args.get("profile") else { return dispatch() };
    let trace = std::sync::Arc::new(sandslash::obs::trace::QueryTrace::new());
    let code = sandslash::obs::trace::with_trace(trace.clone(), dispatch);
    match std::fs::write(path, format!("{}\n", trace.render())) {
        Ok(()) => {
            eprintln!("sandslash: wrote profile to {path}");
            code
        }
        Err(e) => {
            eprintln!("sandslash: write profile {path}: {e}");
            if code == 0 {
                1
            } else {
                code
            }
        }
    }
}

/// Scheduler knobs (PR 4): `--no-steal` pins the run to the
/// global-cursor oracle, `--shards N` overrides topology detection.
/// An unusable `--shards` value is rejected *loudly*, matching the
/// `SANDSLASH_SHARDS` contract — never silently applied or dropped.
fn sched_overrides(args: &Args) -> Overrides {
    let steal = args.flag("no-steal").then_some(false);
    let shards = args.get("shards").and_then(|raw| match raw.trim().parse::<usize>() {
        Ok(n) if n > 0 => Some(n),
        _ => {
            eprintln!(
                "sandslash: ignoring --shards {raw:?} (must be a positive integer); \
                 using the detected topology"
            );
            None
        }
    });
    Overrides { steal, shards }
}

const USAGE: &str = "sandslash <gen|stats|tc|clique|motif|sl|fsm|accel|campaign|serve|query> [options]\n\
    see rust/src/main.rs header for per-command options";

fn load_graph(args: &Args) -> Option<CsrGraph> {
    let name = args.get_or("graph", "er-small");
    if let Some(g) = datasets::load(name) {
        return Some(g);
    }
    let path = std::path::Path::new(name);
    if !path.exists() {
        eprintln!("unknown graph '{name}' (not a dataset name or file)");
        return None;
    }
    let res = if name.ends_with(".csr") {
        io::load_snapshot(path)
    } else {
        io::load_edge_list(path)
    };
    match res {
        Ok(g) => Some(g),
        Err(e) => {
            eprintln!("failed to load {name}: {e}");
            None
        }
    }
}

fn config(args: &Args) -> MinerConfig {
    let opts = if args.flag("lo") { OptFlags::lo() } else { OptFlags::hi() };
    let mut cfg = MinerConfig::new(opts);
    if let Some(t) = args.get("threads") {
        cfg.threads = t.parse().unwrap_or(cfg.threads);
    }
    // mirror the scheduler flags into the per-run config too (the
    // scoped overrides from `run` are what the adapter paths obey;
    // keeping the config in sync makes Debug dumps tell the truth —
    // invalid `--shards` values already warned loudly in
    // `sched_overrides`, so the mirror stays quiet)
    if args.flag("no-steal") {
        cfg.steal = false;
    }
    if let Some(n) = args
        .get("shards")
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
    {
        cfg.shards = Some(n);
    }
    // extension-core oracle pin (PR 5): unlike the scheduler flags this
    // is a per-run OptFlags field, so the config edit is the whole story
    if args.flag("no-extcore") {
        cfg.opts.extcore = false;
    }
    // counting-planner oracle pin (PR 10): same per-run contract
    if args.flag("no-plan") {
        cfg.opts.plan = false;
    }
    // governance budgets (PR 6): CLI flags override the env defaults
    // already resolved by Budget::from_env; unusable values are
    // rejected loudly, matching the --shards contract
    if let Some(raw) = args.get("deadline-ms") {
        match raw.trim().parse::<u64>() {
            Ok(n) if n > 0 => {
                cfg.budget.deadline = Some(std::time::Duration::from_millis(n));
            }
            _ => eprintln!(
                "sandslash: ignoring --deadline-ms {raw:?} (must be a positive integer); \
                 running without a deadline"
            ),
        }
    }
    if let Some(raw) = args.get("max-tasks") {
        match raw.trim().parse::<u64>() {
            Ok(n) if n > 0 => cfg.budget.max_tasks = Some(n),
            _ => eprintln!(
                "sandslash: ignoring --max-tasks {raw:?} (must be a positive integer); \
                 running without a task budget"
            ),
        }
    }
    cfg
}

/// Unwrap a governed mining result for the CLI: an engine error prints
/// its one-line diagnosis and yields its distinct exit code
/// (`Err(code)`); a budget trip prints the [`CancelReason::diagnosis`]
/// naming the knob to raise and hands the partial value back with the
/// trip's nonzero exit code — the caller still prints the partial
/// answer before exiting.
///
/// [`CancelReason::diagnosis`]: sandslash::engine::CancelReason::diagnosis
fn governed<T>(res: Result<Outcome<T>, MineError>) -> Result<(T, i32), i32> {
    match res {
        Err(e) => {
            eprintln!("sandslash: {e}");
            Err(e.exit_code())
        }
        Ok(out) => {
            let code = match out.tripped {
                Some(reason) => {
                    eprintln!("sandslash: {}", reason.diagnosis());
                    reason.exit_code()
                }
                None => 0,
            };
            Ok((out.value, code))
        }
    }
}

fn system(args: &Args) -> System {
    match args.get_or("system", "hi") {
        "lo" => System::SandslashLo,
        "automine" => System::AutomineLike,
        "pangolin" => System::PangolinLike,
        "peregrine" => System::PeregrineLike,
        _ => System::SandslashHi,
    }
}

fn cmd_gen(args: &Args) -> i32 {
    let kind = args.positional.first().map(|s| s.as_str()).unwrap_or("rmat");
    let seed = args.get_u64("seed", 42);
    let label_pool: Vec<u32> = (1..=args.get_u64("labels", 0) as u32).collect();
    let g = match kind {
        "rmat" => gen::rmat(args.get_u64("scale", 12) as u32, args.get_usize("ef", 8), seed, &label_pool),
        "er" => gen::erdos_renyi(args.get_usize("n", 1000), args.get_f64("p", 0.01), seed, &label_pool),
        "ba" => gen::barabasi_albert(args.get_usize("n", 1000), args.get_usize("m", 4), seed, &label_pool),
        "ring" => gen::ring(args.get_usize("n", 1000)),
        "complete" => gen::complete(args.get_usize("n", 32)),
        other => {
            eprintln!("unknown generator '{other}'");
            return 2;
        }
    };
    let out = args.get_or("out", "graph.csr");
    let res = if out.ends_with(".csr") {
        io::save_snapshot(&g, std::path::Path::new(out))
    } else {
        io::save_edge_list(&g, std::path::Path::new(out))
    };
    match res {
        Ok(()) => {
            println!("wrote {out}: {}", stats::stats(&g));
            0
        }
        Err(e) => {
            eprintln!("write failed: {e}");
            1
        }
    }
}

fn cmd_stats(args: &Args) -> i32 {
    let Some(g) = load_graph(args) else { return 1 };
    println!("{}", stats::stats(&g));
    0
}

fn cmd_tc(args: &Args) -> i32 {
    let Some(g) = load_graph(args) else { return 1 };
    let cfg = config(args);
    let (res, t) = timed(|| emulation::tc(&g, system(args), &cfg));
    let (c, code) = match governed(res) {
        Ok(v) => v,
        Err(code) => return code,
    };
    println!("triangles = {c}  [{}]  system={}", fmt_secs(t), system(args).name());
    code
}

fn cmd_clique(args: &Args) -> i32 {
    let Some(g) = load_graph(args) else { return 1 };
    let cfg = config(args);
    let k = args.get_usize("k", 4);
    let (res, t) = if args.flag("lo") {
        // hand-tuned kClist-style path: not engine-backed, ungoverned
        timed(|| Ok(Outcome::complete(clique::clique_lo(&g, k, &cfg).0, SearchStats::default())))
    } else {
        timed(|| emulation::clique(&g, k, system(args), &cfg))
    };
    let (c, code) = match governed(res) {
        Ok(v) => v,
        Err(code) => return code,
    };
    println!("{k}-cliques = {c}  [{}]", fmt_secs(t));
    code
}

fn cmd_motif(args: &Args) -> i32 {
    let Some(g) = load_graph(args) else { return 1 };
    let cfg = config(args);
    let k = args.get_usize("k", 3);
    let sys = if args.flag("lo") { System::SandslashLo } else { system(args) };
    let (res, t) = timed(|| emulation::motifs(&g, k, sys, &cfg));
    let (counts, code) = match governed(res) {
        Ok(v) => v,
        Err(code) => return code,
    };
    let names: &[&str] = match k {
        3 => &library::MOTIF3_NAMES,
        4 => &library::MOTIF4_NAMES,
        _ => &[],
    };
    println!("{k}-motif census  [{}]  system={}", fmt_secs(t), sys.name());
    for (i, c) in counts.iter().enumerate() {
        let name = names.get(i).copied().unwrap_or("motif");
        println!("  {name:>16}: {c}");
    }
    code
}

fn cmd_sl(args: &Args) -> i32 {
    let Some(g) = load_graph(args) else { return 1 };
    let cfg = config(args);
    let p = match args.get_or("pattern", "diamond") {
        "diamond" => library::diamond(),
        "4cycle" => library::cycle(4),
        "tailed-triangle" => library::tailed_triangle(),
        other => {
            eprintln!("unknown pattern '{other}'");
            return 2;
        }
    };
    let (res, t) = timed(|| sl::sl_count(&g, &p, &cfg));
    let (c, code) = match governed(res) {
        Ok(v) => v,
        Err(code) => return code,
    };
    println!("embeddings = {c}  [{}]", fmt_secs(t));
    code
}

fn cmd_fsm(args: &Args) -> i32 {
    let Some(g) = load_graph(args) else { return 1 };
    if !g.is_labeled() {
        eprintln!("FSM needs a labeled graph (e.g. --graph pa-mini)");
        return 2;
    }
    let cfg = config(args);
    let k = args.get_usize("k", 3);
    let sigma = args.get_u64("sigma", 100);
    let (res, t) = if args.flag("bfs") {
        timed(|| fsm_app::fsm_bfs(&g, k, sigma, &cfg))
    } else if args.flag("peregrine") {
        timed(|| {
            sandslash::apps::baselines::peregrine_fsm::peregrine_fsm(&g, k, sigma, &cfg)
                .map(|r| Outcome::complete(r.frequent, SearchStats::default()))
        })
    } else {
        timed(|| fsm_app::fsm(&g, k, sigma, &cfg))
    };
    let (frequent, code) = match governed(res) {
        Ok(v) => v,
        Err(code) => return code,
    };
    println!("{} frequent patterns (k<={k}, sigma>{sigma})  [{}]", frequent.len(), fmt_secs(t));
    for f in frequent.iter().take(args.get_usize("show", 10)) {
        println!("  {}  support={}", f.pattern, f.support);
    }
    code
}

fn cmd_accel(args: &Args) -> i32 {
    let Some(g) = load_graph(args) else { return 1 };
    let dir = args.get_or("artifacts", "artifacts");
    let cfg = config(args);
    let accel = match sandslash::runtime::accel::Accelerator::load(dir) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("accelerator load failed: {e:#}");
            return 1;
        }
    };
    println!("PJRT platform: {}", accel.platform());
    let (want, t_cpu) = timed(|| tc::tc_hi(&g, &cfg));
    let (got, t_xla) = timed(|| accel.triangle_count(&g));
    match got {
        Ok(got) => {
            println!(
                "triangles: combinatorial={want} [{}]  xla-tiled={got} [{}]",
                fmt_secs(t_cpu),
                fmt_secs(t_xla)
            );
            if got != want {
                eprintln!("MISMATCH");
                return 1;
            }
        }
        Err(e) => {
            eprintln!("xla path failed: {e:#}");
            return 1;
        }
    }
    if args.flag("motif4") {
        let (hi_res, t_hi) = timed(|| motif::motif4_hi(&g, &cfg));
        let (hi, code) = match governed(hi_res) {
            Ok(v) => v,
            Err(code) => return code,
        };
        if code != 0 {
            // a partial reference count cannot validate the accelerator
            return code;
        }
        let (acc4, t_acc) = timed(|| accel.motif4(&g, &cfg));
        match acc4 {
            Ok(acc4) => {
                println!("4-motifs: engine [{}] vs accel [{}]", fmt_secs(t_hi), fmt_secs(t_acc));
                for (i, name) in library::MOTIF4_NAMES.iter().enumerate() {
                    println!("  {name:>16}: engine={} accel={}", hi[i], acc4[i]);
                }
                if hi != acc4 {
                    eprintln!("MISMATCH");
                    return 1;
                }
            }
            Err(e) => {
                eprintln!("accel motif4 failed: {e:#}");
                return 1;
            }
        }
    }
    0
}

fn cmd_serve(args: &Args) -> i32 {
    use sandslash::service::{Server, Service, ServiceConfig};
    let mut cfg = ServiceConfig::from_env();
    cfg.max_inflight = args.get_usize("max-inflight", cfg.max_inflight);
    cfg.max_queued = 2 * cfg.max_inflight;
    cfg.cache_bytes = args.get_usize("cache-bytes", cfg.cache_bytes);
    cfg.default_threads = args.get_usize("threads", cfg.default_threads);
    let service = match Service::new(cfg) {
        Ok(s) => std::sync::Arc::new(s),
        Err(e) => {
            eprintln!("sandslash: {e}");
            return 1;
        }
    };
    if let Some(list) = args.get("preload") {
        for name in list.split(',').filter(|s| !s.is_empty()) {
            match service.preload(name) {
                Ok((vertices, edges)) => {
                    eprintln!("sandslash: preloaded {name} ({vertices} vertices, {edges} edges)")
                }
                Err(e) => {
                    eprintln!("sandslash: preload {name}: {e:?}");
                    return 1;
                }
            }
        }
    }
    let server = match Server::bind(service, args.get_or("addr", "127.0.0.1:0")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("sandslash: bind failed: {e}");
            return 1;
        }
    };
    let addr = server.local_addr();
    if let Some(path) = args.get("port-file") {
        // the CI smoke (and any supervisor) reads the ephemeral port here
        if let Err(e) = std::fs::write(path, addr.to_string()) {
            eprintln!("sandslash: write {path}: {e}");
            return 1;
        }
    }
    println!("sandslash: serving on {addr}");
    match server.serve() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("sandslash: serve failed: {e}");
            1
        }
    }
}

fn cmd_query(args: &Args) -> i32 {
    use sandslash::service::{request_over_socket, response_code, Op, PatternSpec, Request};
    let addr = match args.get("addr") {
        Some(a) => a.to_string(),
        None => match args.get("port-file") {
            Some(path) => match std::fs::read_to_string(path) {
                Ok(s) => s.trim().to_string(),
                Err(e) => {
                    eprintln!("sandslash: read {path}: {e}");
                    return 1;
                }
            },
            None => {
                eprintln!("sandslash: query needs --addr or --port-file");
                return 2;
            }
        },
    };
    let line = match args.get("line") {
        // raw passthrough: the caller authors the JSON line itself
        Some(raw) => raw.to_string(),
        None => {
            let mut req = Request::query(
                args.get_or("id", "cli"),
                args.get_or("graph", "er-small"),
                PatternSpec::Named(args.get_or("pattern", "triangle").to_string()),
            );
            // --stats is sugar for --op stats (plus the exposition
            // print-out below)
            match if args.flag("stats") { "stats" } else { args.get_or("op", "query") } {
                "query" => {}
                "cancel" => req.op = Op::Cancel,
                "invalidate" => req.op = Op::Invalidate,
                "graphs" => req.op = Op::Graphs,
                "stats" => req.op = Op::Stats,
                "ping" => req.op = Op::Ping,
                "shutdown" => req.op = Op::Shutdown,
                other => {
                    eprintln!("sandslash: unknown --op {other:?}");
                    return 2;
                }
            }
            if req.op != Op::Query {
                // bare ops carry no query payload on the wire
                req.graph = args.get("graph").map(|s| s.to_string());
                req.pattern = None;
            }
            req.vertex_induced = args.flag("induced");
            req.deadline_ms = args.get("deadline-ms").and_then(|s| s.trim().parse().ok());
            req.max_tasks = args.get("max-tasks").and_then(|s| s.trim().parse().ok());
            req.threads = args.get("threads").and_then(|s| s.trim().parse().ok());
            if args.flag("high") {
                req.priority = sandslash::service::Priority::High;
            }
            req.no_cache = args.flag("no-cache");
            req.trace = args.flag("trace");
            req.target = args.get("target").map(|s| s.to_string());
            req.render()
        }
    };
    match request_over_socket(&addr, &line) {
        Ok(response) => {
            println!("{response}");
            if args.flag("stats") {
                // convenience surface: unescape and print the registry
                // exposition carried inside the stats result
                let text = sandslash::service::json::parse(&response)
                    .ok()
                    .and_then(|v| {
                        v.get("result")
                            .and_then(|r| r.get("exposition"))
                            .and_then(|e| e.as_str().map(|s| s.to_string()))
                    });
                match text {
                    Some(text) => print!("{text}"),
                    None => eprintln!("sandslash: response carried no exposition"),
                }
            }
            // the structured response code doubles as the exit code,
            // mirroring the one-shot commands' table
            response_code(&response).unwrap_or(1)
        }
        Err(e) => {
            eprintln!("sandslash: request failed: {e}");
            1
        }
    }
}

fn cmd_campaign(args: &Args) -> i32 {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let un: Vec<&str> = datasets::unlabeled_names().to_vec();
    let la: Vec<&str> = datasets::labeled_names().to_vec();
    let mut rows = Vec::new();
    match which {
        "table5" => rows.extend(campaign::table5(&un)),
        "table6" => rows.extend(campaign::table6(&["lj-tiny", "or-tiny", "fr-tiny"], &[4, 5])),
        "table7" => rows.extend(campaign::table7(&["lj-tiny", "or-tiny"], &[3, 4])),
        "table8" => rows.extend(campaign::table8(&["lj-tiny", "or-tiny", "fr-tiny"])),
        "table9" => rows.extend(campaign::table9(&["pa-tiny", "yo-tiny", "pdb-tiny"], 3, &[2, 4, 10])),
        "fig8" => rows.extend(campaign::fig8(&["lj-tiny", "or-tiny"], 4)),
        "fig9" => rows.extend(campaign::fig9(&["or-tiny", "fr-tiny"], 8)),
        "fig10" => rows.extend(campaign::fig10(&["or-tiny", "fr-tiny"])),
        "fig11" => rows.extend(campaign::fig11("fr-tiny", 4..=8)),
        "scaling" => rows.extend(campaign::scaling(
            "lj-mini",
            sandslash::util::pool::default_threads(),
        )),
        "all" => {
            rows.extend(campaign::table5(&un));
            rows.extend(campaign::table6(&["lj-tiny", "or-tiny", "fr-tiny"], &[4, 5]));
            rows.extend(campaign::table7(&["lj-tiny", "or-tiny"], &[3, 4]));
            rows.extend(campaign::table8(&["lj-tiny", "or-tiny", "fr-tiny"]));
            rows.extend(campaign::table9(&["pa-tiny", "yo-tiny", "pdb-tiny"], 3, &[2, 4, 10]));
            rows.extend(campaign::fig8(&["lj-tiny", "or-tiny"], 4));
            rows.extend(campaign::fig9(&["or-tiny", "fr-tiny"], 8));
            rows.extend(campaign::fig10(&["or-tiny", "fr-tiny"]));
            rows.extend(campaign::fig11("fr-tiny", 4..=8));
        }
        other => {
            eprintln!("unknown campaign '{other}'");
            return 2;
        }
    }
    println!("{}", campaign::to_markdown(&rows));
    if let Some(out) = args.get("out") {
        if let Err(e) = std::fs::write(out, campaign::to_markdown(&rows)) {
            eprintln!("write {out}: {e}");
            return 1;
        }
    }
    0
}
