//! Coordinator: dataset registry and experiment campaign driver (the
//! part of the framework that regenerates every table and figure of the
//! paper's evaluation from one command).

pub mod campaign;
pub mod datasets;
