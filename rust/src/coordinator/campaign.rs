//! Experiment campaigns: each function regenerates one paper table or
//! figure as a set of [`ResultRow`]s. The benches print these; the
//! `sandslash campaign` subcommand writes them to markdown for
//! EXPERIMENTS.md.

use crate::apps::baselines::emulation::{self, System};
use crate::apps::baselines::{gap_tc, kclist, peregrine_fsm, pgd};
use crate::apps::{clique, fsm_app, motif, tc};
use crate::engine::{MinerConfig, OptFlags};
use crate::graph::CsrGraph;
use crate::pattern::library;
use crate::util::metrics::ResultRow;
use crate::util::timer::timed;

use super::datasets;

const TABLE_SYSTEMS: [System; 4] = [
    System::PangolinLike,
    System::AutomineLike,
    System::PeregrineLike,
    System::SandslashHi,
];

fn cfg() -> MinerConfig {
    MinerConfig::new(OptFlags::hi())
}

fn row(exp: &str, system: &str, graph: &str, params: &str, secs: f64, value: impl ToString) -> ResultRow {
    ResultRow {
        experiment: exp.into(),
        system: system.into(),
        graph: graph.into(),
        params: params.into(),
        seconds: secs,
        value: value.to_string(),
    }
}

/// Table 5: TC across systems + GAP.
pub fn table5(graphs: &[&str]) -> Vec<ResultRow> {
    let mut rows = Vec::new();
    for name in graphs {
        let g = datasets::load(name).expect("dataset");
        for sys in TABLE_SYSTEMS {
            // campaigns run with budgets unset — governed runs complete
            let (c, t) = timed(|| emulation::tc(&g, sys, &cfg()).unwrap().value);
            rows.push(row("table5-tc", sys.name(), name, "", t, c));
        }
        let (c, t) = timed(|| gap_tc::gap_tc(&g, &cfg()));
        rows.push(row("table5-tc", "gap", name, "", t, c));
    }
    rows
}

/// Table 6: k-CL (k = 4, 5) across systems + kClist + Sandslash-Lo.
pub fn table6(graphs: &[&str], ks: &[usize]) -> Vec<ResultRow> {
    let mut rows = Vec::new();
    for name in graphs {
        let g = datasets::load(name).expect("dataset");
        for &k in ks {
            let kp = format!("k={k}");
            for sys in TABLE_SYSTEMS {
                let (c, t) = timed(|| emulation::clique(&g, k, sys, &cfg()).unwrap().value);
                rows.push(row("table6-kcl", sys.name(), name, &kp, t, c));
            }
            let (c, t) = timed(|| kclist::kclist(&g, k, &cfg()).0);
            rows.push(row("table6-kcl", "kclist", name, &kp, t, c));
            let (c, t) = timed(|| clique::clique_lo(&g, k, &cfg()).0);
            rows.push(row("table6-kcl", "sandslash-lo", name, &kp, t, c));
        }
    }
    rows
}

/// Table 7: k-MC (k = 3, 4) across systems + PGD + Sandslash-Lo.
pub fn table7(graphs: &[&str], ks: &[usize]) -> Vec<ResultRow> {
    let mut rows = Vec::new();
    for name in graphs {
        let g = datasets::load(name).expect("dataset");
        for &k in ks {
            let kp = format!("k={k}");
            for sys in TABLE_SYSTEMS {
                let (c, t) = timed(|| emulation::motifs(&g, k, sys, &cfg()).unwrap().value);
                rows.push(row("table7-kmc", sys.name(), name, &kp, t, total(&c)));
            }
            let (c, t) = timed(|| match k {
                3 => pgd::pgd_motif3(&g, &cfg()).unwrap(),
                _ => pgd::pgd_motif4(&g, &cfg()).unwrap(),
            });
            rows.push(row("table7-kmc", "pgd", name, &kp, t, total(&c)));
            let (c, t) = timed(|| match k {
                3 => motif::motif3_lo(&g, &cfg()),
                _ => motif::motif4_lo(&g, &cfg()).unwrap(),
            });
            rows.push(row("table7-kmc", "sandslash-lo", name, &kp, t, total(&c)));
        }
    }
    rows
}

fn total(counts: &[u64]) -> u64 {
    counts.iter().sum()
}

/// Table 8: SL (diamond, 4-cycle) across Pangolin/Peregrine/Sandslash.
pub fn table8(graphs: &[&str]) -> Vec<ResultRow> {
    let mut rows = Vec::new();
    let pats = [("diamond", library::diamond()), ("4-cycle", library::cycle(4))];
    for name in graphs {
        let g = datasets::load(name).expect("dataset");
        for (pname, p) in &pats {
            for sys in [System::PangolinLike, System::PeregrineLike, System::SandslashHi] {
                let (c, t) = timed(|| emulation::sl(&g, p, sys, &cfg()).unwrap().value);
                rows.push(row("table8-sl", sys.name(), name, pname, t, c));
            }
        }
    }
    rows
}

/// Table 9: k-FSM across support thresholds.
pub fn table9(graphs: &[&str], max_edges: usize, sigmas: &[u64]) -> Vec<ResultRow> {
    let mut rows = Vec::new();
    for name in graphs {
        let g = datasets::load(name).expect("dataset");
        for &sigma in sigmas {
            let sp = format!("k={max_edges} sigma={sigma}");
            let (r, t) = timed(|| fsm_app::fsm_bfs(&g, max_edges, sigma, &cfg()).unwrap().value);
            rows.push(row("table9-fsm", "pangolin-like", name, &sp, t, r.len()));
            let (r, t) =
                timed(|| peregrine_fsm::peregrine_fsm(&g, max_edges, sigma, &cfg()).unwrap());
            rows.push(row("table9-fsm", "peregrine-like", name, &sp, t, r.frequent.len()));
            let (r, t) =
                timed(|| fsm_app::fsm_distgraph_like(&g, max_edges, sigma, &cfg()).unwrap().value);
            rows.push(row("table9-fsm", "distgraph-like", name, &sp, t, r.len()));
            let (r, t) = timed(|| fsm_app::fsm(&g, max_edges, sigma, &cfg()).unwrap().value);
            rows.push(row("table9-fsm", "sandslash", name, &sp, t, r.len()));
        }
    }
    rows
}

/// Fig. 8: MEC/MNC memoization speedup for k-MC. Calls the Hi engine
/// directly so the flag override actually takes effect — the emulation
/// wrapper replaces `opts` with the system preset, which silently undid
/// the `mnc = false` row in earlier revisions.
pub fn fig8(graphs: &[&str], k: usize) -> Vec<ResultRow> {
    let mut rows = Vec::new();
    let run = |g: &CsrGraph, c: &MinerConfig| -> Vec<u64> {
        match k {
            3 => motif::motif3_hi(g, c).unwrap().value,
            4 => motif::motif4_hi(g, c).unwrap().value,
            _ => panic!("fig8 supports k in 3..=4"),
        }
    };
    for name in graphs {
        let g = datasets::load(name).expect("dataset");
        let mut base = cfg();
        base.opts.mnc = false;
        let (c0, t0) = timed(|| run(&g, &base));
        rows.push(row("fig8-memo", "no-mnc", name, &format!("k={k}"), t0, total(&c0)));
        let (c1, t1) = timed(|| run(&g, &cfg()));
        rows.push(row("fig8-memo", "mnc", name, &format!("k={k}"), t1, total(&c1)));
        assert_eq!(c0, c1);
    }
    rows
}

/// Fig. 9: speedup from local-graph search. Cliques (k = 4..=max_k) run
/// the hand-tuned kClist path; the non-clique patterns run the generic
/// DFS engine with the PR-2 `OptFlags::lg` stage against the
/// set-centric baseline, so the figure now also measures the
/// generalized LG of paper §5 on diamond/house-class plans.
pub fn fig9(graphs: &[&str], max_k: usize) -> Vec<ResultRow> {
    use crate::engine::dfs;
    use crate::engine::hooks::NoHooks;
    use crate::pattern::plan;

    let mut rows = Vec::new();
    let pats = [
        ("diamond", library::diamond()),
        ("tailed-triangle", library::tailed_triangle()),
        ("4-cycle", library::cycle(4)),
    ];
    for name in graphs {
        let g = datasets::load(name).expect("dataset");
        for k in 4..=max_k {
            let kp = format!("k={k}");
            let (a, t_hi) = timed(|| clique::clique_hi(&g, k, &cfg()).0);
            rows.push(row("fig9-lg", "sandslash-hi", name, &kp, t_hi, a));
            let (b, t_lo) = timed(|| clique::clique_lo(&g, k, &cfg()).0);
            rows.push(row("fig9-lg", "sandslash-lo(LG)", name, &kp, t_lo, b));
            assert_eq!(a, b);
        }
        for (pname, p) in &pats {
            let pl = plan(p, true, true);
            let mut lo_cfg = cfg();
            lo_cfg.opts = OptFlags::lo();
            let (a, t_hi) = timed(|| dfs::count(&g, &pl, &cfg(), &NoHooks).unwrap().value);
            rows.push(row("fig9-lg", "sandslash-hi", name, pname, t_hi, a));
            let (b, t_lo) = timed(|| dfs::count(&g, &pl, &lo_cfg, &NoHooks).unwrap().value);
            rows.push(row("fig9-lg", "sandslash-lo(LG)", name, pname, t_lo, b));
            assert_eq!(a, b);
        }
    }
    rows
}

/// Fig. 10: search-space (enumerated embeddings) of Hi vs Lo for k-CL
/// and k-MC.
pub fn fig10(graphs: &[&str]) -> Vec<ResultRow> {
    let mut rows = Vec::new();
    let mut c = cfg();
    c.opts = OptFlags::hi().with_stats();
    let mut cl = cfg();
    cl.opts = OptFlags::lo().with_stats();
    for name in graphs {
        let g = datasets::load(name).expect("dataset");
        // k-CL (k=5)
        let (r, t) = timed(|| clique::clique_hi(&g, 5, &c));
        rows.push(row("fig10-space", "hi", name, "5-cl", t, r.1.enumerated));
        let (r, t) = timed(|| clique::clique_lo(&g, 5, &cl));
        rows.push(row("fig10-space", "lo", name, "5-cl", t, r.1.enumerated));
        // 4-MC: Hi enumerates all induced 4-subgraphs; Lo only anchors
        let (r, t) = timed(|| motif::motif4_hi(&g, &c).unwrap());
        rows.push(row("fig10-space", "hi", name, "4-mc", t, r.stats.enumerated));
        let (r4, t) = timed(|| {
            let mut cc = cl;
            cc.opts.stats = true;
            let (anchors, s) = clique::clique_hi(&g, 4, &cc);
            let _ = anchors;
            s.enumerated
        });
        rows.push(row("fig10-space", "lo", name, "4-mc", t, r4));
    }
    rows
}

/// Fig. 11: k-CL on fr-mini for k = 4..=9, all systems.
pub fn fig11(graph: &str, ks: std::ops::RangeInclusive<usize>) -> Vec<ResultRow> {
    let g = datasets::load(graph).expect("dataset");
    let mut rows = Vec::new();
    for k in ks {
        let kp = format!("k={k}");
        for sys in TABLE_SYSTEMS {
            // The emulated systems blow up combinatorially at large k
            // (the paper marks them TO at k >= 8); cap them at k = 5 and
            // emit an explicit TO row so the table keeps its shape.
            if k > 5 && sys != System::SandslashHi {
                rows.push(row("fig11-largek", sys.name(), graph, &kp, f64::NAN, "TO"));
                continue;
            }
            let (c, t) = timed(|| emulation::clique(&g, k, sys, &cfg()).unwrap().value);
            rows.push(row("fig11-largek", sys.name(), graph, &kp, t, c));
        }
        let (c, t) = timed(|| kclist::kclist(&g, k, &cfg()).0);
        rows.push(row("fig11-largek", "kclist", graph, &kp, t, c));
        let (c, t) = timed(|| clique::clique_lo(&g, k, &cfg()).0);
        rows.push(row("fig11-largek", "sandslash-lo", graph, &kp, t, c));
    }
    rows
}

/// §6.3 strong scaling: TC + 4-CL + 3-MC at 1..=max threads.
pub fn scaling(graph: &str, max_threads: usize) -> Vec<ResultRow> {
    let g = datasets::load(graph).expect("dataset");
    let mut rows = Vec::new();
    let mut t = 1;
    while t <= max_threads {
        let c = MinerConfig::new(OptFlags::hi()).with_threads(t);
        let tp = format!("threads={t}");
        let (_, s) = timed(|| tc::tc_hi(&g, &c));
        rows.push(row("scaling", "tc", graph, &tp, s, ""));
        let (_, s) = timed(|| clique::clique_hi(&g, 4, &c).0);
        rows.push(row("scaling", "4-cl", graph, &tp, s, ""));
        let (_, s) = timed(|| motif::motif3_hi(&g, &c).unwrap().value);
        rows.push(row("scaling", "3-mc", graph, &tp, s, ""));
        t *= 2;
    }
    rows
}

/// Render rows as a markdown table.
pub fn to_markdown(rows: &[ResultRow]) -> String {
    let mut out = ResultRow::markdown_header();
    for r in rows {
        out.push('\n');
        out.push_str(&r.to_markdown());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_smoke_on_small_inputs() {
        let rows = table5(&["er-small"]);
        assert_eq!(rows.len(), 5);
        // all systems agree on the count
        let counts: Vec<&str> = rows.iter().map(|r| r.value.as_str()).collect();
        assert!(counts.windows(2).all(|w| w[0] == w[1]), "{counts:?}");
    }

    #[test]
    fn fig9_smoke() {
        let rows = fig9(&["er-small"], 4);
        // one hi/lo pair for 4-cliques + one per non-clique pattern
        assert_eq!(rows.len(), 8);
        assert!(rows.iter().any(|r| r.params == "diamond"));
    }

    #[test]
    fn markdown_renders() {
        let rows = table5(&["er-small"]);
        let md = to_markdown(&rows);
        assert!(md.contains("table5-tc") && md.contains("gap"));
    }
}
