//! Dataset registry: named synthetic stand-ins for the paper's inputs
//! (Table 4). Each is a seeded generator call, so every experiment is
//! bit-reproducible. DESIGN.md §4 documents the substitution rationale;
//! the suffix `-mini` marks the scale reduction.
//!
//! Unlabeled (TC / k-CL / SL / k-MC):   lj, or, tw4, fr, uk  (-mini)
//! Labeled  (k-FSM):                    pa, yo, pdb          (-mini)

use crate::graph::{gen, CsrGraph};

/// Scale factor applied to all datasets. The SANDSLASH_SCALE env var
/// bumps every RMAT scale by this many powers of two for larger machines.
fn scale_bump() -> u32 {
    std::env::var("SANDSLASH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0)
}

/// All registered unlabeled dataset names in canonical (paper) order.
pub fn unlabeled_names() -> &'static [&'static str] {
    &["lj-mini", "or-mini", "tw4-mini", "fr-mini", "uk-mini"]
}

/// All registered labeled (k-FSM) dataset names in canonical order.
pub fn labeled_names() -> &'static [&'static str] {
    &["pa-mini", "yo-mini", "pdb-mini"]
}

/// Materialize a dataset by name.
pub fn load(name: &str) -> Option<CsrGraph> {
    let b = scale_bump();
    let g = match name {
        // Unlabeled: RMAT skew tuned per source graph's degree profile
        // (LiveJournal: moderate avg degree 18; Orkut: dense, 76;
        // Twitter40: very skewed; Friendster: large; UK2007: web crawl,
        // locally dense).
        "lj-mini" => gen::rmat(13 + b, 9, 0x1717, &[]),
        "or-mini" => gen::rmat(12 + b, 38, 0x0421, &[]),
        "tw4-mini" => gen::rmat_with(14 + b, 15, 0.65, 0.15, 0.15, 0x7340, &[]),
        "fr-mini" => gen::rmat(14 + b, 14, 0xf12e, &[]),
        "uk-mini" => gen::rmat_with(14 + b, 16, 0.50, 0.22, 0.22, 0x2007, &[]),
        // Labeled: label cardinality mirrors Table 4 (Pa: 37, Yo: 29,
        // Pdb: 25), densities kept low like the sources (avg deg 8-16).
        "pa-mini" => gen::rmat(12 + b, 5, 0x9a73, &labels(37)),
        "yo-mini" => gen::rmat(12 + b, 8, 0x9070, &labels(29)),
        "pdb-mini" => gen::rmat(13 + b, 4, 0x9d6b, &labels(25)),
        // tiny variants for the emulation-heavy benches (BFS baselines
        // materialize whole levels; paper shows them timing out at -mini
        // scale, so the benches demonstrate the blow-up at -tiny scale
        // and report the ratio rather than a TO marker)
        "lj-tiny" => gen::rmat(10 + b, 9, 0x1717, &[]),
        "or-tiny" => gen::rmat(9 + b, 20, 0x0421, &[]),
        "fr-tiny" => gen::rmat(11 + b, 10, 0xf12e, &[]),
        "pa-tiny" => gen::rmat(10 + b, 5, 0x9a73, &labels(37)),
        "yo-tiny" => gen::rmat(10 + b, 8, 0x9070, &labels(29)),
        "pdb-tiny" => gen::rmat(11 + b, 4, 0x9d6b, &labels(25)),
        // small smoke datasets
        "er-small" => gen::erdos_renyi(2000, 0.005, 7, &[]),
        "er-labeled" => gen::erdos_renyi(2000, 0.005, 7, &labels(8)),
        "ba-small" => gen::barabasi_albert(4000, 6, 9, &[]),
        _ => return None,
    };
    Some(g)
}

fn labels(n: u32) -> Vec<u32> {
    (1..=n).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_registered_datasets_load() {
        for name in unlabeled_names().iter().chain(labeled_names()) {
            let g = load(name).unwrap_or_else(|| panic!("{name} missing"));
            assert!(g.num_vertices() > 0, "{name}");
            assert!(g.num_undirected_edges() > 0, "{name}");
        }
        assert!(load("nonexistent").is_none());
    }

    #[test]
    fn labeled_datasets_have_labels() {
        for name in labeled_names() {
            let g = load(name).unwrap();
            assert!(g.is_labeled(), "{name}");
            assert!(g.num_labels() >= 25, "{name}");
        }
    }

    #[test]
    fn datasets_are_reproducible() {
        let a = load("lj-mini").unwrap();
        let b = load("lj-mini").unwrap();
        assert_eq!(a.neighbors, b.neighbors);
    }

    #[test]
    fn skew_profile_orders_match_paper() {
        // Orkut-mini should be densest (highest avg degree), mirroring
        // Table 4 where Orkut has avg degree 76.
        let or = load("or-mini").unwrap();
        let lj = load("lj-mini").unwrap();
        let avg = |g: &crate::graph::CsrGraph| {
            g.num_directed_edges() as f64 / g.num_vertices() as f64
        };
        assert!(avg(&or) > avg(&lj));
    }
}
