//! System emulations (DESIGN.md §5): run each paper application under
//! the search strategy + optimization subset of AutoMine, Pangolin and
//! Peregrine (paper Table 3b), inside one engine so the comparisons in
//! Tables 5–9 isolate exactly the effects the paper attributes to each
//! system.

use crate::engine::bfs::bfs_count_motifs;
use crate::engine::budget::{MineError, Outcome};
use crate::engine::dfs;
use crate::engine::esu::MotifTable;
use crate::engine::hooks::NoHooks;
use crate::engine::{MinerConfig, OptFlags};
use crate::graph::setops::intersect_count;
use crate::graph::orientation::{orient, OrientScheme};
use crate::graph::CsrGraph;
use crate::pattern::symmetry::automorphism_count;
use crate::pattern::{library, plan, Pattern};
use crate::util::metrics::SearchStats;
use crate::util::pool::parallel_reduce;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
/// Which GPM system's optimization set to emulate (DESIGN.md §5):
/// each variant is a preset [`OptFlags`] combination (plus the BFS
/// strategy for Pangolin).
pub enum System {
    /// Sandslash with all high-level optimizations (Table 3a).
    SandslashHi,
    /// Sandslash-Hi plus the low-level LC/LG optimizations.
    SandslashLo,
    /// AutoMine: MO but no SB/DAG; counts every automorphic copy.
    AutomineLike,
    /// Pangolin: BFS strategy with SB + DAG, no MO/DF/MNC.
    PangolinLike,
    /// Peregrine: DFS with on-the-fly SB and MO, no DAG.
    PeregrineLike,
}

impl System {
    /// Row label used in the campaign tables.
    pub fn name(&self) -> &'static str {
        match self {
            System::SandslashHi => "sandslash-hi",
            System::SandslashLo => "sandslash-lo",
            System::AutomineLike => "automine-like",
            System::PangolinLike => "pangolin-like",
            System::PeregrineLike => "peregrine-like",
        }
    }

    /// The optimization preset this system runs with.
    pub fn flags(&self) -> OptFlags {
        match self {
            System::SandslashHi => OptFlags::hi(),
            System::SandslashLo => OptFlags::lo(),
            System::AutomineLike => OptFlags::automine_like(),
            System::PangolinLike => OptFlags::pangolin_like(),
            System::PeregrineLike => OptFlags::peregrine_like(),
        }
    }
}

/// TC under each system model. Governed (PR 6): engine-backed systems
/// forward the [`Outcome`]/[`MineError`] contract; hand-tuned paths
/// report a complete outcome.
pub fn tc(g: &CsrGraph, sys: System, cfg: &MinerConfig) -> Result<Outcome<u64>, MineError> {
    let cfg = MinerConfig { opts: sys.flags(), ..*cfg };
    match sys {
        // Hi/Lo and Pangolin use DAG + intersections (Table 3)
        System::SandslashHi | System::SandslashLo => {
            Ok(Outcome::complete(crate::apps::tc::tc_hi(g, &cfg), SearchStats::default()))
        }
        System::PangolinLike => {
            // BFS: materialize the level-1 frontier (all DAG edges), then
            // a level-2 sweep — same arithmetic, BFS storage behaviour.
            let dag = orient(g, OrientScheme::Degree);
            let frontier: Vec<(u32, u32)> = (0..dag.num_vertices() as u32)
                .flat_map(|v| dag.out_neighbors(v).iter().map(move |&u| (v, u)))
                .collect();
            let c = parallel_reduce(
                frontier.len(),
                cfg.threads,
                cfg.chunk,
                || 0u64,
                |acc, i| {
                    let (v, u) = frontier[i];
                    *acc += intersect_count(dag.out_neighbors(v), dag.out_neighbors(u)) as u64;
                },
                |a, b| a + b,
            );
            Ok(Outcome::complete(c, SearchStats::default()))
        }
        // Peregrine: on-the-fly SB, no DAG; AutoMine: no SB, divide
        System::AutomineLike | System::PeregrineLike => crate::apps::tc::tc_generic(g, &cfg),
    }
}

/// k-CL under each system model. Governed (PR 6) like [`tc`].
pub fn clique(
    g: &CsrGraph,
    k: usize,
    sys: System,
    cfg: &MinerConfig,
) -> Result<Outcome<u64>, MineError> {
    let cfg = MinerConfig { opts: sys.flags(), ..*cfg };
    match sys {
        System::SandslashHi => {
            let (c, stats) = crate::apps::clique::clique_hi(g, k, &cfg);
            Ok(Outcome::complete(c, stats))
        }
        System::SandslashLo => {
            let (c, stats) = crate::apps::clique::clique_lo(g, k, &cfg);
            Ok(Outcome::complete(c, stats))
        }
        System::PangolinLike => {
            Ok(Outcome::complete(bfs_cliques(g, k, &cfg), SearchStats::default()))
        }
        System::AutomineLike => {
            let pl = plan(&library::clique(k), true, false);
            let mut out = dfs::count(g, &pl, &cfg, &NoHooks)?;
            out.value /= automorphism_count(&library::clique(k));
            Ok(out)
        }
        System::PeregrineLike => {
            let pl = plan(&library::clique(k), true, true);
            dfs::count(g, &pl, &cfg, &NoHooks)
        }
    }
}

/// BFS k-clique listing on the DAG (Pangolin's strategy): every level is
/// fully materialized before the next begins.
pub fn bfs_cliques(g: &CsrGraph, k: usize, cfg: &MinerConfig) -> u64 {
    let dag = orient(g, OrientScheme::Degree);
    // level 2: all DAG edges with their candidate sets
    let mut level: Vec<Vec<u32>> = Vec::new();
    for v in 0..dag.num_vertices() as u32 {
        for &u in dag.out_neighbors(v) {
            let mut cand = Vec::new();
            crate::graph::setops::intersect_into(
                dag.out_neighbors(v),
                dag.out_neighbors(u),
                &mut cand,
            );
            level.push(cand);
        }
    }
    for _depth in 2..(k - 1) {
        level = parallel_reduce(
            level.len(),
            cfg.threads,
            cfg.chunk,
            Vec::new,
            |out: &mut Vec<Vec<u32>>, i| {
                let cand = &level[i];
                for (j, &u) in cand.iter().enumerate() {
                    let _ = j;
                    let mut next = Vec::new();
                    crate::graph::setops::intersect_into(cand, dag.out_neighbors(u), &mut next);
                    out.push(next);
                }
            },
            |mut a, b| {
                a.extend(b);
                a
            },
        );
    }
    level.iter().map(|c| c.len() as u64).sum()
}

/// k-MC under each system model; returns counts in all_motifs(k) order.
/// Governed (PR 6) like [`tc`].
pub fn motifs(
    g: &CsrGraph,
    k: usize,
    sys: System,
    cfg: &MinerConfig,
) -> Result<Outcome<Vec<u64>>, MineError> {
    // preset flags, but the caller's planner opt-out survives: the CLI's
    // `--no-plan` reaches the census through this override (PR 10)
    let mut opts = sys.flags();
    opts.plan = opts.plan && cfg.opts.plan;
    let cfg = MinerConfig { opts, ..*cfg };
    match sys {
        // planner-fronted wrappers (PR 10): algebraic census when the
        // plan stage is active, the ESU oracle otherwise
        System::SandslashHi => match k {
            3 => crate::apps::motif::motif3(g, &cfg),
            4 => crate::apps::motif::motif4(g, &cfg),
            _ => panic!("k-MC supports k in 3..=4"),
        },
        System::SandslashLo => match k {
            3 => Ok(Outcome::complete(crate::apps::motif::motif3_lo(g, &cfg), SearchStats::default())),
            4 => Ok(Outcome::complete(crate::apps::motif::motif4_lo(g, &cfg)?, SearchStats::default())),
            _ => panic!("k-MC supports k in 3..=4"),
        },
        System::PangolinLike => {
            let table = MotifTable::new(k);
            Ok(bfs_count_motifs(g, k, &cfg, &table)?.map(|o| o.counts))
        }
        // pattern-at-a-time: match each motif separately through the
        // pattern-guided engine (vertex-induced plans)
        System::AutomineLike | System::PeregrineLike => {
            let sb = sys == System::PeregrineLike;
            let mut counts = Vec::new();
            let mut stats = SearchStats::default();
            let mut tripped = None;
            for p in library::all_motifs(k).iter() {
                let pl = plan(p, true, sb);
                let out = dfs::count(g, &pl, &cfg, &NoHooks)?;
                stats.merge(&out.stats);
                if tripped.is_none() {
                    tripped = out.tripped;
                }
                counts.push(if sb { out.value } else { out.value / automorphism_count(p) });
            }
            Ok(match tripped {
                Some(reason) => Outcome::partial(counts, stats, reason),
                None => Outcome::complete(counts, stats),
            })
        }
    }
}

/// SL under each system model. Governed (PR 6) like [`tc`].
pub fn sl(
    g: &CsrGraph,
    p: &Pattern,
    sys: System,
    cfg: &MinerConfig,
) -> Result<Outcome<u64>, MineError> {
    let mut cfg = MinerConfig { opts: sys.flags(), ..*cfg };
    match sys {
        // Pangolin lacks MNC (Table 3b) — pay per-candidate has_edge;
        // Peregrine uses VSB instead of MNC: emulate as MNC off
        // (per-level recomputation of vertex sets).
        System::PangolinLike | System::PeregrineLike => {
            cfg.opts.mnc = false;
            crate::apps::sl::sl_count(g, p, &cfg)
        }
        _ => crate::apps::sl::sl_count(g, p, &cfg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn cfg() -> MinerConfig {
        MinerConfig::custom(2, 16, OptFlags::hi())
    }

    const ALL: [System; 5] = [
        System::SandslashHi,
        System::SandslashLo,
        System::AutomineLike,
        System::PangolinLike,
        System::PeregrineLike,
    ];

    #[test]
    fn all_systems_agree_on_tc() {
        let g = gen::rmat(8, 6, 4, &[]);
        let want = crate::apps::tc::tc_hi(&g, &cfg());
        for s in ALL {
            assert_eq!(tc(&g, s, &cfg()).unwrap().value, want, "{}", s.name());
        }
    }

    #[test]
    fn all_systems_agree_on_cliques() {
        let g = gen::erdos_renyi(40, 0.25, 6, &[]);
        for k in [3, 4] {
            let want = crate::apps::clique::clique_brute(&g, k);
            for s in ALL {
                assert_eq!(clique(&g, k, s, &cfg()).unwrap().value, want, "{} k={k}", s.name());
            }
        }
    }

    #[test]
    fn all_systems_agree_on_motifs() {
        let g = gen::erdos_renyi(35, 0.2, 8, &[]);
        let want = motifs(&g, 4, System::SandslashHi, &cfg()).unwrap().value;
        for s in ALL {
            assert_eq!(motifs(&g, 4, s, &cfg()).unwrap().value, want, "{}", s.name());
        }
    }

    #[test]
    fn all_systems_agree_on_sl() {
        let g = gen::erdos_renyi(35, 0.2, 10, &[]);
        let p = crate::pattern::library::diamond();
        let want = sl(&g, &p, System::SandslashHi, &cfg()).unwrap().value;
        for s in [System::SandslashHi, System::PangolinLike, System::PeregrineLike] {
            assert_eq!(sl(&g, &p, s, &cfg()).unwrap().value, want, "{}", s.name());
        }
    }
}
