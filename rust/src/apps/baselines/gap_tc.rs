//! GAP-style triangle counting (Beamer et al., the GAP Benchmark Suite
//! [5]): relabel vertices by descending degree, orient edges from lower
//! to higher new id, count via sorted intersections. The degree-sorted
//! relabeling is GAP's trick for skew-bounded work per vertex.

use crate::engine::MinerConfig;
use crate::graph::builder::{degree_desc_order, relabel};
use crate::graph::setops::intersect_count;
use crate::graph::CsrGraph;
use crate::util::pool::parallel_reduce;

/// GAP-benchmark-style triangle count (Table 5's hand-optimized
/// non-GPM baseline).
pub fn gap_tc(g: &CsrGraph, cfg: &MinerConfig) -> u64 {
    // preprocessing: degree-descending relabel
    let perm = degree_desc_order(g);
    let h = relabel(g, &perm);
    // orient by new id: u -> v iff u < v; out-lists are the sorted tails
    let n = h.num_vertices();
    parallel_reduce(
        n,
        cfg.threads,
        cfg.chunk,
        || 0u64,
        |acc, u| {
            let u = u as u32;
            let nu = h.neighbors(u);
            let tail_u = &nu[nu.partition_point(|&x| x < u)..];
            for &v in tail_u {
                let nv = h.neighbors(v);
                let tail_v = &nv[nv.partition_point(|&x| x < v)..];
                *acc += intersect_count(tail_u, tail_v) as u64;
            }
        },
        |a, b| a + b,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::tc::{tc_brute, tc_hi};
    use crate::engine::OptFlags;
    use crate::graph::gen;

    fn cfg() -> MinerConfig {
        MinerConfig::custom(2, 16, OptFlags::hi())
    }

    #[test]
    fn matches_brute_and_hi() {
        for seed in [1, 9] {
            let g = gen::erdos_renyi(60, 0.2, seed, &[]);
            assert_eq!(gap_tc(&g, &cfg()), tc_brute(&g));
            assert_eq!(gap_tc(&g, &cfg()), tc_hi(&g, &cfg()));
        }
    }

    #[test]
    fn rmat_agrees() {
        let g = gen::rmat(9, 8, 3, &[]);
        assert_eq!(gap_tc(&g, &cfg()), tc_hi(&g, &cfg()));
    }
}
