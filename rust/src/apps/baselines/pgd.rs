//! PGD (Ahmed et al. [3]): 3/4-motif counting with proven local-count
//! formulas. The paper notes PGD "does not apply symmetry breaking and
//! has much larger enumeration space" than Sandslash-Lo — so this
//! baseline uses the same formula set as `motif4_lo` but enumerates its
//! anchor patterns (4-cliques, 4-cycles) *without* symmetry breaking,
//! dividing by the automorphism count afterwards.
//!
//! Since PR 10 the degree-term reductions come from the planner's
//! shared formula leaves ([`decompose::vertex_comb_sum`] /
//! [`decompose::edge_local_counts`] via
//! [`crate::apps::motif::edge_raw_counts`]) — one implementation for
//! the Lo path, this baseline, and the decomposition planner. The old
//! hand-rolled `parallel_reduce` closed forms are kept below as
//! unit-test references so a regression in the shared leaves cannot
//! hide behind its own consumers.

use crate::engine::budget::MineError;
use crate::engine::dfs;
use crate::engine::hooks::NoHooks;
use crate::engine::MinerConfig;
use crate::graph::CsrGraph;
use crate::pattern::decompose;
use crate::pattern::{library, plan};

use crate::apps::motif::edge_raw_counts;

/// PGD-style 3-motif counts: [wedge, triangle]. Governed (PR 6): the
/// anchor enumeration runs through the governed DFS engine.
pub fn pgd_motif3(g: &CsrGraph, cfg: &MinerConfig) -> Result<Vec<u64>, MineError> {
    // triangles enumerated without SB (6 automorphic copies each)
    let tri_plan = plan(&library::triangle(), true, false);
    let (t6, _) = dfs::count(g, &tri_plan, cfg, &NoHooks)?.into_parts();
    let t = t6 / 6;
    let paths2 = decompose::vertex_comb_sum(g, cfg, 2);
    Ok(vec![paths2 - 3 * t, t])
}

/// PGD-style 4-motif counts (all_motifs(4) order). Governed (PR 6) like
/// [`pgd_motif3`].
pub fn pgd_motif4(g: &CsrGraph, cfg: &MinerConfig) -> Result<Vec<u64>, MineError> {
    // anchors enumerated without symmetry breaking
    let k4_plan = plan(&library::clique(4), true, false);
    let (c4_raw, _) = dfs::count(g, &k4_plan, cfg, &NoHooks)?.into_parts();
    let c4 = c4_raw / 24;
    let cyc_plan = plan(&library::cycle(4), true, false);
    let (cy_raw, _) = dfs::count(g, &cyc_plan, cfg, &NoHooks)?.into_parts();
    let cy = cy_raw / 8;
    let (raw_d, raw_tt, raw_p4) = edge_raw_counts(g, cfg);
    let raw_s3 = decompose::vertex_comb_sum(g, cfg, 3);
    let d = raw_d - 6 * c4;
    let tt = (raw_tt - 4 * d) / 2;
    let p4 = raw_p4 - 4 * cy;
    let s3 = raw_s3 - tt - 2 * d - 4 * c4;
    Ok(vec![s3, p4, tt, cy, d, c4])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::motif::{motif3_lo, motif4_lo};
    use crate::engine::OptFlags;
    use crate::graph::gen;
    use crate::util::pool::parallel_reduce;

    fn cfg() -> MinerConfig {
        MinerConfig::custom(2, 16, OptFlags::hi())
    }

    /// The pre-PR-10 hand-rolled wedge reduction, kept verbatim as a
    /// reference oracle for the shared `vertex_comb_sum(_, _, 2)` leaf.
    fn reference_paths2(g: &CsrGraph, cfg: &MinerConfig) -> u64 {
        parallel_reduce(
            g.num_vertices(),
            cfg.threads,
            cfg.chunk,
            || 0u64,
            |acc, v| {
                let d = g.degree(v as u32) as u64;
                *acc += d.saturating_sub(1) * d / 2;
            },
            |a, b| a + b,
        )
    }

    /// The pre-PR-10 hand-rolled 3-star reduction, kept verbatim as a
    /// reference oracle for `vertex_comb_sum(_, _, 3)`.
    fn reference_raw_s3(g: &CsrGraph, cfg: &MinerConfig) -> u64 {
        parallel_reduce(
            g.num_vertices(),
            cfg.threads,
            cfg.chunk,
            || 0u64,
            |acc, v| {
                let d = g.degree(v as u32) as u64;
                if d >= 3 {
                    *acc += d * (d - 1) * (d - 2) / 6;
                }
            },
            |a, b| a + b,
        )
    }

    /// The pre-PR-10 hand-rolled per-edge reduction (Listing 3 body),
    /// kept verbatim as a reference oracle for `edge_local_counts`.
    fn reference_edge_raw(g: &CsrGraph, cfg: &MinerConfig) -> (u64, u64, u64) {
        let edges: Vec<(u32, u32)> = g.edges().collect();
        parallel_reduce(
            edges.len(),
            cfg.threads,
            cfg.chunk,
            || (0u64, 0u64, 0u64),
            |acc, i| {
                let (u, v) = edges[i];
                let tri = g.intersect_count(u, v) as u64;
                let su = g.degree(u) as u64 - tri - 1;
                let sv = g.degree(v) as u64 - tri - 1;
                acc.0 += tri.saturating_sub(1) * tri / 2;
                acc.1 += tri * (su + sv);
                acc.2 += su * sv;
            },
            |a, b| (a.0 + b.0, a.1 + b.1, a.2 + b.2),
        )
    }

    #[test]
    fn pgd_matches_sandslash_lo() {
        let g = gen::erdos_renyi(50, 0.15, 7, &[]);
        assert_eq!(pgd_motif3(&g, &cfg()).unwrap(), motif3_lo(&g, &cfg()));
        assert_eq!(pgd_motif4(&g, &cfg()).unwrap(), motif4_lo(&g, &cfg()).unwrap());
    }

    #[test]
    fn shared_leaves_match_the_old_closed_forms() {
        for (scale, seed) in [(7u32, 5u64), (8, 6)] {
            let g = gen::rmat(scale, 5, seed, &[]);
            assert_eq!(
                decompose::vertex_comb_sum(&g, &cfg(), 2),
                reference_paths2(&g, &cfg())
            );
            assert_eq!(
                decompose::vertex_comb_sum(&g, &cfg(), 3),
                reference_raw_s3(&g, &cfg())
            );
            assert_eq!(edge_raw_counts(&g, &cfg()), reference_edge_raw(&g, &cfg()));
        }
    }
}
