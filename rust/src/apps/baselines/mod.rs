//! Hand-optimized baselines (faithful reimplementations of published
//! algorithms) and system emulations (search strategy + optimization
//! subsets of AutoMine / Pangolin / Peregrine, per DESIGN.md §5).

pub mod emulation;
pub mod gap_tc;
pub mod kclist;
pub mod peregrine_fsm;
pub mod pgd;
