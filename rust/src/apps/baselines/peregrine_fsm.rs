//! Peregrine-like FSM: *pattern-at-a-time* matching (paper §6.2, B.3).
//!
//! Peregrine's pattern-centric model enumerates candidate patterns first
//! and then matches each one against the whole graph independently. This
//! is exactly what the paper blames for its FSM behaviour on graphs with
//! many frequent patterns ("enumerates all the possible patterns first
//! and then enumerates embeddings for each pattern one by one"). We
//! reproduce that architecture: candidate children are generated from
//! each frequent pattern purely syntactically (labels × attach points),
//! and every candidate is matched from scratch with the pattern-guided
//! DFS engine; MNI domains are folded at the leaves.

use std::collections::HashSet;

use crate::engine::budget::MineError;
use crate::engine::dfs;
use crate::engine::hooks::NoHooks;
use crate::engine::fsm::{canonical_parent_code, FrequentPattern, FsmResult};
use crate::engine::support::DomainSupport;
use crate::engine::MinerConfig;
use crate::graph::CsrGraph;
use crate::pattern::{canonical_code, plan, CanonCode, Pattern};

/// Mine frequent patterns pattern-at-a-time. Governed (PR 6): every
/// candidate match runs through the governed DFS engine, so deadline or
/// budget trips surface as fewer embeddings folded into the MNI domains
/// (a support lower bound) and worker panics as [`MineError`].
pub fn peregrine_fsm(
    g: &CsrGraph,
    max_edges: usize,
    min_support: u64,
    cfg: &MinerConfig,
) -> Result<FsmResult, MineError> {
    let labels: Vec<u32> = {
        let mut l: Vec<u32> = g.labels.iter().copied().collect();
        l.sort_unstable();
        l.dedup();
        l
    };
    let mut result = FsmResult::default();

    // level 1: single-edge patterns from observed label pairs
    let mut level: Vec<Pattern> = Vec::new();
    {
        let mut seen: HashSet<CanonCode> = HashSet::new();
        for (u, v) in g.edges() {
            let mut p = Pattern::from_edges(&[(0, 1)]);
            let (la, lb) = {
                let (a, b) = (g.label(u), g.label(v));
                if a <= b { (a, b) } else { (b, a) }
            };
            p.set_label(0, la);
            p.set_label(1, lb);
            if seen.insert(canonical_code(&p)) {
                if let Some(support) = match_support(g, &p, min_support, cfg)? {
                    result.frequent.push(FrequentPattern {
                        code: canonical_code(&p),
                        pattern: p.clone(),
                        support,
                        embeddings: 0,
                    });
                    level.push(p);
                }
            }
        }
    }

    for _ in 1..max_edges {
        let mut next: Vec<Pattern> = Vec::new();
        let mut seen: HashSet<CanonCode> = HashSet::new();
        for p in &level {
            for child in syntactic_children(p, &labels) {
                // unique-parent rule keeps the candidate set a tree (must
                // be checked before the seen-dedupe: a child first reached
                // through a non-designated parent must stay eligible)
                if canonical_parent_code(&child) != canonical_code(p) {
                    continue;
                }
                let code = canonical_code(&child);
                if !seen.insert(code.clone()) {
                    continue;
                }
                result.stats.enumerated += 1;
                if let Some(support) = match_support(g, &child, min_support, cfg)? {
                    result.frequent.push(FrequentPattern {
                        code,
                        pattern: child.clone(),
                        support,
                        embeddings: 0,
                    });
                    next.push(child);
                } else {
                    result.stats.pruned += 1;
                }
            }
        }
        if next.is_empty() {
            break;
        }
        level = next;
    }
    result.frequent.sort_by(|a, b| a.code.cmp(&b.code));
    Ok(result)
}

/// All one-edge syntactic extensions of `p`: forward edges with every
/// label, plus missing back edges.
fn syntactic_children(p: &Pattern, labels: &[u32]) -> Vec<Pattern> {
    let n = p.num_vertices();
    let mut out = Vec::new();
    for i in 0..n {
        for &l in labels {
            let mut q = Pattern::new(n + 1);
            for v in 0..n {
                q.set_label(v, p.label(v));
            }
            for (a, b) in p.edges() {
                q.add_edge(a, b);
            }
            q.set_label(n, l);
            q.add_edge(i, n);
            out.push(q);
        }
        for j in (i + 1)..n {
            if !p.has_edge(i, j) {
                let mut q = p.clone();
                q.add_edge(i, j);
                out.push(q);
            }
        }
    }
    out
}

/// Match `p` from scratch; return MNI support if above threshold.
/// Matching runs without symmetry breaking so every automorphic mapping
/// contributes to the domains (exact MNI).
fn match_support(
    g: &CsrGraph,
    p: &Pattern,
    min_support: u64,
    cfg: &MinerConfig,
) -> Result<Option<u64>, MineError> {
    let pl = plan(p, false, false);
    let order: Vec<usize> = pl.levels.iter().map(|l| l.pattern_vertex).collect();
    let k = p.num_vertices();
    let (domains, _) = dfs::mine(
        g,
        &pl,
        cfg,
        &NoHooks,
        || DomainSupport::new(k),
        |d, emb| {
            // emb is in plan order; scatter to pattern positions
            let mut mapping = vec![0u32; k];
            for (i, &v) in emb.iter().enumerate() {
                mapping[order[i]] = v;
            }
            d.add(&mapping);
        },
        |mut a, b| {
            a.merge(&b);
            a
        },
    )?
    .into_parts();
    let s = domains.support();
    Ok((s > min_support).then_some(s))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::fsm::mine_fsm;
    use crate::engine::OptFlags;
    use crate::graph::gen;

    #[test]
    fn agrees_with_dfs_fsm_on_patterns_and_support() {
        let g = gen::erdos_renyi(40, 0.12, 3, &[1, 2]);
        let cfg = MinerConfig::custom(2, 8, OptFlags::hi());
        let a = mine_fsm(&g, 3, 1, &cfg).unwrap().value;
        let b = peregrine_fsm(&g, 3, 1, &cfg).unwrap();
        let sa: Vec<_> = a.iter().map(|f| (f.code.clone(), f.support)).collect();
        let sb: Vec<_> = b.frequent.iter().map(|f| (f.code.clone(), f.support)).collect();
        assert_eq!(sa, sb);
    }
}
