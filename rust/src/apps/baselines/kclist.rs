//! kClist (Danisch, Balalau, Sozio [16]): k-clique listing on the
//! degeneracy-ordered DAG with per-root local graphs. This is the expert
//! baseline Sandslash-Lo is compared against in Table 6 / Fig. 11; the
//! algorithm is identical to the LG machinery Sandslash exposes through
//! `initLG`/`updateLG`, so the baseline shares the substrate in
//! [`crate::engine::local_graph`] — the *difference* in the paper is
//! programming effort (394 lines of bespoke C vs Listing 4), not the
//! algorithm.

use crate::engine::MinerConfig;
use crate::graph::CsrGraph;
use crate::util::metrics::SearchStats;

/// kClist = core-ordered DAG + shrinking local graphs.
pub fn kclist(g: &CsrGraph, k: usize, cfg: &MinerConfig) -> (u64, SearchStats) {
    crate::apps::clique::clique_lo(g, k, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::clique::clique_brute;
    use crate::engine::OptFlags;
    use crate::graph::gen;

    #[test]
    fn kclist_is_exact() {
        let g = gen::erdos_renyi(35, 0.3, 2, &[]);
        let cfg = MinerConfig::custom(2, 8, OptFlags::lo());
        for k in 3..=5 {
            assert_eq!(kclist(&g, k, &cfg).0, clique_brute(&g, k));
        }
    }
}
