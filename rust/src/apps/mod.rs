//! The five paper applications (TC, k-CL, SL, k-MC, k-FSM), their
//! hand-optimized baselines, and the high-level `solve` facade that turns
//! a [`ProblemSpec`] into an answer — the dispatch table of paper §4.3.

pub mod baselines;
pub mod clique;
pub mod fsm_app;
pub mod motif;
pub mod sl;
pub mod tc;

use crate::engine::budget::{MineError, Outcome};
use crate::engine::{MinerConfig, ProblemSpec};
use crate::graph::CsrGraph;
use crate::pattern::library;
use crate::util::metrics::SearchStats;

/// What a solved GPM problem returns.
#[derive(Debug)]
pub enum MiningOutput {
    /// Single-pattern count.
    Count(u64),
    /// Multi-pattern counts with human-readable names.
    PerPattern(Vec<(String, u64)>),
    /// Frequent patterns with their supports.
    Frequent(Vec<(String, u64)>),
    /// Materialized embeddings (listing problems on request).
    Listing(Vec<Vec<u32>>),
}

/// High-level entry point: analyze the spec and run the right engine
/// with the right optimizations (the automation the paper's high-level
/// API promises).
///
/// Governed (PR 6): engine-backed paths forward the engines'
/// [`Outcome`]/[`MineError`] contract; hand-tuned paths that never
/// enter a governed engine (TC-Hi, k-CL) report a complete outcome.
pub fn solve(
    g: &CsrGraph,
    spec: &ProblemSpec,
    cfg: &MinerConfig,
) -> Result<Outcome<MiningOutput>, MineError> {
    if let Some(sigma) = spec.min_support {
        // implicit-pattern, edge-induced, anti-monotonic support: FSM
        let r = fsm_app::fsm(g, spec.k, sigma, cfg)?;
        return Ok(r.map(|pats| {
            MiningOutput::Frequent(
                pats.into_iter().map(|f| (format!("{}", f.pattern), f.support)).collect(),
            )
        }));
    }
    if !spec.explicit {
        // implicit vertex-induced: motif counting (planner-fronted
        // wrappers since PR 10 — the algebraic census when active)
        let counts = match spec.k {
            3 => motif::motif3(g, cfg)?,
            4 => motif::motif4(g, cfg)?,
            k => {
                let table = crate::engine::esu::MotifTable::new(k);
                crate::engine::esu::count_motifs(
                    g,
                    k,
                    cfg,
                    &crate::engine::hooks::NoHooks,
                    &table,
                )?
            }
        };
        let names: Vec<String> = match spec.k {
            3 => library::MOTIF3_NAMES.iter().map(|s| s.to_string()).collect(),
            4 => library::MOTIF4_NAMES.iter().map(|s| s.to_string()).collect(),
            k => (0..counts.value.len()).map(|i| format!("motif{k}-{i}")).collect(),
        };
        return Ok(counts.map(|c| MiningOutput::PerPattern(names.into_iter().zip(c).collect())));
    }
    // explicit pattern(s)
    if spec.patterns.len() == 1 {
        let p = &spec.patterns[0];
        if p.is_clique() && spec.vertex_induced {
            if p.num_vertices() == 3 {
                let c = tc::tc_hi(g, cfg);
                return Ok(Outcome::complete(MiningOutput::Count(c), SearchStats::default()));
            }
            // DAG decision (§4.3): cliques get orientation; LG when Lo
            let (c, stats) = if cfg.opts.lg {
                clique::clique_lo(g, p.num_vertices(), cfg)
            } else {
                clique::clique_hi(g, p.num_vertices(), cfg)
            };
            return Ok(Outcome::complete(MiningOutput::Count(c), stats));
        }
        if spec.listing && !spec.vertex_induced {
            return Ok(sl::sl_count(g, p, cfg)?.map(MiningOutput::Count));
        }
        if cfg.opts.sb {
            // count-only single pattern: the PR-10 planner entry point
            // (enumerated oracle when inactive or cost-model-rejected)
            let out = crate::pattern::decompose::count_with_plan(g, p, spec.vertex_induced, cfg)?;
            return Ok(out.map(MiningOutput::Count));
        }
        let pl = crate::pattern::plan(p, spec.vertex_induced, false);
        let mut out = crate::engine::dfs::count(g, &pl, cfg, &crate::engine::hooks::NoHooks)?;
        out.value /= crate::pattern::symmetry::automorphism_count(p);
        return Ok(out.map(MiningOutput::Count));
    }
    // multiple explicit patterns: count each; the first trip carries
    // through (later patterns still run to completion, so a partial
    // outcome means "at least one row is a lower bound")
    let mut rows = Vec::with_capacity(spec.patterns.len());
    let mut stats = SearchStats::default();
    let mut tripped = None;
    for p in &spec.patterns {
        let pl = crate::pattern::plan(p, spec.vertex_induced, true);
        let out = crate::engine::dfs::count(g, &pl, cfg, &crate::engine::hooks::NoHooks)?;
        stats.merge(&out.stats);
        if tripped.is_none() {
            tripped = out.tripped;
        }
        rows.push((format!("{p}"), out.value));
    }
    Ok(match tripped {
        Some(reason) => Outcome::partial(MiningOutput::PerPattern(rows), stats, reason),
        None => Outcome::complete(MiningOutput::PerPattern(rows), stats),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::OptFlags;
    use crate::graph::gen;

    fn cfg() -> MinerConfig {
        MinerConfig::custom(2, 16, OptFlags::hi())
    }

    #[test]
    fn solve_tc_spec() {
        let g = gen::complete(5);
        match solve(&g, &ProblemSpec::tc(), &cfg()).unwrap().value {
            MiningOutput::Count(c) => assert_eq!(c, 10),
            other => panic!("unexpected output {other:?}"),
        }
    }

    #[test]
    fn solve_clique_spec_hi_and_lo() {
        let g = gen::erdos_renyi(30, 0.3, 4, &[]);
        let want = clique::clique_brute(&g, 4);
        for opts in [OptFlags::hi(), OptFlags::lo()] {
            let c = MinerConfig { opts, ..cfg() };
            match solve(&g, &ProblemSpec::clique_listing(4), &c).unwrap().value {
                MiningOutput::Count(got) => assert_eq!(got, want),
                other => panic!("unexpected output {other:?}"),
            }
        }
    }

    #[test]
    fn solve_motif_spec() {
        let g = gen::ring(8);
        match solve(&g, &ProblemSpec::motif_counting(3), &cfg()).unwrap().value {
            MiningOutput::PerPattern(rows) => {
                assert_eq!(rows[0], ("wedge".to_string(), 8));
                assert_eq!(rows[1], ("triangle".to_string(), 0));
            }
            other => panic!("unexpected output {other:?}"),
        }
    }

    #[test]
    fn solve_sl_spec() {
        let g = gen::complete(4);
        let spec = ProblemSpec::subgraph_listing(crate::pattern::library::diamond());
        match solve(&g, &spec, &cfg()).unwrap().value {
            MiningOutput::Count(c) => assert_eq!(c, 6),
            other => panic!("unexpected output {other:?}"),
        }
    }

    #[test]
    fn solve_fsm_spec() {
        let g = gen::erdos_renyi(40, 0.15, 21, &[1, 2]);
        match solve(&g, &ProblemSpec::fsm(2, 2), &cfg()).unwrap().value {
            MiningOutput::Frequent(rows) => {
                assert!(!rows.is_empty());
                assert!(rows.iter().all(|(_, s)| *s > 2));
            }
            other => panic!("unexpected output {other:?}"),
        }
    }
}
