//! The five paper applications (TC, k-CL, SL, k-MC, k-FSM), their
//! hand-optimized baselines, and the high-level `solve` facade that turns
//! a [`ProblemSpec`] into an answer — the dispatch table of paper §4.3.

pub mod baselines;
pub mod clique;
pub mod fsm_app;
pub mod motif;
pub mod sl;
pub mod tc;

use crate::engine::{MinerConfig, ProblemSpec};
use crate::graph::CsrGraph;
use crate::pattern::library;

/// What a solved GPM problem returns.
#[derive(Debug)]
pub enum MiningOutput {
    /// Single-pattern count.
    Count(u64),
    /// Multi-pattern counts with human-readable names.
    PerPattern(Vec<(String, u64)>),
    /// Frequent patterns with their supports.
    Frequent(Vec<(String, u64)>),
    /// Materialized embeddings (listing problems on request).
    Listing(Vec<Vec<u32>>),
}

/// High-level entry point: analyze the spec and run the right engine
/// with the right optimizations (the automation the paper's high-level
/// API promises).
pub fn solve(g: &CsrGraph, spec: &ProblemSpec, cfg: &MinerConfig) -> MiningOutput {
    if let Some(sigma) = spec.min_support {
        // implicit-pattern, edge-induced, anti-monotonic support: FSM
        let r = fsm_app::fsm(g, spec.k, sigma, cfg);
        return MiningOutput::Frequent(
            r.frequent
                .into_iter()
                .map(|f| (format!("{}", f.pattern), f.support))
                .collect(),
        );
    }
    if !spec.explicit {
        // implicit vertex-induced: motif counting
        let counts = match spec.k {
            3 => motif::motif3_hi(g, cfg).0,
            4 => motif::motif4_hi(g, cfg).0,
            k => {
                let table = crate::engine::esu::MotifTable::new(k);
                crate::engine::esu::count_motifs(
                    g,
                    k,
                    cfg,
                    &crate::engine::hooks::NoHooks,
                    &table,
                )
                .0
            }
        };
        let names: Vec<String> = match spec.k {
            3 => library::MOTIF3_NAMES.iter().map(|s| s.to_string()).collect(),
            4 => library::MOTIF4_NAMES.iter().map(|s| s.to_string()).collect(),
            k => (0..counts.len()).map(|i| format!("motif{k}-{i}")).collect(),
        };
        return MiningOutput::PerPattern(names.into_iter().zip(counts).collect());
    }
    // explicit pattern(s)
    if spec.patterns.len() == 1 {
        let p = &spec.patterns[0];
        if p.is_clique() && spec.vertex_induced {
            if p.num_vertices() == 3 {
                return MiningOutput::Count(tc::tc_hi(g, cfg));
            }
            // DAG decision (§4.3): cliques get orientation; LG when Lo
            let (c, _) = if cfg.opts.lg {
                clique::clique_lo(g, p.num_vertices(), cfg)
            } else {
                clique::clique_hi(g, p.num_vertices(), cfg)
            };
            return MiningOutput::Count(c);
        }
        if spec.listing && !spec.vertex_induced {
            let (c, _) = sl::sl_count(g, p, cfg);
            return MiningOutput::Count(c);
        }
        let pl = crate::pattern::plan(p, spec.vertex_induced, cfg.opts.sb);
        let (c, _) = crate::engine::dfs::count(g, &pl, cfg, &crate::engine::hooks::NoHooks);
        let c = if cfg.opts.sb {
            c
        } else {
            c / crate::pattern::symmetry::automorphism_count(p)
        };
        return MiningOutput::Count(c);
    }
    // multiple explicit patterns: count each
    MiningOutput::PerPattern(
        spec.patterns
            .iter()
            .map(|p| {
                let pl = crate::pattern::plan(p, spec.vertex_induced, true);
                let (c, _) =
                    crate::engine::dfs::count(g, &pl, cfg, &crate::engine::hooks::NoHooks);
                (format!("{p}"), c)
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::OptFlags;
    use crate::graph::gen;

    fn cfg() -> MinerConfig {
        MinerConfig::custom(2, 16, OptFlags::hi())
    }

    #[test]
    fn solve_tc_spec() {
        let g = gen::complete(5);
        match solve(&g, &ProblemSpec::tc(), &cfg()) {
            MiningOutput::Count(c) => assert_eq!(c, 10),
            other => panic!("unexpected output {other:?}"),
        }
    }

    #[test]
    fn solve_clique_spec_hi_and_lo() {
        let g = gen::erdos_renyi(30, 0.3, 4, &[]);
        let want = clique::clique_brute(&g, 4);
        for opts in [OptFlags::hi(), OptFlags::lo()] {
            let c = MinerConfig { opts, ..cfg() };
            match solve(&g, &ProblemSpec::clique_listing(4), &c) {
                MiningOutput::Count(got) => assert_eq!(got, want),
                other => panic!("unexpected output {other:?}"),
            }
        }
    }

    #[test]
    fn solve_motif_spec() {
        let g = gen::ring(8);
        match solve(&g, &ProblemSpec::motif_counting(3), &cfg()) {
            MiningOutput::PerPattern(rows) => {
                assert_eq!(rows[0], ("wedge".to_string(), 8));
                assert_eq!(rows[1], ("triangle".to_string(), 0));
            }
            other => panic!("unexpected output {other:?}"),
        }
    }

    #[test]
    fn solve_sl_spec() {
        let g = gen::complete(4);
        let spec = ProblemSpec::subgraph_listing(crate::pattern::library::diamond());
        match solve(&g, &spec, &cfg()) {
            MiningOutput::Count(c) => assert_eq!(c, 6),
            other => panic!("unexpected output {other:?}"),
        }
    }

    #[test]
    fn solve_fsm_spec() {
        let g = gen::erdos_renyi(40, 0.15, 21, &[1, 2]);
        match solve(&g, &ProblemSpec::fsm(2, 2), &cfg()) {
            MiningOutput::Frequent(rows) => {
                assert!(!rows.is_empty());
                assert!(rows.iter().all(|(_, s)| *s > 2));
            }
            other => panic!("unexpected output {other:?}"),
        }
    }
}
