//! Triangle Counting (TC).
//!
//! Sandslash-Hi decision for triangles (paper §4.3): DAG orientation +
//! sorted set intersection (MNC and MO are *not* used — "for triangles,
//! Sandslash uses set intersection instead of MNC"). Each triangle
//! appears exactly once as (v, u, w) with rank(v) < rank(u) < rank(w), so
//! the count is Σ_v Σ_{u ∈ out(v)} |out(v) ∩ out(u)| with no correction.

use crate::engine::budget::{MineError, Outcome};
use crate::engine::dfs;
use crate::engine::hooks::NoHooks;
use crate::engine::{MinerConfig, OptFlags};
use crate::graph::setops::intersect_count;
use crate::graph::orientation::{orient, Dag, OrientScheme};
use crate::graph::CsrGraph;
use crate::pattern::{library, plan};
use crate::util::pool::parallel_reduce;

/// Sandslash-Hi TC: DAG + intersection.
pub fn tc_hi(g: &CsrGraph, cfg: &MinerConfig) -> u64 {
    let dag = orient(g, OrientScheme::Degree);
    tc_on_dag(&dag, cfg)
}

/// Count triangles on a prebuilt DAG (shared by baselines).
pub fn tc_on_dag(dag: &Dag, cfg: &MinerConfig) -> u64 {
    let n = dag.num_vertices();
    parallel_reduce(
        n,
        cfg.threads,
        cfg.chunk,
        || 0u64,
        |acc, v| {
            let out_v = dag.out_neighbors(v as u32);
            for &u in out_v {
                *acc += intersect_count(out_v, dag.out_neighbors(u)) as u64;
            }
        },
        |a, b| a + b,
    )
}

/// TC through the generic pattern-guided engine (used by the system
/// emulations: Peregrine-like = SB without DAG; AutoMine-like = no SB,
/// divide by |Aut| = 6 at the end). Governed (PR 6): forwards the
/// engine's [`Outcome`]/[`MineError`] contract.
pub fn tc_generic(g: &CsrGraph, cfg: &MinerConfig) -> Result<Outcome<u64>, MineError> {
    let tri = library::triangle();
    let pl = plan(&tri, true, cfg.opts.sb);
    let mut out = dfs::count(g, &pl, cfg, &NoHooks)?;
    if !cfg.opts.sb {
        out.value /= 6;
    }
    Ok(out)
}

/// Reference: brute-force over vertex triples (test oracle; small n only).
pub fn tc_brute(g: &CsrGraph) -> u64 {
    let n = g.num_vertices() as u32;
    let mut c = 0;
    for a in 0..n {
        for b in (a + 1)..n {
            if !g.has_edge(a, b) {
                continue;
            }
            for d in (b + 1)..n {
                if g.has_edge(a, d) && g.has_edge(b, d) {
                    c += 1;
                }
            }
        }
    }
    c
}

/// Per-vertex local triangle counts (local counting substrate; also used
/// by the 3-MC-Lo wedge formula).
pub fn local_triangles_per_edge(g: &CsrGraph, cfg: &MinerConfig) -> Vec<(u32, u32, u32)> {
    let edges: Vec<(u32, u32)> = g.edges().collect();
    parallel_reduce(
        edges.len(),
        cfg.threads,
        cfg.chunk,
        Vec::new,
        |acc: &mut Vec<(u32, u32, u32)>, i| {
            let (u, v) = edges[i];
            acc.push((u, v, g.intersect_count(u, v) as u32));
        },
        |mut a, b| {
            a.extend(b);
            a
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn cfg() -> MinerConfig {
        MinerConfig::custom(2, 16, OptFlags::hi())
    }

    #[test]
    fn known_counts() {
        assert_eq!(tc_hi(&gen::complete(5), &cfg()), 10);
        assert_eq!(tc_hi(&gen::ring(10), &cfg()), 0);
        assert_eq!(tc_hi(&gen::complete(3), &cfg()), 1);
    }

    #[test]
    fn hi_matches_brute_on_random() {
        for seed in [1, 2, 3] {
            let g = gen::erdos_renyi(60, 0.15, seed, &[]);
            assert_eq!(tc_hi(&g, &cfg()), tc_brute(&g));
        }
    }

    #[test]
    fn generic_engine_agrees_with_and_without_sb() {
        let g = gen::rmat(8, 6, 7, &[]);
        let expect = tc_hi(&g, &cfg());
        let (sb, _) = tc_generic(&g, &cfg()).unwrap().into_parts();
        assert_eq!(sb, expect);
        let mut no_sb = cfg();
        no_sb.opts = OptFlags::automine_like();
        let (div, _) = tc_generic(&g, &no_sb).unwrap().into_parts();
        assert_eq!(div, expect);
    }

    #[test]
    fn local_edge_triangles_sum_to_3t() {
        let g = gen::erdos_renyi(50, 0.2, 9, &[]);
        let t = tc_hi(&g, &cfg());
        let per_edge: u64 = local_triangles_per_edge(&g, &cfg())
            .iter()
            .map(|&(_, _, c)| c as u64)
            .sum();
        assert_eq!(per_edge, 3 * t); // each triangle lies on 3 edges
    }
}
