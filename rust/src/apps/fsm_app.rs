//! k-FSM application wrapper: the paper's Table 1 right-hand column
//! realized — edge-induced, implicit patterns, MNI domain support with
//! anti-monotone filtering on the sub-pattern tree.

use crate::engine::budget::{MineError, Outcome};
use crate::engine::fsm::{mine_fsm, mine_fsm_bfs, FrequentPattern};
use crate::engine::MinerConfig;
use crate::graph::CsrGraph;

/// Sandslash k-FSM (DFS on the sub-pattern tree). The full `cfg` is
/// forwarded (PR 5): thread count, scheduler knobs (fat root-pattern
/// bins publish split tasks under starvation), and the extension-core
/// toggle. Governed (PR 6): forwards the engine's
/// [`Outcome`]/[`MineError`] contract.
pub fn fsm(
    g: &CsrGraph,
    max_edges: usize,
    min_support: u64,
    cfg: &MinerConfig,
) -> Result<Outcome<Vec<FrequentPattern>>, MineError> {
    mine_fsm(g, max_edges, min_support, cfg)
}

/// BFS variant (Pangolin-like / Peregrine-FSM-like level sync).
/// Governed (PR 6) like [`fsm`].
pub fn fsm_bfs(
    g: &CsrGraph,
    max_edges: usize,
    min_support: u64,
    cfg: &MinerConfig,
) -> Result<Outcome<Vec<FrequentPattern>>, MineError> {
    mine_fsm_bfs(g, max_edges, min_support, cfg)
}

/// DistGraph-like: the same gSpan-style DFS with a single work queue
/// (coarse tasks — DistGraph's dynamic splitting is approximated by our
/// root-level task pool at chunk 1, pinned to one worker).
/// Governed (PR 6) like [`fsm`].
pub fn fsm_distgraph_like(
    g: &CsrGraph,
    max_edges: usize,
    min_support: u64,
    cfg: &MinerConfig,
) -> Result<Outcome<Vec<FrequentPattern>>, MineError> {
    mine_fsm(g, max_edges, min_support, &cfg.with_threads(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::OptFlags;
    use crate::graph::gen;

    #[test]
    fn dfs_and_bfs_find_same_frequent_patterns() {
        let g = gen::erdos_renyi(50, 0.1, 13, &[1, 2, 3]);
        let cfg = MinerConfig::custom(2, 8, OptFlags::hi());
        let a = fsm(&g, 3, 1, &cfg).unwrap().value;
        let b = fsm_bfs(&g, 3, 1, &cfg).unwrap().value;
        let sa: Vec<_> = a.iter().map(|f| (f.code.clone(), f.support)).collect();
        let sb: Vec<_> = b.iter().map(|f| (f.code.clone(), f.support)).collect();
        assert_eq!(sa, sb);
    }

    #[test]
    fn higher_support_means_fewer_patterns() {
        let g = gen::erdos_renyi(60, 0.1, 17, &[1, 2]);
        let cfg = MinerConfig::custom(2, 8, OptFlags::hi());
        let lo = fsm(&g, 3, 1, &cfg).unwrap().value.len();
        let hi = fsm(&g, 3, 5, &cfg).unwrap().value.len();
        assert!(hi <= lo);
    }
}
