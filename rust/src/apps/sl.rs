//! Subgraph Listing (SL): enumerate all edge-induced embeddings of an
//! explicit pattern (paper §2, problem 3; evaluated on diamond and
//! 4-cycle in Table 8).
//!
//! Sandslash-Hi applies MO + SB + DF + MNC automatically from the
//! high-level spec; this module is a thin wrapper over the
//! pattern-guided DFS engine with an edge-induced plan. With
//! `OptFlags::lg` (the Lo preset) the engine additionally switches deep
//! levels onto shrinking local graphs
//! ([`crate::engine::local_graph::PlanLocalGraph`]) — SL inherits the
//! stage with no changes here because it rides the same plan
//! interpreter.

use crate::engine::budget::{MineError, Outcome};
use crate::engine::dfs;
use crate::engine::hooks::NoHooks;
use crate::engine::MinerConfig;
use crate::graph::{CsrGraph, VertexId};
use crate::pattern::{plan, Pattern};

/// Count edge-induced embeddings of `p`. Governed (PR 6): forwards the
/// DFS engine's [`Outcome`]/[`MineError`] contract.
pub fn sl_count(g: &CsrGraph, p: &Pattern, cfg: &MinerConfig) -> Result<Outcome<u64>, MineError> {
    let pl = plan(p, false, cfg.opts.sb);
    let mut out = dfs::count(g, &pl, cfg, &NoHooks)?;
    if !cfg.opts.sb {
        out.value /= crate::pattern::symmetry::automorphism_count(p);
    }
    Ok(out)
}

/// List embeddings (materialized; for modest result sizes / the listing
/// API demo). Each row is in matching-plan order. Governed (PR 6): a
/// budget trip would silently truncate the listing, so only the full
/// rows of a complete run are returned; partial runs surface through
/// the [`Outcome`] the caller can inspect.
pub fn sl_list(
    g: &CsrGraph,
    p: &Pattern,
    cfg: &MinerConfig,
) -> Result<Outcome<Vec<Vec<VertexId>>>, MineError> {
    let pl = plan(p, false, true);
    dfs::mine(
        g,
        &pl,
        cfg,
        &NoHooks,
        Vec::new,
        |acc: &mut Vec<Vec<VertexId>>, emb| acc.push(emb.to_vec()),
        |mut a, b| {
            a.extend(b);
            a
        },
    )
}

/// Brute-force oracle: count edge-induced embeddings (vertex sets where
/// the pattern maps injectively preserving edges), deduplicated per
/// automorphism class.
pub fn sl_brute(g: &CsrGraph, p: &Pattern) -> u64 {
    let k = p.num_vertices();
    let n = g.num_vertices();
    let mut count = 0u64;
    let mut sel: Vec<u32> = Vec::with_capacity(k);
    fn rec(
        g: &CsrGraph,
        p: &Pattern,
        k: usize,
        sel: &mut Vec<u32>,
        n: usize,
        count: &mut u64,
    ) {
        if sel.len() == k {
            // count injective mappings preserving pattern edges
            let mut perm: Vec<usize> = (0..k).collect();
            let mut found = false;
            loop {
                let ok = (0..k).all(|i| {
                    (0..k).all(|j| {
                        !p.has_edge(i, j) || g.has_edge(sel[perm[i]], sel[perm[j]])
                    })
                });
                if ok {
                    found = true;
                    break;
                }
                if !next_perm(&mut perm) {
                    break;
                }
            }
            if found {
                *count += 1;
            }
            return;
        }
        let start = sel.last().map(|&v| v + 1).unwrap_or(0);
        for v in start..n as u32 {
            sel.push(v);
            rec(g, p, k, sel, n, count);
            sel.pop();
        }
    }
    fn next_perm(p: &mut [usize]) -> bool {
        let n = p.len();
        let mut i = n - 1;
        while i > 0 && p[i - 1] >= p[i] {
            i -= 1;
        }
        if i == 0 {
            return false;
        }
        let mut j = n - 1;
        while p[j] <= p[i - 1] {
            j -= 1;
        }
        p.swap(i - 1, j);
        p[i..].reverse();
        true
    }
    rec(g, p, k, &mut sel, n, &mut count);
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::OptFlags;
    use crate::graph::gen;
    use crate::pattern::library;

    fn cfg() -> MinerConfig {
        MinerConfig::custom(2, 16, OptFlags::hi())
    }

    #[test]
    fn diamond_count_matches_brute() {
        // NOTE: sl counts *embeddings* (one per vertex-set-with-matching),
        // brute counts vertex sets admitting a mapping — for diamond these
        // differ when a K4 admits multiple diamond mappings. Use a graph
        // without K4s for exact match.
        let g = gen::erdos_renyi(25, 0.15, 42, &[]);
        if super::super::clique::clique_brute(&g, 4) == 0 {
            let (c, _) = sl_count(&g, &library::diamond(), &cfg()).unwrap().into_parts();
            assert_eq!(c, sl_brute(&g, &library::diamond()));
        }
    }

    #[test]
    fn cycle4_in_ring_and_k4() {
        let (c, _) = sl_count(&gen::ring(4), &library::cycle(4), &cfg()).unwrap().into_parts();
        assert_eq!(c, 1);
        // K4 contains 3 distinct 4-cycles (pairs of perfect matchings)
        let (k, _) = sl_count(&gen::complete(4), &library::cycle(4), &cfg()).unwrap().into_parts();
        assert_eq!(k, 3);
    }

    #[test]
    fn diamond_in_k4() {
        // K4 has 6 edge-induced diamonds (choose the missing edge)
        let (c, _) = sl_count(&gen::complete(4), &library::diamond(), &cfg()).unwrap().into_parts();
        assert_eq!(c, 6);
    }

    #[test]
    fn listing_agrees_with_count() {
        let g = gen::erdos_renyi(30, 0.2, 5, &[]);
        let p = library::cycle(4);
        let (c, _) = sl_count(&g, &p, &cfg()).unwrap().into_parts();
        let rows = sl_list(&g, &p, &cfg()).unwrap().value;
        assert_eq!(rows.len() as u64, c);
        // all listed embeddings are genuinely cycles
        for r in rows.iter().take(50) {
            assert!(g.has_edge(r[0], r[1]) || g.has_edge(r[0], r[2]) || g.has_edge(r[0], r[3]));
        }
    }

    #[test]
    fn lg_stage_matches_hi_on_sl_patterns() {
        let g = gen::rmat(8, 6, 17, &[]);
        for p in [library::diamond(), library::cycle(4)] {
            let (hi, _) = sl_count(&g, &p, &cfg()).unwrap().into_parts();
            let mut c = cfg();
            c.opts = OptFlags::lo();
            let (lo, _) = sl_count(&g, &p, &c).unwrap().into_parts();
            assert_eq!(hi, lo, "{p}");
        }
    }

    #[test]
    fn sb_on_off_agree() {
        let g = gen::rmat(7, 5, 9, &[]);
        let p = library::cycle(4);
        let (on, _) = sl_count(&g, &p, &cfg()).unwrap().into_parts();
        let mut c = cfg();
        c.opts.sb = false;
        let (off, _) = sl_count(&g, &p, &c).unwrap().into_parts();
        assert_eq!(on, off);
    }
}
