//! k-Motif Counting (k-MC), k = 3, 4.
//!
//! * Sandslash-Hi: pattern-oblivious exact-once enumeration (ESU engine)
//!   with MEC+MNC, classifying leaves by connectivity codes.
//! * Sandslash-Lo: formula-based Local Counting (paper §5, Listings 2–3;
//!   PGD [3]): enumerate only the cheap anchor patterns (triangles for
//!   3-MC; 4-cliques and induced 4-cycles for 4-MC), derive everything
//!   else from per-edge/per-vertex local counts, then convert raw counts
//!   to induced counts with the standard correction identities.

use crate::engine::budget::{MineError, Outcome};
use crate::engine::esu::{count_motifs, MotifTable};
use crate::engine::hooks::NoHooks;
use crate::engine::MinerConfig;
use crate::graph::CsrGraph;
use crate::pattern::decompose;
use crate::pattern::{library, plan};

use super::clique::clique_hi;
use super::tc::tc_hi;

/// 3-motif counts, Hi path: [wedge, triangle] (all_motifs(3) order).
/// Governed (PR 6): forwards the ESU engine's [`Outcome`] contract.
pub fn motif3_hi(g: &CsrGraph, cfg: &MinerConfig) -> Result<Outcome<Vec<u64>>, MineError> {
    let table = MotifTable::new(3);
    count_motifs(g, 3, cfg, &NoHooks, &table)
}

/// 4-motif counts, Hi path (all_motifs(4) order:
/// [3-star, 4-path, tailed-triangle, 4-cycle, diamond, 4-clique]).
/// Governed (PR 6): forwards the ESU engine's [`Outcome`] contract.
pub fn motif4_hi(g: &CsrGraph, cfg: &MinerConfig) -> Result<Outcome<Vec<u64>>, MineError> {
    let table = MotifTable::new(4);
    count_motifs(g, 4, cfg, &NoHooks, &table)
}

/// 3-motif census, planner-fronted (PR 10): with
/// [`OptFlags::plan_active`](crate::engine::OptFlags::plan_active) the
/// algebraic census ([`decompose::motif_census`]) runs — one triangle
/// anchor plus a vertex scan; otherwise the exact-once ESU oracle
/// ([`motif3_hi`]). Both are governed and bit-identical.
pub fn motif3(g: &CsrGraph, cfg: &MinerConfig) -> Result<Outcome<Vec<u64>>, MineError> {
    if cfg.opts.plan_active() {
        decompose::motif_census(g, 3, cfg)
    } else {
        motif3_hi(g, cfg)
    }
}

/// 4-motif census, planner-fronted (PR 10): with
/// [`OptFlags::plan_active`](crate::engine::OptFlags::plan_active) the
/// algebraic census runs — 4-clique and 4-cycle anchors plus one
/// vertex and one edge scan; otherwise the ESU oracle ([`motif4_hi`]).
pub fn motif4(g: &CsrGraph, cfg: &MinerConfig) -> Result<Outcome<Vec<u64>>, MineError> {
    if cfg.opts.plan_active() {
        decompose::motif_census(g, 4, cfg)
    } else {
        motif4_hi(g, cfg)
    }
}

/// 3-MC-Lo (paper Listing 2): triangles by enumeration, wedges by the
/// per-vertex formula Σ_v C(deg v, 2) − 3T (the shared
/// [`decompose::vertex_comb_sum`] leaf since PR 10).
pub fn motif3_lo(g: &CsrGraph, cfg: &MinerConfig) -> Vec<u64> {
    let t = tc_hi(g, cfg);
    let paths2 = decompose::vertex_comb_sum(g, cfg, 2);
    vec![paths2 - 3 * t, t]
}

/// Per-edge raw local counts for the 4-motif formulas: returns
/// (Σ C(tri_e,2), Σ tri_e(s_u+s_v), Σ s_u·s_v) — the body of Listing 3.
/// Since PR 10 this delegates to the planner's shared
/// [`decompose::edge_local_counts`] leaf (one implementation for the
/// Lo path, the PGD baseline and the decomposition planner).
pub fn edge_raw_counts(g: &CsrGraph, cfg: &MinerConfig) -> (u64, u64, u64) {
    decompose::edge_local_counts(g, cfg)
}

/// 4-MC-Lo (paper Listing 3 + PGD conversions): enumerate 4-cliques and
/// induced 4-cycles only; derive diamond / tailed-triangle / 4-path /
/// 3-star from local counts. The 4-cycle anchor runs through the
/// generic DFS engine, so with `OptFlags::lg` in `cfg` it uses the
/// generalized shrinking-local-graph stage past the plan's coverage
/// level. Conversions:
///
/// ```text
/// D  = Σ_e C(tri_e,2) − 6·C4
/// TT = (Σ_e tri_e(s_u+s_v) − 4·D) / 2
/// P4 = Σ_e s_u·s_v − 4·Cy
/// S3 = Σ_v C(deg v,3) − TT − 2·D − 4·C4
/// ```
///
/// The 4-cycle anchor rides the governed DFS engine, so this returns
/// its [`MineError`] on a worker panic (a budget trip would make the
/// formulas unsound, hence the whole-result `Result`).
pub fn motif4_lo(g: &CsrGraph, cfg: &MinerConfig) -> Result<Vec<u64>, MineError> {
    // anchors: the two enumerated patterns of Listing 3
    let (c4, _) = clique_hi(g, 4, cfg);
    let cyc_plan = plan(&library::cycle(4), true, true);
    let (cy, _) = crate::engine::dfs::count(g, &cyc_plan, cfg, &NoHooks)?.into_parts();
    // local counts (shared planner leaves since PR 10)
    let (raw_d, raw_tt, raw_p4) = edge_raw_counts(g, cfg);
    let raw_s3 = decompose::vertex_comb_sum(g, cfg, 3);
    // conversions to induced counts
    let d = raw_d - 6 * c4;
    let tt = (raw_tt - 4 * d) / 2;
    let p4 = raw_p4 - 4 * cy;
    let s3 = raw_s3 - tt - 2 * d - 4 * c4;
    Ok(vec![s3, p4, tt, cy, d, c4])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::OptFlags;
    use crate::graph::gen;

    fn cfg() -> MinerConfig {
        MinerConfig::custom(2, 16, OptFlags::hi())
    }

    #[test]
    fn lo3_matches_hi3() {
        for seed in [1, 2] {
            let g = gen::erdos_renyi(80, 0.1, seed, &[]);
            let (hi, _) = motif3_hi(&g, &cfg()).unwrap().into_parts();
            let lo = motif3_lo(&g, &cfg());
            assert_eq!(hi, lo, "seed {seed}");
        }
    }

    #[test]
    fn lo4_matches_hi4_er() {
        for seed in [3, 4] {
            let g = gen::erdos_renyi(50, 0.15, seed, &[]);
            let (hi, _) = motif4_hi(&g, &cfg()).unwrap().into_parts();
            let lo = motif4_lo(&g, &cfg()).unwrap();
            assert_eq!(hi, lo, "seed {seed}");
        }
    }

    #[test]
    fn lo4_matches_hi4_rmat() {
        let g = gen::rmat(8, 5, 6, &[]);
        let (hi, _) = motif4_hi(&g, &cfg()).unwrap().into_parts();
        let lo = motif4_lo(&g, &cfg()).unwrap();
        assert_eq!(hi, lo);
    }

    #[test]
    fn lo4_with_lg_stage_matches_hi4() {
        // the 4-cycle anchor rides the generic engine: with the full Lo
        // preset it takes the local-graph stage and must not change
        let g = gen::rmat(8, 5, 9, &[]);
        let (hi, _) = motif4_hi(&g, &cfg()).unwrap().into_parts();
        let mut c = cfg();
        c.opts = OptFlags::lo();
        let lo = motif4_lo(&g, &c).unwrap();
        assert_eq!(hi, lo);
    }

    #[test]
    fn complete_graph_4motifs() {
        let g = gen::complete(6);
        let lo = motif4_lo(&g, &cfg()).unwrap();
        assert_eq!(lo, vec![0, 0, 0, 0, 0, 15]);
    }

    #[test]
    fn ring_4motifs() {
        let g = gen::ring(12);
        let lo = motif4_lo(&g, &cfg()).unwrap();
        // 12 paths, nothing else
        assert_eq!(lo, vec![0, 12, 0, 0, 0, 0]);
    }

    #[test]
    fn planner_fronted_wrappers_match_esu_and_respect_plan_flag() {
        let g = gen::rmat(7, 5, 2, &[]);
        let (hi3, _) = motif3_hi(&g, &cfg()).unwrap().into_parts();
        let (hi4, _) = motif4_hi(&g, &cfg()).unwrap().into_parts();
        assert_eq!(motif3(&g, &cfg()).unwrap().value, hi3);
        assert_eq!(motif4(&g, &cfg()).unwrap().value, hi4);
        // per-run opt-out pins the ESU oracle (same counts by construction)
        let mut c = cfg();
        c.opts.plan = false;
        assert_eq!(motif3(&g, &c).unwrap().value, hi3);
        assert_eq!(motif4(&g, &c).unwrap().value, hi4);
    }

    #[test]
    fn motif3_total_is_connected_triples() {
        let g = gen::erdos_renyi(40, 0.2, 8, &[]);
        let (hi, _) = motif3_hi(&g, &cfg()).unwrap().into_parts();
        let lo = motif3_lo(&g, &cfg());
        assert_eq!(hi.iter().sum::<u64>(), lo.iter().sum::<u64>());
    }
}
