//! k-Clique Listing (k-CL).
//!
//! * Sandslash-Hi: DAG orientation (degree-based) + per-root DFS where
//!   the candidate set is the running intersection of out-neighborhoods
//!   (the set-intersection realization of MNC for cliques).
//! * Sandslash-Lo: adds the LG optimization — kClist-style search on a
//!   shrinking local graph built from the core-ordered DAG (paper §5,
//!   Listing 4). The low-level user code is `initLG`/`updateLG`; the
//!   engine mechanics live in [`crate::engine::local_graph`].
//!
//! Cliques mined through the *generic* plan interpreter (e.g. via
//! [`crate::apps::solve`] with a non-clique spec, or the differential
//! tests) get the generalized LG stage of
//! [`crate::engine::local_graph::PlanLocalGraph`] instead; this module
//! keeps the hand-tuned DAG form as the performance ceiling the paper
//! reports in Fig. 9.

use crate::engine::local_graph::LocalGraph;
use crate::engine::MinerConfig;
use crate::graph::setops::{intersect_count, intersect_into};
use crate::graph::orientation::{orient, Dag, OrientScheme};
use crate::graph::CsrGraph;
use crate::util::metrics::SearchStats;
use crate::util::pool::parallel_reduce;

/// Sandslash-Hi k-CL: DAG + running intersections.
pub fn clique_hi(g: &CsrGraph, k: usize, cfg: &MinerConfig) -> (u64, SearchStats) {
    assert!(k >= 3);
    let dag = orient(g, OrientScheme::Degree);
    clique_on_dag(g, &dag, k, cfg)
}

/// k-CL on a caller-supplied DAG: per-root DFS where the candidate
/// set is the running intersection of out-neighborhoods (shared by
/// `clique_hi` and emulations that pick their own orientation).
pub fn clique_on_dag(
    _g: &CsrGraph,
    dag: &Dag,
    k: usize,
    cfg: &MinerConfig,
) -> (u64, SearchStats) {
    let n = dag.num_vertices();
    struct St {
        count: u64,
        stats: SearchStats,
        /// per-level candidate buffers (reused, zero allocation per node)
        bufs: Vec<Vec<u32>>,
    }
    let out = parallel_reduce(
        n,
        cfg.threads,
        cfg.chunk,
        || St { count: 0, stats: SearchStats::default(), bufs: vec![Vec::new(); k] },
        |st, v| {
            let v = v as u32;
            let out_v = dag.out_neighbors(v);
            if out_v.len() + 2 < k {
                return; // DF: cannot reach k
            }
            if cfg.opts.stats {
                st.stats.enumerated += 1;
            }
            rec(dag, k, 2, out_v, st, cfg);
        },
        |a, b| {
            let mut stats = a.stats;
            stats.merge(&b.stats);
            St { count: a.count + b.count, stats, bufs: a.bufs }
        },
    );

    fn rec(dag: &Dag, k: usize, depth: usize, cands: &[u32], st: &mut St, cfg: &MinerConfig) {
        // move the buffer out to satisfy the borrow checker, put it back
        let mut buf = std::mem::take(&mut st.bufs[depth]);
        for i in 0..cands.len() {
            let u = cands[i];
            if cfg.opts.stats {
                st.stats.enumerated += 1;
                st.stats.intersections += 1;
            }
            if depth + 1 == k {
                // last level: count the intersection without
                // materializing it (same kernel family, no buffer write)
                let c = intersect_count(cands, dag.out_neighbors(u)) as u64;
                st.count += c;
                if cfg.opts.stats {
                    st.stats.enumerated += c;
                    st.stats.matches += c;
                }
                continue;
            }
            buf.clear();
            intersect_into(cands, dag.out_neighbors(u), &mut buf);
            if buf.len() + depth + 1 >= k {
                rec(dag, k, depth + 1, &buf, st, cfg);
            } else if cfg.opts.stats {
                st.stats.pruned += 1;
            }
        }
        st.bufs[depth] = buf;
    }

    (out.count, out.stats)
}

/// Sandslash-Lo k-CL: core-ordered DAG + local-graph search (kClist).
/// This is the paper's Listing-4 user code wired to the LG substrate.
pub fn clique_lo(g: &CsrGraph, k: usize, cfg: &MinerConfig) -> (u64, SearchStats) {
    assert!(k >= 3);
    let dag = orient(g, OrientScheme::Core);
    let n = dag.num_vertices();
    let max_out = dag.max_out_degree();
    struct St {
        count: u64,
        stats: SearchStats,
        lg: LocalGraph,
    }
    let out = parallel_reduce(
        n,
        cfg.threads,
        cfg.chunk,
        || St {
            count: 0,
            stats: SearchStats::default(),
            lg: LocalGraph::new(max_out.max(1), k),
        },
        |st, v| {
            let v = v as u32;
            if dag.out_degree(v) + 2 < k {
                return;
            }
            // initLG: local graph on out(v)
            let nl = st.lg.init_from_dag(&dag, v);
            if cfg.opts.stats {
                st.stats.lg_vertices += nl as u64;
            }
            // depth 1: every local vertex is a (v, u) 2-clique
            for u in 0..nl {
                visit(k, 1, u, st, cfg);
            }
        },
        |a, b| {
            let mut stats = a.stats;
            stats.merge(&b.stats);
            St { count: a.count + b.count, stats, lg: a.lg }
        },
    );

    /// Extend the clique with local vertex `u` at `depth` and recurse
    /// over u's surviving candidate prefix. Candidates are read in place
    /// from the local graph (no per-node allocation — §Perf): `u`'s list
    /// prefix is stable during its own subtree because a DAG vertex is
    /// never compacted by its own descendants.
    fn visit(k: usize, depth: usize, u: usize, st: &mut St, cfg: &MinerConfig) {
        if cfg.opts.stats {
            st.stats.enumerated += 1;
        }
        // embedding after adding u = root + depth locals = depth + 1
        let deg = st.lg.degree(depth - 1, u) as usize;
        if depth + 2 == k {
            // every remaining candidate completes a k-clique
            st.count += deg as u64;
            if cfg.opts.stats {
                st.stats.matches += deg as u64;
                st.stats.enumerated += deg as u64;
            }
            return;
        }
        if deg + depth + 1 < k {
            if cfg.opts.stats {
                st.stats.pruned += 1;
            }
            return;
        }
        // updateLG: shrink to the neighbors of u surviving this depth
        st.lg.shrink(depth, u);
        for i in 0..deg {
            let w = st.lg.candidate_at(u, i) as usize;
            visit(k, depth + 1, w, st, cfg);
        }
        st.lg.unshrink(depth, u);
    }

    (out.count, out.stats)
}

/// Brute-force oracle.
pub fn clique_brute(g: &CsrGraph, k: usize) -> u64 {
    fn rec(g: &CsrGraph, k: usize, emb: &mut Vec<u32>, start: u32, count: &mut u64) {
        if emb.len() == k {
            *count += 1;
            return;
        }
        for v in start..g.num_vertices() as u32 {
            if emb.iter().all(|&u| g.has_edge(u, v)) {
                emb.push(v);
                rec(g, k, emb, v + 1, count);
                emb.pop();
            }
        }
    }
    let mut c = 0;
    rec(g, k, &mut Vec::new(), 0, &mut c);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::OptFlags;
    use crate::graph::gen;

    fn cfg() -> MinerConfig {
        MinerConfig::custom(2, 16, OptFlags::hi())
    }

    #[test]
    fn k4_in_complete6() {
        let g = gen::complete(6);
        assert_eq!(clique_hi(&g, 4, &cfg()).0, 15);
        assert_eq!(clique_lo(&g, 4, &cfg()).0, 15);
    }

    #[test]
    fn k5_in_complete7() {
        let g = gen::complete(7);
        assert_eq!(clique_hi(&g, 5, &cfg()).0, 21); // C(7,5)
        assert_eq!(clique_lo(&g, 5, &cfg()).0, 21);
    }

    #[test]
    fn hi_lo_brute_agree_on_random() {
        for seed in [4, 5] {
            let g = gen::erdos_renyi(40, 0.3, seed, &[]);
            for k in 3..=5 {
                let brute = clique_brute(&g, k);
                assert_eq!(clique_hi(&g, k, &cfg()).0, brute, "hi k={k}");
                assert_eq!(clique_lo(&g, k, &cfg()).0, brute, "lo k={k}");
            }
        }
    }

    #[test]
    fn rmat_hi_lo_agree_large_k() {
        let g = gen::rmat(9, 10, 77, &[]);
        for k in 4..=7 {
            assert_eq!(clique_hi(&g, k, &cfg()).0, clique_lo(&g, k, &cfg()).0, "k={k}");
        }
    }

    #[test]
    fn no_cliques_in_sparse_ring() {
        let g = gen::ring(20);
        assert_eq!(clique_hi(&g, 3, &cfg()).0, 0);
        assert_eq!(clique_lo(&g, 4, &cfg()).0, 0);
    }

    #[test]
    fn lo_search_space_not_larger_than_hi() {
        // Fig. 10: the LG path should enumerate no more embeddings.
        let g = gen::rmat(8, 10, 5, &[]);
        let mut c = cfg();
        c.opts = OptFlags::hi().with_stats();
        let (_, hi_stats) = clique_hi(&g, 5, &c);
        let mut cl = cfg();
        cl.opts = OptFlags::lo().with_stats();
        let (_, lo_stats) = clique_lo(&g, 5, &cl);
        assert!(lo_stats.enumerated <= hi_stats.enumerated * 2,
            "lo={} hi={}", lo_stats.enumerated, hi_stats.enumerated);
    }
}
