//! Infrastructure substrates built from scratch (the offline crate
//! registry has no `rand`/`clap`/`serde`/`rayon`/`criterion`, so the
//! framework ships its own equivalents).

pub mod bench;
pub mod bitset;
pub mod cli;
pub mod config;
pub mod fault;
pub mod metrics;
pub mod model;
pub mod pool;
pub mod rng;
pub mod sync;
pub mod timer;
