//! Fixed-capacity bitset over `Vec<u64>` words.
//!
//! Used for visited sets during search, k-core peeling, MNI domains, and
//! dense-tile extraction. Clearing tracks touched words so repeated use
//! inside the DFS hot loop is O(touched), not O(capacity).

#[derive(Clone, Debug, Default)]
/// Fixed-capacity bitset with O(touched) clearing.
pub struct BitSet {
    words: Vec<u64>,
    /// Indices of words that may be non-zero (for sparse clearing).
    touched: Vec<u32>,
}

impl BitSet {
    /// All-zero bitset able to hold indices < `capacity` (rounded up).
    pub fn new(capacity: usize) -> Self {
        Self {
            words: vec![0; capacity.div_ceil(64)],
            touched: Vec::new(),
        }
    }

    #[inline]
    /// Capacity in bits (a multiple of 64).
    pub fn capacity(&self) -> usize {
        self.words.len() * 64
    }

    #[inline]
    /// Set bit `i`.
    pub fn insert(&mut self, i: usize) {
        let w = i / 64;
        if self.words[w] == 0 {
            self.touched.push(w as u32);
        }
        self.words[w] |= 1u64 << (i % 64);
    }

    #[inline]
    /// Clear bit `i`.
    pub fn remove(&mut self, i: usize) {
        self.words[i / 64] &= !(1u64 << (i % 64));
    }

    #[inline]
    /// Test bit `i`.
    pub fn contains(&self, i: usize) -> bool {
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Raw backing words (for the word-parallel kernels in
    /// [`crate::graph::setops`]).
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Sparse clear: only zero the words touched since the last clear.
    pub fn clear(&mut self) {
        for &w in &self.touched {
            self.words[w as usize] = 0;
        }
        self.touched.clear();
    }

    /// Full O(capacity) clear (use after bulk ops that bypass `insert`).
    pub fn clear_all(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
        self.touched.clear();
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Iterate set bits in ascending order.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut bits = w;
            std::iter::from_fn(move || {
                if bits == 0 {
                    None
                } else {
                    let b = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_contains_remove() {
        let mut b = BitSet::new(200);
        b.insert(0);
        b.insert(63);
        b.insert(64);
        b.insert(199);
        assert!(b.contains(0) && b.contains(63) && b.contains(64) && b.contains(199));
        assert!(!b.contains(100));
        b.remove(63);
        assert!(!b.contains(63));
        assert_eq!(b.count_ones(), 3);
    }

    #[test]
    fn sparse_clear_resets() {
        let mut b = BitSet::new(1 << 16);
        for i in [5usize, 1000, 60000] {
            b.insert(i);
        }
        b.clear();
        assert_eq!(b.count_ones(), 0);
        for i in [5usize, 1000, 60000] {
            assert!(!b.contains(i));
        }
    }

    #[test]
    fn iter_ones_sorted() {
        let mut b = BitSet::new(300);
        for i in [7usize, 64, 65, 255] {
            b.insert(i);
        }
        assert_eq!(b.iter_ones().collect::<Vec<_>>(), vec![7, 64, 65, 255]);
    }
}
