//! Fixed-capacity bitset over `Vec<u64>` words.
//!
//! Used for visited sets during search, k-core peeling, MNI domains, and
//! dense-tile extraction. Clearing tracks touched words so repeated use
//! inside the DFS hot loop is O(touched), not O(capacity).
//!
//! Touched-word tracking is deduplicated with a per-word epoch stamp:
//! a word enters `touched` at most once per clear cycle, so
//! insert→remove→insert hammering on one word can never grow the list
//! past the word count (the PR-3 bugfix — previously `insert` re-pushed
//! any currently-zero word, so `touched` grew without bound and the
//! "sparse" clear could walk a list longer than the bitset itself).
//! `remove` never untracks: a word stays tracked until the next clear,
//! which is what makes the dedupe invariant (`touched.len() <=
//! words.len()`) hold unconditionally.

#[derive(Clone, Debug)]
/// Fixed-capacity bitset with O(touched) clearing.
pub struct BitSet {
    words: Vec<u64>,
    /// Indices of words that may be non-zero (for sparse clearing).
    /// Deduplicated: a word appears at most once per clear cycle.
    touched: Vec<u32>,
    /// `stamp[w] == epoch` ⇔ word `w` is already in `touched`.
    stamp: Vec<u32>,
    /// Current clear cycle; bumped by `clear`/`clear_all` so stamps
    /// invalidate in O(1) instead of being rewritten.
    epoch: u32,
}

impl Default for BitSet {
    fn default() -> Self {
        Self::new(0)
    }
}

impl BitSet {
    /// All-zero bitset able to hold indices < `capacity` (rounded up).
    pub fn new(capacity: usize) -> Self {
        let nwords = capacity.div_ceil(64);
        Self {
            words: vec![0; nwords],
            touched: Vec::new(),
            stamp: vec![0; nwords],
            // stamps start at 0, so the epoch must start elsewhere
            epoch: 1,
        }
    }

    #[inline]
    /// Capacity in bits (a multiple of 64).
    pub fn capacity(&self) -> usize {
        self.words.len() * 64
    }

    #[inline]
    /// Set bit `i`.
    pub fn insert(&mut self, i: usize) {
        let w = i / 64;
        if self.stamp[w] != self.epoch {
            self.stamp[w] = self.epoch;
            self.touched.push(w as u32);
        }
        self.words[w] |= 1u64 << (i % 64);
    }

    #[inline]
    /// Clear bit `i`. The word stays tracked (see the module docs): it
    /// will be re-zeroed (a no-op) at the next clear rather than risk a
    /// duplicate `touched` entry if re-inserted first.
    pub fn remove(&mut self, i: usize) {
        self.words[i / 64] &= !(1u64 << (i % 64));
    }

    #[inline]
    /// Test bit `i`.
    pub fn contains(&self, i: usize) -> bool {
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Raw backing words (for the word-parallel kernels in
    /// [`crate::graph::setops`]).
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Sparse clear: only zero the words touched since the last clear.
    pub fn clear(&mut self) {
        for &w in &self.touched {
            self.words[w as usize] = 0;
        }
        self.touched.clear();
        self.advance_epoch();
    }

    /// Full O(capacity) clear (use after bulk ops that bypass `insert`).
    pub fn clear_all(&mut self) {
        self.words.iter_mut().for_each(|w| *w = 0);
        self.touched.clear();
        self.advance_epoch();
    }

    /// Start the next clear cycle; on (u32) wraparound the stamps are
    /// rewritten so a stale stamp can never collide with a live epoch.
    fn advance_epoch(&mut self) {
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            self.stamp.iter_mut().for_each(|s| *s = 0);
            self.epoch = 1;
        }
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Iterate set bits in ascending order.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut bits = w;
            std::iter::from_fn(move || {
                if bits == 0 {
                    None
                } else {
                    let b = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    Some(wi * 64 + b)
                }
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_contains_remove() {
        let mut b = BitSet::new(200);
        b.insert(0);
        b.insert(63);
        b.insert(64);
        b.insert(199);
        assert!(b.contains(0) && b.contains(63) && b.contains(64) && b.contains(199));
        assert!(!b.contains(100));
        b.remove(63);
        assert!(!b.contains(63));
        assert_eq!(b.count_ones(), 3);
    }

    #[test]
    fn sparse_clear_resets() {
        let mut b = BitSet::new(1 << 16);
        for i in [5usize, 1000, 60000] {
            b.insert(i);
        }
        b.clear();
        assert_eq!(b.count_ones(), 0);
        for i in [5usize, 1000, 60000] {
            assert!(!b.contains(i));
        }
    }

    #[test]
    fn iter_ones_sorted() {
        let mut b = BitSet::new(300);
        for i in [7usize, 64, 65, 255] {
            b.insert(i);
        }
        assert_eq!(b.iter_ones().collect::<Vec<_>>(), vec![7, 64, 65, 255]);
    }

    #[test]
    fn insert_remove_cycles_keep_touched_bounded() {
        // regression for the PR-3 bugfix: insert → remove → insert on
        // the same word used to append a duplicate touched entry each
        // cycle, growing the list without bound
        let mut b = BitSet::new(512);
        for round in 0..10_000usize {
            let i = (round * 7) % 512;
            b.insert(i);
            b.remove(i);
            b.insert(i);
            assert!(
                b.touched.len() <= b.words.len(),
                "touched overflowed at round {round}: {} > {}",
                b.touched.len(),
                b.words.len()
            );
        }
        // the dedupe must not break sparse clearing
        b.clear();
        assert_eq!(b.count_ones(), 0);
        assert!(b.touched.is_empty());
        // and the next cycle re-tracks from scratch
        b.insert(100);
        assert_eq!(b.touched.len(), 1);
        b.clear();
        assert_eq!(b.count_ones(), 0);
    }

    #[test]
    fn remove_keeps_word_tracked_until_clear() {
        let mut b = BitSet::new(128);
        b.insert(3);
        b.remove(3); // word 0 now zero but still tracked
        b.insert(70);
        assert_eq!(b.touched.len(), 2);
        b.insert(5); // same word as 3: must not re-track
        assert_eq!(b.touched.len(), 2);
        b.clear();
        assert!(!b.contains(5) && !b.contains(70));
        assert!(b.touched.is_empty());
    }

    #[test]
    fn clear_all_resets_tracking_too() {
        let mut b = BitSet::new(256);
        b.insert(1);
        b.insert(200);
        b.clear_all();
        assert_eq!(b.count_ones(), 0);
        b.insert(1);
        assert_eq!(b.touched.len(), 1);
        b.clear();
        assert_eq!(b.count_ones(), 0);
    }

    #[test]
    fn epoch_wraparound_rewrites_stamps() {
        let mut b = BitSet::new(64);
        b.epoch = u32::MAX; // one clear away from wrapping
        b.insert(0);
        b.clear();
        assert_eq!(b.epoch, 1);
        assert!(b.stamp.iter().all(|&s| s == 0));
        // tracking still works after the wrap
        b.insert(7);
        assert_eq!(b.touched.len(), 1);
        b.clear();
        assert_eq!(b.count_ones(), 0);
    }
}
