//! Synchronization facade for the PR-8 verification layer.
//!
//! Protocol code (`exec/sched.rs`, `engine/budget.rs`,
//! `service/cache.rs`, `service/admission.rs`) imports its mutexes,
//! condvars, atomics and thread routines from here instead of `std`.
//! Normally the re-exports *are* the `std` types — zero cost, zero
//! behavior change. Under `--cfg loom` they swap to the in-tree
//! schedule-exploration model in [`crate::util::model`], and the
//! `rust/tests/loom/` suite re-runs each protocol under every explored
//! interleaving (see the model docs for what is and is not covered).
//!
//! Only the types that *are* the protocol are routed: `Arc`,
//! `OnceLock`, `Instant` and the metrics counters stay `std`
//! everywhere (they are infrastructure around the protocols, not the
//! thing under test), and `service/registry.rs` keeps `std` directly —
//! its single-flight is a clone of the cache's, which is modeled.
//!
//! CI note: the loom test target is the *only* thing that may build
//! under `--cfg loom` with threads — running the ordinary suites that
//! way would put real OS threads on the modeled (token-serialized)
//! primitives outside any `model::check`, where they degrade to
//! single-thread storage.

#[cfg(not(loom))]
pub use std::sync::{Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub use crate::util::model::sync::{Condvar, Mutex, MutexGuard};

/// Atomic types routed through the facade; `Ordering` is always the
/// `std` enum (the model accepts and ignores it — it is sequentially
/// consistent by construction).
pub mod atomic {
    pub use std::sync::atomic::Ordering;

    #[cfg(not(loom))]
    pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize};

    #[cfg(loom)]
    pub use crate::util::model::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize};
}

/// Thread routines routed through the facade (spawn/scope/yield/sleep
/// are all schedule points under the model).
pub mod thread {
    #[cfg(not(loom))]
    pub use std::thread::{
        scope, sleep, spawn, yield_now, JoinHandle, Scope, ScopedJoinHandle,
    };

    #[cfg(loom)]
    pub use crate::util::model::thread::{
        scope, sleep, spawn, yield_now, JoinHandle, Scope, ScopedJoinHandle,
    };
}
