//! Key-value configuration files (substitute for serde/TOML).
//!
//! Format: one `key = value` per line; `#` comments; `[section]` headers
//! prefix keys as `section.key`. Used by the campaign driver to describe
//! dataset registries and experiment sweeps.

use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Default, Clone)]
/// Parsed key-value configuration.
pub struct Config {
    /// Flattened `section.key` -> value map.
    pub values: BTreeMap<String, String>,
}

impl Config {
    /// Parse the text format described in the module docs.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{}.{}", section, k.trim())
            };
            values.insert(key, v.trim().to_string());
        }
        Ok(Self { values })
    }

    /// Read and parse a config file.
    pub fn load(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        Self::parse(&text)
    }

    /// Raw value for `key`.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Value parsed as `usize`, or `default`.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Value parsed as `f64`, or `default`.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// All keys within a section (returned without the section prefix).
    pub fn section(&self, name: &str) -> BTreeMap<String, String> {
        let prefix = format!("{name}.");
        self.values
            .iter()
            .filter_map(|(k, v)| {
                k.strip_prefix(&prefix).map(|s| (s.to_string(), v.clone()))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let c = Config::parse(
            "top = 1\n# comment\n[graphs]\nlj = rmat:17  # inline\nor = rmat:16\n",
        )
        .unwrap();
        assert_eq!(c.get("top"), Some("1"));
        assert_eq!(c.get("graphs.lj"), Some("rmat:17"));
        let s = c.section("graphs");
        assert_eq!(s.len(), 2);
        assert_eq!(s["or"], "rmat:16");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("novalue\n").is_err());
        assert!(Config::parse("[unclosed\n").is_err());
    }

    #[test]
    fn typed_getters() {
        let c = Config::parse("n = 42\nf = 2.5\n").unwrap();
        assert_eq!(c.get_usize("n", 0), 42);
        assert_eq!(c.get_f64("f", 0.0), 2.5);
        assert_eq!(c.get_usize("missing", 7), 7);
    }
}
