//! Search-space and runtime metrics.
//!
//! The paper's Fig. 10 compares the *number of enumerated embeddings*
//! between Sandslash-Hi and Sandslash-Lo; these counters regenerate that
//! figure. Counters are plain `u64` aggregated through the per-thread
//! reduce path (no atomics in the hot loop).

#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
/// Search-space counters (kept per thread, merged at the end).
pub struct SearchStats {
    /// Embeddings materialized at any level of the embedding tree.
    pub enumerated: u64,
    /// Embeddings that reached full pattern size (leaves).
    pub matches: u64,
    /// Candidates rejected by pruning (SB, DF, connectivity, FP).
    pub pruned: u64,
    /// Intersection operations performed.
    pub intersections: u64,
    /// Local-graph vertices materialized (LG overhead proxy).
    pub lg_vertices: u64,
}

impl SearchStats {
    /// Accumulate another thread's counters.
    pub fn merge(&mut self, other: &SearchStats) {
        self.enumerated += other.enumerated;
        self.matches += other.matches;
        self.pruned += other.pruned;
        self.intersections += other.intersections;
        self.lg_vertices += other.lg_vertices;
    }
}

/// One row of a result report (used by the campaign driver + benches).
#[derive(Debug, Clone)]
pub struct ResultRow {
    /// Experiment id (e.g. `table5-tc`).
    pub experiment: String,
    /// System / configuration label.
    pub system: String,
    /// Input graph name.
    pub graph: String,
    /// Free-form parameter string (e.g. `k=5`).
    pub params: String,
    /// Wall time in seconds.
    pub seconds: f64,
    /// Primary result (count, size, ...).
    pub value: String,
}

impl ResultRow {
    /// Table header row.
    pub fn markdown_header() -> String {
        "| experiment | system | graph | params | time | result |\n|---|---|---|---|---|---|".to_string()
    }

    /// Render as one markdown table row.
    pub fn to_markdown(&self) -> String {
        format!(
            "| {} | {} | {} | {} | {} | {} |",
            self.experiment,
            self.system,
            self.graph,
            self.params,
            crate::util::timer::fmt_secs(self.seconds),
            self.value
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_accumulates() {
        let mut a = SearchStats { enumerated: 1, matches: 2, pruned: 3, intersections: 4, lg_vertices: 5 };
        let b = SearchStats { enumerated: 10, matches: 20, pruned: 30, intersections: 40, lg_vertices: 50 };
        a.merge(&b);
        assert_eq!(a.enumerated, 11);
        assert_eq!(a.matches, 22);
        assert_eq!(a.pruned, 33);
        assert_eq!(a.intersections, 44);
        assert_eq!(a.lg_vertices, 55);
    }

    #[test]
    fn markdown_row_shape() {
        let r = ResultRow {
            experiment: "table5".into(),
            system: "sandslash-hi".into(),
            graph: "lj-mini".into(),
            params: "".into(),
            seconds: 0.5,
            value: "42".into(),
        };
        assert_eq!(r.to_markdown().matches('|').count(), 7);
    }
}
