//! Search-space and runtime metrics.
//!
//! The paper's Fig. 10 compares the *number of enumerated embeddings*
//! between Sandslash-Hi and Sandslash-Lo; these counters regenerate that
//! figure. Counters are plain `u64` aggregated through the per-thread
//! reduce path (no atomics in the hot loop).

/// Engine attribution for the dispatch and scheduler counters (PR 5).
///
/// The kernel layer ([`crate::graph::setops`]) and the split protocol
/// ([`crate::exec::split`]) are engine-agnostic, so their counters
/// alone cannot prove that, say, the SIMD merge was selected *inside
/// FSM extension* rather than by a concurrently running DFS test. Each
/// engine therefore wraps its per-task body in [`tag::with_engine`],
/// which sets a thread-local lane; every counted event lands in both
/// the process-global counter and its lane's copy. The lane read costs
/// one `Cell` load and only happens on paths that were already counting
/// (dispatch counters are off by default; split publishes are rare), so
/// the default hot loop is untouched.
pub mod tag {
    use std::cell::Cell;

    /// Which mining engine the current worker task belongs to.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub enum Engine {
        /// The pattern-guided DFS engine — and any untagged caller
        /// (tests, apps driving kernels directly).
        Generic = 0,
        /// Pattern-oblivious ESU enumeration ([`crate::engine::esu`]).
        Esu = 1,
        /// Level-synchronous BFS ([`crate::engine::bfs`]).
        Bfs = 2,
        /// Sub-pattern-tree FSM ([`crate::engine::fsm`]).
        Fsm = 3,
    }

    /// Number of attribution lanes (the `Engine` variants).
    pub const LANES: usize = 4;

    thread_local! {
        static CURRENT: Cell<usize> = const { Cell::new(0) };
    }

    /// Run `f` with every counted event on *this thread* attributed to
    /// `e`. Scoped and nesting-safe (the previous lane is restored on
    /// return, panic included); engines call this once per root task.
    pub fn with_engine<T>(e: Engine, f: impl FnOnce() -> T) -> T {
        let prev = CURRENT.with(|c| c.replace(e as usize));
        struct Restore(usize);
        impl Drop for Restore {
            fn drop(&mut self) {
                CURRENT.with(|c| c.set(self.0));
            }
        }
        let _restore = Restore(prev);
        f()
    }

    /// The lane currently active on this thread (0 = [`Engine::Generic`]).
    #[inline]
    pub(crate) fn current_lane() -> usize {
        CURRENT.with(|c| c.get())
    }

    /// Human-readable lane name for diagnostics.
    pub fn lane_name(lane: usize) -> &'static str {
        match lane {
            1 => "esu",
            2 => "bfs",
            3 => "fsm",
            _ => "generic",
        }
    }
}

/// Kernel-dispatch counters for the adaptive set-operation layer
/// ([`crate::graph::setops`]).
///
/// **Off by default**: the crate's counting design keeps atomics out of
/// the mining hot loop (per-thread [`SearchStats`] merged at the end),
/// and a process-global `fetch_add` per intersection would be a
/// contended cross-core RMW under parallel mining. So each `note_*`
/// call first reads one shared `AtomicBool` (read-only cache line, no
/// contention) and returns unless counting was switched on with
/// [`set_enabled`](dispatch::set_enabled). Tests and benches that
/// assert dispatch selection enable counting around their runs; when
/// enabled, it is one relaxed increment per *kernel invocation* (never
/// per element), each counter padded to its own cache line. Counters
/// are process-global and monotone: to attribute selections to a code
/// region, take a [`snapshot`](dispatch::snapshot) before and after
/// and compare (EXPERIMENTS.md §PR-3 uses exactly this to assert the
/// SIMD path is actually chosen on the TC and k-CL benches).
pub mod dispatch {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

    static ENABLED: AtomicBool = AtomicBool::new(false);

    /// Switch dispatch counting on or off (process-global; leave it on
    /// for the rest of the process once a test enables it — deltas via
    /// [`snapshot`] stay correct either way).
    pub fn set_enabled(on: bool) {
        ENABLED.store(on, Ordering::Relaxed);
    }

    /// Whether dispatch counting is currently on.
    pub fn enabled() -> bool {
        env_enable();
        ENABLED.load(Ordering::Relaxed)
    }

    /// Resolve `SANDSLASH_DISPATCH_STATS` once per process (PR 9):
    /// any usable positive value switches counting on at first use, so
    /// campaign runs and the resident service can export dispatch
    /// selections without a programmatic [`set_enabled`] call. Same
    /// loud-reject parse contract as every `SANDSLASH_*` knob;
    /// [`set_enabled`] still overrides either way afterwards.
    #[inline]
    fn env_enable() {
        use std::sync::OnceLock;
        static INIT: OnceLock<()> = OnceLock::new();
        INIT.get_or_init(|| {
            if crate::util::pool::positive_usize_env(
                "SANDSLASH_DISPATCH_STATS",
                "dispatch counters off until enabled programmatically",
            )
            .is_some()
            {
                ENABLED.store(true, Ordering::Relaxed);
            }
        });
    }

    /// A counter alone on its cache line (no false sharing between the
    /// kernel families).
    #[repr(align(64))]
    struct PaddedCounter(AtomicU64);

    static MERGE: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static GALLOP: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static SIMD_MERGE: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static WORD_PARALLEL: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static MASK_FILTER: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static GATHER_FILTER: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static DIFFERENCE: PaddedCounter = PaddedCounter(AtomicU64::new(0));

    // Per-engine attribution lanes (PR 5): the same families, one
    // copy per [`super::tag::Engine`] lane, bumped alongside the
    // globals only while counting is enabled.
    const FAMILIES: usize = 7;
    const FAM_MERGE: usize = 0;
    const FAM_GALLOP: usize = 1;
    const FAM_SIMD_MERGE: usize = 2;
    const FAM_WORD_PARALLEL: usize = 3;
    const FAM_MASK_FILTER: usize = 4;
    const FAM_GATHER_FILTER: usize = 5;
    const FAM_DIFFERENCE: usize = 6;
    #[allow(clippy::declare_interior_mutable_const)] // array-init seed only
    const ZERO_COUNTER: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static TAGGED: [[PaddedCounter; FAMILIES]; super::tag::LANES] =
        [[ZERO_COUNTER; FAMILIES]; super::tag::LANES];

    #[inline]
    fn note_family(global: &PaddedCounter, family: usize) {
        global.0.fetch_add(1, Ordering::Relaxed);
        TAGGED[super::tag::current_lane()][family].0.fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time copy of every dispatch counter.
    #[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
    pub struct DispatchCounts {
        /// Scalar lockstep merge intersections.
        pub merge: u64,
        /// Galloping (binary-search) intersections.
        pub gallop: u64,
        /// Vectorized (SSE/AVX2) block-merge intersections.
        pub simd_merge: u64,
        /// Word-parallel bitset AND / popcount kernels.
        pub word_parallel: u64,
        /// Embedding-adjacency mask range scans (LG dense mode).
        pub mask_filter: u64,
        /// Gathered connectivity-code filters (MNC dense mode).
        pub gather_filter: u64,
        /// Sorted anti-intersections (`difference_into`) — the PR-8
        /// fix for the carried-forward counter gap: the BFS exclusion
        /// chain and the FSM fresh-candidate split were invisible to
        /// the dispatch counters before this family existed.
        pub difference: u64,
    }

    impl DispatchCounts {
        /// Sum of the non-scalar kernel families (everything past the
        /// lockstep merge) — what the PR-5 migration tests assert moved
        /// inside a tagged engine lane. `difference` is excluded: like
        /// `merge` it is a scalar lockstep kernel, so counting it here
        /// would let a run with zero adaptive-kernel selections pass
        /// the "beyond scalar" assertions.
        pub fn beyond_scalar(&self) -> u64 {
            self.gallop
                + self.simd_merge
                + self.word_parallel
                + self.mask_filter
                + self.gather_filter
        }
    }

    /// Read all counters (relaxed loads: exact under quiescence,
    /// monotone lower bounds under concurrency).
    pub fn snapshot() -> DispatchCounts {
        DispatchCounts {
            merge: MERGE.0.load(Ordering::Relaxed),
            gallop: GALLOP.0.load(Ordering::Relaxed),
            simd_merge: SIMD_MERGE.0.load(Ordering::Relaxed),
            word_parallel: WORD_PARALLEL.0.load(Ordering::Relaxed),
            mask_filter: MASK_FILTER.0.load(Ordering::Relaxed),
            gather_filter: GATHER_FILTER.0.load(Ordering::Relaxed),
            difference: DIFFERENCE.0.load(Ordering::Relaxed),
        }
    }

    /// Read the counters attributed to one engine lane (PR 5): events
    /// counted while that engine's [`super::tag::with_engine`] scope
    /// was active on the executing thread. Same relaxed-load semantics
    /// as [`snapshot`].
    pub fn snapshot_for(e: super::tag::Engine) -> DispatchCounts {
        let lane = &TAGGED[e as usize];
        DispatchCounts {
            merge: lane[FAM_MERGE].0.load(Ordering::Relaxed),
            gallop: lane[FAM_GALLOP].0.load(Ordering::Relaxed),
            simd_merge: lane[FAM_SIMD_MERGE].0.load(Ordering::Relaxed),
            word_parallel: lane[FAM_WORD_PARALLEL].0.load(Ordering::Relaxed),
            mask_filter: lane[FAM_MASK_FILTER].0.load(Ordering::Relaxed),
            gather_filter: lane[FAM_GATHER_FILTER].0.load(Ordering::Relaxed),
            difference: lane[FAM_DIFFERENCE].0.load(Ordering::Relaxed),
        }
    }

    /// Zero every counter (global and per-lane). Racy against
    /// concurrent miners — inside a shared test binary prefer
    /// [`snapshot`] deltas instead.
    pub fn reset() {
        for c in [&MERGE, &GALLOP, &SIMD_MERGE, &WORD_PARALLEL, &MASK_FILTER, &GATHER_FILTER, &DIFFERENCE] {
            c.0.store(0, Ordering::Relaxed);
        }
        for lane in &TAGGED {
            for c in lane {
                c.0.store(0, Ordering::Relaxed);
            }
        }
    }

    // Each note_* also feeds the per-query trace histogram (PR 9).
    // The trace hook sits *outside* the `enabled()` gate — a traced
    // tenant gets its per-family histogram without flipping the
    // process-global counters on for everyone — and is itself one
    // thread-local flag check when no trace is installed.
    #[inline]
    pub(crate) fn note_merge() {
        if enabled() {
            note_family(&MERGE, FAM_MERGE);
        }
        crate::obs::trace::on_dispatch(FAM_MERGE);
    }
    #[inline]
    pub(crate) fn note_gallop() {
        if enabled() {
            note_family(&GALLOP, FAM_GALLOP);
        }
        crate::obs::trace::on_dispatch(FAM_GALLOP);
    }
    #[inline]
    pub(crate) fn note_simd_merge() {
        if enabled() {
            note_family(&SIMD_MERGE, FAM_SIMD_MERGE);
        }
        crate::obs::trace::on_dispatch(FAM_SIMD_MERGE);
    }
    #[inline]
    pub(crate) fn note_word_parallel() {
        if enabled() {
            note_family(&WORD_PARALLEL, FAM_WORD_PARALLEL);
        }
        crate::obs::trace::on_dispatch(FAM_WORD_PARALLEL);
    }
    #[inline]
    pub(crate) fn note_mask_filter() {
        if enabled() {
            note_family(&MASK_FILTER, FAM_MASK_FILTER);
        }
        crate::obs::trace::on_dispatch(FAM_MASK_FILTER);
    }
    #[inline]
    pub(crate) fn note_gather_filter() {
        if enabled() {
            note_family(&GATHER_FILTER, FAM_GATHER_FILTER);
        }
        crate::obs::trace::on_dispatch(FAM_GATHER_FILTER);
    }
    #[inline]
    pub(crate) fn note_difference() {
        if enabled() {
            note_family(&DIFFERENCE, FAM_DIFFERENCE);
        }
        crate::obs::trace::on_dispatch(FAM_DIFFERENCE);
    }
}

/// Scheduling-event counters for the work-stealing executor
/// ([`crate::exec::sched`]).
///
/// Unlike [`crate::util::metrics::dispatch`], these are **always on**:
/// scheduling events happen once per *task* (a block of roots, a
/// steal, a published split) — orders of magnitude rarer than kernel
/// dispatches — so one relaxed increment on a padded line is noise
/// next to the task body, and always-on counting lets the invariance
/// suite and the `pr4-*` bench sections assert that stealing actually
/// fired without a global enable handshake. Counters are
/// process-global and monotone: attribute events to a code region via
/// [`snapshot`](crate::util::metrics::sched::snapshot) deltas.
pub mod sched {
    use std::sync::atomic::{AtomicU64, Ordering};

    /// A counter alone on its cache line (no false sharing between
    /// event families).
    #[repr(align(64))]
    struct PaddedCounter(AtomicU64);

    static CLAIMS: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static STEALS: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static SHARD_CLAIMS: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static SPLITS: PaddedCounter = PaddedCounter(AtomicU64::new(0));

    // Split publishes attributed per engine lane (PR 5). Publishing
    // happens inside the engine's task body (unlike claims/steals,
    // which fire in the scheduler's acquisition loop where no engine
    // scope is active), so the publisher's [`super::tag`] lane is
    // meaningful: it is how tests prove a *non-DFS* engine actually
    // published a split.
    #[allow(clippy::declare_interior_mutable_const)] // array-init seed only
    const ZERO_COUNTER: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static SPLITS_BY_LANE: [PaddedCounter; super::tag::LANES] =
        [ZERO_COUNTER; super::tag::LANES];

    /// Point-in-time copy of every scheduler counter.
    #[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
    pub struct SchedCounts {
        /// Root blocks claimed from the worker's own shard cursor.
        pub claims: u64,
        /// Tasks stolen from another worker's deque (any shard).
        pub steals: u64,
        /// Root blocks claimed from a *foreign* shard's cursor (only
        /// after the thief's own shard fully drained).
        pub shard_claims: u64,
        /// Level-1 candidate suffixes published as split tasks.
        pub splits: u64,
    }

    impl SchedCounts {
        /// Total tasks that moved off their home worker or shard — the
        /// "did load balancing actually happen" aggregate the skewed
        /// regression tests assert on.
        pub fn migrations(&self) -> u64 {
            self.steals + self.shard_claims + self.splits
        }
    }

    /// Read all counters (relaxed loads: exact under quiescence,
    /// monotone lower bounds under concurrency).
    pub fn snapshot() -> SchedCounts {
        SchedCounts {
            claims: CLAIMS.0.load(Ordering::Relaxed),
            steals: STEALS.0.load(Ordering::Relaxed),
            shard_claims: SHARD_CLAIMS.0.load(Ordering::Relaxed),
            splits: SPLITS.0.load(Ordering::Relaxed),
        }
    }

    /// Split publishes attributed to one engine lane (PR 5): the value
    /// is monotone; attribute to a code region via before/after deltas
    /// exactly like [`snapshot`].
    pub fn splits_for(e: super::tag::Engine) -> u64 {
        SPLITS_BY_LANE[e as usize].0.load(Ordering::Relaxed)
    }

    /// Zero every counter (global and per-lane). Racy against
    /// concurrent miners — inside a shared test binary prefer
    /// [`snapshot`] deltas instead.
    pub fn reset() {
        for c in [&CLAIMS, &STEALS, &SHARD_CLAIMS, &SPLITS] {
            c.0.store(0, Ordering::Relaxed);
        }
        for c in &SPLITS_BY_LANE {
            c.0.store(0, Ordering::Relaxed);
        }
    }

    #[inline]
    pub(crate) fn note_claim() {
        CLAIMS.0.fetch_add(1, Ordering::Relaxed);
    }
    #[inline]
    pub(crate) fn note_steal() {
        STEALS.0.fetch_add(1, Ordering::Relaxed);
    }
    #[inline]
    pub(crate) fn note_shard_claim() {
        SHARD_CLAIMS.0.fetch_add(1, Ordering::Relaxed);
    }
    #[inline]
    pub(crate) fn note_split() {
        SPLITS.0.fetch_add(1, Ordering::Relaxed);
        SPLITS_BY_LANE[super::tag::current_lane()].0.fetch_add(1, Ordering::Relaxed);
    }
}

/// Governance-event counters (PR 6): budget trips, caught worker
/// panics, injected faults ([`crate::engine::budget`],
/// [`crate::util::fault`]).
///
/// Always on, like [`sched`]: each event fires at most once per *run*
/// (a trip latches the cancel token; a panic drains a worker), so one
/// relaxed increment on a padded line is free, and the governance
/// suite gets to assert trips without an enable handshake.
pub mod gov {
    use std::sync::atomic::{AtomicU64, Ordering};

    use crate::engine::budget::CancelReason;

    /// A counter alone on its cache line (no false sharing between
    /// event families).
    #[repr(align(64))]
    struct PaddedCounter(AtomicU64);

    static DEADLINE_TRIPS: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static TASK_BUDGET_TRIPS: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static CALLER_TRIPS: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static PANIC_TRIPS: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static PANICS_CAUGHT: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static FAULTS_INJECTED: PaddedCounter = PaddedCounter(AtomicU64::new(0));

    /// Point-in-time copy of every governance counter.
    #[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
    pub struct GovCounts {
        /// Runs tripped by an expired deadline.
        pub deadline_trips: u64,
        /// Runs tripped by an exhausted task budget.
        pub task_budget_trips: u64,
        /// Runs cancelled by a caller token.
        pub caller_trips: u64,
        /// Runs tripped by a worker panic.
        pub panic_trips: u64,
        /// Worker panics caught (may exceed `panic_trips`: only the
        /// first panic per run trips the token).
        pub panics_caught: u64,
        /// Faults fired by the injection harness.
        pub faults_injected: u64,
    }

    impl GovCounts {
        /// Total budget trips of any kind.
        pub fn trips(&self) -> u64 {
            self.deadline_trips + self.task_budget_trips + self.caller_trips + self.panic_trips
        }
    }

    /// Read all counters (relaxed loads: exact under quiescence,
    /// monotone lower bounds under concurrency).
    pub fn snapshot() -> GovCounts {
        GovCounts {
            deadline_trips: DEADLINE_TRIPS.0.load(Ordering::Relaxed),
            task_budget_trips: TASK_BUDGET_TRIPS.0.load(Ordering::Relaxed),
            caller_trips: CALLER_TRIPS.0.load(Ordering::Relaxed),
            panic_trips: PANIC_TRIPS.0.load(Ordering::Relaxed),
            panics_caught: PANICS_CAUGHT.0.load(Ordering::Relaxed),
            faults_injected: FAULTS_INJECTED.0.load(Ordering::Relaxed),
        }
    }

    #[inline]
    pub(crate) fn note_trip(reason: CancelReason) {
        let c = match reason {
            CancelReason::Deadline => &DEADLINE_TRIPS,
            CancelReason::TaskBudget => &TASK_BUDGET_TRIPS,
            CancelReason::Caller => &CALLER_TRIPS,
            CancelReason::WorkerPanic => &PANIC_TRIPS,
        };
        c.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub(crate) fn note_panic_caught() {
        PANICS_CAUGHT.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub(crate) fn note_fault_injected() {
        FAULTS_INJECTED.0.fetch_add(1, Ordering::Relaxed);
    }
}

#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
/// Search-space counters (kept per thread, merged at the end).
pub struct SearchStats {
    /// Embeddings materialized at any level of the embedding tree.
    pub enumerated: u64,
    /// Embeddings that reached full pattern size (leaves).
    pub matches: u64,
    /// Candidates rejected by pruning (SB, DF, connectivity, FP).
    pub pruned: u64,
    /// Intersection operations performed.
    pub intersections: u64,
    /// Local-graph vertices materialized (LG overhead proxy).
    pub lg_vertices: u64,
}

impl SearchStats {
    /// Accumulate another thread's counters.
    pub fn merge(&mut self, other: &SearchStats) {
        self.enumerated += other.enumerated;
        self.matches += other.matches;
        self.pruned += other.pruned;
        self.intersections += other.intersections;
        self.lg_vertices += other.lg_vertices;
    }
}

/// One row of a result report (used by the campaign driver + benches).
#[derive(Debug, Clone)]
pub struct ResultRow {
    /// Experiment id (e.g. `table5-tc`).
    pub experiment: String,
    /// System / configuration label.
    pub system: String,
    /// Input graph name.
    pub graph: String,
    /// Free-form parameter string (e.g. `k=5`).
    pub params: String,
    /// Wall time in seconds.
    pub seconds: f64,
    /// Primary result (count, size, ...).
    pub value: String,
}

impl ResultRow {
    /// Table header row.
    pub fn markdown_header() -> String {
        "| experiment | system | graph | params | time | result |\n|---|---|---|---|---|---|".to_string()
    }

    /// Render as one markdown table row.
    pub fn to_markdown(&self) -> String {
        format!(
            "| {} | {} | {} | {} | {} | {} |",
            self.experiment,
            self.system,
            self.graph,
            self.params,
            crate::util::timer::fmt_secs(self.seconds),
            self.value
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_accumulates() {
        let mut a = SearchStats { enumerated: 1, matches: 2, pruned: 3, intersections: 4, lg_vertices: 5 };
        let b = SearchStats { enumerated: 10, matches: 20, pruned: 30, intersections: 40, lg_vertices: 50 };
        a.merge(&b);
        assert_eq!(a.enumerated, 11);
        assert_eq!(a.matches, 22);
        assert_eq!(a.pruned, 33);
        assert_eq!(a.intersections, 44);
        assert_eq!(a.lg_vertices, 55);
    }

    #[test]
    fn dispatch_counters_record_and_snapshot() {
        dispatch::set_enabled(true);
        let before = dispatch::snapshot();
        dispatch::note_merge();
        dispatch::note_gallop();
        dispatch::note_simd_merge();
        dispatch::note_word_parallel();
        dispatch::note_mask_filter();
        dispatch::note_gather_filter();
        dispatch::note_difference();
        let after = dispatch::snapshot();
        assert!(after.merge > before.merge);
        assert!(after.gallop > before.gallop);
        assert!(after.simd_merge > before.simd_merge);
        assert!(after.word_parallel > before.word_parallel);
        assert!(after.mask_filter > before.mask_filter);
        assert!(after.gather_filter > before.gather_filter);
        assert!(after.difference > before.difference);
        // difference is a scalar family: beyond_scalar must exclude it
        // (structural check on a zeroed value — counter deltas are racy
        // against sibling tests in the shared lib-test binary)
        let only_scalar =
            DispatchCounts { merge: 3, difference: 7, ..DispatchCounts::default() };
        assert_eq!(only_scalar.beyond_scalar(), 0);
    }

    #[test]
    fn sched_counters_record_and_aggregate() {
        let before = sched::snapshot();
        sched::note_claim();
        sched::note_steal();
        sched::note_shard_claim();
        sched::note_split();
        let after = sched::snapshot();
        assert!(after.claims > before.claims);
        assert!(after.steals > before.steals);
        assert!(after.shard_claims > before.shard_claims);
        assert!(after.splits > before.splits);
        // migrations counts everything except home-shard claims
        assert!(after.migrations() >= before.migrations() + 3);
    }

    #[test]
    fn engine_tags_attribute_and_restore() {
        dispatch::set_enabled(true);
        let g_before = dispatch::snapshot_for(tag::Engine::Generic);
        let e_before = dispatch::snapshot_for(tag::Engine::Esu);
        let f_before = dispatch::snapshot_for(tag::Engine::Fsm);
        dispatch::note_merge(); // untagged: generic lane
        tag::with_engine(tag::Engine::Esu, || {
            dispatch::note_word_parallel();
            // nesting: inner scope wins, outer restored after
            tag::with_engine(tag::Engine::Fsm, dispatch::note_gallop);
            dispatch::note_gallop();
        });
        dispatch::note_simd_merge(); // back on the generic lane
        let g_after = dispatch::snapshot_for(tag::Engine::Generic);
        let e_after = dispatch::snapshot_for(tag::Engine::Esu);
        let f_after = dispatch::snapshot_for(tag::Engine::Fsm);
        assert!(g_after.merge > g_before.merge);
        assert!(g_after.simd_merge > g_before.simd_merge);
        assert!(e_after.word_parallel > e_before.word_parallel);
        assert!(e_after.gallop > e_before.gallop);
        assert!(f_after.gallop > f_before.gallop);
        // the per-lane beyond-scalar aggregate moves with its parts
        assert!(e_after.beyond_scalar() >= e_before.beyond_scalar() + 2);
        assert_eq!(tag::lane_name(tag::Engine::Esu as usize), "esu");
    }

    #[test]
    fn split_counts_attribute_to_publisher_lane() {
        let before = sched::splits_for(tag::Engine::Fsm);
        let g_before = sched::splits_for(tag::Engine::Generic);
        tag::with_engine(tag::Engine::Fsm, sched::note_split);
        sched::note_split();
        assert!(sched::splits_for(tag::Engine::Fsm) > before);
        assert!(sched::splits_for(tag::Engine::Generic) > g_before);
    }

    #[test]
    fn gov_counters_record_per_reason() {
        use crate::engine::budget::CancelReason;
        let before = gov::snapshot();
        gov::note_trip(CancelReason::Deadline);
        gov::note_trip(CancelReason::TaskBudget);
        gov::note_trip(CancelReason::Caller);
        gov::note_trip(CancelReason::WorkerPanic);
        gov::note_panic_caught();
        gov::note_fault_injected();
        let after = gov::snapshot();
        assert!(after.deadline_trips > before.deadline_trips);
        assert!(after.task_budget_trips > before.task_budget_trips);
        assert!(after.caller_trips > before.caller_trips);
        assert!(after.panic_trips > before.panic_trips);
        assert!(after.panics_caught > before.panics_caught);
        assert!(after.faults_injected > before.faults_injected);
        assert!(after.trips() >= before.trips() + 4);
    }

    #[test]
    fn markdown_row_shape() {
        let r = ResultRow {
            experiment: "table5".into(),
            system: "sandslash-hi".into(),
            graph: "lj-mini".into(),
            params: "".into(),
            seconds: 0.5,
            value: "42".into(),
        };
        assert_eq!(r.to_markdown().matches('|').count(), 7);
    }
}
