//! Search-space and runtime metrics.
//!
//! The paper's Fig. 10 compares the *number of enumerated embeddings*
//! between Sandslash-Hi and Sandslash-Lo; these counters regenerate that
//! figure. Counters are plain `u64` aggregated through the per-thread
//! reduce path (no atomics in the hot loop).

/// Kernel-dispatch counters for the adaptive set-operation layer
/// ([`crate::graph::setops`]).
///
/// **Off by default**: the crate's counting design keeps atomics out of
/// the mining hot loop (per-thread [`SearchStats`] merged at the end),
/// and a process-global `fetch_add` per intersection would be a
/// contended cross-core RMW under parallel mining. So each `note_*`
/// call first reads one shared `AtomicBool` (read-only cache line, no
/// contention) and returns unless counting was switched on with
/// [`set_enabled`](dispatch::set_enabled). Tests and benches that
/// assert dispatch selection enable counting around their runs; when
/// enabled, it is one relaxed increment per *kernel invocation* (never
/// per element), each counter padded to its own cache line. Counters
/// are process-global and monotone: to attribute selections to a code
/// region, take a [`snapshot`](dispatch::snapshot) before and after
/// and compare (EXPERIMENTS.md §PR-3 uses exactly this to assert the
/// SIMD path is actually chosen on the TC and k-CL benches).
pub mod dispatch {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

    static ENABLED: AtomicBool = AtomicBool::new(false);

    /// Switch dispatch counting on or off (process-global; leave it on
    /// for the rest of the process once a test enables it — deltas via
    /// [`snapshot`] stay correct either way).
    pub fn set_enabled(on: bool) {
        ENABLED.store(on, Ordering::Relaxed);
    }

    /// Whether dispatch counting is currently on.
    pub fn enabled() -> bool {
        ENABLED.load(Ordering::Relaxed)
    }

    /// A counter alone on its cache line (no false sharing between the
    /// kernel families).
    #[repr(align(64))]
    struct PaddedCounter(AtomicU64);

    static MERGE: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static GALLOP: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static SIMD_MERGE: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static WORD_PARALLEL: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static MASK_FILTER: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static GATHER_FILTER: PaddedCounter = PaddedCounter(AtomicU64::new(0));

    /// Point-in-time copy of every dispatch counter.
    #[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
    pub struct DispatchCounts {
        /// Scalar lockstep merge intersections.
        pub merge: u64,
        /// Galloping (binary-search) intersections.
        pub gallop: u64,
        /// Vectorized (SSE/AVX2) block-merge intersections.
        pub simd_merge: u64,
        /// Word-parallel bitset AND / popcount kernels.
        pub word_parallel: u64,
        /// Embedding-adjacency mask range scans (LG dense mode).
        pub mask_filter: u64,
        /// Gathered connectivity-code filters (MNC dense mode).
        pub gather_filter: u64,
    }

    /// Read all counters (relaxed loads: exact under quiescence,
    /// monotone lower bounds under concurrency).
    pub fn snapshot() -> DispatchCounts {
        DispatchCounts {
            merge: MERGE.0.load(Ordering::Relaxed),
            gallop: GALLOP.0.load(Ordering::Relaxed),
            simd_merge: SIMD_MERGE.0.load(Ordering::Relaxed),
            word_parallel: WORD_PARALLEL.0.load(Ordering::Relaxed),
            mask_filter: MASK_FILTER.0.load(Ordering::Relaxed),
            gather_filter: GATHER_FILTER.0.load(Ordering::Relaxed),
        }
    }

    /// Zero every counter. Racy against concurrent miners — inside a
    /// shared test binary prefer [`snapshot`] deltas instead.
    pub fn reset() {
        for c in [&MERGE, &GALLOP, &SIMD_MERGE, &WORD_PARALLEL, &MASK_FILTER, &GATHER_FILTER] {
            c.0.store(0, Ordering::Relaxed);
        }
    }

    #[inline]
    pub(crate) fn note_merge() {
        if enabled() {
            MERGE.0.fetch_add(1, Ordering::Relaxed);
        }
    }
    #[inline]
    pub(crate) fn note_gallop() {
        if enabled() {
            GALLOP.0.fetch_add(1, Ordering::Relaxed);
        }
    }
    #[inline]
    pub(crate) fn note_simd_merge() {
        if enabled() {
            SIMD_MERGE.0.fetch_add(1, Ordering::Relaxed);
        }
    }
    #[inline]
    pub(crate) fn note_word_parallel() {
        if enabled() {
            WORD_PARALLEL.0.fetch_add(1, Ordering::Relaxed);
        }
    }
    #[inline]
    pub(crate) fn note_mask_filter() {
        if enabled() {
            MASK_FILTER.0.fetch_add(1, Ordering::Relaxed);
        }
    }
    #[inline]
    pub(crate) fn note_gather_filter() {
        if enabled() {
            GATHER_FILTER.0.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Scheduling-event counters for the work-stealing executor
/// ([`crate::exec::sched`]).
///
/// Unlike [`crate::util::metrics::dispatch`], these are **always on**:
/// scheduling events happen once per *task* (a block of roots, a
/// steal, a published split) — orders of magnitude rarer than kernel
/// dispatches — so one relaxed increment on a padded line is noise
/// next to the task body, and always-on counting lets the invariance
/// suite and the `pr4-*` bench sections assert that stealing actually
/// fired without a global enable handshake. Counters are
/// process-global and monotone: attribute events to a code region via
/// [`snapshot`](crate::util::metrics::sched::snapshot) deltas.
pub mod sched {
    use std::sync::atomic::{AtomicU64, Ordering};

    /// A counter alone on its cache line (no false sharing between
    /// event families).
    #[repr(align(64))]
    struct PaddedCounter(AtomicU64);

    static CLAIMS: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static STEALS: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static SHARD_CLAIMS: PaddedCounter = PaddedCounter(AtomicU64::new(0));
    static SPLITS: PaddedCounter = PaddedCounter(AtomicU64::new(0));

    /// Point-in-time copy of every scheduler counter.
    #[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
    pub struct SchedCounts {
        /// Root blocks claimed from the worker's own shard cursor.
        pub claims: u64,
        /// Tasks stolen from another worker's deque (any shard).
        pub steals: u64,
        /// Root blocks claimed from a *foreign* shard's cursor (only
        /// after the thief's own shard fully drained).
        pub shard_claims: u64,
        /// Level-1 candidate suffixes published as split tasks.
        pub splits: u64,
    }

    impl SchedCounts {
        /// Total tasks that moved off their home worker or shard — the
        /// "did load balancing actually happen" aggregate the skewed
        /// regression tests assert on.
        pub fn migrations(&self) -> u64 {
            self.steals + self.shard_claims + self.splits
        }
    }

    /// Read all counters (relaxed loads: exact under quiescence,
    /// monotone lower bounds under concurrency).
    pub fn snapshot() -> SchedCounts {
        SchedCounts {
            claims: CLAIMS.0.load(Ordering::Relaxed),
            steals: STEALS.0.load(Ordering::Relaxed),
            shard_claims: SHARD_CLAIMS.0.load(Ordering::Relaxed),
            splits: SPLITS.0.load(Ordering::Relaxed),
        }
    }

    /// Zero every counter. Racy against concurrent miners — inside a
    /// shared test binary prefer [`snapshot`] deltas instead.
    pub fn reset() {
        for c in [&CLAIMS, &STEALS, &SHARD_CLAIMS, &SPLITS] {
            c.0.store(0, Ordering::Relaxed);
        }
    }

    #[inline]
    pub(crate) fn note_claim() {
        CLAIMS.0.fetch_add(1, Ordering::Relaxed);
    }
    #[inline]
    pub(crate) fn note_steal() {
        STEALS.0.fetch_add(1, Ordering::Relaxed);
    }
    #[inline]
    pub(crate) fn note_shard_claim() {
        SHARD_CLAIMS.0.fetch_add(1, Ordering::Relaxed);
    }
    #[inline]
    pub(crate) fn note_split() {
        SPLITS.0.fetch_add(1, Ordering::Relaxed);
    }
}

#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
/// Search-space counters (kept per thread, merged at the end).
pub struct SearchStats {
    /// Embeddings materialized at any level of the embedding tree.
    pub enumerated: u64,
    /// Embeddings that reached full pattern size (leaves).
    pub matches: u64,
    /// Candidates rejected by pruning (SB, DF, connectivity, FP).
    pub pruned: u64,
    /// Intersection operations performed.
    pub intersections: u64,
    /// Local-graph vertices materialized (LG overhead proxy).
    pub lg_vertices: u64,
}

impl SearchStats {
    /// Accumulate another thread's counters.
    pub fn merge(&mut self, other: &SearchStats) {
        self.enumerated += other.enumerated;
        self.matches += other.matches;
        self.pruned += other.pruned;
        self.intersections += other.intersections;
        self.lg_vertices += other.lg_vertices;
    }
}

/// One row of a result report (used by the campaign driver + benches).
#[derive(Debug, Clone)]
pub struct ResultRow {
    /// Experiment id (e.g. `table5-tc`).
    pub experiment: String,
    /// System / configuration label.
    pub system: String,
    /// Input graph name.
    pub graph: String,
    /// Free-form parameter string (e.g. `k=5`).
    pub params: String,
    /// Wall time in seconds.
    pub seconds: f64,
    /// Primary result (count, size, ...).
    pub value: String,
}

impl ResultRow {
    /// Table header row.
    pub fn markdown_header() -> String {
        "| experiment | system | graph | params | time | result |\n|---|---|---|---|---|---|".to_string()
    }

    /// Render as one markdown table row.
    pub fn to_markdown(&self) -> String {
        format!(
            "| {} | {} | {} | {} | {} | {} |",
            self.experiment,
            self.system,
            self.graph,
            self.params,
            crate::util::timer::fmt_secs(self.seconds),
            self.value
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_accumulates() {
        let mut a = SearchStats { enumerated: 1, matches: 2, pruned: 3, intersections: 4, lg_vertices: 5 };
        let b = SearchStats { enumerated: 10, matches: 20, pruned: 30, intersections: 40, lg_vertices: 50 };
        a.merge(&b);
        assert_eq!(a.enumerated, 11);
        assert_eq!(a.matches, 22);
        assert_eq!(a.pruned, 33);
        assert_eq!(a.intersections, 44);
        assert_eq!(a.lg_vertices, 55);
    }

    #[test]
    fn dispatch_counters_record_and_snapshot() {
        dispatch::set_enabled(true);
        let before = dispatch::snapshot();
        dispatch::note_merge();
        dispatch::note_gallop();
        dispatch::note_simd_merge();
        dispatch::note_word_parallel();
        dispatch::note_mask_filter();
        dispatch::note_gather_filter();
        let after = dispatch::snapshot();
        assert!(after.merge > before.merge);
        assert!(after.gallop > before.gallop);
        assert!(after.simd_merge > before.simd_merge);
        assert!(after.word_parallel > before.word_parallel);
        assert!(after.mask_filter > before.mask_filter);
        assert!(after.gather_filter > before.gather_filter);
    }

    #[test]
    fn sched_counters_record_and_aggregate() {
        let before = sched::snapshot();
        sched::note_claim();
        sched::note_steal();
        sched::note_shard_claim();
        sched::note_split();
        let after = sched::snapshot();
        assert!(after.claims > before.claims);
        assert!(after.steals > before.steals);
        assert!(after.shard_claims > before.shard_claims);
        assert!(after.splits > before.splits);
        // migrations counts everything except home-shard claims
        assert!(after.migrations() >= before.migrations() + 3);
    }

    #[test]
    fn markdown_row_shape() {
        let r = ResultRow {
            experiment: "table5".into(),
            system: "sandslash-hi".into(),
            graph: "lj-mini".into(),
            params: "".into(),
            seconds: 0.5,
            value: "42".into(),
        };
        assert_eq!(r.to_markdown().matches('|').count(), 7);
    }
}
