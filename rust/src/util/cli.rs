//! Minimal command-line argument parser (no external crates available in
//! the offline registry, so this substitutes for `clap`).
//!
//! Grammar: `sandslash <subcommand> [positional...] [--key value|--flag]`.

use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
/// Parsed command line.
pub struct Args {
    /// First bare argument, if any.
    pub subcommand: Option<String>,
    /// Remaining bare arguments.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` pairs.
    pub options: HashMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Whether `--name` was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Value of option `--name`.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    /// Option value or `default`.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Option parsed as `usize`, or `default`. An unparsable value is
    /// rejected *loudly* (once per option name) instead of silently
    /// becoming the default — the `SANDSLASH_*` env contract
    /// (see `util::pool::positive_usize_env`), applied to flags.
    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.parsed_or_warn(name, default, "an unsigned integer")
    }

    /// Option parsed as `u64`, or `default`; loud-reject like
    /// [`Args::get_usize`].
    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.parsed_or_warn(name, default, "an unsigned integer")
    }

    /// Option parsed as `f64`, or `default`; loud-reject like
    /// [`Args::get_usize`].
    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.parsed_or_warn(name, default, "a number")
    }

    fn parsed_or_warn<T: std::str::FromStr + std::fmt::Display>(
        &self,
        name: &str,
        default: T,
        what: &str,
    ) -> T {
        let Some(raw) = self.get(name) else { return default };
        match raw.trim().parse::<T>() {
            Ok(v) => v,
            Err(_) => {
                warn_once(name, raw, what, &default);
                default
            }
        }
    }
}

/// One stderr warning per option name per process: repeated getters on
/// the same flag (campaign loops re-read `--k` per table) must not spam.
fn warn_once(name: &str, raw: &str, what: &str, default: &dyn std::fmt::Display) {
    use std::sync::{Mutex, OnceLock};
    static WARNED: OnceLock<Mutex<std::collections::HashSet<String>>> = OnceLock::new();
    let mut warned = WARNED
        .get_or_init(|| Mutex::new(std::collections::HashSet::new()))
        .lock()
        .unwrap_or_else(|e| e.into_inner());
    if warned.insert(name.to_string()) {
        eprintln!("sandslash: ignoring --{name} {raw:?} (not {what}); using {default}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = parse("tc --graph lj-mini --threads 8 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("tc"));
        assert_eq!(a.get("graph"), Some("lj-mini"));
        assert_eq!(a.get_usize("threads", 1), 8);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn parses_eq_form_and_positionals() {
        let a = parse("gen rmat --n=1000 out.el");
        assert_eq!(a.subcommand.as_deref(), Some("gen"));
        assert_eq!(a.positional, vec!["rmat", "out.el"]);
        assert_eq!(a.get_u64("n", 0), 1000);
    }

    #[test]
    fn trailing_flag_not_eaten_as_value() {
        let a = parse("motif --k 4 --lo");
        assert_eq!(a.get_usize("k", 0), 4);
        assert!(a.flag("lo"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("tc");
        assert_eq!(a.get_or("graph", "er-small"), "er-small");
        assert_eq!(a.get_f64("density", 0.5), 0.5);
    }

    #[test]
    fn unparsable_values_fall_back_loudly() {
        // garbage falls back to the default (and warns on stderr once
        // per option name — not assertable here, but the fallback is)
        let a = parse("clique --k banana --sigma 1e3x --p nan-ish");
        assert_eq!(a.get_usize("k", 4), 4);
        assert_eq!(a.get_u64("sigma", 100), 100);
        assert_eq!(a.get_f64("p", 0.25), 0.25);
        // repeated reads stay on the fallback and do not panic
        assert_eq!(a.get_usize("k", 4), 4);
    }

    #[test]
    fn surrounding_whitespace_tolerated() {
        let a = parse("clique --k=4");
        assert_eq!(a.get_usize("k", 0), 4);
        let mut b = parse("clique");
        b.options.insert("k".into(), " 7 ".into());
        assert_eq!(b.get_usize("k", 0), 7);
    }
}
