//! Micro/macro benchmark harness (substitute for `criterion`, which is
//! not in the offline registry). `cargo bench` targets use
//! `harness = false` and drive this directly.
//!
//! Protocol: warm up once, then run until `min_runs` samples or
//! `max_seconds` elapsed, reporting min/median/mean. Benches print the
//! paper-table rows they regenerate.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
}

impl BenchResult {
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s[s.len() / 2]
    }
}

pub struct Bench {
    pub min_runs: usize,
    pub max_seconds: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Self { min_runs: 3, max_seconds: 10.0 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self { min_runs: 2, max_seconds: 5.0 }
    }

    /// Run `f` repeatedly; returns timing samples. The closure's return
    /// value is black-boxed to keep the optimizer honest.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // warmup
        std::hint::black_box(f());
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_runs
            || (start.elapsed().as_secs_f64() < self.max_seconds && samples.len() < 25)
        {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_secs_f64());
            if start.elapsed().as_secs_f64() >= self.max_seconds && samples.len() >= self.min_runs
            {
                break;
            }
        }
        BenchResult { name: name.to_string(), samples }
    }
}

/// Print a markdown table of results: one row per (row_label, cells).
pub fn print_table(title: &str, columns: &[&str], rows: &[(String, Vec<String>)]) {
    println!("\n### {title}\n");
    println!("| | {} |", columns.join(" | "));
    println!("|---|{}|", columns.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for (label, cells) in rows {
        println!("| {} | {} |", label, cells.join(" | "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_at_least_min_runs() {
        let b = Bench { min_runs: 4, max_seconds: 0.05 };
        let r = b.run("noop", || 1 + 1);
        assert!(r.samples.len() >= 4);
        assert!(r.min() >= 0.0);
        assert!(r.median() >= r.min());
    }
}
