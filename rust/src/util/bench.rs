//! Micro/macro benchmark harness (substitute for `criterion`, which is
//! not in the offline registry). `cargo bench` targets use
//! `harness = false` and drive this directly.
//!
//! Protocol: warm up once, then run until `min_runs` samples or
//! `max_seconds` elapsed, reporting min/median/mean. Benches print the
//! paper-table rows they regenerate.
//!
//! # The `BENCH_pr1.json` regeneration contract
//!
//! The artifact at the repo root is written **only** through
//! [`upsert_bench_section`], and its per-section schema only through
//! [`Pr1Section::write`] — two writers share it without drifting:
//!
//! * every tier-1 `cargo test -q` run, via `rust/tests/bench_pr1.rs`
//!   (single-shot smoke numbers, dev profile); this is what replaces
//!   the committed `"build": "pending"` placeholder with real numbers
//!   on any machine that has a Rust toolchain;
//! * `cargo bench --bench table5_tc` / `--bench table6_kcl` (sampled,
//!   release), which overwrite the same sections with better numbers.
//!
//! The same two writers also maintain the PR-3 sections (`pr3-tc`,
//! `pr3-kcl4`, via [`Pr3Section::write`]): the set-centric
//! configuration run twice *in the same process* — once with
//! `setops::set_simd_enabled(false)` (portable scalar kernels) and
//! once with runtime feature detection — so the rows differ only in
//! kernel dispatch, which the writers verify through the
//! [`crate::util::metrics::dispatch`] counters. The PR-4 sections
//! (`pr4-sched-tc`, `pr4-sched-kcl4`, via [`Pr4Section::write`] and
//! the shared [`pr4_compare`] protocol) apply the identical recipe to
//! the *scheduler*: the same workload on the global-cursor oracle and
//! on the work-stealing pool, counts asserted equal, and — on an
//! adversarially skewed two-hub input — the
//! [`crate::util::metrics::sched`] counters asserted to show that
//! steals/splits actually fired. The PR-5 sections (`pr5-kmc`,
//! `pr5-fsm`, via [`Pr5Section::write`] and the shared
//! [`pr5_compare`] protocol) do it once more for the *extension
//! core*: the same ESU / FSM workload on the seed scalar oracle
//! (`OptFlags::extcore = false`) and on the shared extension core,
//! counts asserted equal. The PR-6 section (`pr6-governance`, via
//! [`Pr6Section::write`] and the shared [`pr6_compare`] protocol)
//! closes the sequence for the *governance layer*: the same workload
//! with governance scoped off
//! ([`crate::engine::budget::with_governance_disabled`]) and back on
//! with every budget unset, counts asserted bit-identical and the
//! [`crate::util::metrics::gov`] trip counters asserted silent — the
//! recorded ratio is the whole cost of the admission poll sites. The
//! PR-7 section (`pr7-service`, via [`Pr7Section::write`] and the
//! shared [`pr7_compare`] protocol) measures the resident service
//! ([`crate::service`]): one query submitted cold (admission +
//! governed run + cache fill) and again cached (byte replay), counts
//! asserted equal across the cache boundary. The PR-9 section
//! (`pr9-obs`, via [`Pr9Section::write`] and the shared
//! [`pr9_compare`] protocol) prices the *observability layer*: the
//! same workload run untraced (the default, pay-nothing path) and
//! again under an installed [`crate::obs::trace::QueryTrace`], counts
//! asserted bit-identical — the recorded ratio is the whole cost of
//! the tracing hooks when a trace is live. The PR-10 section
//! (`pr10-plan`, via [`Pr10Section::write`] and the shared
//! [`pr10_compare`] protocol) measures the *decomposition counting
//! planner* ([`crate::pattern::decompose`]): the same count-only
//! workload on the enumerated oracle (`OptFlags::plan = false`) and
//! through the planner, counts asserted bit-identical and — when the
//! planner is live — the planner's engine-stats `enumerated` counter
//! asserted strictly smaller than the oracle's (the asymptotic claim,
//! not just a stopwatch).
//!
//! Writers must assert their differential check (scalar count ==
//! set-centric count, scalar-kernel count == SIMD-kernel count)
//! *before* recording times, so a committed artifact always describes
//! an agreeing build. Sections are upserted individually —
//! regenerating one bench never clobbers another's section. The meta
//! block ([`pr1_meta`]) records threads, dev vs release, and the exact
//! regeneration commands.

use std::time::Instant;

/// Samples collected for one benchmark.
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Wall-time samples in seconds.
    pub samples: Vec<f64>,
}

impl BenchResult {
    /// Fastest sample (least scheduler noise; used for speedups).
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }
    /// Arithmetic mean of the samples.
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
    /// Median sample.
    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s[s.len() / 2]
    }
}

/// Benchmark protocol parameters (see the module docs).
pub struct Bench {
    /// Minimum number of samples.
    pub min_runs: usize,
    /// Soft wall-clock budget.
    pub max_seconds: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Self { min_runs: 3, max_seconds: 10.0 }
    }
}

impl Bench {
    /// Reduced protocol for smoke runs.
    pub fn quick() -> Self {
        Self { min_runs: 2, max_seconds: 5.0 }
    }

    /// Run `f` repeatedly; returns timing samples. The closure's return
    /// value is black-boxed to keep the optimizer honest.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // warmup
        std::hint::black_box(f());
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_runs
            || (start.elapsed().as_secs_f64() < self.max_seconds && samples.len() < 25)
        {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_secs_f64());
            if start.elapsed().as_secs_f64() >= self.max_seconds && samples.len() >= self.min_runs
            {
                break;
            }
        }
        BenchResult { name: name.to_string(), samples }
    }
}

/// Minimal ordered JSON object builder for the `BENCH_*.json` artifacts
/// (the offline registry has no serde). Values are stored pre-rendered;
/// keys keep insertion order.
#[derive(Clone, Debug, Default)]
pub struct Json {
    pairs: Vec<(String, String)>,
}

impl Json {
    /// Empty object.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a string field (escaped).
    pub fn str(mut self, k: &str, v: &str) -> Self {
        let escaped = v.replace('\\', "\\\\").replace('"', "\\\"");
        self.pairs.push((k.to_string(), format!("\"{escaped}\"")));
        self
    }

    /// Append an integer field.
    pub fn int(mut self, k: &str, v: u64) -> Self {
        self.pairs.push((k.to_string(), v.to_string()));
        self
    }

    /// Append a float field (non-finite renders as `null`).
    pub fn num(mut self, k: &str, v: f64) -> Self {
        let rendered = if v.is_finite() { format!("{v:.6}") } else { "null".to_string() };
        self.pairs.push((k.to_string(), rendered));
        self
    }

    /// Render as a single-line JSON object.
    pub fn render_inline(&self) -> String {
        let body: Vec<String> =
            self.pairs.iter().map(|(k, v)| format!("\"{k}\": {v}")).collect();
        format!("{{ {} }}", body.join(", "))
    }
}

/// Insert or replace one named section of a bench-report file, keeping
/// every section written by other benches. File layout (fixed, written
/// only by this function):
///
/// ```json
/// { <meta pairs...>, "sections": { "<name>": { ... }, ... } }
/// ```
pub fn upsert_bench_section(
    path: &std::path::Path,
    meta: &Json,
    section: &str,
    body: &Json,
) -> std::io::Result<()> {
    let mut sections: Vec<(String, String)> = std::fs::read_to_string(path)
        .ok()
        .and_then(|t| extract_sections(&t))
        .unwrap_or_default();
    let rendered = body.render_inline();
    match sections.iter_mut().find(|(n, _)| n == section) {
        Some(entry) => entry.1 = rendered,
        None => sections.push((section.to_string(), rendered)),
    }
    let mut out = String::from("{\n");
    for (k, v) in &meta.pairs {
        out.push_str(&format!("  \"{k}\": {v},\n"));
    }
    out.push_str("  \"sections\": {\n");
    let rows: Vec<String> =
        sections.iter().map(|(n, b)| format!("    \"{n}\": {b}")).collect();
    out.push_str(&rows.join(",\n"));
    out.push_str("\n  }\n}\n");
    std::fs::write(path, out)
}

/// Pull the `"sections"` object out of a previously written report:
/// returns (name, raw-object-text) in file order, or `None` if the text
/// does not match the layout `upsert_bench_section` writes.
fn extract_sections(text: &str) -> Option<Vec<(String, String)>> {
    let start = text.find("\"sections\"")?;
    let rest = &text[start + "\"sections\"".len()..];
    let s = &rest[rest.find('{')?..];
    let b = s.as_bytes();
    let mut i = 1usize; // past the opening '{'
    let mut out = Vec::new();
    loop {
        while i < b.len() && (b[i].is_ascii_whitespace() || b[i] == b',') {
            i += 1;
        }
        if i >= b.len() {
            return None;
        }
        if b[i] == b'}' {
            return Some(out);
        }
        if b[i] != b'"' {
            return None;
        }
        let key_start = i + 1;
        let mut j = key_start;
        while j < b.len() && b[j] != b'"' {
            j += 1; // section names are written without escapes
        }
        if j >= b.len() {
            return None;
        }
        let key = s[key_start..j].to_string();
        i = j + 1;
        while i < b.len() && b[i] != b'{' {
            if b[i] == b':' || b[i].is_ascii_whitespace() {
                i += 1;
            } else {
                return None;
            }
        }
        if i >= b.len() {
            return None;
        }
        // balanced-brace scan, string-aware
        let obj_start = i;
        let (mut depth, mut in_str, mut esc) = (0usize, false, false);
        while i < b.len() {
            let c = b[i];
            if in_str {
                if esc {
                    esc = false;
                } else if c == b'\\' {
                    esc = true;
                } else if c == b'"' {
                    in_str = false;
                }
            } else if c == b'"' {
                in_str = true;
            } else if c == b'{' {
                depth += 1;
            } else if c == b'}' {
                depth -= 1;
                if depth == 0 {
                    i += 1;
                    break;
                }
            }
            i += 1;
        }
        if depth != 0 {
            return None;
        }
        out.push((key, s[obj_start..i].to_string()));
    }
}

/// Repo-root path of the PR-1 set-centric-extension report
/// (`BENCH_pr1.json`, one directory above the crate manifest).
pub fn pr1_report_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_pr1.json")
}

/// Standard meta block for the PR-1 report; section bodies carry their
/// own graph description.
pub fn pr1_meta(threads: usize) -> Json {
    Json::new()
        .str("bench", "pr1-set-centric-extension")
        .int("threads", threads as u64)
        .str("build", if cfg!(debug_assertions) { "dev" } else { "release" })
        .str(
            "regenerate",
            "cargo test -q (smoke) or cargo bench --bench table5_tc / table6_kcl (sampled); \
             pr3-* sections compare the scalar vs SIMD kernel dispatch, pr4-sched-* the \
             cursor vs work-stealing scheduler, pr5-* the scalar extension oracles vs \
             the shared extension core, pr6-governance the governed vs \
             governance-disabled run with budgets unset, pr7-service the resident \
             service's cold vs cached query latency, pr9-obs the untraced vs \
             traced run of the same workload, and pr10-plan the enumerated \
             counting oracle vs the decomposition planner, each from the same run",
        )
}

/// One measured scalar-vs-set-centric comparison, as recorded in a
/// PR-1 report section (shared by the benches and the tier-1 smoke
/// test so the JSON schema cannot drift between writers).
pub struct Pr1Section<'a> {
    /// Input description (generator + parameters).
    pub graph: &'a str,
    /// Pattern name.
    pub pattern: &'a str,
    /// Agreed embedding count (differential check).
    pub count: u64,
    /// Scalar-path wall time (seconds).
    pub scalar_secs: f64,
    /// Set-centric wall time (seconds).
    pub set_secs: f64,
    /// Hand-tuned DAG fast path, when measured alongside.
    pub dag_secs: Option<f64>,
    /// Number of timing samples behind the figures.
    pub samples: usize,
}

impl Pr1Section<'_> {
    /// Scalar-over-set speedup.
    pub fn speedup(&self) -> f64 {
        self.scalar_secs / self.set_secs
    }

    /// Upsert this section into the PR-1 report at the repo root.
    pub fn write(&self, section: &str, threads: usize) -> std::io::Result<()> {
        let mut body = Json::new()
            .str("graph", self.graph)
            .str("pattern", self.pattern)
            .int("count", self.count)
            .num("scalar_secs", self.scalar_secs)
            .num("set_secs", self.set_secs);
        if let Some(d) = self.dag_secs {
            body = body.num("dag_intersect_secs", d);
        }
        let body = body
            .num("speedup_set_over_scalar", self.speedup())
            .int("samples", self.samples as u64);
        upsert_bench_section(&pr1_report_path(), &pr1_meta(threads), section, &body)
    }
}

/// One measured scalar-kernels vs SIMD-kernels comparison
/// (EXPERIMENTS.md §PR-3), as recorded in a `pr3-*` report section:
/// the same set-centric configuration with vectorization force-disabled
/// and re-enabled from the same process, so the rows differ only in
/// kernel dispatch. Shared by the benches and the tier-1 smoke test so
/// the JSON schema cannot drift between writers.
pub struct Pr3Section<'a> {
    /// Input description (generator + parameters).
    pub graph: &'a str,
    /// Pattern name.
    pub pattern: &'a str,
    /// Agreed embedding count (differential check across kernel levels).
    pub count: u64,
    /// Detected dispatch level of the vectorized rows
    /// (`"avx2"` / `"ssse3"` / `"scalar"`).
    pub simd: &'a str,
    /// Wall time with the portable scalar kernels (seconds).
    pub scalar_secs: f64,
    /// Wall time with the vectorized kernels (seconds).
    pub simd_secs: f64,
    /// Number of timing samples behind the figures.
    pub samples: usize,
}

/// Run the §PR-3 scalar-vs-SIMD measurement protocol once and return
/// the section row — the *single* implementation shared by the tier-1
/// smoke test and the `table5_tc`/`table6_kcl`/`fig9_local_graph`
/// benches so the run-toggle-assert sequence cannot drift between
/// writers:
///
/// 1. with dispatch counting **off** (so neither phase pays counter
///    overhead and the two timings are comparable), force the portable
///    scalar kernels and call `timed_run` (which must return the
///    embedding count and the wall seconds to record), then re-enable
///    runtime dispatch and call it again;
/// 2. assert both runs agree on the count;
/// 3. re-check selection on a separate, *untimed* `check_run` with
///    counting on: when the host actually has a vector unit, the SIMD
///    merge must have been *selected* (dispatch-counter delta), not
///    merely available. `check_run` should be one cheap pass of the
///    same workload — its wall time is never recorded.
///
/// The previous counting state is restored before returning.
pub fn pr3_compare<'a>(
    graph: &'a str,
    pattern: &'a str,
    samples: usize,
    mut timed_run: impl FnMut() -> (u64, f64),
    mut check_run: impl FnMut() -> u64,
) -> Pr3Section<'a> {
    use crate::graph::setops;
    use crate::util::metrics::dispatch;
    let counting_was = dispatch::enabled();
    dispatch::set_enabled(false);
    setops::set_simd_enabled(false);
    let (scalar_count, scalar_secs) = timed_run();
    setops::set_simd_enabled(true);
    let (simd_count, simd_secs) = timed_run();
    assert_eq!(
        scalar_count, simd_count,
        "scalar vs SIMD kernels disagree on {graph} / {pattern}"
    );
    dispatch::set_enabled(true);
    let before = dispatch::snapshot();
    let check_count = check_run();
    let after = dispatch::snapshot();
    dispatch::set_enabled(counting_was);
    assert_eq!(
        check_count, simd_count,
        "selection-check run disagrees on {graph} / {pattern}"
    );
    if setops::simd_active() {
        assert!(
            after.simd_merge > before.simd_merge,
            "SIMD merge available ({}) but never selected on {pattern}",
            setops::simd_level_name()
        );
    }
    Pr3Section {
        graph,
        pattern,
        count: simd_count,
        simd: setops::simd_level_name(),
        scalar_secs,
        simd_secs,
        samples,
    }
}

impl Pr3Section<'_> {
    /// Scalar-kernels-over-SIMD-kernels speedup.
    pub fn speedup(&self) -> f64 {
        self.scalar_secs / self.simd_secs
    }

    /// Upsert this section into the shared report at the repo root.
    pub fn write(&self, section: &str, threads: usize) -> std::io::Result<()> {
        let body = Json::new()
            .str("graph", self.graph)
            .str("pattern", self.pattern)
            .int("count", self.count)
            .str("simd_level", self.simd)
            .num("scalar_kernel_secs", self.scalar_secs)
            .num("simd_kernel_secs", self.simd_secs)
            .num("speedup_simd_over_scalar", self.speedup())
            .int("samples", self.samples as u64);
        upsert_bench_section(&pr1_report_path(), &pr1_meta(threads), section, &body)
    }
}

/// One measured cursor-vs-stealing scheduler comparison (EXPERIMENTS.md
/// §PR-4), as recorded in a `pr4-sched-*` report section: the same
/// mining workload scheduled by the seed global-cursor oracle and by
/// the work-stealing pool ([`crate::exec::sched`]), from the same
/// process, so the rows differ only in scheduling. Shared by the
/// benches and the tier-1 smoke test so the JSON schema cannot drift
/// between writers.
pub struct Pr4Section<'a> {
    /// Input description (generator + parameters) of the timed rows.
    pub graph: &'a str,
    /// Pattern name.
    pub pattern: &'a str,
    /// Agreed embedding count (differential check across schedulers).
    pub count: u64,
    /// *Effective* locality shard count of the timed stealing row —
    /// the detected topology clamped to the row's worker count,
    /// exactly as the pool builds it (never more shards than workers).
    pub shards: usize,
    /// Wall time on the global-cursor oracle (seconds).
    pub cursor_secs: f64,
    /// Wall time on the work-stealing scheduler (seconds).
    pub steal_secs: f64,
    /// Deque steals observed on the skewed check input.
    pub skew_steals: u64,
    /// Split tasks published on the skewed check input.
    pub skew_splits: u64,
    /// Number of timing samples behind the figures.
    pub samples: usize,
}

/// Run the §PR-4 cursor-vs-stealing measurement protocol once and
/// return the section row — the single implementation shared by the
/// tier-1 smoke test and the `table5_tc`/`table6_kcl` benches, exactly
/// as [`pr3_compare`] is for the kernel dispatch:
///
/// 1. call `timed_run` (which must return the embedding count and the
///    wall seconds to record) twice under scoped scheduler overrides —
///    first pinned to the cursor oracle, then with stealing on — and
///    assert both runs agree on the count;
/// 2. call `skew_check` (one cheap pass over an adversarially skewed
///    input, e.g. [`crate::graph::gen::two_hub`]; its wall time is
///    never recorded) under the same two overrides, asserting the
///    counts agree, that the oracle pass moved **no**
///    [`crate::util::metrics::sched`] migration counter, and — when
///    this process can actually run parallel (`skew_threads > 1`,
///    more than one core, no `SANDSLASH_NO_STEAL`) — that the
///    stealing pass fired at least one steal, split, or cross-shard
///    claim.
///
/// `timed_threads` is the worker count of the configuration inside
/// `timed_run` and `skew_threads` the one inside `skew_check` — the
/// first determines the *effective* shard count recorded in the
/// section, the second the migration-assertion guard. The closures
/// should build their configs with default scheduler knobs (the
/// scoped overrides outrank `MinerConfig::steal`); the previous
/// override state is restored before returning.
pub fn pr4_compare<'a>(
    graph: &'a str,
    pattern: &'a str,
    samples: usize,
    timed_threads: usize,
    skew_threads: usize,
    mut timed_run: impl FnMut() -> (u64, f64),
    mut skew_check: impl FnMut() -> u64,
) -> Pr4Section<'a> {
    use crate::exec::sched::{self, Overrides};
    use crate::util::metrics::sched as counters;
    let oracle = Overrides { steal: Some(false), shards: None };
    let stealing = Overrides { steal: Some(true), shards: None };
    let (cursor_count, cursor_secs) = sched::with_overrides(oracle, &mut timed_run);
    let (steal_count, steal_secs) = sched::with_overrides(stealing, &mut timed_run);
    assert_eq!(
        cursor_count, steal_count,
        "cursor vs stealing scheduler disagree on {graph} / {pattern}"
    );
    let before = counters::snapshot();
    let skew_cursor = sched::with_overrides(oracle, &mut skew_check);
    let mid = counters::snapshot();
    let skew_steal = sched::with_overrides(stealing, &mut skew_check);
    let after = counters::snapshot();
    assert_eq!(
        skew_cursor, skew_steal,
        "cursor vs stealing scheduler disagree on the skewed input for {pattern}"
    );
    assert_eq!(
        mid.migrations(),
        before.migrations(),
        "the cursor oracle must never steal, split, or cross shards"
    );
    let skew_steals = after.steals - mid.steals;
    let skew_splits = after.splits - mid.splits;
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if sched::steal_enabled_default() && skew_threads > 1 && cores > 1 {
        assert!(
            after.migrations() > mid.migrations(),
            "stealing enabled but no steal/split/shard migration fired on the skewed input \
             for {pattern}"
        );
    }
    Pr4Section {
        graph,
        pattern,
        count: steal_count,
        // the shard count the timed stealing row actually ran with
        // (the pool clamps detection to the worker count)
        shards: crate::exec::topology::shards().clamp(1, timed_threads.max(1)),
        cursor_secs,
        steal_secs,
        skew_steals,
        skew_splits,
        samples,
    }
}

impl Pr4Section<'_> {
    /// Cursor-over-stealing speedup (> 1 means stealing won).
    pub fn speedup(&self) -> f64 {
        self.cursor_secs / self.steal_secs
    }

    /// Upsert this section into the shared report at the repo root.
    pub fn write(&self, section: &str, threads: usize) -> std::io::Result<()> {
        let body = Json::new()
            .str("graph", self.graph)
            .str("pattern", self.pattern)
            .int("count", self.count)
            .int("shards", self.shards as u64)
            .num("cursor_secs", self.cursor_secs)
            .num("steal_secs", self.steal_secs)
            .num("speedup_steal_over_cursor", self.speedup())
            .int("skew_steals", self.skew_steals)
            .int("skew_splits", self.skew_splits)
            .int("samples", self.samples as u64);
        upsert_bench_section(&pr1_report_path(), &pr1_meta(threads), section, &body)
    }
}

/// One measured scalar-oracle vs extension-core comparison
/// (EXPERIMENTS.md §PR-5), as recorded in a `pr5-*` report section:
/// the same ESU or FSM workload run with `OptFlags::extcore` off (the
/// seed scalar loops) and on (the shared extension core of
/// [`crate::engine::extend`]), from the same process, so the rows
/// differ only in extension machinery. Shared by the benches and the
/// tier-1 smoke test so the JSON schema cannot drift between writers.
pub struct Pr5Section<'a> {
    /// Input description (generator + parameters).
    pub graph: &'a str,
    /// Workload name (e.g. `4-motif-esu`, `fsm k<=3 sigma=2`).
    pub workload: &'a str,
    /// Agreed result fingerprint (differential check across paths).
    pub count: u64,
    /// Wall time on the seed scalar oracle (seconds).
    pub oracle_secs: f64,
    /// Wall time on the shared extension core (seconds).
    pub core_secs: f64,
    /// Number of timing samples behind the figures.
    pub samples: usize,
}

/// Run the §PR-5 oracle-vs-core measurement protocol once and return
/// the section row — the single implementation shared by the tier-1
/// smoke test and the engine benches, exactly as [`pr3_compare`] is
/// for the kernel dispatch and [`pr4_compare`] for the scheduler:
/// `run(use_core)` executes the workload with the extension core off
/// (`false`, the seed scalar oracle) then on (`true`), returning a
/// deterministic result fingerprint and the wall seconds to record;
/// the two fingerprints are asserted equal before anything is written.
/// (Under `SANDSLASH_NO_EXTCORE=1` both runs resolve to the oracle and
/// the check degenerates to self-agreement — the CI oracle leg.)
pub fn pr5_compare<'a>(
    graph: &'a str,
    workload: &'a str,
    samples: usize,
    mut run: impl FnMut(bool) -> (u64, f64),
) -> Pr5Section<'a> {
    let (oracle_count, oracle_secs) = run(false);
    let (core_count, core_secs) = run(true);
    assert_eq!(
        oracle_count, core_count,
        "extension core vs scalar oracle disagree on {graph} / {workload}"
    );
    Pr5Section { graph, workload, count: core_count, oracle_secs, core_secs, samples }
}

impl Pr5Section<'_> {
    /// Oracle-over-core speedup (> 1 means the extension core won).
    pub fn speedup(&self) -> f64 {
        self.oracle_secs / self.core_secs
    }

    /// Upsert this section into the shared report at the repo root.
    pub fn write(&self, section: &str, threads: usize) -> std::io::Result<()> {
        let body = Json::new()
            .str("graph", self.graph)
            .str("workload", self.workload)
            .int("count", self.count)
            .num("oracle_secs", self.oracle_secs)
            .num("core_secs", self.core_secs)
            .num("speedup_core_over_oracle", self.speedup())
            .int("samples", self.samples as u64);
        upsert_bench_section(&pr1_report_path(), &pr1_meta(threads), section, &body)
    }
}

/// One measured governance-off vs governance-on comparison
/// (EXPERIMENTS.md §PR-6), as recorded in the `pr6-governance` report
/// section: the same mining workload run with the governance layer
/// scoped off ([`crate::engine::budget::with_governance_disabled`])
/// and back on with every budget unset, from the same process, so the
/// rows differ only in whether the admission poll sites execute.
/// Shared by the benches and the tier-1 smoke test so the JSON schema
/// cannot drift between writers.
pub struct Pr6Section<'a> {
    /// Input description (generator + parameters).
    pub graph: &'a str,
    /// Pattern name.
    pub pattern: &'a str,
    /// Agreed embedding count (differential check across the toggle).
    pub count: u64,
    /// Wall time with governance scoped off (seconds).
    pub gov_off_secs: f64,
    /// Wall time with governance on, budgets unset (seconds).
    pub gov_on_secs: f64,
    /// Number of timing samples behind the figures.
    pub samples: usize,
}

/// Run the §PR-6 governance-off vs governance-on measurement protocol
/// once and return the section row — the single implementation shared
/// by the tier-1 smoke test and the benches, exactly as
/// [`pr3_compare`] is for the kernel dispatch, [`pr4_compare`] for the
/// scheduler, and [`pr5_compare`] for the extension core:
///
/// 1. call `run` (which must execute the workload with **every budget
///    unset** and return the embedding count and the wall seconds to
///    record) under [`crate::engine::budget::with_governance_disabled`]
///    — the kill switch that makes every engine skip its `Governor`
///    entirely — then again with governance live;
/// 2. assert both runs agree on the count (the budgets-unset
///    bit-identical contract of EXPERIMENTS.md §PR-6);
/// 3. assert the [`crate::util::metrics::gov`] trip counters did not
///    move across the governed run — with no budget set, admission
///    must never refuse.
///
/// The recorded `gov_on_secs / gov_off_secs` ratio is therefore the
/// entire cost of the poll sites, expected ≈ 1.
pub fn pr6_compare<'a>(
    graph: &'a str,
    pattern: &'a str,
    samples: usize,
    mut run: impl FnMut() -> (u64, f64),
) -> Pr6Section<'a> {
    use crate::engine::budget;
    use crate::util::metrics::gov;
    let (off_count, gov_off_secs) = budget::with_governance_disabled(&mut run);
    let before = gov::snapshot();
    let (on_count, gov_on_secs) = run();
    let after = gov::snapshot();
    assert_eq!(
        off_count, on_count,
        "governed vs governance-disabled runs disagree on {graph} / {pattern}"
    );
    assert_eq!(
        after.trips(),
        before.trips(),
        "budgets unset but a governance trip fired on {graph} / {pattern}"
    );
    Pr6Section { graph, pattern, count: on_count, gov_off_secs, gov_on_secs, samples }
}

impl Pr6Section<'_> {
    /// Governed-over-ungoverned overhead ratio (≈ 1 means the poll
    /// sites are free).
    pub fn overhead(&self) -> f64 {
        self.gov_on_secs / self.gov_off_secs
    }

    /// Upsert this section into the shared report at the repo root.
    pub fn write(&self, section: &str, threads: usize) -> std::io::Result<()> {
        let body = Json::new()
            .str("graph", self.graph)
            .str("pattern", self.pattern)
            .int("count", self.count)
            .num("gov_off_secs", self.gov_off_secs)
            .num("gov_on_secs", self.gov_on_secs)
            .num("overhead_on_over_off", self.overhead())
            .int("samples", self.samples as u64);
        upsert_bench_section(&pr1_report_path(), &pr1_meta(threads), section, &body)
    }
}

/// One measured cold-vs-cached resident-service comparison
/// (EXPERIMENTS.md §PR-7), as recorded in the `pr7-service` report
/// section: the same query submitted twice to one in-process
/// [`crate::service::Service`] — the first paying admission + governed
/// engine run + cache fill, the second replaying the cached bytes —
/// with the two counts asserted equal before anything is written.
/// Shared by the benches and the tier-1 smoke test so the JSON schema
/// cannot drift between writers.
pub struct Pr7Section<'a> {
    /// Input description (generator + parameters).
    pub graph: &'a str,
    /// Pattern name.
    pub pattern: &'a str,
    /// Agreed embedding count (differential check across the cache).
    pub count: u64,
    /// Wall time of the cold (miss-path) query (seconds).
    pub cold_secs: f64,
    /// Wall time of the cached query (seconds).
    pub cached_secs: f64,
    /// Number of timing samples behind the figures.
    pub samples: usize,
}

/// Run the §PR-7 cold-vs-cached measurement protocol once and return
/// the section row — the single implementation shared by the tier-1
/// smoke test and the benches, completing the sequence of
/// [`pr3_compare`] (kernels), [`pr4_compare`] (scheduler),
/// [`pr5_compare`] (extension core), and [`pr6_compare`] (governance):
/// `run()` submits the query and must return the embedding count, the
/// wall seconds, and whether the response was served from the cache.
/// The first call must miss (`cached == false`), the second must hit
/// (`cached == true`), and the two counts are asserted equal — the
/// byte-replay contract means a disagreeing pair is a cache-soundness
/// bug, not noise.
pub fn pr7_compare<'a>(
    graph: &'a str,
    pattern: &'a str,
    samples: usize,
    mut run: impl FnMut() -> (u64, f64, bool),
) -> Pr7Section<'a> {
    let (cold_count, cold_secs, cold_cached) = run();
    assert!(!cold_cached, "first query of {graph} / {pattern} must be a cache miss");
    let (cached_count, cached_secs, hot_cached) = run();
    assert!(hot_cached, "second query of {graph} / {pattern} must be a cache hit");
    assert_eq!(
        cold_count, cached_count,
        "cached result disagrees with its miss-path original on {graph} / {pattern}"
    );
    Pr7Section { graph, pattern, count: cached_count, cold_secs, cached_secs, samples }
}

impl Pr7Section<'_> {
    /// Cold-over-cached speedup (how much the resident cache saves).
    pub fn speedup(&self) -> f64 {
        self.cold_secs / self.cached_secs
    }

    /// Upsert this section into the shared report at the repo root.
    pub fn write(&self, section: &str, threads: usize) -> std::io::Result<()> {
        let body = Json::new()
            .str("graph", self.graph)
            .str("pattern", self.pattern)
            .int("count", self.count)
            .num("cold_secs", self.cold_secs)
            .num("cached_secs", self.cached_secs)
            .num("speedup_cold_over_cached", self.speedup())
            .int("samples", self.samples as u64);
        upsert_bench_section(&pr1_report_path(), &pr1_meta(threads), section, &body)
    }
}

/// One measured untraced-vs-traced comparison (EXPERIMENTS.md §PR-9),
/// as recorded in the `pr9-obs` report section: the same mining
/// workload run with no [`crate::obs::trace::QueryTrace`] installed
/// (the default — every hook is a branch on an empty thread-local) and
/// again under [`crate::obs::trace::with_trace`], from the same
/// process, so the rows differ only in whether the trace accumulators
/// execute. Shared by the benches and the tier-1 smoke test so the
/// JSON schema cannot drift between writers.
pub struct Pr9Section<'a> {
    /// Input description (generator + parameters).
    pub graph: &'a str,
    /// Pattern name.
    pub pattern: &'a str,
    /// Agreed embedding count (differential check across the toggle).
    pub count: u64,
    /// Wall time with no trace installed (seconds).
    pub untraced_secs: f64,
    /// Wall time under an installed trace (seconds).
    pub traced_secs: f64,
    /// Number of timing samples behind the figures.
    pub samples: usize,
}

/// Run the §PR-9 untraced-vs-traced measurement protocol once and
/// return the section row — the single implementation shared by the
/// tier-1 smoke test and the benches, completing the sequence of
/// [`pr3_compare`] (kernels), [`pr4_compare`] (scheduler),
/// [`pr5_compare`] (extension core), [`pr6_compare`] (governance),
/// and [`pr7_compare`] (service cache):
///
/// 1. call `run` (which must execute the workload and return the
///    embedding count and the wall seconds to record) with no trace
///    installed, then again under [`crate::obs::trace::with_trace`]
///    with a fresh [`crate::obs::trace::QueryTrace`];
/// 2. assert both runs agree on the count (the bit-identical contract
///    of EXPERIMENTS.md §PR-9 — tracing observes, never steers);
/// 3. assert the trace actually recorded work (per-level spans or
///    kernel dispatches), so a hook-threading regression cannot
///    silently turn the traced row into a second untraced row.
///
/// The workload must therefore route through the traced extension
/// paths (any DFS pattern qualifies). The recorded
/// `traced_secs / untraced_secs` ratio is the entire cost of a live
/// trace, expected ≈ 1.
pub fn pr9_compare<'a>(
    graph: &'a str,
    pattern: &'a str,
    samples: usize,
    mut run: impl FnMut() -> (u64, f64),
) -> Pr9Section<'a> {
    use crate::obs::trace::{self, QueryTrace};
    let (untraced_count, untraced_secs) = run();
    let tr = std::sync::Arc::new(QueryTrace::new());
    let (traced_count, traced_secs) = trace::with_trace(tr.clone(), &mut run);
    assert_eq!(
        untraced_count, traced_count,
        "traced vs untraced runs disagree on {graph} / {pattern}"
    );
    assert!(
        tr.level_calls_total() + tr.dispatch_total() > 0,
        "trace installed but no extension hook fired on {graph} / {pattern}"
    );
    Pr9Section { graph, pattern, count: traced_count, untraced_secs, traced_secs, samples }
}

impl Pr9Section<'_> {
    /// Traced-over-untraced overhead ratio (≈ 1 means the hooks are
    /// free when idle and cheap when live).
    pub fn overhead(&self) -> f64 {
        self.traced_secs / self.untraced_secs
    }

    /// Upsert this section into the shared report at the repo root.
    pub fn write(&self, section: &str, threads: usize) -> std::io::Result<()> {
        let body = Json::new()
            .str("graph", self.graph)
            .str("pattern", self.pattern)
            .int("count", self.count)
            .num("untraced_secs", self.untraced_secs)
            .num("traced_secs", self.traced_secs)
            .num("overhead_traced_over_untraced", self.overhead())
            .int("samples", self.samples as u64);
        upsert_bench_section(&pr1_report_path(), &pr1_meta(threads), section, &body)
    }
}

/// One measured enumeration-vs-planner comparison (EXPERIMENTS.md
/// §PR-10), as recorded in the `pr10-plan` report section: the same
/// count-only workload run on the enumerated oracle
/// (`OptFlags::plan = false`) and through the decomposition planner
/// ([`crate::pattern::decompose`]), from the same process, so the rows
/// differ only in the counting route. Shared by the benches and the
/// tier-1 smoke test so the JSON schema cannot drift between writers.
pub struct Pr10Section<'a> {
    /// Input description (generator + parameters).
    pub graph: &'a str,
    /// Workload name (e.g. `4-motif-census`, `5-clique`).
    pub workload: &'a str,
    /// Agreed result fingerprint (differential check across routes).
    pub count: u64,
    /// Wall time on the enumerated oracle (seconds).
    pub enum_secs: f64,
    /// Wall time through the planner (seconds).
    pub plan_secs: f64,
    /// Engine-stats `enumerated` counter of the oracle run.
    pub enum_enumerated: u64,
    /// Engine-stats `enumerated` counter of the planner run.
    pub plan_enumerated: u64,
    /// Number of timing samples behind the figures.
    pub samples: usize,
}

/// Run the §PR-10 enumeration-vs-planner measurement protocol once and
/// return the section row — the single implementation shared by the
/// tier-1 smoke test and the benches, completing the sequence of
/// [`pr3_compare`] (kernels), [`pr4_compare`] (scheduler),
/// [`pr5_compare`] (extension core), [`pr6_compare`] (governance),
/// [`pr7_compare`] (service cache), and [`pr9_compare`] (tracing):
/// `run(use_planner)` executes the workload with the planner pinned
/// off (`false`, the enumerated oracle) then active (`true`),
/// returning a deterministic result fingerprint, the wall seconds to
/// record, and the run's engine-stats `enumerated` counter (collect
/// with `OptFlags::with_stats()`). The two fingerprints are asserted
/// equal before anything is written; the planner leg may never
/// enumerate *more*, and when the caller passes
/// `expect_shrink == true` (a workload whose decomposition is known to
/// apply, e.g. the 4-motif census) and the planner is actually live
/// ([`crate::pattern::decompose::plan_enabled_default`]) its
/// enumeration count is asserted **strictly** smaller — the acceptance
/// criterion of ISSUE 10. Pass `expect_shrink == false` for workloads
/// the planner correctly leaves on the direct route (e.g. a k-clique,
/// its own optimal anchor), where the ratio is recorded as ≈ 1. (Under
/// `SANDSLASH_NO_PLAN=1` both runs resolve to the oracle and every
/// check degenerates to self-agreement — the CI oracle leg, as with
/// [`pr5_compare`].)
pub fn pr10_compare<'a>(
    graph: &'a str,
    workload: &'a str,
    samples: usize,
    expect_shrink: bool,
    mut run: impl FnMut(bool) -> (u64, f64, u64),
) -> Pr10Section<'a> {
    let (enum_count, enum_secs, enum_enumerated) = run(false);
    let (plan_count, plan_secs, plan_enumerated) = run(true);
    assert_eq!(
        enum_count, plan_count,
        "planner vs enumerated oracle disagree on {graph} / {workload}"
    );
    assert!(
        plan_enumerated <= enum_enumerated,
        "planner enumerated more than the oracle on {graph} / {workload}: \
         {plan_enumerated} vs {enum_enumerated}"
    );
    if expect_shrink && crate::pattern::decompose::plan_enabled_default() {
        assert!(
            plan_enumerated < enum_enumerated,
            "planner live but did not shrink the enumeration space on {graph} / {workload}: \
             {plan_enumerated} vs {enum_enumerated}"
        );
    }
    Pr10Section {
        graph,
        workload,
        count: plan_count,
        enum_secs,
        plan_secs,
        enum_enumerated,
        plan_enumerated,
        samples,
    }
}

impl Pr10Section<'_> {
    /// Enumeration-over-planner speedup (> 1 means the planner won).
    pub fn speedup(&self) -> f64 {
        self.enum_secs / self.plan_secs
    }

    /// Upsert this section into the shared report at the repo root.
    pub fn write(&self, section: &str, threads: usize) -> std::io::Result<()> {
        let body = Json::new()
            .str("graph", self.graph)
            .str("workload", self.workload)
            .int("count", self.count)
            .num("enum_secs", self.enum_secs)
            .num("plan_secs", self.plan_secs)
            .num("speedup_plan_over_enum", self.speedup())
            .int("enum_enumerated", self.enum_enumerated)
            .int("plan_enumerated", self.plan_enumerated)
            .int("samples", self.samples as u64);
        upsert_bench_section(&pr1_report_path(), &pr1_meta(threads), section, &body)
    }
}

/// Print a markdown table of results: one row per (row_label, cells).
pub fn print_table(title: &str, columns: &[&str], rows: &[(String, Vec<String>)]) {
    println!("\n### {title}\n");
    println!("| | {} |", columns.join(" | "));
    println!("|---|{}|", columns.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
    for (label, cells) in rows {
        println!("| {} | {} |", label, cells.join(" | "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_at_least_min_runs() {
        let b = Bench { min_runs: 4, max_seconds: 0.05 };
        let r = b.run("noop", || 1 + 1);
        assert!(r.samples.len() >= 4);
        assert!(r.min() >= 0.0);
        assert!(r.median() >= r.min());
    }

    #[test]
    fn json_renders_escaped_and_ordered() {
        let j = Json::new().str("name", "a \"b\" \\ c").int("n", 7).num("t", 0.5);
        assert_eq!(
            j.render_inline(),
            "{ \"name\": \"a \\\"b\\\" \\\\ c\", \"n\": 7, \"t\": 0.500000 }"
        );
        let nan = Json::new().num("t", f64::NAN);
        assert_eq!(nan.render_inline(), "{ \"t\": null }");
    }

    #[test]
    fn upsert_round_trips_and_preserves_other_sections() {
        let path = std::env::temp_dir().join(format!(
            "sandslash_bench_upsert_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let meta = Json::new().str("bench", "unit").int("threads", 2);
        let a = Json::new().int("count", 10).num("secs", 0.25);
        upsert_bench_section(&path, &meta, "alpha", &a).unwrap();
        let b = Json::new().int("count", 20).num("secs", 0.5);
        upsert_bench_section(&path, &meta, "beta", &b).unwrap();
        // replace alpha; beta must survive
        let a2 = Json::new().int("count", 11).num("secs", 0.125);
        upsert_bench_section(&path, &meta, "alpha", &a2).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"alpha\": { \"count\": 11"));
        assert!(text.contains("\"beta\": { \"count\": 20"));
        let sections = extract_sections(&text).unwrap();
        assert_eq!(sections.len(), 2);
        assert_eq!(sections[0].0, "alpha");
        assert_eq!(sections[1].0, "beta");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn extract_rejects_foreign_layouts() {
        assert!(extract_sections("not json").is_none());
        assert!(extract_sections("{\"sections\": {").is_none());
        let ok = extract_sections("{\"sections\": {}}").unwrap();
        assert!(ok.is_empty());
    }
}
