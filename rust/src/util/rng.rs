//! Deterministic pseudo-random number generation.
//!
//! SplitMix64 for seeding, xoshiro256** for the stream — both public
//! domain algorithms. All graph generators and property tests take
//! explicit seeds so every experiment in EXPERIMENTS.md is reproducible.

/// SplitMix64 step: used to expand a single `u64` seed into a full state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (expanded via SplitMix64).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    #[inline]
    /// Next 64 random bits (xoshiro256**).
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift; bound > 0).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k << n assumed).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let x = self.below(n as u64) as usize;
            if !out.contains(&x) {
                out.push(x);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::seeded(1);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seeded(2);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn chance_rates_are_plausible() {
        let mut r = Rng::seeded(4);
        let hits = (0..100_000).filter(|_| r.chance(0.25)).count();
        assert!((23_000..27_000).contains(&hits), "hits={hits}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seeded(5);
        let s = r.sample_indices(50, 10);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 10);
    }
}
