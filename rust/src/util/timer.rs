//! Wall-clock timing helpers used by apps, benches and the campaign
//! driver.

use std::time::Instant;

/// Simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start a stopwatch.
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Elapsed seconds.
    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds.
    pub fn millis(&self) -> f64 {
        self.seconds() * 1e3
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.seconds())
}

/// Human format for seconds: "123 ms", "4.56 s", "2.1 min".
pub fn fmt_secs(s: f64) -> String {
    if !s.is_finite() {
        "—".to_string()
    } else if s < 1.0 {
        format!("{:.1} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_value_and_positive_time() {
        let (v, secs) = timed(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(0.002).ends_with("ms"));
        assert!(fmt_secs(3.5).ends_with(" s"));
        assert!(fmt_secs(300.0).ends_with("min"));
    }
}
