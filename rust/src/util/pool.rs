//! Parallel execution substrate — thin adapters over the scheduler.
//!
//! The paper parallelizes DFS mining with per-root-vertex tasks and
//! work-stealing. Since PR 4 the real machinery lives in
//! [`crate::exec::sched`]: per-worker stealing deques over shard-local
//! cursors, with the seed global-cursor loop retained as the scheduling
//! oracle. This module keeps the seed-era `parallel_for` /
//! `parallel_reduce` signatures so the engine, app, and baseline call
//! sites never changed — they resolve a default
//! [`SchedPolicy`](crate::exec::sched::SchedPolicy) (stealing on unless
//! `SANDSLASH_NO_STEAL=1` or a scoped
//! [`with_overrides`](crate::exec::sched::with_overrides) says
//! otherwise) and forward. It also owns the process-wide environment
//! knobs: `SANDSLASH_THREADS` and `SANDSLASH_CHUNK`, both resolved
//! once per process through the same loud-reject parse contract.

use std::sync::OnceLock;

use crate::exec::sched::{self, SchedPolicy, Task};

/// Seed-era dynamic self-scheduling chunk size, now the stealing
/// scheduler's grain (roots processed per deque interaction).
pub const DEFAULT_CHUNK: usize = 64;

/// Number of worker threads to use (overridable via `SANDSLASH_THREADS`).
///
/// An override that is set but unusable — unparsable or zero — is
/// rejected *loudly* (one stderr warning per process) before falling
/// back to all cores. Silently swallowing it made campaign runs report
/// a thread count in BENCH metadata that was never actually applied.
///
/// The resolved value is cached for the process lifetime (`OnceLock`):
/// campaign loops used to pay an env-var syscall on every
/// `MinerConfig::new`, and the cache is also what guarantees the
/// warning truly fires once. Consequently the variable is pinned at
/// first use — set it before the process starts, not mid-run.
pub fn default_threads() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        positive_usize_env("SANDSLASH_THREADS", "all available cores").unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
    })
}

/// Root-task grain (overridable via `SANDSLASH_CHUNK`, default
/// [`DEFAULT_CHUNK`]) — same loud-reject parse contract and
/// process-lifetime caching as [`default_threads`].
pub fn default_chunk() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        positive_usize_env("SANDSLASH_CHUNK", "the built-in chunk size").unwrap_or(DEFAULT_CHUNK)
    })
}

/// Shared loud-reject env override: `Some(n)` for a usable positive
/// integer, `None` when the variable is unset **or** unusable — the
/// unusable case warns on stderr (naming the variable, the rejected
/// value, the reason, and the `fallback` the caller will use) instead
/// of being silently swallowed. Callers cache the result in a
/// `OnceLock`, which is what bounds the warning to once per process.
pub(crate) fn positive_usize_env(var: &str, fallback: &str) -> Option<usize> {
    let raw = std::env::var(var).ok()?;
    match parse_positive_usize(&raw) {
        Ok(n) => Some(n),
        Err(why) => {
            eprintln!("sandslash: ignoring {var}={raw:?} ({why}); using {fallback}");
            None
        }
    }
}

/// Parse one positive-integer override: surrounding whitespace
/// tolerated, zero and garbage rejected with the reason that lands in
/// the one-shot stderr warning of [`positive_usize_env`].
fn parse_positive_usize(raw: &str) -> Result<usize, &'static str> {
    let trimmed = raw.trim();
    if trimmed.is_empty() {
        return Err("empty value");
    }
    match trimmed.parse::<usize>() {
        Ok(0) => Err("value must be positive"),
        Ok(n) => Ok(n),
        Err(_) => Err("not an unsigned integer"),
    }
}

/// Parallel for over `0..n`: each worker repeatedly claims `chunk` indices.
/// `f(worker_id, index)` must be safe to run concurrently for distinct
/// indices.
pub fn parallel_for(n: usize, threads: usize, chunk: usize, f: impl Fn(usize, usize) + Sync) {
    sched::for_each(n, &SchedPolicy::auto(threads, chunk), f);
}

/// Parallel map-reduce over `0..n` with per-worker accumulators.
///
/// `init` builds one accumulator per worker, `f` folds an index into it,
/// and `merge` combines the per-worker results. This is the backbone of
/// every counting app: accumulators are per-thread (no atomics in the hot
/// loop), merged once at the end. Scheduling (stealing vs the cursor
/// oracle, shard count) comes from the process defaults — callers that
/// need per-run control use [`sched::reduce`] directly.
pub fn parallel_reduce<A: Send>(
    n: usize,
    threads: usize,
    chunk: usize,
    init: impl Fn() -> A + Sync,
    f: impl Fn(&mut A, usize) + Sync,
    merge: impl FnMut(A, A) -> A,
) -> A {
    sched::reduce(
        n,
        &SchedPolicy::auto(threads, chunk),
        init,
        |acc, _, task| match task {
            Task::Roots { start, end } => {
                for i in start..end {
                    f(acc, i);
                }
            }
            Task::Split { .. } => {
                unreachable!("index adapters never publish split tasks")
            }
        },
        merge,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn positive_override_parse_paths() {
        // valid values, with and without surrounding whitespace
        assert_eq!(parse_positive_usize("1"), Ok(1));
        assert_eq!(parse_positive_usize("8"), Ok(8));
        assert_eq!(parse_positive_usize(" 16 "), Ok(16));
        // rejected: zero, garbage, negatives, empties, fractions
        assert_eq!(parse_positive_usize("0"), Err("value must be positive"));
        assert_eq!(parse_positive_usize(" 0 "), Err("value must be positive"));
        assert_eq!(parse_positive_usize(""), Err("empty value"));
        assert_eq!(parse_positive_usize("   "), Err("empty value"));
        assert_eq!(parse_positive_usize("abc"), Err("not an unsigned integer"));
        assert_eq!(parse_positive_usize("-4"), Err("not an unsigned integer"));
        assert_eq!(parse_positive_usize("2.5"), Err("not an unsigned integer"));
        assert_eq!(parse_positive_usize("8 cores"), Err("not an unsigned integer"));
    }

    #[test]
    fn resolved_knobs_are_positive_and_cached() {
        // Cannot assert exact values (environment-dependent), but the
        // contract is: positive, and stable across calls in a process.
        let t = default_threads();
        assert!(t >= 1);
        assert_eq!(default_threads(), t);
        let c = default_chunk();
        assert!(c >= 1);
        assert_eq!(default_chunk(), c);
    }

    #[test]
    fn parallel_for_covers_all_indices_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(n, 4, 64, |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_reduce_sums() {
        for threads in [1, 2, 8] {
            let total = parallel_reduce(
                1000,
                threads,
                7,
                || 0u64,
                |acc, i| *acc += i as u64,
                |a, b| a + b,
            );
            assert_eq!(total, 999 * 1000 / 2);
        }
    }

    #[test]
    fn single_thread_fallback_matches() {
        let a = parallel_reduce(100, 1, 16, || 0u64, |acc, i| *acc += i as u64, |a, b| a + b);
        let b = parallel_reduce(100, 8, 16, || 0u64, |acc, i| *acc += i as u64, |a, b| a + b);
        assert_eq!(a, b);
    }

    #[test]
    fn adapters_honor_scoped_overrides() {
        // both the oracle and the stealing pool must produce the same
        // reduction through the unchanged adapter signature
        let want = 999 * 1000 / 2;
        for steal in [false, true] {
            for shards in [1usize, 2] {
                let ov = crate::exec::sched::Overrides {
                    steal: Some(steal),
                    shards: Some(shards),
                };
                let got = crate::exec::sched::with_overrides(ov, || {
                    parallel_reduce(1000, 4, 8, || 0u64, |acc, i| *acc += i as u64, |a, b| a + b)
                });
                assert_eq!(got, want, "steal={steal} shards={shards}");
            }
        }
    }
}
