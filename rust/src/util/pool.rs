//! Parallel execution substrate.
//!
//! The paper parallelizes DFS mining with per-root-vertex tasks and
//! work-stealing. We implement the equivalent with scoped threads plus
//! *dynamic self-scheduling*: workers claim chunks of the task range from
//! a shared atomic cursor, which gives the same dynamic load balance as a
//! stealing deque for this workload shape (many independent root tasks of
//! wildly varying cost) with no unsafe code and no external crates.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (overridable via SANDSLASH_THREADS).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("SANDSLASH_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Parallel for over `0..n`: each worker repeatedly claims `chunk` indices.
/// `f(worker_id, index)` must be safe to run concurrently for distinct
/// indices.
pub fn parallel_for(n: usize, threads: usize, chunk: usize, f: impl Fn(usize, usize) + Sync) {
    let threads = threads.max(1);
    if threads == 1 || n <= chunk {
        for i in 0..n {
            f(0, i);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for tid in 0..threads {
            let cursor = &cursor;
            let f = &f;
            scope.spawn(move || loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    f(tid, i);
                }
            });
        }
    });
}

/// Parallel map-reduce over `0..n` with per-worker accumulators.
///
/// `init` builds one accumulator per worker, `f` folds an index into it,
/// and `merge` combines the per-worker results. This is the backbone of
/// every counting app: accumulators are per-thread (no atomics in the hot
/// loop), merged once at the end.
pub fn parallel_reduce<A: Send>(
    n: usize,
    threads: usize,
    chunk: usize,
    init: impl Fn() -> A + Sync,
    f: impl Fn(&mut A, usize) + Sync,
    mut merge: impl FnMut(A, A) -> A,
) -> A {
    let threads = threads.max(1);
    if threads == 1 || n <= chunk {
        let mut acc = init();
        for i in 0..n {
            f(&mut acc, i);
        }
        return acc;
    }
    let cursor = AtomicUsize::new(0);
    let results: Vec<A> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let cursor = &cursor;
                let f = &f;
                let init = &init;
                scope.spawn(move || {
                    let mut acc = init();
                    loop {
                        let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                        if start >= n {
                            break;
                        }
                        let end = (start + chunk).min(n);
                        for i in start..end {
                            f(&mut acc, i);
                        }
                    }
                    acc
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut it = results.into_iter();
    let first = it.next().unwrap();
    it.fold(first, |a, b| merge(a, b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(n, 4, 64, |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_reduce_sums() {
        for threads in [1, 2, 8] {
            let total = parallel_reduce(
                1000,
                threads,
                7,
                || 0u64,
                |acc, i| *acc += i as u64,
                |a, b| a + b,
            );
            assert_eq!(total, 999 * 1000 / 2);
        }
    }

    #[test]
    fn single_thread_fallback_matches() {
        let a = parallel_reduce(100, 1, 16, || 0u64, |acc, i| *acc += i as u64, |a, b| a + b);
        let b = parallel_reduce(100, 8, 16, || 0u64, |acc, i| *acc += i as u64, |a, b| a + b);
        assert_eq!(a, b);
    }
}
