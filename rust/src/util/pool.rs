//! Parallel execution substrate.
//!
//! The paper parallelizes DFS mining with per-root-vertex tasks and
//! work-stealing. We implement the equivalent with scoped threads plus
//! *dynamic self-scheduling*: workers claim chunks of the task range from
//! a shared atomic cursor, which gives the same dynamic load balance as a
//! stealing deque for this workload shape (many independent root tasks of
//! wildly varying cost) with no unsafe code and no external crates.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (overridable via `SANDSLASH_THREADS`).
///
/// An override that is set but unusable — unparsable or zero — is
/// rejected *loudly* (one stderr warning per process) before falling
/// back to all cores. Silently swallowing it made campaign runs report
/// a thread count in BENCH metadata that was never actually applied.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("SANDSLASH_THREADS") {
        match parse_thread_override(&v) {
            Ok(n) => return n,
            Err(why) => {
                static WARN_ONCE: std::sync::Once = std::sync::Once::new();
                WARN_ONCE.call_once(|| {
                    eprintln!(
                        "sandslash: ignoring SANDSLASH_THREADS={v:?} ({why}); \
                         using all available cores"
                    );
                });
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Parse a `SANDSLASH_THREADS` override: a positive integer,
/// surrounding whitespace tolerated. The error names the reason for
/// the one-shot stderr warning in [`default_threads`].
fn parse_thread_override(raw: &str) -> Result<usize, &'static str> {
    let trimmed = raw.trim();
    if trimmed.is_empty() {
        return Err("empty value");
    }
    match trimmed.parse::<usize>() {
        Ok(0) => Err("thread count must be positive"),
        Ok(n) => Ok(n),
        Err(_) => Err("not an unsigned integer"),
    }
}

/// Parallel for over `0..n`: each worker repeatedly claims `chunk` indices.
/// `f(worker_id, index)` must be safe to run concurrently for distinct
/// indices.
pub fn parallel_for(n: usize, threads: usize, chunk: usize, f: impl Fn(usize, usize) + Sync) {
    let threads = threads.max(1);
    if threads == 1 || n <= chunk {
        for i in 0..n {
            f(0, i);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for tid in 0..threads {
            let cursor = &cursor;
            let f = &f;
            scope.spawn(move || loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    f(tid, i);
                }
            });
        }
    });
}

/// Parallel map-reduce over `0..n` with per-worker accumulators.
///
/// `init` builds one accumulator per worker, `f` folds an index into it,
/// and `merge` combines the per-worker results. This is the backbone of
/// every counting app: accumulators are per-thread (no atomics in the hot
/// loop), merged once at the end.
pub fn parallel_reduce<A: Send>(
    n: usize,
    threads: usize,
    chunk: usize,
    init: impl Fn() -> A + Sync,
    f: impl Fn(&mut A, usize) + Sync,
    mut merge: impl FnMut(A, A) -> A,
) -> A {
    let threads = threads.max(1);
    if threads == 1 || n <= chunk {
        let mut acc = init();
        for i in 0..n {
            f(&mut acc, i);
        }
        return acc;
    }
    let cursor = AtomicUsize::new(0);
    let results: Vec<A> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let cursor = &cursor;
                let f = &f;
                let init = &init;
                scope.spawn(move || {
                    let mut acc = init();
                    loop {
                        let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                        if start >= n {
                            break;
                        }
                        let end = (start + chunk).min(n);
                        for i in start..end {
                            f(&mut acc, i);
                        }
                    }
                    acc
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut it = results.into_iter();
    let first = it.next().unwrap();
    it.fold(first, |a, b| merge(a, b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn thread_override_parse_paths() {
        // valid values, with and without surrounding whitespace
        assert_eq!(parse_thread_override("1"), Ok(1));
        assert_eq!(parse_thread_override("8"), Ok(8));
        assert_eq!(parse_thread_override(" 16 "), Ok(16));
        // rejected: zero, garbage, negatives, empties, fractions
        assert_eq!(parse_thread_override("0"), Err("thread count must be positive"));
        assert_eq!(parse_thread_override(" 0 "), Err("thread count must be positive"));
        assert_eq!(parse_thread_override(""), Err("empty value"));
        assert_eq!(parse_thread_override("   "), Err("empty value"));
        assert_eq!(parse_thread_override("abc"), Err("not an unsigned integer"));
        assert_eq!(parse_thread_override("-4"), Err("not an unsigned integer"));
        assert_eq!(parse_thread_override("2.5"), Err("not an unsigned integer"));
        assert_eq!(parse_thread_override("8 cores"), Err("not an unsigned integer"));
    }

    #[test]
    fn parallel_for_covers_all_indices_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_for(n, 4, 64, |_, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_reduce_sums() {
        for threads in [1, 2, 8] {
            let total = parallel_reduce(
                1000,
                threads,
                7,
                || 0u64,
                |acc, i| *acc += i as u64,
                |a, b| a + b,
            );
            assert_eq!(total, 999 * 1000 / 2);
        }
    }

    #[test]
    fn single_thread_fallback_matches() {
        let a = parallel_reduce(100, 1, 16, || 0u64, |acc, i| *acc += i as u64, |a, b| a + b);
        let b = parallel_reduce(100, 8, 16, || 0u64, |acc, i| *acc += i as u64, |a, b| a + b);
        assert_eq!(a, b);
    }
}
