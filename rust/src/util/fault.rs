//! Deterministic fault injection (PR 6) — compiled in, default-off.
//!
//! The governance suite (`rust/tests/governance.rs`) must prove that a
//! panic at *any* engine stage surfaces as
//! [`MineError::WorkerPanicked`](crate::engine::budget::MineError)
//! with the process alive, and that deadlines trip mid-run. Both need
//! a way to make a specific worker task misbehave on demand, so the
//! engines carry named fault points ([`point`]) at their interesting
//! stages ([`Stage`]): root-block claims and split re-entries (the
//! `exec::split` task match), FSM child regeneration, and BFS level
//! expansion. Each crossing costs one relaxed load when no plan is
//! installed — the same always-on-but-cheap shape as the scheduler
//! counters.
//!
//! A plan fires at the `at_task`-th matching crossing (process-wide
//! counter, reset by [`install`]): `Panic` raises a recognizable
//! payload (caught by the scheduler's governance layer), `Delay`
//! sleeps — the lever deadline tests use to make a block reliably
//! outlast a short deadline.
//!
//! Environment grammar (`SANDSLASH_FAULT`, parsed once per process by
//! [`init_from_env`], loud-reject like every `SANDSLASH_*` knob):
//!
//! ```text
//! SANDSLASH_FAULT=panic@<task-n>          # panic at the n-th crossing
//! SANDSLASH_FAULT=delay@<task-n>:<ms>     # sleep <ms> at the n-th crossing
//! ```
//!
//! The env form matches every stage; tests install stage-filtered
//! plans programmatically.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

/// Engine stages carrying a fault point. All points sit inside
/// *worker* task bodies (never on the coordinator), so an injected
/// panic exercises the worker catch/drain path — the thing the
/// governance suite exists to prove.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// A claimed root-range task, before its roots are mined
    /// (`exec::split::reduce`, `Task::Roots` arm).
    RootClaim,
    /// A split task re-entering a published level-1 suffix
    /// (`exec::split::reduce`, `Task::Split` arm).
    SplitTask,
    /// FSM child-pattern regeneration inside a root-bin task.
    FsmRegen,
    /// BFS per-parent expansion inside a level task.
    BfsLevel,
}

/// What to do when the planned crossing is reached.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic with a recognizable `"injected fault"` payload.
    Panic,
    /// Sleep for the given duration (deadline tests).
    Delay(Duration),
}

/// One armed fault: fire `action` at the `at_task`-th crossing of a
/// matching fault point (counting from 0; `stage: None` matches every
/// stage).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    /// What to do at the matched crossing.
    pub action: FaultAction,
    /// Which matching crossing fires (0-based).
    pub at_task: u64,
    /// Restrict matching to one stage (`None` = any).
    pub stage: Option<Stage>,
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static CROSSINGS: AtomicU64 = AtomicU64::new(0);
static PLAN: Mutex<Option<FaultPlan>> = Mutex::new(None);

/// Arm `plan` process-wide and reset the crossing counter. Tests
/// serialize on their own lock (the harness state is process-global).
pub fn install(plan: FaultPlan) {
    let mut slot = PLAN.lock().unwrap_or_else(|e| e.into_inner());
    *slot = Some(plan);
    CROSSINGS.store(0, Ordering::SeqCst);
    ACTIVE.store(true, Ordering::SeqCst);
}

/// Disarm the harness (crossings stop counting and cost one relaxed
/// load again).
pub fn clear() {
    ACTIVE.store(false, Ordering::SeqCst);
    let mut slot = PLAN.lock().unwrap_or_else(|e| e.into_inner());
    *slot = None;
}

/// A fault point: named crossing in an engine worker body. One relaxed
/// load when the harness is off.
#[inline]
pub fn point(stage: Stage) {
    if ACTIVE.load(Ordering::Relaxed) {
        crossed(stage);
    }
}

/// Slow path of [`point`]: count the crossing and fire if it is the
/// planned one. The plan is copied out before any panic so the
/// `PLAN` mutex is never poisoned by the injection itself. Every
/// armed crossing is also recorded in the flight recorder (and as the
/// thread's last-seen stage), which is how a post-panic dump names
/// the faulted stage (PR 9).
#[cold]
fn crossed(stage: Stage) {
    crate::obs::flight::note_stage(stage);
    let plan = {
        let slot = PLAN.lock().unwrap_or_else(|e| e.into_inner());
        match *slot {
            Some(p) => p,
            None => return,
        }
    };
    if let Some(want) = plan.stage {
        if want != stage {
            return;
        }
    }
    let n = CROSSINGS.fetch_add(1, Ordering::Relaxed);
    if n == plan.at_task {
        match plan.action {
            FaultAction::Delay(d) => std::thread::sleep(d),
            FaultAction::Panic => {
                crate::util::metrics::gov::note_fault_injected();
                panic!("injected fault: panic at {stage:?} crossing {n}");
            }
        }
    }
}

/// Arm the harness from `SANDSLASH_FAULT` (module docs for the
/// grammar), once per process; an unusable spec warns on stderr and
/// leaves injection off. Called from `Governor::new`, so headless runs
/// pick the plan up before the first governed task.
pub fn init_from_env() {
    static INIT: OnceLock<()> = OnceLock::new();
    INIT.get_or_init(|| {
        if let Ok(raw) = std::env::var("SANDSLASH_FAULT") {
            match parse_spec(&raw) {
                Ok(plan) => install(plan),
                Err(why) => eprintln!(
                    "sandslash: ignoring SANDSLASH_FAULT={raw:?} ({why}); fault injection off"
                ),
            }
        }
    });
}

/// Parse one `SANDSLASH_FAULT` spec (env plans match every stage).
fn parse_spec(raw: &str) -> Result<FaultPlan, &'static str> {
    let spec = raw.trim();
    if let Some(rest) = spec.strip_prefix("panic@") {
        let at_task = rest.trim().parse::<u64>().map_err(|_| "task index not an integer")?;
        return Ok(FaultPlan { action: FaultAction::Panic, at_task, stage: None });
    }
    if let Some(rest) = spec.strip_prefix("delay@") {
        let (task, ms) = rest.split_once(':').ok_or("delay needs <task-n>:<ms>")?;
        let at_task = task.trim().parse::<u64>().map_err(|_| "task index not an integer")?;
        let millis = ms.trim().parse::<u64>().map_err(|_| "delay not an integer (ms)")?;
        return Ok(FaultPlan {
            action: FaultAction::Delay(Duration::from_millis(millis)),
            at_task,
            stage: None,
        });
    }
    Err("expected panic@<task-n> or delay@<task-n>:<ms>")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_grammar_parses_and_rejects() {
        assert_eq!(
            parse_spec("panic@3"),
            Ok(FaultPlan { action: FaultAction::Panic, at_task: 3, stage: None })
        );
        assert_eq!(
            parse_spec(" delay@0:250 "),
            Ok(FaultPlan {
                action: FaultAction::Delay(Duration::from_millis(250)),
                at_task: 0,
                stage: None
            })
        );
        assert!(parse_spec("").is_err());
        assert!(parse_spec("panic").is_err());
        assert!(parse_spec("panic@x").is_err());
        assert!(parse_spec("delay@1").is_err());
        assert!(parse_spec("delay@1:abc").is_err());
        assert!(parse_spec("explode@1").is_err());
    }

    #[test]
    fn stage_filter_counts_only_matching_crossings() {
        // process-global harness: restore the off state when done
        install(FaultPlan {
            action: FaultAction::Delay(Duration::ZERO),
            at_task: 1,
            stage: Some(Stage::FsmRegen),
        });
        point(Stage::RootClaim); // filtered out, must not count
        point(Stage::FsmRegen); // crossing 0
        point(Stage::FsmRegen); // crossing 1 -> fires (zero delay)
        assert_eq!(CROSSINGS.load(Ordering::SeqCst), 2);
        clear();
        point(Stage::FsmRegen); // disarmed, must not count
        assert_eq!(CROSSINGS.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn injected_panic_payload_is_recognizable() {
        install(FaultPlan { action: FaultAction::Panic, at_task: 0, stage: Some(Stage::RootClaim) });
        let caught = std::panic::catch_unwind(|| point(Stage::RootClaim));
        clear();
        let payload = caught.expect_err("the planned crossing must panic");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("injected fault"), "payload: {msg}");
    }
}
