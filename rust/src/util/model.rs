//! In-tree deterministic schedule-exploration model checker — the
//! `loom` substitute behind the [`crate::util::sync`] facade.
//!
//! The offline build environment has no crate registry (Cargo.toml:
//! zero dependencies by design), so the PR-8 concurrency verification
//! layer ships its own systematic concurrency tester the same way
//! `util::bench` stands in for criterion. Under `--cfg loom` the
//! [`crate::util::sync`] facade routes the synchronization of
//! `exec/sched.rs`, `engine/budget.rs`, `service/cache.rs` and
//! `service/admission.rs` onto the modeled types in this module, and
//! the `rust/tests/loom/` suite re-runs each protocol under every
//! explored interleaving.
//!
//! # How it works
//!
//! [`check`] runs a closure repeatedly, once per *schedule*. Modeled
//! threads are real OS threads serialized by a token: exactly one
//! modeled thread executes at a time, and every modeled operation
//! (atomic access, mutex lock/unlock, condvar wait/notify, spawn,
//! yield) is a *schedule point* where the token may move. The sequence
//! of decisions forms a trail; after each schedule the last
//! not-yet-exhausted decision is advanced and the closure replays —
//! depth-first systematic exploration, CHESS-style:
//!
//! * **Preemption bounding**: involuntary switches (taking the token
//!   away from a thread that could keep running, at an atomic or lock
//!   operation) are the branching decisions, bounded per schedule by
//!   [`Model::preemption_bound`] (most concurrency bugs need very few
//!   preemptions). Voluntary switches — blocking, `yield_now`,
//!   `sleep`, thread exit — round-robin deterministically and do not
//!   branch, which keeps idle-spin loops fair and finite.
//! * **Bounded exploration**: [`Model::max_schedules`] caps the number
//!   of schedules (exploration order is deterministic, so a truncated
//!   run is a reproducible prefix). `SANDSLASH_MODEL_ITERS` and
//!   `SANDSLASH_MODEL_PREEMPTIONS` override the defaults process-wide.
//! * **Deadlock detection**: a schedule where every live thread is
//!   blocked aborts the run and reports each thread's state.
//! * **Failure reporting**: a panic in any modeled thread (assertion
//!   failures included) aborts the schedule, unwinds every other
//!   thread, and [`check`] re-panics with the schedule count — the
//!   failing interleaving is the deterministic n-th schedule, so it
//!   can be replayed under a debugger by re-running the test.
//!
//! # What it does *not* model
//!
//! Memory is sequentially consistent: because only one modeled thread
//! runs at a time (with a happens-before edge through the token
//! hand-off), every explored execution is an interleaving of whole
//! operations. Loom's C11 weak-memory reorderings (a `Relaxed` store
//! seen out of order, unsynchronized-data races) are *not* explored —
//! those are covered by the textual `Relaxed` audit in `cargo xtask
//! lint` and the ThreadSanitizer leg of the `rust-analysis` workflow.
//! Spurious condvar wakeups are not injected either (every migrated
//! wait site is a while-loop, so this only loses coverage, never
//! soundness of a pass). See EXPERIMENTS.md §PR-8.

use std::cell::RefCell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar as OsCondvar, Mutex as OsMutex};

/// Default involuntary-switch budget per schedule (the CHESS
/// observation: almost all real interleaving bugs manifest with two or
/// fewer preemptions).
pub const DEFAULT_PREEMPTION_BOUND: usize = 2;

/// Default cap on explored schedules per [`check`] call.
pub const DEFAULT_MAX_SCHEDULES: usize = 4096;

/// Hard per-schedule step cap — a backstop against user code that
/// fails to terminate even under the fair round-robin fallback.
const STEP_CAP: usize = 1 << 20;

/// Marker payload for the internal unwind that tears a modeled thread
/// down when the schedule aborts; never observed by user code.
struct ModelAbort;

/// One modeled thread's scheduling state.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Run {
    /// Runnable (may or may not hold the token).
    Ready,
    /// Waiting for the mutex whose cell address is given.
    BlockedLock(usize),
    /// Waiting on the condvar whose address is given.
    BlockedCv(usize),
    /// Waiting for the thread with the given id to finish.
    BlockedJoin(usize),
    /// Body returned (or unwound); never runs again this schedule.
    Finished,
}

/// One recorded branching decision: which of `options` successor
/// choices was taken at a preemptible point.
#[derive(Clone, Copy, Debug)]
struct Branch {
    taken: usize,
    options: usize,
}

/// Why a schedule aborted.
enum Failure {
    /// A modeled thread panicked; the message is a rendering of the
    /// payload (the payload itself unwinds out of the OS thread).
    Panic(String),
    /// Every live thread was blocked.
    Deadlock(String),
    /// The step backstop tripped.
    StepCap,
}

impl std::fmt::Display for Failure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Failure::Panic(m) => write!(f, "thread panicked: {m}"),
            Failure::Deadlock(m) => write!(f, "deadlock: {m}"),
            Failure::StepCap => write!(f, "schedule exceeded {STEP_CAP} steps"),
        }
    }
}

/// Scheduler state shared by every modeled thread of one schedule.
struct SchedInner {
    threads: Vec<Run>,
    /// Id of the thread holding the token.
    current: usize,
    /// Branch decisions: replayed up to `pos`, extended past it.
    trail: Vec<Branch>,
    pos: usize,
    preemptions: usize,
    bound: usize,
    steps: usize,
    /// Set on the first failure (or external abort); every thread
    /// unwinds via [`ModelAbort`] at its next schedule point.
    abort: bool,
    failure: Option<Failure>,
}

/// One schedule's coordinator: the token, the trail, and the condvar
/// modeled threads park on.
struct Exec {
    inner: OsMutex<SchedInner>,
    cv: OsCondvar,
}

thread_local! {
    /// (executor, thread id) binding of the current OS thread, set for
    /// the duration of a schedule. `None` means "off-model": the model
    /// primitives then degrade to plain single-threaded storage.
    static CTX: RefCell<Option<(Arc<Exec>, usize)>> = const { RefCell::new(None) };
}

fn ctx() -> Option<(Arc<Exec>, usize)> {
    CTX.with(|c| c.borrow().clone())
}

/// Binds the current OS thread to `(exec, tid)` until the guard drops.
struct CtxGuard {
    prev: Option<(Arc<Exec>, usize)>,
}

fn bind(exec: Arc<Exec>, tid: usize) -> CtxGuard {
    let prev = CTX.with(|c| c.borrow_mut().replace((exec, tid)));
    CtxGuard { prev }
}

impl Drop for CtxGuard {
    fn drop(&mut self) {
        CTX.with(|c| *c.borrow_mut() = self.prev.take());
    }
}

/// The kind of schedule point, deciding whether the switch branches.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Point {
    /// The running thread could continue (atomic/lock op): switching
    /// away costs a preemption and is a recorded branch decision.
    Preemptible,
    /// The running thread volunteers the token (`yield_now`, `sleep`):
    /// deterministic round-robin, no branch.
    Yield,
    /// The running thread just blocked: the token must move.
    Blocked,
}

fn render_payload(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

impl Exec {
    fn new(trail: Vec<Branch>, bound: usize) -> Self {
        Exec {
            inner: OsMutex::new(SchedInner {
                threads: vec![Run::Ready],
                current: 0,
                trail,
                pos: 0,
                preemptions: 0,
                bound,
                steps: 0,
                abort: false,
                failure: None,
            }),
            cv: OsCondvar::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, SchedInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// First Ready thread strictly after `from` in cyclic id order,
    /// falling back to `from` itself if it is the only one enabled.
    fn round_robin(threads: &[Run], from: usize) -> Option<usize> {
        let n = threads.len();
        (1..=n).map(|d| (from + d) % n).find(|&t| threads[t] == Run::Ready)
    }

    /// The heart of the checker: consume one schedule point on the
    /// calling modeled thread, possibly moving the token. Returns with
    /// the token re-held; unwinds with [`ModelAbort`] if the schedule
    /// aborted while parked.
    fn schedule(&self, me: usize, point: Point) {
        // A guard Drop running during a panic (mutex release on
        // unwind) must not re-enter the scheduler: the thread is
        // already on its way out, and a second panic would abort the
        // process. State updates done by the caller stand on their own.
        if std::thread::panicking() {
            return;
        }
        let mut g = self.lock();
        if g.abort {
            drop(g);
            std::panic::panic_any(ModelAbort);
        }
        g.steps += 1;
        if g.steps > STEP_CAP {
            g.abort = true;
            g.failure.get_or_insert(Failure::StepCap);
            self.cv.notify_all();
            drop(g);
            std::panic::panic_any(ModelAbort);
        }
        let enabled: Vec<usize> = g
            .threads
            .iter()
            .enumerate()
            .filter(|(_, r)| **r == Run::Ready)
            .map(|(t, _)| t)
            .collect();
        if enabled.is_empty() {
            let desc = g
                .threads
                .iter()
                .enumerate()
                .filter(|(_, r)| **r != Run::Finished)
                .map(|(t, r)| format!("thread {t}: {r:?}"))
                .collect::<Vec<_>>()
                .join(", ");
            g.abort = true;
            g.failure.get_or_insert(Failure::Deadlock(desc));
            self.cv.notify_all();
            drop(g);
            std::panic::panic_any(ModelAbort);
        }
        let next = match point {
            Point::Blocked | Point::Yield => {
                Self::round_robin(&g.threads, me).expect("enabled set non-empty")
            }
            Point::Preemptible => {
                debug_assert_eq!(g.threads[me], Run::Ready, "preemptible point off a ready thread");
                let others: Vec<usize> = enabled.iter().copied().filter(|&t| t != me).collect();
                let options =
                    if g.preemptions < g.bound { 1 + others.len() } else { 1 };
                let choice = if g.pos < g.trail.len() {
                    // Replay: user code is deterministic given the
                    // schedule, so the recorded decision is in range;
                    // clamp defensively rather than corrupt the DFS.
                    g.trail[g.pos].taken.min(options.saturating_sub(1))
                } else {
                    g.trail.push(Branch { taken: 0, options });
                    0
                };
                g.pos += 1;
                if choice == 0 {
                    me
                } else {
                    g.preemptions += 1;
                    others[choice - 1]
                }
            }
        };
        if next == me && g.threads[me] == Run::Ready {
            return;
        }
        g.current = next;
        self.cv.notify_all();
        while g.current != me {
            if g.abort {
                drop(g);
                std::panic::panic_any(ModelAbort);
            }
            g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
        if g.abort {
            drop(g);
            std::panic::panic_any(ModelAbort);
        }
    }

    /// Register a new modeled thread (caller holds the token).
    fn register_thread(&self) -> usize {
        let mut g = self.lock();
        g.threads.push(Run::Ready);
        g.threads.len() - 1
    }

    /// Entry protocol of a freshly spawned modeled thread: park until
    /// the scheduler hands it the token for the first time.
    fn wait_for_token(&self, me: usize) {
        let mut g = self.lock();
        while g.current != me {
            if g.abort {
                drop(g);
                std::panic::panic_any(ModelAbort);
            }
            g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
        if g.abort {
            drop(g);
            std::panic::panic_any(ModelAbort);
        }
    }

    /// Mark the calling thread blocked on the lock at `addr`.
    fn block_on_lock(&self, me: usize, addr: usize) {
        self.lock().threads[me] = Run::BlockedLock(addr);
    }

    /// Mark the calling thread blocked on the condvar at `addr`.
    fn block_on_cv(&self, me: usize, addr: usize) {
        self.lock().threads[me] = Run::BlockedCv(addr);
    }

    /// Make every thread blocked on the lock at `addr` runnable again.
    fn wake_lock_waiters(&self, addr: usize) {
        let mut g = self.lock();
        for r in g.threads.iter_mut() {
            if *r == Run::BlockedLock(addr) {
                *r = Run::Ready;
            }
        }
    }

    /// Wake condvar waiters at `addr` (`all`, or the lowest id).
    fn wake_cv_waiters(&self, addr: usize, all: bool) {
        let mut g = self.lock();
        for r in g.threads.iter_mut() {
            if *r == Run::BlockedCv(addr) {
                *r = Run::Ready;
                if !all {
                    break;
                }
            }
        }
    }

    /// Model-level join: block until thread `target` finishes.
    fn model_join(&self, me: usize, target: usize) {
        loop {
            {
                let mut g = self.lock();
                if g.threads[target] == Run::Finished {
                    return;
                }
                g.threads[me] = Run::BlockedJoin(target);
            }
            self.schedule(me, Point::Blocked);
        }
    }

    /// Thread-exit protocol: record the outcome, wake joiners, and
    /// hand the token onward (or detect termination/deadlock).
    fn finish(&self, me: usize, panic_desc: Option<String>) {
        let mut g = self.lock();
        g.threads[me] = Run::Finished;
        if let Some(d) = panic_desc {
            g.abort = true;
            g.failure.get_or_insert(Failure::Panic(d));
        }
        for r in g.threads.iter_mut() {
            if *r == Run::BlockedJoin(me) {
                *r = Run::Ready;
            }
        }
        if !g.abort {
            if let Some(next) = Self::round_robin(&g.threads, me) {
                g.current = next;
            } else if g.threads.iter().any(|r| *r != Run::Finished) {
                let desc = g
                    .threads
                    .iter()
                    .enumerate()
                    .filter(|(_, r)| **r != Run::Finished)
                    .map(|(t, r)| format!("thread {t}: {r:?}"))
                    .collect::<Vec<_>>()
                    .join(", ");
                g.abort = true;
                g.failure.get_or_insert(Failure::Deadlock(desc));
            }
        }
        self.cv.notify_all();
    }

    /// Abort the schedule from outside a modeled thread (scope
    /// teardown on unwind): wake everything so OS threads can exit.
    fn abort_now(&self) {
        let mut g = self.lock();
        g.abort = true;
        self.cv.notify_all();
    }

    /// Park the coordinating (off-model) thread until every modeled
    /// thread has finished.
    fn wait_all_finished(&self) {
        let mut g = self.lock();
        while g.threads.iter().any(|r| *r != Run::Finished) {
            g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }
}

fn env_usize(name: &str, default: usize) -> usize {
    match std::env::var(name) {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n > 0 => n,
            _ => {
                eprintln!("warning: unusable {name}={v:?}; using {default}");
                default
            }
        },
        Err(_) => default,
    }
}

/// Exploration configuration. [`Model::default`] reads the
/// `SANDSLASH_MODEL_PREEMPTIONS` / `SANDSLASH_MODEL_ITERS` knobs;
/// tests with large state spaces pin explicit smaller values.
#[derive(Clone, Copy, Debug)]
pub struct Model {
    /// Involuntary-switch budget per schedule.
    pub preemption_bound: usize,
    /// Cap on explored schedules (deterministic prefix when hit).
    pub max_schedules: usize,
}

impl Default for Model {
    fn default() -> Self {
        Model {
            preemption_bound: env_usize(
                "SANDSLASH_MODEL_PREEMPTIONS",
                DEFAULT_PREEMPTION_BOUND,
            ),
            max_schedules: env_usize("SANDSLASH_MODEL_ITERS", DEFAULT_MAX_SCHEDULES),
        }
    }
}

/// Advance the trail to the next unexplored schedule (depth-first).
/// Returns `false` when the space (under the preemption bound) is
/// exhausted.
fn advance(trail: &mut Vec<Branch>) -> bool {
    while let Some(last) = trail.last_mut() {
        if last.taken + 1 < last.options {
            last.taken += 1;
            return true;
        }
        trail.pop();
    }
    false
}

impl Model {
    /// Run `f` under every explored schedule. Panics (with the
    /// schedule count) on the first failing interleaving: a panic in
    /// any modeled thread, a deadlock, or the step backstop.
    pub fn check<F: FnMut()>(&self, mut f: F) {
        assert!(
            ctx().is_none(),
            "model::check does not nest: already inside a modeled thread"
        );
        let mut trail: Vec<Branch> = Vec::new();
        let mut schedules = 0usize;
        loop {
            schedules += 1;
            let exec = Arc::new(Exec::new(std::mem::take(&mut trail), self.preemption_bound));
            // The calling thread doubles as modeled thread 0 and holds
            // the token from the start.
            let outcome = {
                let _bound = bind(exec.clone(), 0);
                catch_unwind(AssertUnwindSafe(&mut f))
            };
            let desc = match &outcome {
                Ok(()) => None,
                Err(p) if p.is::<ModelAbort>() => None,
                Err(p) => Some(render_payload(p.as_ref())),
            };
            exec.finish(0, desc);
            exec.wait_all_finished();
            let mut g = exec.lock();
            if let Some(fail) = g.failure.take() {
                let taken: Vec<usize> = g.trail.iter().map(|b| b.taken).collect();
                drop(g);
                panic!(
                    "model check failed on schedule {schedules} \
                     (preemption bound {}): {fail}\n  branch trail: {taken:?}",
                    self.preemption_bound
                );
            }
            trail = std::mem::take(&mut g.trail);
            drop(g);
            if schedules >= self.max_schedules || !advance(&mut trail) {
                break;
            }
        }
    }
}

/// Explore `f` with the default [`Model`] — the loom `model()`
/// equivalent used by the `rust/tests/loom/` suite.
pub fn check<F: FnMut()>(f: F) {
    Model::default().check(f);
}

/// Modeled `std::sync` types: mutual exclusion and condition
/// variables whose blocking is visible to the exploration scheduler.
pub mod sync {
    use super::{ctx, Point};
    use std::cell::UnsafeCell;
    use std::ops::{Deref, DerefMut};
    use std::sync::LockResult;

    /// Modeled mutex: same lock/guard surface as [`std::sync::Mutex`]
    /// (never poisoned — a modeled panic aborts the whole schedule).
    pub struct Mutex<T> {
        locked: UnsafeCell<bool>,
        data: UnsafeCell<T>,
    }

    // SAFETY: all access to the cells happens either while the owning
    // modeled thread holds the scheduler token (exactly one modeled
    // thread runs at a time, with a happens-before edge through the
    // token hand-off mutex), or off-model on a single thread.
    unsafe impl<T: Send> Send for Mutex<T> {}
    // SAFETY: as above — token serialization substitutes for the lock
    // a `std::sync::Mutex` would take.
    unsafe impl<T: Send> Sync for Mutex<T> {}

    impl<T> Mutex<T> {
        /// New unlocked mutex holding `t`.
        pub const fn new(t: T) -> Self {
            Mutex { locked: UnsafeCell::new(false), data: UnsafeCell::new(t) }
        }

        fn addr(&self) -> usize {
            self.locked.get() as usize
        }

        /// Acquire, blocking the modeled thread while contended.
        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            if let Some((exec, me)) = ctx() {
                exec.schedule(me, Point::Preemptible);
                loop {
                    // SAFETY: this thread holds the scheduler token, so
                    // no other modeled thread touches the cell.
                    let locked = unsafe { &mut *self.locked.get() };
                    if !*locked {
                        *locked = true;
                        break;
                    }
                    exec.block_on_lock(me, self.addr());
                    exec.schedule(me, Point::Blocked);
                }
            } else {
                // SAFETY: off-model there is no concurrency; plain
                // single-threaded storage.
                let locked = unsafe { &mut *self.locked.get() };
                assert!(!*locked, "off-model deadlock: model Mutex re-locked");
                *locked = true;
            }
            Ok(MutexGuard { lock: self })
        }
    }

    impl<T: std::fmt::Debug> std::fmt::Debug for Mutex<T> {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.debug_struct("Mutex").finish_non_exhaustive()
        }
    }

    /// RAII guard for [`Mutex`]; releases and wakes waiters on drop.
    pub struct MutexGuard<'a, T> {
        lock: &'a Mutex<T>,
    }

    impl<T> Deref for MutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            // SAFETY: the guard holds the modeled lock, and only the
            // token-holding thread can be executing this.
            unsafe { &*self.lock.data.get() }
        }
    }

    impl<T> DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            // SAFETY: as in `deref` — modeled lock held, token-serial.
            unsafe { &mut *self.lock.data.get() }
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            // SAFETY: releasing the flag this guard set; token-serial.
            unsafe {
                *self.lock.locked.get() = false;
            }
            if let Some((exec, me)) = ctx() {
                exec.wake_lock_waiters(self.lock.addr());
                // A release is a visible operation other threads can
                // race with (no-op during unwind, see `schedule`).
                exec.schedule(me, Point::Preemptible);
            }
        }
    }

    /// Modeled condition variable (no spurious wakeups; every migrated
    /// wait site is a while-loop, so this only loses coverage).
    pub struct Condvar {
        /// Occupies one byte so distinct condvars have distinct
        /// addresses to key waiter lists on.
        _addr: UnsafeCell<u8>,
    }

    // SAFETY: the cell is never read or written — it exists only for
    // its address — so sharing across threads is trivially sound.
    unsafe impl Send for Condvar {}
    // SAFETY: as above; the address is the only thing used.
    unsafe impl Sync for Condvar {}

    impl Condvar {
        /// New condvar with no waiters.
        pub const fn new() -> Self {
            Condvar { _addr: UnsafeCell::new(0) }
        }

        fn addr(&self) -> usize {
            self._addr.get() as usize
        }

        /// Atomically release `guard` and wait for a notification,
        /// re-acquiring before returning. Registration happens before
        /// the mutex is released, so there is no lost-wakeup window.
        pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
            let (exec, me) = ctx().expect("model Condvar::wait outside a model run");
            let lock = guard.lock;
            exec.block_on_cv(me, self.addr());
            // Manual release: the guard's Drop must not run (it would
            // schedule and double-release).
            // SAFETY: this thread holds the modeled lock and the token.
            unsafe {
                *lock.locked.get() = false;
            }
            exec.wake_lock_waiters(lock.addr());
            std::mem::forget(guard);
            exec.schedule(me, Point::Blocked);
            // Notified: re-acquire.
            loop {
                // SAFETY: token-serial access, as in `Mutex::lock`.
                let locked = unsafe { &mut *lock.locked.get() };
                if !*locked {
                    *locked = true;
                    break;
                }
                exec.block_on_lock(me, lock.addr());
                exec.schedule(me, Point::Blocked);
            }
            Ok(MutexGuard { lock })
        }

        /// Wake every waiter.
        pub fn notify_all(&self) {
            if let Some((exec, me)) = ctx() {
                exec.wake_cv_waiters(self.addr(), true);
                exec.schedule(me, Point::Preemptible);
            }
        }

        /// Wake one waiter (lowest thread id — deterministic).
        pub fn notify_one(&self) {
            if let Some((exec, me)) = ctx() {
                exec.wake_cv_waiters(self.addr(), false);
                exec.schedule(me, Point::Preemptible);
            }
        }
    }

    impl Default for Condvar {
        fn default() -> Self {
            Self::new()
        }
    }

    impl std::fmt::Debug for Condvar {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.write_str("Condvar")
        }
    }
}

/// Modeled `std::sync::atomic` types: every access is a preemptible
/// schedule point, so the exploration interleaves threads at exactly
/// the operations the real types would race on.
pub mod atomic {
    use super::{ctx, Point};
    use std::cell::UnsafeCell;
    pub use std::sync::atomic::Ordering;

    macro_rules! model_atomic_common {
        ($name:ident, $ty:ty) => {
            /// Modeled atomic: plain storage serialized by the
            /// exploration scheduler's token (sequentially consistent
            /// regardless of the `Ordering` argument — see the module
            /// docs on what the model does not cover).
            pub struct $name {
                v: UnsafeCell<$ty>,
            }

            // SAFETY: the cell is only accessed while the owning
            // modeled thread holds the scheduler token (one modeled
            // thread at a time, happens-before through the hand-off),
            // or off-model on a single thread.
            unsafe impl Sync for $name {}

            impl $name {
                /// New atomic holding `v`.
                pub const fn new(v: $ty) -> Self {
                    Self { v: UnsafeCell::new(v) }
                }

                fn op<R>(&self, f: impl FnOnce(&mut $ty) -> R) -> R {
                    if let Some((exec, me)) = ctx() {
                        exec.schedule(me, Point::Preemptible);
                    }
                    // SAFETY: token-exclusive (or single-threaded
                    // off-model) — see the `Sync` impl above.
                    f(unsafe { &mut *self.v.get() })
                }

                /// Load the value (`Ordering` accepted for API parity).
                pub fn load(&self, _: Ordering) -> $ty {
                    self.op(|v| *v)
                }

                /// Store `val`.
                pub fn store(&self, val: $ty, _: Ordering) {
                    self.op(|v| *v = val);
                }

                /// Replace the value, returning the previous one.
                pub fn swap(&self, val: $ty, _: Ordering) -> $ty {
                    self.op(|v| std::mem::replace(v, val))
                }

                /// Compare-and-exchange, as [`std::sync::atomic`].
                pub fn compare_exchange(
                    &self,
                    current: $ty,
                    new: $ty,
                    _: Ordering,
                    _: Ordering,
                ) -> Result<$ty, $ty> {
                    self.op(|v| {
                        if *v == current {
                            *v = new;
                            Ok(current)
                        } else {
                            Err(*v)
                        }
                    })
                }
            }

            impl std::fmt::Debug for $name {
                fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                    f.write_str(stringify!($name))
                }
            }

            impl Default for $name {
                fn default() -> Self {
                    Self::new(<$ty as Default>::default())
                }
            }
        };
    }

    macro_rules! model_atomic_int {
        ($name:ident, $ty:ty) => {
            model_atomic_common!($name, $ty);

            impl $name {
                /// Wrapping add, returning the previous value.
                pub fn fetch_add(&self, val: $ty, _: Ordering) -> $ty {
                    self.op(|v| {
                        let prev = *v;
                        *v = prev.wrapping_add(val);
                        prev
                    })
                }

                /// Wrapping subtract, returning the previous value.
                pub fn fetch_sub(&self, val: $ty, _: Ordering) -> $ty {
                    self.op(|v| {
                        let prev = *v;
                        *v = prev.wrapping_sub(val);
                        prev
                    })
                }

                /// Bitwise-or, returning the previous value.
                pub fn fetch_or(&self, val: $ty, _: Ordering) -> $ty {
                    self.op(|v| {
                        let prev = *v;
                        *v = prev | val;
                        prev
                    })
                }

                /// Bitwise-and, returning the previous value.
                pub fn fetch_and(&self, val: $ty, _: Ordering) -> $ty {
                    self.op(|v| {
                        let prev = *v;
                        *v = prev & val;
                        prev
                    })
                }

                /// Maximum, returning the previous value.
                pub fn fetch_max(&self, val: $ty, _: Ordering) -> $ty {
                    self.op(|v| {
                        let prev = *v;
                        *v = prev.max(val);
                        prev
                    })
                }
            }
        };
    }

    model_atomic_common!(AtomicBool, bool);

    impl AtomicBool {
        /// Bitwise-or, returning the previous value.
        pub fn fetch_or(&self, val: bool, _: Ordering) -> bool {
            self.op(|v| {
                let prev = *v;
                *v = prev | val;
                prev
            })
        }
    }

    model_atomic_int!(AtomicU8, u8);
    model_atomic_int!(AtomicU64, u64);
    model_atomic_int!(AtomicUsize, usize);
}

/// Modeled `std::thread` routines: spawn/join and scoped threads whose
/// blocking and hand-offs are schedule points.
pub mod thread {
    use super::{bind, catch_unwind, ctx, render_payload, resume_unwind, AssertUnwindSafe};
    use super::{Arc, ModelAbort, OsMutex, Point};
    use std::marker::PhantomData;
    use std::time::Duration;

    /// Handle to a modeled (non-scoped) thread.
    pub struct JoinHandle<T> {
        tid: usize,
        inner: std::thread::JoinHandle<T>,
    }

    impl<T> JoinHandle<T> {
        /// Model-join (the blocking is visible to the exploration),
        /// then reap the OS thread.
        pub fn join(self) -> std::thread::Result<T> {
            let (exec, me) = ctx().expect("model join outside a model run");
            exec.model_join(me, self.tid);
            self.inner.join()
        }
    }

    /// Spawn a modeled thread. The closure runs only when the
    /// exploration scheduler hands it the token.
    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        let (exec, me) = ctx().expect("model spawn outside a model run");
        let tid = exec.register_thread();
        let exec2 = exec.clone();
        let inner = std::thread::Builder::new()
            .name(format!("model-{tid}"))
            .spawn(move || {
                let _bound = bind(exec2.clone(), tid);
                let r = catch_unwind(AssertUnwindSafe(|| {
                    exec2.wait_for_token(tid);
                    f()
                }));
                match r {
                    Ok(v) => {
                        exec2.finish(tid, None);
                        v
                    }
                    Err(p) => {
                        let desc = if p.is::<ModelAbort>() {
                            None
                        } else {
                            Some(render_payload(p.as_ref()))
                        };
                        exec2.finish(tid, desc);
                        resume_unwind(p)
                    }
                }
            })
            .expect("model thread spawn");
        // The spawn itself is a race: the child may run before the
        // parent's next step.
        exec.schedule(me, Point::Preemptible);
        JoinHandle { tid, inner }
    }

    /// Yield the token round-robin — a voluntary, non-branching switch
    /// (keeps modeled spin loops fair and finite).
    pub fn yield_now() {
        if let Some((exec, me)) = ctx() {
            exec.schedule(me, Point::Yield);
        }
    }

    /// Modeled as a plain [`yield_now`]: exploration has no clock.
    pub fn sleep(_: Duration) {
        yield_now();
    }

    /// Scoped-thread environment, mirroring [`std::thread::scope`].
    ///
    /// Implemented without `std::thread::scope` (whose implicit
    /// OS-level join at scope exit would block while holding the
    /// token): spawned closures are lifetime-erased, every spawned
    /// thread is model-joined before `scope` returns — on the panic
    /// path too — and only then are the OS threads reaped, which is
    /// what makes the erasure sound.
    pub struct Scope<'scope, 'env: 'scope> {
        exec: Arc<super::Exec>,
        /// `(tid, OS handle)` per spawned thread.
        spawned: OsMutex<Vec<(usize, std::thread::JoinHandle<()>)>>,
        /// Invariance over both lifetimes, as in `std::thread::Scope`.
        _marker: PhantomData<&'scope mut &'env mut ()>,
    }

    /// Handle to a modeled scoped thread.
    pub struct ScopedJoinHandle<'scope, T> {
        tid: usize,
        result: Arc<OsMutex<Option<std::thread::Result<T>>>>,
        _marker: PhantomData<&'scope ()>,
    }

    impl<T> ScopedJoinHandle<'_, T> {
        /// Model-join and take the closure's result (or its panic
        /// payload, matching [`std::thread::ScopedJoinHandle::join`]).
        pub fn join(self) -> std::thread::Result<T> {
            let (exec, me) = ctx().expect("model scoped join outside a model run");
            exec.model_join(me, self.tid);
            self.result
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .take()
                .expect("scoped thread finished without storing a result")
        }
    }

    impl<'scope, 'env> Scope<'scope, 'env> {
        /// Spawn a modeled thread borrowing from the enclosing scope.
        pub fn spawn<F, T>(&'scope self, f: F) -> ScopedJoinHandle<'scope, T>
        where
            F: FnOnce() -> T + Send + 'scope,
            T: Send + 'scope,
        {
            let (exec, me) = ctx().expect("model scoped spawn outside a model run");
            let tid = exec.register_thread();
            let result: Arc<OsMutex<Option<std::thread::Result<T>>>> =
                Arc::new(OsMutex::new(None));
            let exec2 = exec.clone();
            let slot = result.clone();
            let body: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
                let _bound = bind(exec2.clone(), tid);
                let r = catch_unwind(AssertUnwindSafe(|| {
                    exec2.wait_for_token(tid);
                    f()
                }));
                let desc = match &r {
                    Ok(_) => None,
                    Err(p) if p.is::<ModelAbort>() => None,
                    Err(p) => Some(render_payload(p.as_ref())),
                };
                // Store before `finish`: once the token moves on, a
                // joiner may immediately take the slot.
                *slot.lock().unwrap_or_else(|e| e.into_inner()) = Some(r);
                exec2.finish(tid, desc);
            });
            // SAFETY: the closure borrows only for 'scope; `scope`
            // model-joins then OS-joins every spawned thread before it
            // returns (including on unwind), so the thread never runs
            // after 'scope data is gone. This is the crossbeam/std
            // scoped-thread argument, enforced by `run_scope` below.
            let body: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(body) };
            let inner = std::thread::Builder::new()
                .name(format!("model-{tid}"))
                .spawn(body)
                .expect("model thread spawn");
            self.spawned.lock().unwrap_or_else(|e| e.into_inner()).push((tid, inner));
            exec.schedule(me, Point::Preemptible);
            ScopedJoinHandle { tid, result, _marker: PhantomData }
        }
    }

    /// Modeled [`std::thread::scope`]: every thread spawned on the
    /// scope is joined (model- and OS-level) before this returns.
    pub fn scope<'env, F, T>(f: F) -> T
    where
        F: for<'scope> FnOnce(&'scope Scope<'scope, 'env>) -> T,
    {
        let (exec, me) = ctx().expect("model scope outside a model run");
        let scope = Scope {
            exec: exec.clone(),
            spawned: OsMutex::new(Vec::new()),
            _marker: PhantomData,
        };
        let r = catch_unwind(AssertUnwindSafe(|| f(&scope)));
        let handles = std::mem::take(
            &mut *scope.spawned.lock().unwrap_or_else(|e| e.into_inner()),
        );
        if r.is_err() {
            // Unwinding out of the scope body: wake every thread so
            // the OS joins below cannot hang, then re-raise.
            scope.exec.abort_now();
        } else {
            // Normal exit: any thread not explicitly joined gets the
            // implicit scope-exit join, modeled so it cannot deadlock.
            for (tid, _) in &handles {
                exec.model_join(me, *tid);
            }
        }
        for (_, h) in handles {
            // Reaping finished threads — this is what licenses the
            // lifetime erasure in `spawn`.
            let _ = h.join();
        }
        match r {
            Ok(v) => v,
            Err(p) => resume_unwind(p),
        }
    }
}

// Always compiled (not just under `--cfg loom`) so the checker's own
// unit tests run in tier-1 and keep it honest even when the loom CI
// leg is not exercised.
#[cfg(test)]
mod tests {
    use super::atomic::{AtomicUsize, Ordering};
    use super::sync::{Condvar, Mutex};
    use super::{check, thread, Model};
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::Arc;

    #[test]
    fn finds_the_lost_update_in_a_naive_counter() {
        // Non-atomic read-modify-write: some interleaving must lose an
        // update, and the checker must find it (this is the smoke test
        // that exploration actually explores).
        let r = catch_unwind(AssertUnwindSafe(|| {
            Model { preemption_bound: 2, max_schedules: 1000 }.check(|| {
                let c = Arc::new(AtomicUsize::new(0));
                let handles: Vec<_> = (0..2)
                    .map(|_| {
                        let c = c.clone();
                        thread::spawn(move || {
                            let v = c.load(Ordering::SeqCst);
                            c.store(v + 1, Ordering::SeqCst);
                        })
                    })
                    .collect();
                for h in handles {
                    h.join().unwrap();
                }
                assert_eq!(c.load(Ordering::SeqCst), 2, "lost update");
            });
        }));
        let msg = format!("{:?}", r.expect_err("the race must be found"));
        assert!(msg.contains("lost update"), "wrong failure: {msg}");
    }

    #[test]
    fn fetch_add_counter_survives_every_schedule() {
        check(|| {
            let c = Arc::new(AtomicUsize::new(0));
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let c = c.clone();
                    thread::spawn(move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            assert_eq!(c.load(Ordering::SeqCst), 2);
        });
    }

    #[test]
    fn mutex_provides_mutual_exclusion() {
        check(|| {
            let m = Arc::new(Mutex::new((0usize, 0usize)));
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let m = m.clone();
                    thread::spawn(move || {
                        let mut g = m.lock().unwrap();
                        // Two fields updated non-atomically under the
                        // lock: any interleaving inside would desync.
                        g.0 += 1;
                        thread::yield_now();
                        g.1 += 1;
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            let g = m.lock().unwrap();
            assert_eq!((g.0, g.1), (2, 2));
        });
    }

    #[test]
    fn condvar_handoff_has_no_lost_wakeup() {
        check(|| {
            let pair = Arc::new((Mutex::new(false), Condvar::new()));
            let p2 = pair.clone();
            let h = thread::spawn(move || {
                let (m, cv) = &*p2;
                let mut ready = m.lock().unwrap();
                while !*ready {
                    ready = cv.wait(ready).unwrap();
                }
            });
            let (m, cv) = &*pair;
            *m.lock().unwrap() = true;
            cv.notify_all();
            h.join().unwrap();
        });
    }

    #[test]
    fn deadlock_is_detected_and_reported() {
        let r = catch_unwind(AssertUnwindSafe(|| {
            Model { preemption_bound: 2, max_schedules: 1000 }.check(|| {
                let a = Arc::new(Mutex::new(0u8));
                let b = Arc::new(Mutex::new(0u8));
                let (a2, b2) = (a.clone(), b.clone());
                let h = thread::spawn(move || {
                    let _ga = a2.lock().unwrap();
                    thread::yield_now();
                    let _gb = b2.lock().unwrap();
                });
                {
                    let _gb = b.lock().unwrap();
                    thread::yield_now();
                    let _ga = a.lock().unwrap();
                }
                h.join().unwrap();
            });
        }));
        let msg = format!("{:?}", r.expect_err("the lock cycle must be found"));
        assert!(msg.contains("deadlock"), "wrong failure: {msg}");
    }

    #[test]
    fn scoped_threads_borrow_and_join() {
        check(|| {
            let data = [1usize, 2, 3];
            let total = thread::scope(|s| {
                let hs: Vec<_> = data
                    .iter()
                    .map(|&x| s.spawn(move || x * 10))
                    .collect();
                hs.into_iter().map(|h| h.join().unwrap()).sum::<usize>()
            });
            assert_eq!(total, 60);
        });
    }

    #[test]
    fn exploration_is_bounded_and_terminates() {
        // A workload with many schedule points under a tiny schedule
        // cap must still return (deterministic truncated prefix).
        Model { preemption_bound: 1, max_schedules: 8 }.check(|| {
            let c = Arc::new(AtomicUsize::new(0));
            let hs: Vec<_> = (0..3)
                .map(|_| {
                    let c = c.clone();
                    thread::spawn(move || {
                        for _ in 0..4 {
                            c.fetch_add(1, Ordering::SeqCst);
                            thread::yield_now();
                        }
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
            assert_eq!(c.load(Ordering::SeqCst), 12);
        });
    }
}
