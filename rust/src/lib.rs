//! # Sandslash
//!
//! A two-level framework for efficient graph pattern mining (GPM),
//! reproducing Chen et al., *"Sandslash: A Two-Level Framework for
//! Efficient Graph Pattern Mining"* (2020) as a three-layer
//! Rust + JAX/Pallas system.
//!
//! ## The two-level API
//!
//! The paper's thesis is that GPM systems force a false choice between
//! productivity and performance, and that a *two-level* design removes
//! it:
//!
//! * **High level** — a problem is *specified*, not programmed: a
//!   [`engine::spec::ProblemSpec`] names the induced-ness, the
//!   listing/counting mode, and the (explicit or implicit) patterns.
//!   [`apps::solve`] analyzes the spec exactly as the paper's §4.3
//!   decision table and picks the search strategy (DFS over a
//!   [`pattern::MatchingPlan`], pattern-oblivious ESU, BFS, or the
//!   sub-pattern-tree FSM engine) plus the high-level optimizations of
//!   Table 3: symmetry breaking (SB), DAG orientation, matching orders
//!   (MO), degree filtering (DF), and the MEC/MNC memoizations —
//!   all selected through [`engine::OptFlags`].
//! * **Low level** — expert users (and the Lo presets) refine the same
//!   search through the [`engine::hooks::LowLevelApi`] trait (the
//!   paper's Listing 1: `toExtend`/`toAdd`/pattern classification /
//!   local counting) and the low-level optimizations: formula-based
//!   local counting (LC, [`apps::motif`]) and search on shrinking
//!   local graphs (LG, [`engine::local_graph`]) — without rewriting
//!   the enumeration logic.
//!
//! Both levels bottom out in one tuned set-kernel layer
//! ([`graph::setops`]), so there is exactly one intersection
//! implementation to optimize, differential-test, and (eventually)
//! offload to the Pallas runtime.
//!
//! ## Layer map
//!
//! * [`graph`] — CSR graphs, generators, orientation (the input substrate)
//! * [`pattern`] — pattern analysis: isomorphism, symmetry breaking,
//!   matching orders, canonical codes
//! * [`engine`] — the mining engines and the two-level API
//! * [`exec`] — the work-stealing, locality-sharded scheduler the
//!   engines fan their root tasks through (cursor oracle retained)
//! * [`apps`] — the five paper applications + hand-optimized baselines
//! * [`service`] — the resident multi-tenant query service: load-once
//!   graphs, line-JSON protocol, admission control, canonical-pattern
//!   result cache (`sandslash serve`)
//! * [`obs`] — observability: scoped per-query traces, the unified
//!   metrics registry behind the `stats` op, and the post-mortem
//!   flight recorder
//! * [`runtime`] — PJRT loader for the AOT-compiled Pallas counting path
//! * [`coordinator`] — dataset registry and experiment campaign driver
//! * [`util`] — substrates (RNG, bitset, pool, CLI, config, bench)
//!
//! `ARCHITECTURE.md` at the repo root walks the life of a query through
//! these layers with per-file pointers; `EXPERIMENTS.md` records every
//! measured constant baked into the source.

// Hot-path engine functions thread explicit state (graph, plan, config,
// hooks, thread state) instead of bundling context structs, and iterate
// buffers by index so the borrow checker permits recursion while a
// candidate set is checked out — both intentional.
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
// Docs are enforced: `cargo doc --no-deps` runs with `-D warnings` in
// CI, so every public item needs at least a one-line doc comment.
#![warn(missing_docs)]

pub mod graph;
pub mod pattern;
pub mod engine;
pub mod exec;
pub mod apps;
pub mod obs;
pub mod service;
pub mod runtime;
pub mod coordinator;
pub mod util;
