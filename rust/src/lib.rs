//! # Sandslash
//!
//! A two-level framework for efficient graph pattern mining (GPM),
//! reproducing Chen et al., *"Sandslash: A Two-Level Framework for
//! Efficient Graph Pattern Mining"* (2020) as a three-layer
//! Rust + JAX/Pallas system.
//!
//! * [`graph`] — CSR graphs, generators, orientation (the input substrate)
//! * [`pattern`] — pattern analysis: isomorphism, symmetry breaking,
//!   matching orders, canonical codes
//! * [`engine`] — the mining engines and the two-level API
//! * [`apps`] — the five paper applications + hand-optimized baselines
//! * [`runtime`] — PJRT loader for the AOT-compiled Pallas counting path
//! * [`coordinator`] — dataset registry and experiment campaign driver
//! * [`util`] — substrates (RNG, bitset, pool, CLI, config, bench)

// Hot-path engine functions thread explicit state (graph, plan, config,
// hooks, thread state) instead of bundling context structs, and iterate
// buffers by index so the borrow checker permits recursion while a
// candidate set is checked out — both intentional.
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]

pub mod graph;
pub mod pattern;
pub mod engine;
pub mod apps;
pub mod runtime;
pub mod coordinator;
pub mod util;
