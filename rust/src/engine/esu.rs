//! Pattern-oblivious vertex-induced enumeration (the paper's §4.1
//! "pattern-oblivious" search), implemented as parallel ESU (Wernicke's
//! algorithm): every connected vertex-induced k-subgraph is enumerated
//! exactly once, so no automorphism checks are needed at the leaves.
//!
//! This drives k-MC (multi-pattern, implicit classification): leaves are
//! classified by their MEC connectivity codes through a precomputed
//! code → motif-id table — the paper's CP optimization with MEC, no
//! isomorphism tests at runtime.

use crate::graph::{CsrGraph, VertexId};
use crate::pattern::{canonical_code, library};
use crate::util::metrics::SearchStats;
use crate::util::pool::parallel_reduce;

use super::embedding::{pack_codes, pattern_from_packed};
use super::hooks::LowLevelApi;
use super::opts::MinerConfig;

/// Motif classification table: packed MEC codes -> motif index in
/// `library::all_motifs(k)` order.
pub struct MotifTable {
    /// Motif size.
    pub k: usize,
    table: Vec<u16>,
    /// Number of isomorphism classes (`all_motifs(k).len()`).
    pub num_motifs: usize,
}

/// Sentinel for packed codes that are not connected k-subgraphs.
pub const UNCLASSIFIED: u16 = u16::MAX;

impl MotifTable {
    /// Build the classification table for `k` in 3..=5.
    pub fn new(k: usize) -> Self {
        assert!((3..=5).contains(&k));
        let motifs = library::all_motifs(k);
        let codes: Vec<_> = motifs.iter().map(canonical_code).collect();
        let bits = k * (k - 1) / 2;
        let mut table = vec![UNCLASSIFIED; 1 << bits];
        for key in 0..(1u64 << bits) {
            let p = pattern_from_packed(k, key);
            if !p.is_connected() {
                continue;
            }
            let c = canonical_code(&p);
            if let Some(idx) = codes.iter().position(|x| *x == c) {
                table[key as usize] = idx as u16;
            }
        }
        Self { k, table, num_motifs: motifs.len() }
    }

    #[inline]
    /// Motif index for packed MEC codes (or [`UNCLASSIFIED`]).
    pub fn classify(&self, packed: u64) -> u16 {
        self.table[packed as usize]
    }
}

struct EsuState<A> {
    acc: A,
    stats: SearchStats,
    emb: Vec<VertexId>,
    codes: Vec<u32>,
    /// Extension candidates, stacked per level: (vertex, level it joined).
    ext: Vec<VertexId>,
    /// Per-level start offsets into `ext`.
    ext_marks: Vec<usize>,
    /// visited[u] = true if u is in the embedding or its neighborhood
    /// (the "exclusive neighborhood" test of ESU).
    visited: Vec<bool>,
    touched: Vec<VertexId>,
    /// MNC connectivity map (used when opts.mnc).
    map: super::mnc::ConnectivityMap,
}

/// Enumerate all connected vertex-induced k-subgraphs exactly once.
/// `leaf(acc, verts, packed_codes)` receives the embedding and its packed
/// MEC codes (structure is fully recoverable from them — Fig. 13).
pub fn esu_mine<A: Send, H: LowLevelApi>(
    g: &CsrGraph,
    k: usize,
    cfg: &MinerConfig,
    hooks: &H,
    init: impl Fn() -> A + Sync,
    leaf: impl Fn(&mut A, &[VertexId], u64) + Sync,
    mut merge: impl FnMut(A, A) -> A,
) -> (A, SearchStats) {
    assert!(k >= 2);
    let n = g.num_vertices();
    let result = parallel_reduce(
        n,
        cfg.threads,
        cfg.chunk,
        || EsuState {
            acc: init(),
            stats: SearchStats::default(),
            emb: Vec::with_capacity(k),
            codes: Vec::with_capacity(k),
            ext: Vec::new(),
            ext_marks: Vec::new(),
            visited: vec![false; n],
            touched: Vec::new(),
            map: super::mnc::ConnectivityMap::with_capacity(1024),
        },
        |st, root| {
            let root = root as VertexId;
            st.emb.clear();
            st.codes.clear();
            st.ext.clear();
            st.ext_marks.clear();
            st.emb.push(root);
            st.codes.push(0);
            if cfg.opts.stats {
                st.stats.enumerated += 1;
            }
            // mark root + its neighborhood; seed ext with neighbors > root
            st.visited[root as usize] = true;
            st.touched.push(root);
            let base = st.ext.len();
            for &u in g.neighbors(root) {
                st.visited[u as usize] = true;
                st.touched.push(u);
                if u > root {
                    st.ext.push(u);
                }
            }
            st.ext_marks.push(base);
            if cfg.opts.mnc {
                for &u in g.neighbors(root) {
                    st.map.or_insert(u, 1);
                }
            }
            esu_extend(g, k, cfg, hooks, st, &leaf);
            if cfg.opts.mnc {
                for &u in g.neighbors(root) {
                    st.map.and_remove(u, 1);
                }
            }
            // reset visited
            for &u in &st.touched {
                st.visited[u as usize] = false;
            }
            st.touched.clear();
        },
        |a, b| {
            let mut stats = a.stats;
            stats.merge(&b.stats);
            EsuState {
                acc: merge(a.acc, b.acc),
                stats,
                emb: a.emb,
                codes: a.codes,
                ext: a.ext,
                ext_marks: a.ext_marks,
                visited: a.visited,
                touched: a.touched,
                map: a.map,
            }
        },
    );
    (result.acc, result.stats)
}

fn esu_extend<A, H: LowLevelApi>(
    g: &CsrGraph,
    k: usize,
    cfg: &MinerConfig,
    hooks: &H,
    st: &mut EsuState<A>,
    leaf: &(impl Fn(&mut A, &[VertexId], u64) + Sync),
) {
    let level = st.emb.len();
    let ext_start = *st.ext_marks.last().unwrap();
    let ext_end = st.ext.len();
    // Iterate over a snapshot of this level's extension set; each chosen
    // w spawns a child whose extension set is the remaining candidates
    // plus w's exclusive neighbors (ESU's exactly-once guarantee).
    for wi in ext_start..ext_end {
        let w = st.ext[wi];
        if !hooks.to_add(g, &st.emb, w, level) {
            st.stats.pruned += cfg.opts.stats as u64;
            continue;
        }
        // MEC: connectivity code of w against the current embedding.
        // With MNC the code is a single map lookup (paper Fig. 5); the
        // fallback recomputes it with one has_edge probe per position.
        let code = if cfg.opts.mnc {
            st.map.get(w)
        } else {
            if cfg.opts.stats {
                st.stats.intersections += st.emb.len() as u64;
            }
            st.emb
                .iter()
                .enumerate()
                .fold(0u32, |c, (i, &u)| c | ((g.has_edge(u, w) as u32) << i))
        };
        st.emb.push(w);
        st.codes.push(code);
        if cfg.opts.stats {
            st.stats.enumerated += 1;
        }
        if st.emb.len() == k {
            if cfg.opts.stats {
                st.stats.matches += 1;
            }
            leaf(&mut st.acc, &st.emb, pack_codes(&st.codes));
            st.emb.pop();
            st.codes.pop();
            continue;
        }
        // child extension set: remaining candidates at this level
        // (after w) plus exclusive neighbors of w
        let child_base = st.ext.len();
        for u in (wi + 1)..ext_end {
            let u = st.ext[u];
            st.ext.push(u);
        }
        let root = st.emb[0];
        for &u in g.neighbors(w) {
            if u > root && !st.visited[u as usize] {
                st.ext.push(u);
            }
        }
        // mark new exclusive neighbors as visited
        for i in (child_base + (ext_end - wi - 1))..st.ext.len() {
            let u = st.ext[i];
            st.visited[u as usize] = true;
            st.touched.push(u);
        }
        st.ext_marks.push(child_base);
        let bit = 1u32 << level;
        if cfg.opts.mnc {
            for &u in g.neighbors(w) {
                st.map.or_insert(u, bit);
            }
        }
        esu_extend(g, k, cfg, hooks, st, leaf);
        if cfg.opts.mnc {
            for &u in g.neighbors(w) {
                st.map.and_remove(u, bit);
            }
        }
        // unmark and truncate
        for i in (child_base + (ext_end - wi - 1))..st.ext.len() {
            let u = st.ext[i];
            st.visited[u as usize] = false;
        }
        st.touched
            .truncate(st.touched.len() - (st.ext.len() - child_base - (ext_end - wi - 1)));
        st.ext.truncate(child_base);
        st.ext_marks.pop();
        st.emb.pop();
        st.codes.pop();
    }
}

/// Count all k-motifs: returns counts indexed like `all_motifs(k)`.
pub fn count_motifs<H: LowLevelApi>(
    g: &CsrGraph,
    k: usize,
    cfg: &MinerConfig,
    hooks: &H,
    table: &MotifTable,
) -> (Vec<u64>, SearchStats) {
    let nm = table.num_motifs;
    esu_mine(
        g,
        k,
        cfg,
        hooks,
        || vec![0u64; nm],
        |acc, _emb, packed| {
            let id = table.classify(packed);
            debug_assert_ne!(id, UNCLASSIFIED);
            acc[id as usize] += 1;
        },
        |mut a, b| {
            for (x, y) in a.iter_mut().zip(b) {
                *x += y;
            }
            a
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::hooks::NoHooks;
    use crate::engine::opts::{MinerConfig, OptFlags};
    use crate::graph::gen;

    fn cfg() -> MinerConfig {
        MinerConfig::custom(2, 8, OptFlags::hi())
    }

    #[test]
    fn motif_table_classifies_triangle_and_wedge() {
        let t = MotifTable::new(3);
        // wedge codes [0,1,01b? position2 adj to pos0 only] = packed 0b01<<1|1
        let tri_key = pack_codes(&[0, 0b1, 0b11]);
        let wedge_key = pack_codes(&[0, 0b1, 0b01]);
        assert_eq!(t.classify(tri_key), 1);
        assert_eq!(t.classify(wedge_key), 0);
    }

    #[test]
    fn k3_counts_on_complete_graph() {
        let g = gen::complete(5);
        let t = MotifTable::new(3);
        let (counts, _) = count_motifs(&g, 3, &cfg(), &NoHooks, &t);
        assert_eq!(counts[1], 10); // C(5,3) triangles
        assert_eq!(counts[0], 0); // no induced wedges
    }

    #[test]
    fn k3_counts_on_ring() {
        let g = gen::ring(10);
        let t = MotifTable::new(3);
        let (counts, _) = count_motifs(&g, 3, &cfg(), &NoHooks, &t);
        assert_eq!(counts[0], 10); // one wedge per vertex
        assert_eq!(counts[1], 0);
    }

    #[test]
    fn k4_counts_on_complete_graph() {
        let g = gen::complete(6);
        let t = MotifTable::new(4);
        let (counts, _) = count_motifs(&g, 4, &cfg(), &NoHooks, &t);
        assert_eq!(counts[5], 15); // C(6,4) 4-cliques, everything else 0
        assert_eq!(counts[..5].iter().sum::<u64>(), 0);
    }

    #[test]
    fn k4_counts_on_ring() {
        let g = gen::ring(12);
        let t = MotifTable::new(4);
        let (counts, _) = count_motifs(&g, 4, &cfg(), &NoHooks, &t);
        assert_eq!(counts[1], 12); // 4-paths
        assert_eq!(counts[3], 0); // no 4-cycles in a 12-ring
        assert_eq!(counts[0], 0); // no 3-stars (max degree 2)
    }

    #[test]
    fn total_equals_brute_force_on_random_graph() {
        let g = gen::erdos_renyi(30, 0.25, 5, &[]);
        let t = MotifTable::new(4);
        let (counts, _) = count_motifs(&g, 4, &cfg(), &NoHooks, &t);
        // brute force: all C(30,4) vertex subsets, keep connected induced
        let mut brute = vec![0u64; 6];
        let n = 30u32;
        for a in 0..n {
            for b in (a + 1)..n {
                for c in (b + 1)..n {
                    for d in (c + 1)..n {
                        let vs = [a, b, c, d];
                        let mut p = crate::pattern::Pattern::new(4);
                        for i in 0..4 {
                            for j in (i + 1)..4 {
                                if g.has_edge(vs[i], vs[j]) {
                                    p.add_edge(i, j);
                                }
                            }
                        }
                        if p.is_connected() {
                            let code = canonical_code(&p);
                            let idx = library::all_motifs(4)
                                .iter()
                                .position(|m| canonical_code(m) == code)
                                .unwrap();
                            brute[idx] += 1;
                        }
                    }
                }
            }
        }
        assert_eq!(counts, brute);
    }

    #[test]
    fn thread_counts_invariant() {
        let g = gen::rmat(8, 6, 13, &[]);
        let t = MotifTable::new(4);
        let c1 = count_motifs(
            &g,
            4,
            &MinerConfig::custom(1, usize::MAX, OptFlags::hi()),
            &NoHooks,
            &t,
        )
        .0;
        let c4 = count_motifs(
            &g,
            4,
            &MinerConfig::custom(4, 32, OptFlags::hi()),
            &NoHooks,
            &t,
        )
        .0;
        assert_eq!(c1, c4);
    }
}
