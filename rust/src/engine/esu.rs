//! Pattern-oblivious vertex-induced enumeration (the paper's §4.1
//! "pattern-oblivious" search), implemented as parallel ESU (Wernicke's
//! algorithm): every connected vertex-induced k-subgraph is enumerated
//! exactly once, so no automorphism checks are needed at the leaves.
//!
//! This drives k-MC (multi-pattern, implicit classification): leaves are
//! classified by their MEC connectivity codes through a precomputed
//! code → motif-id table — the paper's CP optimization with MEC, no
//! isomorphism tests at runtime.
//!
//! # Extension paths (PR 5)
//!
//! Candidate ("extension set") construction runs on one of two paths:
//!
//! * **Extension core** (`opts.extcore`, the default): the exclusive
//!   neighbors of each chosen vertex come from
//!   [`ExtCore::exclusive_into`] — the coverage bitmap
//!   anti-intersected against the bounded neighbor tail, word-parallel
//!   ([`crate::graph::setops::andnot_words_into`]) past the dense
//!   crossover. Level-1 candidates additionally flow through the
//!   shared [`SplitDriver`], so a starving worker can steal the
//!   untraversed suffix of a hub root's subtree
//!   ([`crate::exec::split`]) exactly as in the set-centric DFS
//!   engine.
//! * **Scalar oracle** (`opts.extcore` off or `SANDSLASH_NO_EXTCORE=1`):
//!   the seed loop, kept verbatim — per-candidate probes of a
//!   `visited[]` boolean array, whole roots only (the oracle never
//!   publishes splits). Results must be bit-identical
//!   (`rust/tests/extcore_differential.rs`).
//!
//! # The stats rule
//!
//! Every [`SearchStats`] counter describes the *search tree*, not the
//! extension machinery, so stats are invariant across the MNC and
//! extcore toggles: `enumerated`/`matches` count embeddings,
//! `pruned` counts rejected candidates, and `intersections` counts one
//! per *expanded* embedding (each embedding builds exactly one child
//! extension set — the root's level-1 seed included). The seed code
//! gated these inconsistently (the MNC-off fallback charged
//! `emb.len()` probes per candidate while the MNC path charged
//! nothing); the per-construction rule is tested by
//! `stats_counters_invariant_across_mnc_and_core` below.

use crate::exec::sched::WorkerCtx;
use crate::exec::split::{self, SplitDriver, Splittable};
use crate::graph::{CsrGraph, VertexId};
use crate::pattern::{canonical_code, library};
use crate::util::metrics::{tag, SearchStats};

use super::budget::{self, Governor, MineError, Outcome};
use super::embedding::{pack_codes, pattern_from_packed};
use super::extend::ExtCore;
use super::hooks::LowLevelApi;
use super::opts::MinerConfig;

/// Motif classification table: packed MEC codes -> motif index in
/// `library::all_motifs(k)` order.
pub struct MotifTable {
    /// Motif size.
    pub k: usize,
    table: Vec<u16>,
    /// Number of isomorphism classes (`all_motifs(k).len()`).
    pub num_motifs: usize,
}

/// Sentinel for packed codes that are not connected k-subgraphs.
pub const UNCLASSIFIED: u16 = u16::MAX;

impl MotifTable {
    /// Build the classification table for `k` in 3..=5.
    pub fn new(k: usize) -> Self {
        assert!((3..=5).contains(&k));
        let motifs = library::all_motifs(k);
        let codes: Vec<_> = motifs.iter().map(canonical_code).collect();
        let bits = k * (k - 1) / 2;
        let mut table = vec![UNCLASSIFIED; 1 << bits];
        for key in 0..(1u64 << bits) {
            let p = pattern_from_packed(k, key);
            if !p.is_connected() {
                continue;
            }
            let c = canonical_code(&p);
            if let Some(idx) = codes.iter().position(|x| *x == c) {
                table[key as usize] = idx as u16;
            }
        }
        Self { k, table, num_motifs: motifs.len() }
    }

    #[inline]
    /// Motif index for packed MEC codes (or [`UNCLASSIFIED`]).
    pub fn classify(&self, packed: u64) -> u16 {
        self.table[packed as usize]
    }
}

struct EsuState<A> {
    acc: A,
    stats: SearchStats,
    emb: Vec<VertexId>,
    codes: Vec<u32>,
    /// Extension candidates, stacked per level: (vertex, level it joined).
    ext: Vec<VertexId>,
    /// Per-level start offsets into `ext`.
    ext_marks: Vec<usize>,
    /// visited[u] = true if u is in the embedding or its neighborhood
    /// (the "exclusive neighborhood" test of ESU) — the scalar oracle's
    /// marking array; the core path keeps the same set in `core`'s
    /// coverage bitmap.
    visited: Vec<bool>,
    touched: Vec<VertexId>,
    /// MNC connectivity map (used when opts.mnc).
    map: super::mnc::ConnectivityMap,
    /// Shared extension core (used when opts.extcore).
    core: ExtCore,
}

/// Enumerate all connected vertex-induced k-subgraphs exactly once.
/// `leaf(acc, verts, packed_codes)` receives the embedding and its packed
/// MEC codes (structure is fully recoverable from them — Fig. 13).
/// Governed (PR 6): budget trips return a partial [`Outcome`], worker
/// panics return [`MineError::WorkerPanicked`].
pub fn esu_mine<A: Send, H: LowLevelApi>(
    g: &CsrGraph,
    k: usize,
    cfg: &MinerConfig,
    hooks: &H,
    init: impl Fn() -> A + Sync,
    leaf: impl Fn(&mut A, &[VertexId], u64) + Sync,
    mut merge: impl FnMut(A, A) -> A,
) -> Result<Outcome<A>, MineError> {
    assert!(k >= 2);
    let n = g.num_vertices();
    let pol = cfg.sched_policy();
    let gov = budget::governance_enabled().then(|| Governor::new(&cfg.budget));
    let use_core = cfg.opts.extcore_active();
    let engine = EsuEngine {
        g,
        k,
        cfg,
        hooks,
        leaf: &leaf,
        use_core,
        _acc: std::marker::PhantomData,
    };
    let result = split::reduce(
        n,
        &pol,
        &engine,
        gov.as_ref(),
        || EsuState {
            acc: init(),
            stats: SearchStats::default(),
            emb: Vec::with_capacity(k),
            codes: Vec::with_capacity(k),
            ext: Vec::new(),
            ext_marks: Vec::new(),
            // the scalar oracle's marking array; the core path keeps
            // the same set in its (lazily sized) coverage bitmap, so
            // don't commit n bytes per worker it would never read
            visited: if use_core { Vec::new() } else { vec![false; n] },
            touched: Vec::new(),
            map: super::mnc::ConnectivityMap::with_capacity(1024),
            core: ExtCore::new(),
        },
        |a, b| {
            let mut stats = a.stats;
            stats.merge(&b.stats);
            EsuState {
                acc: merge(a.acc, b.acc),
                stats,
                emb: a.emb,
                codes: a.codes,
                ext: a.ext,
                ext_marks: a.ext_marks,
                visited: a.visited,
                touched: a.touched,
                map: a.map,
                core: a.core,
            }
        },
    );
    match gov {
        Some(g) => g.finish(result.acc, result.stats, "esu"),
        None => Ok(Outcome::complete(result.acc, result.stats)),
    }
}

/// The ESU engine as a [`Splittable`] root task (PR 5): the level-1
/// sequence is the root's extension-set positions — the `> root` tail
/// of the root's neighbor list, a pure function of (graph, root) — so
/// a replayed split lands on exactly the candidates its publisher was
/// iterating. Only the extension-core path publishes; the scalar
/// oracle runs whole roots.
struct EsuEngine<'e, A, H, L> {
    g: &'e CsrGraph,
    k: usize,
    cfg: &'e MinerConfig,
    hooks: &'e H,
    leaf: &'e L,
    use_core: bool,
    _acc: std::marker::PhantomData<fn() -> A>,
}

impl<A, H, L> Splittable for EsuEngine<'_, A, H, L>
where
    A: Send,
    H: LowLevelApi,
    L: Fn(&mut A, &[VertexId], u64) + Sync,
{
    type Acc = EsuState<A>;

    fn mine_root(
        &self,
        st: &mut EsuState<A>,
        ctx: &WorkerCtx<'_>,
        root: usize,
        window: Option<(usize, usize)>,
    ) {
        tag::with_engine(tag::Engine::Esu, || self.root_task(st, ctx, root, window));
    }
}

impl<A, H, L> EsuEngine<'_, A, H, L>
where
    H: LowLevelApi,
    L: Fn(&mut A, &[VertexId], u64) + Sync,
{
    /// One root task — or, for a split, one published level-1 window of
    /// it. The setup (coverage marking, extension-set seed, MNC seed)
    /// is worker-local and deterministic, so a split replays it; the
    /// root's own accounting is done only by the `window = None` task.
    fn root_task(
        &self,
        st: &mut EsuState<A>,
        ctx: &WorkerCtx<'_>,
        root_idx: usize,
        window: Option<(usize, usize)>,
    ) {
        let (g, k, cfg) = (self.g, self.k, self.cfg);
        debug_assert!(
            window.is_none() || self.use_core,
            "only the extension core publishes ESU splits"
        );
        let root = root_idx as VertexId;
        st.emb.clear();
        st.codes.clear();
        st.ext.clear();
        st.ext_marks.clear();
        st.emb.push(root);
        st.codes.push(0);
        if cfg.opts.stats && window.is_none() {
            st.stats.enumerated += 1;
            // the root's level-1 extension-set seed (stats rule above)
            st.stats.intersections += 1;
        }
        // mark root + its neighborhood; seed ext with neighbors > root
        st.touched.push(root);
        if self.use_core {
            st.core.begin_root(g.num_vertices());
            st.core.cover_mark(root as usize);
            for &u in g.neighbors(root) {
                st.core.cover_mark(u as usize);
                st.touched.push(u);
            }
        } else {
            st.visited[root as usize] = true;
            for &u in g.neighbors(root) {
                st.visited[u as usize] = true;
                st.touched.push(u);
            }
        }
        let nbrs = g.neighbors(root);
        st.ext.extend_from_slice(&nbrs[nbrs.partition_point(|&x| x <= root)..]);
        st.ext_marks.push(0);
        if cfg.opts.mnc {
            for &u in g.neighbors(root) {
                st.map.or_insert(u, 1);
            }
        }
        if self.use_core {
            esu_extend_core(g, k, cfg, self.hooks, st, Some((ctx, root_idx, window)), self.leaf);
        } else {
            esu_extend(g, k, cfg, self.hooks, st, self.leaf);
        }
        if cfg.opts.mnc {
            for &u in g.neighbors(root) {
                st.map.and_remove(u, 1);
            }
        }
        // reset the coverage marking (symmetric, O(touched))
        if self.use_core {
            for &u in &st.touched {
                st.core.cover_unmark(u as usize);
            }
        } else {
            for &u in &st.touched {
                st.visited[u as usize] = false;
            }
        }
        st.touched.clear();
    }
}

fn esu_extend<A, H: LowLevelApi>(
    g: &CsrGraph,
    k: usize,
    cfg: &MinerConfig,
    hooks: &H,
    st: &mut EsuState<A>,
    leaf: &(impl Fn(&mut A, &[VertexId], u64) + Sync),
) {
    let level = st.emb.len();
    let ext_start = *st.ext_marks.last().unwrap();
    let ext_end = st.ext.len();
    // Iterate over a snapshot of this level's extension set; each chosen
    // w spawns a child whose extension set is the remaining candidates
    // plus w's exclusive neighbors (ESU's exactly-once guarantee).
    for wi in ext_start..ext_end {
        let w = st.ext[wi];
        if !hooks.to_add(g, &st.emb, w, level) {
            st.stats.pruned += cfg.opts.stats as u64;
            continue;
        }
        // MEC: connectivity code of w against the current embedding.
        // With MNC the code is a single map lookup (paper Fig. 5); the
        // fallback recomputes it with one has_edge probe per position.
        let code = if cfg.opts.mnc {
            st.map.get(w)
        } else {
            st.emb
                .iter()
                .enumerate()
                .fold(0u32, |c, (i, &u)| c | ((g.has_edge(u, w) as u32) << i))
        };
        st.emb.push(w);
        st.codes.push(code);
        if cfg.opts.stats {
            st.stats.enumerated += 1;
        }
        if st.emb.len() == k {
            if cfg.opts.stats {
                st.stats.matches += 1;
            }
            leaf(&mut st.acc, &st.emb, pack_codes(&st.codes));
            st.emb.pop();
            st.codes.pop();
            continue;
        }
        // child extension set: remaining candidates at this level
        // (after w) plus exclusive neighbors of w
        let child_base = st.ext.len();
        for u in (wi + 1)..ext_end {
            let u = st.ext[u];
            st.ext.push(u);
        }
        let root = st.emb[0];
        for &u in g.neighbors(w) {
            if u > root && !st.visited[u as usize] {
                st.ext.push(u);
            }
        }
        // one child extension-set construction (the stats rule: count
        // the tree event, not the probes, so MNC/extcore toggles are
        // stats-invariant)
        if cfg.opts.stats {
            st.stats.intersections += 1;
        }
        // mark new exclusive neighbors as visited
        for i in (child_base + (ext_end - wi - 1))..st.ext.len() {
            let u = st.ext[i];
            st.visited[u as usize] = true;
            st.touched.push(u);
        }
        st.ext_marks.push(child_base);
        let bit = 1u32 << level;
        if cfg.opts.mnc {
            for &u in g.neighbors(w) {
                st.map.or_insert(u, bit);
            }
        }
        esu_extend(g, k, cfg, hooks, st, leaf);
        if cfg.opts.mnc {
            for &u in g.neighbors(w) {
                st.map.and_remove(u, bit);
            }
        }
        // unmark and truncate
        for i in (child_base + (ext_end - wi - 1))..st.ext.len() {
            let u = st.ext[i];
            st.visited[u as usize] = false;
        }
        st.touched
            .truncate(st.touched.len() - (st.ext.len() - child_base - (ext_end - wi - 1)));
        st.ext.truncate(child_base);
        st.ext_marks.pop();
        st.emb.pop();
        st.codes.pop();
    }
}

/// Extension-core twin of [`esu_extend`]: identical traversal (same
/// candidate sequences, same pruning, same MEC codes — bit-identical
/// leaves), with the exclusive-neighbor sets built by
/// [`ExtCore::exclusive_into`] instead of per-candidate `visited[]`
/// probes, and — at level 1 only (`l1` present) — the candidate loop
/// driven by the shared [`SplitDriver`] so hub roots hand their
/// untraversed suffixes to starving workers.
fn esu_extend_core<A, H: LowLevelApi>(
    g: &CsrGraph,
    k: usize,
    cfg: &MinerConfig,
    hooks: &H,
    st: &mut EsuState<A>,
    l1: Option<(&WorkerCtx<'_>, usize, Option<(usize, usize)>)>,
    leaf: &(impl Fn(&mut A, &[VertexId], u64) + Sync),
) {
    let level = st.emb.len();
    let ext_start = *st.ext_marks.last().unwrap();
    let ext_end = st.ext.len();
    let len = ext_end - ext_start;
    let mut driver =
        l1.map(|(ctx, root, window)| SplitDriver::new(ctx, root, len, window));
    let mut plain = 0..len;
    loop {
        let rel = match driver.as_mut() {
            Some(d) => match d.next() {
                Some(p) => p,
                None => break,
            },
            None => match plain.next() {
                Some(p) => p,
                None => break,
            },
        };
        let wi = ext_start + rel;
        let w = st.ext[wi];
        if !hooks.to_add(g, &st.emb, w, level) {
            st.stats.pruned += cfg.opts.stats as u64;
            continue;
        }
        let code = if cfg.opts.mnc {
            st.map.get(w)
        } else {
            st.emb
                .iter()
                .enumerate()
                .fold(0u32, |c, (i, &u)| c | ((g.has_edge(u, w) as u32) << i))
        };
        st.emb.push(w);
        st.codes.push(code);
        if cfg.opts.stats {
            st.stats.enumerated += 1;
        }
        if st.emb.len() == k {
            if cfg.opts.stats {
                st.stats.matches += 1;
            }
            leaf(&mut st.acc, &st.emb, pack_codes(&st.codes));
            st.emb.pop();
            st.codes.pop();
            continue;
        }
        // child extension set: remaining candidates at this level
        // (after w) plus w's exclusive neighbors via the core kernels
        let child_base = st.ext.len();
        for u in (wi + 1)..ext_end {
            let u = st.ext[u];
            st.ext.push(u);
        }
        let root = st.emb[0];
        let excl_base = st.ext.len();
        st.core.exclusive_into(g, w, root, &mut st.ext);
        if cfg.opts.stats {
            st.stats.intersections += 1;
        }
        // mark the new exclusive neighbors as covered
        for i in excl_base..st.ext.len() {
            let u = st.ext[i];
            st.core.cover_mark(u as usize);
            st.touched.push(u);
        }
        st.ext_marks.push(child_base);
        let bit = 1u32 << level;
        if cfg.opts.mnc {
            for &u in g.neighbors(w) {
                st.map.or_insert(u, bit);
            }
        }
        esu_extend_core(g, k, cfg, hooks, st, None, leaf);
        if cfg.opts.mnc {
            for &u in g.neighbors(w) {
                st.map.and_remove(u, bit);
            }
        }
        // unmark and truncate (symmetric pop)
        for i in excl_base..st.ext.len() {
            st.core.cover_unmark(st.ext[i] as usize);
        }
        st.touched.truncate(st.touched.len() - (st.ext.len() - excl_base));
        st.ext.truncate(child_base);
        st.ext_marks.pop();
        st.emb.pop();
        st.codes.pop();
    }
}

/// Count all k-motifs: returns counts indexed like `all_motifs(k)`.
/// Same governed return contract as [`esu_mine`].
pub fn count_motifs<H: LowLevelApi>(
    g: &CsrGraph,
    k: usize,
    cfg: &MinerConfig,
    hooks: &H,
    table: &MotifTable,
) -> Result<Outcome<Vec<u64>>, MineError> {
    let nm = table.num_motifs;
    esu_mine(
        g,
        k,
        cfg,
        hooks,
        || vec![0u64; nm],
        |acc, _emb, packed| {
            let id = table.classify(packed);
            debug_assert_ne!(id, UNCLASSIFIED);
            acc[id as usize] += 1;
        },
        |mut a, b| {
            for (x, y) in a.iter_mut().zip(b) {
                *x += y;
            }
            a
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::hooks::NoHooks;
    use crate::engine::opts::{MinerConfig, OptFlags};
    use crate::graph::gen;

    fn cfg() -> MinerConfig {
        MinerConfig::custom(2, 8, OptFlags::hi())
    }

    #[test]
    fn motif_table_classifies_triangle_and_wedge() {
        let t = MotifTable::new(3);
        // wedge codes [0,1,01b? position2 adj to pos0 only] = packed 0b01<<1|1
        let tri_key = pack_codes(&[0, 0b1, 0b11]);
        let wedge_key = pack_codes(&[0, 0b1, 0b01]);
        assert_eq!(t.classify(tri_key), 1);
        assert_eq!(t.classify(wedge_key), 0);
    }

    #[test]
    fn k3_counts_on_complete_graph() {
        let g = gen::complete(5);
        let t = MotifTable::new(3);
        let (counts, _) = count_motifs(&g, 3, &cfg(), &NoHooks, &t).unwrap().into_parts();
        assert_eq!(counts[1], 10); // C(5,3) triangles
        assert_eq!(counts[0], 0); // no induced wedges
    }

    #[test]
    fn k3_counts_on_ring() {
        let g = gen::ring(10);
        let t = MotifTable::new(3);
        let (counts, _) = count_motifs(&g, 3, &cfg(), &NoHooks, &t).unwrap().into_parts();
        assert_eq!(counts[0], 10); // one wedge per vertex
        assert_eq!(counts[1], 0);
    }

    #[test]
    fn k4_counts_on_complete_graph() {
        let g = gen::complete(6);
        let t = MotifTable::new(4);
        let (counts, _) = count_motifs(&g, 4, &cfg(), &NoHooks, &t).unwrap().into_parts();
        assert_eq!(counts[5], 15); // C(6,4) 4-cliques, everything else 0
        assert_eq!(counts[..5].iter().sum::<u64>(), 0);
    }

    #[test]
    fn k4_counts_on_ring() {
        let g = gen::ring(12);
        let t = MotifTable::new(4);
        let (counts, _) = count_motifs(&g, 4, &cfg(), &NoHooks, &t).unwrap().into_parts();
        assert_eq!(counts[1], 12); // 4-paths
        assert_eq!(counts[3], 0); // no 4-cycles in a 12-ring
        assert_eq!(counts[0], 0); // no 3-stars (max degree 2)
    }

    #[test]
    fn total_equals_brute_force_on_random_graph() {
        let g = gen::erdos_renyi(30, 0.25, 5, &[]);
        let t = MotifTable::new(4);
        let (counts, _) = count_motifs(&g, 4, &cfg(), &NoHooks, &t).unwrap().into_parts();
        // brute force: all C(30,4) vertex subsets, keep connected induced
        let mut brute = vec![0u64; 6];
        let n = 30u32;
        for a in 0..n {
            for b in (a + 1)..n {
                for c in (b + 1)..n {
                    for d in (c + 1)..n {
                        let vs = [a, b, c, d];
                        let mut p = crate::pattern::Pattern::new(4);
                        for i in 0..4 {
                            for j in (i + 1)..4 {
                                if g.has_edge(vs[i], vs[j]) {
                                    p.add_edge(i, j);
                                }
                            }
                        }
                        if p.is_connected() {
                            let code = canonical_code(&p);
                            let idx = library::all_motifs(4)
                                .iter()
                                .position(|m| canonical_code(m) == code)
                                .unwrap();
                            brute[idx] += 1;
                        }
                    }
                }
            }
        }
        assert_eq!(counts, brute);
    }

    #[test]
    fn extension_core_matches_scalar_oracle() {
        let g = gen::rmat(8, 6, 37, &[]);
        for k in [3usize, 4] {
            let t = MotifTable::new(k);
            let mut oracle = cfg();
            oracle.opts.extcore = false;
            let (want, _) = count_motifs(&g, k, &oracle, &NoHooks, &t).unwrap().into_parts();
            let (got, _) = count_motifs(&g, k, &cfg(), &NoHooks, &t).unwrap().into_parts();
            assert_eq!(got, want, "k={k}");
            // and with MNC off on both paths
            let mut o2 = oracle;
            o2.opts.mnc = false;
            let mut c2 = cfg();
            c2.opts.mnc = false;
            assert_eq!(
                count_motifs(&g, k, &c2, &NoHooks, &t).unwrap().value,
                count_motifs(&g, k, &o2, &NoHooks, &t).unwrap().value,
                "k={k} mnc off"
            );
        }
    }

    #[test]
    fn extension_core_respects_fp_hook() {
        struct NoOdd;
        impl crate::engine::hooks::LowLevelApi for NoOdd {
            fn to_add(&self, _g: &CsrGraph, _e: &[VertexId], u: VertexId, _l: usize) -> bool {
                u % 2 == 0
            }
        }
        let g = gen::rmat(7, 5, 29, &[]);
        let t = MotifTable::new(4);
        let mut oracle = cfg();
        oracle.opts.extcore = false;
        let (want, _) = count_motifs(&g, 4, &oracle, &NoOdd, &t).unwrap().into_parts();
        let (got, _) = count_motifs(&g, 4, &cfg(), &NoOdd, &t).unwrap().into_parts();
        assert_eq!(got, want);
    }

    #[test]
    fn stats_counters_invariant_across_mnc_and_core() {
        // the PR-5 stats rule: counters describe the search tree, so
        // every (mnc, extcore) combination reports identical stats
        let g = gen::rmat(7, 5, 11, &[]);
        let t = MotifTable::new(4);
        let base = MinerConfig::single_thread(OptFlags::hi().with_stats());
        let (c0, s0) = count_motifs(&g, 4, &base, &NoHooks, &t).unwrap().into_parts();
        assert!(s0.enumerated > 0 && s0.matches > 0 && s0.intersections > 0);
        // every expanded embedding builds exactly one child extension set
        assert!(s0.intersections <= s0.enumerated);
        for (mnc, extcore) in [(true, false), (false, true), (false, false)] {
            let mut c = base;
            c.opts.mnc = mnc;
            c.opts.extcore = extcore;
            let (counts, stats) = count_motifs(&g, 4, &c, &NoHooks, &t).unwrap().into_parts();
            assert_eq!(counts, c0, "mnc={mnc} extcore={extcore}");
            assert_eq!(stats, s0, "mnc={mnc} extcore={extcore}");
        }
    }

    #[test]
    fn thread_counts_invariant() {
        let g = gen::rmat(8, 6, 13, &[]);
        let t = MotifTable::new(4);
        let c1 = count_motifs(
            &g,
            4,
            &MinerConfig::custom(1, usize::MAX, OptFlags::hi()),
            &NoHooks,
            &t,
        )
        .unwrap()
        .value;
        let c4 = count_motifs(
            &g,
            4,
            &MinerConfig::custom(4, 32, OptFlags::hi()),
            &NoHooks,
            &t,
        )
        .unwrap()
        .value;
        assert_eq!(c1, c4);
    }
}
