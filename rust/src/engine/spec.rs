//! The Sandslash high-level API (paper Table 1).
//!
//! A GPM problem is *specified*, not programmed: three required flags
//! (vertex/edge-induced, listing/counting, explicit/implicit patterns)
//! plus pattern definitions or an implicit-pattern rule. The solver
//! (`apps::solve`) analyzes the spec — exactly the decision table of
//! §4.3 — and picks search strategy, data structures, and optimizations.

use crate::pattern::Pattern;

#[derive(Clone, Debug)]
/// A GPM problem specification (paper Table 1): what to mine, not how.
pub struct ProblemSpec {
    /// `isVertexInduced`
    pub vertex_induced: bool,
    /// `isListing` (false = counting)
    pub listing: bool,
    /// `isExplicit`
    pub explicit: bool,
    /// Maximum embedding size k (vertices for vertex-induced problems,
    /// edges for edge-induced FSM).
    pub k: usize,
    /// `getExplicitPatterns()`
    pub patterns: Vec<Pattern>,
    /// `isImplicitPattern(pt) := pt.support > min_support` (FSM)
    pub min_support: Option<u64>,
}

impl ProblemSpec {
    /// TC: vertex-induced counting of the explicit triangle pattern.
    pub fn tc() -> Self {
        Self {
            vertex_induced: true,
            listing: false,
            explicit: true,
            k: 3,
            patterns: vec![crate::pattern::library::triangle()],
            min_support: None,
        }
    }

    /// k-CL: vertex-induced listing of the k-clique.
    pub fn clique_listing(k: usize) -> Self {
        Self {
            vertex_induced: true,
            listing: true,
            explicit: true,
            k,
            patterns: vec![crate::pattern::library::clique(k)],
            min_support: None,
        }
    }

    /// SL: edge-induced listing of an explicit pattern.
    pub fn subgraph_listing(p: Pattern) -> Self {
        Self {
            vertex_induced: false,
            listing: true,
            explicit: true,
            k: p.num_vertices(),
            patterns: vec![p],
            min_support: None,
        }
    }

    /// k-MC: vertex-induced counting of all (implicit) k-vertex patterns.
    pub fn motif_counting(k: usize) -> Self {
        Self {
            vertex_induced: true,
            listing: false,
            explicit: false,
            k,
            patterns: Vec::new(),
            min_support: None,
        }
    }

    /// k-FSM: edge-induced, implicit patterns filtered by MNI support —
    /// the right-hand column of the paper's Table 1.
    pub fn fsm(max_edges: usize, min_support: u64) -> Self {
        Self {
            vertex_induced: false,
            listing: false,
            explicit: false,
            k: max_edges,
            patterns: Vec::new(),
            min_support: Some(min_support),
        }
    }

    /// Decision: orientation (DAG) is enabled only for single explicit
    /// clique patterns (§4.3).
    pub fn wants_dag(&self) -> bool {
        self.explicit && self.patterns.len() == 1 && self.patterns[0].is_clique()
    }

    /// Decision: matching order for single explicit non-triangle patterns.
    pub fn wants_mo(&self) -> bool {
        self.explicit
            && self.patterns.len() == 1
            && !(self.patterns[0].is_clique() && self.patterns[0].num_vertices() == 3)
    }

    /// Decision: MNC everywhere except triangles (set intersection wins).
    pub fn wants_mnc(&self) -> bool {
        !(self.explicit
            && self.patterns.len() == 1
            && self.patterns[0].num_vertices() == 3
            && self.patterns[0].is_clique())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_table_matches_paper() {
        assert!(ProblemSpec::tc().wants_dag());
        assert!(!ProblemSpec::tc().wants_mo()); // triangle: MO not beneficial
        assert!(!ProblemSpec::tc().wants_mnc()); // triangle: intersection
        assert!(ProblemSpec::clique_listing(4).wants_dag());
        assert!(ProblemSpec::clique_listing(4).wants_mo());
        let sl = ProblemSpec::subgraph_listing(crate::pattern::library::diamond());
        assert!(!sl.wants_dag()); // diamond is not a clique
        assert!(sl.wants_mo() && sl.wants_mnc());
        assert!(!ProblemSpec::motif_counting(4).wants_dag());
        assert!(ProblemSpec::fsm(3, 100).min_support.is_some());
    }
}
