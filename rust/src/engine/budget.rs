//! Query governance (PR 6): per-run budgets, cooperative cancellation,
//! and the unified mining error surface.
//!
//! The ROADMAP's resident multi-tenant service needs every mining run
//! to be *boundable*: a clique query on a hub-heavy graph can cost
//! 1000× what the same query costs on a uniform graph, so a process
//! serving many users must be able to limit, cancel, and survive any
//! single run. This module generalizes the PR-5 BFS byte budget
//! ([`crate::engine::bfs::BfsCapExceeded`]) into one governance layer:
//!
//! * [`Budget`] — the per-run limits (`deadline`, `max_tasks`,
//!   `bfs_bytes`), carried on [`MinerConfig`](super::MinerConfig) and
//!   seeded from `SANDSLASH_DEADLINE_MS` / `SANDSLASH_MAX_TASKS`.
//! * [`CancelToken`] — one atomic byte encoding *whether* and *why* a
//!   run was cancelled ([`CancelReason`]); first trip wins. Callers
//!   install their own token with [`with_cancel`] to cancel a run
//!   asynchronously.
//! * [`Governor`] — the per-run referee the scheduler polls: one
//!   relaxed load on the hot path ([`Governor::is_cancelled`]), one
//!   [`Governor::admit`] charge per claimed root block (the same
//!   granularity the PR-4 deques already lock at), deadline checks
//!   only when a deadline is set.
//! * [`Outcome`] / [`MineError`] — every engine entry point returns
//!   `Result<Outcome<T>, MineError>`: a budget trip is **not** an
//!   error — the partial counts accumulated before the trip come back
//!   with `complete == false` (graceful degradation; a future
//!   approximate mode reads straight off this) — while a worker panic
//!   ([`MineError::WorkerPanicked`]) or the BFS byte budget
//!   ([`MineError::BfsCapExceeded`]) is.
//!
//! Cancellation is cooperative and near-free: the token is polled at
//! exactly the points the PR-4/PR-5 split protocol already polls (per
//! level-1 candidate, per claimed block, per BFS level), so the
//! steady-state cost is one additional relaxed load at an existing
//! poll site. `SANDSLASH_NO_GOV=1` (or the scoped
//! [`with_governance_disabled`], which the benches use to time the
//! ungoverned path) removes even that load by running the engines with
//! no governor at all — the same kill-switch contract as
//! `SANDSLASH_NO_STEAL` / `SANDSLASH_NO_SIMD`.

use std::cell::{Cell, RefCell};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

// PR-8: the CancelToken byte and the governor's task counter and
// panic-note mutex go through the sync facade so the loom suite can
// model-check first-trip-wins under racing cancels
// (tests/loom/budget.rs). Arc/OnceLock/Instant stay std — they are
// plumbing around the protocol, not the protocol.
use crate::util::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use crate::util::sync::Mutex;

use crate::engine::bfs::BfsCapExceeded;
use crate::util::metrics::{gov, SearchStats};

/// Per-run resource limits. All fields default to `None` (unlimited):
/// with every limit unset and no caller token installed, the governed
/// path degenerates to one relaxed load per claimed block and the
/// engines' counts are bit-identical to ungoverned runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Budget {
    /// Wall-clock deadline for the run, measured from
    /// [`Governor::new`]. Checked once per claimed root block (and per
    /// BFS level), so the trip granularity is one block, not one root.
    pub deadline: Option<Duration>,
    /// Maximum number of scheduler tasks (claimed blocks, split tasks,
    /// BFS expansion blocks) the run may consume. Honored within one
    /// block grain: the task that crosses the limit is refused, tasks
    /// already running finish.
    pub max_tasks: Option<u64>,
    /// Byte budget for one materialized BFS level (the PR-5 cap,
    /// absorbed here). `None` resolves `SANDSLASH_BFS_CAP` and then
    /// [`crate::engine::bfs::DEFAULT_BFS_CAP_BYTES`].
    pub bfs_bytes: Option<usize>,
}

impl Budget {
    /// The process-default budget: `SANDSLASH_DEADLINE_MS` and
    /// `SANDSLASH_MAX_TASKS` (loud-reject parse like every
    /// `SANDSLASH_*` numeric knob, resolved once per process),
    /// `bfs_bytes` unset.
    pub fn from_env() -> Self {
        static CACHE: OnceLock<(Option<u64>, Option<u64>)> = OnceLock::new();
        let &(ms, tasks) = CACHE.get_or_init(|| {
            (
                crate::util::pool::positive_usize_env("SANDSLASH_DEADLINE_MS", "no deadline")
                    .map(|n| n as u64),
                crate::util::pool::positive_usize_env("SANDSLASH_MAX_TASKS", "no task budget")
                    .map(|n| n as u64),
            )
        });
        Self {
            deadline: ms.map(Duration::from_millis),
            max_tasks: tasks,
            bfs_bytes: None,
        }
    }

    /// Whether any limit is set (callers with no limits and no caller
    /// token skip the per-block accounting entirely).
    pub fn is_limited(&self) -> bool {
        self.deadline.is_some() || self.max_tasks.is_some()
    }
}

/// Why a run was cancelled. Encoded in one atomic byte on the
/// [`CancelToken`]; the first reason to trip wins and later trips are
/// ignored, so a run reports the *original* cause even when (say) a
/// deadline also expires while a panic drains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelReason {
    /// The [`Budget::deadline`] expired.
    Deadline,
    /// The [`Budget::max_tasks`] task budget was exhausted.
    TaskBudget,
    /// The caller cancelled via a [`CancelToken`] installed with
    /// [`with_cancel`] (or [`CancelToken::cancel`]).
    Caller,
    /// A worker panicked; the run terminated through the normal
    /// protocol instead of poisoning scheduler locks. Surfaced to the
    /// caller as [`MineError::WorkerPanicked`], never as a partial
    /// [`Outcome`] — a panicking hook may have lost counts.
    WorkerPanic,
}

impl CancelReason {
    const CODES: [CancelReason; 4] = [
        CancelReason::Deadline,
        CancelReason::TaskBudget,
        CancelReason::Caller,
        CancelReason::WorkerPanic,
    ];

    fn as_u8(self) -> u8 {
        match self {
            CancelReason::Deadline => 1,
            CancelReason::TaskBudget => 2,
            CancelReason::Caller => 3,
            CancelReason::WorkerPanic => 4,
        }
    }

    fn from_u8(code: u8) -> Option<Self> {
        if code == 0 {
            None
        } else {
            Some(Self::CODES[(code - 1) as usize])
        }
    }

    /// Distinct process exit code for CLI runs that end on this trip
    /// (see `main.rs`; 0 = complete, 1 = load/internal error, 2 =
    /// usage, 3 = BFS cap, 4 = worker panic).
    pub fn exit_code(self) -> i32 {
        match self {
            CancelReason::Deadline => 5,
            CancelReason::TaskBudget => 6,
            CancelReason::Caller => 7,
            CancelReason::WorkerPanic => 4,
        }
    }

    /// One-line diagnosis naming the knob to raise, following the
    /// `BfsCapExceeded` message pattern.
    pub fn diagnosis(self) -> &'static str {
        match self {
            CancelReason::Deadline => {
                "deadline exceeded: counts below are partial; raise --deadline-ms \
                 (or SANDSLASH_DEADLINE_MS) or narrow the query to finish"
            }
            CancelReason::TaskBudget => {
                "task budget exhausted: counts below are partial; raise --max-tasks \
                 (or SANDSLASH_MAX_TASKS) or narrow the query to finish"
            }
            CancelReason::Caller => {
                "cancelled by caller: counts below are partial up to the cancellation point"
            }
            CancelReason::WorkerPanic => {
                "a worker panicked mid-run: results were discarded, not returned partial"
            }
        }
    }
}

/// Shared cancellation flag: one atomic byte holding the first
/// [`CancelReason`] to trip (0 = not cancelled). Clone the `Arc` it is
/// usually wrapped in, hand one side to [`with_cancel`], and call
/// [`CancelToken::cancel`] from any thread to stop the governed run at
/// its next poll site.
#[derive(Debug, Default)]
pub struct CancelToken {
    state: AtomicU8,
}

impl CancelToken {
    /// A fresh, untripped token.
    pub const fn new() -> Self {
        Self { state: AtomicU8::new(0) }
    }

    /// Cancel on behalf of the caller (trips with
    /// [`CancelReason::Caller`]).
    pub fn cancel(&self) {
        self.trip(CancelReason::Caller);
    }

    /// Trip with an explicit reason. First trip wins; returns whether
    /// this call was the one that tripped it.
    pub fn trip(&self, reason: CancelReason) -> bool {
        self.state
            .compare_exchange(0, reason.as_u8(), Ordering::AcqRel, Ordering::Relaxed)
            .is_ok()
    }

    /// The reason this token tripped, if it has (one relaxed load —
    /// the hot-path poll).
    pub fn cancelled(&self) -> Option<CancelReason> {
        CancelReason::from_u8(self.state.load(Ordering::Relaxed))
    }

    /// Whether the token has tripped (one relaxed load).
    pub fn is_cancelled(&self) -> bool {
        self.state.load(Ordering::Relaxed) != 0
    }
}

thread_local! {
    /// Ambient caller token, installed by [`with_cancel`] and picked up
    /// by [`Governor::new`] — the same scoped-override shape as
    /// [`crate::exec::sched::with_overrides`], so callers can cancel
    /// runs that reach the engines through fixed app signatures.
    static CALLER_TOKEN: RefCell<Option<Arc<CancelToken>>> = const { RefCell::new(None) };
    /// Scoped governance kill switch (see [`with_governance_disabled`]).
    static GOV_DISABLED: Cell<bool> = const { Cell::new(false) };
}

/// Run `f` with `token` installed as the ambient caller-cancellation
/// token: every [`Governor`] created inside the scope (on this thread)
/// polls it once per claimed block and trips [`CancelReason::Caller`]
/// when it is cancelled. Restores the previous token on exit.
///
/// **Reentrancy (PR 7)**: the install is thread-local and scoped —
/// never process-global — which is what makes the resident service
/// sound. Each query installs its own token around its own engine run;
/// concurrent queries on other threads see their own tokens (or none),
/// and when the scope exits the previous token is restored, so a
/// thread that goes on to serve another query cannot carry a stale
/// cancel across. A pre-cancelled token therefore trips exactly one
/// query; the same thread's next run completes untouched (asserted by
/// `tests/service_concurrency.rs::scoped_thread_locals_do_not_leak`).
pub fn with_cancel<R>(token: Arc<CancelToken>, f: impl FnOnce() -> R) -> R {
    let prev = CALLER_TOKEN.with(|t| t.replace(Some(token)));
    struct Restore(Option<Arc<CancelToken>>);
    impl Drop for Restore {
        fn drop(&mut self) {
            CALLER_TOKEN.with(|t| *t.borrow_mut() = self.0.take());
        }
    }
    let _restore = Restore(prev);
    f()
}

/// The ambient caller token installed by [`with_cancel`], if any.
fn current_cancel() -> Option<Arc<CancelToken>> {
    CALLER_TOKEN.with(|t| t.borrow().clone())
}

/// Process-wide governance kill switch: `SANDSLASH_NO_GOV` set to any
/// non-empty value other than `0` runs every engine with no governor
/// at all — no token, no polls, no panic catching — the exact pre-PR-6
/// hot path. Same contract as the other `SANDSLASH_NO_*` switches.
fn no_gov_env() -> bool {
    static CACHE: OnceLock<bool> = OnceLock::new();
    *CACHE.get_or_init(|| match std::env::var("SANDSLASH_NO_GOV") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    })
}

/// Run `f` with governance disabled on this thread (engines entered
/// inside the scope run ungoverned, as if `SANDSLASH_NO_GOV=1`). The
/// `pr6-governance` bench uses this to time the governance-off path
/// from the same process.
pub fn with_governance_disabled<R>(f: impl FnOnce() -> R) -> R {
    let prev = GOV_DISABLED.with(|d| d.replace(true));
    struct Restore(bool);
    impl Drop for Restore {
        fn drop(&mut self) {
            GOV_DISABLED.with(|d| d.set(self.0));
        }
    }
    let _restore = Restore(prev);
    f()
}

/// Whether engines entered on this thread should create a governor:
/// `false` under `SANDSLASH_NO_GOV=1` or inside
/// [`with_governance_disabled`].
pub fn governance_enabled() -> bool {
    !no_gov_env() && !GOV_DISABLED.with(|d| d.get())
}

/// Per-run governance state: the deadline clock, the task counter, the
/// run's own [`CancelToken`], the optional caller token, and the
/// first-caught panic payload. Engines create one per entry
/// ([`Governor::new`]), thread `Option<&Governor>` down to the
/// scheduler, and convert the end state with [`Governor::finish`].
pub struct Governor {
    deadline: Option<Instant>,
    max_tasks: u64,
    tasks: AtomicU64,
    token: CancelToken,
    external: Option<Arc<CancelToken>>,
    panic_note: Mutex<Option<String>>,
    limited: bool,
}

impl Governor {
    /// Start governing one run under `budget` (the deadline clock
    /// starts now). Picks up the ambient [`with_cancel`] token and arms
    /// the fault-injection harness from `SANDSLASH_FAULT` (once per
    /// process).
    pub fn new(budget: &Budget) -> Self {
        crate::util::fault::init_from_env();
        crate::obs::flight::note_query_start();
        let external = current_cancel();
        let limited = budget.is_limited() || external.is_some();
        Self {
            deadline: budget.deadline.map(|d| Instant::now() + d),
            max_tasks: budget.max_tasks.unwrap_or(u64::MAX),
            tasks: AtomicU64::new(0),
            token: CancelToken::new(),
            external,
            panic_note: Mutex::new(None),
            limited,
        }
    }

    /// Hot-path poll: has this run been cancelled? One relaxed load —
    /// placed at exactly the sites the split gate already polls.
    #[inline]
    pub fn is_cancelled(&self) -> bool {
        self.token.is_cancelled()
    }

    /// The reason this run tripped, if it has.
    pub fn cancelled(&self) -> Option<CancelReason> {
        self.token.cancelled()
    }

    /// Whether any limit (deadline, task budget, caller token) is
    /// armed. Unlimited governors skip the per-block charge and only
    /// pay the relaxed cancellation load (which can still trip — a
    /// worker panic cancels even an unlimited run).
    pub fn limited(&self) -> bool {
        self.limited
    }

    /// Charge one scheduler task against the budget. Called once per
    /// claimed block / split task / BFS expansion block — never per
    /// root. Returns `false` once the run is cancelled (by this charge
    /// or earlier); the refusing worker drops the task and proceeds to
    /// termination.
    pub fn admit(&self) -> bool {
        if self.token.is_cancelled() {
            return false;
        }
        if !self.limited {
            crate::obs::trace::on_budget_charge();
            return true;
        }
        if let Some(ext) = &self.external {
            if ext.is_cancelled() {
                self.trip(CancelReason::Caller);
                return false;
            }
        }
        if let Some(deadline) = self.deadline {
            if Instant::now() >= deadline {
                self.trip(CancelReason::Deadline);
                return false;
            }
        }
        if self.max_tasks != u64::MAX
            && self.tasks.fetch_add(1, Ordering::Relaxed) >= self.max_tasks
        {
            self.trip(CancelReason::TaskBudget);
            return false;
        }
        crate::obs::trace::on_budget_charge();
        true
    }

    /// Trip the run's token (first reason wins), count it, record it
    /// in the active query trace (if any), and dump the flight
    /// recorder for post-mortem (PR 9).
    pub fn trip(&self, reason: CancelReason) {
        if self.token.trip(reason) {
            gov::note_trip(reason);
            crate::obs::trace::on_trip(reason);
            crate::obs::flight::note_trip(reason.exit_code() as u64);
            let why = match reason {
                CancelReason::WorkerPanic => "worker-panic",
                _ => "budget-trip",
            };
            crate::obs::flight::dump_to_stderr(why);
        }
    }

    /// Record a caught worker panic: keep the first payload, trip
    /// [`CancelReason::WorkerPanic`]. The scheduler calls this from the
    /// worker that caught the unwind; [`Governor::finish`] turns it
    /// into [`MineError::WorkerPanicked`].
    pub fn note_panic(&self, payload: String) {
        let mut slot = self.panic_note.lock().unwrap_or_else(|e| e.into_inner());
        if slot.is_none() {
            *slot = Some(payload);
        }
        drop(slot);
        gov::note_panic_caught();
        // the flight event carries the last fault stage this thread
        // crossed — what names the faulted stage in the dump
        crate::obs::flight::note_panic();
        self.trip(CancelReason::WorkerPanic);
    }

    /// Convert the end-of-run state: a recorded panic is
    /// `Err(WorkerPanicked)`, a tripped budget is a partial
    /// [`Outcome`], anything else is complete.
    pub fn finish<T>(
        &self,
        value: T,
        stats: SearchStats,
        engine: &'static str,
    ) -> Result<Outcome<T>, MineError> {
        crate::obs::flight::note_query_end();
        let note = self.panic_note.lock().unwrap_or_else(|e| e.into_inner()).take();
        if let Some(payload) = note {
            return Err(MineError::WorkerPanicked { engine, payload });
        }
        match self.token.cancelled() {
            Some(CancelReason::WorkerPanic) => Err(MineError::WorkerPanicked {
                engine,
                payload: "worker panicked (payload lost)".to_string(),
            }),
            Some(reason) => Ok(Outcome::partial(value, stats, reason)),
            None => Ok(Outcome::complete(value, stats)),
        }
    }
}

/// The result of a governed mining run: the value (counts, listings,
/// frequent patterns), the merged search counters, and whether the run
/// saw its whole search space. A tripped budget yields
/// `complete == false` with the counts accumulated *before* the trip —
/// always a lower bound on the true count, never garbage.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Outcome<T> {
    /// The (possibly partial) mining result.
    pub value: T,
    /// Merged per-worker search counters.
    pub stats: SearchStats,
    /// `true` iff the run explored its entire search space.
    pub complete: bool,
    /// Why the run stopped early (`None` iff `complete`).
    pub tripped: Option<CancelReason>,
}

impl<T> Outcome<T> {
    /// A run that explored everything.
    pub fn complete(value: T, stats: SearchStats) -> Self {
        Self { value, stats, complete: true, tripped: None }
    }

    /// A run that tripped a budget after accumulating `value`.
    pub fn partial(value: T, stats: SearchStats, reason: CancelReason) -> Self {
        Self { value, stats, complete: false, tripped: Some(reason) }
    }

    /// Split into `(value, stats)` — the seed-era tuple shape, for
    /// call sites that only want the numbers.
    pub fn into_parts(self) -> (T, SearchStats) {
        (self.value, self.stats)
    }

    /// Transform the carried value, preserving stats and trip state —
    /// for facades (e.g. [`crate::apps::solve`]) that re-shape engine
    /// results without touching governance semantics.
    pub fn map<U>(self, f: impl FnOnce(T) -> U) -> Outcome<U> {
        Outcome { value: f(self.value), stats: self.stats, complete: self.complete, tripped: self.tripped }
    }
}

/// The unified mining error. Budget trips are *not* errors (they come
/// back as partial [`Outcome`]s); this enum covers the cases where no
/// trustworthy partial result exists.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MineError {
    /// A materialized BFS level exceeded its byte budget (the PR-5
    /// error, absorbed; [`Budget::bfs_bytes`] / `SANDSLASH_BFS_CAP`).
    BfsCapExceeded(BfsCapExceeded),
    /// A worker panicked mid-run. The run terminated through the normal
    /// active==0 protocol (no poisoned scheduler locks, no process
    /// abort) and the first panic payload was captured.
    WorkerPanicked {
        /// Which engine was running (`"dfs"`, `"esu"`, `"bfs"`,
        /// `"fsm"`).
        engine: &'static str,
        /// The stringified panic payload.
        payload: String,
    },
}

impl MineError {
    /// Distinct nonzero process exit code for CLI runs (see the map on
    /// [`CancelReason::exit_code`]).
    pub fn exit_code(&self) -> i32 {
        match self {
            MineError::BfsCapExceeded(_) => 3,
            MineError::WorkerPanicked { .. } => 4,
        }
    }
}

impl std::fmt::Display for MineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MineError::BfsCapExceeded(e) => e.fmt(f),
            MineError::WorkerPanicked { engine, payload } => write!(
                f,
                "a {engine} worker panicked mid-run: {payload}; the run was drained cleanly \
                 (no results) — rerun, or fix the panicking hook"
            ),
        }
    }
}

impl std::error::Error for MineError {}

impl From<BfsCapExceeded> for MineError {
    fn from(e: BfsCapExceeded) -> Self {
        MineError::BfsCapExceeded(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_first_trip_wins() {
        let t = CancelToken::new();
        assert_eq!(t.cancelled(), None);
        assert!(!t.is_cancelled());
        assert!(t.trip(CancelReason::Deadline));
        assert!(!t.trip(CancelReason::TaskBudget), "second trip must lose");
        assert_eq!(t.cancelled(), Some(CancelReason::Deadline));
        assert!(t.is_cancelled());
    }

    #[test]
    fn reason_codes_round_trip() {
        for r in CancelReason::CODES {
            assert_eq!(CancelReason::from_u8(r.as_u8()), Some(r));
        }
        assert_eq!(CancelReason::from_u8(0), None);
    }

    #[test]
    fn exit_codes_are_distinct_and_nonzero() {
        let mut codes = vec![
            MineError::BfsCapExceeded(BfsCapExceeded {
                level: 2,
                embeddings: 1,
                bytes: 2,
                cap: 1,
            })
            .exit_code(),
            MineError::WorkerPanicked { engine: "dfs", payload: String::new() }.exit_code(),
            CancelReason::Deadline.exit_code(),
            CancelReason::TaskBudget.exit_code(),
            CancelReason::Caller.exit_code(),
        ];
        codes.sort_unstable();
        let len = codes.len();
        codes.dedup();
        assert_eq!(codes.len(), len, "exit codes must be distinct");
        assert!(codes.iter().all(|&c| c > 2), "0/1/2 are reserved for ok/load/usage");
    }

    #[test]
    fn unlimited_governor_admits_forever_until_tripped() {
        let gov = Governor::new(&Budget::default());
        assert!(!gov.limited());
        for _ in 0..1000 {
            assert!(gov.admit());
        }
        gov.trip(CancelReason::Caller);
        assert!(!gov.admit());
        assert_eq!(gov.cancelled(), Some(CancelReason::Caller));
    }

    #[test]
    fn task_budget_admits_exactly_max_tasks() {
        let budget = Budget { max_tasks: Some(5), ..Budget::default() };
        let gov = Governor::new(&budget);
        assert!(gov.limited());
        for i in 0..5 {
            assert!(gov.admit(), "task {i} is within budget");
        }
        assert!(!gov.admit(), "task 5 crosses the budget");
        assert_eq!(gov.cancelled(), Some(CancelReason::TaskBudget));
    }

    #[test]
    fn elapsed_deadline_refuses_admission() {
        let budget = Budget { deadline: Some(Duration::ZERO), ..Budget::default() };
        let gov = Governor::new(&budget);
        assert!(!gov.admit());
        assert_eq!(gov.cancelled(), Some(CancelReason::Deadline));
        let out = gov.finish(7u64, SearchStats::default(), "dfs").unwrap();
        assert!(!out.complete);
        assert_eq!(out.tripped, Some(CancelReason::Deadline));
        assert_eq!(out.value, 7);
    }

    #[test]
    fn ambient_caller_token_trips_caller() {
        let token = Arc::new(CancelToken::new());
        token.cancel();
        let out = with_cancel(token, || {
            let gov = Governor::new(&Budget::default());
            assert!(!gov.admit());
            gov.finish(3u64, SearchStats::default(), "esu").unwrap()
        });
        assert_eq!(out.tripped, Some(CancelReason::Caller));
        // the scope restored the previous (absent) token
        assert!(current_cancel().is_none());
    }

    #[test]
    fn panic_note_beats_partial_outcome() {
        let gov = Governor::new(&Budget { max_tasks: Some(1), ..Budget::default() });
        gov.note_panic("boom".to_string());
        match gov.finish(0u64, SearchStats::default(), "fsm") {
            Err(MineError::WorkerPanicked { engine, payload }) => {
                assert_eq!(engine, "fsm");
                assert_eq!(payload, "boom");
            }
            other => panic!("expected WorkerPanicked, got {other:?}"),
        }
    }

    #[test]
    fn governance_scoped_disable_restores() {
        assert!(governance_enabled());
        with_governance_disabled(|| assert!(!governance_enabled()));
        assert!(governance_enabled());
    }

    #[test]
    fn diagnosis_names_the_knob() {
        assert!(CancelReason::Deadline.diagnosis().contains("SANDSLASH_DEADLINE_MS"));
        assert!(CancelReason::Deadline.diagnosis().contains("--deadline-ms"));
        assert!(CancelReason::TaskBudget.diagnosis().contains("SANDSLASH_MAX_TASKS"));
        assert!(CancelReason::TaskBudget.diagnosis().contains("--max-tasks"));
    }

    #[test]
    fn mine_error_display_is_actionable() {
        let e = MineError::WorkerPanicked { engine: "bfs", payload: "hook failed".into() };
        let msg = format!("{e}");
        assert!(msg.contains("bfs") && msg.contains("hook failed"));
        let cap: MineError = BfsCapExceeded { level: 3, embeddings: 9, bytes: 10, cap: 5 }.into();
        assert!(format!("{cap}").contains("SANDSLASH_BFS_CAP"));
    }
}
