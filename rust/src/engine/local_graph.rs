//! Search on Local Graphs (paper §5 "LG", Listing 4; kClist [16]).
//!
//! For k-CL, every extension vertex must be adjacent to *all* embedding
//! vertices, so instead of scanning global neighbor lists the search
//! materializes the subgraph induced by the out-neighborhood of the root
//! and then *shrinks* it level by level: at depth d only vertices that
//! survived depth d-1 and are adjacent to the newly chosen vertex remain.
//!
//! Representation follows kClist: one adjacency array shared across
//! depths with *per-depth degrees* — `updateLG` just swaps surviving
//! neighbors to the front of each list and records the new degree, so
//! push/pop is O(touched edges) with zero allocation (exactly the
//! mechanics of the paper's Listing 4).

use crate::graph::orientation::Dag;
use crate::graph::VertexId;

pub struct LocalGraph {
    /// Local-id adjacency, flat; lists mutate in place across depths.
    adj: Vec<u32>,
    offsets: Vec<u32>,
    /// deg[depth][v_local]
    deg: Vec<Vec<u32>>,
    /// label[v_local] = deepest level at which the vertex is still alive.
    alive: Vec<u32>,
    /// Map local id -> global vertex.
    globals: Vec<VertexId>,
    num_local: usize,
    max_depth: usize,
}

impl LocalGraph {
    pub fn new(max_vertices: usize, max_depth: usize) -> Self {
        Self {
            adj: Vec::new(),
            offsets: vec![0; max_vertices + 1],
            deg: vec![vec![0; max_vertices]; max_depth + 1],
            alive: vec![0; max_vertices],
            globals: vec![0; max_vertices],
            num_local: 0,
            max_depth,
        }
    }

    /// `initLG`: build the local graph induced by the out-neighborhood of
    /// `root` in the DAG (vertices = out(root); edges = DAG edges among
    /// them). Returns the number of local vertices.
    pub fn init_from_dag(&mut self, dag: &Dag, root: VertexId) -> usize {
        let nbrs = dag.out_neighbors(root);
        let n = nbrs.len();
        self.num_local = n;
        if self.deg[0].len() < n {
            for d in &mut self.deg {
                d.resize(n, 0);
            }
            self.alive.resize(n, 0);
            self.globals.resize(n, 0);
            self.offsets.resize(n + 1, 0);
        }
        self.globals[..n].copy_from_slice(nbrs);
        for a in self.alive[..n].iter_mut() {
            *a = 0;
        }
        // adjacency among locals: intersect out(u) with nbrs
        self.adj.clear();
        self.offsets[0] = 0;
        for (i, &u) in nbrs.iter().enumerate() {
            let mut d = 0u32;
            let (mut a, mut b) = (0usize, 0usize);
            let out_u = dag.out_neighbors(u);
            while a < out_u.len() && b < n {
                let (x, y) = (out_u[a], nbrs[b]);
                if x == y {
                    self.adj.push(b as u32); // local id of the target
                    d += 1;
                    a += 1;
                    b += 1;
                } else if x < y {
                    a += 1;
                } else {
                    b += 1;
                }
            }
            self.deg[0][i] = d;
            self.offsets[i + 1] = self.adj.len() as u32;
        }
        n
    }

    pub fn num_vertices(&self) -> usize {
        self.num_local
    }

    pub fn global(&self, local: usize) -> VertexId {
        self.globals[local]
    }

    #[inline]
    pub fn degree(&self, depth: usize, local: usize) -> u32 {
        self.deg[depth][local]
    }

    #[inline]
    pub fn adj(&self, depth: usize, local: usize) -> &[u32] {
        let s = self.offsets[local] as usize;
        &self.adj[s..s + self.deg[depth][local] as usize]
    }

    #[inline]
    pub fn is_alive(&self, depth: usize, local: usize) -> bool {
        self.alive[local] >= depth as u32
    }

    /// `updateLG`: descend to `depth`, keeping only vertices adjacent to
    /// `chosen` (local id) that are alive at depth-1. For every survivor,
    /// compact its depth-(d-1) adjacency list in place so the first
    /// `deg[d]` entries are the surviving neighbors (Listing 4's
    /// swap-to-tail loop).
    pub fn shrink(&mut self, depth: usize, chosen: usize) -> u32 {
        debug_assert!(depth <= self.max_depth);
        // Survivors are chosen's depth-1 list prefix. Iterating it by
        // index is safe: compaction below only touches survivors' lists,
        // and `chosen` is never its own DAG-descendant, so chosen's range
        // is left untouched (no allocation needed — §Perf: the original
        // `to_vec` here cost ~2x on the k-CL hot path).
        let c_start = self.offsets[chosen] as usize;
        let n_surv = self.deg[depth - 1][chosen] as usize;
        for i in 0..n_surv {
            let v = self.adj[c_start + i] as usize;
            self.alive[v] = depth as u32;
        }
        for i in 0..n_surv {
            let v = self.adj[c_start + i] as usize;
            let start = self.offsets[v] as usize;
            let old_deg = self.deg[depth - 1][v] as usize;
            let mut keep = 0usize;
            for j in 0..old_deg {
                let w = self.adj[start + j];
                if self.alive[w as usize] >= depth as u32 {
                    self.adj.swap(start + keep, start + j);
                    keep += 1;
                }
            }
            self.deg[depth][v] = keep as u32;
        }
        n_surv as u32
    }

    /// Undo `shrink` at `depth` (drop survivor markings). Adjacency
    /// permutations don't need undoing: list *prefixes* per depth remain
    /// valid because deeper compactions only permute within the prefix of
    /// shallower depths.
    pub fn unshrink(&mut self, depth: usize, chosen: usize) {
        let s = self.offsets[chosen] as usize;
        let d = self.deg[depth - 1][chosen] as usize;
        for i in 0..d {
            let v = self.adj[s + i] as usize;
            if self.alive[v] >= depth as u32 {
                self.alive[v] = depth as u32 - 1;
            }
        }
    }

    /// Survivor local-ids at `depth` reachable from `chosen`'s list at
    /// depth-1 (the candidate set for the next level).
    pub fn candidates(&self, depth: usize, chosen: usize) -> &[u32] {
        let s = self.offsets[chosen] as usize;
        &self.adj[s..s + self.deg[depth - 1][chosen] as usize]
    }

    /// In-place candidate access (no slice borrow held across recursion).
    #[inline]
    pub fn candidate_at(&self, chosen: usize, i: usize) -> u32 {
        self.adj[self.offsets[chosen] as usize + i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::graph::orientation::{orient, OrientScheme};

    #[test]
    fn init_builds_neighborhood_subgraph() {
        let g = gen::complete(5);
        let dag = orient(&g, OrientScheme::Degree);
        let mut lg = LocalGraph::new(8, 5);
        // root = rank-0 vertex: its out-neighborhood is the other 4
        let root = (0..5u32).find(|&v| dag.out_degree(v) == 4).unwrap();
        let n = lg.init_from_dag(&dag, root);
        assert_eq!(n, 4);
        // local graph of K5's neighborhood is the DAG on K4: degrees 3,2,1,0
        let mut degs: Vec<u32> = (0..4).map(|v| lg.degree(0, v)).collect();
        degs.sort_unstable();
        assert_eq!(degs, vec![0, 1, 2, 3]);
    }

    #[test]
    fn shrink_keeps_only_common_neighbors() {
        let g = gen::complete(5);
        let dag = orient(&g, OrientScheme::Core);
        let mut lg = LocalGraph::new(8, 5);
        let root = (0..5u32).find(|&v| dag.out_degree(v) == 4).unwrap();
        lg.init_from_dag(&dag, root);
        // choose the local vertex with max local out-degree (3)
        let chosen = (0..4).max_by_key(|&v| lg.degree(0, v)).unwrap();
        let survivors = lg.shrink(1, chosen);
        assert_eq!(survivors, 3);
        lg.unshrink(1, chosen);
    }

    #[test]
    fn shrink_unshrink_restores_depth0_view() {
        let g = gen::rmat(7, 8, 2, &[]);
        let dag = orient(&g, OrientScheme::Core);
        let mut lg = LocalGraph::new(g.max_degree() + 1, 6);
        for root in 0..g.num_vertices() as u32 {
            if dag.out_degree(root) < 2 {
                continue;
            }
            let n = lg.init_from_dag(&dag, root);
            let before: Vec<Vec<u32>> = (0..n)
                .map(|v| {
                    let mut a = lg.adj(0, v).to_vec();
                    a.sort_unstable();
                    a
                })
                .collect();
            let chosen = (0..n).max_by_key(|&v| lg.degree(0, v)).unwrap();
            lg.shrink(1, chosen);
            lg.unshrink(1, chosen);
            for v in 0..n {
                let mut a = lg.adj(0, v).to_vec();
                a.sort_unstable();
                assert_eq!(a, before[v], "root {root} local {v}");
            }
            break;
        }
    }
}
