//! Search on Shrinking Local Graphs (paper §5 "LG", Listing 4; kClist).
//!
//! Deep DFS levels should intersect small, degeneracy-bounded *local*
//! adjacency lists instead of global CSR rows. Two substrates share the
//! same list mechanics:
//!
//! * [`LocalGraph`] — the clique-only original: vertices are the
//!   DAG out-neighborhood of the root, every extension must be adjacent
//!   to the whole embedding, so each level shrinks the graph to the
//!   chosen vertex's neighbors (exactly kClist / the paper's Listing 4).
//!   Driven by the hand-tuned k-CL-Lo app
//!   ([`crate::apps::clique::clique_lo`]).
//! * [`PlanLocalGraph`] — the generalization to **arbitrary matching
//!   plans**: the local vertex universe is the union of the candidate
//!   sets named by the plan (the neighborhoods of the already-matched
//!   vertices that future levels constrain against), each local vertex
//!   tracks an *adjacency bitmask against the partial embedding* so
//!   non-edge (anti-adjacency) constraints resolve in O(1), and
//!   symmetry-breaking range bounds are translated once into local-id
//!   bounds. Levels that constrain every deeper level ("cone" levels,
//!   [`crate::pattern::matching_order::LevelPlan::lg_cone`]) still get
//!   the kClist shrink. Driven by the generic DFS engine
//!   ([`crate::engine::dfs`]) behind `OptFlags::lg`.
//!
//! Representation follows kClist: one adjacency array shared across
//! depths with *per-depth degrees* — `updateLG` just swaps surviving
//! neighbors to the front of each list and records the new degree, so
//! push/pop is O(touched edges) with zero allocation (exactly the
//! mechanics of the paper's Listing 4). The private `shrink_lists` /
//! `unshrink_lists` helpers are that shared mechanic.

use crate::graph::orientation::Dag;
use crate::graph::{CsrGraph, VertexId};

/// `updateLG` (paper Listing 4): descend to `depth`, keeping only
/// vertices adjacent to `chosen` that are alive at `depth - 1`. For
/// every survivor, compact its depth-(d-1) adjacency list in place so
/// the first `deg[d]` entries are the surviving neighbors (the
/// swap-to-front loop). Returns the number of survivors.
///
/// O(touched edges), zero allocation. `chosen`'s own range is left
/// untouched: compaction only rewrites survivors' lists, and `chosen`
/// is never its own neighbor (no self loops), so iterating its prefix
/// by index during the loop is safe.
fn shrink_lists(
    adj: &mut [u32],
    offsets: &[u32],
    deg: &mut [Vec<u32>],
    alive: &mut [u32],
    depth: usize,
    chosen: usize,
) -> u32 {
    let c_start = offsets[chosen] as usize;
    let n_surv = deg[depth - 1][chosen] as usize;
    for i in 0..n_surv {
        let v = adj[c_start + i] as usize;
        alive[v] = depth as u32;
    }
    for i in 0..n_surv {
        let v = adj[c_start + i] as usize;
        let start = offsets[v] as usize;
        let old_deg = deg[depth - 1][v] as usize;
        let mut keep = 0usize;
        for j in 0..old_deg {
            let w = adj[start + j];
            if alive[w as usize] >= depth as u32 {
                adj.swap(start + keep, start + j);
                keep += 1;
            }
        }
        deg[depth][v] = keep as u32;
    }
    n_surv as u32
}

/// Undo [`shrink_lists`] at `depth` (drop survivor markings). Adjacency
/// permutations don't need undoing: list *prefixes* per depth remain
/// valid because deeper compactions only permute within the prefix of
/// shallower depths.
fn unshrink_lists(
    adj: &[u32],
    offsets: &[u32],
    deg: &[Vec<u32>],
    alive: &mut [u32],
    depth: usize,
    chosen: usize,
) {
    let s = offsets[chosen] as usize;
    let d = deg[depth - 1][chosen] as usize;
    for i in 0..d {
        let v = adj[s + i] as usize;
        if alive[v] >= depth as u32 {
            alive[v] = depth as u32 - 1;
        }
    }
}

/// Clique-only shrinking local graph over a DAG out-neighborhood
/// (kClist; paper Listing 4). See the module docs for the relation to
/// [`PlanLocalGraph`].
pub struct LocalGraph {
    /// Local-id adjacency, flat; lists mutate in place across depths.
    adj: Vec<u32>,
    offsets: Vec<u32>,
    /// deg[depth][v_local]
    deg: Vec<Vec<u32>>,
    /// label[v_local] = deepest level at which the vertex is still alive.
    alive: Vec<u32>,
    /// Map local id -> global vertex.
    globals: Vec<VertexId>,
    num_local: usize,
    max_depth: usize,
}

impl LocalGraph {
    /// Allocate for local graphs of up to `max_vertices` vertices and
    /// shrink depth `max_depth` (both grown on demand by `init`).
    pub fn new(max_vertices: usize, max_depth: usize) -> Self {
        Self {
            adj: Vec::new(),
            offsets: vec![0; max_vertices + 1],
            deg: vec![vec![0; max_vertices]; max_depth + 1],
            alive: vec![0; max_vertices],
            globals: vec![0; max_vertices],
            num_local: 0,
            max_depth,
        }
    }

    /// `initLG`: build the local graph induced by the out-neighborhood of
    /// `root` in the DAG (vertices = out(root); edges = DAG edges among
    /// them). Returns the number of local vertices.
    pub fn init_from_dag(&mut self, dag: &Dag, root: VertexId) -> usize {
        let nbrs = dag.out_neighbors(root);
        let n = nbrs.len();
        self.num_local = n;
        if self.deg[0].len() < n {
            for d in &mut self.deg {
                d.resize(n, 0);
            }
            self.alive.resize(n, 0);
            self.globals.resize(n, 0);
            self.offsets.resize(n + 1, 0);
        }
        self.globals[..n].copy_from_slice(nbrs);
        for a in self.alive[..n].iter_mut() {
            *a = 0;
        }
        // adjacency among locals: intersect out(u) with nbrs
        self.adj.clear();
        self.offsets[0] = 0;
        for (i, &u) in nbrs.iter().enumerate() {
            let mut d = 0u32;
            let (mut a, mut b) = (0usize, 0usize);
            let out_u = dag.out_neighbors(u);
            while a < out_u.len() && b < n {
                let (x, y) = (out_u[a], nbrs[b]);
                if x == y {
                    self.adj.push(b as u32); // local id of the target
                    d += 1;
                    a += 1;
                    b += 1;
                } else if x < y {
                    a += 1;
                } else {
                    b += 1;
                }
            }
            self.deg[0][i] = d;
            self.offsets[i + 1] = self.adj.len() as u32;
        }
        n
    }

    /// Number of local vertices in the current local graph.
    pub fn num_vertices(&self) -> usize {
        self.num_local
    }

    /// Global vertex id behind local id `local`.
    pub fn global(&self, local: usize) -> VertexId {
        self.globals[local]
    }

    /// Local out-degree of `local` at `depth`.
    #[inline]
    pub fn degree(&self, depth: usize, local: usize) -> u32 {
        self.deg[depth][local]
    }

    /// Adjacency prefix of `local` valid at `depth` (the surviving
    /// neighbors).
    #[inline]
    pub fn adj(&self, depth: usize, local: usize) -> &[u32] {
        let s = self.offsets[local] as usize;
        &self.adj[s..s + self.deg[depth][local] as usize]
    }

    /// Whether `local` survived every shrink up to `depth`.
    #[inline]
    pub fn is_alive(&self, depth: usize, local: usize) -> bool {
        self.alive[local] >= depth as u32
    }

    /// `updateLG`: descend to `depth`, keeping only vertices adjacent to
    /// `chosen` (local id) that are alive at depth-1 (the shared
    /// `shrink_lists` mechanic; no allocation — §Perf: the original
    /// `to_vec` here cost ~2x on the k-CL hot path).
    pub fn shrink(&mut self, depth: usize, chosen: usize) -> u32 {
        debug_assert!(depth <= self.max_depth);
        let Self { adj, offsets, deg, alive, .. } = self;
        shrink_lists(adj, offsets, deg, alive, depth, chosen)
    }

    /// Undo `shrink` at `depth` (drop survivor markings).
    pub fn unshrink(&mut self, depth: usize, chosen: usize) {
        let Self { adj, offsets, deg, alive, .. } = self;
        unshrink_lists(adj, offsets, deg, alive, depth, chosen);
    }

    /// Survivor local-ids at `depth` reachable from `chosen`'s list at
    /// depth-1 (the candidate set for the next level).
    pub fn candidates(&self, depth: usize, chosen: usize) -> &[u32] {
        let s = self.offsets[chosen] as usize;
        &self.adj[s..s + self.deg[depth - 1][chosen] as usize]
    }

    /// In-place candidate access (no slice borrow held across recursion).
    #[inline]
    pub fn candidate_at(&self, chosen: usize, i: usize) -> u32 {
        self.adj[self.offsets[chosen] as usize + i]
    }
}

/// One vertex pushed into a [`PlanLocalGraph`] descent.
struct LgFrame {
    /// Local id of the chosen vertex.
    local: u32,
    /// Shrink depth at push time — the vertex's adjacency prefix at this
    /// depth is its valid candidate list for deeper levels (rows above
    /// it are never written for this vertex once it stops surviving).
    sd_at: u32,
    /// Whether this push performed a kClist shrink (cone level).
    cone: bool,
}

/// Shrinking local graph for **arbitrary matching plans** (the
/// generalization of the paper's clique-only LG; see module docs).
///
/// Lifecycle, driven by [`crate::engine::dfs`] when `OptFlags::lg` is
/// set and the crossover heuristic fires:
///
/// 1. [`init`](PlanLocalGraph::init) — build the local universe from
///    the union of the neighborhoods named by the plan's `lg_pre_mask`,
///    the local adjacency among universe members, the per-vertex
///    embedding-adjacency bitmasks for every position in
///    `lg_touch_mask`, and one sorted candidate list per pre-LG source
///    position.
/// 2. [`push`](PlanLocalGraph::push) / [`pop`](PlanLocalGraph::pop) —
///    O(touched edges) descent bookkeeping: mark/unmark the new
///    position's adjacency bit on the chosen vertex's local neighbors,
///    and shrink/unshrink the graph at cone levels.
/// 3. [`copy_source`](PlanLocalGraph::copy_source) — materialize a
///    bounded candidate seed list; the engine then filters each seed
///    element with one [`embadj`](PlanLocalGraph::embadj) mask test.
///
/// Local ids are assigned in ascending global-id order, so
/// symmetry-breaking bounds translate once per level into a local-id
/// range ([`local_range`](PlanLocalGraph::local_range)) instead of
/// being re-checked per candidate.
#[derive(Default)]
pub struct PlanLocalGraph {
    /// Local-id adjacency, flat; lists mutate in place across depths.
    adj: Vec<u32>,
    offsets: Vec<u32>,
    /// deg[shrink_depth][v_local]
    deg: Vec<Vec<u32>>,
    /// label[v_local] = deepest shrink the vertex survived.
    alive: Vec<u32>,
    /// Map local id -> global vertex, sorted ascending.
    globals: Vec<VertexId>,
    /// embadj[v_local] bit p = v is adjacent to the vertex matched at
    /// embedding position p (pre-LG positions filled at init, LG-phase
    /// positions maintained by push/pop).
    embadj: Vec<u32>,
    /// pre[j] = sorted local ids adjacent to emb[j], for every adjacency
    /// source position j < base named by the plan's suffix.
    pre: Vec<Vec<u32>>,
    /// Vertices chosen during the LG phase, by position - base.
    stack: Vec<LgFrame>,
    num_local: usize,
    /// Embedding length at init (= the plan level of the switch).
    base: usize,
    /// Current shrink depth (= number of cone frames on the stack).
    sd: usize,
}

impl PlanLocalGraph {
    /// Empty local graph; all storage is grown on first
    /// [`init`](PlanLocalGraph::init) and reused across root tasks.
    pub fn new() -> Self {
        Self::default()
    }

    /// `initLG`, generalized: build the local graph for the partial
    /// embedding `emb`. The vertex universe is the union of
    /// `N(emb[j])` over the positions `j` in `pre_mask` (the plan's
    /// [`lg_pre_mask`](crate::pattern::matching_order::LevelPlan::lg_pre_mask)
    /// — every candidate of every remaining level lies in it), minus
    /// the embedding itself. `touch_mask` names the additional
    /// positions (non-adjacency sources) whose adjacency bit must be
    /// precomputed. `depth_budget` bounds the number of cone shrinks
    /// (the plan size is always enough). Returns the universe size.
    pub fn init(
        &mut self,
        g: &CsrGraph,
        emb: &[VertexId],
        pre_mask: u32,
        touch_mask: u32,
        depth_budget: usize,
    ) -> usize {
        self.base = emb.len();
        self.sd = 0;
        self.stack.clear();
        debug_assert!(pre_mask != 0);
        debug_assert_eq!(pre_mask & !((1u32 << self.base) - 1), 0);

        // ---- universe: union of the named neighborhoods, minus emb
        self.globals.clear();
        let mut m = pre_mask;
        while m != 0 {
            let j = m.trailing_zeros() as usize;
            m &= m - 1;
            self.globals.extend_from_slice(g.neighbors(emb[j]));
        }
        self.globals.sort_unstable();
        self.globals.dedup();
        self.globals.retain(|v| !emb.contains(v));
        let n = self.globals.len();
        self.num_local = n;
        if n == 0 {
            return 0;
        }

        // ---- storage (grown once, reused across tasks)
        if self.alive.len() < n {
            self.alive.resize(n, 0);
            self.embadj.resize(n, 0);
            self.offsets.resize(n + 1, 0);
        }
        for a in self.alive[..n].iter_mut() {
            *a = 0;
        }
        for e in self.embadj[..n].iter_mut() {
            *e = 0;
        }
        while self.deg.len() <= depth_budget {
            self.deg.push(Vec::new());
        }
        for row in self.deg.iter_mut() {
            if row.len() < n {
                row.resize(n, 0);
            }
        }
        if self.pre.len() < self.base {
            self.pre.resize_with(self.base, Vec::new);
        }

        // ---- adjacency among universe members (sorted by local id at
        // depth 0; deeper prefixes are unordered after shrinks)
        let Self { adj, offsets, deg, globals, .. } = self;
        adj.clear();
        offsets[0] = 0;
        for i in 0..n {
            let mut d = 0u32;
            for_each_common(g.neighbors(globals[i]), &globals[..n], |b| {
                adj.push(b as u32);
                d += 1;
            });
            deg[0][i] = d;
            offsets[i + 1] = adj.len() as u32;
        }

        // ---- embedding-adjacency bits + pre-LG candidate lists
        let Self { globals, embadj, pre, .. } = self;
        let mut m = touch_mask | pre_mask;
        while m != 0 {
            let j = m.trailing_zeros() as usize;
            m &= m - 1;
            let bit = 1u32 << j;
            let want_list = pre_mask >> j & 1 == 1;
            if want_list {
                pre[j].clear();
            }
            let list = &mut pre[j];
            for_each_common(g.neighbors(emb[j]), &globals[..n], |b| {
                embadj[b] |= bit;
                if want_list {
                    list.push(b as u32);
                }
            });
        }
        n
    }

    /// Number of local vertices in the current universe.
    pub fn num_vertices(&self) -> usize {
        self.num_local
    }

    /// Global vertex id behind local id `local`.
    #[inline]
    pub fn global(&self, local: usize) -> VertexId {
        self.globals[local]
    }

    /// Adjacency bitmask of `local` against the partial embedding (one
    /// bit per matched position; see the struct docs).
    #[inline]
    pub fn embadj(&self, local: usize) -> u32 {
        self.embadj[local]
    }

    /// Current shrink depth (number of cone levels on the stack).
    pub fn shrink_depth(&self) -> usize {
        self.sd
    }

    /// Deepest shrink `local` survived (the raw `alive` label).
    pub fn alive_label(&self, local: usize) -> u32 {
        self.alive[local]
    }

    /// Local degree of `local` at shrink depth `depth`.
    pub fn degree(&self, depth: usize, local: usize) -> u32 {
        self.deg[depth][local]
    }

    /// Adjacency prefix of `local` valid at shrink depth `depth`.
    pub fn adj_prefix(&self, depth: usize, local: usize) -> &[u32] {
        let s = self.offsets[local] as usize;
        &self.adj[s..s + self.deg[depth][local] as usize]
    }

    /// Translate global symmetry-breaking bounds (`cand > lo`,
    /// `cand < hi`) into a half-open local-id range — valid because
    /// local ids are assigned in ascending global order.
    pub fn local_range(&self, lo: Option<VertexId>, hi: Option<VertexId>) -> (u32, u32) {
        let g = &self.globals[..self.num_local];
        let s = lo.map_or(0, |l| g.partition_point(|&x| x <= l));
        let e = hi.map_or(self.num_local, |h| g.partition_point(|&x| x < h));
        (s as u32, e as u32)
    }

    /// Length of the candidate source list for plan position `pos`:
    /// the precomputed list for pre-LG positions, the chosen vertex's
    /// valid adjacency prefix for LG-phase positions.
    pub fn source_len(&self, pos: usize) -> usize {
        if pos < self.base {
            self.pre[pos].len()
        } else {
            let f = &self.stack[pos - self.base];
            self.deg[f.sd_at as usize][f.local as usize] as usize
        }
    }

    /// Append the source list for `pos`, restricted to the local-id
    /// range `[lo, hi)` (from [`local_range`](Self::local_range)), onto
    /// `out`. Pre-LG lists are sorted, so the bounds are fused by
    /// binary search; LG-phase prefixes are unordered after shrinks and
    /// are filtered element-wise. The copy (rather than iterating the
    /// prefix in place) keeps the list stable while deeper shrinks
    /// permute it.
    pub fn copy_source(&self, pos: usize, lo: u32, hi: u32, out: &mut Vec<u32>) {
        if pos < self.base {
            let list = &self.pre[pos];
            let s = list.partition_point(|&u| u < lo);
            let e = list.partition_point(|&u| u < hi);
            out.extend_from_slice(&list[s..e]);
        } else {
            let f = &self.stack[pos - self.base];
            let start = self.offsets[f.local as usize] as usize;
            let len = self.deg[f.sd_at as usize][f.local as usize] as usize;
            for &u in &self.adj[start..start + len] {
                if u >= lo && u < hi {
                    out.push(u);
                }
            }
        }
    }

    /// Dense-mode candidate scan (EXPERIMENTS.md §PR-3): append every
    /// local id in `[lo, hi)` whose embedding-adjacency mask satisfies
    /// `mask & want == want && mask & veto == 0`, via the vectorized
    /// mask kernel in [`crate::graph::setops`] (8 masks per compare on
    /// AVX2) instead of per-bit tests on a copied seed list.
    ///
    /// Equivalent to seeding from any adjacency source named in `want`
    /// and then mask-filtering: a mask-passing vertex carries the bit
    /// of every source position, and a bit is set exactly for members
    /// of that source's candidate list (pre-LG lists at init, valid
    /// shrink prefixes at push) — so membership is implied and only
    /// the mask test remains. Output is ascending by local id.
    pub fn collect_candidates(
        &self,
        lo: u32,
        hi: u32,
        want: u32,
        veto: u32,
        out: &mut Vec<u32>,
    ) {
        crate::graph::setops::mask_filter_into(
            &self.embadj[lo as usize..hi as usize],
            lo,
            want,
            veto,
            out,
        );
    }

    /// Record `local` as the match for the next embedding position:
    /// set that position's adjacency bit on every valid local neighbor,
    /// and — when `cone` (the level constrains all deeper levels) —
    /// perform the kClist shrink. O(touched edges).
    pub fn push(&mut self, local: usize, cone: bool) {
        let pos_bit = 1u32 << (self.base + self.stack.len());
        let sd_at = self.sd;
        // a legal candidate survived every shrink so far, so its
        // adjacency prefix at the current depth is valid
        debug_assert!(self.alive[local] as usize >= sd_at);
        let start = self.offsets[local] as usize;
        let len = self.deg[sd_at][local] as usize;
        for i in start..start + len {
            self.embadj[self.adj[i] as usize] |= pos_bit;
        }
        self.stack.push(LgFrame { local: local as u32, sd_at: sd_at as u32, cone });
        if cone {
            self.sd += 1;
            let depth = self.sd;
            let Self { adj, offsets, deg, alive, .. } = self;
            shrink_lists(adj, offsets, deg, alive, depth, local);
        }
    }

    /// Undo the matching [`push`](Self::push): unshrink (if a cone
    /// level) and clear the position's adjacency bits. The bit-clearing
    /// prefix is identical to the one marked at push time — deeper
    /// shrinks only permute *within* it and never change its length.
    pub fn pop(&mut self) {
        let f = self.stack.pop().expect("PlanLocalGraph::pop without push");
        let local = f.local as usize;
        if f.cone {
            let depth = self.sd;
            {
                let Self { adj, offsets, deg, alive, .. } = self;
                unshrink_lists(adj, offsets, deg, alive, depth, local);
            }
            self.sd -= 1;
            debug_assert_eq!(self.sd, f.sd_at as usize);
        }
        let pos_bit = 1u32 << (self.base + self.stack.len());
        let start = self.offsets[local] as usize;
        let len = self.deg[f.sd_at as usize][local] as usize;
        for i in start..start + len {
            self.embadj[self.adj[i] as usize] &= !pos_bit;
        }
    }
}

/// Visit the positions in `globals` whose value also appears in sorted
/// `nbrs`, in ascending order — the universe-membership merge used by
/// [`PlanLocalGraph::init`]. Adaptive like the
/// [`crate::graph::setops`] kernels: binary-search the shorter side
/// when the lengths are skewed by more than 8x, lockstep merge
/// otherwise.
fn for_each_common(nbrs: &[VertexId], globals: &[VertexId], mut f: impl FnMut(usize)) {
    if nbrs.len() > globals.len().saturating_mul(8) {
        for (b, &gv) in globals.iter().enumerate() {
            if nbrs.binary_search(&gv).is_ok() {
                f(b);
            }
        }
    } else if globals.len() > nbrs.len().saturating_mul(8) {
        for &x in nbrs {
            if let Ok(b) = globals.binary_search(&x) {
                f(b);
            }
        }
    } else {
        let (mut a, mut b) = (0usize, 0usize);
        while a < nbrs.len() && b < globals.len() {
            let (x, y) = (nbrs[a], globals[b]);
            if x == y {
                f(b);
                a += 1;
                b += 1;
            } else if x < y {
                a += 1;
            } else {
                b += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::graph::orientation::{orient, OrientScheme};
    use crate::pattern::{library, plan, MatchingPlan};
    use crate::util::rng::Rng;

    #[test]
    fn init_builds_neighborhood_subgraph() {
        let g = gen::complete(5);
        let dag = orient(&g, OrientScheme::Degree);
        let mut lg = LocalGraph::new(8, 5);
        // root = rank-0 vertex: its out-neighborhood is the other 4
        let root = (0..5u32).find(|&v| dag.out_degree(v) == 4).unwrap();
        let n = lg.init_from_dag(&dag, root);
        assert_eq!(n, 4);
        // local graph of K5's neighborhood is the DAG on K4: degrees 3,2,1,0
        let mut degs: Vec<u32> = (0..4).map(|v| lg.degree(0, v)).collect();
        degs.sort_unstable();
        assert_eq!(degs, vec![0, 1, 2, 3]);
    }

    #[test]
    fn shrink_keeps_only_common_neighbors() {
        let g = gen::complete(5);
        let dag = orient(&g, OrientScheme::Core);
        let mut lg = LocalGraph::new(8, 5);
        let root = (0..5u32).find(|&v| dag.out_degree(v) == 4).unwrap();
        lg.init_from_dag(&dag, root);
        // choose the local vertex with max local out-degree (3)
        let chosen = (0..4).max_by_key(|&v| lg.degree(0, v)).unwrap();
        let survivors = lg.shrink(1, chosen);
        assert_eq!(survivors, 3);
        lg.unshrink(1, chosen);
    }

    #[test]
    fn shrink_unshrink_restores_depth0_view() {
        let g = gen::rmat(7, 8, 2, &[]);
        let dag = orient(&g, OrientScheme::Core);
        let mut lg = LocalGraph::new(g.max_degree() + 1, 6);
        for root in 0..g.num_vertices() as u32 {
            if dag.out_degree(root) < 2 {
                continue;
            }
            let n = lg.init_from_dag(&dag, root);
            let before: Vec<Vec<u32>> = (0..n)
                .map(|v| {
                    let mut a = lg.adj(0, v).to_vec();
                    a.sort_unstable();
                    a
                })
                .collect();
            let chosen = (0..n).max_by_key(|&v| lg.degree(0, v)).unwrap();
            lg.shrink(1, chosen);
            lg.unshrink(1, chosen);
            for v in 0..n {
                let mut a = lg.adj(0, v).to_vec();
                a.sort_unstable();
                assert_eq!(a, before[v], "root {root} local {v}");
            }
            break;
        }
    }

    // ---------- PlanLocalGraph ----------

    #[test]
    fn plan_lg_universe_and_bits_for_diamond_prefix() {
        // K4: init after matching the first diamond chord vertex
        let g = gen::complete(4);
        let pl = plan(&library::diamond(), true, true);
        assert_eq!(pl.lg_level, 1);
        let lp = &pl.levels[1];
        let mut lg = PlanLocalGraph::new();
        let emb = [0u32];
        let n = lg.init(&g, &emb, lp.lg_pre_mask, lp.lg_touch_mask, pl.size());
        // universe = N(0) = {1, 2, 3}
        assert_eq!(n, 3);
        assert_eq!((0..n).map(|u| lg.global(u)).collect::<Vec<_>>(), vec![1, 2, 3]);
        // all universe members are adjacent to position 0
        for u in 0..n {
            assert_eq!(lg.embadj(u) & 1, 1);
            assert_eq!(lg.degree(0, u), 2); // K3 among locals
        }
        // pre list for position 0 covers the whole universe, sorted
        assert_eq!(lg.source_len(0), 3);
        let mut out = Vec::new();
        lg.copy_source(0, 0, n as u32, &mut out);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn plan_lg_local_range_translates_bounds() {
        let g = gen::complete(5);
        let pl = plan(&library::clique(3), true, true);
        let mut lg = PlanLocalGraph::new();
        let lp = &pl.levels[1];
        lg.init(&g, &[2u32], lp.lg_pre_mask, lp.lg_touch_mask, pl.size());
        // universe = {0, 1, 3, 4}
        assert_eq!(lg.num_vertices(), 4);
        // cand > 2 keeps locals {3, 4} = local ids {2, 3}
        assert_eq!(lg.local_range(Some(2), None), (2, 4));
        // cand < 4 keeps globals {0, 1, 3} = local ids {0, 1, 2}
        assert_eq!(lg.local_range(None, Some(4)), (0, 3));
        assert_eq!(lg.local_range(Some(0), Some(3)), (1, 2));
    }

    #[test]
    fn plan_lg_collect_candidates_matches_manual_filter() {
        let g = gen::rmat(7, 6, 9, &[]);
        let pl = plan(&library::diamond(), true, true);
        let lp = &pl.levels[pl.lg_level];
        let mut lg = PlanLocalGraph::new();
        let mut checked = 0;
        for root in 0..g.num_vertices() as u32 {
            let emb = [root];
            let n = lg.init(&g, &emb, lp.lg_pre_mask, lp.lg_touch_mask, pl.size());
            if n < 4 {
                continue;
            }
            for (lo, hi) in [(0u32, n as u32), (1, n as u32 - 1)] {
                let mut got = Vec::new();
                lg.collect_candidates(lo, hi, lp.adj_mask, lp.nonadj_mask, &mut got);
                let want: Vec<u32> = (lo..hi)
                    .filter(|&u| {
                        let ea = lg.embadj(u as usize);
                        ea & lp.adj_mask == lp.adj_mask && ea & lp.nonadj_mask == 0
                    })
                    .collect();
                assert_eq!(got, want, "root {root} range [{lo},{hi})");
            }
            checked += 1;
            if checked >= 5 {
                break;
            }
        }
        assert!(checked > 0, "no usable roots");
    }

    /// Random legal descent through a plan: push candidates that satisfy
    /// the embadj constraints, snapshotting (alive, per-depth degrees,
    /// prefix sets) before each push and checking exact restoration
    /// after the matching pop — the LG push/pop invariants.
    fn walk_and_check(
        pl: &MatchingPlan,
        lg: &mut PlanLocalGraph,
        emb: &mut Vec<u32>,
        level: usize,
        rng: &mut Rng,
        budget: &mut u32,
    ) {
        if level == pl.size() || *budget == 0 {
            return;
        }
        *budget -= 1;
        let lp = &pl.levels[level];
        let n = lg.num_vertices();
        let sd = lg.shrink_depth();
        let cands: Vec<usize> = (0..n)
            .filter(|&u| {
                lg.embadj(u) & lp.adj_mask == lp.adj_mask
                    && lg.embadj(u) & lp.nonadj_mask == 0
                    && !emb.contains(&lg.global(u))
            })
            .collect();
        // candidates implied alive by the cone-adjacency argument
        for &u in &cands {
            assert!(lg.alive_label(u) >= sd as u32, "candidate not alive");
        }
        // explore a couple of random branches
        for _ in 0..2 {
            if cands.is_empty() {
                break;
            }
            let u = cands[rng.below(cands.len() as u64) as usize];
            let snap_alive: Vec<u32> = (0..n).map(|v| lg.alive_label(v)).collect();
            let snap_deg: Vec<Vec<u32>> =
                (0..=sd).map(|d| (0..n).map(|v| lg.degree(d, v)).collect()).collect();
            // depth-sd rows are only valid (written this task) for
            // vertices alive at sd; dead vertices keep stale counts
            // from earlier tasks, so their prefixes must not be sliced
            let snap_pfx: Vec<Option<Vec<u32>>> = (0..n)
                .map(|v| {
                    if sd == 0 || lg.alive_label(v) >= sd as u32 {
                        let mut p = lg.adj_prefix(sd, v).to_vec();
                        p.sort_unstable();
                        Some(p)
                    } else {
                        None
                    }
                })
                .collect();
            let snap_emb: Vec<u32> = (0..n).map(|v| lg.embadj(v)).collect();

            emb.push(lg.global(u));
            lg.push(u, lp.lg_cone);
            if lp.lg_cone {
                // alive labels never regress below their pre-push value:
                // survivors advance to the new depth, everyone else keeps
                // the old label
                for v in 0..n {
                    assert!(
                        lg.alive_label(v) == snap_alive[v]
                            || lg.alive_label(v) == sd as u32 + 1,
                        "alive regressed at {v}"
                    );
                }
            }
            walk_and_check(pl, lg, emb, level + 1, rng, budget);
            lg.pop();
            emb.pop();

            for v in 0..n {
                assert_eq!(lg.alive_label(v), snap_alive[v], "alive not restored at {v}");
                assert_eq!(lg.embadj(v), snap_emb[v], "embadj not restored at {v}");
                if let Some(want) = &snap_pfx[v] {
                    let mut p = lg.adj_prefix(sd, v).to_vec();
                    p.sort_unstable();
                    assert_eq!(&p, want, "prefix set changed at {v}");
                }
            }
            for (d, row) in snap_deg.iter().enumerate() {
                for v in 0..n {
                    assert_eq!(lg.degree(d, v), row[v], "deg[{d}][{v}] not restored");
                }
            }
        }
    }

    #[test]
    fn plan_lg_push_pop_property() {
        let mut rng = Rng::seeded(0x516);
        for (pi, pat) in [
            library::clique(4),
            library::diamond(),
            library::cycle(4),
            library::cycle(5),
            library::tailed_triangle(),
        ]
        .into_iter()
        .enumerate()
        {
            let g = gen::rmat(7, 6, 31 + pi as u64, &[]);
            let pl = plan(&pat, true, true);
            let level = pl.lg_level;
            if level + 2 > pl.size() {
                continue;
            }
            let mut lg = PlanLocalGraph::new();
            let mut tried = 0;
            for root in 0..g.num_vertices() as u32 {
                // grow a legal prefix emb[0..level] by brute force
                let mut emb = vec![root];
                for l in 1..level {
                    let lp = &pl.levels[l];
                    let cand = (0..g.num_vertices() as u32).find(|&v| {
                        !emb.contains(&v)
                            && (0..l).all(|j| {
                                lp.adj_mask >> j & 1 == 0 || g.has_edge(v, emb[j])
                            })
                    });
                    match cand {
                        Some(v) => emb.push(v),
                        None => break,
                    }
                }
                if emb.len() < level {
                    continue;
                }
                let lp = &pl.levels[level];
                let n = lg.init(&g, &emb, lp.lg_pre_mask, lp.lg_touch_mask, pl.size());
                if n < 3 {
                    continue;
                }
                let mut budget = 200u32;
                walk_and_check(&pl, &mut lg, &mut emb, level, &mut rng, &mut budget);
                tried += 1;
                if tried >= 5 {
                    break;
                }
            }
            assert!(tried > 0, "no usable roots for {pat}");
        }
    }
}
