//! Support definitions (paper §2, §3.1).
//!
//! Default support is the embedding count. FSM uses *domain* (MNI)
//! support: for each pattern vertex position, the set of distinct data
//! vertices appearing there across all embeddings; support = the minimum
//! domain size. MNI is anti-monotonic, which is what lets the FSM engine
//! prune whole sub-pattern subtrees (`isSupportAntiMonotonic`).

use std::collections::HashSet;

use crate::graph::VertexId;

/// Domain (MNI) support accumulator for a pattern with `k` vertex
/// positions — the paper's `getDomainSupport` helper.
#[derive(Clone, Debug, Default)]
pub struct DomainSupport {
    /// Distinct data vertices seen at each pattern position.
    pub domains: Vec<HashSet<VertexId>>,
}

impl DomainSupport {
    /// Empty domains for a k-position pattern.
    pub fn new(k: usize) -> Self {
        Self { domains: vec![HashSet::new(); k] }
    }

    /// Fold one embedding (vertex mapping in pattern-position order).
    pub fn add(&mut self, mapping: &[VertexId]) {
        debug_assert_eq!(mapping.len(), self.domains.len());
        for (d, &v) in self.domains.iter_mut().zip(mapping) {
            d.insert(v);
        }
    }

    /// `mergeDomainSupport`: position-wise union.
    pub fn merge(&mut self, other: &DomainSupport) {
        for (a, b) in self.domains.iter_mut().zip(&other.domains) {
            a.extend(b);
        }
    }

    /// MNI support = min over positions of distinct data vertices.
    pub fn support(&self) -> u64 {
        self.domains.iter().map(|d| d.len() as u64).min().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mni_is_min_domain() {
        let mut s = DomainSupport::new(2);
        s.add(&[1, 10]);
        s.add(&[2, 10]);
        s.add(&[3, 10]);
        assert_eq!(s.support(), 1); // position 1 always maps to 10
    }

    #[test]
    fn merge_unions_positionwise() {
        let mut a = DomainSupport::new(2);
        a.add(&[1, 5]);
        let mut b = DomainSupport::new(2);
        b.add(&[2, 5]);
        b.add(&[3, 6]);
        a.merge(&b);
        assert_eq!(a.domains[0].len(), 3);
        assert_eq!(a.domains[1].len(), 2);
        assert_eq!(a.support(), 2);
    }

    #[test]
    fn mni_anti_monotonicity_on_example() {
        // embeddings of a child pattern are extensions of parent
        // embeddings, so each child domain is a subset of (a projection
        // of) the parent's — verify on a concrete instance.
        let mut parent = DomainSupport::new(2);
        let mut child = DomainSupport::new(3);
        for (a, b, c) in [(1, 2, 7), (1, 3, 8), (4, 2, 7)] {
            parent.add(&[a, b]);
            child.add(&[a, b, c]);
        }
        assert!(child.support() <= parent.support());
    }
}
