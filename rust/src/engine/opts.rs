//! Optimization flags (paper Table 3) and miner configuration.
//!
//! Every table in the evaluation is a sweep over these flags: the system
//! emulations (DESIGN.md §5) are just preset combinations.
//!
//! ```
//! use sandslash::engine::OptFlags;
//!
//! let hi = OptFlags::hi(); // all high-level optimizations (Table 3a)
//! assert!(hi.sets && hi.sb && hi.dag && !hi.lg);
//!
//! let lo = OptFlags::lo(); // Hi + local counting + shrinking local graphs
//! assert!(lo.lc && lo.lg);
//!
//! // emulated systems stay on the scalar probe path so table
//! // comparisons isolate the optimizations each system lacks
//! assert!(!OptFlags::peregrine_like().sets);
//!
//! // flags compose freely for sweeps (e.g. Fig. 8's MNC ablation)
//! let mut ablated = OptFlags::hi();
//! ablated.mnc = false;
//! assert_ne!(ablated, OptFlags::hi());
//! ```

/// One switch per optimization of the paper's Table 3 (high-level:
/// `sb`/`dag`/`mo`/`df`/`mnc`/`mec`/`sets`; low-level: `lc`/`lg`), plus
/// the `stats` toggle for Fig.-10 style search-space counters. Presets
/// ([`OptFlags::hi`], [`OptFlags::lo`], the `*_like` emulations) are
/// the sweep points used by every table in EXPERIMENTS.md.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OptFlags {
    /// Symmetry breaking via partial orders (B.1).
    pub sb: bool,
    /// Orientation: search the degree/core-ordered DAG (B.2; cliques).
    pub dag: bool,
    /// Matching order (B.3; explicit patterns).
    pub mo: bool,
    /// Degree filtering.
    pub df: bool,
    /// Memoization of neighborhood connectivity (connectivity map).
    pub mnc: bool,
    /// Memoization of embedding connectivity (carry codes down the tree).
    pub mec: bool,
    /// Set-centric extension: compute each DFS level's candidate set once
    /// with the adaptive kernels in [`crate::graph::setops`] (G²Miner /
    /// kClist-style formulation) instead of probing every neighbor of the
    /// pivot. Supersedes MNC in the generic engine when enabled.
    pub sets: bool,
    /// Low-level: formula-based local counting.
    pub lc: bool,
    /// Low-level: search on shrinking local graphs (paper §5 "LG").
    /// In the generic DFS engine this layers on `sets`: past the plan's
    /// coverage level, small frontiers switch to a
    /// [`crate::engine::local_graph::PlanLocalGraph`] and deep levels
    /// intersect degeneracy-bounded local lists instead of global CSR
    /// rows. The clique apps use the hand-tuned kClist form instead.
    pub lg: bool,
    /// Collect search-space statistics (Fig. 10).
    pub stats: bool,
}

impl OptFlags {
    /// Sandslash-Hi: all high-level optimizations (Table 3a left) plus
    /// the set-centric extension frontier.
    pub fn hi() -> Self {
        Self { sb: true, dag: true, mo: true, df: true, mnc: true, mec: true, sets: true, lc: false, lg: false, stats: false }
    }

    /// Sandslash-Lo: Hi plus low-level optimizations.
    pub fn lo() -> Self {
        Self { lc: true, lg: true, ..Self::hi() }
    }

    /// Everything off (naive enumeration with only correctness checks).
    pub fn none() -> Self {
        Self { sb: true, dag: false, mo: false, df: false, mnc: false, mec: false, sets: false, lc: false, lg: false, stats: false }
    }

    /// AutoMine-like: matching order but no symmetry breaking, no DAG —
    /// counts every automorphic copy and divides at the end (DESIGN.md §5).
    /// Emulations stay on the scalar probe path so the table comparisons
    /// keep isolating the optimizations each system lacks.
    pub fn automine_like() -> Self {
        Self { sb: false, dag: false, mo: true, df: false, mnc: false, mec: true, sets: false, lc: false, lg: false, stats: false }
    }

    /// Pangolin-like: BFS strategy (selected separately), SB + DAG but no
    /// MNC/MO/DF.
    pub fn pangolin_like() -> Self {
        Self { sb: true, dag: true, mo: false, df: false, mnc: false, mec: true, sets: false, lc: false, lg: false, stats: false }
    }

    /// Peregrine-like: DFS, on-the-fly SB and MO, but no DAG orientation.
    pub fn peregrine_like() -> Self {
        Self { sb: true, dag: false, mo: true, df: false, mnc: false, mec: true, sets: false, lc: false, lg: false, stats: false }
    }

    /// This preset with search-space statistics collection enabled.
    pub fn with_stats(mut self) -> Self {
        self.stats = true;
        self
    }
}

/// Execution configuration for one mining run: thread count, dynamic
/// self-scheduling chunk size, and the optimization flags.
#[derive(Clone, Copy, Debug)]
pub struct MinerConfig {
    /// Worker thread count (root tasks are claimed dynamically).
    pub threads: usize,
    /// Root-task chunk size for dynamic self-scheduling.
    pub chunk: usize,
    /// Optimization switches (paper Table 3).
    pub opts: OptFlags,
}

impl MinerConfig {
    /// All available cores with the default chunk size.
    pub fn new(opts: OptFlags) -> Self {
        Self { threads: crate::util::pool::default_threads(), chunk: 64, opts }
    }

    /// One worker, one chunk — deterministic sequential execution.
    pub fn single_thread(opts: OptFlags) -> Self {
        Self { threads: 1, chunk: usize::MAX, opts }
    }

    /// This configuration with an explicit thread count.
    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_differ_as_documented() {
        assert!(OptFlags::hi().sb && OptFlags::hi().mnc && !OptFlags::hi().lc);
        assert!(OptFlags::hi().sets && OptFlags::lo().sets);
        assert!(OptFlags::lo().lc && OptFlags::lo().lg);
        assert!(!OptFlags::automine_like().sb);
        assert!(!OptFlags::peregrine_like().dag && OptFlags::peregrine_like().sb);
        // emulated systems stay on the scalar probe path
        assert!(!OptFlags::automine_like().sets && !OptFlags::pangolin_like().sets);
        assert!(!OptFlags::peregrine_like().sets && !OptFlags::none().sets);
    }
}
