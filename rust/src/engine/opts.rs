//! Optimization flags (paper Table 3) and miner configuration.
//!
//! Every table in the evaluation is a sweep over these flags: the system
//! emulations (DESIGN.md §5) are just preset combinations.
//!
//! ```
//! use sandslash::engine::OptFlags;
//!
//! let hi = OptFlags::hi(); // all high-level optimizations (Table 3a)
//! assert!(hi.sets && hi.sb && hi.dag && !hi.lg);
//!
//! let lo = OptFlags::lo(); // Hi + local counting + shrinking local graphs
//! assert!(lo.lc && lo.lg);
//!
//! // emulated systems stay on the scalar probe path so table
//! // comparisons isolate the optimizations each system lacks
//! assert!(!OptFlags::peregrine_like().sets);
//!
//! // flags compose freely for sweeps (e.g. Fig. 8's MNC ablation)
//! let mut ablated = OptFlags::hi();
//! ablated.mnc = false;
//! assert_ne!(ablated, OptFlags::hi());
//! ```

use crate::exec::sched;

/// One switch per optimization of the paper's Table 3 (high-level:
/// `sb`/`dag`/`mo`/`df`/`mnc`/`mec`/`sets`; low-level: `lc`/`lg`), plus
/// the `stats` toggle for Fig.-10 style search-space counters. Presets
/// ([`OptFlags::hi`], [`OptFlags::lo`], the `*_like` emulations) are
/// the sweep points used by every table in EXPERIMENTS.md.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OptFlags {
    /// Symmetry breaking via partial orders (B.1).
    pub sb: bool,
    /// Orientation: search the degree/core-ordered DAG (B.2; cliques).
    pub dag: bool,
    /// Matching order (B.3; explicit patterns).
    pub mo: bool,
    /// Degree filtering.
    pub df: bool,
    /// Memoization of neighborhood connectivity (connectivity map).
    pub mnc: bool,
    /// Memoization of embedding connectivity (carry codes down the tree).
    pub mec: bool,
    /// Set-centric extension: compute each DFS level's candidate set once
    /// with the adaptive kernels in [`crate::graph::setops`] (G²Miner /
    /// kClist-style formulation) instead of probing every neighbor of the
    /// pivot. Supersedes MNC in the generic engine when enabled.
    pub sets: bool,
    /// Low-level: formula-based local counting.
    pub lc: bool,
    /// Low-level: search on shrinking local graphs (paper §5 "LG").
    /// In the generic DFS engine this layers on `sets`: past the plan's
    /// coverage level, small frontiers switch to a
    /// [`crate::engine::local_graph::PlanLocalGraph`] and deep levels
    /// intersect degeneracy-bounded local lists instead of global CSR
    /// rows. The clique apps use the hand-tuned kClist form instead.
    pub lg: bool,
    /// Collect search-space statistics (Fig. 10).
    pub stats: bool,
}

impl OptFlags {
    /// Sandslash-Hi: all high-level optimizations (Table 3a left) plus
    /// the set-centric extension frontier.
    pub fn hi() -> Self {
        Self { sb: true, dag: true, mo: true, df: true, mnc: true, mec: true, sets: true, lc: false, lg: false, stats: false }
    }

    /// Sandslash-Lo: Hi plus low-level optimizations.
    pub fn lo() -> Self {
        Self { lc: true, lg: true, ..Self::hi() }
    }

    /// Everything off (naive enumeration with only correctness checks).
    pub fn none() -> Self {
        Self { sb: true, dag: false, mo: false, df: false, mnc: false, mec: false, sets: false, lc: false, lg: false, stats: false }
    }

    /// AutoMine-like: matching order but no symmetry breaking, no DAG —
    /// counts every automorphic copy and divides at the end (DESIGN.md §5).
    /// Emulations stay on the scalar probe path so the table comparisons
    /// keep isolating the optimizations each system lacks.
    pub fn automine_like() -> Self {
        Self { sb: false, dag: false, mo: true, df: false, mnc: false, mec: true, sets: false, lc: false, lg: false, stats: false }
    }

    /// Pangolin-like: BFS strategy (selected separately), SB + DAG but no
    /// MNC/MO/DF.
    pub fn pangolin_like() -> Self {
        Self { sb: true, dag: true, mo: false, df: false, mnc: false, mec: true, sets: false, lc: false, lg: false, stats: false }
    }

    /// Peregrine-like: DFS, on-the-fly SB and MO, but no DAG orientation.
    pub fn peregrine_like() -> Self {
        Self { sb: true, dag: false, mo: true, df: false, mnc: false, mec: true, sets: false, lc: false, lg: false, stats: false }
    }

    /// This preset with search-space statistics collection enabled.
    pub fn with_stats(mut self) -> Self {
        self.stats = true;
        self
    }
}

/// Execution configuration for one mining run: thread count, root-task
/// grain, scheduler selection (PR 4), and the optimization flags.
#[derive(Clone, Copy, Debug)]
pub struct MinerConfig {
    /// Worker thread count (root tasks are claimed dynamically).
    pub threads: usize,
    /// Root-task grain: roots processed per scheduler interaction
    /// (default [`crate::util::pool::default_chunk`], overridable via
    /// `SANDSLASH_CHUNK`).
    pub chunk: usize,
    /// Scheduler selection: `true` (the default) runs the sharded
    /// work-stealing executor in [`crate::exec`]; `false` pins the run
    /// to the seed global-cursor loop — the *scheduling oracle* every
    /// count must agree with. Honored by the engines that resolve
    /// [`MinerConfig::sched_policy`] (the generic DFS engine, i.e. the
    /// `sl`/generic-pattern paths); the hand-tuned apps and the
    /// esu/bfs/fsm engines reach the scheduler through the fixed
    /// `util::pool` adapter signatures, which cannot see this field —
    /// pin those with the scoped
    /// [`sched::with_overrides`](crate::exec::sched::with_overrides)
    /// (what the CLI's `--no-steal` does around its whole dispatch) or
    /// the process-wide `SANDSLASH_NO_STEAL=1` kill switch, which
    /// force the oracle everywhere and outrank this flag.
    pub steal: bool,
    /// Locality shard override, same scope caveat as
    /// [`MinerConfig::steal`]; `None` uses the detected topology
    /// ([`crate::exec::topology`], `SANDSLASH_SHARDS`).
    pub shards: Option<usize>,
    /// Optimization switches (paper Table 3).
    pub opts: OptFlags,
}

impl MinerConfig {
    /// All available cores with the default grain and the stealing
    /// scheduler.
    pub fn new(opts: OptFlags) -> Self {
        Self {
            threads: crate::util::pool::default_threads(),
            chunk: crate::util::pool::default_chunk(),
            steal: true,
            shards: None,
            opts,
        }
    }

    /// One worker, one chunk — deterministic sequential execution.
    pub fn single_thread(opts: OptFlags) -> Self {
        Self { threads: 1, chunk: usize::MAX, steal: true, shards: None, opts }
    }

    /// Explicit thread count and grain (tests and sweeps); scheduler
    /// knobs stay at their defaults (stealing on, topology shards).
    pub fn custom(threads: usize, chunk: usize, opts: OptFlags) -> Self {
        Self { threads, chunk, steal: true, shards: None, opts }
    }

    /// This configuration with an explicit thread count.
    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t;
        self
    }

    /// This configuration with the scheduler pinned (`false` = the
    /// global-cursor oracle).
    pub fn with_steal(mut self, steal: bool) -> Self {
        self.steal = steal;
        self
    }

    /// This configuration with an explicit locality shard count.
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = Some(shards);
        self
    }

    /// Resolve this configuration into a scheduler policy: scoped
    /// [`sched::with_overrides`] settings win over the per-run fields,
    /// and the `SANDSLASH_NO_STEAL` kill switch wins over everything
    /// (one shared resolver,
    /// [`SchedPolicy::resolve`](sched::SchedPolicy::resolve), so this
    /// path and the adapters cannot drift).
    pub fn sched_policy(&self) -> sched::SchedPolicy {
        sched::SchedPolicy::resolve(self.threads, self.chunk, self.steal, self.shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_differ_as_documented() {
        assert!(OptFlags::hi().sb && OptFlags::hi().mnc && !OptFlags::hi().lc);
        assert!(OptFlags::hi().sets && OptFlags::lo().sets);
        assert!(OptFlags::lo().lc && OptFlags::lo().lg);
        assert!(!OptFlags::automine_like().sb);
        assert!(!OptFlags::peregrine_like().dag && OptFlags::peregrine_like().sb);
        // emulated systems stay on the scalar probe path
        assert!(!OptFlags::automine_like().sets && !OptFlags::pangolin_like().sets);
        assert!(!OptFlags::peregrine_like().sets && !OptFlags::none().sets);
    }

    #[test]
    fn scheduler_knobs_default_on_and_pin() {
        let cfg = MinerConfig::custom(4, 8, OptFlags::hi());
        assert!(cfg.steal && cfg.shards.is_none());
        let pinned = cfg.with_steal(false).with_shards(2);
        assert!(!pinned.steal);
        assert_eq!(pinned.shards, Some(2));
        let pol = pinned.sched_policy();
        assert!(!pol.steal);
        assert_eq!(pol.shards, 2);
        assert_eq!((pol.threads, pol.chunk), (4, 8));
        // scoped overrides outrank the per-run fields
        sched::with_overrides(
            sched::Overrides { steal: None, shards: Some(5) },
            || assert_eq!(pinned.sched_policy().shards, 5),
        );
    }
}
