//! Optimization flags (paper Table 3) and miner configuration.
//!
//! Every table in the evaluation is a sweep over these flags: the system
//! emulations (DESIGN.md §5) are just preset combinations.
//!
//! ```
//! use sandslash::engine::OptFlags;
//!
//! let hi = OptFlags::hi(); // all high-level optimizations (Table 3a)
//! assert!(hi.sets && hi.sb && hi.dag && !hi.lg);
//!
//! let lo = OptFlags::lo(); // Hi + local counting + shrinking local graphs
//! assert!(lo.lc && lo.lg);
//!
//! // emulated systems stay on the scalar probe path so table
//! // comparisons isolate the optimizations each system lacks...
//! assert!(!OptFlags::peregrine_like().sets);
//! // ...but every preset keeps the shared extension core (PR 5): like
//! // the SIMD kernels and the scheduler, it is an execution substrate,
//! // not a Table-3 optimization (disable via `extcore = false` or
//! // `SANDSLASH_NO_EXTCORE=1` to pin the seed scalar oracles)
//! assert!(OptFlags::pangolin_like().extcore && OptFlags::none().extcore);
//! // ...and the decomposition counting planner (PR 10), the other
//! // substrate flag (`plan = false` or `SANDSLASH_NO_PLAN=1` pins the
//! // enumerated counting oracle)
//! assert!(OptFlags::hi().plan && OptFlags::none().plan);
//!
//! // flags compose freely for sweeps (e.g. Fig. 8's MNC ablation)
//! let mut ablated = OptFlags::hi();
//! ablated.mnc = false;
//! assert_ne!(ablated, OptFlags::hi());
//! ```

use std::time::Duration;

use crate::engine::budget::Budget;
use crate::exec::sched;

/// One switch per optimization of the paper's Table 3 (high-level:
/// `sb`/`dag`/`mo`/`df`/`mnc`/`mec`/`sets`; low-level: `lc`/`lg`), plus
/// the `stats` toggle for Fig.-10 style search-space counters. Presets
/// ([`OptFlags::hi`], [`OptFlags::lo`], the `*_like` emulations) are
/// the sweep points used by every table in EXPERIMENTS.md.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OptFlags {
    /// Symmetry breaking via partial orders (B.1).
    pub sb: bool,
    /// Orientation: search the degree/core-ordered DAG (B.2; cliques).
    pub dag: bool,
    /// Matching order (B.3; explicit patterns).
    pub mo: bool,
    /// Degree filtering.
    pub df: bool,
    /// Memoization of neighborhood connectivity (connectivity map).
    pub mnc: bool,
    /// Memoization of embedding connectivity (carry codes down the tree).
    pub mec: bool,
    /// Set-centric extension: compute each DFS level's candidate set once
    /// with the adaptive kernels in [`crate::graph::setops`] (G²Miner /
    /// kClist-style formulation) instead of probing every neighbor of the
    /// pivot. Supersedes MNC in the generic engine when enabled.
    pub sets: bool,
    /// Low-level: formula-based local counting.
    pub lc: bool,
    /// Low-level: search on shrinking local graphs (paper §5 "LG").
    /// In the generic DFS engine this layers on `sets`: past the plan's
    /// coverage level, small frontiers switch to a
    /// [`crate::engine::local_graph::PlanLocalGraph`] and deep levels
    /// intersect degeneracy-bounded local lists instead of global CSR
    /// rows. The clique apps use the hand-tuned kClist form instead.
    pub lg: bool,
    /// Shared extension core (PR 5): run the ESU, BFS and FSM engines
    /// on the sorted-candidate-set machinery of
    /// [`crate::engine::extend`] instead of their seed scalar loops
    /// (visited-array probes, per-pair `has_edge` code folds,
    /// per-neighbor embedding scans). On in every preset — like the
    /// SIMD kernels and the work-stealing scheduler it is an execution
    /// substrate, not a Table-3 optimization, so the system emulations
    /// keep it too. `false` (or the process-wide
    /// `SANDSLASH_NO_EXTCORE=1` kill switch, which outranks this flag)
    /// pins the seed loops, the differential oracles.
    pub extcore: bool,
    /// Decomposition counting planner (PR 10): route count-only
    /// queries through [`crate::pattern::decompose`], which replaces
    /// per-embedding enumeration with anchor pieces plus closed-form
    /// degree formulas (inclusion–exclusion coefficients derived on
    /// the pattern itself) whenever the cost model says it wins. On in
    /// every preset — like `extcore` it is an execution substrate, not
    /// a Table-3 optimization. `false` (or the process-wide
    /// `SANDSLASH_NO_PLAN=1` kill switch, which outranks this flag)
    /// pins the enumerated path, the differential oracle.
    pub plan: bool,
    /// Collect search-space statistics (Fig. 10).
    pub stats: bool,
}

impl OptFlags {
    /// Sandslash-Hi: all high-level optimizations (Table 3a left) plus
    /// the set-centric extension frontier.
    pub fn hi() -> Self {
        Self { sb: true, dag: true, mo: true, df: true, mnc: true, mec: true, sets: true, lc: false, lg: false, extcore: true, plan: true, stats: false }
    }

    /// Sandslash-Lo: Hi plus low-level optimizations.
    pub fn lo() -> Self {
        Self { lc: true, lg: true, ..Self::hi() }
    }

    /// Everything off (naive enumeration with only correctness checks).
    pub fn none() -> Self {
        Self { sb: true, dag: false, mo: false, df: false, mnc: false, mec: false, sets: false, lc: false, lg: false, extcore: true, plan: true, stats: false }
    }

    /// AutoMine-like: matching order but no symmetry breaking, no DAG —
    /// counts every automorphic copy and divides at the end (DESIGN.md §5).
    /// Emulations stay on the scalar probe path so the table comparisons
    /// keep isolating the optimizations each system lacks.
    pub fn automine_like() -> Self {
        Self { sb: false, dag: false, mo: true, df: false, mnc: false, mec: true, sets: false, lc: false, lg: false, extcore: true, plan: true, stats: false }
    }

    /// Pangolin-like: BFS strategy (selected separately), SB + DAG but no
    /// MNC/MO/DF.
    pub fn pangolin_like() -> Self {
        Self { sb: true, dag: true, mo: false, df: false, mnc: false, mec: true, sets: false, lc: false, lg: false, extcore: true, plan: true, stats: false }
    }

    /// Peregrine-like: DFS, on-the-fly SB and MO, but no DAG orientation.
    pub fn peregrine_like() -> Self {
        Self { sb: true, dag: false, mo: true, df: false, mnc: false, mec: true, sets: false, lc: false, lg: false, extcore: true, plan: true, stats: false }
    }

    /// This preset with search-space statistics collection enabled.
    pub fn with_stats(mut self) -> Self {
        self.stats = true;
        self
    }

    /// This preset with the shared extension core switched on or off
    /// (`false` pins the ESU/BFS/FSM engines to their seed scalar
    /// oracles; sweeps and the differential tests use this).
    pub fn with_extcore(mut self, on: bool) -> Self {
        self.extcore = on;
        self
    }

    /// Whether the shared extension core actually runs: the per-run
    /// [`OptFlags::extcore`] flag gated by the process-wide
    /// `SANDSLASH_NO_EXTCORE=1` kill switch
    /// ([`crate::engine::extend::extcore_enabled_default`]), which
    /// outranks it — exactly how `SANDSLASH_NO_STEAL` outranks
    /// [`MinerConfig::steal`].
    pub fn extcore_active(&self) -> bool {
        self.extcore && crate::engine::extend::extcore_enabled_default()
    }

    /// This preset with the decomposition counting planner switched on
    /// or off (`false` pins count-only queries to the enumerated
    /// oracle; sweeps and the differential tests use this).
    pub fn with_plan(mut self, on: bool) -> Self {
        self.plan = on;
        self
    }

    /// Whether the decomposition counting planner actually runs: the
    /// per-run [`OptFlags::plan`] flag gated by the process-wide
    /// `SANDSLASH_NO_PLAN=1` kill switch
    /// ([`crate::pattern::decompose::plan_enabled_default`]), which
    /// outranks it — the same contract as [`OptFlags::extcore_active`].
    pub fn plan_active(&self) -> bool {
        self.plan && crate::pattern::decompose::plan_enabled_default()
    }
}

/// Execution configuration for one mining run: thread count, root-task
/// grain, scheduler selection (PR 4), and the optimization flags.
#[derive(Clone, Copy, Debug)]
pub struct MinerConfig {
    /// Worker thread count (root tasks are claimed dynamically).
    pub threads: usize,
    /// Root-task grain: roots processed per scheduler interaction
    /// (default [`crate::util::pool::default_chunk`], overridable via
    /// `SANDSLASH_CHUNK`).
    pub chunk: usize,
    /// Scheduler selection: `true` (the default) runs the sharded
    /// work-stealing executor in [`crate::exec`]; `false` pins the run
    /// to the seed global-cursor loop — the *scheduling oracle* every
    /// count must agree with. Honored by the engines that resolve
    /// [`MinerConfig::sched_policy`]: the generic DFS engine and,
    /// since PR 5, the ESU and FSM engines (all three fan roots
    /// through [`crate::exec::split::reduce`] and publish split
    /// tasks). The hand-tuned apps and the BFS engine still reach the
    /// scheduler through the fixed `util::pool` adapter signatures,
    /// which cannot see this field — pin those with the scoped
    /// [`sched::with_overrides`](crate::exec::sched::with_overrides)
    /// (what the CLI's `--no-steal` does around its whole dispatch) or
    /// the process-wide `SANDSLASH_NO_STEAL=1` kill switch, which
    /// force the oracle everywhere and outrank this flag.
    pub steal: bool,
    /// Locality shard override, same scope caveat as
    /// [`MinerConfig::steal`]; `None` uses the detected topology
    /// ([`crate::exec::topology`], `SANDSLASH_SHARDS`).
    pub shards: Option<usize>,
    /// Per-run resource limits (PR 6): wall-clock deadline, scheduler
    /// task budget, and the BFS level byte budget (the PR-5 `bfs_cap`,
    /// absorbed into [`Budget::bfs_bytes`]). Constructors seed it from
    /// `SANDSLASH_DEADLINE_MS` / `SANDSLASH_MAX_TASKS`; all limits
    /// default to unlimited.
    pub budget: Budget,
    /// Optimization switches (paper Table 3).
    pub opts: OptFlags,
}

impl MinerConfig {
    /// All available cores with the default grain and the stealing
    /// scheduler.
    pub fn new(opts: OptFlags) -> Self {
        Self {
            threads: crate::util::pool::default_threads(),
            chunk: crate::util::pool::default_chunk(),
            steal: true,
            shards: None,
            budget: Budget::from_env(),
            opts,
        }
    }

    /// One worker, one chunk — deterministic sequential execution.
    pub fn single_thread(opts: OptFlags) -> Self {
        Self {
            threads: 1,
            chunk: usize::MAX,
            steal: true,
            shards: None,
            budget: Budget::from_env(),
            opts,
        }
    }

    /// Explicit thread count and grain (tests and sweeps); scheduler
    /// knobs stay at their defaults (stealing on, topology shards).
    pub fn custom(threads: usize, chunk: usize, opts: OptFlags) -> Self {
        Self { threads, chunk, steal: true, shards: None, budget: Budget::from_env(), opts }
    }

    /// This configuration with an explicit thread count.
    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t;
        self
    }

    /// This configuration with the scheduler pinned (`false` = the
    /// global-cursor oracle).
    pub fn with_steal(mut self, steal: bool) -> Self {
        self.steal = steal;
        self
    }

    /// This configuration with an explicit locality shard count.
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = Some(shards);
        self
    }

    /// This configuration with an explicit BFS level byte budget
    /// (overrides the `SANDSLASH_BFS_CAP` environment resolution).
    pub fn with_bfs_cap(mut self, bytes: usize) -> Self {
        self.budget.bfs_bytes = Some(bytes);
        self
    }

    /// This configuration under an explicit [`Budget`] (replaces every
    /// limit at once).
    pub fn with_budget(mut self, budget: Budget) -> Self {
        self.budget = budget;
        self
    }

    /// This configuration with a wall-clock deadline (the clock starts
    /// when the engine entry point builds its governor).
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.budget.deadline = Some(deadline);
        self
    }

    /// This configuration with a scheduler task budget (claimed
    /// blocks + split tasks + BFS expansion blocks).
    pub fn with_max_tasks(mut self, max_tasks: u64) -> Self {
        self.budget.max_tasks = Some(max_tasks);
        self
    }

    /// Resolve this configuration into a scheduler policy: scoped
    /// [`sched::with_overrides`] settings win over the per-run fields,
    /// and the `SANDSLASH_NO_STEAL` kill switch wins over everything
    /// (one shared resolver,
    /// [`SchedPolicy::resolve`](sched::SchedPolicy::resolve), so this
    /// path and the adapters cannot drift).
    pub fn sched_policy(&self) -> sched::SchedPolicy {
        sched::SchedPolicy::resolve(self.threads, self.chunk, self.steal, self.shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_differ_as_documented() {
        assert!(OptFlags::hi().sb && OptFlags::hi().mnc && !OptFlags::hi().lc);
        assert!(OptFlags::hi().sets && OptFlags::lo().sets);
        assert!(OptFlags::lo().lc && OptFlags::lo().lg);
        assert!(!OptFlags::automine_like().sb);
        assert!(!OptFlags::peregrine_like().dag && OptFlags::peregrine_like().sb);
        // emulated systems stay on the scalar probe path
        assert!(!OptFlags::automine_like().sets && !OptFlags::pangolin_like().sets);
        assert!(!OptFlags::peregrine_like().sets && !OptFlags::none().sets);
        // ... but every preset keeps the shared extension core (a
        // substrate, not a Table-3 optimization)
        for preset in [
            OptFlags::hi(),
            OptFlags::lo(),
            OptFlags::none(),
            OptFlags::automine_like(),
            OptFlags::pangolin_like(),
            OptFlags::peregrine_like(),
        ] {
            assert!(preset.extcore);
            // the counting planner is a substrate too (PR 10)
            assert!(preset.plan);
        }
        assert!(!OptFlags::hi().with_extcore(false).extcore);
        // the kill switch can only ever pin the oracle, never force the
        // core past an explicit opt-out
        assert!(!OptFlags::hi().with_extcore(false).extcore_active());
        assert!(!OptFlags::hi().with_plan(false).plan);
        assert!(!OptFlags::hi().with_plan(false).plan_active());
    }

    #[test]
    fn budget_knobs_default_unset_and_build() {
        let cfg = MinerConfig::custom(2, 8, OptFlags::hi());
        // SANDSLASH_DEADLINE_MS / SANDSLASH_MAX_TASKS are unset in the
        // test environment, so the default budget is unlimited
        assert_eq!(cfg.budget.bfs_bytes, None);
        assert_eq!(cfg.with_bfs_cap(1 << 20).budget.bfs_bytes, Some(1 << 20));
        let limited = cfg
            .with_deadline(Duration::from_millis(250))
            .with_max_tasks(64);
        assert_eq!(limited.budget.deadline, Some(Duration::from_millis(250)));
        assert_eq!(limited.budget.max_tasks, Some(64));
        assert!(limited.budget.is_limited());
        let replaced = limited.with_budget(Budget::default());
        assert!(!replaced.budget.is_limited());
    }

    #[test]
    fn scheduler_knobs_default_on_and_pin() {
        let cfg = MinerConfig::custom(4, 8, OptFlags::hi());
        assert!(cfg.steal && cfg.shards.is_none());
        let pinned = cfg.with_steal(false).with_shards(2);
        assert!(!pinned.steal);
        assert_eq!(pinned.shards, Some(2));
        let pol = pinned.sched_policy();
        assert!(!pol.steal);
        assert_eq!(pol.shards, 2);
        assert_eq!((pol.threads, pol.chunk), (4, 8));
        // scoped overrides outrank the per-run fields
        sched::with_overrides(
            sched::Overrides { steal: None, shards: Some(5) },
            || assert_eq!(pinned.sched_policy().shards, 5),
        );
    }
}
