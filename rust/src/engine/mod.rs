//! The Sandslash mining engines and two-level API.
//!
//! * [`spec`] — high-level problem specification (paper Table 1)
//! * [`hooks`] — low-level API (paper Listing 1)
//! * [`dfs`] — pattern-guided DFS over matching plans
//! * [`esu`] — pattern-oblivious exact-once vertex-induced enumeration
//! * [`bfs`] — level-synchronous engine (Pangolin-like emulation)
//! * [`fsm`] — sub-pattern-tree DFS for frequent subgraph mining
//! * [`extend`] — the shared extension core (PR 5): sorted-candidate-set
//!   construction on the adaptive kernels, used by ESU/BFS/FSM (the DFS
//!   engine has its own set-centric frontier)
//! * [`local_graph`] — kClist-style shrinking local graphs (LG)
//! * [`embedding`], [`mnc`] — MEC codes and the MNC connectivity map
//! * [`support`] — count and MNI/domain supports
//! * [`opts`] — optimization flags and presets (paper Table 3)
//! * [`budget`] — query governance (PR 6): per-run budgets, cooperative
//!   cancellation, worker panic isolation, and the unified
//!   [`MineError`] surface every engine entry point returns

pub mod bfs;
pub mod budget;
pub mod dfs;
pub mod embedding;
pub mod esu;
pub mod extend;
pub mod fsm;
pub mod hooks;
pub mod local_graph;
pub mod mnc;
pub mod opts;
pub mod spec;
pub mod support;

pub use budget::{Budget, CancelReason, CancelToken, MineError, Outcome};
pub use opts::{MinerConfig, OptFlags};
pub use spec::ProblemSpec;
