//! Embedding state for DFS exploration, with Memoization of Embedding
//! Connectivity (MEC): each embedding vertex carries its *connectivity
//! code* — a bit-vector over earlier positions it is adjacent to (paper
//! Fig. 4 / Fig. 13). Codes are pushed and popped with the DFS so leaf
//! classification never re-touches the input graph.

use crate::graph::{CsrGraph, VertexId};
use crate::pattern::Pattern;

#[derive(Debug, Default, Clone)]
/// Embedding stack with per-vertex MEC connectivity codes.
pub struct Embedding {
    verts: Vec<VertexId>,
    codes: Vec<u32>,
}

impl Embedding {
    /// Pre-size for a k-vertex pattern.
    pub fn with_capacity(k: usize) -> Self {
        Self { verts: Vec::with_capacity(k), codes: Vec::with_capacity(k) }
    }

    #[inline]
    /// Push a vertex with its connectivity code.
    pub fn push(&mut self, v: VertexId, code: u32) {
        self.verts.push(v);
        self.codes.push(code);
    }

    #[inline]
    /// Pop the deepest vertex (and its code).
    pub fn pop(&mut self) {
        self.verts.pop();
        self.codes.pop();
    }

    #[inline]
    /// Current embedding size.
    pub fn len(&self) -> usize {
        self.verts.len()
    }

    /// True when no vertices are matched.
    pub fn is_empty(&self) -> bool {
        self.verts.is_empty()
    }

    #[inline]
    /// Matched vertices, in matching order.
    pub fn verts(&self) -> &[VertexId] {
        &self.verts
    }

    #[inline]
    /// Connectivity codes, parallel to `verts`.
    pub fn codes(&self) -> &[u32] {
        &self.codes
    }

    #[inline]
    /// Vertex matched at `pos`.
    pub fn vertex(&self, pos: usize) -> VertexId {
        self.verts[pos]
    }

    #[inline]
    /// Injectivity check: is `v` already matched?
    pub fn contains(&self, v: VertexId) -> bool {
        self.verts.contains(&v)
    }

    /// Recompute the connectivity code of `v` against the current
    /// embedding from the input graph (the MEC-off path).
    pub fn compute_code(&self, g: &CsrGraph, v: VertexId) -> u32 {
        let mut code = 0u32;
        for (i, &u) in self.verts.iter().enumerate() {
            if g.has_edge(u, v) {
                code |= 1 << i;
            }
        }
        code
    }
}

/// Pack per-position connectivity codes into a single integer key.
/// Position i contributes i bits (position 0 has none), so a k-vertex
/// embedding packs into k(k-1)/2 bits — 10 bits for k = 5.
#[inline]
pub fn pack_codes(codes: &[u32]) -> u64 {
    let mut key = 0u64;
    let mut shift = 0u32;
    for (i, &c) in codes.iter().enumerate().skip(1) {
        key |= ((c as u64) & ((1 << i) - 1)) << shift;
        shift += i as u32;
    }
    key
}

/// Rebuild the pattern structure of an embedding from packed codes
/// (paper Fig. 13: "with this code we can rebuild the exact structure").
pub fn pattern_from_packed(k: usize, key: u64) -> Pattern {
    let mut p = Pattern::new(k);
    let mut shift = 0u32;
    for i in 1..k {
        let code = (key >> shift) & ((1 << i) - 1);
        for j in 0..i {
            if code >> j & 1 == 1 {
                p.add_edge(j, i);
            }
        }
        shift += i as u32;
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::pattern::{canonical_code, library};

    #[test]
    fn push_pop_tracks_codes() {
        let mut e = Embedding::with_capacity(3);
        e.push(10, 0);
        e.push(20, 0b1);
        e.push(30, 0b11);
        assert_eq!(e.len(), 3);
        assert_eq!(e.codes(), &[0, 0b1, 0b11]);
        e.pop();
        assert_eq!(e.len(), 2);
    }

    #[test]
    fn compute_code_matches_graph() {
        // diamond: 0-1, 0-2, 1-2, 1-3, 2-3
        let g = GraphBuilder::from_edges(4, &[(0, 1), (0, 2), (1, 2), (1, 3), (2, 3)]).build();
        let mut e = Embedding::with_capacity(4);
        e.push(0, 0);
        e.push(1, 0b1);
        e.push(2, 0b11);
        // vertex 3 adjacent to 1 (pos 1) and 2 (pos 2), not 0 (pos 0)
        assert_eq!(e.compute_code(&g, 3), 0b110);
    }

    #[test]
    fn fig13_roundtrip() {
        // Paper Fig. 13: embedding code {1,1,1,1,0,1} rebuilds the
        // structure. Here: codes per position [., 1, 11, 101].
        let codes = [0u32, 0b1, 0b11, 0b101];
        let key = pack_codes(&codes);
        let p = pattern_from_packed(4, key);
        assert!(p.has_edge(0, 1) && p.has_edge(0, 2) && p.has_edge(1, 2));
        assert!(p.has_edge(0, 3) && p.has_edge(2, 3) && !p.has_edge(1, 3));
    }

    #[test]
    fn packed_triangle_is_triangle() {
        let key = pack_codes(&[0, 0b1, 0b11]);
        let p = pattern_from_packed(3, key);
        assert_eq!(canonical_code(&p), canonical_code(&library::triangle()));
    }
}
