//! The shared extension core (PR 5): sorted-candidate-set construction
//! on the adaptive kernels in [`crate::graph::setops`], factored out of
//! the per-engine scalar loops so the ESU, BFS and FSM engines extend
//! embeddings through the same substrate as the set-centric DFS engine.
//!
//! The paper's central claim is that *one* framework serves every GPM
//! workload from one efficient substrate. Before this module, only the
//! pattern-guided DFS engine did: ESU probed a `visited[]` boolean
//! array per candidate, BFS recomputed MEC codes with one `has_edge`
//! binary search per (candidate, position) pair, and FSM scanned the
//! whole embedding per neighbor to classify back vs forward edges. The
//! core replaces those with:
//!
//! * **Exclusive-neighbor sets** ([`ExtCore::exclusive_into`], ESU): a
//!   coverage bitmap (`emb ∪ N(emb)`, maintained with the same
//!   mark/unmark discipline as the seed `visited[]`) anti-intersected
//!   against the bounded candidate tail — O(1) bitset probes in the
//!   sparse regime, the word-parallel
//!   [`setops::andnot_words_into`] kernel past the dense crossover
//!   ([`DENSE_EXCL_WORD_FACTOR`], the §PR-3 bitset×bitset shape).
//! * **Exclusive-neighbor chains** ([`ExtCore::exclusive_chain_into`],
//!   BFS): the same set expressed as a ping-pong
//!   [`setops::difference_into`] chain over the matched prefix's
//!   adjacency lists — BFS embeddings are independent, so there is no
//!   incremental bitmap to consult.
//! * **Batched MEC codes** ([`ExtCore::codes_for`]): the
//!   positions-adjacency codes of a whole candidate list in one
//!   adaptive intersection per embedding position, instead of one
//!   `has_edge` probe per (candidate, position) pair.
//! * **Member/fresh neighbor splits** ([`ExtCore::members_and_fresh`],
//!   FSM): one intersection + one anti-intersection against the sorted
//!   embedding classify every neighbor as a back-edge target (with its
//!   position recovered by binary search) or a forward-edge target,
//!   replacing the per-neighbor O(k) `position()` scan.
//! * **The SoA embedding arena** ([`EmbArena`], FSM): each sub-pattern
//!   bin stores its embeddings as one flat `Vec<VertexId>` with a
//!   stride, so extension is a linear scan over contiguous rows instead
//!   of pointer chasing through `Vec<Vec<VertexId>>`, and deduplication
//!   is one deterministic sort instead of a `HashSet` per bin.
//!
//! Every engine keeps its seed scalar loop alive verbatim as the
//! differential oracle, selected by `OptFlags::extcore = false` or the
//! process-wide `SANDSLASH_NO_EXTCORE=1` kill switch — the same
//! oracle-vs-fast-path contract as the SIMD kernels
//! (`SANDSLASH_NO_SIMD`) and the scheduler (`SANDSLASH_NO_STEAL`).
//! Results must be bit-identical; `rust/tests/extcore_differential.rs`
//! holds the invariance matrix.

use std::sync::OnceLock;

use crate::graph::{setops, CsrGraph, VertexId};
use crate::util::bitset::BitSet;

/// Process-wide extension-core default: `false` only under
/// `SANDSLASH_NO_EXTCORE` (any non-empty value other than `0`) — the CI
/// oracle leg's kill switch, same contract as `SANDSLASH_NO_SIMD` and
/// `SANDSLASH_NO_STEAL`. Cached for the process lifetime.
pub fn extcore_enabled_default() -> bool {
    static CACHE: OnceLock<bool> = OnceLock::new();
    *CACHE.get_or_init(|| {
        !std::env::var("SANDSLASH_NO_EXTCORE")
            .is_ok_and(|v| !v.trim().is_empty() && v.trim() != "0")
    })
}

/// Dense crossover for the exclusive-neighbor construction: once the
/// bounded candidate tail reaches `(cover words) ×` this factor, the
/// per-element bitset probes are replaced by publishing the tail as a
/// second bitmap and sweeping `cand & !cover` word-parallel — the same
/// break-even shape as the §PR-3 `DENSE_FRONTIER_WORD_FACTOR` (the
/// AND-NOT costs one pass over the word array regardless of tail
/// length, the probe filter one dependent load per element; 4 covers
/// the tail-bitmap build on top of break-even).
pub const DENSE_EXCL_WORD_FACTOR: usize = 4;

/// Reusable per-thread buffers for the extension core. All storage is
/// recycled across root tasks — zero allocation on the hot path once
/// warm, exactly like the DFS engine's `Frontier`.
#[derive(Default)]
pub struct ExtCore {
    /// Coverage bitmap: the embedding and its neighborhood (ESU's
    /// `visited` set), maintained by the engine through
    /// [`cover_mark`](Self::cover_mark)/[`cover_unmark`](Self::cover_unmark).
    cover: BitSet,
    /// Scratch bitmap for the dense anti-intersection path.
    cand_bits: BitSet,
    /// Sorted copy of an unsorted candidate list ([`codes_for`](Self::codes_for)).
    sorted: Vec<VertexId>,
    /// `order[i]` = original index of `sorted[i]`.
    order: Vec<u32>,
    /// Ping-pong scratch lists.
    scratch_a: Vec<VertexId>,
    scratch_b: Vec<VertexId>,
}

impl ExtCore {
    /// Fresh core with empty buffers (they size lazily to the graph).
    pub fn new() -> Self {
        Self::default()
    }

    /// Size the coverage bitmap for a graph of `n` vertices. Must be
    /// called before the first [`cover_mark`](Self::cover_mark) of a
    /// root task; keeps existing capacity when already large enough.
    pub fn begin_root(&mut self, n: usize) {
        if self.cover.capacity() < n {
            self.cover = BitSet::new(n);
        }
    }

    /// Mark `u` as covered (in the embedding or its neighborhood). The
    /// engine tracks what it marked and must
    /// [`cover_unmark`](Self::cover_unmark) exactly that on backtrack —
    /// the same symmetric discipline as the seed `visited[]` array.
    #[inline]
    pub fn cover_mark(&mut self, u: usize) {
        self.cover.insert(u);
    }

    /// Unmark `u` (symmetric pop of [`cover_mark`](Self::cover_mark)).
    #[inline]
    pub fn cover_unmark(&mut self, u: usize) {
        self.cover.remove(u);
    }

    /// Whether `u` is currently covered.
    #[inline]
    pub fn cover_contains(&self, u: usize) -> bool {
        self.cover.contains(u)
    }

    fn ensure_cand_bits(&mut self, n: usize) {
        if self.cand_bits.capacity() < n {
            self.cand_bits = BitSet::new(n);
        }
    }

    /// Exclusive neighbors of `w` for ESU: `{u ∈ N(w) : u > root}`
    /// minus the coverage bitmap, appended to `out` in ascending order
    /// (`out`'s prior content — the inherited remaining candidates — is
    /// kept). Sparse tails probe the bitmap per element; past the
    /// [`DENSE_EXCL_WORD_FACTOR`] crossover the tail is published as a
    /// bitmap and swept with the word-parallel AND-NOT kernel.
    pub fn exclusive_into(
        &mut self,
        g: &CsrGraph,
        w: VertexId,
        root: VertexId,
        out: &mut Vec<VertexId>,
    ) {
        let nbrs = g.neighbors(w);
        let tail = &nbrs[nbrs.partition_point(|&x| x <= root)..];
        if tail.is_empty() {
            return;
        }
        let words = self.cover.capacity() / 64;
        if tail.len() >= words.saturating_mul(DENSE_EXCL_WORD_FACTOR).max(1) {
            crate::obs::trace::on_excl_dense();
            self.ensure_cand_bits(self.cover.capacity());
            for &u in tail {
                self.cand_bits.insert(u as usize);
            }
            setops::andnot_words_into(self.cand_bits.words(), self.cover.words(), out);
            self.cand_bits.clear();
        } else {
            crate::obs::trace::on_excl_sparse();
            for &u in tail {
                if !self.cover.contains(u as usize) {
                    out.push(u);
                }
            }
        }
    }

    /// Exclusive neighbors of `w` for BFS: the same set as
    /// [`exclusive_into`](Self::exclusive_into) but computed without an
    /// incremental bitmap — a ping-pong [`setops::difference_into`]
    /// chain of the bounded tail against every matched vertex's
    /// adjacency list. Sound because every non-root prefix vertex is a
    /// neighbor of the (still-matched) vertex whose expansion added it,
    /// so the chain removes embedding members along with their
    /// neighborhoods; the root itself is excluded by the `> root`
    /// bound. Appends to `out` in ascending order.
    pub fn exclusive_chain_into(
        &mut self,
        g: &CsrGraph,
        w: VertexId,
        root: VertexId,
        prefix: &[VertexId],
        out: &mut Vec<VertexId>,
    ) {
        let nbrs = g.neighbors(w);
        let tail = &nbrs[nbrs.partition_point(|&x| x <= root)..];
        if tail.is_empty() {
            return;
        }
        self.scratch_a.clear();
        self.scratch_a.extend_from_slice(tail);
        for &v in prefix {
            if self.scratch_a.is_empty() {
                break;
            }
            self.scratch_b.clear();
            setops::difference_into(&self.scratch_a, g.neighbors(v), &mut self.scratch_b);
            std::mem::swap(&mut self.scratch_a, &mut self.scratch_b);
        }
        out.extend_from_slice(&self.scratch_a);
    }

    /// Batched MEC codes: `codes[i]` receives the bitmask of positions
    /// `j` with `cands[i] ∈ N(verts[j])`, computed with one adaptive
    /// intersection per embedding position instead of one `has_edge`
    /// probe per (candidate, position) pair. `cands` may be unsorted
    /// but must be duplicate-free (ESU/BFS extension sets are).
    pub fn codes_for(
        &mut self,
        g: &CsrGraph,
        verts: &[VertexId],
        cands: &[VertexId],
        codes: &mut Vec<u32>,
    ) {
        codes.clear();
        codes.resize(cands.len(), 0);
        if cands.is_empty() || verts.is_empty() {
            return;
        }
        self.order.clear();
        self.order.extend(0..cands.len() as u32);
        self.order.sort_unstable_by_key(|&i| cands[i as usize]);
        self.sorted.clear();
        self.sorted.extend(self.order.iter().map(|&i| cands[i as usize]));
        for (j, &v) in verts.iter().enumerate() {
            self.scratch_a.clear();
            setops::intersect_into(&self.sorted, g.neighbors(v), &mut self.scratch_a);
            // scratch ⊆ sorted and both ascend: one two-pointer walk
            // scatters the hits back through `order`
            let mut i = 0usize;
            for &x in &self.scratch_a {
                while self.sorted[i] != x {
                    i += 1;
                }
                codes[self.order[i] as usize] |= 1 << j;
                i += 1;
            }
        }
    }

    /// FSM neighbor classification: split `N(v)` into `members` (also
    /// mapped by the embedding — back-edge targets) and `fresh` (not
    /// mapped — forward-edge targets) with one adaptive intersection
    /// plus one anti-intersection against the *sorted* embedding,
    /// replacing the per-neighbor O(k) position scan. Both outputs are
    /// cleared first and ascend.
    pub fn members_and_fresh(
        &mut self,
        g: &CsrGraph,
        sorted_emb: &[VertexId],
        v: VertexId,
        members: &mut Vec<VertexId>,
        fresh: &mut Vec<VertexId>,
    ) {
        members.clear();
        fresh.clear();
        setops::intersect_into(sorted_emb, g.neighbors(v), members);
        setops::difference_into(g.neighbors(v), sorted_emb, fresh);
    }
}

/// Flat structure-of-arrays embedding storage for one FSM sub-pattern
/// bin: `len() = data.len() / stride` rows of `stride` vertices each,
/// contiguous in memory. Extension iterates [`rows`](Self::rows) — a
/// linear scan — and deduplication ([`sort_dedup`](Self::sort_dedup))
/// is one deterministic lexicographic sort, replacing the seed's
/// `HashSet<Vec<VertexId>>` per bin (whose iteration order was also
/// nondeterministic; arenas make every downstream order canonical).
/// Deliberately no `Default`: a stride-0 arena would bypass the
/// [`EmbArena::new`] invariant every accessor relies on.
#[derive(Clone, Debug)]
pub struct EmbArena {
    data: Vec<VertexId>,
    stride: usize,
}

impl EmbArena {
    /// Empty arena for rows of `stride` vertices.
    pub fn new(stride: usize) -> Self {
        assert!(stride > 0, "embedding rows need at least one vertex");
        Self { data: Vec::new(), stride }
    }

    /// Vertices per row.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        self.data.len() / self.stride
    }

    /// Whether the arena holds no rows.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Append one row (must match the stride).
    #[inline]
    pub fn push_row(&mut self, row: &[VertexId]) {
        debug_assert_eq!(row.len(), self.stride, "row width must match the arena stride");
        self.data.extend_from_slice(row);
    }

    /// Row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[VertexId] {
        &self.data[i * self.stride..(i + 1) * self.stride]
    }

    /// Iterate rows in storage order (the linear scan FSM extension
    /// runs).
    pub fn rows(&self) -> std::slice::ChunksExact<'_, VertexId> {
        self.data.chunks_exact(self.stride)
    }

    /// Sort rows lexicographically and drop exact duplicates — the
    /// arena equivalent of the seed's per-bin `HashSet`, but with a
    /// canonical (deterministic) row order. Duplicates are held until
    /// this seal step instead of being rejected on insert; callers seal
    /// once per expansion, before support evaluation.
    pub fn sort_dedup(&mut self) {
        let k = self.stride;
        if self.data.len() <= k {
            return;
        }
        let rows = self.data.len() / k;
        let mut idx: Vec<u32> = (0..rows as u32).collect();
        let data = &self.data;
        idx.sort_unstable_by(|&a, &b| {
            data[a as usize * k..(a as usize + 1) * k]
                .cmp(&data[b as usize * k..(b as usize + 1) * k])
        });
        let mut out = Vec::with_capacity(self.data.len());
        for &i in &idx {
            let row = &self.data[i as usize * k..(i as usize + 1) * k];
            if out.len() < k || &out[out.len() - k..] != row {
                out.extend_from_slice(row);
            }
        }
        self.data = out;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn exclusive_matches_scalar_probe_on_both_regimes() {
        // two_hub: hub tails take the dense AND-NOT path, leaf tails
        // the sparse probes; both must equal the seed visited[] filter
        let g = gen::two_hub(300);
        let mut core = ExtCore::new();
        let n = g.num_vertices();
        core.begin_root(n);
        // a leaf root, so hub tails keep real survivors on both paths
        let root: VertexId = 2;
        let mut visited = vec![false; n];
        visited[root as usize] = true;
        core.cover_mark(root as usize);
        for &u in g.neighbors(root) {
            visited[u as usize] = true;
            core.cover_mark(u as usize);
        }
        for w in [1u32, 5, 150] {
            let mut got = Vec::new();
            core.exclusive_into(&g, w, root, &mut got);
            let want: Vec<VertexId> = g
                .neighbors(w)
                .iter()
                .copied()
                .filter(|&u| u > root && !visited[u as usize])
                .collect();
            assert_eq!(got, want, "w={w}");
            if w == 1 {
                // the hub tail must be a real dense-path workload
                assert!(want.len() > 100, "degenerate dense case");
            }
            // the chain form (no bitmap) agrees on the same set
            let mut chained = Vec::new();
            core.exclusive_chain_into(&g, w, root, &[root], &mut chained);
            assert_eq!(chained, want, "chain w={w}");
        }
        for &u in g.neighbors(root) {
            core.cover_unmark(u as usize);
        }
        core.cover_unmark(root as usize);
    }

    #[test]
    fn codes_match_per_pair_probes() {
        let g = gen::erdos_renyi(60, 0.2, 7, &[]);
        let mut core = ExtCore::new();
        let verts: Vec<VertexId> = vec![3, 17, 41];
        // unsorted, duplicate-free candidate list
        let cands: Vec<VertexId> = vec![50, 2, 33, 4, 59, 18];
        let mut codes = Vec::new();
        core.codes_for(&g, &verts, &cands, &mut codes);
        for (i, &c) in cands.iter().enumerate() {
            let want = verts
                .iter()
                .enumerate()
                .fold(0u32, |m, (j, &v)| m | ((g.has_edge(v, c) as u32) << j));
            assert_eq!(codes[i], want, "candidate {c}");
        }
        // empty inputs produce empty/zero codes
        core.codes_for(&g, &verts, &[], &mut codes);
        assert!(codes.is_empty());
        core.codes_for(&g, &[], &cands, &mut codes);
        assert_eq!(codes, vec![0; cands.len()]);
    }

    #[test]
    fn members_and_fresh_partition_the_neighborhood() {
        let g = gen::erdos_renyi(50, 0.25, 9, &[]);
        let mut core = ExtCore::new();
        let mut emb: Vec<VertexId> = vec![4, 11, 30, 42];
        emb.sort_unstable();
        let (mut members, mut fresh) = (Vec::new(), Vec::new());
        for v in [4u32, 11, 30] {
            core.members_and_fresh(&g, &emb, v, &mut members, &mut fresh);
            let want_members: Vec<VertexId> =
                g.neighbors(v).iter().copied().filter(|u| emb.contains(u)).collect();
            let want_fresh: Vec<VertexId> =
                g.neighbors(v).iter().copied().filter(|u| !emb.contains(u)).collect();
            assert_eq!(members, want_members, "v={v}");
            assert_eq!(fresh, want_fresh, "v={v}");
        }
    }

    #[test]
    fn arena_rows_round_trip_and_dedup_deterministically() {
        let mut a = EmbArena::new(3);
        assert!(a.is_empty());
        a.push_row(&[5, 1, 9]);
        a.push_row(&[2, 2, 2]);
        a.push_row(&[5, 1, 9]); // duplicate
        a.push_row(&[2, 2, 1]);
        assert_eq!(a.len(), 4);
        assert_eq!(a.row(1), &[2, 2, 2]);
        a.sort_dedup();
        let rows: Vec<&[VertexId]> = a.rows().collect();
        assert_eq!(rows, vec![&[2u32, 2, 1][..], &[2, 2, 2], &[5, 1, 9]]);
        // idempotent
        a.sort_dedup();
        assert_eq!(a.len(), 3);
        // single-row and empty arenas are fixpoints
        let mut one = EmbArena::new(2);
        one.push_row(&[7, 8]);
        one.sort_dedup();
        assert_eq!(one.row(0), &[7, 8]);
    }

    #[test]
    fn kill_switch_resolution_is_cached_and_boolean() {
        // cannot set the env here (OnceLock pins first resolution); the
        // contract is stability across calls
        let first = extcore_enabled_default();
        assert_eq!(extcore_enabled_default(), first);
    }
}
