//! The Sandslash low-level API (paper Listing 1).
//!
//! Users customize the mining process by implementing this trait; every
//! method has a pass-through default so a "no-op hooks" implementation
//! costs nothing (the engines are generic over `H: LowLevelApi`, so the
//! defaults inline away — no virtual dispatch on the hot path).
//!
//! Mapping to the paper's API:
//! * `to_extend(emb, pos)`      — Listing 1 line 1
//! * `to_add(g, emb, u, level)` — Listing 1 line 2 (vertex extension)
//! * `get_pattern(codes)`       — Listing 1 line 4 (CP optimization)
//! * `local_reduce(...)`        — Listing 1 line 5 (LC optimization)
//! * local-graph search (`initLG`/`updateLG`, lines 6–8) is provided by
//!   [`crate::engine::local_graph::LocalGraph`], which the k-CL-Lo app
//!   drives exactly as in the paper's Listing 4.

use crate::graph::{CsrGraph, VertexId};

/// The low-level customization hooks of the paper's Listing 1 (see
/// the module docs for the line-by-line mapping).
pub trait LowLevelApi: Sync {
    /// Should the embedding vertex at `pos` be extended? (FP)
    #[inline]
    fn to_extend(&self, _emb: &[VertexId], _pos: usize) -> bool {
        true
    }

    /// May the embedding be extended with vertex `u` at `level`? (FP)
    #[inline]
    fn to_add(&self, _g: &CsrGraph, _emb: &[VertexId], _u: VertexId, _level: usize) -> bool {
        true
    }

    /// Classify the pattern of a full embedding from its packed
    /// connectivity codes; return a pattern id. (CP) `None` = use the
    /// system's canonical classification.
    #[inline]
    fn get_pattern(&self, _packed_codes: u64) -> Option<usize> {
        None
    }

    /// Accumulate formula-based local counts at `depth`. (LC)
    #[inline]
    fn local_reduce(&self, _g: &CsrGraph, _depth: usize, _emb: &[VertexId], _supports: &mut [i64]) {
    }
}

/// The high-level path: no customization.
#[derive(Default, Clone, Copy)]
pub struct NoHooks;

impl LowLevelApi for NoHooks {}

#[cfg(test)]
mod tests {
    use super::*;

    struct OnlyEven;
    impl LowLevelApi for OnlyEven {
        fn to_add(&self, _g: &CsrGraph, _emb: &[VertexId], u: VertexId, _l: usize) -> bool {
            u % 2 == 0
        }
    }

    #[test]
    fn defaults_pass_through() {
        let g = crate::graph::gen::ring(4);
        let h = NoHooks;
        assert!(h.to_extend(&[0], 0));
        assert!(h.to_add(&g, &[0], 1, 1));
        assert_eq!(h.get_pattern(0), None);
    }

    #[test]
    fn custom_hook_overrides() {
        let g = crate::graph::gen::ring(4);
        let h = OnlyEven;
        assert!(h.to_add(&g, &[], 2, 0));
        assert!(!h.to_add(&g, &[], 3, 0));
    }
}
