//! BFS (level-synchronous) exploration engine — the strategy of
//! Arabesque/RStream/Pangolin (paper §4.1). Materializes the entire
//! embedding list of each level before producing the next, which exposes
//! maximal parallelism but pays the memory cost the paper measures
//! (Pangolin: 3.5 TB vs Sandslash 436 GB on Gsh). Used here as the
//! faithful substrate for the Pangolin-like system emulation in the
//! benchmark tables.
//!
//! # Extension paths (PR 5)
//!
//! Expansion runs on one of two paths:
//!
//! * **Extension core** (`opts.extcore`, the default): MEC codes for a
//!   whole extension list come from one batched
//!   [`ExtCore::codes_for`] pass (one adaptive intersection per
//!   embedding position), and each child's exclusive-neighbor set from
//!   the [`ExtCore::exclusive_chain_into`] anti-intersection chain —
//!   no per-(candidate, position) `has_edge` probes, no per-neighbor
//!   `contains`/`any` scans.
//! * **Scalar oracle** (`opts.extcore` off or `SANDSLASH_NO_EXTCORE=1`):
//!   the seed loops, kept verbatim. Level contents are identical
//!   element-for-element, so counts *and* `peak_embeddings` agree
//!   (`rust/tests/extcore_differential.rs`).
//!
//! # The level byte budget (PR 5)
//!
//! Because materialization is the whole point of this engine, a large
//! input can OOM-kill the host before producing a row. Each level's
//! estimated footprint is therefore held to a byte budget —
//! [`Budget::bfs_bytes`] (set via [`MinerConfig::with_bfs_cap`]), the
//! `SANDSLASH_BFS_CAP` environment
//! override, or [`DEFAULT_BFS_CAP_BYTES`] — enforced *while* the level
//! materializes: workers add each expanded embedding's footprint to a
//! shared running total and stop expanding as soon as it crosses the
//! budget (slack is bounded by one parent embedding's children per
//! worker, not by the level), and the run aborts with a
//! [`BfsCapExceeded`] diagnosis instead of dying silently. A post-hoc
//! check alone would defend nothing — the over-budget level would
//! already be resident when it ran.
//!
//! # Governance (PR 6)
//!
//! The engine is governed like its DFS siblings: each delivered
//! scheduler task is charged against the run's [`Budget`], the cancel
//! token is polled per expanded parent (the BFS analogue of the
//! level-1 candidate poll), and a trip drains the remaining tasks and
//! returns a partial [`Outcome`] — zero counts when the trip lands
//! before the final classify level, a prefix of the counts when it
//! lands inside it. Worker panics surface as
//! [`MineError::WorkerPanicked`] with the process intact.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;

use crate::exec::sched::{self, SchedPolicy, Task};
use crate::graph::{CsrGraph, VertexId};
use crate::util::fault;
use crate::util::metrics::{tag, SearchStats};
use crate::util::pool::positive_usize_env;

use super::budget::{self, Budget, Governor, MineError, Outcome};
use super::embedding::pack_codes;
use super::esu::MotifTable;
use super::extend::ExtCore;
use super::opts::MinerConfig;

/// Built-in byte budget for one materialized BFS level (8 GiB): far
/// above anything the test/bench inputs materialize, low enough that a
/// runaway emulation fails with a diagnosis before the OOM killer gets
/// involved. Override per run with [`MinerConfig::with_bfs_cap`] or
/// process-wide with `SANDSLASH_BFS_CAP` (bytes).
pub const DEFAULT_BFS_CAP_BYTES: usize = 8 << 30;

/// Resolve the process-wide BFS level budget: `SANDSLASH_BFS_CAP`
/// (loud-reject parse, like every `SANDSLASH_*` numeric knob) or the
/// built-in default. Cached for the process lifetime.
fn default_bfs_cap() -> usize {
    static CACHE: OnceLock<usize> = OnceLock::new();
    *CACHE.get_or_init(|| {
        positive_usize_env("SANDSLASH_BFS_CAP", "the built-in 8 GiB BFS level budget")
            .unwrap_or(DEFAULT_BFS_CAP_BYTES)
    })
}

/// A materialized BFS level exceeded the byte budget. The message names
/// both knobs so the fix is actionable from the error alone.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BfsCapExceeded {
    /// 1-based level (embedding size) that blew the budget.
    pub level: usize,
    /// Embeddings materialized when the budget tripped (a partial
    /// level: expansion stops as soon as the running total crosses the
    /// budget).
    pub embeddings: u64,
    /// Estimated bytes materialized when the budget tripped.
    pub bytes: u64,
    /// The budget that was in force.
    pub cap: u64,
}

impl std::fmt::Display for BfsCapExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "BFS level {} materialized {} embeddings (~{} bytes), over the {}-byte level \
             budget; raise SANDSLASH_BFS_CAP (or MinerConfig::with_bfs_cap) to proceed, or \
             use a DFS engine, which never materializes levels",
            self.level, self.embeddings, self.bytes, self.cap
        )
    }
}

impl std::error::Error for BfsCapExceeded {}

/// One BFS embedding: vertices, MEC codes, ESU extension set.
#[derive(Clone, Debug)]
struct BfsEmb {
    verts: Vec<VertexId>,
    codes: Vec<u32>,
    ext: Vec<VertexId>,
}

/// Result of a BFS motif count: per-motif counts plus the peak number of
/// materialized embeddings (the memory-pressure proxy reported in
/// EXPERIMENTS.md).
#[derive(Debug)]
pub struct BfsOutcome {
    /// Per-motif counts (library order).
    pub counts: Vec<u64>,
    /// Peak number of simultaneously materialized embeddings.
    pub peak_embeddings: u64,
}

/// Estimated heap footprint of one materialized embedding: struct
/// overhead plus the element storage of its three vectors (an
/// under-estimate — it ignores allocator slack — which is fine for a
/// budget meant to trip well before the OOM killer would).
#[inline]
fn emb_bytes(e: &BfsEmb) -> u64 {
    let fixed = std::mem::size_of::<BfsEmb>() as u64;
    let elem = std::mem::size_of::<VertexId>() as u64;
    fixed + (e.verts.len() + e.codes.len() + e.ext.len()) as u64 * elem
}

/// Estimated heap footprint of one (possibly partial) level.
fn level_bytes(level: &[BfsEmb]) -> u64 {
    level.iter().map(emb_bytes).sum()
}

fn check_budget(level_no: usize, level: &[BfsEmb], cap: usize) -> Result<(), BfsCapExceeded> {
    let bytes = level_bytes(level);
    if bytes > cap as u64 {
        return Err(BfsCapExceeded {
            level: level_no,
            embeddings: level.len() as u64,
            bytes,
            cap: cap as u64,
        });
    }
    Ok(())
}

/// Count k-motifs with level-synchronous ESU expansion, or fail loudly
/// when a materialized level would exceed the byte budget (module
/// docs). Governed (PR 6): see the module-level governance section.
pub fn bfs_count_motifs(
    g: &CsrGraph,
    k: usize,
    cfg: &MinerConfig,
    table: &MotifTable,
) -> Result<Outcome<BfsOutcome>, MineError> {
    assert!(k >= 3);
    let n = g.num_vertices();
    let use_core = cfg.opts.extcore_active();
    let cap = cfg.budget.bfs_bytes.unwrap_or_else(default_bfs_cap);
    let pol = SchedPolicy::auto(cfg.threads, cfg.chunk.max(1));
    let gov = budget::governance_enabled().then(|| Governor::new(&cfg.budget));
    // level 1: single-vertex embeddings with ext = {u in N(v) : u > v}
    let mut level: Vec<BfsEmb> = (0..n as VertexId)
        .map(|v| BfsEmb {
            verts: vec![v],
            codes: vec![0],
            ext: g.neighbors(v).iter().copied().filter(|&u| u > v).collect(),
        })
        .collect();
    check_budget(1, &level, cap)?;
    let mut peak = level.len() as u64;
    let mut stats = SearchStats::default();
    stats.enumerated += level.len() as u64;

    for depth in 1..(k - 1) {
        // The budget is enforced *during* materialization: a shared
        // running byte total, bumped per expanded parent, flips `over`
        // as soon as the level crosses the cap, and every later parent
        // is skipped — so the resident overshoot is bounded by one
        // parent's children per worker, not by the level. (A post-hoc
        // check alone would run only after the damage was resident.)
        let spent = AtomicU64::new(0);
        let over = AtomicBool::new(false);
        let next = sched::reduce_governed(
            level.len(),
            &pol,
            gov.as_ref(),
            || (Vec::new(), ExtCore::new(), Vec::new()),
            |acc: &mut (Vec<BfsEmb>, ExtCore, Vec<u32>), ctx, task| {
                let Task::Roots { start: lo, end: hi } = task else {
                    unreachable!("the BFS engine never publishes split tasks")
                };
                for i in lo..hi {
                    if over.load(Ordering::Relaxed) || ctx.cancelled() {
                        return;
                    }
                    // one crossing per expanded parent (PR 6 fault grammar)
                    fault::point(fault::Stage::BfsLevel);
                    let (out, core, codes_buf) = acc;
                    let e = &level[i];
                    let start = out.len();
                    tag::with_engine(tag::Engine::Bfs, || {
                        if use_core {
                            expand_core(g, core, codes_buf, e, out);
                        } else {
                            expand(g, e, depth, out);
                        }
                    });
                    let added: u64 = out[start..].iter().map(emb_bytes).sum();
                    if spent.fetch_add(added, Ordering::Relaxed) + added > cap as u64 {
                        over.store(true, Ordering::Relaxed);
                    }
                }
            },
            |mut a, b| {
                a.0.extend(b.0);
                a
            },
        )
        .0;
        if over.load(Ordering::Relaxed) {
            return Err(BfsCapExceeded {
                level: depth + 1,
                embeddings: next.len() as u64,
                bytes: level_bytes(&next),
                cap: cap as u64,
            }
            .into());
        }
        stats.enumerated += next.len() as u64;
        peak = peak.max(next.len() as u64);
        // belt over suspenders: the incremental total and the sealed
        // level must agree on being under budget
        check_budget(depth + 1, &next, cap)?;
        level = next;
    }

    // final level: classify instead of materializing
    let nm = table.num_motifs;
    let counts = sched::reduce_governed(
        level.len(),
        &pol,
        gov.as_ref(),
        || (vec![0u64; nm], ExtCore::new(), Vec::new(), Vec::new()),
        |acc: &mut (Vec<u64>, ExtCore, Vec<u32>, Vec<u32>), ctx, task| {
            let Task::Roots { start: lo, end: hi } = task else {
                unreachable!("the BFS engine never publishes split tasks")
            };
            for i in lo..hi {
                if ctx.cancelled() {
                    return;
                }
                // one crossing per classified parent (PR 6 fault grammar)
                fault::point(fault::Stage::BfsLevel);
                let (counts, core, codes_buf, code_stack) = acc;
                let e = &level[i];
                tag::with_engine(tag::Engine::Bfs, || {
                if use_core {
                    // batched MEC codes: one adaptive intersection per
                    // position instead of |ext| × |verts| edge probes;
                    // the leaf code stack is a per-worker scratch with
                    // only its last slot rewritten per candidate — no
                    // allocation in the innermost loop
                    core.codes_for(g, &e.verts, &e.ext, codes_buf);
                    if e.ext.is_empty() {
                        return;
                    }
                    code_stack.clear();
                    code_stack.extend_from_slice(&e.codes);
                    code_stack.push(0);
                    for wi in 0..e.ext.len() {
                        *code_stack.last_mut().unwrap() = codes_buf[wi];
                        let id = table.classify(pack_codes(code_stack));
                        counts[id as usize] += 1;
                    }
                } else {
                    for &w in &e.ext {
                        let code = e
                            .verts
                            .iter()
                            .enumerate()
                            .fold(0u32, |c, (j, &u)| c | ((g.has_edge(u, w) as u32) << j));
                        let mut codes = e.codes.clone();
                        codes.push(code);
                        let id = table.classify(pack_codes(&codes));
                        counts[id as usize] += 1;
                    }
                }
                });
            }
        },
        |mut a, b| {
            for (x, y) in a.0.iter_mut().zip(b.0) {
                *x += y;
            }
            a
        },
    )
    .0;
    stats.matches = counts.iter().sum();
    stats.enumerated += stats.matches;
    let outcome = BfsOutcome { counts, peak_embeddings: peak };
    match gov {
        Some(gv) => gv.finish(outcome, stats, "bfs"),
        None => Ok(Outcome::complete(outcome, stats)),
    }
}

/// Seed scalar expansion, kept verbatim as the differential oracle: one
/// `has_edge` probe per (candidate, position) pair for the MEC code,
/// one `contains` + `any(has_edge)` scan per neighbor for the child
/// extension set.
fn expand(g: &CsrGraph, e: &BfsEmb, _depth: usize, out: &mut Vec<BfsEmb>) {
    let root = e.verts[0];
    for (wi, &w) in e.ext.iter().enumerate() {
        let code = e
            .verts
            .iter()
            .enumerate()
            .fold(0u32, |c, (j, &u)| c | ((g.has_edge(u, w) as u32) << j));
        let mut verts = e.verts.clone();
        verts.push(w);
        let mut codes = e.codes.clone();
        codes.push(code);
        // child ext: remaining candidates + exclusive neighbors of w
        let mut ext: Vec<VertexId> = e.ext[wi + 1..].to_vec();
        for &u in g.neighbors(w) {
            if u > root
                && !verts.contains(&u)
                && !e.verts.iter().any(|&s| g.has_edge(s, u))
            {
                ext.push(u);
            }
        }
        out.push(BfsEmb { verts, codes, ext });
    }
}

/// Extension-core twin of [`expand`]: batched codes, anti-intersection
/// chains — identical child embeddings in identical order.
fn expand_core(
    g: &CsrGraph,
    core: &mut ExtCore,
    codes_buf: &mut Vec<u32>,
    e: &BfsEmb,
    out: &mut Vec<BfsEmb>,
) {
    let root = e.verts[0];
    core.codes_for(g, &e.verts, &e.ext, codes_buf);
    for (wi, &w) in e.ext.iter().enumerate() {
        let mut verts = e.verts.clone();
        verts.push(w);
        let mut codes = e.codes.clone();
        codes.push(codes_buf[wi]);
        // child ext: remaining candidates + exclusive neighbors of w
        // (the chain also removes embedding members — extend docs)
        let mut ext: Vec<VertexId> = e.ext[wi + 1..].to_vec();
        core.exclusive_chain_into(g, w, root, &e.verts, &mut ext);
        out.push(BfsEmb { verts, codes, ext });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::esu::{count_motifs, MotifTable};
    use crate::engine::hooks::NoHooks;
    use crate::engine::opts::{MinerConfig, OptFlags};
    use crate::graph::gen;

    fn cfg() -> MinerConfig {
        MinerConfig::custom(2, 8, OptFlags::pangolin_like())
    }

    #[test]
    fn bfs_matches_dfs_motif_counts_k3() {
        let g = gen::rmat(7, 6, 21, &[]);
        let t = MotifTable::new(3);
        let bfs = bfs_count_motifs(&g, 3, &cfg(), &t).unwrap();
        assert!(bfs.complete);
        let (dfs, _) = count_motifs(&g, 3, &cfg(), &NoHooks, &t).unwrap().into_parts();
        assert_eq!(bfs.value.counts, dfs);
    }

    #[test]
    fn bfs_matches_dfs_motif_counts_k4() {
        let g = gen::erdos_renyi(60, 0.12, 9, &[]);
        let t = MotifTable::new(4);
        let bfs = bfs_count_motifs(&g, 4, &cfg(), &t).unwrap();
        let (dfs, _) = count_motifs(&g, 4, &cfg(), &NoHooks, &t).unwrap().into_parts();
        assert_eq!(bfs.value.counts, dfs);
    }

    #[test]
    fn core_and_oracle_agree_on_counts_and_peak() {
        let g = gen::rmat(7, 5, 33, &[]);
        let t = MotifTable::new(4);
        let core = bfs_count_motifs(&g, 4, &cfg(), &t).unwrap();
        let mut oracle_cfg = cfg();
        oracle_cfg.opts.extcore = false;
        let oracle = bfs_count_motifs(&g, 4, &oracle_cfg, &t).unwrap();
        assert_eq!(core.value.counts, oracle.value.counts);
        // levels are identical element-for-element, not just count-equal
        assert_eq!(core.value.peak_embeddings, oracle.value.peak_embeddings);
        assert_eq!(core.stats.enumerated, oracle.stats.enumerated);
    }

    #[test]
    fn peak_embeddings_grows_with_level() {
        let g = gen::erdos_renyi(50, 0.2, 3, &[]);
        let t = MotifTable::new(4);
        let out = bfs_count_motifs(&g, 4, &cfg(), &t).unwrap().value;
        // BFS materialization must exceed the vertex count on any
        // non-trivial graph
        assert!(out.peak_embeddings > 50);
    }

    #[test]
    fn byte_budget_trips_loudly_instead_of_materializing() {
        let g = gen::erdos_renyi(60, 0.15, 5, &[]);
        let t = MotifTable::new(4);
        let starved = cfg().with_bfs_cap(1024);
        let err = match bfs_count_motifs(&g, 4, &starved, &t) {
            Err(crate::engine::budget::MineError::BfsCapExceeded(e)) => e,
            other => panic!("1 KiB cannot hold a level: {other:?}"),
        };
        assert!(err.bytes > err.cap);
        assert!(err.embeddings > 0);
        let msg = format!("{err}");
        assert!(msg.contains("SANDSLASH_BFS_CAP"), "diagnosis must name the knob: {msg}");
        // a sane budget on the same input succeeds
        let ok = bfs_count_motifs(&g, 4, &cfg().with_bfs_cap(64 << 20), &t).unwrap().value;
        assert!(ok.counts.iter().sum::<u64>() > 0);
    }
}
