//! BFS (level-synchronous) exploration engine — the strategy of
//! Arabesque/RStream/Pangolin (paper §4.1). Materializes the entire
//! embedding list of each level before producing the next, which exposes
//! maximal parallelism but pays the memory cost the paper measures
//! (Pangolin: 3.5 TB vs Sandslash 436 GB on Gsh). Used here as the
//! faithful substrate for the Pangolin-like system emulation in the
//! benchmark tables.

use crate::graph::{CsrGraph, VertexId};
use crate::util::metrics::SearchStats;
use crate::util::pool::parallel_reduce;

use super::embedding::pack_codes;
use super::esu::MotifTable;
use super::opts::MinerConfig;

/// One BFS embedding: vertices, MEC codes, ESU extension set.
#[derive(Clone, Debug)]
struct BfsEmb {
    verts: Vec<VertexId>,
    codes: Vec<u32>,
    ext: Vec<VertexId>,
}

/// Result of a BFS motif count: per-motif counts plus the peak number of
/// materialized embeddings (the memory-pressure proxy reported in
/// EXPERIMENTS.md).
pub struct BfsOutcome {
    /// Per-motif counts (library order).
    pub counts: Vec<u64>,
    /// Search counters.
    pub stats: SearchStats,
    /// Peak number of simultaneously materialized embeddings.
    pub peak_embeddings: u64,
}

/// Count k-motifs with level-synchronous ESU expansion.
pub fn bfs_count_motifs(
    g: &CsrGraph,
    k: usize,
    cfg: &MinerConfig,
    table: &MotifTable,
) -> BfsOutcome {
    assert!(k >= 3);
    let n = g.num_vertices();
    // level 1: single-vertex embeddings with ext = {u in N(v) : u > v}
    let mut level: Vec<BfsEmb> = (0..n as VertexId)
        .map(|v| BfsEmb {
            verts: vec![v],
            codes: vec![0],
            ext: g.neighbors(v).iter().copied().filter(|&u| u > v).collect(),
        })
        .collect();
    let mut peak = level.len() as u64;
    let mut stats = SearchStats::default();
    stats.enumerated += level.len() as u64;

    for depth in 1..(k - 1) {
        let next = parallel_reduce(
            level.len(),
            cfg.threads,
            cfg.chunk.max(1),
            Vec::new,
            |out: &mut Vec<BfsEmb>, i| {
                let e = &level[i];
                expand(g, e, depth, out);
            },
            |mut a, b| {
                a.extend(b);
                a
            },
        );
        stats.enumerated += next.len() as u64;
        peak = peak.max(next.len() as u64);
        level = next;
    }

    // final level: classify instead of materializing
    let nm = table.num_motifs;
    let counts = parallel_reduce(
        level.len(),
        cfg.threads,
        cfg.chunk.max(1),
        || vec![0u64; nm],
        |acc: &mut Vec<u64>, i| {
            let e = &level[i];
            for &w in &e.ext {
                let code = e
                    .verts
                    .iter()
                    .enumerate()
                    .fold(0u32, |c, (j, &u)| c | ((g.has_edge(u, w) as u32) << j));
                let mut codes = e.codes.clone();
                codes.push(code);
                let id = table.classify(pack_codes(&codes));
                acc[id as usize] += 1;
            }
        },
        |mut a, b| {
            for (x, y) in a.iter_mut().zip(b) {
                *x += y;
            }
            a
        },
    );
    stats.matches = counts.iter().sum();
    stats.enumerated += stats.matches;
    BfsOutcome { counts, stats, peak_embeddings: peak }
}

fn expand(g: &CsrGraph, e: &BfsEmb, _depth: usize, out: &mut Vec<BfsEmb>) {
    let root = e.verts[0];
    for (wi, &w) in e.ext.iter().enumerate() {
        let code = e
            .verts
            .iter()
            .enumerate()
            .fold(0u32, |c, (j, &u)| c | ((g.has_edge(u, w) as u32) << j));
        let mut verts = e.verts.clone();
        verts.push(w);
        let mut codes = e.codes.clone();
        codes.push(code);
        // child ext: remaining candidates + exclusive neighbors of w
        let mut ext: Vec<VertexId> = e.ext[wi + 1..].to_vec();
        for &u in g.neighbors(w) {
            if u > root
                && !verts.contains(&u)
                && !e.verts.iter().any(|&s| g.has_edge(s, u))
            {
                ext.push(u);
            }
        }
        out.push(BfsEmb { verts, codes, ext });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::esu::{count_motifs, MotifTable};
    use crate::engine::hooks::NoHooks;
    use crate::engine::opts::{MinerConfig, OptFlags};
    use crate::graph::gen;

    fn cfg() -> MinerConfig {
        MinerConfig::custom(2, 8, OptFlags::pangolin_like())
    }

    #[test]
    fn bfs_matches_dfs_motif_counts_k3() {
        let g = gen::rmat(7, 6, 21, &[]);
        let t = MotifTable::new(3);
        let bfs = bfs_count_motifs(&g, 3, &cfg(), &t);
        let (dfs, _) = count_motifs(&g, 3, &cfg(), &NoHooks, &t);
        assert_eq!(bfs.counts, dfs);
    }

    #[test]
    fn bfs_matches_dfs_motif_counts_k4() {
        let g = gen::erdos_renyi(60, 0.12, 9, &[]);
        let t = MotifTable::new(4);
        let bfs = bfs_count_motifs(&g, 4, &cfg(), &t);
        let (dfs, _) = count_motifs(&g, 4, &cfg(), &NoHooks, &t);
        assert_eq!(bfs.counts, dfs);
    }

    #[test]
    fn peak_embeddings_grows_with_level() {
        let g = gen::erdos_renyi(50, 0.2, 3, &[]);
        let t = MotifTable::new(4);
        let out = bfs_count_motifs(&g, 4, &cfg(), &t);
        // BFS materialization must exceed the vertex count on any
        // non-trivial graph
        assert!(out.peak_embeddings > 50);
    }
}
